#!/usr/bin/env python3
"""Perf-delta table + soft regression gate for the bench-smoke CI job.

Downloads the bench-results.json artifacts from the previous successful
runs of this workflow on main (via the `gh` CLI baked into GitHub
runners), joins them with the current run's results by bench name, and
renders a markdown delta table into the job summary.

Gating policy (soft gate): smoke-mode numbers are noisy, so a single bad
comparison only warns. The job fails (exit 1) only when the same bench
regresses by more than REGRESSION_THRESHOLD on *two consecutive runs*
against the same older baseline: both this run and the previous
successful main run must be slower than the run before that. A noisy
current run cannot gate (the previous run was healthy), and a noisy
baseline cannot gate (the comparison anchors on the older baseline).
Infrastructure errors (no artifacts, gh failures) degrade to a note in
the summary and exit 0.
"""

import argparse
import json
import os
import subprocess
import sys
import tempfile

# A bench "regresses" when its metric is worse than a baseline by more
# than this fraction; it gates the job only when the regression shows on
# two consecutive runs (this one and the previous successful main run,
# both measured against the run before that).
REGRESSION_THRESHOLD = 0.30


def read_results(path):
    """bench-results.json is one JSON object per line."""
    results = {}
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError:
                continue
            name = obj.get("name")
            if name:
                results[name] = obj
    return results


def previous_results(repo, workflow, artifact, count=2):
    """Artifacts from up to `count` previous successful main runs,
    newest first: [(run_id, results), ...]."""
    runs = json.loads(
        subprocess.check_output(
            [
                "gh", "run", "list",
                "--repo", repo,
                "--workflow", workflow,
                "--branch", "main",
                "--status", "success",
                "--limit", "15",
                "--json", "databaseId",
            ],
            text=True,
        )
    )
    current = os.environ.get("GITHUB_RUN_ID")
    baselines = []
    for run in runs:
        if len(baselines) >= count:
            break
        run_id = str(run["databaseId"])
        if run_id == current:
            continue
        with tempfile.TemporaryDirectory() as tmp:
            try:
                subprocess.check_call(
                    [
                        "gh", "run", "download", run_id,
                        "--repo", repo,
                        "--name", artifact,
                        "--dir", tmp,
                    ],
                    stdout=subprocess.DEVNULL,
                    stderr=subprocess.DEVNULL,
                )
            except subprocess.CalledProcessError:
                continue  # run without the artifact (e.g. older layout)
            path = os.path.join(tmp, "bench-results.json")
            if os.path.exists(path):
                baselines.append((run_id, read_results(path)))
    return baselines


def metric_of(obj):
    """(value, unit, higher_is_better) for one bench result."""
    if "gbps" in obj:
        return obj["gbps"], "Gbps", True
    if "ops_per_sec" in obj:
        return obj["ops_per_sec"], "ops/s", True
    return obj.get("median_secs", 0.0) * 1e3, "ms", False


def config_of(obj):
    """The (io-backend, hash-tier) pair a result was measured under
    (results predating those matrix axes count as buffered/cryptographic
    — they were)."""
    return (
        obj.get("io_backend") or "buffered",
        obj.get("hash_tier") or "cryptographic",
    )


def regression_of(cur_obj, prev_obj):
    """Fractional regression of `cur` vs `prev` (positive = worse), or
    None when not comparable — including when the two results were
    measured under different io-backends or hash tiers (like-for-like
    only: a backend or tier switch is a configuration change, not a
    regression)."""
    if config_of(cur_obj) != config_of(prev_obj):
        return None
    cur_v, _, higher = metric_of(cur_obj)
    prev_v, _, _ = metric_of(prev_obj)
    if prev_v == 0:
        return None
    pct = (cur_v - prev_v) / prev_v
    return -pct if higher else pct


def fmt_val(v, unit):
    if unit == "ops/s" and v >= 1000:
        return f"{v:,.0f} {unit}"
    return f"{v:.3f} {unit}" if v < 100 else f"{v:.1f} {unit}"


def gated_benches(current, baselines):
    """Benches whose regression persisted across two consecutive runs:
    both the current run and the previous successful main run (prev1)
    are past the threshold relative to the run before that (prev2).
    Needs two baselines; a noisy current run alone never gates because
    prev1-vs-prev2 was healthy then."""
    if len(baselines) < 2:
        return []
    (_, prev1), (_, prev2) = baselines[0], baselines[1]
    gated = []
    for name, cur in sorted(current.items()):
        if name not in prev1 or name not in prev2:
            continue
        r_cur = regression_of(cur, prev2[name])
        r_prev = regression_of(prev1[name], prev2[name])
        persisted = (
            r_cur is not None
            and r_prev is not None
            and r_cur > REGRESSION_THRESHOLD
            and r_prev > REGRESSION_THRESHOLD
        )
        if persisted:
            gated.append((name, [r_cur, r_prev]))
    return gated


def render(current, previous, prev_run):
    lines = [
        "### Bench delta vs previous main run"
        + (f" (run {prev_run})" if prev_run else ""),
        "",
        "_Soft gate: the job fails only when a bench regresses "
        f">{REGRESSION_THRESHOLD:.0%} on two consecutive runs (this one "
        "and the previous main run, vs the run before that); anything "
        "else is a warning — smoke-mode numbers are noisy._",
        "",
        "| bench | previous | current | delta |",
        "|---|---:|---:|---:|",
    ]
    for name in sorted(current):
        cur_v, unit, higher = metric_of(current[name])
        prev = previous.get(name) if previous else None
        if prev is None:
            lines.append(f"| `{name}` | — | {fmt_val(cur_v, unit)} | new |")
            continue
        prev_v, _, _ = metric_of(prev)
        if config_of(prev) != config_of(current[name]):
            prev_cfg = "/".join(config_of(prev))
            cur_cfg = "/".join(config_of(current[name]))
            delta = f"config changed ({prev_cfg} → {cur_cfg})"
        elif prev_v == 0:
            delta = "n/a"
        else:
            pct = (cur_v - prev_v) / prev_v * 100.0
            better = pct >= 0 if higher else pct <= 0
            marker = "" if abs(pct) < 5 else (" :white_check_mark:" if better else " :warning:")
            delta = f"{pct:+.1f}%{marker}"
        lines.append(
            f"| `{name}` | {fmt_val(prev_v, unit)} | {fmt_val(cur_v, unit)} | {delta} |"
        )
    if previous:
        gone = sorted(set(previous) - set(current))
        for name in gone:
            lines.append(f"| `{name}` | {fmt_val(*metric_of(previous[name])[:2])} | — | removed |")
    return "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True, help="this run's bench-results.json")
    ap.add_argument("--repo", required=True)
    ap.add_argument("--workflow", default="ci.yml")
    ap.add_argument("--artifact", default="bench-results")
    ap.add_argument("--summary", default=os.environ.get("GITHUB_STEP_SUMMARY"))
    args = ap.parse_args()

    gated = []
    try:
        current = read_results(args.current)
        if not current:
            raise RuntimeError(f"no results parsed from {args.current}")
        baselines = previous_results(args.repo, args.workflow, args.artifact)
        if not baselines:
            out = (
                "### Bench delta\n\nNo previous `bench-results` artifact found on main "
                "— this run becomes the baseline.\n"
            )
        else:
            prev_run, previous = baselines[0]
            out = render(current, previous, prev_run)
            gated = gated_benches(current, baselines)
            if gated:
                out += "\n#### :x: Persistent regressions (gating)\n\n"
                for name, (r_cur, r_prev) in gated:
                    out += (
                        f"- `{name}` regressed on two consecutive runs vs the "
                        f"older baseline: now {r_cur:+.0%}, previous run "
                        f"{r_prev:+.0%} (threshold {REGRESSION_THRESHOLD:.0%})\n"
                    )
    except Exception as e:  # infra problems stay warn-only by contract
        out = f"### Bench delta\n\nComparison skipped: `{e}`\n"

    print(out)
    if args.summary:
        with open(args.summary, "a", encoding="utf-8") as f:
            f.write(out)
    return 1 if gated else 0


if __name__ == "__main__":
    sys.exit(main())
