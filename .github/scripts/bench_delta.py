#!/usr/bin/env python3
"""Warn-only perf-delta table for the bench-smoke CI job.

Downloads the bench-results.json artifact from the previous successful run
of this workflow on main (via the `gh` CLI baked into GitHub runners),
joins it with the current run's results by bench name, and renders a
markdown delta table into the job summary. Never fails the job: any error
degrades to a note in the summary.
"""

import argparse
import json
import os
import subprocess
import sys
import tempfile


def read_results(path):
    """bench-results.json is one JSON object per line."""
    results = {}
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError:
                continue
            name = obj.get("name")
            if name:
                results[name] = obj
    return results


def previous_results(repo, workflow, artifact):
    """Fetch the artifact from the last successful main run, or None."""
    runs = json.loads(
        subprocess.check_output(
            [
                "gh", "run", "list",
                "--repo", repo,
                "--workflow", workflow,
                "--branch", "main",
                "--status", "success",
                "--limit", "10",
                "--json", "databaseId",
            ],
            text=True,
        )
    )
    current = os.environ.get("GITHUB_RUN_ID")
    for run in runs:
        run_id = str(run["databaseId"])
        if run_id == current:
            continue
        with tempfile.TemporaryDirectory() as tmp:
            try:
                subprocess.check_call(
                    [
                        "gh", "run", "download", run_id,
                        "--repo", repo,
                        "--name", artifact,
                        "--dir", tmp,
                    ],
                    stdout=subprocess.DEVNULL,
                    stderr=subprocess.DEVNULL,
                )
            except subprocess.CalledProcessError:
                continue  # run without the artifact (e.g. older layout)
            path = os.path.join(tmp, "bench-results.json")
            if os.path.exists(path):
                return run_id, read_results(path)
    return None, None


def metric_of(obj):
    """(value, unit, higher_is_better) for one bench result."""
    if "gbps" in obj:
        return obj["gbps"], "Gbps", True
    if "ops_per_sec" in obj:
        return obj["ops_per_sec"], "ops/s", True
    return obj.get("median_secs", 0.0) * 1e3, "ms", False


def fmt_val(v, unit):
    if unit == "ops/s" and v >= 1000:
        return f"{v:,.0f} {unit}"
    return f"{v:.3f} {unit}" if v < 100 else f"{v:.1f} {unit}"


def render(current, previous, prev_run):
    lines = [
        "### Bench delta vs previous main run"
        + (f" (run {prev_run})" if prev_run else ""),
        "",
        "_Warn-only: trends, not gates. Smoke-mode numbers are noisy._",
        "",
        "| bench | previous | current | delta |",
        "|---|---:|---:|---:|",
    ]
    for name in sorted(current):
        cur_v, unit, higher = metric_of(current[name])
        prev = previous.get(name) if previous else None
        if prev is None:
            lines.append(f"| `{name}` | — | {fmt_val(cur_v, unit)} | new |")
            continue
        prev_v, _, _ = metric_of(prev)
        if prev_v == 0:
            delta = "n/a"
        else:
            pct = (cur_v - prev_v) / prev_v * 100.0
            better = pct >= 0 if higher else pct <= 0
            marker = "" if abs(pct) < 5 else (" :white_check_mark:" if better else " :warning:")
            delta = f"{pct:+.1f}%{marker}"
        lines.append(
            f"| `{name}` | {fmt_val(prev_v, unit)} | {fmt_val(cur_v, unit)} | {delta} |"
        )
    if previous:
        gone = sorted(set(previous) - set(current))
        for name in gone:
            lines.append(f"| `{name}` | {fmt_val(*metric_of(previous[name])[:2])} | — | removed |")
    return "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True, help="this run's bench-results.json")
    ap.add_argument("--repo", required=True)
    ap.add_argument("--workflow", default="ci.yml")
    ap.add_argument("--artifact", default="bench-results")
    ap.add_argument("--summary", default=os.environ.get("GITHUB_STEP_SUMMARY"))
    args = ap.parse_args()

    try:
        current = read_results(args.current)
        if not current:
            raise RuntimeError(f"no results parsed from {args.current}")
        prev_run, previous = previous_results(args.repo, args.workflow, args.artifact)
        if previous is None:
            out = (
                "### Bench delta\n\nNo previous `bench-results` artifact found on main "
                "— this run becomes the baseline.\n"
            )
        else:
            out = render(current, previous, prev_run)
    except Exception as e:  # warn-only by contract
        out = f"### Bench delta\n\nComparison skipped: `{e}`\n"

    print(out)
    if args.summary:
        with open(args.summary, "a", encoding="utf-8") as f:
            f.write(out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
