#!/usr/bin/env python3
"""Markdown link checker (stdlib only) for the repo's top-level docs.

Checks, for every `[text](target)` link in the given files:

* relative file targets resolve to an existing file or directory
  (relative to the markdown file's own directory);
* `#fragment` targets (same-file or on a relative target) match a
  heading in the target file, using GitHub's anchor slug rules;
* absolute `http(s)`/`mailto` targets are skipped (offline CI).

Exit status is the number of broken links (0 = clean).
"""

import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation, dash spaces."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def headings(path: Path) -> set:
    slugs = set()
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if not in_fence and line.startswith("#"):
            slugs.add(slugify(line.lstrip("#")))
    return slugs


def strip_code(text: str) -> str:
    """Drop fenced code blocks and inline code spans before link scanning."""
    out, in_fence = [], False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(re.sub(r"`[^`]*`", "", line))
    return "\n".join(out)


def check(md: Path) -> list:
    errors = []
    for target in LINK.findall(strip_code(md.read_text(encoding="utf-8"))):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, fragment = target.partition("#")
        dest = (md.parent / path_part).resolve() if path_part else md.resolve()
        if not dest.exists():
            errors.append(f"{md}: broken link target `{target}`")
            continue
        if fragment and dest.suffix == ".md":
            if slugify(fragment) not in headings(dest):
                errors.append(f"{md}: no heading for anchor `{target}`")
    return errors


def main() -> int:
    errors = []
    for name in sys.argv[1:]:
        md = Path(name)
        if not md.exists():
            errors.append(f"{md}: file not found")
            continue
        errors.extend(check(md))
    for e in errors:
        print(f"error: {e}", file=sys.stderr)
    if not errors:
        print(f"ok: {len(sys.argv) - 1} files, all links resolve")
    return min(len(errors), 100)


if __name__ == "__main__":
    sys.exit(main())
