//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! A real mixed dataset (~288 MiB, mirroring the paper's mixed-size shape)
//! is transferred over loopback TCP by every algorithm, with the checksum
//! running through the **AOT-compiled Pallas kernel via XLA/PJRT**
//! (`--hash fvr256-xla`, the default here): Layer-1 kernel → Layer-2 HLO
//! artifact → Layer-3 Rust coordinator, Python nowhere at runtime.
//!
//! For each algorithm we report wall time and the paper's Eq. 1 overhead
//! against measured transfer-only and checksum-only baselines. Results are
//! recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_transfer [--native]
//! ```

use std::sync::Arc;
use std::time::Instant;

use fiver::coordinator::session::run_local_transfer;
use fiver::coordinator::{native_factory, xla_factory, HasherFactory, RealAlgorithm, SessionConfig};
use fiver::faults::FaultPlan;
use fiver::hashes::HashAlgorithm;
use fiver::metrics::overhead;
use fiver::storage::{FsStorage, Storage};
use fiver::util::fmt::{bytes, pct, secs, Table};
use fiver::workload::Dataset;

fn main() -> anyhow::Result<()> {
    let native = std::env::args().any(|a| a == "--native");
    let hasher: HasherFactory = if native {
        println!("hash: native FVR-256");
        native_factory(HashAlgorithm::Fvr256)
    } else {
        let dir = fiver::runtime::find_artifacts_dir()?;
        let manifest = fiver::runtime::Manifest::load(&dir)?;
        let engine = fiver::runtime::XlaHashEngine::load(&manifest, "1m", false)?;
        println!(
            "hash: FVR-256 through XLA/PJRT artifact `{}` (Pallas kernel, AOT)",
            engine.name()
        );
        xla_factory(engine)
    };

    // Mixed-size dataset in the paper's spirit, scaled to run in seconds:
    // many small + a few large files.
    let ds = Dataset::mixed_shuffled(
        "e2e-mixed",
        &[(24, 2 << 20), (12, 8 << 20), (3, 48 << 20)],
        42,
    );
    let base = std::env::temp_dir().join(format!("fiver-e2e-{}", std::process::id()));
    println!("dataset: {} files, {}", ds.len(), bytes(ds.total_bytes()));
    ds.materialize(&base.join("src"), 1)?;
    let names: Vec<String> = ds.files.iter().map(|f| f.name.clone()).collect();

    // Baseline 1: transfer-only (no verification).
    let t_transfer = run_once(&base, &names, RealAlgorithm::TransferOnly, &hasher)?;
    // Baseline 2: checksum-only (hash every file once at "source").
    let ck_start = Instant::now();
    let src: Arc<dyn Storage> = Arc::new(FsStorage::new(&base.join("src"))?);
    for name in &names {
        let size = src.size_of(name)?;
        let mut h = hasher();
        let mut r = src.open_read(name)?;
        let mut buf = vec![0u8; 1 << 20];
        let mut left = size;
        while left > 0 {
            let want = buf.len().min(left as usize);
            let n = r.read_next(&mut buf[..want])?;
            h.update(&buf[..n]);
            left -= n as u64;
        }
        let _ = h.finalize();
    }
    let t_checksum = ck_start.elapsed().as_secs_f64();
    println!(
        "baselines: transfer-only {}, checksum-only {}\n",
        secs(t_transfer),
        secs(t_checksum)
    );

    let mut table = Table::new(&["algorithm", "time", "overhead (Eq.1)", "throughput"]);
    for alg in [
        RealAlgorithm::Sequential,
        RealAlgorithm::FileLevelPpl,
        RealAlgorithm::BlockLevelPpl,
        RealAlgorithm::Fiver,
        RealAlgorithm::FiverChunk,
        RealAlgorithm::FiverHybrid,
    ] {
        let t = run_once(&base, &names, alg, &hasher)?;
        table.row(&[
            alg.name().to_string(),
            secs(t),
            pct(overhead(t, t_checksum, t_transfer)),
            fiver::util::fmt::rate_bps(ds.total_bytes() as f64 * 8.0 / t),
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper's claim: FIVER within ~10% of max(t_transfer, t_chksum);\n\
         sequential ≈ sum of both; pipelined baselines in between."
    );
    std::fs::remove_dir_all(&base).ok();
    Ok(())
}

fn run_once(
    base: &std::path::Path,
    names: &[String],
    alg: RealAlgorithm,
    hasher: &HasherFactory,
) -> anyhow::Result<f64> {
    let src: Arc<dyn Storage> = Arc::new(FsStorage::new(&base.join("src"))?);
    let dst_dir = base.join(format!("dst-{}", alg.name()));
    let dst: Arc<dyn Storage> = Arc::new(FsStorage::new(&dst_dir)?);
    let mut cfg = SessionConfig::new(alg, hasher.clone());
    cfg.block_size = 8 << 20;
    cfg.hybrid_threshold = 16 << 20;
    let (report, _) = run_local_transfer(names, src, dst, &cfg, &FaultPlan::none())?;
    std::fs::remove_dir_all(&dst_dir).ok();
    Ok(report.elapsed_secs)
}
