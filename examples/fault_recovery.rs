//! Fault recovery on real transfers (§IV-A, Table III in miniature).
//!
//! Injects bit flips into the wire path of a real loopback transfer and
//! compares FIVER's file-level vs chunk-level recovery: both must deliver
//! bit-identical files, but chunk-level resends only the corrupted chunks.
//!
//! ```bash
//! cargo run --release --example fault_recovery
//! ```

use std::sync::Arc;

use fiver::coordinator::session::run_local_transfer;
use fiver::coordinator::{native_factory, RealAlgorithm, SessionConfig};
use fiver::faults::FaultPlan;
use fiver::hashes::{hex_digest, HashAlgorithm};
use fiver::storage::{FsStorage, Storage};
use fiver::util::fmt::{bytes, Table};
use fiver::workload::Dataset;

fn main() -> anyhow::Result<()> {
    let ds = Dataset::uniform("fr", 32 << 20, 6); // 6 x 32 MiB
    let base = std::env::temp_dir().join(format!("fiver-faultrec-{}", std::process::id()));
    ds.materialize(&base.join("src"), 3)?;
    let names: Vec<String> = ds.files.iter().map(|f| f.name.clone()).collect();
    println!("dataset: {} files, {}\n", ds.len(), bytes(ds.total_bytes()));

    let mut table = Table::new(&[
        "faults", "algorithm", "failures detected", "bytes resent", "reread", "verify RTTs",
        "delivered intact",
    ]);
    for fault_count in [0usize, 4, 12] {
        let plan = FaultPlan::random(&ds, fault_count, 0xBEEF + fault_count as u64);
        for alg in [
            RealAlgorithm::Fiver,
            RealAlgorithm::FiverChunk,
            RealAlgorithm::FiverMerkle,
            RealAlgorithm::BlockLevelPpl,
        ] {
            let src: Arc<dyn Storage> = Arc::new(FsStorage::new(&base.join("src"))?);
            let dst_dir = base.join(format!("dst-{}-{}", alg.name(), fault_count));
            let dst: Arc<dyn Storage> = Arc::new(FsStorage::new(&dst_dir)?);
            let mut cfg = SessionConfig::new(alg, native_factory(HashAlgorithm::Fvr256));
            cfg.block_size = 4 << 20; // 4 MiB chunks: a flip costs one chunk
            let (report, _) = run_local_transfer(&names, src, dst, &cfg, &plan)?;

            // Ground truth: every delivered file must be bit-identical.
            let mut intact = true;
            for f in &ds.files {
                let a = std::fs::read(base.join("src").join(&f.name))?;
                let b = std::fs::read(dst_dir.join(&f.name))?;
                intact &= hex_digest(HashAlgorithm::Sha256, &a)
                    == hex_digest(HashAlgorithm::Sha256, &b);
            }
            table.row(&[
                fault_count.to_string(),
                alg.name().to_string(),
                report.failures_detected.to_string(),
                bytes(report.bytes_resent),
                bytes(report.bytes_reread),
                report.verify_rtts.to_string(),
                if intact { "yes".into() } else { "NO".to_string() },
            ]);
            std::fs::remove_dir_all(&dst_dir).ok();
        }
    }
    println!("{}", table.render());
    println!(
        "paper Table III: file-level FIVER resends whole files (time nearly\n\
         doubles at 24 faults); chunk-level and block-level resend only the\n\
         corrupted chunk/block, staying nearly flat. FIVER-Merkle goes one\n\
         step further: O(log n) digest round trips localize each fault to a\n\
         64 KiB leaf, so repair bytes shrink by block_size/leaf_size."
    );
    std::fs::remove_dir_all(&base).ok();
    Ok(())
}
