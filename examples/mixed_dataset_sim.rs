//! Simulated reproduction of the paper's headline experiment: the 165.5 GB
//! ESNet mixed dataset over the WAN path (89 ms RTT), all five algorithms.
//!
//! This is Figs 7b + 8 + 9 in one run: Eq. 1 overheads, receiver cache
//! hit-ratio traces, and the FIVER-Hybrid trade-off, simulated in
//! milliseconds of wall time by the fluid engine.
//!
//! ```bash
//! cargo run --release --example mixed_dataset_sim
//! ```

use fiver::config::{AlgoParams, Testbed};
use fiver::faults::FaultPlan;
use fiver::sim::algorithms::{run, Algorithm};
use fiver::util::fmt::{bytes, pct, secs, Table};
use fiver::workload::Dataset;

fn main() {
    let tb = Testbed::esnet_wan();
    let ds = Dataset::esnet_mixed(42);
    println!(
        "{} on {}: {} files, {} (bandwidth {}, RTT {:.0} ms, MD5 {})\n",
        ds.name,
        tb.name,
        ds.len(),
        bytes(ds.total_bytes()),
        fiver::util::fmt::rate_bps(tb.bandwidth * 8.0),
        tb.rtt * 1e3,
        fiver::util::fmt::rate_bps(tb.src.hash_md5 * 8.0),
    );

    let mut t = Table::new(&[
        "algorithm", "virtual time", "overhead", "avg hit ratio", "misses", "tcp restarts",
    ]);
    for alg in [
        Algorithm::Sequential,
        Algorithm::FileLevelPpl,
        Algorithm::BlockLevelPpl,
        Algorithm::Fiver,
        Algorithm::FiverHybrid,
    ] {
        let s = run(tb, AlgoParams::default(), &ds, &FaultPlan::none(), alg);
        t.row(&[
            s.algorithm.clone(),
            secs(s.total_time),
            pct(s.overhead().expect("sim runs carry Eq. 1 baselines")),
            pct(s.dst_trace.average()),
            bytes(s.dst_trace.total_misses()),
            s.tcp_restarts.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "paper (Figs 7b/8/9): FIVER <5% overhead and ~100% hit ratio; block-level\n\
         ~20%; file-level/sequential ~60% with hit-ratio dips below 10% on the\n\
         files larger than free memory; FIVER-Hybrid ~20% faster than sequential\n\
         at the same cache behaviour."
    );
}
