//! Quickstart: the FIVER public API in ~60 lines.
//!
//! 1. Generate a small dataset on disk.
//! 2. Transfer it over loopback TCP with FIVER (transfer + checksum of the
//!    same file concurrently, one shared read).
//! 3. Verify the received bytes independently.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use fiver::coordinator::session::run_local_transfer;
use fiver::coordinator::{native_factory, RealAlgorithm, SessionConfig};
use fiver::faults::FaultPlan;
use fiver::hashes::{hex_digest, HashAlgorithm};
use fiver::storage::{FsStorage, Storage};
use fiver::workload::Dataset;

fn main() -> anyhow::Result<()> {
    // 1. A dataset of 8 x 8 MiB files with deterministic pseudo-random
    //    content.
    let base = std::env::temp_dir().join(format!("fiver-quickstart-{}", std::process::id()));
    let ds = Dataset::uniform("qs", 8 << 20, 8);
    ds.materialize(&base.join("src"), 7)?;
    println!("dataset: {} files, {}", ds.len(), fiver::util::fmt::bytes(ds.total_bytes()));

    // 2. FIVER transfer over 127.0.0.1. The receiver writes files under
    //    dst/ and both ends hash the stream through the shared queue —
    //    no second read of any file.
    let src: Arc<dyn Storage> = Arc::new(FsStorage::new(&base.join("src"))?);
    let dst: Arc<dyn Storage> = Arc::new(FsStorage::new(&base.join("dst"))?);
    let cfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Fvr256));
    let names: Vec<String> = ds.files.iter().map(|f| f.name.clone()).collect();
    let (report, receiver) = run_local_transfer(&names, src, dst, &cfg, &FaultPlan::none())?;
    println!(
        "{}: {} in {:.2}s — {} units verified, {} failures",
        report.algorithm,
        fiver::util::fmt::bytes(report.bytes_sent),
        report.elapsed_secs,
        receiver.units_verified,
        receiver.units_failed,
    );

    // 3. Independent end-to-end check: bytes on the destination disk equal
    //    bytes on the source disk.
    for f in &ds.files {
        let a = std::fs::read(base.join("src").join(&f.name))?;
        let b = std::fs::read(base.join("dst").join(&f.name))?;
        assert_eq!(
            hex_digest(HashAlgorithm::Sha256, &a),
            hex_digest(HashAlgorithm::Sha256, &b),
            "mismatch on {}",
            f.name
        );
    }
    println!("independent SHA-256 comparison: all {} files identical", ds.len());
    std::fs::remove_dir_all(&base).ok();
    Ok(())
}
