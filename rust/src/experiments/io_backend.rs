//! Storage I/O backend sweep — beyond the paper: how the way bytes move
//! between process and disk (buffered pread/pwrite vs mmap vs
//! O_DIRECT-style aligned I/O) changes FIVER's coupled-flow throughput
//! and — the FIVER-Hybrid angle the paper cares about — what read-back
//! verification costs once the page cache does or does not hold the
//! transferred bytes. The simulated sweep runs backend × file-size ×
//! concurrency through the fluid testbed's per-backend cost model
//! ([`crate::config::IoCost`]); a real loopback engine run then
//! cross-checks the machinery end-to-end on every backend the host
//! supports, with per-backend sync counts from the new storage telemetry.

use std::sync::Arc;

use crate::config::{AlgoParams, Testbed, MB};
use crate::coordinator::scheduler::EngineConfig;
use crate::coordinator::session::run_parallel_local_transfer;
use crate::coordinator::{native_factory, RealAlgorithm, SessionConfig};
use crate::faults::FaultPlan;
use crate::hashes::HashAlgorithm;
use crate::sim::algorithms::{run, run_concurrent, Algorithm};
use crate::storage::{FsStorage, IoBackend, Storage};
use crate::util::fmt;
use crate::util::rng::SplitMix64;
use crate::util::tmpdir::TempDir;
use crate::workload::Dataset;

/// Run the sweep and render the report.
pub fn io_backend_sweep() -> String {
    let mut out = String::new();
    out.push_str(
        "I/O backend sweep — storage engine (buffered / mmap / direct)\n\
         under FIVER's coupled flow, sim cost model + real loopback:\n",
    );
    out.push_str(&sim_sweep());
    out.push_str(&hybrid_read_back());
    out.push_str(&real_mode_cross_check());
    out
}

/// Simulated backend × dataset × concurrency grid (FIVER, HPCLab-40G).
fn sim_sweep() -> String {
    let tb = Testbed::hpclab_40g();
    let datasets =
        [Dataset::uniform("100M", 100 * MB, 64), Dataset::uniform("1G", 1024 * MB, 8)];
    let mut table = fmt::Table::new(&["backend", "dataset", "N", "time", "Eq.1 overhead"]);
    for backend in IoBackend::ALL {
        for ds in &datasets {
            for n in [1usize, 4] {
                let params = AlgoParams { io_backend: backend, ..AlgoParams::default() };
                let s = run_concurrent(tb, params, ds, &FaultPlan::none(), Algorithm::Fiver, n, n);
                table.row(&[
                    backend.name().to_string(),
                    ds.name.clone(),
                    n.to_string(),
                    fmt::secs(s.total_time),
                    format!("{:+.1}%", s.overhead().unwrap() * 100.0),
                ]);
            }
        }
    }
    format!("\n{} — simulated FIVER grid:\n{}", tb.name, table.render())
}

/// Receiver-side *read-back* verification is where the backend's
/// page-cache behavior bites: a re-read policy (Sequential here) pays
/// disk for every checksum byte under the direct backend, while
/// FIVER-Hybrid's queue path never re-reads at all — the backend barely
/// matters. This is the FIVER-Hybrid scenario the paper cares about,
/// measured per backend instead of assumed.
fn hybrid_read_back() -> String {
    // HPCLab-1G: the one testbed whose destination disk (1.45 Gbps) is
    // slower than its hash core (3.4 Gbps), so a cache-bypassed re-read
    // is visibly disk-bound. 1 GB files fit its 14 GB of free memory —
    // buffered/mmap read back from cache, direct cannot.
    let tb = Testbed::hpclab_1g();
    let ds = Dataset::uniform("1G", 1024 * MB, 4);
    let mut table =
        fmt::Table::new(&["algorithm", "backend", "time", "dst hit ratio", "Eq.1 overhead"]);
    for alg in [Algorithm::Sequential, Algorithm::FiverHybrid] {
        for backend in IoBackend::ALL {
            let params = AlgoParams { io_backend: backend, ..AlgoParams::default() };
            let s = run(tb, params, &ds, &FaultPlan::none(), alg);
            table.row(&[
                alg.name().to_string(),
                backend.name().to_string(),
                fmt::secs(s.total_time),
                fmt::pct(s.dst_trace.average()),
                format!("{:+.1}%", s.overhead().unwrap() * 100.0),
            ]);
        }
    }
    format!(
        "\n{} — read-back verification vs the queue path (1G files):\n{}",
        tb.name,
        table.render()
    )
}

/// A scaled-down real engine run per backend over loopback TCP with
/// `FsStorage` on both ends — measured, not asserted (loopback wall-clock
/// depends on the host); sync counts attribute durability cost per
/// backend.
fn real_mode_cross_check() -> String {
    let files = 24usize;
    let size = 256 * 1024usize;
    let mut rng = SplitMix64::new(0x10BACE);
    let mut payloads = Vec::with_capacity(files);
    for _ in 0..files {
        let mut data = vec![0u8; size];
        rng.fill_bytes(&mut data);
        payloads.push(data);
    }
    let mut table =
        fmt::Table::new(&["backend", "effective", "time", "storage syncs", "pool peak"]);
    for backend in IoBackend::ALL {
        let base = match TempDir::create(&format!("fiver-iobk-{}", backend.name())) {
            Ok(d) => d,
            Err(e) => {
                table.row(&[
                    backend.name().to_string(),
                    format!("scratch dir failed: {e}"),
                    String::new(),
                    String::new(),
                    String::new(),
                ]);
                continue;
            }
        };
        let src_fs = FsStorage::with_backend(&base.join("src"), backend).expect("src storage");
        let dst_fs = FsStorage::with_backend(&base.join("dst"), backend).expect("dst storage");
        let effective = dst_fs.backend().name().to_string();
        let mut names = Vec::with_capacity(files);
        for (i, data) in payloads.iter().enumerate() {
            let name = format!("b{i:03}");
            let mut w = src_fs.open_write(&name).expect("create source");
            w.write_next(data).expect("write source");
            w.flush().expect("flush source");
            names.push(name);
        }
        let mut cfg =
            SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Fvr256));
        cfg.io_backend = backend;
        let eng = EngineConfig {
            concurrency: 2,
            parallel: 1,
            hash_workers: 2,
            batch_threshold: 512 * 1024,
            batch_bytes: 2 << 20,
        };
        let src: Arc<dyn Storage> = Arc::new(src_fs);
        let dst: Arc<dyn Storage> = Arc::new(dst_fs);
        let (report, rreports) =
            run_parallel_local_transfer(&names, src, dst.clone(), &cfg, &eng, &FaultPlan::none())
                .expect("real backend run");
        let total = report.aggregate();
        assert_eq!(total.bytes_sent, (files * size) as u64);
        // Byte-identical delivery through the trait surface (works on
        // every backend, unlike std::fs reads).
        for (name, expect) in names.iter().zip(&payloads) {
            let got = crate::storage::read_all(&dst, name).expect("read back");
            assert_eq!(&got, expect, "backend {} delivered different bytes", backend.name());
        }
        let rsyncs: u64 = rreports.iter().map(|r| r.storage_syncs).max().unwrap_or(0);
        table.row(&[
            backend.name().to_string(),
            effective,
            fmt::secs(total.elapsed_secs),
            format!("snd {} / rcv {}", total.storage_syncs, rsyncs),
            total.pool_peak_in_flight.to_string(),
        ]);
    }
    format!(
        "\nreal mode (loopback, {files}x{}, FsStorage both ends, fvr256):\n{}",
        fmt::bytes(size as u64),
        table.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_renders_every_backend() {
        let out = io_backend_sweep();
        for b in IoBackend::ALL {
            assert!(out.contains(b.name()), "{} missing from the sweep", b.name());
        }
        assert!(out.contains("read-back"));
        assert!(out.contains("real mode"));
    }

    #[test]
    fn direct_read_back_is_costlier_than_buffered_for_reread_policies() {
        // The modeled point of the sweep: bypassing the page cache makes
        // a re-read policy's destination checksum pay disk instead of
        // memory (Sequential on HPCLab-1G: ~1.45 Gbps disk vs 3.4 Gbps
        // cached hash), while FIVER's queue path stays backend-agnostic.
        let tb = Testbed::hpclab_1g();
        let ds = Dataset::uniform("1G", 1024 * MB, 2);
        let time = |alg: Algorithm, backend: IoBackend| {
            let params = AlgoParams { io_backend: backend, ..AlgoParams::default() };
            run(tb, params, &ds, &FaultPlan::none(), alg).total_time
        };
        let seq_buffered = time(Algorithm::Sequential, IoBackend::Buffered);
        let seq_direct = time(Algorithm::Sequential, IoBackend::Direct);
        assert!(
            seq_direct > 1.15 * seq_buffered,
            "direct read-back must pay disk: {seq_direct:.1}s vs {seq_buffered:.1}s"
        );
        // The queue path barely cares which backend moves the bytes.
        let f_buffered = time(Algorithm::Fiver, IoBackend::Buffered);
        let f_direct = time(Algorithm::Fiver, IoBackend::Direct);
        assert!(
            (f_direct - f_buffered).abs() / f_buffered < 0.15,
            "FIVER must stay backend-insensitive: {f_direct:.1}s vs {f_buffered:.1}s"
        );
    }
}
