//! Fig 10: impact of the hash algorithm (MD5 / SHA1 / SHA256) on total
//! execution time, ESNet-LAN mixed dataset.

use crate::config::Testbed;
use crate::faults::FaultPlan;
use crate::hashes::HashAlgorithm;
use crate::sim::algorithms::{checksum_only, run, Algorithm};
use crate::util::fmt::{secs, Table};
use crate::workload::Dataset;

/// Render Figure 10: hash algorithm throughput comparison.
pub fn fig10() -> String {
    let tb = Testbed::esnet_lan();
    let ds = Dataset::esnet_mixed(42);
    let mut out = format!(
        "Fig 10 — hash algorithm impact, {} on {}\n\
         paper: Checksum-Only 476 / 713 / 1043 s for MD5 / SHA1 / SHA256;\n\
         FIVER lowest overhead throughout; block-level +50-60 s, file-level\n\
         +300 s over the Checksum-Only baseline; per-algorithm deltas stay\n\
         constant as the baseline grows\n\n",
        ds.name, tb.name
    );
    let mut t = Table::new(&["hash", "ChecksumOnly", "FIVER", "BlockLevelPpl", "FileLevelPpl"]);
    for hash in [HashAlgorithm::Md5, HashAlgorithm::Sha1, HashAlgorithm::Sha256] {
        let mut params = super::params();
        params.hash = hash;
        let base = checksum_only(tb, params, &ds);
        let mut cells = vec![hash.name().to_string(), secs(base)];
        for alg in [Algorithm::Fiver, Algorithm::BlockLevelPpl, Algorithm::FileLevelPpl] {
            let s = run(tb, params, &ds, &FaultPlan::none(), alg);
            cells.push(format!("{} (+{})", secs(s.total_time), secs(s.total_time - base)));
        }
        t.row(&cells);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MB;

    /// Fig 10 shape: checksum-only time scales with hash cost (SHA256 over
    /// 2x MD5), and FIVER's delta over the baseline stays smallest.
    #[test]
    fn hash_cost_scales_baseline() {
        let tb = Testbed::esnet_lan();
        let ds = Dataset::uniform("1G", 1024 * MB, 3);
        let mut p = super::super::params();
        p.hash = HashAlgorithm::Md5;
        let md5 = checksum_only(tb, p, &ds);
        p.hash = HashAlgorithm::Sha256;
        let sha256 = checksum_only(tb, p, &ds);
        let ratio = sha256 / md5;
        assert!(
            (1.9..2.6).contains(&ratio),
            "paper ratio 1043/476 = 2.19, got {ratio}"
        );
    }

    #[test]
    fn fiver_delta_smallest_under_expensive_hash() {
        let tb = Testbed::esnet_lan();
        let ds = Dataset::uniform("1G", 1024 * MB, 4);
        let mut p = super::super::params();
        p.hash = HashAlgorithm::Sha256;
        let base = checksum_only(tb, p, &ds);
        let fiver = run(tb, p, &ds, &FaultPlan::none(), Algorithm::Fiver).total_time;
        let file = run(tb, p, &ds, &FaultPlan::none(), Algorithm::FileLevelPpl).total_time;
        assert!(fiver - base < file - base, "fiver +{} vs file +{}", fiver - base, file - base);
    }
}
