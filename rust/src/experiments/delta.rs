//! Delta-sync sweep — beyond the paper: when does an rsync-style
//! incremental re-transfer (`--delta`, [`crate::coordinator::delta`])
//! beat shipping the dataset again? The simulated sweep crosses mutation
//! rate with Merkle leaf size: scattered point edits dirty whole leaves,
//! so small leaves ship fewer bytes but pay a bigger per-leaf signature
//! payload, while large leaves amplify every edit into more re-sent
//! data. Because the sender must *scan* its full source either way, the
//! delta only wins while the wire (not the scan) is the bottleneck — the
//! crossover the table exposes. A real loopback engine run then
//! demonstrates the same machinery end-to-end: mutate a few leaves,
//! rename a file, re-run with `--delta`, verify bit-identical delivery
//! and count the bytes that never crossed the wire.

use std::sync::Arc;

use crate::config::{AlgoParams, Testbed, GB, KB, MB};
use crate::coordinator::scheduler::EngineConfig;
use crate::coordinator::session::run_recoverable_local_transfer;
use crate::coordinator::{native_factory, RealAlgorithm, SessionConfig};
use crate::faults::FaultPlan;
use crate::hashes::HashAlgorithm;
use crate::sim::algorithms::{run, run_delta, Algorithm};
use crate::storage::{MemStorage, Storage};
use crate::util::fmt;
use crate::util::rng::SplitMix64;
use crate::util::tmpdir::TempDir;
use crate::workload::Dataset;

/// Expected fraction of leaves dirtied by `edits` point mutations placed
/// uniformly at random over `leaves` leaves: `1 - (1 - 1/L)^k`. This is
/// the leaf-granularity amplification term — the same k edits dirty a
/// larger *byte* fraction under a larger leaf.
fn dirty_leaf_fraction(leaves: u64, edits: u64) -> f64 {
    if leaves == 0 {
        return 0.0;
    }
    let l = leaves as f64;
    1.0 - (1.0 - 1.0 / l).powf(edits as f64)
}

/// Run the sweep and render the report.
pub fn delta_sweep() -> String {
    let mut out = String::new();
    out.push_str(
        "Delta-sync sweep — re-transfer of an already-delivered dataset\n\
         after k scattered point edits per GB, as a function of Merkle\n\
         leaf size. Wire bytes = dirty leaves + per-leaf signatures; the\n\
         sender scans its full source regardless, so delta wins only\n\
         while the network is the bottleneck:\n",
    );
    let ds = Dataset::uniform("1G", GB, 4);
    let total = ds.total_bytes();
    // HPCLab-1G: hash outruns the 1 Gb/s wire (network-bound — delta's
    // home turf). HPCLab-40G: the wire outruns the hash (scan-bound —
    // delta can only lose time, though it still saves bytes).
    for tb in [Testbed::hpclab_1g(), Testbed::hpclab_40g()] {
        let full = run(tb, AlgoParams::default(), &ds, &FaultPlan::none(), Algorithm::Fiver);
        let mut table = crate::util::fmt::Table::new(&[
            "edits/GB", "leaf", "dirty", "wire bytes", "time", "vs full",
        ]);
        for edits_per_gb in [4u64, 64, 1024, 16384] {
            for leaf in [16 * KB, 64 * KB, 256 * KB, MB] {
                let per_file_leaves = crate::merkle::leaf_count(GB, leaf);
                let per_file_edits = edits_per_gb; // 1 GB files
                let dirty = dirty_leaf_fraction(per_file_leaves, per_file_edits);
                let p = AlgoParams { leaf_size: leaf, delta_fraction: dirty, ..Default::default() };
                let s = run_delta(tb, p, &ds, false);
                let dlen = p.leaf_digest_len() as u64;
                let sig_bytes = per_file_leaves
                    * (crate::coordinator::delta::WEAK_LEN as u64 + dlen)
                    * ds.files.len() as u64;
                let wire = total - s.bytes_skipped_delta + sig_bytes;
                table.row(&[
                    edits_per_gb.to_string(),
                    fmt::bytes(leaf),
                    format!("{:.2}%", dirty * 100.0),
                    fmt::bytes(wire),
                    fmt::secs(s.total_time),
                    format!("{:.2}x", s.total_time / full.total_time),
                ]);
            }
        }
        out.push_str(&format!(
            "\n{} — full re-send: {} / {}:\n{}",
            tb.name,
            fmt::secs(full.total_time),
            fmt::bytes(total),
            table.render()
        ));
    }
    out.push_str(&real_delta_check());
    out
}

/// Real loopback delta re-run: deliver a dataset (populating journals),
/// mutate ~5% of the leaves and rename one file at the source, then
/// re-run with `--delta` — measured wire savings, verified bit-identical
/// delivery, and the renamed file re-journaled under its new name.
fn real_delta_check() -> String {
    let files = 16usize;
    let size = 256 * 1024usize;
    let leaf = 16 * 1024u64;
    let total = (files * size) as u64;
    let src = MemStorage::new();
    let dst = MemStorage::new();
    let mut rng = SplitMix64::new(0xDE17A);
    let mut names = Vec::with_capacity(files);
    for i in 0..files {
        let mut data = vec![0u8; size];
        rng.fill_bytes(&mut data);
        let name = format!("d{i:03}");
        src.put(&name, data);
        names.push(name);
    }
    let jroot = TempDir::create("fiver-delta-exp").expect("scratch dir");
    let mut scfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Fvr256));
    scfg.leaf_size = leaf;
    scfg.journal_dir = Some(jroot.join("snd"));
    let mut rcfg = scfg.clone();
    rcfg.journal_dir = Some(jroot.join("rcv"));
    let eng = EngineConfig {
        concurrency: 2,
        parallel: 1,
        hash_workers: 2,
        batch_threshold: 0,
        batch_bytes: 1,
    };
    let run_once = |scfg: &SessionConfig, rcfg: &SessionConfig, names: &[String]| {
        run_recoverable_local_transfer(
            names,
            Arc::new(src.clone()) as Arc<dyn Storage>,
            Arc::new(dst.clone()) as Arc<dyn Storage>,
            scfg,
            rcfg,
            &eng,
            &FaultPlan::none(),
        )
        .expect("loopback run")
    };
    run_once(&scfg, &rcfg, &names);
    // Mutate ~5% of each file's leaves and rename one file at the source.
    let leaves_per_file = size as u64 / leaf;
    let mutate_per_file = (leaves_per_file / 20).max(1);
    for name in &names {
        let mut data = src.get(name).expect("source file");
        for k in 0..mutate_per_file {
            let l = (rng.next_u64() % leaves_per_file) as usize;
            let off = l * leaf as usize + (k as usize % leaf as usize);
            data[off] ^= 0xFF;
        }
        src.put(name, data);
    }
    let new_name = "d999-renamed".to_string();
    src.rename(&names[0], &new_name).expect("rename source file");
    names[0] = new_name;
    scfg.delta = true;
    rcfg.delta = true;
    let (report, _) = run_once(&scfg, &rcfg, &names);
    for name in &names {
        assert_eq!(
            src.get(name).unwrap(),
            dst.get(name).unwrap(),
            "delivered bytes differ on {name}"
        );
    }
    let rep = report.aggregate();
    format!(
        "\nreal mode (loopback, {files}x{}, ~5% of leaves mutated + one\n\
         file renamed, then --delta):\n  \
         re-run sent {} of {} ({} matched in place; {} clean leaves, {}\n  \
         dirty); delivery verified bit-identical\n",
        fmt::bytes(size as u64),
        fmt::bytes(rep.bytes_sent),
        fmt::bytes(total),
        fmt::bytes(rep.bytes_skipped_delta),
        rep.leaves_clean,
        rep.leaves_dirty,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dirty_fraction_sane() {
        assert_eq!(dirty_leaf_fraction(0, 10), 0.0);
        assert_eq!(dirty_leaf_fraction(1024, 0), 0.0);
        // One edit dirties ~one leaf.
        let one = dirty_leaf_fraction(1024, 1);
        assert!((one - 1.0 / 1024.0).abs() < 1e-9, "{one}");
        // Many more edits than leaves saturate toward 1.
        assert!(dirty_leaf_fraction(64, 10_000) > 0.99);
        // Monotone in edits.
        assert!(dirty_leaf_fraction(1024, 100) < dirty_leaf_fraction(1024, 1000));
    }

    /// Leaf-size crossover: under scattered point edits, a larger leaf
    /// dirties a strictly larger byte fraction.
    #[test]
    fn larger_leaves_amplify_edits() {
        let edits = 256u64;
        let small = dirty_leaf_fraction(crate::merkle::leaf_count(GB, 16 * KB), edits);
        let large = dirty_leaf_fraction(crate::merkle::leaf_count(GB, MB), edits);
        // Byte fraction = leaf fraction here (uniform leaves).
        assert!(large > small, "1M {large} should dirty more than 16K {small}");
    }
}
