//! Table III: execution times under fault injection — FIVER file-level vs
//! chunk-level verification vs block-level pipelining, HPCLab-40G, 15
//! large files (10x1GB + 5x10GB), 0 / 8 / 24 faults.

use crate::config::Testbed;
use crate::faults::FaultPlan;
use crate::sim::algorithms::{run, Algorithm};
use crate::util::fmt::{bytes, secs, Table};
use crate::workload::Dataset;

/// Render Table III: fault detection and repair across algorithms.
pub fn table3() -> String {
    let tb = Testbed::hpclab_40g();
    let ds = Dataset::table3_dataset();
    let mut out = format!(
        "Table III — fault recovery, {} files ({}) on {}\n\
         paper (s):  faults  FIVER-file  FIVER-chunk  BlockLevelPpl\n\
         paper:         0       179.2       180.2        204.2\n\
         paper:         8       253.1       186.2        208.8\n\
         paper:        24       347.3       198.5        222.3\n\n",
        ds.len(),
        bytes(ds.total_bytes()),
        tb.name
    );
    let mut t = Table::new(&[
        "faults",
        "algorithm",
        "time",
        "resent",
        "failures detected",
        "repair rounds",
        "reread",
        "verify RTTs",
    ]);
    for count in [0usize, 8, 24] {
        let plan = FaultPlan::random(&ds, count, 0xF1BE5 + count as u64);
        for alg in [
            Algorithm::Fiver,
            Algorithm::FiverChunk,
            Algorithm::FiverMerkle,
            Algorithm::BlockLevelPpl,
        ] {
            let s = run(tb, super::params(), &ds, &plan, alg);
            t.row(&[
                count.to_string(),
                s.algorithm.clone(),
                secs(s.total_time),
                bytes(s.bytes_resent),
                s.failures_detected.to_string(),
                s.repair_rounds.to_string(),
                bytes(s.bytes_reread),
                s.verify_rtts.to_string(),
            ]);
        }
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table III shape: file-level FIVER degrades steeply with fault count;
    /// chunk-level stays nearly flat; both catch every fault.
    #[test]
    fn recovery_cost_shape() {
        let tb = Testbed::hpclab_40g();
        let ds = Dataset::table3_dataset();
        let p = super::super::params();
        let t0 = run(tb, p, &ds, &FaultPlan::none(), Algorithm::Fiver).total_time;
        let plan24 = FaultPlan::random(&ds, 24, 99);
        let file24 = run(tb, p, &ds, &plan24, Algorithm::Fiver);
        let chunk24 = run(tb, p, &ds, &plan24, Algorithm::FiverChunk);
        // Paper: 347.3/179.2 = 1.94x for file-level at 24 faults.
        let file_blowup = file24.total_time / t0;
        assert!(file_blowup > 1.4, "file-level blowup {file_blowup}");
        // Paper: 198.5/180.2 = 1.10x for chunk-level.
        let chunk0 = run(tb, p, &ds, &FaultPlan::none(), Algorithm::FiverChunk).total_time;
        let chunk_blowup = chunk24.total_time / chunk0;
        assert!(chunk_blowup < 1.35, "chunk-level blowup {chunk_blowup}");
        assert!(chunk24.total_time < file24.total_time);
        // Resent data: chunk-level sends ~24 chunks, file-level whole files.
        assert!(chunk24.bytes_resent < file24.bytes_resent / 2);
    }

    /// Merkle repair cost stays flat in fault count and far below both
    /// chunk- and file-level recovery (leaf resolution beats chunk
    /// resolution by block_size/leaf_size).
    #[test]
    fn merkle_repair_flattens_table3() {
        let tb = Testbed::hpclab_40g();
        let ds = Dataset::table3_dataset();
        let p = super::super::params();
        let t0 = run(tb, p, &ds, &FaultPlan::none(), Algorithm::FiverMerkle).total_time;
        let plan24 = FaultPlan::random(&ds, 24, 99);
        let merkle24 = run(tb, p, &ds, &plan24, Algorithm::FiverMerkle);
        let chunk24 = run(tb, p, &ds, &plan24, Algorithm::FiverChunk);
        assert!(
            merkle24.total_time / t0 < 1.08,
            "merkle blowup {}",
            merkle24.total_time / t0
        );
        // 24 faults repair with <= 24 leaves of 64 KiB, not 256 MB chunks.
        assert!(merkle24.bytes_resent <= 24 * p.leaf_size);
        assert!(merkle24.bytes_resent < chunk24.bytes_resent / 1000);
        assert_eq!(merkle24.bytes_reread, merkle24.bytes_resent);
    }

    /// Chunk-level verification in the no-fault case costs about the same
    /// as file-level (paper: 179.2 vs 180.2 s).
    #[test]
    fn chunk_overhead_negligible_without_faults() {
        let tb = Testbed::hpclab_40g();
        let ds = Dataset::table3_dataset();
        let p = super::super::params();
        let file = run(tb, p, &ds, &FaultPlan::none(), Algorithm::Fiver).total_time;
        let chunk = run(tb, p, &ds, &FaultPlan::none(), Algorithm::FiverChunk).total_time;
        assert!((chunk / file - 1.0).abs() < 0.05, "file {file} vs chunk {chunk}");
    }
}
