//! Cache hit-ratio timeline figures (Figs 1, 4, 8, 9).

use crate::config::{Testbed, GB};
use crate::faults::FaultPlan;
use crate::metrics::RunSummary;
use crate::sim::algorithms::{run, Algorithm};
use crate::util::fmt::{pct, secs, Table};
use crate::workload::Dataset;

fn run_alg(tb: Testbed, ds: &Dataset, alg: Algorithm) -> RunSummary {
    run(tb, super::params(), ds, &FaultPlan::none(), alg)
}

/// Fig 1: sequential transfer of one 8 GB file in ESNet-LAN — the
/// motivating observation that checksum I/O after a transfer is served
/// from the page cache on both ends.
pub fn fig1() -> String {
    let tb = Testbed::esnet_lan();
    let ds = Dataset::uniform("8G", 8 * GB, 1);
    let s = run_alg(tb, &ds, Algorithm::Sequential);
    let transfer_share = s.t_transfer_only / s.total_time;
    let mut out = format!(
        "Fig 1 — Sequential transfer of 1x8GB in {} (paper: ~18 s transfer +\n\
         ~27 s checksum; sender cold during transfer, then both sides ~100%\n\
         cache hit ratio during checksum)\n\n\
         total {}  (transfer-only {}, checksum-only {}; transfer phase = {} of total)\n",
        tb.name,
        secs(s.total_time),
        secs(s.t_transfer_only),
        secs(s.t_checksum_only),
        pct(transfer_share),
    );
    out.push_str(&format!(
        "sender   hit-ratio timeline: [{}] avg {}\n",
        s.src_trace.sparkline(60),
        pct(s.src_trace.average())
    ));
    out.push_str(&format!(
        "receiver hit-ratio timeline: [{}] avg {}\n",
        s.dst_trace.sparkline(60),
        pct(s.dst_trace.average())
    ));
    out.push_str(
        "(sender's low-hit prefix = the transfer's first read; the checksum\n\
         phase that follows is all cache hits on both sides — file < free mem)\n",
    );
    out
}

/// Fig 4: receiver-side hit ratios, Shuffled mixed dataset, HPCLab-1G.
pub fn fig4() -> String {
    trace_figure(
        Testbed::hpclab_1g(),
        Dataset::hpclab_mixed(42),
        "Fig 4",
        "paper: FIVER & BlockLevelPpl ~100%; FileLevelPpl 84.1% / Sequential 84.4%\n\
         (five 20GB files > 16 GB free memory drop below 50% during checksum)",
    )
}

/// Fig 8: receiver-side hit ratios, Shuffled mixed dataset, ESNet-WAN.
pub fn fig8() -> String {
    trace_figure(
        Testbed::esnet_wan(),
        Dataset::esnet_mixed(42),
        "Fig 8",
        "paper: FIVER 99.96% / BlockLevelPpl 99.69% (FIVER finishes 50 s earlier);\n\
         FileLevelPpl 78.5% / Sequential 77.8% with sub-10% dips on large files",
    )
}

fn trace_figure(tb: Testbed, ds: Dataset, label: &str, paper: &str) -> String {
    let mut out = format!("{label} — receiver hit ratios, {} on {}\n{paper}\n\n", ds.name, tb.name);
    let mut t = Table::new(&[
        "algorithm", "time", "time-avg hit", "byte-avg hit", "misses", "buckets<10%", "timeline",
    ]);
    for alg in [
        Algorithm::Fiver,
        Algorithm::BlockLevelPpl,
        Algorithm::FileLevelPpl,
        Algorithm::Sequential,
    ] {
        let s = run_alg(tb, &ds, alg);
        t.row(&[
            s.algorithm.clone(),
            secs(s.total_time),
            pct(s.dst_trace.bucket_mean()),
            pct(s.dst_trace.average()),
            crate::util::fmt::bytes(s.dst_trace.total_misses()),
            pct(s.dst_trace.frac_below(0.10)),
            s.dst_trace.sparkline(40),
        ]);
    }
    out.push_str(&t.render());
    out
}

/// Fig 9: FIVER-Hybrid vs the others on ESNet-WAN mixed — cuts ~20% of
/// the sequential/file-level time while keeping their disk-exercising
/// cache behaviour on larger-than-memory files.
pub fn fig9() -> String {
    let tb = Testbed::esnet_wan();
    let ds = Dataset::esnet_mixed(42);
    let mut out = format!(
        "Fig 9 — FIVER-Hybrid, {} on {}\n\
         paper: FIVER 587 s / BlockLevelPpl 658 s (always-cached);\n\
         FIVER-Hybrid 837 s vs FileLevelPpl 1021 s / Sequential 1037 s —\n\
         ~20% faster at the same ~2.5M cache misses (disk-verified large files)\n\n",
        ds.name, tb.name
    );
    let mut t = Table::new(&["algorithm", "time", "time-avg hit", "misses", "vs Sequential"]);
    let seq = run_alg(tb, &ds, Algorithm::Sequential);
    for alg in [
        Algorithm::Fiver,
        Algorithm::BlockLevelPpl,
        Algorithm::FiverHybrid,
        Algorithm::FileLevelPpl,
        Algorithm::Sequential,
    ] {
        let s = run_alg(tb, &ds, alg);
        t.row(&[
            s.algorithm.clone(),
            secs(s.total_time),
            pct(s.dst_trace.bucket_mean()),
            crate::util::fmt::bytes(s.dst_trace.total_misses()),
            format!("{:+.1}%", (s.total_time / seq.total_time - 1.0) * 100.0),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MB;

    /// Fig 1 invariant: checksum phases read from cache on both sides.
    #[test]
    fn fig1_checksum_is_cached() {
        let tb = Testbed::esnet_lan();
        let ds = Dataset::uniform("8G", 8 * GB, 1);
        let s = run_alg(tb, &ds, Algorithm::Sequential);
        // Sender: first read misses (transfer), second read hits (checksum)
        // -> average around 50%; receiver: writes then cached checksum
        // -> ~100%.
        assert!(s.src_trace.average() > 0.35 && s.src_trace.average() < 0.65,
            "sender avg {}", s.src_trace.average());
        assert!(s.dst_trace.average() > 0.95, "receiver avg {}", s.dst_trace.average());
    }

    /// Fig 4/8 invariant: FIVER and block-level stay ~100%; sequential and
    /// file-level dip when files exceed free memory.
    #[test]
    fn fig4_hit_ratio_ordering() {
        let tb = Testbed::hpclab_1g();
        // Trimmed version of the HPCLab mixed dataset (same shape).
        let ds = Dataset::mixed_shuffled(
            "mix",
            &[(20, 10 * MB), (20, 500 * MB), (2, 20 * GB)],
            7,
        );
        let fiver = run_alg(tb, &ds, Algorithm::Fiver);
        let seq = run_alg(tb, &ds, Algorithm::Sequential);
        assert!(fiver.dst_trace.average() > 0.99, "FIVER {}", fiver.dst_trace.average());
        assert!(
            seq.dst_trace.average() < 0.95,
            "Sequential should dip on 20G files: {}",
            seq.dst_trace.average()
        );
        assert!(fiver.total_time < seq.total_time);
    }

    /// Fig 9 invariant: hybrid sits between FIVER and Sequential in time,
    /// and matches Sequential's miss count within a factor of two.
    #[test]
    fn fig9_hybrid_between() {
        let tb = Testbed::esnet_wan();
        let ds = Dataset::mixed_shuffled(
            "mix",
            &[(20, 10 * MB), (10, 500 * MB), (2, 16 * GB)],
            9,
        );
        let fiver = run_alg(tb, &ds, Algorithm::Fiver);
        let hybrid = run_alg(tb, &ds, Algorithm::FiverHybrid);
        let seq = run_alg(tb, &ds, Algorithm::Sequential);
        assert!(fiver.total_time <= hybrid.total_time);
        assert!(hybrid.total_time < seq.total_time, "hybrid {} < seq {}",
            hybrid.total_time, seq.total_time);
        let miss_ratio =
            hybrid.dst_trace.total_misses() as f64 / seq.dst_trace.total_misses().max(1) as f64;
        assert!((0.4..=2.0).contains(&miss_ratio), "miss ratio {miss_ratio}");
    }
}
