//! Adaptive-controller convergence experiment: launch the simulated
//! engine with a deliberately misconfigured knob — one hash worker on a
//! hash-bound SHA1 run, eight stripes on a net-bound throttled run —
//! and let the *same* [`Aimd`] decision core the real engine ships
//! drive the fluid sim's knobs. The run must converge to within 10% of
//! the hand-tuned configuration's throughput, with the full decision
//! trail auditable.
//!
//! The rig is a deliberately small mirror of the real data plane over
//! [`FluidSim`] directly: one coupled flow per file (read → net →
//! write + the busier endpoint's hash station), hash capacity scaling
//! linearly with pool width via [`FluidSim::set_capacity`], and the
//! stripe count latched per file exactly like the sender — an in-flight
//! file never changes its lane set. Excess stripes carry a per-lane
//! framing/reassembly overhead on the wire, so a saturated link rewards
//! walking P down.

use crate::config::{gbps, AlgoParams, Testbed, GB, MB};
use crate::coordinator::control::{Aimd, ControlConfig, ControlEvent, WindowSample};
use crate::hashes::{HashAlgorithm, HashTier};
use crate::sim::{FlowId, FluidSim, ResourceId};
use crate::util::fmt;

/// Wire overhead per stripe beyond the first (per-lane TCP framing,
/// acks, and receiver-side reassembly stalls): a P-stripe file costs
/// `1 + 0.06 (P-1)` network-bytes per payload byte.
const STRIPE_OVERHEAD: f64 = 0.06;

/// Control window in simulated seconds (the sim's `--control-interval`).
const WINDOW_S: f64 = 0.25;

/// The controller configuration the experiment runs under. Identical to
/// the real defaults except a tighter confidence gate: sim windows are
/// noise-free, so a small sustained imbalance is already signal.
fn control_cfg() -> ControlConfig {
    ControlConfig {
        adaptive: true,
        interval_ms: (WINDOW_S * 1e3) as u64,
        max_parallel: 8,
        max_hash_workers: 4,
        conf_threshold: 1.15,
        cooldown_windows: 2,
    }
}

/// A minimal simulated data plane with live knobs.
struct Rig {
    sim: FluidSim,
    read: ResourceId,
    write: ResourceId,
    net: ResourceId,
    hash: ResourceId,
    /// Single-worker hash rate (bytes/s); capacity = `hash_one * workers`.
    hash_one: f64,
    workers: usize,
    stripes: usize,
}

/// Outcome of one rig run.
struct Leg {
    secs: f64,
    windows: usize,
    events: Vec<ControlEvent>,
    workers: usize,
    stripes: usize,
}

impl Rig {
    /// A rig over `tb`'s disk rates with an explicit link capacity
    /// (`net_cap` — the throttled leg overrides the testbed's wire).
    /// Hash capacity follows the run's tier via
    /// [`AlgoParams::leaf_hash_rate`], so a `Tiered` rig hashes leaves
    /// at XXH3's rate plus the cryptographic fold surcharge.
    fn new(
        tb: &Testbed,
        alg: HashAlgorithm,
        tier: HashTier,
        net_cap: f64,
        workers: usize,
        stripes: usize,
    ) -> Rig {
        let mut sim = FluidSim::new();
        let params = AlgoParams { hash: alg, hash_tier: tier, ..Default::default() };
        let hash_one = params.leaf_hash_rate(&tb.src).min(params.leaf_hash_rate(&tb.dst));
        let read = sim.add_resource("read", tb.src.disk_read);
        let write = sim.add_resource("write", tb.dst.disk_write);
        let net = sim.add_resource("net", net_cap);
        let workers = workers.max(1);
        let hash = sim.add_resource("hash", hash_one * workers as f64);
        Rig { sim, read, write, net, hash, hash_one, workers, stripes: stripes.max(1) }
    }

    /// Pool actuation: linear capacity scaling, like
    /// [`crate::sim::testbed::SimEnv::new_parallel`]'s worker model.
    fn set_workers(&mut self, w: usize) {
        self.workers = w.max(1);
        self.sim.set_capacity(self.hash, self.hash_one * self.workers as f64);
    }

    /// Start one file's coupled flow at the *current* stripe count.
    fn start_file(&mut self, bytes: f64) -> FlowId {
        let w_net = 1.0 + STRIPE_OVERHEAD * (self.stripes - 1) as f64;
        self.sim.start_flow(
            bytes,
            vec![(self.read, 1.0), (self.net, w_net), (self.write, 1.0), (self.hash, 1.0)],
            None,
        )
    }

    /// Cumulative busy seconds in the obs plane's group order.
    fn busy(&self) -> [(&'static str, f64); 4] {
        [
            ("read", self.sim.busy_seconds(self.read)),
            ("hash", self.sim.busy_seconds(self.hash)),
            ("write", self.sim.busy_seconds(self.write)),
            ("net", self.sim.busy_seconds(self.net)),
        ]
    }

    /// Transfer `n_files` files of `file_bytes` each, one at a time,
    /// sampling the controller every [`WINDOW_S`]. `aimd = None` is a
    /// static (non-adaptive) run of the same rig.
    fn run(
        mut self,
        mut aimd: Option<Aimd>,
        cfg: &ControlConfig,
        n_files: usize,
        file_bytes: f64,
    ) -> Leg {
        let mut remaining_files = n_files;
        let mut current: Option<(FlowId, f64)> = None;
        let mut done_bytes = 0.0f64;
        let mut prev_total = 0.0f64;
        let mut prev_busy = self.busy();
        let mut windows = 0usize;
        'run: loop {
            let window_end = self.sim.now() + WINDOW_S;
            loop {
                if current.is_none() {
                    if remaining_files == 0 {
                        break 'run;
                    }
                    remaining_files -= 1;
                    // Stripe count latches here, at the file boundary.
                    current = Some((self.start_file(file_bytes), file_bytes));
                }
                let dt_left = window_end - self.sim.now();
                if dt_left <= 1e-9 {
                    break;
                }
                let (f, sz) = current.unwrap();
                self.sim.step(dt_left);
                if self.sim.is_done(f) {
                    done_bytes += sz;
                    current = None;
                }
            }
            windows += 1;
            assert!(windows < 1_000_000, "adaptive sim runaway");
            let total = done_bytes
                + current.map(|(f, sz)| sz - self.sim.remaining(f)).unwrap_or(0.0);
            let busy = self.busy();
            let mut delta = busy;
            for (d, p) in delta.iter_mut().zip(prev_busy.iter()) {
                d.1 = (d.1 - p.1).max(0.0);
            }
            let sample = WindowSample {
                t_secs: self.sim.now(),
                busy: delta,
                throughput: (total - prev_total) / WINDOW_S,
                hash_workers: self.workers,
                stripes: self.stripes,
                pool_occupancy: (0, 0),
            };
            prev_total = total;
            prev_busy = busy;
            if let Some(a) = aimd.as_mut() {
                if let Some((actuator, to)) = a.step(&sample) {
                    match actuator {
                        "hash_workers" => self.set_workers(to.clamp(1, cfg.max_hash_workers)),
                        "stripes" => self.stripes = to.clamp(1, cfg.max_parallel),
                        _ => {}
                    }
                }
            }
        }
        Leg {
            secs: self.sim.now(),
            windows,
            events: aimd.map(|mut a| a.take_events()).unwrap_or_default(),
            workers: self.workers,
            stripes: self.stripes,
        }
    }
}

/// Leg 1: SHA1 on HPCLab-40G is hash-bound at one worker (~2 Gbps vs
/// the 6 Gbps destination write path); launch misconfigured at 1 and
/// let the controller grow the pool.
fn hash_leg(aimd: Option<Aimd>, cfg: &ControlConfig, workers: usize) -> Leg {
    let tb = Testbed::hpclab_40g();
    Rig::new(&tb, HashAlgorithm::Sha1, HashTier::Cryptographic, tb.bandwidth, workers, 1)
        .run(aimd, cfg, 16, GB as f64)
}

/// Leg 1b: the identical hash-bound rig under `--hash-tier tiered`.
/// XXH3-128 leaves lift the single-worker hash rate past the 6 Gbps
/// write path, so the run is no longer hash-bound: one worker already
/// matches the hand-tuned pool and the controller has nothing to grow.
fn tiered_leg(aimd: Option<Aimd>, cfg: &ControlConfig, workers: usize) -> Leg {
    let tb = Testbed::hpclab_40g();
    Rig::new(&tb, HashAlgorithm::Sha1, HashTier::Tiered, tb.bandwidth, workers, 1)
        .run(aimd, cfg, 16, GB as f64)
}

/// Leg 2: the same rig throttled to a 1 Gbps wire, launched with eight
/// stripes — per-lane overhead wastes ~30% of a saturated link, so the
/// controller probe-halves P down to one.
fn net_leg(aimd: Option<Aimd>, cfg: &ControlConfig, stripes: usize) -> Leg {
    let tb = Testbed::hpclab_40g();
    Rig::new(&tb, HashAlgorithm::Sha1, HashTier::Cryptographic, gbps(1.0), 1, stripes)
        .run(aimd, cfg, 40, 128.0 * MB as f64)
}

/// Render one leg's decision trail (same shape as the CLI report).
fn trail(events: &[ControlEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&format!(
            "  t+{:>6.2}s {:<12} {:<7} {} -> {}  [{}]\n",
            ev.t_secs, ev.actuator, ev.action, ev.before, ev.after, ev.signal
        ));
    }
    out
}

/// Run both legs and render the convergence report.
pub fn adaptive_convergence() -> String {
    let cfg = control_cfg();
    let mut table = fmt::Table::new(&[
        "leg", "misconfigured", "adaptive", "hand-tuned", "adaptive vs hand", "decisions",
        "converged",
    ]);
    let h_mis = hash_leg(None, &cfg, 1);
    let h_ada = hash_leg(Some(Aimd::new(cfg.clone())), &cfg, 1);
    let h_hand = hash_leg(None, &cfg, cfg.max_hash_workers);
    table.row(&[
        "hash-bound sha1 (1 worker)".to_string(),
        fmt::secs(h_mis.secs),
        fmt::secs(h_ada.secs),
        fmt::secs(h_hand.secs),
        format!("{:+.1}%", (h_ada.secs / h_hand.secs - 1.0) * 100.0),
        h_ada.events.len().to_string(),
        format!("{} workers", h_ada.workers),
    ]);
    let t_mis = tiered_leg(None, &cfg, 1);
    let t_ada = tiered_leg(Some(Aimd::new(cfg.clone())), &cfg, 1);
    let t_hand = tiered_leg(None, &cfg, cfg.max_hash_workers);
    table.row(&[
        "same rig, --hash-tier tiered".to_string(),
        fmt::secs(t_mis.secs),
        fmt::secs(t_ada.secs),
        fmt::secs(t_hand.secs),
        format!("{:+.1}%", (t_ada.secs / t_hand.secs - 1.0) * 100.0),
        t_ada.events.len().to_string(),
        format!("{} workers", t_ada.workers),
    ]);
    let n_mis = net_leg(None, &cfg, 8);
    let n_ada = net_leg(Some(Aimd::new(cfg.clone())), &cfg, 8);
    let n_hand = net_leg(None, &cfg, 1);
    table.row(&[
        "net-bound 1G (8 stripes)".to_string(),
        fmt::secs(n_mis.secs),
        fmt::secs(n_ada.secs),
        fmt::secs(n_hand.secs),
        format!("{:+.1}%", (n_ada.secs / n_hand.secs - 1.0) * 100.0),
        n_ada.events.len().to_string(),
        format!("{} stripes", n_ada.stripes),
    ]);
    format!(
        "Adaptive concurrency control — convergence from misconfigured\n\
         starts (HPCLab-40G rig, {:.0} ms control windows, same Aimd core\n\
         as the real engine; see DESIGN.md):\n{}\n\
         hash leg decision trail ({} windows total):\n{}\n\
         net leg decision trail ({} windows total):\n{}",
        WINDOW_S * 1e3,
        table.render(),
        h_ada.windows,
        trail(&h_ada.events),
        n_ada.windows,
        trail(&n_ada.events),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_leg_converges_within_ten_percent() {
        let cfg = control_cfg();
        let mis = hash_leg(None, &cfg, 1);
        let ada = hash_leg(Some(Aimd::new(cfg.clone())), &cfg, 1);
        let hand = hash_leg(None, &cfg, cfg.max_hash_workers);
        assert!(
            mis.secs > 1.5 * hand.secs,
            "the misconfigured start must actually hurt: {:.1}s vs {:.1}s",
            mis.secs,
            hand.secs
        );
        assert!(
            ada.secs <= 1.10 * hand.secs,
            "adaptive must land within 10% of hand-tuned: {:.1}s vs {:.1}s",
            ada.secs,
            hand.secs
        );
        // SHA1 on HPCLab-40G: ~2.0 Gbps per worker against a 6 Gbps
        // write path — three workers tip the bottleneck off hash.
        assert_eq!(ada.workers, 3, "trail: {:?}", ada.events);
        assert!(!ada.events.is_empty());
        assert!(ada
            .events
            .iter()
            .all(|e| e.actuator == "hash_workers" && e.action == "grow"));
        // Convergence within k windows: every decision in the first 20.
        for e in &ada.events {
            assert!(e.t_secs <= 20.0 * WINDOW_S, "late decision: {e:?}");
        }
    }

    #[test]
    fn tiered_leg_is_no_longer_hash_bound() {
        let cfg = control_cfg();
        // Under the tiered model one worker already clears the 6 Gbps
        // write path, so a "misconfigured" single-worker start matches
        // the hand-tuned pool — the run is write-bound, not hash-bound.
        let one = tiered_leg(None, &cfg, 1);
        let hand = tiered_leg(None, &cfg, cfg.max_hash_workers);
        assert!(
            one.secs <= 1.02 * hand.secs,
            "tiered single-worker must match hand-tuned: {:.1}s vs {:.1}s",
            one.secs,
            hand.secs
        );
        // And it beats the cryptographic single-worker leg outright.
        let crypto_one = hash_leg(None, &cfg, 1);
        assert!(
            one.secs < 0.67 * crypto_one.secs,
            "tiered must lift the hash-bound leg: {:.1}s vs {:.1}s",
            one.secs,
            crypto_one.secs
        );
        // The controller agrees: no hash-pool growth decisions fire.
        let ada = tiered_leg(Some(Aimd::new(cfg.clone())), &cfg, 1);
        assert!(
            ada.events.iter().all(|e| !(e.actuator == "hash_workers" && e.action == "grow")),
            "tiered leg must not be diagnosed hash-bound: {:?}",
            ada.events
        );
        assert_eq!(ada.workers, 1);
    }

    #[test]
    fn net_leg_sheds_stripes_within_ten_percent() {
        let cfg = control_cfg();
        let mis = net_leg(None, &cfg, 8);
        let ada = net_leg(Some(Aimd::new(cfg.clone())), &cfg, 8);
        let hand = net_leg(None, &cfg, 1);
        assert!(
            mis.secs > 1.25 * hand.secs,
            "8 stripes on a saturated 1G wire must waste capacity: {:.1}s vs {:.1}s",
            mis.secs,
            hand.secs
        );
        assert!(
            ada.secs <= 1.10 * hand.secs,
            "adaptive must land within 10% of hand-tuned: {:.1}s vs {:.1}s",
            ada.secs,
            hand.secs
        );
        assert_eq!(ada.stripes, 1, "trail: {:?}", ada.events);
        let shrinks: Vec<(usize, usize)> = ada
            .events
            .iter()
            .filter(|e| e.actuator == "stripes" && e.action == "shrink")
            .map(|e| (e.before, e.after))
            .collect();
        assert_eq!(shrinks, vec![(8, 4), (4, 2), (2, 1)], "trail: {:?}", ada.events);
        assert!(
            ada.events.iter().all(|e| e.action != "restore"),
            "every probe improves throughput here — no restores: {:?}",
            ada.events
        );
    }

    #[test]
    fn report_renders_both_trails() {
        let out = adaptive_convergence();
        assert!(out.contains("hash-bound sha1"));
        assert!(out.contains("--hash-tier tiered"));
        assert!(out.contains("net-bound 1G"));
        assert!(out.contains("hash_workers"));
        assert!(out.contains("stripes"));
    }
}
