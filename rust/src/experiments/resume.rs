//! Crash/resume sweep — beyond the paper: what a mid-transfer process
//! kill costs with and without the checkpoint journal
//! (`crate::coordinator::journal`). The simulated sweep kills a FIVER
//! transfer at several points of the dataset and restarts it cold (page
//! caches lost, TCP slow start, restart downtime): without a journal the
//! whole dataset re-transfers; with one, only the crossing file's
//! unjournaled tail does. A real loopback engine run then demonstrates
//! the same machinery end-to-end: injected kill, journal handshake,
//! tail-only re-send, bit-identical delivery.

use std::sync::Arc;

use crate::config::{AlgoParams, Testbed, GB};
use crate::coordinator::scheduler::EngineConfig;
use crate::coordinator::session::run_recoverable_local_transfer;
use crate::coordinator::{native_factory, RealAlgorithm, SessionConfig};
use crate::faults::FaultPlan;
use crate::hashes::HashAlgorithm;
use crate::sim::testbed::SimEnv;
use crate::storage::{MemStorage, Storage};
use crate::util::fmt;
use crate::util::rng::SplitMix64;
use crate::util::tmpdir::TempDir;
use crate::workload::Dataset;

/// Restart dead time modeled for the simulated kills (process restart +
/// re-listen + reconnect), on top of the resume-handshake RTT.
const DOWNTIME_SECS: f64 = 5.0;

/// Simulated FIVER transfer of `ds` with an optional kill after
/// `crash_at` streamed bytes. `checkpoint_bytes` is the journal's
/// watermark granularity; 0 means no journal, so the restarted run
/// re-sends the entire dataset. Returns `(total_time, bytes_sent)`.
fn sim_run_with_crash(
    tb: Testbed,
    params: AlgoParams,
    ds: &Dataset,
    crash_at: Option<u64>,
    checkpoint_bytes: u64,
) -> (f64, u64) {
    let mut env = SimEnv::new(tb, params);
    let mut sent = 0u64;
    let mut crashed = false;
    let mut i = 0usize;
    while i < ds.files.len() {
        let f = &ds.files[i];
        if let Some(at) = crash_at {
            if !crashed && sent + f.size >= at {
                // Stream up to the kill boundary, then die and restart.
                let part = at - sent;
                if part > 0 {
                    let flow = env.start_fiver_flow(f, 0, part);
                    env.pump_until(flow);
                    sent += part;
                }
                env.crash_restart(DOWNTIME_SECS);
                crashed = true;
                if checkpoint_bytes == 0 {
                    // No journal: nothing provably delivered — restart
                    // the dataset from scratch.
                    i = 0;
                    continue;
                }
                // Journaled: this file resumes at its checkpointed
                // watermark; fully-delivered files skip at the handshake.
                let wm = (part / checkpoint_bytes) * checkpoint_bytes;
                if f.size > wm {
                    let flow = env.start_fiver_flow(f, wm, f.size - wm);
                    env.pump_until(flow);
                    sent += f.size - wm;
                }
                i += 1;
                continue;
            }
        }
        let flow = env.start_fiver_flow(f, 0, f.size);
        env.pump_until(flow);
        sent += f.size;
        i += 1;
    }
    let t = env.start_timer(params.control_rtts * tb.rtt);
    env.pump_until(t);
    (env.now(), sent)
}

/// Run the sweep and render the report.
pub fn resume_sweep() -> String {
    let mut out = String::new();
    out.push_str(
        "Crash/resume sweep — FIVER killed mid-dataset and restarted\n\
         (cold caches + slow start + 5 s downtime). `none` restarts the\n\
         whole dataset; journaled runs re-send only the crossing file's\n\
         unjournaled tail:\n",
    );
    let params = AlgoParams::default();
    for tb in [Testbed::hpclab_40g(), Testbed::esnet_wan()] {
        let ds = Dataset::uniform("4G", 4 * GB, 8);
        let total = ds.total_bytes();
        let (clean_time, clean_sent) = sim_run_with_crash(tb, params, &ds, None, 0);
        let mut table = crate::util::fmt::Table::new(&[
            "crash at", "journal ckpt", "time", "vs clean", "sent", "re-sent",
        ]);
        for frac in [0.25f64, 0.50, 0.75] {
            let at = (total as f64 * frac) as u64;
            for (label, ckpt) in [
                ("none", 0u64),
                ("64 MiB", 64 << 20),
                ("1 MiB", 1 << 20),
            ] {
                let (time, sent) = sim_run_with_crash(tb, params, &ds, Some(at), ckpt);
                table.row(&[
                    format!("{:.0}%", frac * 100.0),
                    label.to_string(),
                    fmt::secs(time),
                    format!("{:.2}x", time / clean_time),
                    fmt::bytes(sent),
                    fmt::bytes(sent - clean_sent),
                ]);
            }
        }
        out.push_str(&format!(
            "\n{} — clean run: {} / {}:\n{}",
            tb.name,
            fmt::secs(clean_time),
            fmt::bytes(clean_sent),
            table.render()
        ));
    }
    out.push_str(&real_crash_resume_check());
    out
}

/// Real loopback kill + journal resume: a 2-session engine run is killed
/// after ~40% of the dataset, then restarted with `--resume` against the
/// same journals — measured savings, verified bit-identical delivery.
fn real_crash_resume_check() -> String {
    let files = 8usize;
    let size = 256 * 1024usize;
    let total = (files * size) as u64;
    let src = MemStorage::new();
    let dst = MemStorage::new();
    let mut rng = SplitMix64::new(0x5E5);
    let mut names = Vec::with_capacity(files);
    let mut contents = Vec::with_capacity(files);
    for i in 0..files {
        let mut data = vec![0u8; size];
        rng.fill_bytes(&mut data);
        let name = format!("r{i:03}");
        src.put(&name, data.clone());
        names.push(name);
        contents.push(data);
    }
    let jroot = TempDir::create("fiver-resume-exp").expect("scratch dir");
    let mut scfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Fvr256));
    scfg.leaf_size = 16 << 10;
    scfg.journal_checkpoint_leaves = 2;
    scfg.journal_dir = Some(jroot.join("snd"));
    let mut rcfg = scfg.clone();
    rcfg.journal_dir = Some(jroot.join("rcv"));
    let eng = EngineConfig {
        concurrency: 2,
        parallel: 1,
        hash_workers: 2,
        batch_threshold: 0,
        batch_bytes: 1,
    };
    let kill_at = total * 2 / 5;
    let crashed = run_recoverable_local_transfer(
        &names,
        Arc::new(src.clone()) as Arc<dyn Storage>,
        Arc::new(dst.clone()) as Arc<dyn Storage>,
        &scfg,
        &rcfg,
        &eng,
        &FaultPlan::none().with_crash_after_bytes(kill_at),
    );
    assert!(crashed.is_err(), "planned kill must abort the run");
    scfg.resume = true;
    rcfg.resume = true;
    let (report, _) = run_recoverable_local_transfer(
        &names,
        Arc::new(src.clone()) as Arc<dyn Storage>,
        Arc::new(dst.clone()) as Arc<dyn Storage>,
        &scfg,
        &rcfg,
        &eng,
        &FaultPlan::none(),
    )
    .expect("resumed run");
    for (name, expect) in names.iter().zip(&contents) {
        assert_eq!(&dst.get(name).unwrap(), expect, "delivered bytes differ on {name}");
    }
    let total_rep = report.aggregate();
    format!(
        "\nreal mode (loopback, {files}x{}, kill after {}, then --resume):\n  \
         resumed run sent {} ({} saved by the journal, {} files skipped \
         outright); delivery verified bit-identical\n",
        fmt::bytes(size as u64),
        fmt::bytes(kill_at),
        fmt::bytes(total_rep.bytes_sent),
        fmt::bytes(total_rep.bytes_skipped),
        total_rep.files_skipped,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MB;

    #[test]
    fn journaled_restart_beats_scratch_restart() {
        let tb = Testbed::hpclab_40g();
        let ds = Dataset::uniform("1G", GB, 4);
        let p = AlgoParams::default();
        let total = ds.total_bytes();
        let at = total / 2;
        let (t_clean, sent_clean) = sim_run_with_crash(tb, p, &ds, None, 0);
        let (t_none, sent_none) = sim_run_with_crash(tb, p, &ds, Some(at), 0);
        let (t_jrnl, sent_jrnl) = sim_run_with_crash(tb, p, &ds, Some(at), 64 * MB);
        assert_eq!(sent_clean, total);
        // Scratch restart re-sends everything streamed before the kill.
        assert_eq!(sent_none, at + total);
        // Journaled restart re-sends at most one checkpoint interval.
        assert!(sent_jrnl <= total + 64 * MB, "sent {sent_jrnl}");
        assert!(t_clean < t_jrnl && t_jrnl < t_none, "{t_clean} < {t_jrnl} < {t_none}");
    }

    #[test]
    fn sweep_renders() {
        // The full sweep runs in `repro-experiments resume`; here just the
        // sim rows for one testbed shape (the real check runs in the
        // crash-recovery integration tests).
        let tb = Testbed::hpclab_40g();
        let ds = Dataset::uniform("1G", GB, 2);
        let (t, sent) = sim_run_with_crash(tb, AlgoParams::default(), &ds, Some(GB / 3), GB / 8);
        assert!(t > 0.0 && sent >= ds.total_bytes());
    }
}
