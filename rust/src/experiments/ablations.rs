//! Ablations over the design parameters the paper argues about in §III:
//!
//! * **Block size** (block-level pipelining): "Finding the optimal block
//!   size could be challenging since small blocks will suffer from poor
//!   transfer throughput and large blocks will cause suboptimal pipelining
//!   of transfer and checksum operations."
//! * **Chunk size** (FIVER chunk-level verification): "frequent execution
//!   of digest() ... does not affect the performance of FIVER too much
//!   unless CHUNK_SIZE is too small"; smaller chunks also cost less to
//!   repair.
//! * **Queue capacity** (Algorithms 1 & 2): the fixed-size queue bounds
//!   memory while transferring back-pressure; FIVER should be insensitive
//!   above a small floor.

use crate::config::{AlgoParams, Testbed, GB, MB};
use crate::faults::FaultPlan;
use crate::sim::algorithms::{run, Algorithm};
use crate::util::fmt::{bytes, pct, secs, Table};
use crate::workload::Dataset;

/// Block-size sweep for block-level pipelining (ESNet-WAN, where both
/// failure modes are visible).
pub fn ablation_block_size() -> String {
    let tb = Testbed::esnet_wan();
    let uniform = Dataset::uniform("10G", 10 * GB, 2);
    let sorted = Dataset::sorted_5m250m(50);
    let mut out = String::from(
        "Ablation — block size in block-level pipelining (ESNet-WAN)\n\
         paper §III: small blocks suffer poor transfer throughput (per-block\n\
         restarts), large blocks pipeline poorly; 256 MB was their pick\n\n",
    );
    let mut t = Table::new(&["block size", "uniform 2x10G", "Sorted-5M250M"]);
    for bs in [16 * MB, 64 * MB, 256 * MB, GB] {
        let params = AlgoParams { block_size: bs, ..AlgoParams::default() };
        let u = run(tb, params, &uniform, &FaultPlan::none(), Algorithm::BlockLevelPpl);
        let s = run(tb, params, &sorted, &FaultPlan::none(), Algorithm::BlockLevelPpl);
        t.row(&[bytes(bs), pct(u.overhead().unwrap()), pct(s.overhead().unwrap())]);
    }
    out.push_str(&t.render());
    out
}

/// Chunk-size sweep for FIVER chunk-level verification under faults.
pub fn ablation_chunk_size() -> String {
    let tb = Testbed::hpclab_40g();
    let ds = Dataset::table3_dataset();
    let faults = FaultPlan::random(&ds, 8, 0xAB1A);
    let mut out = String::from(
        "Ablation — FIVER CHUNK_SIZE under 8 faults (HPCLab-40G, Table III dataset)\n\
         paper §IV-A: chunk-level verification is ~free without faults and its\n\
         recovery cost shrinks with the chunk\n\n",
    );
    let mut t = Table::new(&["chunk size", "no faults", "8 faults", "resent"]);
    for cs in [16 * MB, 64 * MB, 256 * MB, GB] {
        let params = AlgoParams { chunk_size: cs, ..AlgoParams::default() };
        let clean = run(tb, params, &ds, &FaultPlan::none(), Algorithm::FiverChunk);
        let faulty = run(tb, params, &ds, &faults, Algorithm::FiverChunk);
        t.row(&[
            bytes(cs),
            secs(clean.total_time),
            secs(faulty.total_time),
            bytes(faulty.bytes_resent),
        ]);
    }
    out.push_str(&t.render());
    out
}

/// Queue-capacity sweep on a real loopback transfer (the one parameter
/// that only exists in real mode).
pub fn ablation_queue_capacity() -> String {
    use crate::coordinator::session::run_local_transfer;
    use crate::coordinator::{native_factory, RealAlgorithm, SessionConfig};
    use crate::hashes::HashAlgorithm;
    use crate::storage::MemStorage;
    use crate::util::rng::SplitMix64;
    use std::sync::Arc;

    let mut out = String::from(
        "Ablation — queue capacity, real loopback FIVER transfer (8 x 8 MiB)\n\
         Algorithms 1 & 2: the fixed-size queue bounds memory; throughput\n\
         should be flat above a small floor (back-pressure, not starvation)\n\n",
    );
    let src = MemStorage::new();
    let mut rng = SplitMix64::new(5);
    let mut names = Vec::new();
    for i in 0..8 {
        let mut data = vec![0u8; 8 << 20];
        rng.fill_bytes(&mut data);
        let name = format!("q{i}");
        src.put(&name, data);
        names.push(name);
    }
    let total = 8u64 * (8 << 20);
    let mut t = Table::new(&["queue capacity", "time", "throughput"]);
    for cap in [256 << 10, 1 << 20, 8 << 20, 64 << 20] {
        let mut cfg = SessionConfig::new(
            RealAlgorithm::Fiver,
            native_factory(HashAlgorithm::Fvr256),
        );
        cfg.queue_capacity = cap;
        // Median of 3 runs to damp scheduler noise.
        let mut times: Vec<f64> = (0..3)
            .map(|_| {
                let dst = MemStorage::new();
                let (rep, _) = run_local_transfer(
                    &names,
                    Arc::new(src.clone()),
                    Arc::new(dst),
                    &cfg,
                    &FaultPlan::none(),
                )
                .expect("transfer");
                rep.elapsed_secs
            })
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[1];
        t.row(&[
            bytes(cap as u64),
            format!("{:.3}s", median),
            crate::util::fmt::rate_bps(total as f64 * 8.0 / median),
        ]);
    }
    out.push_str(&t.render());
    out
}

/// All three ablations.
pub fn ablations() -> String {
    format!(
        "{}\n{}\n{}",
        ablation_block_size(),
        ablation_chunk_size(),
        ablation_queue_capacity()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// §III claim: tiny blocks hurt in the WAN; the paper's 256 MB choice
    /// beats 16 MB on uniform data.
    #[test]
    fn small_blocks_hurt_wan_uniform() {
        let tb = Testbed::esnet_wan();
        let ds = Dataset::uniform("10G", 10 * GB, 2);
        let small = run(
            tb,
            AlgoParams { block_size: 16 * MB, ..AlgoParams::default() },
            &ds,
            &FaultPlan::none(),
            Algorithm::BlockLevelPpl,
        );
        let paper_pick = run(
            tb,
            AlgoParams { block_size: 256 * MB, ..AlgoParams::default() },
            &ds,
            &FaultPlan::none(),
            Algorithm::BlockLevelPpl,
        );
        let so = small.overhead().unwrap();
        let po = paper_pick.overhead().unwrap();
        assert!(so > po, "16M {so} should exceed 256M {po}");
    }

    /// §IV-A claim: chunk size barely affects fault-free time, but repair
    /// cost scales with it.
    #[test]
    fn chunk_size_tradeoff() {
        let tb = Testbed::hpclab_40g();
        let ds = Dataset::table3_dataset();
        let p16 = AlgoParams { chunk_size: 16 * MB, ..AlgoParams::default() };
        let p1g = AlgoParams { chunk_size: GB, ..AlgoParams::default() };
        let clean16 = run(tb, p16, &ds, &FaultPlan::none(), Algorithm::FiverChunk).total_time;
        let clean1g = run(tb, p1g, &ds, &FaultPlan::none(), Algorithm::FiverChunk).total_time;
        assert!((clean16 / clean1g - 1.0).abs() < 0.05, "fault-free ~flat: {clean16} vs {clean1g}");
        let faults = FaultPlan::random(&ds, 8, 3);
        let r16 = run(tb, p16, &ds, &faults, Algorithm::FiverChunk).bytes_resent;
        let r1g = run(tb, p1g, &ds, &faults, Algorithm::FiverChunk).bytes_resent;
        assert!(r16 < r1g, "smaller chunks repair cheaper: {r16} vs {r1g}");
    }
}
