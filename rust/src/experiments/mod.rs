//! Experiment drivers: one per table/figure of the paper's evaluation
//! (§IV), regenerating the same rows/series from the simulated testbeds.
//! See DESIGN.md §4 for the experiment index and EXPERIMENTS.md for
//! paper-vs-measured results.
//!
//! Run via the `repro-experiments` binary: `repro-experiments fig5`,
//! `repro-experiments all`, etc.

/// Design-ablation sweep.
pub mod ablations;
/// Adaptive-controller convergence from misconfigured starts.
pub mod adaptive;
/// Concurrency/parallelism sweep.
pub mod concurrency;
/// Delta-sync sweep plus a real loopback check.
pub mod delta;
/// Table III: fault injection.
pub mod faults_table;
/// Figure 10: hash throughput.
pub mod hash_fig;
/// Storage I/O backend sweep.
pub mod io_backend;
/// Figures 3/5/6/7: verification overheads.
pub mod overheads;
/// Crash/resume sweep.
pub mod resume;
/// Figures 1/4/8/9: time-series traces.
pub mod traces;

use crate::config::{AlgoParams, Testbed, GB, MB};
use crate::workload::Dataset;

/// Render Tables I and II (testbed specifications as configured).
pub fn tables() -> String {
    let mut t = crate::util::fmt::Table::new(&[
        "Testbed", "bandwidth", "RTT", "src disk R", "dst disk W", "MD5 rate", "free mem",
    ]);
    for tb in Testbed::all() {
        t.row(&[
            tb.name.to_string(),
            crate::util::fmt::rate_bps(tb.bandwidth * 8.0),
            format!("{:.1} ms", tb.rtt * 1e3),
            crate::util::fmt::rate_bps(tb.src.disk_read * 8.0),
            crate::util::fmt::rate_bps(tb.dst.disk_write * 8.0),
            crate::util::fmt::rate_bps(tb.src.hash_md5 * 8.0),
            crate::util::fmt::bytes(tb.src.free_mem),
        ]);
    }
    format!(
        "Tables I & II — testbed specifications (rates calibrated from the\n\
         paper's reported achieved numbers, see config/mod.rs):\n{}",
        t.render()
    )
}

/// The uniform datasets used per testbed (file sizes representing "small
/// and large files in each network", §IV).
pub fn uniform_datasets(tb: &Testbed) -> Vec<Dataset> {
    match tb.name {
        "HPCLab-1G" | "HPCLab-40G" => vec![
            Dataset::uniform("10M", 10 * MB, 1000),
            Dataset::uniform("100M", 100 * MB, 100),
            Dataset::uniform("1G", GB, 10),
            Dataset::uniform("10G", 10 * GB, 1),
        ],
        _ => vec![
            Dataset::uniform("100M", 100 * MB, 100),
            Dataset::uniform("1G", GB, 10),
            Dataset::uniform("10G", 10 * GB, 4),
            Dataset::uniform("100G", 100 * GB, 1),
        ],
    }
}

/// The mixed datasets per testbed: Shuffled + Sorted-5M250M (§IV).
pub fn mixed_datasets(tb: &Testbed) -> Vec<Dataset> {
    let shuffled = match tb.name {
        "HPCLab-1G" | "HPCLab-40G" => Dataset::hpclab_mixed(42),
        _ => Dataset::esnet_mixed(42),
    };
    vec![shuffled, Dataset::sorted_5m250m(100)]
}

/// Default parameters (MD5, 256 MB blocks — the paper's configuration).
pub fn params() -> AlgoParams {
    AlgoParams::default()
}

/// Run an experiment by name; `all` runs the full set.
pub fn run_by_name(name: &str) -> Option<String> {
    Some(match name {
        "tables" => tables(),
        "fig1" => traces::fig1(),
        "fig3" => overheads::figure(Testbed::hpclab_1g(), "Fig 3"),
        "fig4" => traces::fig4(),
        "fig5" => overheads::figure(Testbed::hpclab_40g(), "Fig 5"),
        "fig6" => overheads::figure(Testbed::esnet_lan(), "Fig 6"),
        "fig7" => overheads::figure(Testbed::esnet_wan(), "Fig 7"),
        "fig8" => traces::fig8(),
        "fig9" => traces::fig9(),
        "fig10" => hash_fig::fig10(),
        "table3" => faults_table::table3(),
        "ablations" => ablations::ablations(),
        "concurrency" => concurrency::concurrency_sweep(),
        "resume" => resume::resume_sweep(),
        "delta" => delta::delta_sweep(),
        "io_backend" => io_backend::io_backend_sweep(),
        "adaptive" => adaptive::adaptive_convergence(),
        "all" => {
            let mut out = String::new();
            for n in ALL {
                out.push_str(&run_by_name(n).unwrap());
                out.push_str("\n\n");
            }
            out
        }
        _ => return None,
    })
}

/// All experiment names in paper order.
pub const ALL: &[&str] = &[
    "tables", "fig1", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "table3",
    "ablations", "concurrency", "resume", "delta", "io_backend", "adaptive",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_by_name_covers_all() {
        for n in ALL {
            assert!(run_by_name(n).is_some() || *n == "all", "{n}");
        }
        assert!(run_by_name("nope").is_none());
    }

    #[test]
    fn tables_render() {
        let s = tables();
        assert!(s.contains("ESNet-WAN"));
        assert!(s.contains("HPCLab-1G"));
    }

    #[test]
    fn dataset_sets_per_testbed() {
        assert_eq!(uniform_datasets(&Testbed::hpclab_1g()).len(), 4);
        assert_eq!(uniform_datasets(&Testbed::esnet_lan()).len(), 4);
        let mixed = mixed_datasets(&Testbed::esnet_wan());
        assert_eq!(mixed.len(), 2);
        assert_eq!(mixed[0].len(), 271);
    }
}
