//! Concurrency sweep — the parallel engine beyond the paper: the 1000×10M
//! lots-of-small-files dataset driven by N concurrent sessions sharing a
//! hash worker pool, in the simulated testbeds and over a real loopback
//! engine run. The serial FIVER driver is latency/hash-core-bound on this
//! workload; concurrency moves the bottleneck to the slowest shared
//! resource (destination disk on HPCLab-40G).

use std::sync::Arc;

use crate::config::{AlgoParams, Testbed, MB};
use crate::coordinator::scheduler::EngineConfig;
use crate::coordinator::session::run_parallel_local_transfer;
use crate::coordinator::{native_factory, RealAlgorithm, SessionConfig};
use crate::faults::FaultPlan;
use crate::hashes::HashAlgorithm;
use crate::sim::algorithms::{run_concurrent, Algorithm};
use crate::storage::{MemStorage, Storage};
use crate::util::fmt;
use crate::util::rng::SplitMix64;
use crate::workload::Dataset;

/// Session counts swept (hash pool sized to match).
pub const SWEEP: [usize; 4] = [1, 2, 4, 8];

/// Run the sweep and render the report.
pub fn concurrency_sweep() -> String {
    let mut out = String::new();
    out.push_str(
        "Concurrency sweep — parallel engine on the 1000x10M dataset\n\
         (FIVER, N concurrent sessions, shared hash pool of N workers,\n\
         small files batched per the scheduler's aggregation plan):\n",
    );
    for tb in [Testbed::hpclab_40g(), Testbed::esnet_wan()] {
        let ds = Dataset::uniform("10M", 10 * MB, 1000);
        let mut table =
            fmt::Table::new(&["N", "time", "speedup", "Eq.1 overhead", "min session util"]);
        let mut base_time = 0.0;
        for n in SWEEP {
            let s = run_concurrent(
                tb,
                AlgoParams::default(),
                &ds,
                &FaultPlan::none(),
                Algorithm::Fiver,
                n,
                n,
            );
            if n == 1 {
                base_time = s.total_time;
            }
            let min_util = s
                .per_session
                .iter()
                .map(|x| x.utilization(s.total_time))
                .fold(1.0f64, f64::min);
            table.row(&[
                n.to_string(),
                fmt::secs(s.total_time),
                format!("{:.2}x", base_time / s.total_time),
                format!("{:+.1}%", s.overhead().unwrap() * 100.0),
                fmt::pct(min_util),
            ]);
        }
        out.push_str(&format!("\n{} — simulated:\n{}", tb.name, table.render()));
    }
    out.push_str(&pool_starvation_sweep());
    out.push_str(&real_mode_sweep());
    out
}

/// Shrink the data-plane buffer pool under fixed concurrency: the point
/// where the pool (not hash/net/disk) becomes the bottleneck — the regime
/// `--pool-buffers` must be kept out of. Pool capacity is an explicit sim
/// resource (see [`crate::sim::testbed::SimEnv::new_parallel`]).
fn pool_starvation_sweep() -> String {
    let tb = Testbed::hpclab_40g();
    let ds = Dataset::uniform("10M", 10 * MB, 200);
    let n = 4usize;
    let base = AlgoParams::default();
    let queue_bufs = base.queue_capacity / base.io_buf_size;
    let mut table = fmt::Table::new(&["pool buffers", "time", "vs unbounded"]);
    let unbounded = run_concurrent(tb, base, &ds, &FaultPlan::none(), Algorithm::Fiver, n, n);
    for (label, bufs) in [
        ("8x queue", 8 * queue_bufs),
        ("4x queue", 4 * queue_bufs),
        ("1x queue", queue_bufs),
        ("1/2 queue", queue_bufs / 2),
        ("1/4 queue", queue_bufs / 4),
    ] {
        // Per-endpoint pool sized against ONE session's queue worth of
        // buffers: below ~1x the pool (not hash/net/disk) caps the
        // endpoint and the sweep shows the cliff.
        let params = AlgoParams { pool_buffers: bufs, ..base };
        let s = run_concurrent(tb, params, &ds, &FaultPlan::none(), Algorithm::Fiver, n, n);
        table.row(&[
            label.to_string(),
            fmt::secs(s.total_time),
            format!("{:.2}x", s.total_time / unbounded.total_time),
        ]);
    }
    format!(
        "\n{} — pool starvation at concurrency {n} (unbounded: {}):\n{}",
        tb.name,
        fmt::secs(unbounded.total_time),
        table.render()
    )
}

/// A scaled-down real engine run over loopback TCP (the 1000×10M shape at
/// 1/80 size so `repro-experiments all` stays quick): reports wall-clock
/// at concurrency 1 vs 8 — measured, not asserted, because loopback
/// wall-clock depends on the host.
fn real_mode_sweep() -> String {
    let files = 192usize;
    let size = 128 * 1024usize;
    let src = MemStorage::new();
    let mut rng = SplitMix64::new(0xC0C0);
    let mut names = Vec::with_capacity(files);
    for i in 0..files {
        let mut data = vec![0u8; size];
        rng.fill_bytes(&mut data);
        let name = format!("c{i:04}");
        src.put(&name, data);
        names.push(name);
    }
    let cfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Fvr256));
    // Real runs render through the same RunSummary surface as the sim
    // (pool telemetry mirrored from the aggregate TransferReport).
    let run = |concurrency: usize| -> crate::metrics::RunSummary {
        let eng = EngineConfig {
            concurrency,
            parallel: 1,
            hash_workers: concurrency.max(2),
            batch_threshold: 256 * 1024,
            batch_bytes: 2 << 20,
        };
        let dst: Arc<dyn Storage> = Arc::new(MemStorage::new());
        let (report, _receiver) = run_parallel_local_transfer(
            &names,
            Arc::new(src.clone()),
            dst,
            &cfg,
            &eng,
            &FaultPlan::none(),
        )
        .expect("real engine run");
        let total = report.aggregate();
        assert_eq!(total.bytes_sent, (files * size) as u64);
        crate::metrics::RunSummary::from_real(&total, concurrency)
    };
    let s1 = run(1);
    let s8 = run(8);
    format!(
        "\nreal mode (loopback, {files}x{}, MemStorage, fvr256):\n  \
         concurrency 1: {}   concurrency 8: {}   ({:.2}x)\n  \
         sender pool: peak {} / {} buffers in flight, {} / {} fallback allocs\n",
        fmt::bytes(size as u64),
        fmt::secs(s1.total_time),
        fmt::secs(s8.total_time),
        s1.total_time / s8.total_time,
        s1.pool_peak_in_flight,
        s8.pool_peak_in_flight,
        s1.pool_fallback_allocs,
        s8.pool_fallback_allocs,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_renders_all_rows() {
        let out = concurrency_sweep();
        assert!(out.contains("HPCLab-40G"));
        assert!(out.contains("ESNet-WAN"));
        assert!(out.contains("pool starvation"));
        assert!(out.contains("real mode"));
        // One row per swept N per testbed.
        for n in SWEEP {
            assert!(out.lines().any(|l| l.trim_start().starts_with(&n.to_string())), "{n}");
        }
    }
}
