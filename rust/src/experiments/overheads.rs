//! Overhead bar-chart figures (Figs 3, 5, 6, 7): per testbed, Eq. 1
//! overhead of Sequential / FileLevelPpl / BlockLevelPpl / FIVER over the
//! uniform datasets (subfigure a) and the mixed datasets (subfigure b).

use crate::config::Testbed;
use crate::faults::FaultPlan;
use crate::sim::algorithms::{run, Algorithm};
use crate::util::fmt::{pct, secs, Table};
use crate::workload::Dataset;

/// The four algorithms the paper's overhead figures compare.
pub const FIGURE_ALGS: [Algorithm; 4] = [
    Algorithm::Sequential,
    Algorithm::FileLevelPpl,
    Algorithm::BlockLevelPpl,
    Algorithm::Fiver,
];

/// Paper-reported overhead summaries quoted in §IV text, for side-by-side
/// comparison in the rendered output.
fn paper_note(tb: &Testbed) -> &'static str {
    match tb.name {
        "HPCLab-1G" => {
            "paper: FIVER <3% uniform / <1% mixed; FileLevelPpl up to 25% large files;\n\
             BlockLevelPpl ~FIVER uniform, 6% Shuffled, >20% Sorted-5M250M"
        }
        "HPCLab-40G" => {
            "paper: FIVER <10% uniform, <5% mixed; BlockLevelPpl 13-16% uniform,\n\
             20% Shuffled, ~60% Sorted; FileLevelPpl up to 70% single-file, 55-60% mixed"
        }
        "ESNet-LAN" => {
            "paper: FIVER <10%; BlockLevelPpl <10% small files, ~15% large, 12%\n\
             Shuffled, 38% Sorted; FileLevelPpl 52% Shuffled, 39% Sorted"
        }
        _ => {
            "paper: FIVER <10% all types; BlockLevelPpl ~15% uniform, 20% Shuffled,\n\
             ~61% Sorted; FileLevelPpl >60% mixed"
        }
    }
}

/// Render one overhead figure (both subfigures).
pub fn figure(tb: Testbed, label: &str) -> String {
    let mut out = format!(
        "{label} — overhead (Eq. 1) in {} ({})\n{}\n\n",
        tb.name,
        match tb.name {
            "HPCLab-1G" => "checksum faster than transfer",
            _ => "transfer faster than checksum",
        },
        paper_note(&tb),
    );
    out.push_str(&subfigure(tb, &super::uniform_datasets(&tb), "a) uniform datasets"));
    out.push('\n');
    out.push_str(&subfigure(tb, &super::mixed_datasets(&tb), "b) mixed datasets"));
    out
}

fn subfigure(tb: Testbed, datasets: &[Dataset], caption: &str) -> String {
    let mut t = Table::new(&[
        "dataset", "algorithm", "time", "t_transfer", "t_chksum", "overhead",
    ]);
    for ds in datasets {
        for alg in FIGURE_ALGS {
            let s = run(tb, super::params(), ds, &FaultPlan::none(), alg);
            t.row(&[
                ds.name.clone(),
                s.algorithm.clone(),
                secs(s.total_time),
                secs(s.t_transfer_only),
                secs(s.t_checksum_only),
                pct(s.overhead().expect("sim runs carry Eq. 1 baselines")),
            ]);
        }
    }
    format!("{caption}\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgoParams, GB, MB};
    use crate::metrics::RunSummary;

    fn overhead_of(tb: Testbed, ds: &Dataset, alg: Algorithm) -> RunSummary {
        run(tb, AlgoParams::default(), ds, &FaultPlan::none(), alg)
    }

    /// Fig 3a shape: in HPCLab-1G every algorithm is cheap for small
    /// files; file-level pipelining pays ~25% on the single large file.
    #[test]
    fn fig3_shape() {
        let tb = Testbed::hpclab_1g();
        let small = Dataset::uniform("10M", 10 * MB, 50);
        for alg in FIGURE_ALGS {
            let o = overhead_of(tb, &small, alg).overhead().unwrap();
            assert!(o < 0.40, "{}: small-file overhead {o}", alg.name());
        }
        let large = Dataset::uniform("10G", 10 * GB, 1);
        let file = overhead_of(tb, &large, Algorithm::FileLevelPpl).overhead().unwrap();
        let fiver = overhead_of(tb, &large, Algorithm::Fiver).overhead().unwrap();
        assert!(file > 0.15, "file-level on one large file: {file}");
        assert!(fiver < 0.05, "FIVER on one large file: {fiver}");
    }

    /// Fig 5 shape: HPCLab-40G, block-level ~13-16% uniform, FIVER <10%.
    #[test]
    fn fig5_shape() {
        let tb = Testbed::hpclab_40g();
        let ds = Dataset::uniform("1G", GB, 10);
        let block = overhead_of(tb, &ds, Algorithm::BlockLevelPpl).overhead().unwrap();
        let fiver = overhead_of(tb, &ds, Algorithm::Fiver).overhead().unwrap();
        assert!(fiver < 0.10, "FIVER {fiver}");
        assert!(block > fiver, "block {block} > fiver {fiver}");
        assert!((0.05..0.35).contains(&block), "block {block}");
    }

    /// Fig 6b/7b shape: Sorted-5M250M punishes block-level pipelining far
    /// more than Shuffled, and WAN more than LAN.
    #[test]
    fn sorted_vs_shuffled_and_wan_amplification() {
        let sorted = Dataset::sorted_5m250m(30);
        let lan = overhead_of(Testbed::esnet_lan(), &sorted, Algorithm::BlockLevelPpl)
            .overhead()
            .unwrap();
        let wan = overhead_of(Testbed::esnet_wan(), &sorted, Algorithm::BlockLevelPpl)
            .overhead()
            .unwrap();
        assert!(lan > 0.20, "LAN sorted block-level {lan}");
        assert!(wan > lan, "WAN {wan} should exceed LAN {lan}");
        let fiver_wan =
            overhead_of(Testbed::esnet_wan(), &sorted, Algorithm::Fiver).overhead().unwrap();
        assert!(fiver_wan < 0.10, "FIVER sorted WAN {fiver_wan}");
    }

    #[test]
    fn figure_renders() {
        // Smoke the smallest figure end-to-end (trimmed datasets for speed).
        let tb = Testbed::hpclab_40g();
        let ds = [Dataset::uniform("100M", 100 * MB, 5)];
        let s = subfigure(tb, &ds, "a) uniform");
        assert!(s.contains("FIVER"));
        assert!(s.contains("overhead"));
    }
}
