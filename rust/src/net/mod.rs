//! TCP throughput model: slow start, congestion avoidance toward link
//! rate, and the RFC 2581 idle restart the paper blames for block-level
//! pipelining's WAN penalty ("dividing large files into smaller blocks
//! could deteriorate transfer throughput ... which may trigger TCP window
//! size reset for every block transfer").
//!
//! The model is deliberately a *rate envelope*, not a packet simulator:
//! the fluid-flow engine ([`crate::sim`]) asks "what send rate does the
//! connection sustain at time t, and when does that rate next change?" —
//! enough to reproduce the paper's phenomena (per-block restarts, idle
//! resets after checksum stalls, RTT-dominated small-file costs) without
//! simulating 165 GB at MTU granularity.

/// TCP connection parameters.
#[derive(Debug, Clone, Copy)]
pub struct TcpParams {
    /// Link (bottleneck) bandwidth in bytes/sec.
    pub bandwidth: f64,
    /// Round-trip time in seconds.
    pub rtt: f64,
    /// Initial congestion window in bytes (RFC 6928: 10 * MSS).
    pub init_cwnd: u64,
    /// Retransmission timeout; idle longer than this resets cwnd
    /// (RFC 2581 §4.1 restart window). Linux default minimum is 200 ms,
    /// production RTO ~ max(1s, smoothed RTT); we use max(1s, 2*RTT).
    pub rto: f64,
}

impl TcpParams {
    /// Parameters for a link of the given bandwidth and round-trip time.
    pub fn new(bandwidth_bytes_per_sec: f64, rtt_secs: f64) -> TcpParams {
        TcpParams {
            bandwidth: bandwidth_bytes_per_sec,
            rtt: rtt_secs,
            init_cwnd: 10 * 1460,
            rto: (2.0 * rtt_secs).max(1.0),
        }
    }

    /// Bandwidth-delay product in bytes — the cwnd needed to fill the pipe.
    pub fn bdp(&self) -> f64 {
        (self.bandwidth * self.rtt).max(self.init_cwnd as f64)
    }
}

/// Connection state: tracks cwnd growth and idle periods.
///
/// Usage from the fluid engine: call [`on_active`] when a flow (re)starts
/// using the connection, then repeatedly query [`rate`] /
/// [`next_rate_change`] as virtual time advances; call [`on_idle_start`]
/// when the sender stops having data to send.
#[derive(Debug, Clone)]
pub struct TcpConn {
    /// The link parameters this connection models.
    pub params: TcpParams,
    /// cwnd in bytes.
    cwnd: f64,
    /// Time the connection last sent data (for idle-reset detection).
    last_send: Option<f64>,
    /// Number of slow-start restarts incurred (metrics: the paper's
    /// "TCP window resets").
    pub restarts: u64,
}

impl TcpConn {
    /// A fresh connection (starts in slow start).
    pub fn new(params: TcpParams) -> TcpConn {
        TcpConn { params, cwnd: params.init_cwnd as f64, last_send: None, restarts: 0 }
    }

    /// Mark the connection active at `now`. If it had been idle longer than
    /// RTO, the congestion window collapses back to the restart window
    /// (slow start restart) — the penalty block-level pipelining pays per
    /// block when checksum is the bottleneck.
    pub fn on_active(&mut self, now: f64) {
        if let Some(last) = self.last_send {
            if now - last > self.params.rto && self.cwnd > self.params.init_cwnd as f64 {
                self.cwnd = self.params.init_cwnd as f64;
                self.restarts += 1;
            }
        }
        self.last_send = Some(now);
    }

    /// Record that data flowed up to time `now` (keeps idle detection
    /// accurate) and grow cwnd for the elapsed active period: doubling per
    /// RTT (slow start) until the BDP, then capped (the paper's fabrics are
    /// loss-free at these utilizations, so we stay at the envelope).
    pub fn advance(&mut self, from: f64, to: f64) {
        debug_assert!(to >= from);
        let bdp = self.params.bdp();
        if self.cwnd < bdp {
            let rtts = (to - from) / self.params.rtt;
            self.cwnd = (self.cwnd * 2f64.powf(rtts)).min(bdp);
        }
        self.last_send = Some(to);
    }

    /// Instantaneous sustainable send rate (bytes/sec).
    pub fn rate(&self) -> f64 {
        (self.cwnd / self.params.rtt).min(self.params.bandwidth)
    }

    /// Time until the rate next changes materially (None if at link rate).
    /// The engine uses this to bound its integration steps during slow
    /// start; one RTT per step reproduces doubling behaviour.
    pub fn next_rate_change(&self) -> Option<f64> {
        if self.rate() >= self.params.bandwidth * 0.999 {
            None
        } else {
            Some(self.params.rtt)
        }
    }

    /// Called when the sender goes idle at `now` (e.g. sequential transfer
    /// entering its checksum phase, or block pipelining stalling on the
    /// checksum station).
    pub fn on_idle_start(&mut self, now: f64) {
        self.last_send = Some(now);
    }

    /// Seconds to move `bytes` through this connection starting at `now`,
    /// assuming the connection is the only bottleneck (used for analytic
    /// shortcuts and tests; the fluid engine integrates rate() instead).
    pub fn transfer_time(&mut self, now: f64, bytes: u64) -> f64 {
        self.on_active(now);
        let mut t = 0.0;
        let mut remaining = bytes as f64;
        // Integrate slow start RTT by RTT, then finish at link rate.
        loop {
            let rate = self.rate();
            if self.next_rate_change().is_none() {
                t += remaining / rate;
                self.advance(now + t, now + t);
                self.last_send = Some(now + t);
                return t;
            }
            let step = self.params.rtt;
            let sent = rate * step;
            if sent >= remaining {
                t += remaining / rate;
                self.last_send = Some(now + t);
                return t;
            }
            remaining -= sent;
            let from = now + t;
            t += step;
            self.advance(from, now + t);
        }
    }

    /// Current congestion window (bytes), exposed for tests/metrics.
    pub fn cwnd(&self) -> f64 {
        self.cwnd
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gbps(g: f64) -> f64 {
        g * 1e9 / 8.0
    }

    #[test]
    fn bdp_dominates_lan() {
        // LAN: tiny RTT -> BDP ~ init window -> immediately at link rate.
        let p = TcpParams::new(gbps(1.0), 0.0002);
        let c = TcpConn::new(p);
        assert!(c.rate() >= p.bandwidth * 0.5, "LAN connection starts near line rate");
    }

    #[test]
    fn wan_slow_start_ramps() {
        let p = TcpParams::new(gbps(40.0), 0.089);
        let mut c = TcpConn::new(p);
        c.on_active(0.0);
        let r0 = c.rate();
        c.advance(0.0, 5.0 * p.rtt);
        assert!(c.rate() > 20.0 * r0, "five RTTs of doubling: {} -> {}", r0, c.rate());
        assert!(c.rate() <= p.bandwidth);
    }

    #[test]
    fn reaches_link_rate_eventually() {
        let p = TcpParams::new(gbps(40.0), 0.089);
        let mut c = TcpConn::new(p);
        c.on_active(0.0);
        c.advance(0.0, 100.0 * p.rtt);
        assert!(c.rate() >= p.bandwidth * 0.999);
        assert!(c.next_rate_change().is_none());
    }

    #[test]
    fn idle_reset_collapses_cwnd() {
        let p = TcpParams::new(gbps(40.0), 0.089);
        let mut c = TcpConn::new(p);
        c.on_active(0.0);
        c.advance(0.0, 10.0); // fully ramped
        let fast = c.rate();
        c.on_idle_start(10.0);
        c.on_active(20.0); // idle 10 s >> RTO
        assert!(c.rate() < fast / 100.0, "cwnd should collapse after idle");
        assert_eq!(c.restarts, 1);
    }

    #[test]
    fn short_idle_does_not_reset() {
        let p = TcpParams::new(gbps(40.0), 0.089);
        let mut c = TcpConn::new(p);
        c.on_active(0.0);
        c.advance(0.0, 10.0);
        let fast = c.rate();
        c.on_idle_start(10.0);
        c.on_active(10.0 + p.rto * 0.5);
        assert_eq!(c.rate(), fast);
        assert_eq!(c.restarts, 0);
    }

    #[test]
    fn transfer_time_monotone_in_bytes() {
        let p = TcpParams::new(gbps(1.0), 0.03);
        let t1 = TcpConn::new(p).transfer_time(0.0, 10 << 20);
        let t2 = TcpConn::new(p).transfer_time(0.0, 100 << 20);
        assert!(t2 > t1);
    }

    #[test]
    fn transfer_time_close_to_ideal_for_large_files() {
        let p = TcpParams::new(gbps(1.0), 0.0002);
        let bytes = 1u64 << 30;
        let t = TcpConn::new(p).transfer_time(0.0, bytes);
        let ideal = bytes as f64 / p.bandwidth;
        assert!(t >= ideal);
        assert!(t < ideal * 1.1, "LAN large transfer within 10% of line rate: {t} vs {ideal}");
    }

    #[test]
    fn small_file_wan_dominated_by_rampup() {
        let p = TcpParams::new(gbps(40.0), 0.089);
        let bytes = 10u64 << 20; // 10 MB
        let t = TcpConn::new(p).transfer_time(0.0, bytes);
        let ideal = bytes as f64 / p.bandwidth;
        assert!(t > 5.0 * ideal, "WAN small file pays slow start: {t} vs {ideal}");
    }
}
