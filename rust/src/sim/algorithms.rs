//! Simulated drivers for the five integrity-verification algorithms
//! (paper §III/§IV, Fig 2): Sequential, file-level pipelining, block-level
//! pipelining, FIVER (file- and chunk-level verification) and FIVER-Hybrid.
//!
//! Modeling decisions (calibrated against the paper's own reported numbers,
//! see DESIGN.md §2 and EXPERIMENTS.md):
//!
//! * **Pipelined stations are lockstep**: "transfer of a file is overlapped
//!   with checksum calculation of another file" — at any instant one unit
//!   transfers while the *previous* unit checksums; a round ends when both
//!   finish (this is what makes Sorted-5M250M adversarial: a 250 MB
//!   checksum pairs with a 5 MB transfer and vice versa).
//! * **Filesystem-fed checksums pay a read-path factor**
//!   ([`crate::config::AlgoParams::fs_read_factor`], default 1.12): per the
//!   paper, pipelined checksum processes "execute system calls to open and
//!   read files ... which causes overhead because of context switching
//!   between user and kernel modes", while FIVER's queue handoff does not.
//! * **Transfer-station stalls cost a resume bubble** of 0.5 RTT (ACK-clock
//!   restart) and, past the RTO, a full slow-start restart
//!   ([`crate::net::TcpConn::on_active`]) — the WAN penalty the paper
//!   ascribes to per-block idle periods.
//! * **Control exchanges**: Sequential serializes one control RTT per file
//!   (verify-before-next-file is its definition); the pipelined algorithms
//!   and FIVER overlap digest exchange with subsequent data (FIVER's
//!   checksum thread owns the control channel; Algorithm 1 line 19) and pay
//!   one RTT at dataset end.

use std::collections::VecDeque;

use crate::config::{AlgoParams, Testbed};
use crate::coordinator::scheduler::{WorkItem, WorkStealQueue};
use crate::faults::FaultPlan;
use crate::metrics::{RunSummary, SessionStats};
use crate::sim::testbed::{Side, SimEnv};
use crate::sim::FlowId;
use crate::workload::{Dataset, FileSpec};

/// Algorithm selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Transfer file, then checksum it, then next file (Fig 2a).
    Sequential,
    /// Globus-style: checksum of file i overlaps transfer of file i+1.
    FileLevelPpl,
    /// Liu et al.: files split into blocks; checksum of block i overlaps
    /// transfer of block i+1.
    BlockLevelPpl,
    /// FIVER with file-level verification (Algorithms 1 & 2).
    Fiver,
    /// FIVER with chunk-level verification (§IV-A, Table III).
    FiverChunk,
    /// FIVER for files smaller than free memory, Sequential otherwise
    /// (§IV-B, Fig 9).
    FiverHybrid,
    /// FIVER with a streaming Merkle digest tree: O(log n) digest exchange
    /// localizes corruption to leaves; only those are re-sent (see
    /// [`crate::merkle`]).
    FiverMerkle,
}

impl Algorithm {
    /// Every simulated algorithm, in presentation order — the single
    /// source of truth for tests and experiment drivers.
    pub const ALL: [Algorithm; 7] = [
        Algorithm::Sequential,
        Algorithm::FileLevelPpl,
        Algorithm::BlockLevelPpl,
        Algorithm::Fiver,
        Algorithm::FiverChunk,
        Algorithm::FiverHybrid,
        Algorithm::FiverMerkle,
    ];

    /// Canonical display/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Sequential => "Sequential",
            Algorithm::FileLevelPpl => "FileLevelPpl",
            Algorithm::BlockLevelPpl => "BlockLevelPpl",
            Algorithm::Fiver => "FIVER",
            Algorithm::FiverChunk => "FIVER-Chunk",
            Algorithm::FiverHybrid => "FIVER-Hybrid",
            Algorithm::FiverMerkle => "FIVER-Merkle",
        }
    }

    /// Parse a CLI algorithm name.
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_ascii_lowercase().as_str() {
            "sequential" | "seq" => Some(Algorithm::Sequential),
            "filelevelppl" | "file" | "file-level" => Some(Algorithm::FileLevelPpl),
            "blocklevelppl" | "block" | "block-level" => Some(Algorithm::BlockLevelPpl),
            "fiver" => Some(Algorithm::Fiver),
            "fiver-chunk" | "fiverchunk" | "chunk" => Some(Algorithm::FiverChunk),
            "fiver-hybrid" | "fiverhybrid" | "hybrid" => Some(Algorithm::FiverHybrid),
            "fiver-merkle" | "fivermerkle" | "merkle" | "tree" => Some(Algorithm::FiverMerkle),
            _ => None,
        }
    }
}

/// A transfer/verify unit: a whole file or one block of it.
#[derive(Debug, Clone)]
struct Unit {
    file_idx: usize,
    offset: u64,
    len: u64,
    attempt: u32,
}

/// Baseline: dataset transfer with no integrity verification (Eq. 1's
/// `t_transfer`). Back-to-back transfers on a persistent connection,
/// pipelined control, one final RTT.
pub fn transfer_only(tb: Testbed, params: AlgoParams, ds: &Dataset) -> f64 {
    let mut env = SimEnv::new(tb, params);
    for f in &ds.files {
        let flow = env.start_transfer(f, 0, f.size);
        env.pump_until(flow);
    }
    let t = env.start_timer(tb.rtt);
    env.pump_until(t);
    env.now()
}

/// Baseline: checksum of the dataset at both hosts with no transfer (Eq.
/// 1's `t_chksum`): cold sequential reads from disk, one hash core per
/// host, hosts in parallel — total is the slower host.
pub fn checksum_only(tb: Testbed, params: AlgoParams, ds: &Dataset) -> f64 {
    let mut env = SimEnv::new(tb, params);
    let mut idx = [0usize; 2];
    let mut cur: [Option<crate::sim::FlowId>; 2] = [None, None];
    loop {
        for (s, side) in [Side::Src, Side::Dst].into_iter().enumerate() {
            if cur[s].is_none() && idx[s] < ds.files.len() {
                let f = &ds.files[idx[s]];
                // Baseline checksum is a dedicated cold read (md5sum-style):
                // no pipelining interference, so no fs_read_factor.
                cur[s] = Some(env.start_checksum(side, f, 0, f.size, false));
                idx[s] += 1;
            }
        }
        if cur.iter().all(|c| c.is_none()) {
            break;
        }
        env.pump_step();
        for c in cur.iter_mut() {
            if let Some(flow) = *c {
                if env.sim.is_done(flow) {
                    *c = None;
                }
            }
        }
    }
    env.now()
}

/// Delta-sync model (the real engine's `--delta`): per file, exchange the
/// per-leaf signature payload, then run a coupled scan flow that ships
/// only [`AlgoParams::delta_fraction`] of the bytes while the receiver
/// reconstructs and re-hashes locally (see
/// [`SimEnv::start_delta_flow`]). Signatures are journal-served (free to
/// produce); `cold_receiver` charges a full read+hash pass of the old
/// data at the destination instead — the no-journal path.
///
/// Faults are not modeled here: delta repairs ride the same Merkle
/// verification backstop as a full run, so the regime of interest is the
/// byte economics — when does scanning everything to ship a fraction
/// beat shipping everything? (See `experiments::delta`.)
pub fn run_delta(tb: Testbed, params: AlgoParams, ds: &Dataset, cold_receiver: bool) -> RunSummary {
    let mut env = SimEnv::new(tb, params);
    let dirty = params.delta_fraction.clamp(0.0, 1.0);
    let mut summary = RunSummary {
        algorithm: "FIVER-Delta".to_string(),
        dataset: ds.name.clone(),
        testbed: tb.name.to_string(),
        io_backend: params.io_backend.name().to_string(),
        hash_tier: params.hash_tier.name().to_string(),
        concurrency: 1,
        ..Default::default()
    };
    // Signature bytes on the wire follow the tier's leaf digest width.
    let dlen = params.leaf_digest_len() as u64;
    // One handshake round trip covers the whole session's DeltaReq/Sig
    // exchange (the real engine batches every file into one connection).
    let hs = env.start_timer(env.params.control_rtts * tb.rtt);
    env.pump_until(hs);
    summary.verify_rtts += 1;
    for f in &ds.files {
        let leaves = crate::merkle::leaf_count(f.size, params.leaf_size);
        if cold_receiver {
            // No receiver journal: the basis is hashed from the old data
            // on demand before the scan can start.
            let sig = env.start_checksum(Side::Dst, f, 0, f.size, false);
            env.pump_until(sig);
        }
        // Per-leaf (weak, strong) signature payload crosses the control
        // channel — the term that punishes small leaves.
        let sig_bytes = leaves * (crate::coordinator::delta::WEAK_LEN as u64 + dlen);
        let sig = env.start_ctrl_bytes(sig_bytes);
        env.pump_until(sig);
        let flow = env.start_delta_flow(f, dirty);
        env.pump_until(flow);
        // Root exchange of the reconstructed file, like FIVER's digest.
        summary.verify_rtts += 1;
        let dirty_leaves = ((leaves as f64) * dirty).ceil() as u64;
        let dirty_bytes = (f.size as f64 * dirty).round() as u64;
        summary.leaves_dirty += dirty_leaves;
        summary.leaves_clean += leaves - dirty_leaves;
        summary.bytes_skipped_delta += f.size - dirty_bytes;
    }
    let t = env.start_timer(env.params.control_rtts * env.tb.rtt);
    env.pump_until(t);
    summary.total_time = env.now();
    summary.tcp_restarts = env.restarts();
    attach_obs(&env, &mut summary);
    summary.src_trace = std::mem::take(&mut env.src_trace);
    summary.dst_trace = std::mem::take(&mut env.dst_trace);
    summary.t_transfer_only = transfer_only(tb, params, ds);
    summary.t_checksum_only = checksum_only(tb, params, ds);
    summary
}

/// Simulate `alg` over `ds` with `faults`, producing the run summary
/// (including Eq. 1 baselines computed in separate clean simulations).
pub fn run(
    tb: Testbed,
    params: AlgoParams,
    ds: &Dataset,
    faults: &FaultPlan,
    alg: Algorithm,
) -> RunSummary {
    let mut env = SimEnv::new(tb, params);
    let mut summary = RunSummary {
        algorithm: alg.name().to_string(),
        dataset: ds.name.clone(),
        testbed: tb.name.to_string(),
        io_backend: params.io_backend.name().to_string(),
        hash_tier: params.hash_tier.name().to_string(),
        concurrency: 1,
        ..Default::default()
    };
    match alg {
        Algorithm::Sequential => run_sequential(&mut env, ds, faults, &mut summary, None),
        Algorithm::FileLevelPpl => run_pipelined(&mut env, ds, faults, &mut summary, None),
        Algorithm::BlockLevelPpl => {
            run_pipelined(&mut env, ds, faults, &mut summary, Some(params.block_size))
        }
        Algorithm::Fiver => run_fiver(&mut env, ds, faults, &mut summary, false),
        Algorithm::FiverChunk => run_fiver(&mut env, ds, faults, &mut summary, true),
        Algorithm::FiverHybrid => run_hybrid(&mut env, ds, faults, &mut summary),
        Algorithm::FiverMerkle => run_fiver_merkle(&mut env, ds, faults, &mut summary),
    }
    summary.total_time = env.now();
    summary.tcp_restarts = env.restarts();
    attach_obs(&env, &mut summary);
    summary.src_trace = std::mem::take(&mut env.src_trace);
    summary.dst_trace = std::mem::take(&mut env.dst_trace);
    summary.t_transfer_only = transfer_only(tb, params, ds);
    summary.t_checksum_only = checksum_only(tb, params, ds);
    summary
}

/// Fill a summary's observability fields from the sim's utilization
/// integrals — the same attribution math as the real engine's span
/// recorder, so sim and real runs label bottlenecks identically. Span
/// counts and percentiles stay zero: the fluid model has busy time per
/// stage, not per-operation latencies.
fn attach_obs(env: &SimEnv, summary: &mut RunSummary) {
    let busy = env.stage_busy();
    summary.stage_stats = busy
        .iter()
        .map(|&(name, secs)| crate::obs::StageStats {
            stage: name.to_string(),
            busy_secs: secs,
            ..Default::default()
        })
        .collect();
    let (label, confidence) = crate::obs::attribute(&busy);
    summary.bottleneck = label;
    summary.bottleneck_confidence = confidence;
}

/// Both-side checksum of a unit through the filesystem (the non-FIVER
/// read path): hash weight includes the read-path factor.
fn start_unit_checksums(env: &mut SimEnv, f: &FileSpec, u: &Unit) -> [crate::sim::FlowId; 2] {
    let factor = env.params.fs_read_factor;
    [
        start_fs_checksum(env, Side::Src, f, u.offset, u.len, factor),
        start_fs_checksum(env, Side::Dst, f, u.offset, u.len, factor),
    ]
}

/// start_checksum with the filesystem read-path factor applied by
/// stretching the flow length (equivalent to slowing the hash stage).
fn start_fs_checksum(
    env: &mut SimEnv,
    side: Side,
    f: &FileSpec,
    offset: u64,
    len: u64,
    factor: f64,
) -> crate::sim::FlowId {
    let flow = env.start_checksum(side, f, offset, len, false);
    // Stretch: remaining work scaled by factor (the cache/trace accounting
    // already happened for `len` bytes).
    let extra = (len as f64) * (factor - 1.0);
    if extra > 0.0 {
        env.sim.stretch_flow(flow, extra);
    }
    flow
}

fn run_sequential(
    env: &mut SimEnv,
    ds: &Dataset,
    faults: &FaultPlan,
    summary: &mut RunSummary,
    // For FIVER-Hybrid: restrict to these file indices (None = all).
    only: Option<&[usize]>,
) {
    let indices: Vec<usize> = match only {
        Some(list) => list.to_vec(),
        None => (0..ds.files.len()).collect(),
    };
    let mut attempts = vec![0u32; ds.files.len()];
    for &i in &indices {
        loop {
            let f = &ds.files[i];
            let tr = env.start_transfer(f, 0, f.size);
            env.pump_until(tr);
            let u = Unit { file_idx: i, offset: 0, len: f.size, attempt: attempts[i] };
            let cks = start_unit_checksums(env, f, &u);
            env.pump_until_all(&cks);
            // Serial verification: exchange digests before the next file.
            let ctrl = env.start_timer(env.params.control_rtts * env.tb.rtt);
            env.pump_until(ctrl);
            summary.verify_rtts += 1;
            if faults.for_attempt(i, attempts[i]).is_empty() {
                break;
            }
            summary.failures_detected += 1;
            summary.bytes_resent += f.size;
            summary.bytes_reread += f.size;
            summary.repair_rounds += 1;
            attempts[i] += 1;
        }
    }
}

/// Lockstep two-station pipeline shared by file-level (unit = file) and
/// block-level (unit = block) pipelining: round k transfers unit k while
/// unit k-1 checksums; the round ends when both finish.
fn run_pipelined(
    env: &mut SimEnv,
    ds: &Dataset,
    faults: &FaultPlan,
    summary: &mut RunSummary,
    block_size: Option<u64>,
) {
    let mut queue: std::collections::VecDeque<Unit> = ds
        .files
        .iter()
        .enumerate()
        .flat_map(|(i, f)| split_units(i, f.size, block_size))
        .collect();
    let mut in_checksum: Option<Unit> = None;
    let mut last_transfer_end = env.now();
    loop {
        let to_transfer = queue.pop_front();
        if to_transfer.is_none() && in_checksum.is_none() {
            break;
        }
        let mut flows = Vec::new();
        let mut transferred: Option<Unit> = None;
        if let Some(u) = to_transfer {
            // Resume bubble: the transfer station sat idle since its last
            // unit ended (checksum station was the round's long pole).
            // Restarting costs ACK-clock rebuild time proportional to how
            // much of the in-flight window drained during the stall,
            // saturating at ~half an RTT once fully drained. This is the
            // §III trade-off: tiny blocks stall often (many bubbles),
            // large blocks pipeline poorly (misalignment) — see
            // `experiments::ablations::ablation_block_size`.
            let stall = env.now() - last_transfer_end;
            if stall > 1e-9 {
                let bubble = env.start_timer(0.5 * stall.min(env.tb.rtt));
                env.pump_until(bubble);
            }
            let f = &ds.files[u.file_idx];
            let flow = env.start_transfer(f, u.offset, u.len);
            flows.push((flow, true, Some(u.clone())));
            transferred = Some(u);
        }
        if let Some(u) = in_checksum.take() {
            let f = &ds.files[u.file_idx];
            let cks = start_unit_checksums(env, f, &u);
            for c in cks {
                flows.push((c, false, Some(u.clone())));
            }
            // Verification result handled after the round completes.
            in_checksum = Some(u);
        }
        // Round barrier: wait for transfer + checksum to finish, tracking
        // when the transfer station freed up (for stall detection).
        for (flow, is_transfer, _) in &flows {
            env.pump_until(*flow);
            if *is_transfer {
                last_transfer_end = env.now();
            }
        }
        // Verify the checksummed unit (digest exchange overlaps the next
        // round's data; only failures cost a re-queue).
        if let Some(u) = in_checksum.take() {
            summary.verify_rtts += 1;
            let unit_faults = faults
                .for_attempt(u.file_idx, u.attempt)
                .into_iter()
                .filter(|ft| ft.offset >= u.offset && ft.offset < u.offset + u.len)
                .count();
            if unit_faults > 0 {
                summary.failures_detected += 1;
                summary.bytes_resent += u.len;
                summary.bytes_reread += u.len;
                summary.repair_rounds += 1;
                queue.push_back(Unit { attempt: u.attempt + 1, ..u });
            }
        }
        in_checksum = transferred;
    }
    let t = env.start_timer(env.params.control_rtts * env.tb.rtt);
    env.pump_until(t);
}

fn split_units(file_idx: usize, size: u64, block_size: Option<u64>) -> Vec<Unit> {
    match block_size {
        None => vec![Unit { file_idx, offset: 0, len: size, attempt: 0 }],
        Some(bs) => {
            let mut units = Vec::new();
            let mut off = 0;
            while off < size {
                let len = bs.min(size - off);
                units.push(Unit { file_idx, offset: off, len, attempt: 0 });
                off += len;
            }
            if units.is_empty() {
                units.push(Unit { file_idx, offset: 0, len: 0, attempt: 0 });
            }
            units
        }
    }
}

fn run_fiver(
    env: &mut SimEnv,
    ds: &Dataset,
    faults: &FaultPlan,
    summary: &mut RunSummary,
    chunk_level: bool,
) {
    let all: Vec<usize> = (0..ds.files.len()).collect();
    run_fiver_files(env, ds, faults, summary, &all, chunk_level);
    let t = env.start_timer(env.params.control_rtts * env.tb.rtt);
    env.pump_until(t);
}

fn run_fiver_files(
    env: &mut SimEnv,
    ds: &Dataset,
    faults: &FaultPlan,
    summary: &mut RunSummary,
    indices: &[usize],
    chunk_level: bool,
) {
    for &i in indices {
        let f = &ds.files[i];
        let flow = env.start_fiver_flow(f, 0, f.size);
        env.pump_until(flow);
        // Digest exchange rides the control channel concurrently with the
        // next file's data (Algorithm 1: checksum thread owns the socket
        // exchange) — no serial cost here. Verification failures trigger
        // recovery.
        summary.verify_rtts += if chunk_level {
            (f.size.div_ceil(env.params.chunk_size)).max(1)
        } else {
            1
        };
        let file_faults = faults.for_attempt(i, 0);
        if file_faults.is_empty() {
            continue;
        }
        if chunk_level {
            // §IV-A: only the chunks containing corruption are re-sent
            // (sender "creates a new file with same metadata as the
            // original file except offset and length").
            let cs = env.params.chunk_size;
            let mut bad_chunks: Vec<u64> =
                file_faults.iter().map(|ft| ft.offset / cs).collect();
            bad_chunks.sort_unstable();
            bad_chunks.dedup();
            summary.failures_detected += bad_chunks.len() as u64;
            for c in bad_chunks {
                let off = c * cs;
                let len = cs.min(f.size - off);
                summary.bytes_resent += len;
                summary.bytes_reread += len;
                summary.repair_rounds += 1;
                summary.verify_rtts += 1; // fresh chunk digest exchange
                let refl = env.start_fiver_flow(f, off, len);
                env.pump_until(refl);
            }
        } else {
            // File-level verification: the whole file is transferred again
            // (and re-verified; attempt 1 is clean unless planned).
            summary.failures_detected += 1;
            let mut attempt = 1u32;
            loop {
                summary.bytes_resent += f.size;
                summary.bytes_reread += f.size;
                summary.repair_rounds += 1;
                summary.verify_rtts += 1; // fresh file digest exchange
                let refl = env.start_fiver_flow(f, 0, f.size);
                env.pump_until(refl);
                if faults.for_attempt(i, attempt).is_empty() {
                    break;
                }
                summary.failures_detected += 1;
                attempt += 1;
            }
        }
    }
}

/// FIVER-Merkle: the stream folds into a digest tree as it drains from
/// the shared queue (same transfer profile as FIVER), and a failed root
/// exchange is binary-searched down the tree — `descent_rounds` control
/// round trips — so only the corrupted leaves are re-read and re-sent.
/// Faults planned at occurrence `n > 0` strike the `n`-th repair round's
/// re-sent ranges, exercising repair-loop convergence.
fn run_fiver_merkle(
    env: &mut SimEnv,
    ds: &Dataset,
    faults: &FaultPlan,
    summary: &mut RunSummary,
) {
    let leaf = env.params.leaf_size;
    for i in 0..ds.files.len() {
        let f = &ds.files[i];
        let flow = env.start_fiver_flow(f, 0, f.size);
        env.pump_until(flow);
        // Root exchange overlaps the next file's data, like FIVER's digest.
        summary.verify_rtts += 1;
        let leaves = crate::merkle::leaf_count(f.size, leaf);
        let mut attempt = 0u32;
        // Repaired ranges of the previous round: occurrence-(n+1) faults
        // only strike bytes actually re-sent in round n+1.
        let mut resent: Option<Vec<(u64, u64)>> = None; // None = full stream
        loop {
            let round_faults: Vec<crate::faults::Fault> = faults
                .for_attempt(i, attempt)
                .into_iter()
                .filter(|ft| match &resent {
                    None => true,
                    Some(ranges) => {
                        ranges.iter().any(|&(o, l)| ft.offset >= o && ft.offset < o + l)
                    }
                })
                .collect();
            if round_faults.is_empty() {
                break;
            }
            summary.failures_detected += 1; // one mismatched root exchange
            let mut bad_leaves: Vec<u64> = round_faults.iter().map(|ft| ft.offset / leaf).collect();
            bad_leaves.sort_unstable();
            bad_leaves.dedup();
            // Descent: one batched node-range query round per tree level,
            // then a fresh root after the repairs land.
            let rounds = crate::merkle::descent_rounds(leaves) as u64 + 1;
            let t = env.start_timer(rounds as f64 * env.tb.rtt);
            env.pump_until(t);
            summary.verify_rtts += rounds;
            let mut ranges = Vec::with_capacity(bad_leaves.len());
            for l in bad_leaves {
                let off = l * leaf;
                let len = leaf.min(f.size - off);
                summary.bytes_resent += len;
                summary.bytes_reread += len;
                let refl = env.start_fiver_flow(f, off, len);
                env.pump_until(refl);
                ranges.push((off, len));
            }
            summary.repair_rounds += 1;
            resent = Some(ranges);
            attempt += 1;
        }
    }
    let t = env.start_timer(env.params.control_rtts * env.tb.rtt);
    env.pump_until(t);
}

/// One simulated engine session: the files it still owes from its current
/// work item, its in-flight flow, and its accounting.
struct Sess {
    fifo: VecDeque<usize>,
    cur: Option<Cur>,
    stats: SessionStats,
}

/// A session's in-flight activity.
struct Cur {
    file: usize,
    /// Transfer attempt last verified / currently being repaired.
    attempt: u32,
    phase: Phase,
    flow: FlowId,
    t0: f64,
}

enum Phase {
    /// The initial coupled stream of the file.
    Stream,
    /// FIVER-Merkle node-range descent (a timer); repairs queued behind.
    Descent { pending: VecDeque<(u64, u64)>, all: Vec<(u64, u64)> },
    /// A repair re-send flow; more ranges may be queued.
    Repair { pending: VecDeque<(u64, u64)>, all: Vec<(u64, u64)> },
}

/// The parallel engine in the simulator: N concurrent sessions drive
/// FIVER-family coupled flows over the shared testbed resources, fed by
/// the same batching + work-stealing schedule as the real engine
/// ([`crate::workload::plan_batches`] dealt round-robin, own-front pop,
/// longest-victim back steal) and a shared hash pool of `hash_workers`
/// cores per host. This is how Table II/III-style runs replay with
/// concurrency sweeps.
///
/// Only the queue-family policies are modeled (Sequential and the
/// pipelined baselines are definitionally single-station).
pub fn run_concurrent(
    tb: Testbed,
    params: AlgoParams,
    ds: &Dataset,
    faults: &FaultPlan,
    alg: Algorithm,
    concurrency: usize,
    hash_workers: usize,
) -> RunSummary {
    assert!(
        matches!(alg, Algorithm::Fiver | Algorithm::FiverChunk | Algorithm::FiverMerkle),
        "run_concurrent models the queue-family (FIVER) algorithms"
    );
    let n = concurrency.max(1);
    let mut env = SimEnv::new_parallel(tb, params, n, hash_workers.max(1));
    let mut summary = RunSummary {
        algorithm: alg.name().to_string(),
        dataset: ds.name.clone(),
        testbed: tb.name.to_string(),
        io_backend: params.io_backend.name().to_string(),
        hash_tier: params.hash_tier.name().to_string(),
        concurrency: n,
        ..Default::default()
    };
    // The real scheduler itself plans and deals the work: batch small
    // files, round-robin onto per-session deques, steal when idle —
    // `WorkStealQueue` is shared with the real engine so the policies
    // cannot diverge.
    let sizes: Vec<u64> = ds.files.iter().map(|f| f.size).collect();
    let items: Vec<WorkItem> =
        crate::workload::plan_batches(&sizes, params.batch_threshold, params.batch_bytes)
            .into_iter()
            .map(|files| WorkItem { files })
            .collect();
    let queue = WorkStealQueue::new(items, n);
    let mut sessions: Vec<Sess> = (0..n)
        .map(|s| Sess {
            fifo: VecDeque::new(),
            cur: None,
            stats: SessionStats { session: s, ..Default::default() },
        })
        .collect();
    loop {
        // Dispatch idle sessions: own item front, else steal from the
        // back of the longest other deque (the WorkStealQueue policy).
        for s in 0..n {
            if sessions[s].cur.is_some() {
                continue;
            }
            if sessions[s].fifo.is_empty() {
                if let Some(item) = queue.next(s) {
                    sessions[s].fifo = item.files.into();
                }
            }
            if let Some(file) = sessions[s].fifo.pop_front() {
                let t0 = env.now();
                let flow = env.start_fiver_flow_on(s, &ds.files[file], 0, ds.files[file].size);
                sessions[s].cur = Some(Cur { file, attempt: 0, phase: Phase::Stream, flow, t0 });
            }
        }
        if sessions.iter().all(|s| s.cur.is_none()) {
            break; // nothing in flight and the deques are drained
        }
        // Reap already-complete flows (zero-byte files finish at birth)
        // *before* advancing time — stepping with only done flows active
        // would integrate an arbitrary empty interval.
        let mut reaped = false;
        for s in 0..n {
            let done = sessions[s].cur.as_ref().map(|c| env.sim.is_done(c.flow)).unwrap_or(false);
            if done {
                on_flow_done(&mut env, &mut summary, &mut sessions[s], s, ds, faults, alg);
                reaped = true;
            }
        }
        if reaped {
            continue; // re-dispatch the now-idle sessions first
        }
        env.pump_step();
    }
    let t = env.start_timer(params.control_rtts * tb.rtt);
    env.pump_until(t);
    summary.total_time = env.now();
    summary.tcp_restarts = env.restarts();
    attach_obs(&env, &mut summary);
    summary.src_trace = std::mem::take(&mut env.src_trace);
    summary.dst_trace = std::mem::take(&mut env.dst_trace);
    summary.per_session = sessions.into_iter().map(|s| s.stats).collect();
    summary.t_transfer_only = transfer_only(tb, params, ds);
    summary.t_checksum_only = checksum_only(tb, params, ds);
    summary
}

/// A session's flow completed: account it and advance its state machine.
fn on_flow_done(
    env: &mut SimEnv,
    summary: &mut RunSummary,
    sess: &mut Sess,
    s: usize,
    ds: &Dataset,
    faults: &FaultPlan,
    alg: Algorithm,
) {
    let cur = sess.cur.take().expect("flow completion without a current file");
    let now = env.now();
    sess.stats.busy_secs += now - cur.t0;
    match cur.phase {
        Phase::Stream => {
            let f = &ds.files[cur.file];
            sess.stats.files += 1;
            sess.stats.bytes += f.size;
            // Root/digest exchange overlaps the next file's data, like the
            // serial drivers.
            summary.verify_rtts += if alg == Algorithm::FiverChunk {
                (f.size.div_ceil(env.params.chunk_size)).max(1)
            } else {
                1
            };
            verify_round(env, summary, sess, s, ds, faults, alg, cur.file, 0, None);
        }
        Phase::Descent { pending, all } => {
            start_next_repair(env, sess, s, ds, cur.file, cur.attempt, pending, all, now);
        }
        Phase::Repair { pending, all } => {
            if pending.is_empty() {
                match alg {
                    // §IV-A chunk recovery is a single round by policy.
                    Algorithm::FiverChunk => {}
                    Algorithm::Fiver => verify_round(
                        env,
                        summary,
                        sess,
                        s,
                        ds,
                        faults,
                        alg,
                        cur.file,
                        cur.attempt + 1,
                        None,
                    ),
                    Algorithm::FiverMerkle => verify_round(
                        env,
                        summary,
                        sess,
                        s,
                        ds,
                        faults,
                        alg,
                        cur.file,
                        cur.attempt + 1,
                        Some(all),
                    ),
                    _ => unreachable!("run_concurrent only models queue-family algorithms"),
                }
            } else {
                start_next_repair(env, sess, s, ds, cur.file, cur.attempt, pending, all, now);
            }
        }
    }
}

/// Launch the next queued repair range as a coupled flow.
#[allow(clippy::too_many_arguments)]
fn start_next_repair(
    env: &mut SimEnv,
    sess: &mut Sess,
    s: usize,
    ds: &Dataset,
    file: usize,
    attempt: u32,
    mut pending: VecDeque<(u64, u64)>,
    all: Vec<(u64, u64)>,
    now: f64,
) {
    let (off, len) = pending.pop_front().expect("repair phase with no ranges");
    let flow = env.start_fiver_flow_on(s, &ds.files[file], off, len);
    sess.cur = Some(Cur { file, attempt, phase: Phase::Repair { pending, all }, flow, t0: now });
}

/// Check a file's verification outcome for `attempt` and, on a mismatch,
/// start the algorithm's repair machinery. Faults planned at occurrence
/// `n > 0` only strike bytes the `n`-th round actually re-sent (`resent`),
/// mirroring the serial drivers.
#[allow(clippy::too_many_arguments)]
fn verify_round(
    env: &mut SimEnv,
    summary: &mut RunSummary,
    sess: &mut Sess,
    s: usize,
    ds: &Dataset,
    faults: &FaultPlan,
    alg: Algorithm,
    file: usize,
    attempt: u32,
    resent: Option<Vec<(u64, u64)>>,
) {
    let f = &ds.files[file];
    let round_faults: Vec<crate::faults::Fault> = faults
        .for_attempt(file, attempt)
        .into_iter()
        .filter(|ft| match &resent {
            None => true,
            Some(ranges) => ranges.iter().any(|&(o, l)| ft.offset >= o && ft.offset < o + l),
        })
        .collect();
    if round_faults.is_empty() {
        return; // verified; the session is idle again
    }
    let now = env.now();
    match alg {
        Algorithm::Fiver => {
            // File-level verification: the whole file transfers again.
            summary.failures_detected += 1;
            summary.bytes_resent += f.size;
            summary.bytes_reread += f.size;
            summary.repair_rounds += 1;
            summary.verify_rtts += 1; // fresh file digest exchange
            let flow = env.start_fiver_flow_on(s, f, 0, f.size);
            sess.cur = Some(Cur {
                file,
                attempt,
                phase: Phase::Repair { pending: VecDeque::new(), all: vec![(0, f.size)] },
                flow,
                t0: now,
            });
        }
        Algorithm::FiverChunk => {
            // §IV-A: only the chunks containing corruption are re-sent.
            let cs = env.params.chunk_size;
            let mut bad: Vec<u64> = round_faults.iter().map(|ft| ft.offset / cs).collect();
            bad.sort_unstable();
            bad.dedup();
            summary.failures_detected += bad.len() as u64;
            let mut ranges: VecDeque<(u64, u64)> = VecDeque::new();
            for c in bad {
                let off = c * cs;
                let len = cs.min(f.size - off);
                summary.bytes_resent += len;
                summary.bytes_reread += len;
                summary.repair_rounds += 1;
                summary.verify_rtts += 1; // fresh chunk digest exchange
                ranges.push_back((off, len));
            }
            let all: Vec<(u64, u64)> = ranges.iter().copied().collect();
            start_next_repair(env, sess, s, ds, file, attempt, ranges, all, now);
        }
        Algorithm::FiverMerkle => {
            let leaf = env.params.leaf_size;
            summary.failures_detected += 1; // one mismatched root exchange
            let leaves = crate::merkle::leaf_count(f.size, leaf);
            let rounds = crate::merkle::descent_rounds(leaves) as u64 + 1;
            summary.verify_rtts += rounds;
            let mut bad: Vec<u64> = round_faults.iter().map(|ft| ft.offset / leaf).collect();
            bad.sort_unstable();
            bad.dedup();
            let mut ranges: VecDeque<(u64, u64)> = VecDeque::new();
            for l in bad {
                let off = l * leaf;
                let len = leaf.min(f.size - off);
                summary.bytes_resent += len;
                summary.bytes_reread += len;
                ranges.push_back((off, len));
            }
            summary.repair_rounds += 1;
            let all: Vec<(u64, u64)> = ranges.iter().copied().collect();
            // Descent first: one batched node-range query round per tree
            // level (a pure control-channel delay), then the repairs.
            let timer = env.start_timer(rounds as f64 * env.tb.rtt);
            sess.cur = Some(Cur {
                file,
                attempt,
                phase: Phase::Descent { pending: ranges, all },
                flow: timer,
                t0: now,
            });
        }
        _ => unreachable!("run_concurrent only models queue-family algorithms"),
    }
}

/// FIVER-Hybrid (§IV-B): FIVER for files smaller than free memory (their
/// checksum re-read would be served from cache anyway), Sequential for
/// larger files (so the checksum read truly exercises the disk and
/// catches write-path corruption).
fn run_hybrid(env: &mut SimEnv, ds: &Dataset, faults: &FaultPlan, summary: &mut RunSummary) {
    let threshold = env.tb.dst.free_mem;
    for i in 0..ds.files.len() {
        let f = &ds.files[i];
        if f.size < threshold {
            run_fiver_files(env, ds, faults, summary, &[i], false);
        } else {
            run_sequential(env, ds, faults, summary, Some(&[i]));
        }
    }
    let t = env.start_timer(env.params.control_rtts * env.tb.rtt);
    env.pump_until(t);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgoParams, GB, MB};

    fn quick_run(tb: Testbed, ds: &Dataset, alg: Algorithm) -> RunSummary {
        run(tb, AlgoParams::default(), ds, &FaultPlan::none(), alg)
    }

    #[test]
    fn fiver_beats_sequential() {
        let ds = Dataset::uniform("1G", GB, 4);
        let tb = Testbed::hpclab_40g();
        let fiver = quick_run(tb, &ds, Algorithm::Fiver);
        let seq = quick_run(tb, &ds, Algorithm::Sequential);
        assert!(
            fiver.total_time < seq.total_time,
            "FIVER {} >= Sequential {}",
            fiver.total_time,
            seq.total_time
        );
        let fo = fiver.overhead().unwrap();
        let so = seq.overhead().unwrap();
        assert!(fo < 0.10, "FIVER overhead {fo}");
        assert!(so > 0.25, "Sequential overhead {so}");
    }

    #[test]
    fn fiver_under_10pct_everywhere() {
        for tb in Testbed::all() {
            let ds = Dataset::uniform("1G", GB, 4);
            let s = quick_run(tb, &ds, Algorithm::Fiver);
            let o = s.overhead().unwrap();
            assert!(o < 0.10, "{}: FIVER overhead {o}", tb.name);
        }
    }

    #[test]
    fn sorted_dataset_punishes_pipelining() {
        let ds = Dataset::sorted_5m250m(20);
        let tb = Testbed::hpclab_40g();
        let block = quick_run(tb, &ds, Algorithm::BlockLevelPpl);
        let fiver = quick_run(tb, &ds, Algorithm::Fiver);
        let bo = block.overhead().unwrap();
        let fo = fiver.overhead().unwrap();
        assert!(bo > fo + 0.2, "block {bo} should far exceed fiver {fo}");
    }

    #[test]
    fn block_better_than_file_on_large_files() {
        let ds = Dataset::uniform("10G", 10 * GB, 2);
        let tb = Testbed::esnet_lan();
        let file = quick_run(tb, &ds, Algorithm::FileLevelPpl);
        let block = quick_run(tb, &ds, Algorithm::BlockLevelPpl);
        assert!(
            block.total_time < file.total_time,
            "block {} should beat file-level {}",
            block.total_time,
            file.total_time
        );
    }

    #[test]
    fn fault_recovery_chunk_cheaper_than_file() {
        let ds = Dataset::uniform("4G", 4 * GB, 3);
        let tb = Testbed::hpclab_40g();
        let faults = FaultPlan::random(&ds, 6, 7);
        let p = AlgoParams::default();
        let file = run(tb, p, &ds, &faults, Algorithm::Fiver);
        let chunk = run(tb, p, &ds, &faults, Algorithm::FiverChunk);
        assert!(file.failures_detected > 0 && chunk.failures_detected > 0);
        assert!(
            chunk.bytes_resent < file.bytes_resent,
            "chunk resends {} should be < file resends {}",
            chunk.bytes_resent,
            file.bytes_resent
        );
        assert!(chunk.total_time < file.total_time);
    }

    #[test]
    fn hybrid_faster_than_sequential_same_misses() {
        // Mixed dataset with some larger-than-memory files.
        let ds = Dataset::mixed_shuffled("mix", &[(20, 100 * MB), (2, 16 * GB)], 3);
        let tb = Testbed::hpclab_1g(); // free_mem = 14 GB < 16 GB files
        let hybrid = quick_run(tb, &ds, Algorithm::FiverHybrid);
        let seq = quick_run(tb, &ds, Algorithm::Sequential);
        assert!(hybrid.total_time < seq.total_time);
        // Same disk-exercising behaviour on the large files: both see misses.
        assert!(hybrid.dst_trace.total_misses() > 0);
        let ratio = hybrid.dst_trace.total_misses() as f64 / seq.dst_trace.total_misses() as f64;
        assert!((0.5..=2.0).contains(&ratio), "miss counts comparable: {ratio}");
    }

    #[test]
    fn all_algorithms_catch_all_faults() {
        let ds = Dataset::uniform("512M", 512 * MB, 4);
        let tb = Testbed::hpclab_40g();
        let faults = FaultPlan::random(&ds, 5, 11);
        for alg in Algorithm::ALL {
            let s = run(tb, AlgoParams::default(), &ds, &faults, alg);
            assert!(
                s.failures_detected > 0,
                "{}: no failures detected",
                alg.name()
            );
            assert!(s.bytes_resent > 0, "{}: nothing resent", alg.name());
        }
    }

    #[test]
    fn parse_roundtrip() {
        for alg in Algorithm::ALL {
            assert_eq!(Algorithm::parse(alg.name()), Some(alg), "{}", alg.name());
        }
    }

    #[test]
    fn merkle_repair_cheaper_than_chunk() {
        let ds = Dataset::uniform("4G", 4 * GB, 3);
        let tb = Testbed::hpclab_40g();
        let faults = FaultPlan::random(&ds, 6, 7);
        let p = AlgoParams::default();
        let chunk = run(tb, p, &ds, &faults, Algorithm::FiverChunk);
        let merkle = run(tb, p, &ds, &faults, Algorithm::FiverMerkle);
        assert!(merkle.failures_detected > 0);
        // Repair bytes: O(leaf) per fault vs O(chunk) per fault.
        assert!(merkle.bytes_resent <= 6 * p.leaf_size, "{}", merkle.bytes_resent);
        assert!(
            merkle.bytes_resent < chunk.bytes_resent / 100,
            "merkle {} vs chunk {}",
            merkle.bytes_resent,
            chunk.bytes_resent
        );
        // Descent round trips are the price of leaf resolution; they must
        // not eat the repair-byte win (small slack for the tiny-flow ramp).
        assert!(
            merkle.total_time <= chunk.total_time * 1.05,
            "merkle {} vs chunk {}",
            merkle.total_time,
            chunk.total_time
        );
        assert!(merkle.repair_rounds > 0 && merkle.verify_rtts > 0);
    }

    #[test]
    fn merkle_converges_when_repairs_are_corrupted_too() {
        use crate::faults::Fault;
        let ds = Dataset::uniform("1G", GB, 1);
        let tb = Testbed::hpclab_40g();
        // Corrupt the stream, then corrupt the first repair of that range.
        let faults = FaultPlan {
            faults: vec![
                Fault { file_idx: 0, offset: 12_345, bit: 0, occurrence: 0 },
                Fault { file_idx: 0, offset: 12_345, bit: 1, occurrence: 1 },
            ],
            crash: None,
        };
        let p = AlgoParams::default();
        let s = run(tb, p, &ds, &faults, Algorithm::FiverMerkle);
        assert_eq!(s.repair_rounds, 2, "round 1 corrupted -> round 2 repairs it");
        assert_eq!(s.failures_detected, 2);
        assert!(s.bytes_resent <= 2 * p.leaf_size);
    }

    /// Acceptance: on the 1000×10M dataset, `--concurrency 8` (with a
    /// matching hash pool) beats `--concurrency 1` wall-clock, and
    /// FIVER's verification overhead stays under the paper's 10% headline.
    #[test]
    fn concurrency_8_beats_1_on_1000x10m() {
        let ds = Dataset::uniform("10M", 10 * MB, 1000);
        let tb = Testbed::hpclab_40g();
        let p = AlgoParams::default();
        let c1 = run_concurrent(tb, p, &ds, &FaultPlan::none(), Algorithm::Fiver, 1, 1);
        let c8 = run_concurrent(tb, p, &ds, &FaultPlan::none(), Algorithm::Fiver, 8, 8);
        assert!(
            c8.total_time < c1.total_time * 0.8,
            "concurrency 8 ({}) should beat concurrency 1 ({})",
            c8.total_time,
            c1.total_time
        );
        assert!(c1.overhead().unwrap() < 0.10, "c1 overhead {:?}", c1.overhead());
        assert!(c8.overhead().unwrap() < 0.10, "c8 overhead {:?}", c8.overhead());
        // Per-session accounting conserves the dataset.
        assert_eq!(c8.concurrency, 8);
        assert_eq!(c8.per_session.len(), 8);
        assert_eq!(c8.per_session.iter().map(|s| s.files).sum::<usize>(), 1000);
        assert_eq!(c8.per_session.iter().map(|s| s.bytes).sum::<u64>(), ds.total_bytes());
        // Work stealing keeps every session busy most of the run.
        for s in &c8.per_session {
            assert!(
                s.utilization(c8.total_time) > 0.5,
                "session {} under-utilized: {}",
                s.session,
                s.utilization(c8.total_time)
            );
        }
    }

    #[test]
    fn concurrent_run_survives_zero_byte_files() {
        // A zero-size file's flow is done at birth; it must not leave the
        // session's transfer station occupied (regression: the dispatcher
        // asserted "one transfer at a time").
        let mut files = vec![FileSpec { id: 0, name: "z0".into(), size: 0 }];
        for i in 1..4u64 {
            files.push(FileSpec { id: i, name: format!("f{i}"), size: 100 * MB });
        }
        files.push(FileSpec { id: 4, name: "z1".into(), size: 0 });
        let ds = Dataset { name: "zeroes".into(), files };
        let s = run_concurrent(
            Testbed::hpclab_40g(),
            AlgoParams::default(),
            &ds,
            &FaultPlan::none(),
            Algorithm::Fiver,
            2,
            2,
        );
        assert_eq!(s.per_session.iter().map(|x| x.files).sum::<usize>(), 5);
        assert_eq!(s.per_session.iter().map(|x| x.bytes).sum::<u64>(), 300 * MB);
        assert!(s.total_time > 0.0);
    }

    #[test]
    fn concurrency_1_matches_serial_fiver() {
        let ds = Dataset::uniform("1G", GB, 4);
        let tb = Testbed::hpclab_40g();
        let p = AlgoParams::default();
        let serial = quick_run(tb, &ds, Algorithm::Fiver);
        let conc = run_concurrent(tb, p, &ds, &FaultPlan::none(), Algorithm::Fiver, 1, 1);
        let rel = (conc.total_time - serial.total_time).abs() / serial.total_time;
        assert!(rel < 0.02, "serial {} vs concurrent-1 {}", serial.total_time, conc.total_time);
    }

    /// The concurrent driver's fault accounting matches the serial
    /// drivers' (same failures caught, same repair bytes) for every
    /// queue-family algorithm.
    #[test]
    fn concurrent_fault_counts_match_serial() {
        let ds = Dataset::uniform("512M", 512 * MB, 6);
        let tb = Testbed::hpclab_40g();
        let faults = FaultPlan::random(&ds, 5, 11);
        let p = AlgoParams::default();
        for alg in [Algorithm::Fiver, Algorithm::FiverChunk, Algorithm::FiverMerkle] {
            let serial = run(tb, p, &ds, &faults, alg);
            let conc = run_concurrent(tb, p, &ds, &faults, alg, 3, 3);
            assert_eq!(conc.failures_detected, serial.failures_detected, "{}", alg.name());
            assert_eq!(conc.bytes_resent, serial.bytes_resent, "{}", alg.name());
            assert_eq!(conc.repair_rounds, serial.repair_rounds, "{}", alg.name());
        }
    }

    /// Small-file batching amortizes: with aggregation disabled the same
    /// run is never faster (per-item scheduling overhead is the only
    /// difference in a clean run, so the times should be close — this
    /// pins that batching at least does no harm).
    #[test]
    fn batching_does_no_harm() {
        let ds = Dataset::uniform("10M", 10 * MB, 120);
        let tb = Testbed::esnet_wan();
        let batched = run_concurrent(
            tb,
            AlgoParams::default(),
            &ds,
            &FaultPlan::none(),
            Algorithm::Fiver,
            4,
            4,
        );
        let unbatched = run_concurrent(
            tb,
            AlgoParams { batch_threshold: 0, ..AlgoParams::default() },
            &ds,
            &FaultPlan::none(),
            Algorithm::Fiver,
            4,
            4,
        );
        assert!(
            batched.total_time <= unbatched.total_time * 1.01,
            "batched {} vs unbatched {}",
            batched.total_time,
            unbatched.total_time
        );
    }

    /// On a network-limited testbed (hash faster than the wire), a mostly
    /// clean delta run beats a full re-send, and the counters account for
    /// the skipped bytes.
    #[test]
    fn delta_mostly_clean_beats_full_resend_when_network_bound() {
        let ds = Dataset::uniform("1G", GB, 4);
        let tb = Testbed::hpclab_1g(); // hash rate > bandwidth
        let p = AlgoParams { delta_fraction: 0.05, ..AlgoParams::default() };
        let delta = run_delta(tb, p, &ds, false);
        let full = quick_run(tb, &ds, Algorithm::Fiver);
        assert!(
            delta.total_time < full.total_time,
            "delta {} should beat full {}",
            delta.total_time,
            full.total_time
        );
        let total = ds.total_bytes();
        assert!(
            delta.bytes_skipped_delta > (total as f64 * 0.90) as u64,
            "skipped {} of {}",
            delta.bytes_skipped_delta,
            total
        );
        assert!(delta.leaves_clean > delta.leaves_dirty);
    }

    /// delta_fraction 1.0 (the default) is a full copy: nothing skipped,
    /// and the scan pass makes it no faster than a plain FIVER run.
    #[test]
    fn delta_all_dirty_skips_nothing() {
        let ds = Dataset::uniform("1G", GB, 2);
        let tb = Testbed::hpclab_40g();
        let s = run_delta(tb, AlgoParams::default(), &ds, false);
        assert_eq!(s.bytes_skipped_delta, 0);
        assert_eq!(s.leaves_clean, 0);
        assert!(s.leaves_dirty > 0);
        let full = quick_run(tb, &ds, Algorithm::Fiver);
        assert!(s.total_time >= full.total_time * 0.95, "{} vs {}", s.total_time, full.total_time);
    }

    /// A receiver without a journal hashes its old data to produce the
    /// signature basis — strictly slower than the journal-served path.
    #[test]
    fn delta_cold_receiver_pays_for_signatures() {
        let ds = Dataset::uniform("1G", GB, 4);
        let tb = Testbed::hpclab_1g();
        let p = AlgoParams { delta_fraction: 0.05, ..AlgoParams::default() };
        let warm = run_delta(tb, p, &ds, false);
        let cold = run_delta(tb, p, &ds, true);
        assert!(cold.total_time > warm.total_time, "{} vs {}", cold.total_time, warm.total_time);
    }

    #[test]
    fn merkle_retransfer_fault_outside_resent_range_is_moot() {
        use crate::faults::Fault;
        let ds = Dataset::uniform("1G", GB, 1);
        let tb = Testbed::hpclab_40g();
        // The occurrence-1 fault targets bytes that round 1 never re-sends
        // (different leaf): it cannot strike, so one round suffices.
        let faults = FaultPlan {
            faults: vec![
                Fault { file_idx: 0, offset: 12_345, bit: 0, occurrence: 0 },
                Fault { file_idx: 0, offset: 500 << 20, bit: 1, occurrence: 1 },
            ],
            crash: None,
        };
        let s = run(tb, AlgoParams::default(), &ds, &faults, Algorithm::FiverMerkle);
        assert_eq!(s.repair_rounds, 1);
    }
}
