//! Simulated testbed: binds a [`crate::config::Testbed`] to fluid-engine
//! resources, a TCP connection model, and per-host page caches, and
//! provides the flow constructors the algorithm drivers compose.

use crate::cache::PageCache;
use crate::config::{AlgoParams, IoCost, Testbed};
use crate::metrics::HitTrace;
use crate::net::TcpConn;
use crate::obs::{Recorder, Shard, SpanEvent, Stage};
use crate::sim::{FlowId, FluidSim, ResourceId};
use crate::workload::FileSpec;

/// Which endpoint a checksum/cache operation belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    /// The sending host.
    Src,
    /// The receiving host.
    Dst,
}

/// Fluid-engine resource handles for one src-dst pair.
#[derive(Debug, Clone, Copy)]
pub struct Res {
    /// Source disk (capacity = sequential read rate; writes are weighted).
    pub src_disk: ResourceId,
    /// Destination disk.
    pub dst_disk: ResourceId,
    /// Network path.
    pub net: ResourceId,
    /// Memory-bus read rate per host (cached checksum I/O).
    pub src_mem: ResourceId,
    /// Destination memory/page-cache bandwidth.
    pub dst_mem: ResourceId,
    /// One checksum core per host (the paper's single-threaded hashing).
    pub src_hash: ResourceId,
    /// Destination hash engine.
    pub dst_hash: ResourceId,
    /// Data-plane buffer pool throughput cap per host (infinite when
    /// `AlgoParams::pool_buffers` is 0). Little's law: a coupled FIVER
    /// flow holds each pooled buffer from fill until the hash worker
    /// drops it, so aggregate throughput <= pool_bytes / residency with
    /// residency ~ (queue_capacity + io_buf_size) / hash_rate. An ample
    /// pool leaves this far above every other bottleneck; a starved pool
    /// caps the whole endpoint — the regime concurrency sweeps probe.
    pub src_pool: ResourceId,
    /// Destination worker-pool admission.
    pub dst_pool: ResourceId,
}

/// A simulated testbed session set: one TCP connection and transfer
/// station per session (the engine's GridFTP-style concurrency), one
/// shared resource set. The single-session constructors/methods are the
/// classic serial drivers' API; `*_on` variants address a session.
pub struct SimEnv {
    /// The underlying fluid simulator.
    pub sim: FluidSim,
    /// One connection envelope per session.
    pub tcps: Vec<TcpConn>,
    /// Source page-cache model.
    pub src_cache: PageCache,
    /// Destination page-cache model.
    pub dst_cache: PageCache,
    /// Testbed specification.
    pub tb: Testbed,
    /// Algorithm parameters for the run.
    pub params: AlgoParams,
    /// Resource handles.
    pub res: Res,
    /// Source-side cache hit trace.
    pub src_trace: HitTrace,
    /// Destination-side cache hit trace.
    pub dst_trace: HitTrace,
    /// Currently active network transfer flow per session (at most one at
    /// a time per session — the station discipline); drives TCP cap
    /// management in [`SimEnv::pump_step`].
    active: Vec<Option<FlowId>>,
    /// (flow, side, hit_bytes, miss_bytes, t_start, stage): recorded into
    /// the hit trace (and, when tracing is on, as a virtual-time span)
    /// when the flow completes.
    pending_traces: Vec<(FlowId, Side, u64, u64, f64, Stage)>,
    /// Observability plane (off unless `FIVER_TRACE=1` or
    /// [`SimEnv::enable_tracing`]); spans carry virtual nanoseconds.
    pub obs: Recorder,
    obs_shard: Shard,
}

impl SimEnv {
    /// An environment for `tb` under `params`.
    pub fn new(tb: Testbed, params: AlgoParams) -> SimEnv {
        Self::new_parallel(tb, params, 1, 1)
    }

    /// A testbed with `sessions` concurrent transfer stations and a hash
    /// pool of `hash_workers` cores per host (capacity scales linearly —
    /// the shared-pool model of the real engine's
    /// [`crate::coordinator::pool::HashPool`]).
    pub fn new_parallel(
        tb: Testbed,
        params: AlgoParams,
        sessions: usize,
        hash_workers: usize,
    ) -> SimEnv {
        let n = sessions.max(1);
        let w = hash_workers.max(1) as f64;
        let mut sim = FluidSim::new();
        // Pooled buffer capacity as a rate cap (see `Res::src_pool`):
        // pool_bytes / residency, residency ~ (queue + one buffer) /
        // SINGLE-worker hash rate — a buffer is held until *its file's*
        // hash job (one worker) drains it, so summing over sessions gives
        // an aggregate cap scaled by the single-core rate, not the pooled
        // rate. pool_buffers == 0 models an unbounded pool.
        let pool_rate = |hash_rate_one: f64| -> f64 {
            if params.pool_buffers == 0 {
                f64::INFINITY
            } else {
                let pool_bytes = (params.pool_buffers * params.io_buf_size) as f64;
                let residency_bytes = (params.queue_capacity + params.io_buf_size) as f64;
                pool_bytes * hash_rate_one / residency_bytes
            }
        };
        let res = Res {
            src_disk: sim.add_resource("src_disk", tb.src.disk_read),
            dst_disk: sim.add_resource("dst_disk", tb.dst.disk_read.max(tb.dst.disk_write)),
            net: sim.add_resource("net", tb.bandwidth),
            src_mem: sim.add_resource("src_mem", tb.src.mem_read),
            dst_mem: sim.add_resource("dst_mem", tb.dst.mem_read),
            src_hash: sim.add_resource("src_hash", params.leaf_hash_rate(&tb.src) * w),
            dst_hash: sim.add_resource("dst_hash", params.leaf_hash_rate(&tb.dst) * w),
            src_pool: sim.add_resource("src_pool", pool_rate(params.leaf_hash_rate(&tb.src))),
            dst_pool: sim.add_resource("dst_pool", pool_rate(params.leaf_hash_rate(&tb.dst))),
        };
        let obs = Recorder::from_env();
        let obs_shard = obs.shard("sim");
        SimEnv {
            sim,
            tcps: (0..n).map(|_| TcpConn::new(tb.tcp_params())).collect(),
            src_cache: PageCache::new(tb.src.free_mem),
            dst_cache: PageCache::new(tb.dst.free_mem),
            tb,
            params,
            res,
            src_trace: HitTrace::new(1.0),
            dst_trace: HitTrace::new(1.0),
            active: vec![None; n],
            pending_traces: Vec::new(),
            obs,
            obs_shard,
        }
    }

    /// Swap in an enabled recorder regardless of `FIVER_TRACE` (tests,
    /// sim trace exports). Call before flows complete — spans finished
    /// under the previous recorder are not replayed.
    pub fn enable_tracing(&mut self) {
        self.obs = Recorder::enabled();
        self.obs_shard = self.obs.shard("sim");
    }

    /// Completed-flow spans recorded so far (virtual-time; oldest first).
    pub fn sim_spans(&self) -> Vec<SpanEvent> {
        self.obs_shard.spans()
    }

    /// Per-stage-group busy seconds — the sim analogue of the real
    /// engine's span-derived attribution groups (see
    /// [`crate::obs::attribute`]). Hash takes the busier endpoint core:
    /// either side's checksum station can gate the coupled pipeline.
    pub fn stage_busy(&self) -> Vec<(&'static str, f64)> {
        let s = &self.sim;
        vec![
            ("read", s.busy_seconds(self.res.src_disk)),
            ("hash", s.busy_seconds(self.res.src_hash).max(s.busy_seconds(self.res.dst_hash))),
            ("write", s.busy_seconds(self.res.dst_disk)),
            ("net", s.busy_seconds(self.res.net)),
        ]
    }

    /// Number of concurrent sessions.
    pub fn sessions(&self) -> usize {
        self.tcps.len()
    }

    /// Total TCP slow-start restarts across all sessions.
    pub fn restarts(&self) -> u64 {
        self.tcps.iter().map(|t| t.restarts).sum()
    }

    /// Current simulated time in seconds.
    pub fn now(&self) -> f64 {
        self.sim.now()
    }

    fn cache(&mut self, side: Side) -> &mut PageCache {
        match side {
            Side::Src => &mut self.src_cache,
            Side::Dst => &mut self.dst_cache,
        }
    }

    /// Per-backend storage cost weights (`AlgoParams::io_backend`).
    fn io_cost(&self) -> IoCost {
        IoCost::of(self.params.io_backend)
    }

    /// Disk-write weight at the destination: writing is slower than the
    /// resource capacity (= read rate), so each written byte consumes
    /// proportionally more disk time.
    fn write_weight(&self) -> f64 {
        (self.tb.dst.disk_read.max(self.tb.dst.disk_write)) / self.tb.dst.disk_write
    }

    /// Simulate the page-cache effect of a sequential read of
    /// `[offset, offset+len)`, stepping in cache granularity so
    /// self-eviction of larger-than-memory files emerges. Returns
    /// (hit_bytes, miss_bytes).
    pub fn cache_read(&mut self, side: Side, file: &FileSpec, offset: u64, len: u64) -> (u64, u64) {
        const STEP: u64 = 8 << 20;
        if self.io_cost().bypass_page_cache {
            // Direct I/O: every read comes off the disk, and reading
            // neither consults nor populates the cache.
            return (0, len);
        }
        let cache = self.cache(side);
        let (mut hits, mut misses) = (0u64, 0u64);
        let mut pos = offset;
        let end = offset + len;
        while pos < end {
            let n = STEP.min(end - pos);
            let acc = cache.read(file.id, pos, n);
            hits += acc.hit_bytes;
            misses += acc.miss_bytes;
            pos += n;
        }
        (hits, misses)
    }

    /// Insert written data into the destination cache (streaming write).
    pub fn cache_write(&mut self, side: Side, file: &FileSpec, offset: u64, len: u64) {
        const STEP: u64 = 8 << 20;
        if self.io_cost().bypass_page_cache {
            return; // direct writes never warm the destination cache
        }
        let cache = self.cache(side);
        let mut pos = offset;
        let end = offset + len;
        while pos < end {
            let n = STEP.min(end - pos);
            cache.write(file.id, pos, n);
            pos += n;
        }
    }

    /// Start a network transfer of `[offset, offset+len)` of `file` on
    /// session 0: reads at the source (disk or cache depending on
    /// residency), crosses the network under the TCP envelope, writes at
    /// the destination. Accounts source-side cache reads and
    /// destination-side cache writes, and records the source trace on
    /// completion.
    pub fn start_transfer(&mut self, file: &FileSpec, offset: u64, len: u64) -> FlowId {
        self.start_transfer_on(0, file, offset, len)
    }

    /// [`SimEnv::start_transfer`] on an explicit session.
    pub fn start_transfer_on(
        &mut self,
        session: usize,
        file: &FileSpec,
        offset: u64,
        len: u64,
    ) -> FlowId {
        assert!(self.active[session].is_none(), "one transfer at a time (station discipline)");
        let now = self.now();
        self.tcps[session].on_active(now);
        let cost = self.io_cost();
        let (hits, misses) = self.cache_read(Side::Src, file, offset, len);
        self.cache_write(Side::Dst, file, offset, len);
        let miss_frac = if len == 0 { 0.0 } else { misses as f64 / len as f64 };
        let hit_frac = 1.0 - miss_frac;
        let w_write = self.write_weight() * cost.write_weight_mult;
        let cap = self.tcps[session].rate();
        let flow = self.sim.start_flow(
            len as f64,
            vec![
                (self.res.src_disk, miss_frac),
                (self.res.src_mem, hit_frac * cost.cached_read_weight * cost.syscall_weight),
                (self.res.net, 1.0),
                (self.res.dst_disk, w_write),
            ],
            Some(cap),
        );
        // Zero-byte flows are done at birth: nothing for the TCP envelope
        // to pace, so don't occupy the station (it is only released by
        // pump_step, which callers may never reach for such flows).
        if !self.sim.is_done(flow) {
            self.active[session] = Some(flow);
        }
        self.pending_traces.push((flow, Side::Src, hits, misses, now, Stage::Send));
        flow
    }

    /// Start a checksum computation of `[offset, offset+len)` at `side`.
    /// `from_queue=true` is FIVER's I/O sharing: no file reads at all —
    /// bytes arrive via the in-memory queue (accounted as pure cache hits,
    /// matching how the paper reports FIVER's ~100% hit ratio).
    pub fn start_checksum(
        &mut self,
        side: Side,
        file: &FileSpec,
        offset: u64,
        len: u64,
        from_queue: bool,
    ) -> FlowId {
        let now = self.now();
        let (hash_res, mem_res, disk_res) = match side {
            Side::Src => (self.res.src_hash, self.res.src_mem, self.res.src_disk),
            Side::Dst => (self.res.dst_hash, self.res.dst_mem, self.res.dst_disk),
        };
        let (uses, hits, misses) = if from_queue {
            (vec![(hash_res, 1.0)], len, 0)
        } else {
            let cost = self.io_cost();
            let (hits, misses) = self.cache_read(side, file, offset, len);
            let miss_frac = if len == 0 { 0.0 } else { misses as f64 / len as f64 };
            (
                vec![
                    (hash_res, 1.0),
                    (mem_res, (1.0 - miss_frac) * cost.cached_read_weight * cost.syscall_weight),
                    (disk_res, miss_frac),
                ],
                hits,
                misses,
            )
        };
        let flow = self.sim.start_flow(len as f64, uses, None);
        self.pending_traces.push((flow, side, hits, misses, now, Stage::Hash));
        flow
    }

    /// Start a FIVER coupled flow on session 0: one read feeds the socket
    /// and both hash threads through the bounded queue, so the rate is
    /// the min of every stage (Algorithm 1 & 2's back-pressure). Checksum
    /// bytes are traced as pure hits on both sides.
    pub fn start_fiver_flow(&mut self, file: &FileSpec, offset: u64, len: u64) -> FlowId {
        self.start_fiver_flow_on(0, file, offset, len)
    }

    /// [`SimEnv::start_fiver_flow`] on an explicit session.
    pub fn start_fiver_flow_on(
        &mut self,
        session: usize,
        file: &FileSpec,
        offset: u64,
        len: u64,
    ) -> FlowId {
        assert!(self.active[session].is_none(), "one transfer at a time");
        let now = self.now();
        self.tcps[session].on_active(now);
        let cost = self.io_cost();
        let (hits, misses) = self.cache_read(Side::Src, file, offset, len);
        self.cache_write(Side::Dst, file, offset, len);
        let miss_frac = if len == 0 { 0.0 } else { misses as f64 / len as f64 };
        let w_write = self.write_weight() * cost.write_weight_mult;
        let cap = self.tcps[session].rate();
        let flow = self.sim.start_flow(
            len as f64,
            vec![
                (self.res.src_disk, miss_frac),
                (
                    self.res.src_mem,
                    (1.0 - miss_frac) * cost.cached_read_weight * cost.syscall_weight,
                ),
                (self.res.net, 1.0),
                (self.res.dst_disk, w_write),
                (self.res.src_hash, 1.0),
                (self.res.dst_hash, 1.0),
                (self.res.src_pool, 1.0),
                (self.res.dst_pool, 1.0),
            ],
            Some(cap),
        );
        // See start_transfer_on: a done-at-birth flow must not hold the
        // station, or the next start on this session would assert.
        if !self.sim.is_done(flow) {
            self.active[session] = Some(flow);
        }
        // Source trace: the single shared read; checksum I/O on both sides
        // is served from the queue (pure hits). The coupled flow spans as
        // one Send (the pipeline) plus the destination's Hash leg.
        self.pending_traces.push((flow, Side::Src, hits + len, misses, now, Stage::Send));
        self.pending_traces.push((flow, Side::Dst, len, 0, now, Stage::Hash));
        flow
    }

    /// Start a delta-sync coupled flow (the real engine's `--delta`
    /// steady state, see [`crate::coordinator::delta`]): the sender reads
    /// and rolling-scans the *whole* source (full read + hash cost), but
    /// only `dirty_frac` of each scanned byte crosses the wire. The
    /// receiver reconstructs locally — copying clean bytes from its own
    /// old copy (a destination read), writing the full staging file, and
    /// re-hashing the reconstructed result end-to-end (served from the
    /// just-written cache when the backend allows it).
    ///
    /// The flow is not registered with the session's TCP envelope: the
    /// wire leg is `dirty_frac` of the scan rate, so a whole-flow cap
    /// would wrongly throttle the scan; the `net` resource capacity still
    /// bounds the shipped bytes. Signature generation is journal-served
    /// (free) — model a cold receiver by charging a separate
    /// [`SimEnv::start_checksum`] of the old data first.
    pub fn start_delta_flow(&mut self, file: &FileSpec, dirty_frac: f64) -> FlowId {
        let now = self.now();
        let cost = self.io_cost();
        let dirty = dirty_frac.clamp(0.0, 1.0);
        let clean = 1.0 - dirty;
        // Sender: one full sequential read of the new source.
        let (shits, smisses) = self.cache_read(Side::Src, file, 0, file.size);
        let smiss_frac = if file.size == 0 { 0.0 } else { smisses as f64 / file.size as f64 };
        // Receiver: reads its old copy for the clean-leaf copies, then
        // writes the full staging file (which warms the cache for the
        // re-hash pass).
        let (dhits, dmisses) = self.cache_read(Side::Dst, file, 0, file.size);
        let dmiss_frac = if file.size == 0 { 0.0 } else { dmisses as f64 / file.size as f64 };
        self.cache_write(Side::Dst, file, 0, file.size);
        let w_write = self.write_weight() * cost.write_weight_mult;
        // Re-hash read: straight after the write, so cached unless the
        // backend bypasses the page cache (direct re-reads pay disk).
        let rehash_disk = if cost.bypass_page_cache { 1.0 } else { 0.0 };
        let rehash_mem = if cost.bypass_page_cache {
            0.0
        } else {
            cost.cached_read_weight * cost.syscall_weight
        };
        let flow = self.sim.start_flow(
            file.size as f64,
            vec![
                (self.res.src_disk, smiss_frac),
                (
                    self.res.src_mem,
                    (1.0 - smiss_frac) * cost.cached_read_weight * cost.syscall_weight,
                ),
                (self.res.src_hash, 1.0),
                (self.res.net, dirty),
                (self.res.dst_disk, clean * dmiss_frac + w_write + rehash_disk),
                (
                    self.res.dst_mem,
                    clean * (1.0 - dmiss_frac) * cost.cached_read_weight * cost.syscall_weight
                        + rehash_mem,
                ),
                (self.res.dst_hash, 1.0),
                (self.res.src_pool, 1.0),
                (self.res.dst_pool, 1.0),
            ],
            None,
        );
        let dirty_bytes = (file.size as f64 * dirty).round() as u64;
        self.pending_traces.push((flow, Side::Src, shits, smisses, now, Stage::Send));
        self.pending_traces.push((flow, Side::Dst, dhits + dirty_bytes, dmisses, now, Stage::Hash));
        flow
    }

    /// A control-plane byte exchange (delta signature payloads): `bytes`
    /// crossing the network alone, unpaced.
    pub fn start_ctrl_bytes(&mut self, bytes: u64) -> FlowId {
        self.sim.start_flow(bytes as f64, vec![(self.res.net, 1.0)], None)
    }

    /// A pure-delay flow of `secs` (control exchanges, pipeline bubbles).
    pub fn start_timer(&mut self, secs: f64) -> FlowId {
        self.sim.start_flow(secs.max(0.0), vec![], Some(1.0))
    }

    /// Model a process kill + restart of both endpoints: page caches are
    /// lost, every TCP envelope restarts from a cold slow start, and the
    /// restart costs `downtime` seconds of dead time plus one resume-
    /// handshake RTT. Callers must have drained in-flight flows first
    /// (the drivers split the crossing flow at the crash byte), exactly
    /// as a kill truncates a stream at a frame boundary.
    pub fn crash_restart(&mut self, downtime: f64) {
        assert!(!self.transfer_active(), "abandon in-flight flows before a crash");
        self.src_cache = PageCache::new(self.tb.src.free_mem);
        self.dst_cache = PageCache::new(self.tb.dst.free_mem);
        let params = self.tb.tcp_params();
        for t in self.tcps.iter_mut() {
            let survived = t.restarts + 1; // the kill itself is a restart
            *t = TcpConn::new(params);
            t.restarts = survived;
        }
        let timer = self.start_timer(downtime.max(0.0) + self.tb.rtt);
        self.pump_until(timer);
    }

    /// One engine step with TCP envelope management across every active
    /// session. Returns completed flows.
    pub fn pump_step(&mut self) -> Vec<FlowId> {
        let before = self.now();
        let mut max_dt = f64::INFINITY;
        for s in 0..self.active.len() {
            if let Some(f) = self.active[s] {
                let rate = self.tcps[s].rate();
                self.sim.set_cap(f, Some(rate));
                if let Some(dt) = self.tcps[s].next_rate_change() {
                    max_dt = max_dt.min(dt);
                }
            }
        }
        let step = self.sim.step(if max_dt.is_finite() { max_dt } else { 1e18 });
        let now = self.now();
        for s in 0..self.active.len() {
            if let Some(f) = self.active[s] {
                self.tcps[s].advance(before, now);
                if self.sim.is_done(f) {
                    self.active[s] = None;
                    self.tcps[s].on_idle_start(now);
                }
            }
        }
        // Flush finished trace records.
        let done: Vec<usize> = self
            .pending_traces
            .iter()
            .enumerate()
            .filter(|(_, (f, ..))| self.sim.is_done(*f))
            .map(|(i, _)| i)
            .collect();
        for i in done.into_iter().rev() {
            let (_, side, hits, misses, t0, stage) = self.pending_traces.swap_remove(i);
            let trace = match side {
                Side::Src => &mut self.src_trace,
                Side::Dst => &mut self.dst_trace,
            };
            trace.record(t0, now, hits, misses);
            // Virtual-time span: the flow's lifetime, in sim nanoseconds.
            self.obs_shard.record_ns(stage, (t0 * 1e9) as u64, ((now - t0) * 1e9) as u64);
        }
        step.completed
    }

    /// Pump until `flow` is done.
    pub fn pump_until(&mut self, flow: FlowId) {
        let mut guard = 0u64;
        while !self.sim.is_done(flow) {
            self.pump_step();
            guard += 1;
            assert!(guard < 50_000_000, "simulation runaway");
        }
    }

    /// Pump until all of `flows` are done.
    pub fn pump_until_all(&mut self, flows: &[FlowId]) {
        for &f in flows {
            self.pump_until(f);
        }
    }

    /// Whether any transfer flow is still running.
    pub fn transfer_active(&self) -> bool {
        self.active.iter().any(|a| a.is_some())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{gbps, GB, MB};
    use crate::workload::FileSpec;

    fn file(id: u64, size: u64) -> FileSpec {
        FileSpec { id, name: format!("f{id}"), size }
    }

    fn env() -> SimEnv {
        SimEnv::new(Testbed::hpclab_1g(), AlgoParams::default())
    }

    #[test]
    fn transfer_rate_bottlenecked_by_net() {
        let mut e = env();
        let f = file(0, GB);
        let flow = e.start_transfer(&f, 0, f.size);
        e.pump_until(flow);
        let expect = GB as f64 / gbps(1.0); // 1 Gbps link is the bottleneck
        let got = e.now();
        assert!(
            (got - expect) / expect < 0.10,
            "1 GB over 1 Gbps ~ {expect:.1}s, got {got:.1}s"
        );
    }

    #[test]
    fn fiver_flow_bottlenecked_by_slowest_stage() {
        // HPCLab-40G: hash (3 Gbps) is the slowest stage of the coupled flow.
        let mut e = SimEnv::new(Testbed::hpclab_40g(), AlgoParams::default());
        let f = file(0, 10 * GB);
        let flow = e.start_fiver_flow(&f, 0, f.size);
        e.pump_until(flow);
        let expect = (10 * GB) as f64 / gbps(3.0);
        let got = e.now();
        assert!(
            (got - expect).abs() / expect < 0.10,
            "hash-bound: expect ~{expect:.1}s, got {got:.1}s"
        );
    }

    #[test]
    fn checksum_after_transfer_reads_cache() {
        let mut e = env();
        let f = file(0, 100 * MB); // well under free_mem
        let flow = e.start_transfer(&f, 0, f.size);
        e.pump_until(flow);
        let t0 = e.now();
        let ck = e.start_checksum(Side::Dst, &f, 0, f.size, false);
        e.pump_until(ck);
        // Cached: rate = min(mem, hash) = hash = 3.4 Gbps, not disk.
        let dt = e.now() - t0;
        let expect = (100 * MB) as f64 / gbps(3.4);
        assert!((dt - expect).abs() / expect < 0.15, "expect {expect:.3}, got {dt:.3}");
        assert!(e.dst_trace.average() > 0.99, "dst checksum should hit cache");
    }

    #[test]
    fn large_file_checksum_misses_at_source() {
        let mut e = env(); // free_mem = 14 GB
        let f = file(0, 20 * GB);
        let flow = e.start_transfer(&f, 0, f.size);
        e.pump_until(flow);
        let (hits, misses) = e.cache_read(Side::Src, &f, 0, f.size);
        assert!(
            misses as f64 / (hits + misses) as f64 > 0.9,
            "20 GB > 14 GB free mem: checksum re-read should miss"
        );
    }

    #[test]
    fn timer_advances_clock() {
        let mut e = env();
        let t = e.start_timer(2.5);
        e.pump_until(t);
        assert!((e.now() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn queue_checksum_traces_pure_hits() {
        let mut e = env();
        let f = file(0, 50 * MB);
        let ck = e.start_checksum(Side::Dst, &f, 0, f.size, true);
        e.pump_until(ck);
        assert_eq!(e.dst_trace.total_misses(), 0);
        assert!(e.dst_trace.average() >= 1.0);
    }

    #[test]
    fn two_sessions_double_throughput_with_pooled_hash() {
        // Engine model: two concurrent FIVER flows with a 2-worker hash
        // pool each run at the single-core hash rate (3 Gbps on
        // HPCLab-40G), so both 10 GB files finish in the time one file
        // took serially — aggregate throughput doubles.
        let mut e = SimEnv::new_parallel(Testbed::hpclab_40g(), AlgoParams::default(), 2, 2);
        let fa = file(0, 10 * GB);
        let fb = file(1, 10 * GB);
        let a = e.start_fiver_flow_on(0, &fa, 0, fa.size);
        let b = e.start_fiver_flow_on(1, &fb, 0, fb.size);
        let mut guard = 0;
        while !e.sim.is_done(a) || !e.sim.is_done(b) {
            e.pump_step();
            guard += 1;
            assert!(guard < 1_000_000, "runaway");
        }
        let expect = (10 * GB) as f64 / gbps(3.0);
        let got = e.now();
        assert!(
            (got - expect).abs() / expect < 0.12,
            "two pooled sessions: expect ~{expect:.1}s, got {got:.1}s"
        );
        assert_eq!(e.sessions(), 2);
        assert!(!e.transfer_active());
    }

    #[test]
    fn starved_buffer_pool_caps_fiver_throughput() {
        // Ample pool: the coupled flow is hash-bound (3 Gbps on
        // HPCLab-40G). A pool holding only half the queue's worth of
        // bytes halves the achievable rate (Little's law cap), and an
        // unbounded pool (pool_buffers = 0) matches the ample case.
        let base = AlgoParams::default();
        let queue_bufs = base.queue_capacity / base.io_buf_size;
        let ample = AlgoParams { pool_buffers: 4 * queue_bufs, ..base };
        let starved = AlgoParams { pool_buffers: queue_bufs / 2, ..base };
        let time_with = |params: AlgoParams| {
            let mut e = SimEnv::new_parallel(Testbed::hpclab_40g(), params, 1, 1);
            let f = file(0, 10 * GB);
            let flow = e.start_fiver_flow(&f, 0, f.size);
            e.pump_until(flow);
            e.now()
        };
        let t_unbounded = time_with(base);
        let t_ample = time_with(ample);
        let t_starved = time_with(starved);
        assert!(
            (t_ample - t_unbounded).abs() / t_unbounded < 0.02,
            "ample pool must not throttle: {t_ample:.1}s vs {t_unbounded:.1}s"
        );
        assert!(
            t_starved > 1.7 * t_ample,
            "half-queue pool should roughly halve throughput: \
             {t_starved:.1}s vs {t_ample:.1}s"
        );
    }

    #[test]
    fn crash_restart_cools_caches_and_advances_clock() {
        let mut e = env();
        let f = file(0, 100 * MB);
        let flow = e.start_transfer(&f, 0, f.size);
        e.pump_until(flow);
        // Warm: a checksum read after the transfer hits cache.
        let (hits, _) = e.cache_read(Side::Dst, &f, 0, f.size);
        assert!(hits > 0, "transfer should have warmed the dst cache");
        let before = e.now();
        e.crash_restart(2.0);
        assert!(e.now() >= before + 2.0, "downtime + handshake RTT must elapse");
        assert!(e.restarts() >= 1, "the kill counts as a TCP restart");
        // Cold: the same read now misses (caches were lost with the
        // process).
        let (_, misses) = e.cache_read(Side::Dst, &f, 0, f.size);
        assert!(misses as f64 / f.size as f64 > 0.9, "restart must cold the caches");
        assert!(!e.transfer_active());
    }

    #[test]
    fn direct_backend_bypasses_page_cache() {
        use crate::storage::IoBackend;
        let params = AlgoParams { io_backend: IoBackend::Direct, ..AlgoParams::default() };
        let mut e = SimEnv::new(Testbed::hpclab_1g(), params);
        let f = file(0, 100 * MB);
        let flow = e.start_transfer(&f, 0, f.size);
        e.pump_until(flow);
        // Read-back verification after the transfer misses everything:
        // direct writes never warmed the destination cache.
        let (hits, misses) = e.cache_read(Side::Dst, &f, 0, f.size);
        assert_eq!(hits, 0);
        assert_eq!(misses, f.size);
    }

    #[test]
    fn direct_read_back_checksum_pays_disk() {
        use crate::storage::IoBackend;
        let time_for = |backend: IoBackend| {
            let params = AlgoParams { io_backend: backend, ..AlgoParams::default() };
            let mut e = SimEnv::new(Testbed::hpclab_1g(), params);
            let f = file(0, 100 * MB);
            let flow = e.start_transfer(&f, 0, f.size);
            e.pump_until(flow);
            let t0 = e.now();
            let ck = e.start_checksum(Side::Dst, &f, 0, f.size, false);
            e.pump_until(ck);
            e.now() - t0
        };
        let buffered = time_for(IoBackend::Buffered);
        let direct = time_for(IoBackend::Direct);
        // Buffered read-back hits the just-warmed cache (hash-bound at
        // 3.4 Gbps); direct re-reads off the 1.45 Gbps disk.
        assert!(
            direct > 1.8 * buffered,
            "direct read-back must pay disk: {direct:.3}s vs {buffered:.3}s"
        );
    }

    #[test]
    fn stage_busy_attributes_hash_bound_fiver_flow() {
        // HPCLab-40G: the coupled flow is gated by the 3 Gbps hash cores,
        // so the busy decomposition must label the run hash-bound.
        let mut e = SimEnv::new(Testbed::hpclab_40g(), AlgoParams::default());
        let f = file(0, 10 * GB);
        let flow = e.start_fiver_flow(&f, 0, f.size);
        e.pump_until(flow);
        let (label, confidence) = crate::obs::attribute(&e.stage_busy());
        assert_eq!(label, "hash-bound", "busy: {:?}", e.stage_busy());
        assert!(confidence > 1.0, "confidence {confidence}");
    }

    #[test]
    fn sim_spans_carry_virtual_time() {
        let mut e = env();
        e.enable_tracing();
        let f = file(0, 100 * MB);
        let flow = e.start_transfer(&f, 0, f.size);
        e.pump_until(flow);
        let spans = e.sim_spans();
        assert_eq!(spans.len(), 1, "one completed flow = one span");
        let wall_ns = (e.now() * 1e9) as u64;
        assert_eq!(spans[0].stage, Stage::Send);
        assert!(spans[0].dur_ns > 0 && spans[0].dur_ns <= wall_ns);
    }

    #[test]
    fn tcp_slow_start_visible_on_wan_small_file() {
        let mut e = SimEnv::new(Testbed::esnet_wan(), AlgoParams::default());
        let f = file(0, 10 * MB);
        let flow = e.start_transfer(&f, 0, f.size);
        e.pump_until(flow);
        let ideal = (10 * MB) as f64 / gbps(5.75);
        assert!(e.now() > 3.0 * ideal, "slow start should dominate: {} vs {ideal}", e.now());
    }
}
