//! Discrete-event fluid-flow simulator.
//!
//! The paper's phenomena are *rate relationships*: transfer rate vs
//! checksum rate vs disk rate decide which algorithm wins and by how much
//! (repro band 0/5 — the real 100 Gbps testbeds are substituted per
//! DESIGN.md §2). This engine models the testbed as shared **resources**
//! (disk, NIC, hash cores, memory bus) with byte/sec capacities and
//! **flows** (a transfer, a checksum computation) that consume them.
//!
//! Rates are allocated by *weighted max-min fairness* (progressive
//! filling): all active flows rise together; a resource saturates when the
//! weighted sum of its users' rates reaches capacity, freezing those users;
//! per-flow caps (TCP congestion windows) freeze individual flows. This is
//! the classic fluid approximation of TCP-fair sharing, exact enough for
//! reproduction of end-to-end times while letting 165 GB datasets simulate
//! in milliseconds.
//!
//! Submodules: [`testbed`] instantiates resources from a
//! [`crate::config::Testbed`]; [`algorithms`] drives the five
//! integrity-verification policies over the engine.

/// The simulated verification algorithms.
pub mod algorithms;
/// Testbed environment built on the fluid sim.
pub mod testbed;

use std::collections::HashMap;

/// Index of a resource in the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ResourceId(usize);

/// Index of a flow in the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlowId(usize);

#[derive(Debug)]
struct Resource {
    name: String,
    capacity: f64, // bytes/sec; f64::INFINITY for unconstrained
}

#[derive(Debug)]
struct FlowState {
    /// Remaining bytes of work.
    remaining: f64,
    /// (resource, weight): this flow consumes `weight` resource-bytes per
    /// flow-byte. E.g. a checksum flow with an 80% cache hit ratio uses
    /// (mem_bus, 0.8) and (disk, 0.2) plus (hash, 1.0).
    uses: Vec<(ResourceId, f64)>,
    /// External rate cap in bytes/sec (TCP congestion window envelope).
    cap: Option<f64>,
    /// Current allocated rate (recomputed on every topology change).
    rate: f64,
    done: bool,
}

/// Outcome of one engine step.
#[derive(Debug, Default)]
pub struct Step {
    /// Virtual seconds advanced.
    pub dt: f64,
    /// Flows that completed at the new time.
    pub completed: Vec<FlowId>,
}

/// The fluid-flow engine.
#[derive(Debug, Default)]
pub struct FluidSim {
    now: f64,
    resources: Vec<Resource>,
    flows: Vec<FlowState>,
    rates_dirty: bool,
    /// Accumulated busy seconds per resource (utilization-weighted time;
    /// feeds bottleneck attribution in the sim testbed).
    resource_busy: Vec<f64>,
}

impl FluidSim {
    /// An empty simulator at `t = 0`.
    pub fn new() -> FluidSim {
        FluidSim::default()
    }

    /// Current simulated time in seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Register a resource of the given capacity; returns its id.
    pub fn add_resource(&mut self, name: &str, capacity_bytes_per_sec: f64) -> ResourceId {
        assert!(capacity_bytes_per_sec > 0.0, "capacity must be positive");
        self.resources.push(Resource { name: name.to_string(), capacity: capacity_bytes_per_sec });
        self.resource_busy.push(0.0);
        ResourceId(self.resources.len() - 1)
    }

    /// Change a resource's capacity mid-run — the adaptive controller's
    /// sim-side actuation path (e.g. widening the hash pool scales the
    /// hash station linearly). Rates are recomputed on the next step;
    /// busy accounting for elapsed intervals keeps the capacity that was
    /// in force when they accrued.
    pub fn set_capacity(&mut self, r: ResourceId, capacity_bytes_per_sec: f64) {
        assert!(capacity_bytes_per_sec > 0.0, "capacity must be positive");
        if self.resources[r.0].capacity != capacity_bytes_per_sec {
            self.resources[r.0].capacity = capacity_bytes_per_sec;
            self.rates_dirty = true;
        }
    }

    /// Utilization-weighted busy time accumulated by a resource so far:
    /// each step contributes `dt * consumed_rate / capacity` (clamped to
    /// `dt` — a saturated resource is 100% busy). Infinite-capacity
    /// resources are never busy.
    pub fn busy_seconds(&self, r: ResourceId) -> f64 {
        self.resource_busy[r.0]
    }

    /// The name `r` was registered with.
    pub fn resource_name(&self, r: ResourceId) -> &str {
        &self.resources[r.0].name
    }

    /// Start a flow of `bytes` over weighted resources with an optional cap.
    /// Zero-byte flows complete on the next step without consuming time.
    pub fn start_flow(
        &mut self,
        bytes: f64,
        uses: Vec<(ResourceId, f64)>,
        cap: Option<f64>,
    ) -> FlowId {
        assert!(bytes >= 0.0);
        for &(r, w) in &uses {
            assert!(r.0 < self.resources.len(), "unknown resource");
            assert!(w >= 0.0, "negative weight");
        }
        self.flows.push(FlowState { remaining: bytes, uses, cap, rate: 0.0, done: bytes <= 0.0 });
        self.rates_dirty = true;
        FlowId(self.flows.len() - 1)
    }

    /// Add `extra` bytes of work to an in-flight flow (used to model
    /// per-byte cost factors, e.g. the filesystem read-path overhead of
    /// non-FIVER checksums).
    pub fn stretch_flow(&mut self, f: FlowId, extra: f64) {
        assert!(extra >= 0.0);
        let flow = &mut self.flows[f.0];
        if extra > 0.0 {
            flow.remaining += extra;
            if flow.done {
                flow.done = false;
            }
            self.rates_dirty = true;
        }
    }

    /// Update a flow's rate cap (TCP window growth/reset).
    pub fn set_cap(&mut self, f: FlowId, cap: Option<f64>) {
        if self.flows[f.0].cap != cap {
            self.flows[f.0].cap = cap;
            self.rates_dirty = true;
        }
    }

    /// Whether flow `f` has finished.
    pub fn is_done(&self, f: FlowId) -> bool {
        self.flows[f.0].done
    }

    /// Bytes flow `f` still has to move.
    pub fn remaining(&self, f: FlowId) -> f64 {
        self.flows[f.0].remaining
    }

    /// Currently allocated rate (valid after a step or [`recompute_rates`]).
    pub fn rate(&self, f: FlowId) -> f64 {
        self.flows[f.0].rate
    }

    /// Number of unfinished flows.
    pub fn active_flows(&self) -> usize {
        self.flows.iter().filter(|f| !f.done).count()
    }

    /// Weighted max-min fair (progressive-filling) rate allocation.
    pub fn recompute_rates(&mut self) {
        let n = self.flows.len();
        let mut avail: Vec<f64> = self.resources.iter().map(|r| r.capacity).collect();
        let mut frozen: Vec<bool> = self.flows.iter().map(|f| f.done).collect();
        let mut lambda_cur = 0.0f64;
        for f in self.flows.iter_mut() {
            if f.done {
                f.rate = 0.0;
            }
        }
        loop {
            // Weighted demand per resource from unfrozen flows.
            let mut demand: HashMap<usize, f64> = HashMap::new();
            for (i, f) in self.flows.iter().enumerate() {
                if frozen[i] {
                    continue;
                }
                for &(r, w) in &f.uses {
                    if w > 0.0 {
                        *demand.entry(r.0).or_insert(0.0) += w;
                    }
                }
            }
            let any_unfrozen = frozen.iter().enumerate().any(|(i, &fz)| !fz && i < n);
            if !any_unfrozen {
                break;
            }
            // Next event: a resource saturating or a cap being reached.
            let mut next = f64::INFINITY;
            for (&r, &d) in &demand {
                if d > 0.0 && avail[r].is_finite() {
                    next = next.min(avail[r] / d);
                }
            }
            for (i, f) in self.flows.iter().enumerate() {
                if !frozen[i] {
                    if let Some(cap) = f.cap {
                        next = next.min(cap - lambda_cur);
                    }
                }
            }
            if !next.is_finite() {
                // Only unconstrained flows remain: give them a huge rate.
                for (i, f) in self.flows.iter_mut().enumerate() {
                    if !frozen[i] {
                        f.rate = f64::MAX / 4.0;
                        frozen[i] = true;
                    }
                }
                break;
            }
            let step = next.max(0.0);
            lambda_cur += step;
            // Consume capacity for the step.
            for (&r, &d) in &demand {
                if avail[r].is_finite() {
                    avail[r] -= step * d;
                }
            }
            // Freeze flows: on saturated resources, or at their cap.
            let mut newly_frozen = Vec::new();
            for (i, f) in self.flows.iter().enumerate() {
                if frozen[i] {
                    continue;
                }
                let capped = f.cap.map(|c| lambda_cur >= c - 1e-12).unwrap_or(false);
                let saturated = f.uses.iter().any(|&(r, w)| {
                    w > 0.0
                        && avail[r.0].is_finite()
                        && avail[r.0] <= 1e-9 * self.resources[r.0].capacity
                });
                if capped || saturated {
                    newly_frozen.push(i);
                }
            }
            if newly_frozen.is_empty() {
                // Numerical safety: freeze everything at current level.
                for (i, _) in self.flows.iter().enumerate() {
                    if !frozen[i] {
                        newly_frozen.push(i);
                    }
                }
            }
            for i in newly_frozen {
                self.flows[i].rate = lambda_cur;
                frozen[i] = true;
            }
        }
        self.rates_dirty = false;
    }

    /// Advance time until the next flow completion, but at most `max_dt`
    /// seconds (drivers bound steps by TCP rate-change events / timers).
    /// Returns the elapsed time and any completed flows.
    pub fn step(&mut self, max_dt: f64) -> Step {
        assert!(max_dt > 0.0, "max_dt must be positive");
        if self.rates_dirty {
            self.recompute_rates();
        }
        // Zero-length flows complete immediately.
        let mut completed: Vec<FlowId> = Vec::new();
        for (i, f) in self.flows.iter_mut().enumerate() {
            if !f.done && f.remaining <= 1e-9 {
                f.done = true;
                f.rate = 0.0;
                completed.push(FlowId(i));
            }
        }
        if !completed.is_empty() {
            self.rates_dirty = true;
            return Step { dt: 0.0, completed };
        }
        // Time to the earliest completion at current rates.
        let mut dt = max_dt;
        for f in &self.flows {
            if !f.done && f.rate > 0.0 {
                dt = dt.min(f.remaining / f.rate);
            }
        }
        // Busy accounting at the (still valid) current rates: each
        // resource is `consumed/capacity` utilized for this interval.
        if dt > 0.0 {
            let mut consumed = vec![0.0f64; self.resources.len()];
            for f in &self.flows {
                if f.done || f.rate <= 0.0 {
                    continue;
                }
                for &(r, w) in &f.uses {
                    consumed[r.0] += f.rate * w;
                }
            }
            for (busy, (res, used)) in
                self.resource_busy.iter_mut().zip(self.resources.iter().zip(&consumed))
            {
                if res.capacity.is_finite() {
                    *busy += dt * (used / res.capacity).min(1.0);
                }
            }
        }
        // Advance all flows.
        for (i, f) in self.flows.iter_mut().enumerate() {
            if f.done {
                continue;
            }
            f.remaining -= f.rate * dt;
            if f.remaining <= 1e-6 {
                f.remaining = 0.0;
                f.done = true;
                f.rate = 0.0;
                completed.push(FlowId(i));
            }
        }
        self.now += dt;
        if !completed.is_empty() {
            self.rates_dirty = true;
        }
        Step { dt, completed }
    }

    /// Run until `flow` completes; panics if no progress is possible.
    /// Returns the completion time.
    pub fn run_until_done(&mut self, flow: FlowId) -> f64 {
        let mut guard = 0u64;
        while !self.is_done(flow) {
            let s = self.step(f64::INFINITY);
            assert!(
                s.dt > 0.0 || !s.completed.is_empty(),
                "no progress: flow starved (rate 0, nothing completing)"
            );
            guard += 1;
            assert!(guard < 10_000_000, "simulation runaway");
        }
        self.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_runs_at_capacity() {
        let mut sim = FluidSim::new();
        let disk = sim.add_resource("disk", 100.0);
        let f = sim.start_flow(1000.0, vec![(disk, 1.0)], None);
        let t = sim.run_until_done(f);
        assert!((t - 10.0).abs() < 1e-6, "1000 bytes at 100 B/s = 10 s, got {t}");
    }

    #[test]
    fn flow_rate_is_min_over_resources() {
        let mut sim = FluidSim::new();
        let fast = sim.add_resource("net", 1000.0);
        let slow = sim.add_resource("disk", 50.0);
        let f = sim.start_flow(500.0, vec![(fast, 1.0), (slow, 1.0)], None);
        let t = sim.run_until_done(f);
        assert!((t - 10.0).abs() < 1e-6, "bottleneck 50 B/s, got {t}");
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut sim = FluidSim::new();
        let disk = sim.add_resource("disk", 100.0);
        let a = sim.start_flow(500.0, vec![(disk, 1.0)], None);
        let b = sim.start_flow(500.0, vec![(disk, 1.0)], None);
        sim.recompute_rates();
        assert!((sim.rate(a) - 50.0).abs() < 1e-6);
        assert!((sim.rate(b) - 50.0).abs() < 1e-6);
        let t = sim.run_until_done(b);
        assert!((t - 10.0).abs() < 1e-6);
    }

    #[test]
    fn released_capacity_speeds_up_survivor() {
        let mut sim = FluidSim::new();
        let disk = sim.add_resource("disk", 100.0);
        let a = sim.start_flow(200.0, vec![(disk, 1.0)], None);
        let b = sim.start_flow(600.0, vec![(disk, 1.0)], None);
        sim.run_until_done(a); // a done at t=4 (both at 50 B/s)
        let t = sim.run_until_done(b);
        // b: 200 bytes by t=4, remaining 400 at 100 B/s -> t=8.
        assert!((t - 8.0).abs() < 1e-6, "got {t}");
    }

    #[test]
    fn cap_limits_flow_and_leaves_capacity() {
        let mut sim = FluidSim::new();
        let net = sim.add_resource("net", 100.0);
        let a = sim.start_flow(100.0, vec![(net, 1.0)], Some(10.0));
        let b = sim.start_flow(900.0, vec![(net, 1.0)], None);
        sim.recompute_rates();
        assert!((sim.rate(a) - 10.0).abs() < 1e-6, "capped at 10");
        assert!((sim.rate(b) - 90.0).abs() < 1e-6, "uncapped gets the rest");
    }

    #[test]
    fn weighted_flow_consumes_proportionally() {
        // Checksum flow with 80% cache hits: disk weight 0.2.
        let mut sim = FluidSim::new();
        let disk = sim.add_resource("disk", 100.0);
        let hash = sim.add_resource("hash", 400.0);
        let f = sim.start_flow(1000.0, vec![(disk, 0.2), (hash, 1.0)], None);
        sim.recompute_rates();
        // Progress limited by hash at 400 B/s and disk at 100/0.2=500 B/s.
        assert!((sim.rate(f) - 400.0).abs() < 1e-6, "rate {}", sim.rate(f));
    }

    #[test]
    fn weighted_contention() {
        let mut sim = FluidSim::new();
        let disk = sim.add_resource("disk", 100.0);
        // Transfer (weight 1) + checksum with 50% misses (weight 0.5).
        let t = sim.start_flow(1e9, vec![(disk, 1.0)], None);
        let c = sim.start_flow(1e9, vec![(disk, 0.5)], None);
        sim.recompute_rates();
        // Progressive filling: both rise to lambda where 1.0*l + 0.5*l = 100
        // -> l = 66.67: both frozen when disk saturates.
        assert!((sim.rate(t) - 200.0 / 3.0).abs() < 1e-3, "rate {}", sim.rate(t));
        assert!((sim.rate(c) - 200.0 / 3.0).abs() < 1e-3, "rate {}", sim.rate(c));
    }

    #[test]
    fn zero_byte_flow_completes_immediately() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource("r", 10.0);
        let f = sim.start_flow(0.0, vec![(r, 1.0)], None);
        let t = sim.run_until_done(f);
        assert_eq!(t, 0.0);
    }

    #[test]
    fn step_respects_max_dt() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource("r", 10.0);
        let f = sim.start_flow(100.0, vec![(r, 1.0)], None);
        let s = sim.step(2.0);
        assert!((s.dt - 2.0).abs() < 1e-9);
        assert!(!sim.is_done(f));
        assert!((sim.remaining(f) - 80.0).abs() < 1e-6);
    }

    #[test]
    fn capacity_change_mid_flight_rescales_rates() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource("hash", 100.0);
        let f = sim.start_flow(1000.0, vec![(r, 1.0)], None);
        sim.step(5.0); // 500 bytes at 100 B/s
        assert!((sim.remaining(f) - 500.0).abs() < 1e-6);
        sim.set_capacity(r, 250.0); // grow the pool: 2.5x capacity
        let t = sim.run_until_done(f);
        assert!((t - 7.0).abs() < 1e-6, "remaining 500 at 250 B/s: t=7, got {t}");
        // Busy time: saturated both before and after the change.
        assert!((sim.busy_seconds(r) - 7.0).abs() < 1e-6);
    }

    #[test]
    fn cap_change_mid_flight() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource("r", 100.0);
        let f = sim.start_flow(100.0, vec![(r, 1.0)], Some(10.0));
        sim.step(5.0); // 50 bytes at 10 B/s
        assert!((sim.remaining(f) - 50.0).abs() < 1e-6);
        sim.set_cap(f, None);
        let t = sim.run_until_done(f);
        assert!((t - 5.5).abs() < 1e-6, "remaining 50 at 100 B/s: t=5.5, got {t}");
    }

    #[test]
    fn flow_with_no_resources_is_unbounded() {
        let mut sim = FluidSim::new();
        let f = sim.start_flow(1e12, vec![], None);
        let t = sim.run_until_done(f);
        assert!(t < 1e-3, "unconstrained flow finishes instantly");
    }

    #[test]
    fn busy_seconds_track_utilization() {
        let mut sim = FluidSim::new();
        let disk = sim.add_resource("disk", 100.0);
        let hash = sim.add_resource("hash", 400.0);
        let f = sim.start_flow(1000.0, vec![(disk, 1.0), (hash, 1.0)], None);
        let t = sim.run_until_done(f);
        assert!((t - 10.0).abs() < 1e-6);
        // Disk saturated the whole run; hash ran at 100/400 = 25%.
        assert!((sim.busy_seconds(disk) - 10.0).abs() < 1e-6, "{}", sim.busy_seconds(disk));
        assert!((sim.busy_seconds(hash) - 2.5).abs() < 1e-6, "{}", sim.busy_seconds(hash));
    }

    #[test]
    fn three_stage_pipeline_flow() {
        // A FIVER-style coupled flow: disk -> net -> write + 2 hash cores.
        let mut sim = FluidSim::new();
        let disk = sim.add_resource("src_disk", 750.0);
        let net = sim.add_resource("net", 5000.0);
        let write = sim.add_resource("dst_disk", 1500.0);
        let h1 = sim.add_resource("src_hash", 375.0);
        let h2 = sim.add_resource("dst_hash", 375.0);
        let f = sim.start_flow(
            3750.0,
            vec![(disk, 1.0), (net, 1.0), (write, 1.0), (h1, 1.0), (h2, 1.0)],
            None,
        );
        let t = sim.run_until_done(f);
        assert!((t - 10.0).abs() < 1e-6, "hash-bound at 375 B/s, got {t}");
    }
}
