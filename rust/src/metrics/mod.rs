//! Metrics: the paper's Equation 1 overhead, hit-ratio timelines (Figs 1,
//! 4, 8, 9) and run summaries.

/// The paper's Eq. 1:
/// `overhead = (t_algorithm - max(t_chksum, t_transfer)) / max(t_chksum, t_transfer)`.
///
/// Example from §IV: transfer 90 s, checksum 120 s, FIVER 130 s → 8.3 %.
/// Panics when both baselines are zero; prefer [`overhead_checked`] where
/// zero baselines are possible (real runs don't measure them).
pub fn overhead(t_algorithm: f64, t_chksum: f64, t_transfer: f64) -> f64 {
    overhead_checked(t_algorithm, t_chksum, t_transfer)
        .expect("baseline must be positive")
}

/// Checked Eq. 1: `None` when the baseline `max(t_chksum, t_transfer)`
/// is not positive — real-run summaries carry zero baselines (a single
/// real run can't measure the transfer-only / checksum-only legs), and
/// asking for their overhead should degrade, not abort.
pub fn overhead_checked(t_algorithm: f64, t_chksum: f64, t_transfer: f64) -> Option<f64> {
    let base = t_chksum.max(t_transfer);
    (base > 0.0).then(|| (t_algorithm - base) / base)
}

/// A time-bucketed hit-ratio trace (receiver side unless noted), matching
/// the paper's per-second cache statistics plots.
#[derive(Debug, Clone, Default)]
pub struct HitTrace {
    /// Bucket width in (virtual) seconds.
    pub bucket: f64,
    /// Per-bucket (hit_bytes, miss_bytes).
    pub samples: Vec<(u64, u64)>,
}

impl HitTrace {
    /// A trace bucketing accesses every `bucket_secs` of (virtual) time.
    pub fn new(bucket_secs: f64) -> HitTrace {
        HitTrace { bucket: bucket_secs, samples: Vec::new() }
    }

    /// Record an access spanning `[t0, t1)` with the given byte counts,
    /// spread uniformly over the interval's buckets.
    pub fn record(&mut self, t0: f64, t1: f64, hit_bytes: u64, miss_bytes: u64) {
        assert!(t1 >= t0);
        let first = (t0 / self.bucket) as usize;
        let last = ((t1 / self.bucket) as usize).max(first);
        let n = last - first + 1;
        while self.samples.len() <= last {
            self.samples.push((0, 0));
        }
        for i in first..=last {
            self.samples[i].0 += hit_bytes / n as u64;
            self.samples[i].1 += miss_bytes / n as u64;
        }
        // Remainders to the first bucket (keeps totals exact).
        self.samples[first].0 += hit_bytes % n as u64;
        self.samples[first].1 += miss_bytes % n as u64;
    }

    /// Per-bucket hit ratios; buckets with no accesses yield `None`.
    pub fn ratios(&self) -> Vec<Option<f64>> {
        self.samples
            .iter()
            .map(|&(h, m)| {
                if h + m == 0 {
                    None
                } else {
                    Some(h as f64 / (h + m) as f64)
                }
            })
            .collect()
    }

    /// Average hit ratio over all accesses (the paper's "84.1% average hit
    /// ratio" style numbers).
    pub fn average(&self) -> f64 {
        let (h, m) = self
            .samples
            .iter()
            .fold((0u64, 0u64), |(ah, am), &(h, m)| (ah + h, am + m));
        if h + m == 0 {
            1.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Mean of the per-bucket hit ratios (the paper's "average hit ratio"
    /// is the time-average of its plotted per-second series — a long
    /// low-hit period counts by its duration, not its bytes).
    pub fn bucket_mean(&self) -> f64 {
        let ratios: Vec<f64> = self.ratios().into_iter().flatten().collect();
        if ratios.is_empty() {
            1.0
        } else {
            ratios.iter().sum::<f64>() / ratios.len() as f64
        }
    }

    /// Total missed bytes across the trace.
    pub fn total_misses(&self) -> u64 {
        self.samples.iter().map(|&(_, m)| m).sum()
    }

    /// Fraction of non-empty buckets whose hit ratio is below `threshold`
    /// (the paper's "hit ratio falls below 10% during checksum of large
    /// files" observations).
    pub fn frac_below(&self, threshold: f64) -> f64 {
        let ratios: Vec<f64> = self.ratios().into_iter().flatten().collect();
        if ratios.is_empty() {
            return 0.0;
        }
        ratios.iter().filter(|&&r| r < threshold).count() as f64 / ratios.len() as f64
    }

    /// Render a sparkline for terminal output.
    pub fn sparkline(&self, width: usize) -> String {
        const GLYPHS: [char; 9] = [' ', '▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let ratios = self.ratios();
        if ratios.is_empty() {
            return String::new();
        }
        let step = (ratios.len() as f64 / width as f64).max(1.0);
        let mut out = String::new();
        let mut i = 0.0;
        while (i as usize) < ratios.len() && out.chars().count() < width {
            let r = ratios[i as usize];
            out.push(match r {
                None => ' ',
                Some(v) => GLYPHS[1 + ((v * 7.0).round() as usize).min(7)],
            });
            i += step;
        }
        out
    }
}

/// Per-session accounting for a parallel engine run (real or simulated):
/// what one concurrency slot moved and how long it was occupied.
#[derive(Debug, Clone, Default)]
pub struct SessionStats {
    /// Session index this row describes.
    pub session: usize,
    /// Files this session transferred (work-stealing makes this uneven by
    /// design — slow sessions shed work).
    pub files: usize,
    /// Payload bytes this session streamed.
    pub bytes: u64,
    /// Virtual/wall seconds the session had a flow (or repair exchange)
    /// in flight.
    pub busy_secs: f64,
}

impl SessionStats {
    /// Fraction of the run this session was busy.
    pub fn utilization(&self, total_secs: f64) -> f64 {
        if total_secs <= 0.0 {
            0.0
        } else {
            (self.busy_secs / total_secs).min(1.0)
        }
    }
}

/// Summary of one simulated or real run of an algorithm over a dataset.
#[derive(Debug, Clone, Default)]
pub struct RunSummary {
    /// Algorithm name.
    pub algorithm: String,
    /// Dataset name.
    pub dataset: String,
    /// Testbed name.
    pub testbed: String,
    /// End-to-end wall/virtual time (s).
    pub total_time: f64,
    /// Baselines for Eq. 1.
    pub t_transfer_only: f64,
    /// Standalone checksum time — the `t_cksm` term of Eq. 1.
    pub t_checksum_only: f64,
    /// Receiver-side hit trace.
    pub dst_trace: HitTrace,
    /// Sender-side hit trace.
    pub src_trace: HitTrace,
    /// TCP slow-start restarts incurred.
    pub tcp_restarts: u64,
    /// Bytes retransmitted due to verification failures.
    pub bytes_resent: u64,
    /// Verification failures detected (== faults caught).
    pub failures_detected: u64,
    /// Repair rounds executed (re-transfer batches after a failed verify).
    pub repair_rounds: u64,
    /// Bytes re-read from source storage for repairs.
    pub bytes_reread: u64,
    /// Control-channel round trips spent on verification (digest/root
    /// exchanges plus Merkle node-range query rounds).
    pub verify_rtts: u64,
    /// Data-plane pool telemetry, mirrored from a real run's
    /// `TransferReport` by [`RunSummary::from_real`] (the sim models pool
    /// capacity as a rate cap instead, so simulated summaries leave these
    /// at 0): grace-expired unpooled allocations, and the peak pooled
    /// buffers in flight.
    pub pool_fallback_allocs: u64,
    /// Peak pooled buffers in flight (see above).
    pub pool_peak_in_flight: u64,
    /// Adaptive pool-capacity raises (real runs; 0 in the sim).
    pub pool_grow_events: u64,
    /// Storage I/O engine of the run (real runs mirror the endpoint's
    /// storage; the sim records the modeled `AlgoParams::io_backend`).
    pub io_backend: String,
    /// Storage sync calls (real runs; the sim does not model fsync).
    pub storage_syncs: u64,
    /// O_DIRECT per-op fallbacks to buffered I/O (real runs with the
    /// direct backend; 0 elsewhere).
    pub direct_fallbacks: u64,
    /// Per-stage busy time + latency percentiles from the observability
    /// plane. Real runs fill counts and p50/p95/p99 from the merged
    /// shard histograms; sim runs fill the four bottleneck groups'
    /// `busy_secs` from the fluid model's resource utilization. Empty
    /// when tracing is disabled.
    pub stage_stats: Vec<crate::obs::StageStats>,
    /// Bottleneck label from per-stage busy-time decomposition
    /// (`hash-bound` / `read-bound` / `write-bound` / `net-bound`;
    /// empty when unknown).
    pub bottleneck: String,
    /// Busiest stage group over the runner-up (>= 1;
    /// [`f64::INFINITY`] when no other group recorded anything —
    /// rendered as `sole` / JSON `null`).
    pub bottleneck_confidence: f64,
    /// Files the resume handshake verified from the journal and skipped.
    pub files_skipped: u64,
    /// Bytes those skipped files would have re-sent.
    pub bytes_skipped: u64,
    /// Bytes a `--delta` run matched against the receiver's existing data
    /// and never sent (sim: the modeled clean fraction of the dataset).
    pub bytes_skipped_delta: u64,
    /// Leaves re-sent as literals in a delta run (changed data).
    pub leaves_dirty: u64,
    /// Leaves matched clean and copied from the receiver's own data.
    pub leaves_clean: u64,
    /// Delta files whose rolling scan the sender-side signature cache
    /// skipped (its journaled record matched the receiver's basis).
    pub delta_scans_skipped: u64,
    /// Hash tier of the run (`fast` / `cryptographic` / `tiered`; empty
    /// for summaries that predate tiering).
    pub hash_tier: String,
    /// Concurrent sessions used (1 for the serial drivers).
    pub concurrency: usize,
    /// Per-session accounting (empty for the serial drivers).
    pub per_session: Vec<SessionStats>,
}

impl RunSummary {
    /// Checked Eq. 1 overhead: `None` when the baselines are unknown
    /// (real runs leave them at 0 — see [`RunSummary::from_real`]).
    pub fn overhead(&self) -> Option<f64> {
        overhead_checked(self.total_time, self.t_checksum_only, self.t_transfer_only)
    }

    /// Mirror a real engine run's aggregate report into a summary
    /// (wall-clock, repair, data-plane pool and observability
    /// telemetry), so real and simulated runs render through the same
    /// reporting surface. The Eq. 1 baselines are not measurable from a
    /// single real run and stay 0 — [`RunSummary::overhead`] returns
    /// `None` on these.
    pub fn from_real(
        report: &crate::coordinator::TransferReport,
        concurrency: usize,
    ) -> RunSummary {
        RunSummary {
            algorithm: report.algorithm.clone(),
            total_time: report.elapsed_secs,
            bytes_resent: report.bytes_resent,
            failures_detected: report.failures_detected,
            repair_rounds: report.repair_rounds,
            bytes_reread: report.bytes_reread,
            verify_rtts: report.verify_rtts,
            pool_fallback_allocs: report.pool_fallback_allocs,
            pool_peak_in_flight: report.pool_peak_in_flight,
            pool_grow_events: report.pool_grow_events,
            io_backend: report.io_backend.clone(),
            storage_syncs: report.storage_syncs,
            direct_fallbacks: report.direct_fallbacks,
            stage_stats: report.stage_stats.clone(),
            bottleneck: report.bottleneck.clone(),
            bottleneck_confidence: report.bottleneck_confidence,
            files_skipped: report.files_skipped,
            bytes_skipped: report.bytes_skipped,
            bytes_skipped_delta: report.bytes_skipped_delta,
            leaves_dirty: report.leaves_dirty,
            leaves_clean: report.leaves_clean,
            delta_scans_skipped: report.delta_scans_skipped,
            hash_tier: report.hash_tier.clone(),
            concurrency,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_paper_example() {
        // §IV: transfer 90 s, checksum 120 s, algorithm 130 s -> 8.3 %.
        let o = overhead(130.0, 120.0, 90.0);
        assert!((o - 0.0833).abs() < 1e-3, "{o}");
    }

    #[test]
    fn eq1_checked_degrades_on_zero_baselines() {
        assert_eq!(overhead_checked(130.0, 0.0, 0.0), None);
        let o = overhead_checked(130.0, 120.0, 90.0).unwrap();
        assert!((o - 0.0833).abs() < 1e-3, "{o}");
        // A real-run summary (zero baselines) must degrade, not abort.
        let real = RunSummary { total_time: 1.5, ..Default::default() };
        assert_eq!(real.overhead(), None);
    }

    #[test]
    fn eq1_can_be_negative() {
        // An algorithm faster than the slower baseline is possible when the
        // baseline itself pays I/O contention the algorithm avoids.
        assert!(overhead(100.0, 120.0, 90.0) < 0.0);
    }

    #[test]
    fn trace_records_and_averages() {
        let mut t = HitTrace::new(1.0);
        t.record(0.0, 2.0, 100, 100);
        t.record(2.0, 3.0, 300, 0);
        assert!((t.average() - 400.0 / 500.0).abs() < 1e-9);
        assert_eq!(t.total_misses(), 100);
    }

    #[test]
    fn trace_ratios_mark_idle_buckets() {
        let mut t = HitTrace::new(1.0);
        t.record(0.0, 0.5, 10, 0);
        t.record(3.0, 3.5, 0, 10);
        let r = t.ratios();
        assert_eq!(r[0], Some(1.0));
        assert_eq!(r[1], None);
        assert_eq!(r[3], Some(0.0));
    }

    #[test]
    fn frac_below_detects_low_periods() {
        let mut t = HitTrace::new(1.0);
        t.record(0.0, 1.0, 100, 0); // bucket 0+1: high
        t.record(2.0, 2.5, 5, 95); // bucket 2: 5%
        assert!(t.frac_below(0.10) > 0.0);
    }

    #[test]
    fn totals_exact_under_spreading() {
        let mut t = HitTrace::new(1.0);
        t.record(0.0, 7.0, 1000003, 999999);
        let (h, m) = t
            .samples
            .iter()
            .fold((0u64, 0u64), |(ah, am), &(h, m)| (ah + h, am + m));
        assert_eq!(h, 1000003);
        assert_eq!(m, 999999);
    }

    #[test]
    fn from_real_mirrors_report_counters() {
        let report = crate::coordinator::TransferReport {
            algorithm: "FIVER".into(),
            elapsed_secs: 1.5,
            bytes_resent: 64,
            failures_detected: 2,
            repair_rounds: 2,
            bytes_reread: 64,
            verify_rtts: 9,
            pool_fallback_allocs: 3,
            pool_peak_in_flight: 40,
            bytes_skipped: 128,
            files_skipped: 1,
            bytes_skipped_delta: 4096,
            leaves_dirty: 2,
            leaves_clean: 14,
            ..Default::default()
        };
        let s = RunSummary::from_real(&report, 4);
        assert_eq!(s.algorithm, "FIVER");
        assert_eq!(s.total_time, 1.5);
        assert_eq!(s.pool_fallback_allocs, 3);
        assert_eq!(s.pool_peak_in_flight, 40);
        assert_eq!(s.concurrency, 4);
        assert_eq!(s.failures_detected, 2);
        assert_eq!(s.bytes_skipped, 128);
        assert_eq!(s.files_skipped, 1);
        assert_eq!(s.bytes_skipped_delta, 4096);
        assert_eq!(s.leaves_dirty, 2);
        assert_eq!(s.leaves_clean, 14);
    }

    #[test]
    fn session_utilization_bounds() {
        let s = SessionStats { session: 0, files: 3, bytes: 100, busy_secs: 5.0 };
        assert!((s.utilization(10.0) - 0.5).abs() < 1e-9);
        assert_eq!(s.utilization(0.0), 0.0);
        assert_eq!(s.utilization(1.0), 1.0, "clamped");
    }

    #[test]
    fn sparkline_renders() {
        let mut t = HitTrace::new(1.0);
        t.record(0.0, 4.0, 50, 50);
        let s = t.sparkline(10);
        assert!(!s.is_empty());
    }
}
