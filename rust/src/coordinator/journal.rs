//! Checkpoint journal + resume handshake — crash-recoverable transfers.
//!
//! A production transfer service must survive a process kill mid-dataset
//! without re-hashing and re-sending everything. This module records
//! engine progress durably on *both* endpoints and lets a restarted
//! sender/receiver pair negotiate per-file restart offsets:
//!
//! * Each endpoint folds the in-order byte stream of every file through a
//!   [`LeafTracker`] — a streaming leaf hasher at the session's Merkle
//!   leaf granularity (`SessionConfig::leaf_size`), independent of which
//!   verification policy the transfer runs. Completed leaf digests append
//!   to a per-file [`FileJournal`] record.
//! * Records are **append-only and prefix-valid**: a fixed binary header
//!   followed by fixed-stride leaf digests. Recovery parses the header and
//!   keeps `floor((len - header) / digest_len)` digests — a torn append
//!   truncates to the last whole digest, a torn header invalidates the
//!   record (full re-transfer), and no state is ever rewritten in place
//!   except explicit repair patches. Durability ordering at a checkpoint
//!   is *data before journal*: the receiver syncs the destination file,
//!   then appends + syncs the journal, so a journaled watermark never
//!   claims bytes the storage could have lost.
//! * On restart, the receiver offers `(file, watermark)` per journaled
//!   record; the sender counter-offers the longest common complete-leaf
//!   prefix together with its Merkle root over its *own* journaled leaves
//!   ([`negotiate_sender`]); the receiver folds its leaves to the same
//!   root and issues a verdict ([`negotiate_receiver`]). Equal roots mean
//!   the prefix already delivered matches the source **without re-reading
//!   a single prefix byte on either side**; a mismatch falls back to full
//!   re-transfer of that file. Agreed files re-enter the scheduler as
//!   their unfinished tail only; fully-delivered files whose complete
//!   roots match are skipped outright.
//! * A resumed file is verified end-to-end by the journal's digest tree
//!   regardless of the session algorithm: both endpoints seed a
//!   [`crate::merkle::MerkleBuilder`] with the agreed prefix leaves and
//!   fold the tail from their queues, then run the existing
//!   `TreeRoot`/descent exchange — so tail corruption repairs at leaf
//!   granularity, exactly like FIVER-Merkle.
//!
//! See DESIGN.md "Checkpoint journal & crash recovery" for the record
//! format and the crash-consistency argument.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::protocol::{Frame, UNIT_FILE};
use super::{HasherFactory, SessionConfig};
use crate::hashes::Hasher;
use crate::merkle::MerkleTree;
use crate::storage::Storage;

/// Record magic (8 bytes, versioned).
const MAGIC: &[u8; 8] = b"FVRJNL01";

/// Data-sync callback a [`JournalFold`] runs before each checkpoint —
/// `Storage::sync_file` on the receiver (fdatasync the destination
/// inode), `None` on the read-only sender side.
pub type DataSync = Box<dyn Fn() -> Result<()> + Send>;

/// Fixed part of the record header: magic + name_len(u32) + size(u64) +
/// leaf_size(u64) + digest_len(u32).
const FIXED_HEADER: usize = 8 + 4 + 8 + 8 + 4;

/// Upper bound on journaled file names (defensive parse limit).
const MAX_NAME: usize = 4096;

// ---------------------------------------------------------------------------
// Journal directory
// ---------------------------------------------------------------------------

/// One endpoint's journal: a directory of per-file records, keyed by the
/// dataset-global file index (which is stable across restarts because the
/// engine is re-invoked with the same file list).
pub struct Journal {
    dir: PathBuf,
}

impl Journal {
    /// Open (creating if needed) a journal directory.
    pub fn open(dir: &Path) -> Result<Journal> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating journal dir {}", dir.display()))?;
        Ok(Journal { dir: dir.to_path_buf() })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn record_path(&self, file_idx: u32) -> PathBuf {
        self.dir.join(format!("f{file_idx:06}.fjl"))
    }

    /// Start a fresh record for `file_idx` (truncating any stale one).
    pub fn create(
        &self,
        file_idx: u32,
        name: &str,
        size: u64,
        leaf_size: u64,
        digest_len: usize,
    ) -> Result<FileJournal> {
        anyhow::ensure!(leaf_size > 0 && digest_len > 0, "bad journal geometry");
        anyhow::ensure!(name.len() <= MAX_NAME, "file name too long to journal");
        let mut header = Vec::with_capacity(FIXED_HEADER + name.len());
        header.extend_from_slice(MAGIC);
        header.extend_from_slice(&(name.len() as u32).to_le_bytes());
        header.extend_from_slice(&size.to_le_bytes());
        header.extend_from_slice(&leaf_size.to_le_bytes());
        header.extend_from_slice(&(digest_len as u32).to_le_bytes());
        header.extend_from_slice(name.as_bytes());
        let path = self.record_path(file_idx);
        let mut file = File::create(&path)
            .with_context(|| format!("creating journal record {}", path.display()))?;
        file.write_all(&header)?;
        file.sync_data().context("journal header sync")?;
        Ok(FileJournal {
            file,
            digest_len,
            header_len: header.len() as u64,
            synced_leaves: 0,
            pending: Vec::new(),
        })
    }

    /// Reopen an existing record for a resumed file, truncating it to the
    /// agreed `keep_leaves` digests (the negotiated common prefix). Tail
    /// digests past the agreement are discarded; appends continue from
    /// there as the resumed stream flows.
    pub fn open_resumed(&self, file_idx: u32, keep_leaves: u64) -> Result<FileJournal> {
        let path = self.record_path(file_idx);
        let rec = self
            .load(file_idx)?
            .with_context(|| format!("no journal record to resume at {}", path.display()))?;
        let keep = keep_leaves.min(rec.leaf_count());
        let header_len = (FIXED_HEADER + rec.name.len()) as u64;
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("reopening journal record {}", path.display()))?;
        file.set_len(header_len + keep * rec.digest_len as u64)?;
        file.sync_data().context("journal truncate sync")?;
        Ok(FileJournal {
            file,
            digest_len: rec.digest_len,
            header_len,
            synced_leaves: keep,
            pending: Vec::new(),
        })
    }

    /// Parse one record; `None` when absent or invalid (torn header,
    /// unknown magic — recovery treats both as "no checkpoint").
    pub fn load(&self, file_idx: u32) -> Result<Option<JournalRecord>> {
        let path = self.record_path(file_idx);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e).context("reading journal record"),
        };
        Ok(parse_record(&bytes))
    }

    /// Every parseable record in the journal, keyed by file index.
    pub fn load_all(&self) -> Result<BTreeMap<u32, JournalRecord>> {
        let mut out = BTreeMap::new();
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
            Err(e) => return Err(e).context("reading journal dir"),
        };
        for entry in entries {
            let entry = entry?;
            let fname = entry.file_name();
            let Some(fname) = fname.to_str() else { continue };
            let Some(idx) = fname
                .strip_prefix('f')
                .and_then(|s| s.strip_suffix(".fjl"))
                .and_then(|s| s.parse::<u32>().ok())
            else {
                continue;
            };
            if let Some(rec) = self.load(idx)? {
                out.insert(idx, rec);
            }
        }
        Ok(out)
    }

    /// Drop a record (stale / rejected at handshake). Best-effort.
    pub fn remove(&self, file_idx: u32) {
        std::fs::remove_file(self.record_path(file_idx)).ok();
    }

    /// Open-or-create the record for one file as its stream begins: a
    /// resumed file (`start_at > 0`) truncates its record to the agreed
    /// complete-leaf prefix and continues from there; a fresh file starts
    /// a new record. Single-sourced so sender and receiver compute
    /// identical journal state (keep-leaves rounding included).
    pub fn begin_record(
        &self,
        file_idx: u32,
        name: &str,
        size: u64,
        start_at: u64,
        cfg: &SessionConfig,
    ) -> Result<FileJournal> {
        if start_at > 0 {
            self.open_resumed(file_idx, start_at / cfg.leaf_size)
        } else {
            let dlen = (cfg.hasher)().digest_len();
            self.create(file_idx, name, size, cfg.leaf_size, dlen)
        }
    }

    /// [`Journal::begin_record`] plus a [`LeafTracker`] positioned to
    /// continue it — the stream-side journaling pair (non-tree files,
    /// where the stream thread itself folds leaves).
    pub fn begin_file(
        &self,
        file_idx: u32,
        name: &str,
        size: u64,
        start_at: u64,
        cfg: &SessionConfig,
    ) -> Result<(FileJournal, LeafTracker)> {
        let fj = self.begin_record(file_idx, name, size, start_at, cfg)?;
        let tracker = if start_at > 0 {
            LeafTracker::resume(cfg.leaf_size, &cfg.hasher, start_at / cfg.leaf_size)
        } else {
            LeafTracker::new(cfg.leaf_size, &cfg.hasher)
        };
        Ok((fj, tracker))
    }

    /// [`Journal::begin_record`] wrapped for the verification tree job
    /// ([`JournalFold`]): FIVER-Merkle and resumed files journal from the
    /// hash job's single pass instead of paying a second in-memory hash
    /// on the stream thread. `sync_data` runs before every checkpoint
    /// (the data-before-journal ordering); `None` on the sender, whose
    /// source is read-only.
    pub fn begin_fold(
        &self,
        file_idx: u32,
        name: &str,
        size: u64,
        start_at: u64,
        cfg: &SessionConfig,
        sync_data: Option<DataSync>,
    ) -> Result<JournalFold> {
        let fj = self.begin_record(file_idx, name, size, start_at, cfg)?;
        Ok(JournalFold {
            fj,
            checkpoint_leaves: cfg.journal_checkpoint_leaves.max(1),
            sync_data,
            failed: false,
        })
    }

    /// Patch a (possibly closed) record after repair `Fix` frames rewrote
    /// byte `ranges` of the file: every journaled leaf the ranges touch is
    /// recomputed via `recompute(offset, len)` (a storage re-hash of at
    /// most the touched leaves) and overwritten in place, then synced. A
    /// crash mid-patch at worst tears one digest, which fails the next
    /// resume handshake closed (full re-transfer).
    pub fn patch_record(
        &self,
        file_idx: u32,
        ranges: &[(u64, u64)],
        mut recompute: impl FnMut(u64, u64) -> Result<Vec<u8>>,
    ) -> Result<()> {
        let Some(rec) = self.load(file_idx)? else { return Ok(()) };
        let dirty = leaves_touched(ranges, rec.leaf_size, rec.leaf_count());
        if dirty.is_empty() {
            return Ok(());
        }
        let path = self.record_path(file_idx);
        let mut file = OpenOptions::new().write(true).open(&path)?;
        let header_len = (FIXED_HEADER + rec.name.len()) as u64;
        for l in dirty {
            let loff = l * rec.leaf_size;
            let llen = rec.leaf_size.min(rec.size.saturating_sub(loff));
            let d = recompute(loff, llen)?;
            anyhow::ensure!(d.len() == rec.digest_len, "digest width mismatch in patch");
            file.seek(SeekFrom::Start(header_len + l * rec.digest_len as u64))?;
            file.write_all(&d)?;
        }
        file.sync_data().context("journal patch sync")?;
        Ok(())
    }
}

/// Leaf indices (`< recorded`) whose spans intersect any of `ranges` —
/// shared by the closed-record patch path and the receiver's open-file
/// repair path, so the range→leaf mapping cannot diverge.
pub(crate) fn leaves_touched(ranges: &[(u64, u64)], leaf_size: u64, recorded: u64) -> Vec<u64> {
    let mut dirty: Vec<u64> = Vec::new();
    if recorded == 0 {
        return dirty;
    }
    for &(off, len) in ranges {
        if len == 0 {
            continue;
        }
        let first = off / leaf_size;
        let last = (off + len - 1) / leaf_size;
        for l in first..=last.min(recorded - 1) {
            dirty.push(l);
        }
    }
    dirty.sort_unstable();
    dirty.dedup();
    dirty
}

fn parse_record(bytes: &[u8]) -> Option<JournalRecord> {
    if bytes.len() < FIXED_HEADER || &bytes[..8] != MAGIC {
        return None;
    }
    let name_len = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let size = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
    let leaf_size = u64::from_le_bytes(bytes[20..28].try_into().unwrap());
    let digest_len = u32::from_le_bytes(bytes[28..32].try_into().unwrap()) as usize;
    if name_len > MAX_NAME || leaf_size == 0 || digest_len == 0 || digest_len > 128 {
        return None;
    }
    if bytes.len() < FIXED_HEADER + name_len {
        return None;
    }
    let name = std::str::from_utf8(&bytes[FIXED_HEADER..FIXED_HEADER + name_len]).ok()?;
    let tail = &bytes[FIXED_HEADER + name_len..];
    // Prefix-valid recovery: keep whole digests, drop a torn append, and
    // clip anything past the file's possible leaf count.
    let max_leaves = crate::merkle::leaf_count(size, leaf_size) as usize;
    let whole = (tail.len() / digest_len).min(max_leaves);
    Some(JournalRecord {
        name: name.to_string(),
        size,
        leaf_size,
        digest_len,
        leaves: tail[..whole * digest_len].to_vec(),
    })
}

// ---------------------------------------------------------------------------
// Per-file record writer
// ---------------------------------------------------------------------------

/// Appender for one file's journal record. Digests buffer in memory and
/// become durable only at [`FileJournal::checkpoint`] — callers sync the
/// data file *first*, so the journal never gets ahead of storage.
pub struct FileJournal {
    file: File,
    digest_len: usize,
    header_len: u64,
    /// Digests already appended and synced.
    synced_leaves: u64,
    /// Buffered digests awaiting the next checkpoint.
    pending: Vec<u8>,
}

impl FileJournal {
    /// Buffer one completed leaf digest (in leaf order).
    pub fn push_leaf(&mut self, digest: &[u8]) {
        assert_eq!(digest.len(), self.digest_len, "digest width mismatch");
        self.pending.extend_from_slice(digest);
    }

    /// Buffered digests not yet durable.
    pub fn pending_leaves(&self) -> u64 {
        (self.pending.len() / self.digest_len) as u64
    }

    /// Digests recorded so far (synced + pending).
    pub fn leaves_recorded(&self) -> u64 {
        self.synced_leaves + self.pending_leaves()
    }

    /// Make the buffered digests durable: one append + fsync. The caller
    /// must have synced the corresponding data-file bytes first (the
    /// crash-consistency ordering).
    pub fn checkpoint(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let at = self.header_len + self.synced_leaves * self.digest_len as u64;
        self.file.seek(SeekFrom::Start(at))?;
        self.file.write_all(&self.pending)?;
        self.file.sync_data().context("journal checkpoint sync")?;
        self.synced_leaves += self.pending_leaves();
        self.pending.clear();
        Ok(())
    }

    /// Replace an already-recorded leaf digest (repair patched its bytes).
    /// Synced digests rewrite in place; pending ones patch the buffer.
    /// The write becomes durable at the next [`FileJournal::checkpoint`].
    pub fn overwrite_leaf(&mut self, idx: u64, digest: &[u8]) -> Result<()> {
        anyhow::ensure!(digest.len() == self.digest_len, "digest width mismatch");
        anyhow::ensure!(idx < self.leaves_recorded(), "overwrite of unrecorded leaf {idx}");
        if idx < self.synced_leaves {
            self.file.seek(SeekFrom::Start(self.header_len + idx * self.digest_len as u64))?;
            self.file.write_all(digest)?;
        } else {
            let at = ((idx - self.synced_leaves) as usize) * self.digest_len;
            self.pending[at..at + self.digest_len].copy_from_slice(digest);
        }
        Ok(())
    }

    /// Force durability of in-place overwrites even when nothing is
    /// pending (checkpoint is a no-op then).
    pub fn sync(&mut self) -> Result<()> {
        self.file.sync_data().context("journal sync")?;
        Ok(())
    }
}

/// A file's journal record owned by its verification tree job: the job's
/// single hash pass over the queue feeds both the Merkle leaves and the
/// journal, so FIVER-Merkle and resumed files no longer pay a second
/// in-memory hash for journaling (the stream-side [`LeafTracker`] path
/// still serves policies that build no tree).
///
/// Durability ordering is preserved: `sync_data` (the destination file's
/// `fdatasync`, via `Storage::sync_file` — `None` on the read-only sender
/// side) runs before every journal checkpoint, and the job pushes only
/// leaves whose bytes it has already consumed *after* the receiver wrote
/// them to storage. The journal may *lag* the stream (it attests less,
/// never more), which is always safe for a watermark.
///
/// Checkpoint errors disable journaling for the file rather than failing
/// the hash job: the journal is a progress record, not a correctness
/// gate, and a missing checkpoint only costs resume coverage.
pub struct JournalFold {
    fj: FileJournal,
    checkpoint_leaves: u64,
    sync_data: Option<DataSync>,
    failed: bool,
}

impl JournalFold {
    /// Record one completed leaf digest; checkpoints (data sync, then
    /// journal append + fsync) at the configured cadence.
    pub fn push_leaf(&mut self, digest: &[u8]) {
        if self.failed {
            return;
        }
        self.fj.push_leaf(digest);
        if self.fj.pending_leaves() >= self.checkpoint_leaves {
            self.checkpoint();
        }
    }

    fn checkpoint(&mut self) {
        if self.failed {
            return;
        }
        let r = (|| -> Result<()> {
            if let Some(sync) = &self.sync_data {
                sync()?;
            }
            self.fj.checkpoint()
        })();
        if let Err(e) = r {
            eprintln!("warning: journal checkpoint failed, journaling stops for this file: {e:#}");
            self.failed = true;
        }
    }

    /// Final checkpoint at stream end (callers push the final partial
    /// leaf first — and only when the stream actually completed).
    pub fn finish(&mut self) {
        self.checkpoint();
    }
}

// ---------------------------------------------------------------------------
// Parsed record
// ---------------------------------------------------------------------------

/// A parsed journal record: the leaf digests of one file's delivered
/// prefix (all complete leaves, plus the final partial leaf once the
/// stream finished).
#[derive(Debug, Clone)]
pub struct JournalRecord {
    pub name: String,
    pub size: u64,
    pub leaf_size: u64,
    pub digest_len: usize,
    /// Concatenated leaf digests, `digest_len` stride.
    pub leaves: Vec<u8>,
}

impl JournalRecord {
    pub fn leaf_count(&self) -> u64 {
        (self.leaves.len() / self.digest_len) as u64
    }

    /// Does the record cover the whole file (every leaf, including the
    /// final partial one)?
    pub fn is_complete(&self) -> bool {
        self.leaf_count() >= crate::merkle::leaf_count(self.size, self.leaf_size)
    }

    /// Recorded leaves that are *complete* (span a full `leaf_size`) — the
    /// unit a mid-file resume can restart from.
    pub fn aligned_leaves(&self) -> u64 {
        self.leaf_count().min(self.size / self.leaf_size)
    }

    /// Byte watermark this record attests: the whole file when complete,
    /// else the complete-leaf-aligned prefix.
    pub fn watermark(&self) -> u64 {
        if self.is_complete() {
            self.size
        } else {
            self.aligned_leaves() * self.leaf_size
        }
    }

    /// Merkle root over the first `k_leaves` digests (a tree over a
    /// `prefix_bytes`-byte virtual file) — the handshake's prefix proof.
    /// Pure digest folding: no file bytes are read.
    pub fn prefix_root(
        &self,
        k_leaves: u64,
        prefix_bytes: u64,
        factory: &HasherFactory,
    ) -> Vec<u8> {
        let k = k_leaves as usize;
        assert!(k >= 1 && k * self.digest_len <= self.leaves.len(), "prefix out of range");
        let tree = MerkleTree::from_leaves(
            self.leaf_size,
            prefix_bytes,
            self.digest_len,
            self.leaves[..k * self.digest_len].to_vec(),
            factory,
        );
        tree.root().to_vec()
    }
}

// ---------------------------------------------------------------------------
// Streaming leaf hasher
// ---------------------------------------------------------------------------

/// Folds an in-order byte stream into leaf digests at `leaf_size`
/// granularity — the journal's twin of [`crate::merkle::MerkleBuilder`],
/// but emitting digests incrementally (so they can checkpoint mid-file)
/// and resumable from a completed-leaf count.
pub struct LeafTracker {
    leaf_size: u64,
    hasher: Box<dyn Hasher>,
    /// Bytes absorbed into the open leaf.
    filled: u64,
    /// Leaves completed so far (index of the open leaf).
    completed: u64,
}

impl LeafTracker {
    pub fn new(leaf_size: u64, factory: &HasherFactory) -> LeafTracker {
        LeafTracker::resume(leaf_size, factory, 0)
    }

    /// A tracker whose first `completed` leaves are already journaled
    /// (resume: hashing continues at the leaf boundary).
    pub fn resume(leaf_size: u64, factory: &HasherFactory, completed: u64) -> LeafTracker {
        assert!(leaf_size > 0, "leaf_size must be positive");
        LeafTracker { leaf_size, hasher: factory(), filled: 0, completed }
    }

    pub fn leaf_size(&self) -> u64 {
        self.leaf_size
    }

    pub fn completed_leaves(&self) -> u64 {
        self.completed
    }

    /// Bytes absorbed into the currently open (partial) leaf.
    pub fn filled(&self) -> u64 {
        self.filled
    }

    /// Stream position: completed leaves plus the open partial leaf.
    pub fn position(&self) -> u64 {
        self.completed * self.leaf_size + self.filled
    }

    /// Absorb in-order bytes; `on_leaf(idx, digest)` fires per completed
    /// leaf.
    pub fn update(&mut self, mut data: &[u8], mut on_leaf: impl FnMut(u64, Vec<u8>)) {
        while !data.is_empty() {
            let take = ((self.leaf_size - self.filled) as usize).min(data.len());
            self.hasher.update(&data[..take]);
            self.filled += take as u64;
            data = &data[take..];
            if self.filled == self.leaf_size {
                let d = self.hasher.finalize();
                self.hasher.reset();
                self.filled = 0;
                on_leaf(self.completed, d);
                self.completed += 1;
            }
        }
    }

    /// Close the stream: emit the final partial leaf, or the single empty
    /// leaf of an empty stream that never emitted anything.
    pub fn finish(&mut self, mut on_leaf: impl FnMut(u64, Vec<u8>)) {
        if self.filled > 0 || self.completed == 0 {
            let d = self.hasher.finalize();
            self.hasher.reset();
            self.filled = 0;
            on_leaf(self.completed, d);
            self.completed += 1;
        }
    }

    /// Rebuild the open leaf's hasher state from `prefix` — the bytes of
    /// the current leaf up to the stream position, re-read from storage
    /// after a repair rewrote part of them (at most one leaf per file).
    pub fn rebuild_partial(&mut self, prefix: &[u8]) {
        assert!((prefix.len() as u64) < self.leaf_size, "partial rebuild spans a whole leaf");
        self.hasher.reset();
        self.hasher.update(prefix);
        self.filled = prefix.len() as u64;
    }
}

// ---------------------------------------------------------------------------
// Resume plan + handshake
// ---------------------------------------------------------------------------

/// One file's negotiated resume state (this endpoint's own view).
#[derive(Debug, Clone)]
pub struct ResumedFile {
    /// First byte the tail stream covers; `== size` for a file whose full
    /// delivery was verified at handshake (skipped outright).
    pub offset: u64,
    pub size: u64,
    /// Journaled leaf digests covering `[0, offset)` — this endpoint's own
    /// copy, proved root-equal to the peer's at handshake. Seeds the
    /// resumed file's verification tree (digest width comes from the
    /// session's hasher, checked compatible at the handshake).
    pub leaves: Vec<u8>,
}

/// The negotiated outcome of a resume handshake: per-file restart offsets
/// and prefix leaves. Empty when resuming was not requested or nothing
/// matched.
#[derive(Debug, Clone, Default)]
pub struct ResumePlan {
    pub files: std::collections::HashMap<u32, ResumedFile>,
}

impl ResumePlan {
    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }

    pub fn get(&self, file_idx: u32) -> Option<&ResumedFile> {
        self.files.get(&file_idx)
    }

    /// The file's agreed *partial* resume state (`None` for fresh files,
    /// fully-skipped files, or a size disagreement) — the single source
    /// of the tail-eligibility predicate, shared by sender and receiver
    /// so the two endpoints can never diverge on what "resumed" means.
    pub fn partial_for(&self, file_idx: u32, size: u64) -> Option<&ResumedFile> {
        self.files.get(&file_idx).filter(|r| r.offset > 0 && r.offset < size && r.size == size)
    }

    /// Agreed restart offset for a file (`None` = transfer from scratch).
    pub fn offset_for(&self, file_idx: u32) -> Option<u64> {
        self.files.get(&file_idx).map(|r| r.offset)
    }

    /// Was this file fully delivered and verified at handshake?
    pub fn is_complete(&self, file_idx: u32) -> bool {
        self.files.get(&file_idx).map(|r| r.offset == r.size).unwrap_or(false)
    }

    /// Files skipped outright (complete at handshake).
    pub fn skipped_files(&self) -> u64 {
        self.files.values().filter(|r| r.offset == r.size).count() as u64
    }

    /// Bytes the resumed run does not re-send (sum of agreed offsets).
    pub fn skipped_bytes(&self) -> u64 {
        self.files.values().map(|r| r.offset).sum()
    }
}

/// Leaf count of a valid resume offset, or `None` when the offset cannot
/// anchor a resume (zero, misaligned, or past the file).
fn prefix_leaves_for(offset: u64, size: u64, leaf_size: u64) -> Option<u64> {
    if offset == size {
        Some(crate::merkle::leaf_count(size, leaf_size))
    } else if offset > 0 && offset < size && offset % leaf_size == 0 {
        Some(offset / leaf_size)
    } else {
        None
    }
}

/// Receiver side of the resume handshake, on the dedicated resume control
/// connection (its `Hello` already consumed by the accept loop): offer
/// every compatible journal record, verify the sender's counter-offered
/// prefix roots against our own leaves, and issue verdicts. Rejected
/// records are dropped from the journal (full re-transfer).
pub fn negotiate_receiver<S: Read + Write>(
    sock: &mut S,
    journal: Option<&Journal>,
    cfg: &SessionConfig,
    storage: &Arc<dyn Storage>,
) -> Result<ResumePlan> {
    let dlen = (cfg.hasher)().digest_len();
    let records = match journal {
        Some(j) => j.load_all()?,
        None => BTreeMap::new(),
    };
    let mut offered: BTreeMap<u32, (JournalRecord, u64)> = BTreeMap::new();
    for (idx, rec) in records {
        if rec.leaf_size != cfg.leaf_size || rec.digest_len != dlen {
            continue; // journaled under a different configuration
        }
        let wm = rec.watermark();
        // The destination must still hold the journaled prefix.
        if storage.size_of(&rec.name).unwrap_or(0) < wm {
            continue;
        }
        Frame::ResumeOffer {
            file_idx: idx,
            watermark: wm,
            leaf_size: rec.leaf_size,
            name: rec.name.clone(),
        }
        .write_to(sock)?;
        offered.insert(idx, (rec, wm));
    }
    Frame::Done.write_to(sock)?;
    sock.flush()?;

    let mut acks: Vec<(u32, u64, Vec<u8>)> = Vec::new();
    loop {
        let f = Frame::read_from(sock)?.context("resume channel closed awaiting acks")?;
        match f {
            Frame::ResumeAck { file_idx, offset, digest } => acks.push((file_idx, offset, digest)),
            Frame::Done => break,
            other => bail!("expected ResumeAck on resume channel, got {other:?}"),
        }
    }

    let mut plan = ResumePlan::default();
    for (idx, offset, digest) in acks {
        let Some((rec, wm)) = offered.get(&idx) else {
            bail!("resume ack for unoffered file {idx}");
        };
        let k = prefix_leaves_for(offset, rec.size, rec.leaf_size)
            .filter(|&k| offset <= *wm && k <= rec.leaf_count());
        // Only a *failed root comparison* proves the checkpoint divergent;
        // a decline (empty digest: sender has no/stale journal) or an
        // invalid offset must not cost us a record that correctly attests
        // delivered bytes — a later, correctly-configured resume can
        // still use it.
        let mut divergent = false;
        let ok = match k {
            Some(k) if !digest.is_empty() => {
                let equal = rec.prefix_root(k, offset, &cfg.hasher) == digest;
                divergent = !equal;
                equal
            }
            _ => false,
        };
        Frame::Verdict { file_idx: idx, unit: UNIT_FILE, ok }.write_to(sock)?;
        if ok {
            let k = k.expect("checked above") as usize;
            plan.files.insert(
                idx,
                ResumedFile {
                    offset,
                    size: rec.size,
                    leaves: rec.leaves[..k * rec.digest_len].to_vec(),
                },
            );
        } else if divergent {
            if let Some(j) = journal {
                // Proven divergence: discard; the file re-transfers from
                // scratch and the record is recreated at its FileStart.
                j.remove(idx);
            }
        }
    }
    Frame::Done.write_to(sock)?;
    sock.flush()?;
    Ok(plan)
}

/// Sender side of the resume handshake: read the receiver's offers, reply
/// with the longest common complete-leaf prefix and its root over our own
/// journaled leaves (empty digest = declined), then collect verdicts.
pub fn negotiate_sender<S: Read + Write>(
    sock: &mut S,
    journal: Option<&Journal>,
    cfg: &SessionConfig,
    names: &[String],
    sizes: &[u64],
) -> Result<ResumePlan> {
    let dlen = (cfg.hasher)().digest_len();
    let records = match journal {
        Some(j) => j.load_all()?,
        None => BTreeMap::new(),
    };
    let mut offers: Vec<(u32, u64, u64, String)> = Vec::new();
    loop {
        let f = Frame::read_from(sock)?.context("resume channel closed awaiting offers")?;
        match f {
            Frame::ResumeOffer { file_idx, watermark, leaf_size, name } => {
                offers.push((file_idx, watermark, leaf_size, name));
            }
            Frame::Done => break,
            other => bail!("expected ResumeOffer on resume channel, got {other:?}"),
        }
    }

    let mut candidates: BTreeMap<u32, ResumedFile> = BTreeMap::new();
    for (idx, watermark, leaf_size, name) in offers {
        let mut ack_offset = 0u64;
        let mut digest = Vec::new();
        let known = leaf_size == cfg.leaf_size
            && (idx as usize) < names.len()
            && names[idx as usize] == name;
        if known {
            let size = sizes[idx as usize];
            if let Some(rec) = records.get(&idx) {
                // digest_len must match too: folding differently-sized
                // digests through the session hasher would produce an
                // ill-formed root that reads as *divergence* on the
                // receiver (costing it a valid record) instead of as the
                // stale-configuration decline it really is.
                let compatible = rec.name == name
                    && rec.size == size
                    && rec.leaf_size == leaf_size
                    && rec.digest_len == dlen
                    && watermark <= size;
                if compatible {
                    // Longest common prefix: the shorter journal wins; a
                    // full skip needs both records complete.
                    let (offset, k) = if watermark == size && rec.is_complete() {
                        (size, crate::merkle::leaf_count(size, leaf_size))
                    } else {
                        let k = rec.aligned_leaves().min(watermark / leaf_size);
                        (k * leaf_size, k)
                    };
                    let valid = prefix_leaves_for(offset, size, leaf_size)
                        .map(|kk| kk == k && k <= rec.leaf_count())
                        .unwrap_or(false);
                    if valid {
                        digest = rec.prefix_root(k, offset, &cfg.hasher);
                        ack_offset = offset;
                        candidates.insert(
                            idx,
                            ResumedFile {
                                offset,
                                size,
                                leaves: rec.leaves[..k as usize * rec.digest_len].to_vec(),
                            },
                        );
                    }
                }
            }
        }
        Frame::ResumeAck { file_idx: idx, offset: ack_offset, digest }.write_to(sock)?;
    }
    Frame::Done.write_to(sock)?;
    sock.flush()?;

    let mut plan = ResumePlan::default();
    loop {
        let f = Frame::read_from(sock)?.context("resume channel closed awaiting verdicts")?;
        match f {
            Frame::Verdict { file_idx, ok, .. } => {
                if ok {
                    if let Some(rf) = candidates.remove(&file_idx) {
                        plan.files.insert(file_idx, rf);
                    }
                }
            }
            Frame::Done => break,
            other => bail!("expected Verdict on resume channel, got {other:?}"),
        }
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::native_factory;
    use crate::coordinator::RealAlgorithm;
    use crate::hashes::HashAlgorithm;
    use crate::merkle::MerkleBuilder;
    use crate::storage::MemStorage;
    use crate::util::tmpdir::TempDir;

    fn factory() -> HasherFactory {
        native_factory(HashAlgorithm::Md5)
    }

    fn cfg_with(leaf: u64) -> SessionConfig {
        let mut cfg = SessionConfig::new(RealAlgorithm::Fiver, factory());
        cfg.leaf_size = leaf;
        cfg
    }

    /// Journal `data` through a tracker, checkpointing every leaf.
    fn record_stream(j: &Journal, idx: u32, name: &str, data: &[u8], leaf: u64, finish: bool) {
        let f = factory();
        let dlen = f().digest_len();
        let mut fj = j.create(idx, name, data.len() as u64, leaf, dlen).unwrap();
        let mut tr = LeafTracker::new(leaf, &f);
        tr.update(data, |_, d| fj.push_leaf(&d));
        if finish {
            tr.finish(|_, d| fj.push_leaf(&d));
        }
        fj.checkpoint().unwrap();
    }

    #[test]
    fn record_roundtrip_and_watermarks() {
        let dir = TempDir::create("fiver-jrnl").unwrap();
        let j = Journal::open(dir.path()).unwrap();
        let data: Vec<u8> = (0u8..=255).cycle().take(2500).collect();
        // Complete record: 2 full leaves + 1 partial at leaf 1000.
        record_stream(&j, 0, "a/b.bin", &data, 1000, true);
        let rec = j.load(0).unwrap().unwrap();
        assert_eq!(rec.name, "a/b.bin");
        assert_eq!(rec.size, 2500);
        assert_eq!(rec.leaf_count(), 3);
        assert!(rec.is_complete());
        assert_eq!(rec.aligned_leaves(), 2);
        assert_eq!(rec.watermark(), 2500);
        // Partial record: only whole leaves journaled.
        record_stream(&j, 1, "c", &data, 1000, false);
        let rec = j.load(1).unwrap().unwrap();
        assert_eq!(rec.leaf_count(), 2);
        assert!(!rec.is_complete());
        assert_eq!(rec.watermark(), 2000);
        assert_eq!(j.load_all().unwrap().len(), 2);
        // Missing record.
        assert!(j.load(9).unwrap().is_none());
        j.remove(0);
        assert!(j.load(0).unwrap().is_none());
    }

    #[test]
    fn torn_tail_truncates_torn_header_invalidates() {
        let dir = TempDir::create("fiver-jrnl").unwrap();
        let j = Journal::open(dir.path()).unwrap();
        let data = vec![7u8; 3000];
        record_stream(&j, 0, "t", &data, 1000, false);
        let path = dir.path().join("f000000.fjl");
        // Torn append: garbage partial digest at the end.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0xAB; 5]);
        std::fs::write(&path, &bytes).unwrap();
        let rec = j.load(0).unwrap().unwrap();
        assert_eq!(rec.leaf_count(), 3, "torn tail drops to the last whole digest");
        // Torn header: record is invalid, not garbage.
        std::fs::write(&path, &bytes[..10]).unwrap();
        assert!(j.load(0).unwrap().is_none());
        // Wrong magic.
        std::fs::write(&path, b"NOTAJRNLxxxxxxxxxxxxxxxxxxxxxxxxxxxx").unwrap();
        assert!(j.load(0).unwrap().is_none());
    }

    #[test]
    fn tracker_matches_merkle_builder() {
        let f = factory();
        let data: Vec<u8> = (0u8..200).cycle().take(10_123).collect();
        let mut b = MerkleBuilder::new(512, f.clone());
        for part in data.chunks(333) {
            b.update(part);
        }
        let tree = b.finish();
        let mut leaves = Vec::new();
        let mut tr = LeafTracker::new(512, &f);
        for part in data.chunks(777) {
            tr.update(part, |_, d| leaves.extend_from_slice(&d));
        }
        tr.finish(|_, d| leaves.extend_from_slice(&d));
        assert_eq!(tr.completed_leaves() as usize, tree.leaf_count());
        let rebuilt =
            MerkleTree::from_leaves(512, data.len() as u64, tree.digest_len(), leaves, &f);
        assert_eq!(rebuilt.root(), tree.root());
        // Empty stream: one empty leaf.
        let mut empty = LeafTracker::new(512, &f);
        let mut n = 0;
        empty.finish(|_, _| n += 1);
        assert_eq!(n, 1);
        assert_eq!(empty.position(), 0);
    }

    #[test]
    fn tracker_resume_continues_at_leaf_boundary() {
        let f = factory();
        let data = vec![9u8; 4096];
        let mut full = Vec::new();
        let mut tr = LeafTracker::new(1024, &f);
        tr.update(&data, |_, d| full.extend_from_slice(&d));
        // Resume after 2 leaves: the tail produces the same digests.
        let mut tail = Vec::new();
        let mut tr2 = LeafTracker::resume(1024, &f, 2);
        assert_eq!(tr2.position(), 2048);
        tr2.update(&data[2048..], |i, d| {
            assert!(i >= 2);
            tail.extend_from_slice(&d);
        });
        let dlen = f().digest_len();
        assert_eq!(&full[2 * dlen..], &tail[..]);
    }

    #[test]
    fn open_resumed_truncates_and_appends() {
        let dir = TempDir::create("fiver-jrnl").unwrap();
        let j = Journal::open(dir.path()).unwrap();
        let data = vec![3u8; 4000];
        record_stream(&j, 0, "r", &data, 1000, false); // 4 leaves
        let f = factory();
        let dlen = f().digest_len();
        let mut fj = j.open_resumed(0, 2).unwrap();
        assert_eq!(fj.leaves_recorded(), 2);
        // Re-append leaves 2 and 3 (as the resumed stream would).
        let mut tr = LeafTracker::resume(1000, &f, 2);
        tr.update(&data[2000..], |_, d| fj.push_leaf(&d));
        fj.checkpoint().unwrap();
        let rec = j.load(0).unwrap().unwrap();
        assert_eq!(rec.leaf_count(), 4);
        // The re-appended digests equal the originals.
        let fresh = {
            let mut leaves = Vec::new();
            let mut t = LeafTracker::new(1000, &f);
            t.update(&data, |_, d| leaves.extend_from_slice(&d));
            leaves
        };
        assert_eq!(rec.leaves, fresh);
        assert_eq!(dlen * 4, rec.leaves.len());
    }

    #[test]
    fn overwrite_and_patch_leaves() {
        let dir = TempDir::create("fiver-jrnl").unwrap();
        let j = Journal::open(dir.path()).unwrap();
        let data = vec![1u8; 3000];
        record_stream(&j, 0, "p", &data, 1000, true);
        // Patch leaf 1 via the closed-record path.
        let f = factory();
        let patched: Vec<u8> = {
            let mut h = f();
            h.update(&[0xEE; 1000]);
            h.finalize()
        };
        let p2 = patched.clone();
        j.patch_record(0, &[(1500, 10)], move |off, len| {
            assert_eq!((off, len), (1000, 1000));
            Ok(p2.clone())
        })
        .unwrap();
        let rec = j.load(0).unwrap().unwrap();
        assert_eq!(&rec.leaves[rec.digest_len..2 * rec.digest_len], &patched[..]);
        // Zero-length ranges and out-of-record leaves are ignored.
        j.patch_record(0, &[(2999, 0)], |_, _| panic!("no leaf touched")).unwrap();
        assert!(leaves_touched(&[(5000, 100)], 1000, 3).is_empty());
        assert_eq!(leaves_touched(&[(999, 2)], 1000, 3), vec![0, 1]);
    }

    #[test]
    fn prefix_root_matches_stream_tree() {
        let dir = TempDir::create("fiver-jrnl").unwrap();
        let j = Journal::open(dir.path()).unwrap();
        let f = factory();
        let data: Vec<u8> = (0u8..=255).cycle().take(5000).collect();
        record_stream(&j, 0, "x", &data, 1000, false);
        let rec = j.load(0).unwrap().unwrap();
        // Root over the first 3 leaves == a builder over the first 3000 B.
        let got = rec.prefix_root(3, 3000, &f);
        let mut b = MerkleBuilder::new(1000, f.clone());
        b.update(&data[..3000]);
        assert_eq!(got, b.finish().root());
    }

    #[test]
    fn handshake_agrees_on_common_prefix() {
        let dir = TempDir::create("fiver-hs").unwrap();
        let sdir = dir.join("snd");
        let rdir = dir.join("rcv");
        let sj = Journal::open(&sdir).unwrap();
        let rj = Journal::open(&rdir).unwrap();
        let data: Vec<u8> = (0u8..=255).cycle().take(10_000).collect();
        let leaf = 1000u64;
        // Records carry the *full* source size; leaves cover the streamed
        // prefix. file 0: receiver journaled 6 leaves, sender only 4 ->
        // the common prefix is the sender's 4000 bytes.
        let partial = |j: &Journal, idx: u32, name: &str, size: u64, bytes: &[u8]| {
            let f = factory();
            let dlen = f().digest_len();
            let mut fj = j.create(idx, name, size, leaf, dlen).unwrap();
            let mut tr = LeafTracker::new(leaf, &f);
            tr.update(bytes, |_, d| fj.push_leaf(&d));
            fj.checkpoint().unwrap();
        };
        partial(&rj, 0, "f0", 10_000, &data[..6000]);
        partial(&sj, 0, "f0", 10_000, &data[..4000]);
        // file 1: both complete -> skipped outright.
        record_stream(&rj, 1, "f1", &data[..2500], leaf, true);
        record_stream(&sj, 1, "f1", &data[..2500], leaf, true);
        // file 2: receiver journal diverges (different bytes) -> rejected.
        partial(&rj, 2, "f2", 3000, &[0xAA; 3000]);
        partial(&sj, 2, "f2", 3000, &data[..3000]);
        // file 3: receiver-only record -> the sender declines; the record
        // must survive (a decline is not divergence).
        partial(&rj, 3, "f3", 4000, &data[..2000]);

        let cfg = cfg_with(leaf);
        let names: Vec<String> = vec!["f0".into(), "f1".into(), "f2".into(), "f3".into()];
        let sizes: Vec<u64> = vec![10_000, 2500, 3000, 4000];
        // Destination holds at least each record's watermark.
        let dst = MemStorage::new();
        dst.put("f0", data[..6000].to_vec());
        dst.put("f1", data[..2500].to_vec());
        dst.put("f2", vec![0xAA; 3000]);
        dst.put("f3", data[..2000].to_vec());
        let storage: Arc<dyn Storage> = Arc::new(dst);

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let rcfg = cfg.clone();
        let recv = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            negotiate_receiver(&mut sock, Some(&rj), &rcfg, &storage).unwrap()
        });
        let mut sock = std::net::TcpStream::connect(addr).unwrap();
        let splan = negotiate_sender(&mut sock, Some(&sj), &cfg, &names, &sizes).unwrap();
        let rplan = recv.join().unwrap();

        for plan in [&splan, &rplan] {
            assert_eq!(plan.offset_for(0), Some(4000), "common prefix = sender's 4 leaves");
            assert_eq!(plan.offset_for(1), Some(2500), "both complete -> full skip");
            assert!(plan.is_complete(1));
            assert_eq!(plan.offset_for(2), None, "divergent prefix rejected");
            assert_eq!(plan.offset_for(3), None, "declined offer resumes nothing");
            assert_eq!(plan.skipped_files(), 1);
            assert_eq!(plan.skipped_bytes(), 4000 + 2500);
        }
        // Both sides hold root-equal prefix leaves for file 0.
        let s0 = splan.get(0).unwrap();
        let r0 = rplan.get(0).unwrap();
        assert_eq!(s0.leaves, r0.leaves);
        assert_eq!(s0.size, 10_000);
        // Only *proven divergence* costs a record: file 2 was dropped,
        // the merely-declined file 3 survives for a later resume.
        let rj = Journal::open(&rdir).unwrap();
        assert!(rj.load(2).unwrap().is_none());
        assert!(rj.load(3).unwrap().is_some(), "declined record must survive");
        assert!(rj.load(0).unwrap().is_some());
    }

    #[test]
    fn handshake_with_no_journals_is_empty() {
        let cfg = cfg_with(1024);
        let storage: Arc<dyn Storage> = Arc::new(MemStorage::new());
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let rcfg = cfg.clone();
        let recv = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            negotiate_receiver(&mut sock, None, &rcfg, &storage).unwrap()
        });
        let mut sock = std::net::TcpStream::connect(addr).unwrap();
        let splan = negotiate_sender(&mut sock, None, &cfg, &["a".into()], &[100]).unwrap();
        assert!(splan.is_empty());
        assert!(recv.join().unwrap().is_empty());
    }

    #[test]
    fn prefix_leaf_geometry() {
        assert_eq!(prefix_leaves_for(0, 0, 64), Some(1), "empty file skips via its one leaf");
        assert_eq!(prefix_leaves_for(128, 128, 64), Some(2), "exact-multiple full skip");
        assert_eq!(prefix_leaves_for(100, 100, 64), Some(2), "partial-leaf full skip");
        assert_eq!(prefix_leaves_for(64, 100, 64), Some(1));
        assert_eq!(prefix_leaves_for(0, 100, 64), None, "offset 0 = no resume");
        assert_eq!(prefix_leaves_for(65, 100, 64), None, "misaligned");
        assert_eq!(prefix_leaves_for(200, 100, 64), None, "past the file");
    }
}
