//! Checkpoint journal + resume handshake — crash-recoverable transfers.
//!
//! A production transfer service must survive a process kill mid-dataset
//! without re-hashing and re-sending everything. This module records
//! engine progress durably on *both* endpoints and lets a restarted
//! sender/receiver pair negotiate per-file restart offsets:
//!
//! * Each endpoint folds the in-order byte stream of every file through a
//!   [`LeafTracker`] — a streaming leaf hasher at the session's Merkle
//!   leaf granularity (`SessionConfig::leaf_size`), independent of which
//!   verification policy the transfer runs. Completed leaf digests append
//!   to a per-file [`FileJournal`] record.
//! * Records are **append-only and prefix-valid**: a fixed binary header
//!   followed by fixed-stride leaf digests. Recovery parses the header and
//!   keeps `floor((len - header) / digest_len)` digests — a torn append
//!   truncates to the last whole digest, a torn header invalidates the
//!   record (full re-transfer), and no state is ever rewritten in place
//!   except explicit repair patches. Durability ordering at a checkpoint
//!   is *data before journal*: the receiver syncs the destination file,
//!   then appends + syncs the journal, so a journaled watermark never
//!   claims bytes the storage could have lost.
//! * On restart, the receiver offers `(name, watermark)` per journaled
//!   record; the sender counter-offers the longest common complete-leaf
//!   prefix together with its Merkle root over its *own* journaled leaves
//!   ([`negotiate_sender`]); the receiver folds its leaves to the same
//!   root and issues a verdict ([`negotiate_receiver`]). Equal roots mean
//!   the prefix already delivered matches the source **without re-reading
//!   a single prefix byte on either side**; a mismatch falls back to full
//!   re-transfer of that file. Agreed files re-enter the scheduler as
//!   their unfinished tail only; fully-delivered files whose complete
//!   roots match are skipped outright.
//! * A resumed file is verified end-to-end by the journal's digest tree
//!   regardless of the session algorithm: both endpoints seed a
//!   [`crate::merkle::MerkleBuilder`] with the agreed prefix leaves and
//!   fold the tail from their queues, then run the existing
//!   `TreeRoot`/descent exchange — so tail corruption repairs at leaf
//!   granularity, exactly like FIVER-Merkle.
//!
//! Records are **name-keyed** (journal v2): a record file is named by a
//! hash of the file's path, and the authoritative name lives inside the
//! record — so resume and delta survive a changed file list (renames and
//! insertions shift dataset indices, never names). v2 records also store
//! a 32-bit rolling weak sum next to each strong leaf digest, which is
//! exactly the per-leaf signature the delta handshake
//! ([`negotiate_delta_receiver`]) serves for free. Legacy v1 records
//! (strong digests only, historically one per dataset index) still parse
//! and resume; they simply cannot seed a delta basis from the journal.
//!
//! To scale to million-file datasets the journal also keeps an
//! **append-only segment file** (`segment.fjs`): [`Journal::compact`]
//! folds every per-file record into one length-prefixed segment and
//! deletes the per-file files, so a quiescent journal is a single file.
//! Per-file records written after a compaction override the segment copy
//! for their name; a torn segment tail is dropped at the last whole
//! frame, exactly like a torn record tail.
//!
//! See DESIGN.md "Checkpoint journal & crash recovery" for the v1 record
//! format and crash-consistency argument, and "Delta sync & journal v2"
//! for the v2/segment formats and compatibility rules.

use std::collections::{BTreeMap, HashMap};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::delta::{DeltaBasis, DeltaPlan, Rolling32, WEAK_LEN};
use super::protocol::{Frame, UNIT_FILE};
use super::{HasherFactory, SessionConfig};
use crate::hashes::Hasher;
use crate::merkle::MerkleTree;
use crate::storage::Storage;

/// Record magic, v1 (8 bytes): strong leaf digests only.
const MAGIC_V1: &[u8; 8] = b"FVRJNL01";

/// Record magic, v2: each leaf entry is a 32-bit rolling weak sum
/// followed by the strong digest.
const MAGIC_V2: &[u8; 8] = b"FVRJNL02";

/// Segment-file magic: `SEG_MAGIC` then repeated `[len: u32 LE][record]`
/// frames, each framing one complete record (either version).
const SEG_MAGIC: &[u8; 8] = b"FVRJSG02";

/// Cap on one file's delta-signature payload (stays safely under the
/// frame decoder's 64 MiB payload limit). Basis leaves past the cap are
/// simply not offered; their spans re-transfer in full.
const MAX_SIG_BYTES: usize = 48 << 20;

/// Data-sync callback a [`JournalFold`] runs before each checkpoint —
/// `Storage::sync_file` on the receiver (fdatasync the destination
/// inode), `None` on the read-only sender side.
pub type DataSync = Box<dyn Fn() -> Result<()> + Send>;

/// Fixed part of the record header: magic + name_len(u32) + size(u64) +
/// leaf_size(u64) + digest_len(u32).
const FIXED_HEADER: usize = 8 + 4 + 8 + 8 + 4;

/// Upper bound on journaled file names (defensive parse limit).
const MAX_NAME: usize = 4096;

// ---------------------------------------------------------------------------
// Journal directory
// ---------------------------------------------------------------------------

/// One endpoint's journal: a directory of name-keyed per-file records
/// plus an optional compacted segment file. Lookup is by file *name* —
/// dataset indices shift when the file list changes between runs, names
/// do not.
#[derive(Clone)]
pub struct Journal {
    dir: PathBuf,
}

/// FNV-1a over a file name — the stable, path-safe key a record file is
/// named by. A (vanishingly rare) collision makes two names share one
/// record slot; the loser parses to a mismatched embedded name, reads as
/// "no checkpoint", and simply re-transfers in full.
fn fnv64(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in name.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

impl Journal {
    /// Open (creating if needed) a journal directory.
    pub fn open(dir: &Path) -> Result<Journal> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating journal dir {}", dir.display()))?;
        Ok(Journal { dir: dir.to_path_buf() })
    }

    /// The journal's directory on disk.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Where `name`'s per-file record lives (`r<fnv64(name)>.fjl`).
    pub fn record_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("r{:016x}.fjl", fnv64(name)))
    }

    /// The compacted segment file (`segment.fjs`).
    pub fn segment_path(&self) -> PathBuf {
        self.dir.join("segment.fjs")
    }

    /// Start a fresh v2 record for `name` (truncating any stale one).
    pub fn create(
        &self,
        name: &str,
        size: u64,
        leaf_size: u64,
        digest_len: usize,
    ) -> Result<FileJournal> {
        anyhow::ensure!(leaf_size > 0 && digest_len > 0, "bad journal geometry");
        anyhow::ensure!(name.len() <= MAX_NAME, "file name too long to journal");
        let mut header = Vec::with_capacity(FIXED_HEADER + name.len());
        header.extend_from_slice(MAGIC_V2);
        header.extend_from_slice(&(name.len() as u32).to_le_bytes());
        header.extend_from_slice(&size.to_le_bytes());
        header.extend_from_slice(&leaf_size.to_le_bytes());
        header.extend_from_slice(&(digest_len as u32).to_le_bytes());
        header.extend_from_slice(name.as_bytes());
        let path = self.record_path(name);
        let mut file = File::create(&path)
            .with_context(|| format!("creating journal record {}", path.display()))?;
        file.write_all(&header)?;
        file.sync_data().context("journal header sync")?;
        Ok(FileJournal {
            file,
            digest_len,
            stride: WEAK_LEN + digest_len,
            header_len: header.len() as u64,
            synced_leaves: 0,
            pending: Vec::new(),
        })
    }

    /// Reopen `name`'s record for a resumed file, keeping the agreed
    /// `keep_leaves` entries (the negotiated common prefix) and
    /// discarding everything past them; appends continue from there as
    /// the resumed stream flows. The kept prefix is rewritten to the
    /// name-keyed path, which also upgrades records found in legacy
    /// index-keyed files or the segment (a record upgraded from v1 stays
    /// v1 — it has no weak sums to carry).
    pub fn open_resumed(&self, name: &str, keep_leaves: u64) -> Result<FileJournal> {
        let rec = self
            .find(name)?
            .with_context(|| format!("no journal record to resume for {name}"))?;
        let keep = keep_leaves.min(rec.leaf_count()) as usize;
        let v2 = rec.has_weaks();
        let bytes = encode_record(&rec, keep, v2);
        let path = self.record_path(name);
        let mut file = File::options()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("rewriting journal record {}", path.display()))?;
        file.write_all(&bytes)?;
        file.sync_data().context("journal truncate sync")?;
        Ok(FileJournal {
            file,
            digest_len: rec.digest_len,
            stride: if v2 { WEAK_LEN + rec.digest_len } else { rec.digest_len },
            header_len: (FIXED_HEADER + rec.name.len()) as u64,
            synced_leaves: keep as u64,
            pending: Vec::new(),
        })
    }

    /// Parse `name`'s per-file record; `None` when absent or invalid
    /// (torn header, unknown magic, or a hash-collision slot holding a
    /// different name — recovery treats all three as "no checkpoint").
    pub fn load(&self, name: &str) -> Result<Option<JournalRecord>> {
        let bytes = match std::fs::read(self.record_path(name)) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e).context("reading journal record"),
        };
        Ok(parse_record(&bytes).filter(|r| r.name == name))
    }

    /// [`Journal::load`] extended to the segment and legacy index-keyed
    /// files — the resume path's lookup, since a record may live in any
    /// of the three places.
    pub fn find(&self, name: &str) -> Result<Option<JournalRecord>> {
        if let Some(rec) = self.load(name)? {
            return Ok(Some(rec));
        }
        Ok(self.load_all()?.remove(name))
    }

    /// Every parseable record, keyed by the name embedded in the record:
    /// the segment's frames first (last occurrence per name wins), then
    /// every `*.fjl` file (per-file records override the segment).
    pub fn load_all(&self) -> Result<BTreeMap<String, JournalRecord>> {
        let mut out = self.load_segment();
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
            Err(e) => return Err(e).context("reading journal dir"),
        };
        for entry in entries {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("fjl") {
                continue;
            }
            if let Ok(bytes) = std::fs::read(&path) {
                if let Some(rec) = parse_record(&bytes) {
                    out.insert(rec.name.clone(), rec);
                }
            }
        }
        Ok(out)
    }

    /// Parse the segment file into its per-name records (empty when
    /// absent or unrecognized). A torn tail keeps the valid frame prefix.
    fn load_segment(&self) -> BTreeMap<String, JournalRecord> {
        let mut out = BTreeMap::new();
        let Ok(bytes) = std::fs::read(self.segment_path()) else { return out };
        if bytes.len() < 8 || &bytes[..8] != SEG_MAGIC {
            return out;
        }
        let mut at = 8usize;
        while bytes.len() - at >= 4 {
            let len = u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()) as usize;
            at += 4;
            if len == 0 || len > bytes.len() - at {
                break; // torn tail: keep the frames before it
            }
            if let Some(rec) = parse_record(&bytes[at..at + len]) {
                out.insert(rec.name.clone(), rec);
            }
            at += len;
        }
        out
    }

    /// Write `records` as a fresh segment (tmp file + atomic rename, so
    /// a crash leaves either the old segment or the new one).
    fn write_segment(&self, records: &BTreeMap<String, JournalRecord>) -> Result<()> {
        let tmp = self.dir.join("segment.fjs.tmp");
        let mut buf = Vec::new();
        buf.extend_from_slice(SEG_MAGIC);
        for rec in records.values() {
            let body = encode_record(rec, rec.leaf_count() as usize, rec.has_weaks());
            buf.extend_from_slice(&(body.len() as u32).to_le_bytes());
            buf.extend_from_slice(&body);
        }
        let mut f = File::create(&tmp).context("creating segment tmp")?;
        f.write_all(&buf)?;
        f.sync_data().context("segment sync")?;
        std::fs::rename(&tmp, self.segment_path()).context("segment rename")?;
        Ok(())
    }

    /// Fold every per-file record into one deduplicated segment and
    /// delete the per-file files — after a completed run the journal is
    /// a single file regardless of dataset size. Crash-safe: the segment
    /// replaces atomically, and per-file files deleted late merely
    /// override the identical segment copy until the next compaction.
    pub fn compact(&self) -> Result<()> {
        let all = self.load_all()?;
        if !all.is_empty() {
            self.write_segment(&all)?;
        }
        if let Ok(entries) = std::fs::read_dir(&self.dir) {
            for entry in entries.flatten() {
                let path = entry.path();
                if path.extension().and_then(|e| e.to_str()) == Some("fjl") {
                    std::fs::remove_file(&path).ok();
                }
            }
        }
        Ok(())
    }

    /// Drop `name`'s record everywhere it may live (stale / rejected at
    /// handshake): the name-keyed file, any legacy index-keyed file
    /// carrying the name, and the segment copy. Best-effort.
    pub fn remove(&self, name: &str) {
        let keyed = self.record_path(name);
        std::fs::remove_file(&keyed).ok();
        if let Ok(entries) = std::fs::read_dir(&self.dir) {
            for entry in entries.flatten() {
                let path = entry.path();
                if path == keyed || path.extension().and_then(|e| e.to_str()) != Some("fjl") {
                    continue;
                }
                if let Ok(bytes) = std::fs::read(&path) {
                    if parse_record(&bytes).map(|r| r.name == name).unwrap_or(false) {
                        std::fs::remove_file(&path).ok();
                    }
                }
            }
        }
        let mut seg = self.load_segment();
        if seg.remove(name).is_some() {
            self.write_segment(&seg).ok();
        }
    }

    /// Open-or-create the record for one file as its stream begins: a
    /// resumed file (`start_at > 0`) truncates its record to the agreed
    /// complete-leaf prefix and continues from there; a fresh file starts
    /// a new record. Single-sourced so sender and receiver compute
    /// identical journal state (keep-leaves rounding included).
    pub fn begin_record(
        &self,
        name: &str,
        size: u64,
        start_at: u64,
        cfg: &SessionConfig,
    ) -> Result<FileJournal> {
        if start_at > 0 {
            self.open_resumed(name, start_at / cfg.leaf_size)
        } else {
            self.create(name, size, cfg.leaf_size, cfg.leaf_len())
        }
    }

    /// [`Journal::begin_record`] plus a [`LeafTracker`] positioned to
    /// continue it — the stream-side journaling pair (non-tree files,
    /// where the stream thread itself folds leaves).
    pub fn begin_file(
        &self,
        name: &str,
        size: u64,
        start_at: u64,
        cfg: &SessionConfig,
    ) -> Result<(FileJournal, LeafTracker)> {
        let fj = self.begin_record(name, size, start_at, cfg)?;
        let leaf = cfg.leaf_factory();
        let tracker = if start_at > 0 {
            LeafTracker::resume(cfg.leaf_size, &leaf, start_at / cfg.leaf_size)
        } else {
            LeafTracker::new(cfg.leaf_size, &leaf)
        };
        Ok((fj, tracker))
    }

    /// [`Journal::begin_record`] wrapped for the verification tree job
    /// ([`JournalFold`]): FIVER-Merkle and resumed files journal from the
    /// hash job's single pass instead of paying a second in-memory hash
    /// on the stream thread. `sync_data` runs before every checkpoint
    /// (the data-before-journal ordering); `None` on the sender, whose
    /// source is read-only.
    pub fn begin_fold(
        &self,
        name: &str,
        size: u64,
        start_at: u64,
        cfg: &SessionConfig,
        sync_data: Option<DataSync>,
    ) -> Result<JournalFold> {
        let fj = self.begin_record(name, size, start_at, cfg)?;
        Ok(JournalFold {
            fj,
            checkpoint_leaves: cfg.journal_checkpoint_leaves.max(1),
            sync_data,
            failed: false,
        })
    }

    /// Patch a (possibly closed) record after repair `Fix` frames rewrote
    /// byte `ranges` of the file: every journaled leaf the ranges touch
    /// is recomputed via `recompute(offset, len)` (a storage re-hash of
    /// at most the touched leaves, yielding the strong digest and rolling
    /// weak sum) and overwritten in place, then synced. A crash mid-patch
    /// at worst tears one entry, which fails the next resume handshake
    /// closed (full re-transfer). Only the name-keyed per-file record is
    /// patched — a segment-only copy is from a prior run, and the current
    /// run always writes a per-file record that overrides it.
    pub fn patch_record(
        &self,
        name: &str,
        ranges: &[(u64, u64)],
        mut recompute: impl FnMut(u64, u64) -> Result<(Vec<u8>, u32)>,
    ) -> Result<()> {
        let Some(rec) = self.load(name)? else { return Ok(()) };
        let dirty = leaves_touched(ranges, rec.leaf_size, rec.leaf_count());
        if dirty.is_empty() {
            return Ok(());
        }
        let v2 = rec.has_weaks();
        let stride = if v2 { WEAK_LEN + rec.digest_len } else { rec.digest_len } as u64;
        let mut file = OpenOptions::new().write(true).open(self.record_path(name))?;
        let header_len = (FIXED_HEADER + rec.name.len()) as u64;
        for l in dirty {
            let loff = l * rec.leaf_size;
            let llen = rec.leaf_size.min(rec.size.saturating_sub(loff));
            let (d, w) = recompute(loff, llen)?;
            if d.len() != rec.digest_len {
                // The record was written under a different hash tier (its
                // digest stride no longer matches the session's). Patching
                // in place would corrupt every later entry's offset, so
                // decline: drop the stale record — the next transfer simply
                // re-journals from scratch instead of resuming.
                drop(file);
                self.remove(name);
                return Ok(());
            }
            file.seek(SeekFrom::Start(header_len + l * stride))?;
            if v2 {
                file.write_all(&w.to_le_bytes())?;
            }
            file.write_all(&d)?;
        }
        file.sync_data().context("journal patch sync")?;
        Ok(())
    }
}

/// Leaf indices (`< recorded`) whose spans intersect any of `ranges` —
/// shared by the closed-record patch path and the receiver's open-file
/// repair path, so the range→leaf mapping cannot diverge.
pub(crate) fn leaves_touched(ranges: &[(u64, u64)], leaf_size: u64, recorded: u64) -> Vec<u64> {
    let mut dirty: Vec<u64> = Vec::new();
    if recorded == 0 {
        return dirty;
    }
    for &(off, len) in ranges {
        if len == 0 {
            continue;
        }
        let first = off / leaf_size;
        let last = (off + len - 1) / leaf_size;
        for l in first..=last.min(recorded - 1) {
            dirty.push(l);
        }
    }
    dirty.sort_unstable();
    dirty.dedup();
    dirty
}

fn parse_record(bytes: &[u8]) -> Option<JournalRecord> {
    if bytes.len() < FIXED_HEADER {
        return None;
    }
    let v2 = match &bytes[..8] {
        m if m == MAGIC_V2 => true,
        m if m == MAGIC_V1 => false,
        _ => return None,
    };
    let name_len = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let size = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
    let leaf_size = u64::from_le_bytes(bytes[20..28].try_into().unwrap());
    let digest_len = u32::from_le_bytes(bytes[28..32].try_into().unwrap()) as usize;
    if name_len > MAX_NAME || leaf_size == 0 || digest_len == 0 || digest_len > 128 {
        return None;
    }
    if bytes.len() < FIXED_HEADER + name_len {
        return None;
    }
    let name = std::str::from_utf8(&bytes[FIXED_HEADER..FIXED_HEADER + name_len]).ok()?;
    let tail = &bytes[FIXED_HEADER + name_len..];
    // Prefix-valid recovery: keep whole entries, drop a torn append, and
    // clip anything past the file's possible leaf count.
    let stride = if v2 { WEAK_LEN + digest_len } else { digest_len };
    let max_leaves = crate::merkle::leaf_count(size, leaf_size) as usize;
    let whole = (tail.len() / stride).min(max_leaves);
    let mut leaves = Vec::with_capacity(whole * digest_len);
    let mut weaks = Vec::new();
    if v2 {
        weaks.reserve(whole);
        for entry in tail[..whole * stride].chunks_exact(stride) {
            weaks.push(u32::from_le_bytes(entry[..WEAK_LEN].try_into().unwrap()));
            leaves.extend_from_slice(&entry[WEAK_LEN..]);
        }
    } else {
        leaves.extend_from_slice(&tail[..whole * digest_len]);
    }
    Some(JournalRecord { name: name.to_string(), size, leaf_size, digest_len, leaves, weaks })
}

/// Serialize the first `keep` leaf entries of `rec` as a standalone
/// record (v2 `[weak][strong]` entries when `with_weaks`, else v1).
/// Requires `keep <= rec.leaf_count()` and, with weaks, that the record
/// carries them.
fn encode_record(rec: &JournalRecord, keep: usize, with_weaks: bool) -> Vec<u8> {
    let dlen = rec.digest_len;
    let stride = if with_weaks { WEAK_LEN + dlen } else { dlen };
    let mut out = Vec::with_capacity(FIXED_HEADER + rec.name.len() + keep * stride);
    out.extend_from_slice(if with_weaks { MAGIC_V2 } else { MAGIC_V1 });
    out.extend_from_slice(&(rec.name.len() as u32).to_le_bytes());
    out.extend_from_slice(&rec.size.to_le_bytes());
    out.extend_from_slice(&rec.leaf_size.to_le_bytes());
    out.extend_from_slice(&(dlen as u32).to_le_bytes());
    out.extend_from_slice(rec.name.as_bytes());
    for i in 0..keep {
        if with_weaks {
            out.extend_from_slice(&rec.weaks[i].to_le_bytes());
        }
        out.extend_from_slice(&rec.leaves[i * dlen..(i + 1) * dlen]);
    }
    out
}

// ---------------------------------------------------------------------------
// Per-file record writer
// ---------------------------------------------------------------------------

/// Appender for one file's journal record. Entries buffer in memory and
/// become durable only at [`FileJournal::checkpoint`] — callers sync the
/// data file *first*, so the journal never gets ahead of storage.
pub struct FileJournal {
    file: File,
    digest_len: usize,
    /// Bytes one journaled leaf entry occupies: weak + strong digest for
    /// a v2 record, strong only for one upgraded from legacy v1.
    stride: usize,
    header_len: u64,
    /// Entries already appended and synced.
    synced_leaves: u64,
    /// Buffered entries awaiting the next checkpoint.
    pending: Vec<u8>,
}

impl FileJournal {
    /// Buffer one completed leaf entry (in leaf order): the strong digest
    /// plus its rolling weak sum (dropped on a v1-format record).
    pub fn push_leaf(&mut self, digest: &[u8], weak: u32) {
        assert_eq!(digest.len(), self.digest_len, "digest width mismatch");
        if self.stride > self.digest_len {
            self.pending.extend_from_slice(&weak.to_le_bytes());
        }
        self.pending.extend_from_slice(digest);
    }

    /// Buffered entries not yet durable.
    pub fn pending_leaves(&self) -> u64 {
        (self.pending.len() / self.stride) as u64
    }

    /// Digests recorded so far (synced + pending).
    pub fn leaves_recorded(&self) -> u64 {
        self.synced_leaves + self.pending_leaves()
    }

    /// Make the buffered digests durable: one append + fsync. The caller
    /// must have synced the corresponding data-file bytes first (the
    /// crash-consistency ordering).
    pub fn checkpoint(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let at = self.header_len + self.synced_leaves * self.stride as u64;
        self.file.seek(SeekFrom::Start(at))?;
        self.file.write_all(&self.pending)?;
        self.file.sync_data().context("journal checkpoint sync")?;
        self.synced_leaves += self.pending_leaves();
        self.pending.clear();
        Ok(())
    }

    /// Replace an already-recorded leaf entry (repair patched its bytes).
    /// Synced entries rewrite in place; pending ones patch the buffer.
    /// The write becomes durable at the next [`FileJournal::checkpoint`].
    pub fn overwrite_leaf(&mut self, idx: u64, digest: &[u8], weak: u32) -> Result<()> {
        anyhow::ensure!(digest.len() == self.digest_len, "digest width mismatch");
        anyhow::ensure!(idx < self.leaves_recorded(), "overwrite of unrecorded leaf {idx}");
        let with_weak = self.stride > self.digest_len;
        if idx < self.synced_leaves {
            self.file.seek(SeekFrom::Start(self.header_len + idx * self.stride as u64))?;
            if with_weak {
                self.file.write_all(&weak.to_le_bytes())?;
            }
            self.file.write_all(digest)?;
        } else {
            let mut at = ((idx - self.synced_leaves) as usize) * self.stride;
            if with_weak {
                self.pending[at..at + WEAK_LEN].copy_from_slice(&weak.to_le_bytes());
                at += WEAK_LEN;
            }
            self.pending[at..at + self.digest_len].copy_from_slice(digest);
        }
        Ok(())
    }

    /// Force durability of in-place overwrites even when nothing is
    /// pending (checkpoint is a no-op then).
    pub fn sync(&mut self) -> Result<()> {
        self.file.sync_data().context("journal sync")?;
        Ok(())
    }
}

/// A file's journal record owned by its verification tree job: the job's
/// single hash pass over the queue feeds both the Merkle leaves and the
/// journal, so FIVER-Merkle and resumed files no longer pay a second
/// in-memory hash for journaling (the stream-side [`LeafTracker`] path
/// still serves policies that build no tree).
///
/// Durability ordering is preserved: `sync_data` (the destination file's
/// `fdatasync`, via `Storage::sync_file` — `None` on the read-only sender
/// side) runs before every journal checkpoint, and the job pushes only
/// leaves whose bytes it has already consumed *after* the receiver wrote
/// them to storage. The journal may *lag* the stream (it attests less,
/// never more), which is always safe for a watermark.
///
/// Checkpoint errors disable journaling for the file rather than failing
/// the hash job: the journal is a progress record, not a correctness
/// gate, and a missing checkpoint only costs resume coverage.
pub struct JournalFold {
    fj: FileJournal,
    checkpoint_leaves: u64,
    sync_data: Option<DataSync>,
    failed: bool,
}

impl JournalFold {
    /// Record one completed leaf entry; checkpoints (data sync, then
    /// journal append + fsync) at the configured cadence.
    pub fn push_leaf(&mut self, digest: &[u8], weak: u32) {
        if self.failed {
            return;
        }
        self.fj.push_leaf(digest, weak);
        if self.fj.pending_leaves() >= self.checkpoint_leaves {
            self.checkpoint();
        }
    }

    fn checkpoint(&mut self) {
        if self.failed {
            return;
        }
        let r = (|| -> Result<()> {
            if let Some(sync) = &self.sync_data {
                sync()?;
            }
            self.fj.checkpoint()
        })();
        if let Err(e) = r {
            eprintln!("warning: journal checkpoint failed, journaling stops for this file: {e:#}");
            self.failed = true;
        }
    }

    /// Final checkpoint at stream end (callers push the final partial
    /// leaf first — and only when the stream actually completed).
    pub fn finish(&mut self) {
        self.checkpoint();
    }
}

// ---------------------------------------------------------------------------
// Parsed record
// ---------------------------------------------------------------------------

/// A parsed journal record: the leaf digests of one file's delivered
/// prefix (all complete leaves, plus the final partial leaf once the
/// stream finished).
#[derive(Debug, Clone)]
pub struct JournalRecord {
    /// The file's dataset-relative name (the record's key).
    pub name: String,
    /// Full source size in bytes.
    pub size: u64,
    /// Merkle leaf granularity the digests were folded at.
    pub leaf_size: u64,
    /// Width of one strong digest.
    pub digest_len: usize,
    /// Concatenated strong leaf digests, `digest_len` stride.
    pub leaves: Vec<u8>,
    /// Rolling weak sums, one per leaf (empty for legacy v1 records).
    pub weaks: Vec<u32>,
}

impl JournalRecord {
    /// Leaf entries the record holds.
    pub fn leaf_count(&self) -> u64 {
        (self.leaves.len() / self.digest_len) as u64
    }

    /// Does every recorded leaf carry its rolling weak sum (v2)? Only
    /// such records can seed a delta basis without re-reading data.
    pub fn has_weaks(&self) -> bool {
        self.leaf_count() > 0 && self.weaks.len() as u64 == self.leaf_count()
    }

    /// The record's delta-signature payload (`[weak][strong]` per *full*
    /// leaf, capped at `max_leaves`), or `None` when the record carries
    /// no weak sums. A trailing partial leaf is excluded — it cannot
    /// anchor a window match.
    pub fn sig_payload(&self, max_leaves: u64) -> Option<Vec<u8>> {
        let n = self.aligned_leaves().min(max_leaves) as usize;
        if n == 0 || self.weaks.len() < n {
            return None;
        }
        let dlen = self.digest_len;
        let mut out = Vec::with_capacity(n * (WEAK_LEN + dlen));
        for i in 0..n {
            out.extend_from_slice(&self.weaks[i].to_le_bytes());
            out.extend_from_slice(&self.leaves[i * dlen..(i + 1) * dlen]);
        }
        Some(out)
    }

    /// Does the record cover the whole file (every leaf, including the
    /// final partial one)?
    pub fn is_complete(&self) -> bool {
        self.leaf_count() >= crate::merkle::leaf_count(self.size, self.leaf_size)
    }

    /// Recorded leaves that are *complete* (span a full `leaf_size`) — the
    /// unit a mid-file resume can restart from.
    pub fn aligned_leaves(&self) -> u64 {
        self.leaf_count().min(self.size / self.leaf_size)
    }

    /// Byte watermark this record attests: the whole file when complete,
    /// else the complete-leaf-aligned prefix.
    pub fn watermark(&self) -> u64 {
        if self.is_complete() {
            self.size
        } else {
            self.aligned_leaves() * self.leaf_size
        }
    }

    /// Merkle root over the first `k_leaves` digests (a tree over a
    /// `prefix_bytes`-byte virtual file) — the handshake's prefix proof.
    /// Pure digest folding: no file bytes are read. `node_factory` and
    /// `rooted` describe the session's tree shape (see
    /// [`SessionConfig::node_factory`] and [`SessionConfig::tree_rooted`])
    /// so prefix roots match what the live pipeline would build.
    pub fn prefix_root(
        &self,
        k_leaves: u64,
        prefix_bytes: u64,
        node_factory: &HasherFactory,
        rooted: bool,
    ) -> Vec<u8> {
        let k = k_leaves as usize;
        assert!(k >= 1 && k * self.digest_len <= self.leaves.len(), "prefix out of range");
        let tree = MerkleTree::from_leaves(
            self.leaf_size,
            prefix_bytes,
            self.digest_len,
            self.leaves[..k * self.digest_len].to_vec(),
            node_factory,
            rooted,
        );
        tree.root().to_vec()
    }
}

// ---------------------------------------------------------------------------
// Streaming leaf hasher
// ---------------------------------------------------------------------------

/// Folds an in-order byte stream into leaf digests at `leaf_size`
/// granularity — the journal's twin of [`crate::merkle::MerkleBuilder`],
/// but emitting digests incrementally (so they can checkpoint mid-file)
/// and resumable from a completed-leaf count.
pub struct LeafTracker {
    leaf_size: u64,
    hasher: Box<dyn Hasher>,
    /// Rolling weak sum of the open leaf (journal v2 records one per
    /// leaf, which is what the delta handshake later serves as a basis).
    weak: Rolling32,
    /// Bytes absorbed into the open leaf.
    filled: u64,
    /// Leaves completed so far (index of the open leaf).
    completed: u64,
}

impl LeafTracker {
    /// A tracker positioned at the start of a stream.
    ///
    /// ```
    /// use fiver::coordinator::journal::LeafTracker;
    /// use fiver::coordinator::native_factory;
    /// use fiver::hashes::HashAlgorithm;
    ///
    /// let factory = native_factory(HashAlgorithm::Md5);
    /// let mut tracker = LeafTracker::new(4, &factory);
    /// let mut leaves = Vec::new();
    /// tracker.update(b"abcdefgh", |idx, digest, weak| leaves.push((idx, digest, weak)));
    /// tracker.finish(|idx, digest, weak| leaves.push((idx, digest, weak)));
    /// assert_eq!(leaves.len(), 2); // "abcd" and "efgh", nothing partial
    /// assert_eq!(leaves[0].0, 0);
    /// assert_eq!(leaves[1].0, 1);
    /// ```
    pub fn new(leaf_size: u64, factory: &HasherFactory) -> LeafTracker {
        LeafTracker::resume(leaf_size, factory, 0)
    }

    /// A tracker whose first `completed` leaves are already journaled
    /// (resume: hashing continues at the leaf boundary).
    pub fn resume(leaf_size: u64, factory: &HasherFactory, completed: u64) -> LeafTracker {
        assert!(leaf_size > 0, "leaf_size must be positive");
        LeafTracker {
            leaf_size,
            hasher: factory(),
            weak: Rolling32::new(),
            filled: 0,
            completed,
        }
    }

    /// Leaf granularity the tracker folds at.
    pub fn leaf_size(&self) -> u64 {
        self.leaf_size
    }

    /// Leaves completed so far (index of the open leaf).
    pub fn completed_leaves(&self) -> u64 {
        self.completed
    }

    /// Bytes absorbed into the currently open (partial) leaf.
    pub fn filled(&self) -> u64 {
        self.filled
    }

    /// Stream position: completed leaves plus the open partial leaf.
    pub fn position(&self) -> u64 {
        self.completed * self.leaf_size + self.filled
    }

    /// Absorb in-order bytes; `on_leaf(idx, digest, weak)` fires per
    /// completed leaf with its strong digest and rolling weak sum.
    pub fn update(&mut self, mut data: &[u8], mut on_leaf: impl FnMut(u64, Vec<u8>, u32)) {
        while !data.is_empty() {
            let take = ((self.leaf_size - self.filled) as usize).min(data.len());
            self.hasher.update(&data[..take]);
            self.weak.update(&data[..take]);
            self.filled += take as u64;
            data = &data[take..];
            if self.filled == self.leaf_size {
                let d = self.hasher.finalize();
                self.hasher.reset();
                let w = self.weak.digest();
                self.weak.reset();
                self.filled = 0;
                on_leaf(self.completed, d, w);
                self.completed += 1;
            }
        }
    }

    /// Close the stream: emit the final partial leaf, or the single empty
    /// leaf of an empty stream that never emitted anything.
    pub fn finish(&mut self, mut on_leaf: impl FnMut(u64, Vec<u8>, u32)) {
        if self.filled > 0 || self.completed == 0 {
            let d = self.hasher.finalize();
            self.hasher.reset();
            let w = self.weak.digest();
            self.weak.reset();
            self.filled = 0;
            on_leaf(self.completed, d, w);
            self.completed += 1;
        }
    }

    /// Rebuild the open leaf's hasher state from `prefix` — the bytes of
    /// the current leaf up to the stream position, re-read from storage
    /// after a repair rewrote part of them (at most one leaf per file).
    pub fn rebuild_partial(&mut self, prefix: &[u8]) {
        assert!((prefix.len() as u64) < self.leaf_size, "partial rebuild spans a whole leaf");
        self.hasher.reset();
        self.hasher.update(prefix);
        self.weak.reset();
        self.weak.update(prefix);
        self.filled = prefix.len() as u64;
    }
}

// ---------------------------------------------------------------------------
// Resume plan + handshake
// ---------------------------------------------------------------------------

/// One file's negotiated resume state (this endpoint's own view).
#[derive(Debug, Clone)]
pub struct ResumedFile {
    /// First byte the tail stream covers; `== size` for a file whose full
    /// delivery was verified at handshake (skipped outright).
    pub offset: u64,
    /// Total size in bytes of the journaled file.
    pub size: u64,
    /// Journaled leaf digests covering `[0, offset)` — this endpoint's own
    /// copy, proved root-equal to the peer's at handshake. Seeds the
    /// resumed file's verification tree (digest width comes from the
    /// session's hasher, checked compatible at the handshake).
    pub leaves: Vec<u8>,
}

/// The negotiated outcome of a resume handshake: per-file restart offsets
/// and prefix leaves, keyed by file *name* (the journal's key — dataset
/// indices are not stable across a changed file list). Empty when
/// resuming was not requested or nothing matched.
#[derive(Debug, Clone, Default)]
pub struct ResumePlan {
    /// file name → negotiated resume state.
    pub files: HashMap<String, ResumedFile>,
}

impl ResumePlan {
    /// Nothing resumed.
    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }

    /// The file's negotiated state, if any.
    pub fn get(&self, name: &str) -> Option<&ResumedFile> {
        self.files.get(name)
    }

    /// The file's agreed *partial* resume state (`None` for fresh files,
    /// fully-skipped files, or a size disagreement) — the single source
    /// of the tail-eligibility predicate, shared by sender and receiver
    /// so the two endpoints can never diverge on what "resumed" means.
    pub fn partial_for(&self, name: &str, size: u64) -> Option<&ResumedFile> {
        self.files.get(name).filter(|r| r.offset > 0 && r.offset < size && r.size == size)
    }

    /// Agreed restart offset for a file (`None` = transfer from scratch).
    pub fn offset_for(&self, name: &str) -> Option<u64> {
        self.files.get(name).map(|r| r.offset)
    }

    /// Was this file fully delivered and verified at handshake?
    pub fn is_complete(&self, name: &str) -> bool {
        self.files.get(name).map(|r| r.offset == r.size).unwrap_or(false)
    }

    /// Files skipped outright (complete at handshake).
    pub fn skipped_files(&self) -> u64 {
        self.files.values().filter(|r| r.offset == r.size).count() as u64
    }

    /// Bytes the resumed run does not re-send (sum of agreed offsets).
    pub fn skipped_bytes(&self) -> u64 {
        self.files.values().map(|r| r.offset).sum()
    }
}

/// Leaf count of a valid resume offset, or `None` when the offset cannot
/// anchor a resume (zero, misaligned, or past the file).
fn prefix_leaves_for(offset: u64, size: u64, leaf_size: u64) -> Option<u64> {
    if offset == size {
        Some(crate::merkle::leaf_count(size, leaf_size))
    } else if offset > 0 && offset < size && offset % leaf_size == 0 {
        Some(offset / leaf_size)
    } else {
        None
    }
}

/// Receiver side of the resume handshake, on the dedicated resume control
/// connection (its `Hello` already consumed by the accept loop): offer
/// every compatible journal record, verify the sender's counter-offered
/// prefix roots against our own leaves, and issue verdicts. Rejected
/// records are dropped from the journal (full re-transfer).
pub fn negotiate_receiver<S: Read + Write>(
    sock: &mut S,
    journal: Option<&Journal>,
    cfg: &SessionConfig,
    storage: &Arc<dyn Storage>,
) -> Result<ResumePlan> {
    let dlen = cfg.leaf_len();
    let records = match journal {
        Some(j) => j.load_all()?,
        None => BTreeMap::new(),
    };
    // Offers ride a receiver-local ordinal in the frames' `file_idx`
    // field — records are name-keyed, so no shared dataset index exists.
    // The ordinal only associates each ack/verdict with its offer.
    let mut offered: Vec<(String, JournalRecord, u64)> = Vec::new();
    for (name, rec) in records {
        if rec.leaf_size != cfg.leaf_size || rec.digest_len != dlen {
            continue; // journaled under a different configuration
        }
        let wm = rec.watermark();
        // The destination must still hold the journaled prefix.
        if storage.size_of(&name).unwrap_or(0) < wm {
            continue;
        }
        Frame::ResumeOffer {
            file_idx: offered.len() as u32,
            watermark: wm,
            leaf_size: rec.leaf_size,
            name: name.clone(),
        }
        .write_to(sock)?;
        offered.push((name, rec, wm));
    }
    Frame::Done.write_to(sock)?;
    sock.flush()?;

    let mut acks: Vec<(u32, u64, Vec<u8>)> = Vec::new();
    loop {
        let f = Frame::read_from(sock)?.context("resume channel closed awaiting acks")?;
        match f {
            Frame::ResumeAck { file_idx, offset, digest } => acks.push((file_idx, offset, digest)),
            Frame::Done => break,
            other => bail!("expected ResumeAck on resume channel, got {other:?}"),
        }
    }

    let node_factory = cfg.node_factory();
    let mut plan = ResumePlan::default();
    for (ord, offset, digest) in acks {
        let Some((name, rec, wm)) = offered.get(ord as usize) else {
            bail!("resume ack for unoffered ordinal {ord}");
        };
        let k = prefix_leaves_for(offset, rec.size, rec.leaf_size)
            .filter(|&k| offset <= *wm && k <= rec.leaf_count());
        // Only a *failed root comparison* proves the checkpoint divergent;
        // a decline (empty digest: sender has no/stale journal) or an
        // invalid offset must not cost us a record that correctly attests
        // delivered bytes — a later, correctly-configured resume can
        // still use it.
        let mut divergent = false;
        let ok = match k {
            Some(k) if !digest.is_empty() => {
                let equal =
                    rec.prefix_root(k, offset, &node_factory, cfg.tree_rooted()) == digest;
                divergent = !equal;
                equal
            }
            _ => false,
        };
        Frame::Verdict { file_idx: ord, unit: UNIT_FILE, ok }.write_to(sock)?;
        if ok {
            let k = k.expect("checked above") as usize;
            plan.files.insert(
                name.clone(),
                ResumedFile {
                    offset,
                    size: rec.size,
                    leaves: rec.leaves[..k * rec.digest_len].to_vec(),
                },
            );
        } else if divergent {
            if let Some(j) = journal {
                // Proven divergence: discard; the file re-transfers from
                // scratch and the record is recreated at its FileStart.
                j.remove(name);
            }
        }
    }
    Frame::Done.write_to(sock)?;
    sock.flush()?;
    Ok(plan)
}

/// Sender side of the resume handshake: read the receiver's offers, reply
/// with the longest common complete-leaf prefix and its root over our own
/// journaled leaves (empty digest = declined), then collect verdicts.
pub fn negotiate_sender<S: Read + Write>(
    sock: &mut S,
    journal: Option<&Journal>,
    cfg: &SessionConfig,
    names: &[String],
    sizes: &[u64],
) -> Result<ResumePlan> {
    let dlen = cfg.leaf_len();
    let records = match journal {
        Some(j) => j.load_all()?,
        None => BTreeMap::new(),
    };
    // Offers match the *current* file list by name — a rename or
    // reordering between runs shifts indices, never names.
    let by_name: HashMap<&str, usize> =
        names.iter().enumerate().map(|(i, n)| (n.as_str(), i)).collect();
    let mut offers: Vec<(u32, u64, u64, String)> = Vec::new();
    loop {
        let f = Frame::read_from(sock)?.context("resume channel closed awaiting offers")?;
        match f {
            Frame::ResumeOffer { file_idx, watermark, leaf_size, name } => {
                offers.push((file_idx, watermark, leaf_size, name));
            }
            Frame::Done => break,
            other => bail!("expected ResumeOffer on resume channel, got {other:?}"),
        }
    }

    let node_factory = cfg.node_factory();
    let mut candidates: HashMap<u32, (String, ResumedFile)> = HashMap::new();
    for (ord, watermark, leaf_size, name) in offers {
        let mut ack_offset = 0u64;
        let mut digest = Vec::new();
        if leaf_size == cfg.leaf_size {
            if let Some(&idx) = by_name.get(name.as_str()) {
                if let Some(c) = records.get(&name).and_then(|rec| {
                    resume_candidate(
                        rec,
                        sizes[idx],
                        watermark,
                        leaf_size,
                        dlen,
                        &node_factory,
                        cfg.tree_rooted(),
                    )
                }) {
                    let (offset, root, rf) = c;
                    ack_offset = offset;
                    digest = root;
                    candidates.insert(ord, (name.clone(), rf));
                }
            }
        }
        Frame::ResumeAck { file_idx: ord, offset: ack_offset, digest }.write_to(sock)?;
    }
    Frame::Done.write_to(sock)?;
    sock.flush()?;

    let mut plan = ResumePlan::default();
    loop {
        let f = Frame::read_from(sock)?.context("resume channel closed awaiting verdicts")?;
        match f {
            Frame::Verdict { file_idx, ok, .. } => {
                if ok {
                    if let Some((name, rf)) = candidates.remove(&file_idx) {
                        plan.files.insert(name, rf);
                    }
                }
            }
            Frame::Done => break,
            other => bail!("expected Verdict on resume channel, got {other:?}"),
        }
    }
    Ok(plan)
}

/// The sender's counter-offer for one compatible record: the longest
/// common complete-leaf prefix (the shorter journal wins; a full skip
/// needs both records complete), its root over our own journaled leaves,
/// and the resulting resume state. `None` when the record is stale or
/// incompatible — declined, which the receiver must not read as
/// divergence. digest_len must match too: folding differently-sized
/// digests through the session hasher would produce an ill-formed root
/// that reads as *divergence* on the receiver (costing it a valid
/// record) instead of the stale-configuration decline it really is.
fn resume_candidate(
    rec: &JournalRecord,
    size: u64,
    watermark: u64,
    leaf_size: u64,
    dlen: usize,
    node_factory: &HasherFactory,
    rooted: bool,
) -> Option<(u64, Vec<u8>, ResumedFile)> {
    let compatible = rec.size == size
        && rec.leaf_size == leaf_size
        && rec.digest_len == dlen
        && watermark <= size;
    if !compatible {
        return None;
    }
    let (offset, k) = if watermark == size && rec.is_complete() {
        (size, crate::merkle::leaf_count(size, leaf_size))
    } else {
        let k = rec.aligned_leaves().min(watermark / leaf_size);
        (k * leaf_size, k)
    };
    let valid = prefix_leaves_for(offset, size, leaf_size)
        .map(|kk| kk == k && k <= rec.leaf_count())
        .unwrap_or(false);
    if !valid {
        return None;
    }
    let digest = rec.prefix_root(k, offset, node_factory, rooted);
    let leaves = rec.leaves[..k as usize * rec.digest_len].to_vec();
    Some((offset, digest, ResumedFile { offset, size, leaves }))
}

// ---------------------------------------------------------------------------
// Delta handshake
// ---------------------------------------------------------------------------

/// Receiver side of the delta handshake, on the dedicated delta control
/// connection (its `Hello` with [`super::protocol::DELTA_SESSION`]
/// already consumed by the accept loop): for every `DeltaReq` the sender
/// lists, answer a `DeltaSig` with per-leaf `(weak, strong)` signatures
/// of whatever basis this endpoint holds for the name — served for free
/// from a compatible complete v2 journal record, else computed by
/// reading the existing destination data, else empty (decline: the file
/// transfers in full). The receiver retains no state: reconstruction
/// later reads the old bytes straight from storage by name, and the
/// Merkle verification pass backstops a basis that was stale or lying.
pub fn negotiate_delta_receiver<S: Read + Write>(
    sock: &mut S,
    journal: Option<&Journal>,
    cfg: &SessionConfig,
    storage: &Arc<dyn Storage>,
) -> Result<()> {
    let dlen = cfg.leaf_len();
    let max_leaves = (MAX_SIG_BYTES / (WEAK_LEN + dlen)) as u64;
    let records = match journal {
        Some(j) => j.load_all()?,
        None => BTreeMap::new(),
    };
    let mut reqs: Vec<(u32, String)> = Vec::new();
    loop {
        let f = Frame::read_from(sock)?.context("delta channel closed awaiting requests")?;
        match f {
            Frame::DeltaReq { file_idx, name, .. } => reqs.push((file_idx, name)),
            Frame::Done => break,
            other => bail!("expected DeltaReq on delta channel, got {other:?}"),
        }
    }
    for (ord, name) in reqs {
        let (basis_size, sigs) =
            delta_sigs_for(records.get(&name), &name, cfg, dlen, max_leaves, storage);
        Frame::DeltaSig { file_idx: ord, basis_size, sigs }.write_to(sock)?;
    }
    Frame::Done.write_to(sock)?;
    sock.flush()?;
    Ok(())
}

/// The receiver's basis signatures for one requested name: `(old size,
/// payload)`, where an empty payload declines. The journaled fast path
/// requires a complete v2 record whose geometry matches the session and
/// whose size matches the bytes actually on disk; anything else falls
/// back to a read+hash of the destination's full leaves.
fn delta_sigs_for(
    rec: Option<&JournalRecord>,
    name: &str,
    cfg: &SessionConfig,
    dlen: usize,
    max_leaves: u64,
    storage: &Arc<dyn Storage>,
) -> (u64, Vec<u8>) {
    let leaf = cfg.leaf_size;
    let Ok(old_size) = storage.size_of(name) else {
        return (0, Vec::new()); // no destination file: nothing to offer
    };
    if old_size < leaf {
        return (old_size, Vec::new()); // no full leaf can anchor a match
    }
    if let Some(rec) = rec {
        let fresh = rec.leaf_size == leaf
            && rec.digest_len == dlen
            && rec.is_complete()
            && rec.size == old_size;
        if fresh {
            if let Some(sigs) = rec.sig_payload(max_leaves) {
                return (old_size, sigs);
            }
        }
    }
    match sigs_from_storage(storage, name, old_size, leaf, &cfg.leaf_factory(), max_leaves) {
        Ok(sigs) => (old_size, sigs),
        Err(_) => (old_size, Vec::new()), // unreadable basis: decline
    }
}

/// Read the destination's full leaves and fold each into its `(weak,
/// strong)` signature — the no-journal basis path (one sequential read
/// of the old data, the cost rsync's receiver pays).
fn sigs_from_storage(
    storage: &Arc<dyn Storage>,
    name: &str,
    old_size: u64,
    leaf: u64,
    factory: &HasherFactory,
    max_leaves: u64,
) -> Result<Vec<u8>> {
    let n = (old_size / leaf).min(max_leaves);
    let mut rs = storage.open_read(name)?;
    let mut hasher = factory();
    let dlen = hasher.digest_len();
    let mut out = Vec::with_capacity(n as usize * (WEAK_LEN + dlen));
    let mut buf = vec![0u8; leaf as usize];
    for i in 0..n {
        let off = i * leaf;
        let mut got = 0usize;
        while got < buf.len() {
            let k = rs.read_at(off + got as u64, &mut buf[got..])?;
            anyhow::ensure!(k > 0, "short read hashing delta basis for {name}");
            got += k;
        }
        hasher.reset();
        hasher.update(&buf);
        let strong = hasher.finalize();
        out.extend_from_slice(&Rolling32::of(&buf).to_le_bytes());
        out.extend_from_slice(&strong);
    }
    Ok(out)
}

/// Sender side of the delta handshake: request a basis for every file
/// that could possibly reuse one (at least one leaf long), then collect
/// the receiver's signatures into a [`DeltaPlan`] keyed by this run's
/// file indices. Files absent from the plan transfer in full.
pub fn negotiate_delta_sender<S: Read + Write>(
    sock: &mut S,
    cfg: &SessionConfig,
    names: &[String],
    sizes: &[u64],
) -> Result<DeltaPlan> {
    let dlen = cfg.leaf_len();
    let mut asked = vec![false; names.len()];
    for (i, name) in names.iter().enumerate() {
        if sizes[i] < cfg.leaf_size {
            continue; // a sub-leaf source can never anchor a copy
        }
        Frame::DeltaReq { file_idx: i as u32, size: sizes[i], name: name.clone() }
            .write_to(sock)?;
        asked[i] = true;
    }
    Frame::Done.write_to(sock)?;
    sock.flush()?;

    let mut plan = DeltaPlan::default();
    loop {
        let f = Frame::read_from(sock)?.context("delta channel closed awaiting signatures")?;
        match f {
            Frame::DeltaSig { file_idx, basis_size, sigs } => {
                let idx = file_idx as usize;
                if idx >= names.len() || !asked[idx] {
                    bail!("delta signature for unrequested file {file_idx}");
                }
                if sigs.is_empty() {
                    continue; // declined
                }
                if let Some(b) =
                    DeltaBasis::from_sig_payload(basis_size, cfg.leaf_size, dlen, &sigs)
                {
                    plan.files.insert(file_idx, b);
                }
            }
            Frame::Done => break,
            other => bail!("expected DeltaSig on delta channel, got {other:?}"),
        }
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::native_factory;
    use crate::coordinator::RealAlgorithm;
    use crate::hashes::HashAlgorithm;
    use crate::merkle::MerkleBuilder;
    use crate::storage::MemStorage;
    use crate::util::tmpdir::TempDir;

    fn factory() -> HasherFactory {
        native_factory(HashAlgorithm::Md5)
    }

    fn cfg_with(leaf: u64) -> SessionConfig {
        let mut cfg = SessionConfig::new(RealAlgorithm::Fiver, factory());
        cfg.leaf_size = leaf;
        cfg
    }

    /// Journal `data` through a tracker, checkpointing every leaf.
    fn record_stream(j: &Journal, name: &str, data: &[u8], leaf: u64, finish: bool) {
        let f = factory();
        let dlen = f().digest_len();
        let mut fj = j.create(name, data.len() as u64, leaf, dlen).unwrap();
        let mut tr = LeafTracker::new(leaf, &f);
        tr.update(data, |_, d, w| fj.push_leaf(&d, w));
        if finish {
            tr.finish(|_, d, w| fj.push_leaf(&d, w));
        }
        fj.checkpoint().unwrap();
    }

    /// Strong-hash `data` with the test factory.
    fn strong_of(data: &[u8]) -> Vec<u8> {
        let mut h = factory()();
        h.update(data);
        h.finalize()
    }

    #[test]
    fn record_roundtrip_and_watermarks() {
        let dir = TempDir::create("fiver-jrnl").unwrap();
        let j = Journal::open(dir.path()).unwrap();
        let data: Vec<u8> = (0u8..=255).cycle().take(2500).collect();
        // Complete record: 2 full leaves + 1 partial at leaf 1000.
        record_stream(&j, "a/b.bin", &data, 1000, true);
        let rec = j.load("a/b.bin").unwrap().unwrap();
        assert_eq!(rec.name, "a/b.bin");
        assert_eq!(rec.size, 2500);
        assert_eq!(rec.leaf_count(), 3);
        assert!(rec.is_complete());
        assert_eq!(rec.aligned_leaves(), 2);
        assert_eq!(rec.watermark(), 2500);
        // Partial record: only whole leaves journaled.
        record_stream(&j, "c", &data, 1000, false);
        let rec = j.load("c").unwrap().unwrap();
        assert_eq!(rec.leaf_count(), 2);
        assert!(!rec.is_complete());
        assert_eq!(rec.watermark(), 2000);
        assert_eq!(j.load_all().unwrap().len(), 2);
        // Missing record.
        assert!(j.load("nope").unwrap().is_none());
        j.remove("a/b.bin");
        assert!(j.load("a/b.bin").unwrap().is_none());
    }

    #[test]
    fn torn_tail_truncates_torn_header_invalidates() {
        let dir = TempDir::create("fiver-jrnl").unwrap();
        let j = Journal::open(dir.path()).unwrap();
        let data = vec![7u8; 3000];
        record_stream(&j, "t", &data, 1000, false);
        let path = j.record_path("t");
        // Torn append: garbage partial entry at the end.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0xAB; 5]);
        std::fs::write(&path, &bytes).unwrap();
        let rec = j.load("t").unwrap().unwrap();
        assert_eq!(rec.leaf_count(), 3, "torn tail drops to the last whole entry");
        // Torn header: record is invalid, not garbage.
        std::fs::write(&path, &bytes[..10]).unwrap();
        assert!(j.load("t").unwrap().is_none());
        // Wrong magic.
        std::fs::write(&path, b"NOTAJRNLxxxxxxxxxxxxxxxxxxxxxxxxxxxx").unwrap();
        assert!(j.load("t").unwrap().is_none());
    }

    #[test]
    fn weak_sums_journaled_and_sig_payload() {
        let dir = TempDir::create("fiver-jrnl").unwrap();
        let j = Journal::open(dir.path()).unwrap();
        let data: Vec<u8> = (3u8..).map(|b| b.wrapping_mul(31)).take(2500).collect();
        record_stream(&j, "w", &data, 1000, true);
        let rec = j.load("w").unwrap().unwrap();
        assert!(rec.has_weaks());
        assert_eq!(rec.weaks.len(), 3);
        assert_eq!(rec.weaks[0], Rolling32::of(&data[..1000]));
        assert_eq!(rec.weaks[1], Rolling32::of(&data[1000..2000]));
        let dlen = rec.digest_len;
        // Signatures cover only *full* leaves: 2 of the 3.
        let sigs = rec.sig_payload(u64::MAX).unwrap();
        assert_eq!(sigs.len(), 2 * (WEAK_LEN + dlen));
        assert_eq!(&sigs[..WEAK_LEN], &rec.weaks[0].to_le_bytes());
        assert_eq!(&sigs[WEAK_LEN..WEAK_LEN + dlen], &rec.leaves[..dlen]);
        assert_eq!(&sigs[WEAK_LEN + dlen..2 * WEAK_LEN + dlen], &rec.weaks[1].to_le_bytes());
        // The cap truncates, and zero full leaves declines.
        assert_eq!(rec.sig_payload(1).unwrap().len(), WEAK_LEN + dlen);
        record_stream(&j, "tiny", &data[..500], 1000, true);
        let tiny = j.load("tiny").unwrap().unwrap();
        assert!(tiny.sig_payload(u64::MAX).is_none(), "sub-leaf file offers no signatures");
    }

    /// Hand-build a v1 (strong-only, index-keyed era) record file.
    fn v1_bytes(name: &str, size: u64, leaf: u64, dlen: usize, digests: &[Vec<u8>]) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC_V1);
        b.extend_from_slice(&(name.len() as u32).to_le_bytes());
        b.extend_from_slice(&size.to_le_bytes());
        b.extend_from_slice(&leaf.to_le_bytes());
        b.extend_from_slice(&(dlen as u32).to_le_bytes());
        b.extend_from_slice(name.as_bytes());
        for d in digests {
            b.extend_from_slice(d);
        }
        b
    }

    #[test]
    fn v1_records_read_compatible() {
        let dir = TempDir::create("fiver-jrnl").unwrap();
        let j = Journal::open(dir.path()).unwrap();
        let f = factory();
        let dlen = f().digest_len();
        let data = vec![5u8; 4000];
        let digests: Vec<Vec<u8>> = data.chunks(1000).map(strong_of).collect();
        // A PR-4-era journal keyed the file by transfer index, not name.
        let legacy = dir.path().join("f000003.fjl");
        std::fs::write(&legacy, v1_bytes("legacy.bin", 4000, 1000, dlen, &digests)).unwrap();
        // Name-keyed lookup misses it; the scan-everything paths find it.
        assert!(j.load("legacy.bin").unwrap().is_none());
        let rec = j.find("legacy.bin").unwrap().unwrap();
        assert_eq!((rec.size, rec.leaf_size, rec.leaf_count()), (4000, 1000, 4));
        assert!(!rec.has_weaks(), "v1 carries no weak sums");
        assert!(rec.sig_payload(u64::MAX).is_none(), "strong-only record declines delta");
        assert!(j.load_all().unwrap().contains_key("legacy.bin"));
        // Resuming upgrades it to a name-keyed path, still in v1 format
        // (no weak sums are invented for data we never re-read).
        let mut fj = j.open_resumed("legacy.bin", 2).unwrap();
        assert_eq!(fj.leaves_recorded(), 2);
        let mut tr = LeafTracker::resume(1000, &f, 2);
        tr.update(&data[2000..], |_, d, w| fj.push_leaf(&d, w));
        fj.checkpoint().unwrap();
        let rec = j.load("legacy.bin").unwrap().unwrap();
        assert_eq!(rec.leaf_count(), 4);
        assert!(!rec.has_weaks());
        assert_eq!(rec.leaves, digests.concat());
    }

    #[test]
    fn segment_compaction_override_and_remove() {
        let dir = TempDir::create("fiver-jrnl").unwrap();
        let j = Journal::open(dir.path()).unwrap();
        let data: Vec<u8> = (0u8..=255).cycle().take(3000).collect();
        record_stream(&j, "s1", &data[..2500], 1000, true);
        record_stream(&j, "s2", &data, 1000, false);
        j.compact().unwrap();
        assert!(!j.record_path("s1").exists(), "compaction folds per-file records away");
        assert!(j.segment_path().exists());
        let all = j.load_all().unwrap();
        assert_eq!(all.len(), 2);
        assert!(all["s1"].is_complete());
        assert_eq!(all["s1"].weaks[0], Rolling32::of(&data[..1000]));
        assert!(j.load("s1").unwrap().is_none(), "segment entries are not name-keyed files");
        assert_eq!(j.find("s1").unwrap().unwrap().size, 2500);
        // A torn segment tail keeps the valid prefix.
        let mut bytes = std::fs::read(j.segment_path()).unwrap();
        bytes.extend_from_slice(&[0xAB; 5]);
        std::fs::write(j.segment_path(), &bytes).unwrap();
        assert_eq!(j.load_all().unwrap().len(), 2);
        // A newer per-file record overrides the segment copy.
        record_stream(&j, "s1", &data[..1200], 1000, true);
        assert_eq!(j.load_all().unwrap()["s1"].size, 1200);
        // Remove masks the segment copy too.
        j.remove("s2");
        let all = j.load_all().unwrap();
        assert_eq!(all.len(), 1);
        assert!(!all.contains_key("s2"));
        j.remove("s1");
        assert!(j.load_all().unwrap().is_empty());
    }

    #[test]
    fn tracker_matches_merkle_builder() {
        let f = factory();
        let data: Vec<u8> = (0u8..200).cycle().take(10_123).collect();
        let mut b = MerkleBuilder::new(512, f.clone());
        for part in data.chunks(333) {
            b.update(part);
        }
        let tree = b.finish();
        let mut leaves = Vec::new();
        let mut weaks = Vec::new();
        let mut tr = LeafTracker::new(512, &f);
        for part in data.chunks(777) {
            tr.update(part, |_, d, w| {
                leaves.extend_from_slice(&d);
                weaks.push(w);
            });
        }
        tr.finish(|_, d, w| {
            leaves.extend_from_slice(&d);
            weaks.push(w);
        });
        assert_eq!(tr.completed_leaves() as usize, tree.leaf_count());
        let rebuilt =
            MerkleTree::from_leaves(512, data.len() as u64, tree.leaf_len(), leaves, &f, false);
        assert_eq!(rebuilt.root(), tree.root());
        // Weak sums match a one-shot rolling sum over each leaf,
        // regardless of how the stream was chunked.
        for (i, w) in weaks.iter().enumerate() {
            let end = ((i + 1) * 512).min(data.len());
            assert_eq!(*w, Rolling32::of(&data[i * 512..end]), "leaf {i}");
        }
        // Empty stream: one empty leaf.
        let mut empty = LeafTracker::new(512, &f);
        let mut n = 0;
        empty.finish(|_, _, _| n += 1);
        assert_eq!(n, 1);
        assert_eq!(empty.position(), 0);
    }

    #[test]
    fn tracker_resume_continues_at_leaf_boundary() {
        let f = factory();
        let data = vec![9u8; 4096];
        let mut full = Vec::new();
        let mut tr = LeafTracker::new(1024, &f);
        tr.update(&data, |_, d, _| full.extend_from_slice(&d));
        // Resume after 2 leaves: the tail produces the same digests.
        let mut tail = Vec::new();
        let mut tr2 = LeafTracker::resume(1024, &f, 2);
        assert_eq!(tr2.position(), 2048);
        tr2.update(&data[2048..], |i, d, _| {
            assert!(i >= 2);
            tail.extend_from_slice(&d);
        });
        let dlen = f().digest_len();
        assert_eq!(&full[2 * dlen..], &tail[..]);
    }

    #[test]
    fn open_resumed_truncates_and_appends() {
        let dir = TempDir::create("fiver-jrnl").unwrap();
        let j = Journal::open(dir.path()).unwrap();
        let data = vec![3u8; 4000];
        record_stream(&j, "r", &data, 1000, false); // 4 leaves
        let f = factory();
        let dlen = f().digest_len();
        let mut fj = j.open_resumed("r", 2).unwrap();
        assert_eq!(fj.leaves_recorded(), 2);
        // Re-append leaves 2 and 3 (as the resumed stream would).
        let mut tr = LeafTracker::resume(1000, &f, 2);
        tr.update(&data[2000..], |_, d, w| fj.push_leaf(&d, w));
        fj.checkpoint().unwrap();
        let rec = j.load("r").unwrap().unwrap();
        assert_eq!(rec.leaf_count(), 4);
        assert!(rec.has_weaks(), "a resumed v2 record keeps its weak sums");
        // The re-appended digests equal the originals.
        let fresh = {
            let mut leaves = Vec::new();
            let mut t = LeafTracker::new(1000, &f);
            t.update(&data, |_, d, _| leaves.extend_from_slice(&d));
            leaves
        };
        assert_eq!(rec.leaves, fresh);
        assert_eq!(dlen * 4, rec.leaves.len());
    }

    #[test]
    fn overwrite_and_patch_leaves() {
        let dir = TempDir::create("fiver-jrnl").unwrap();
        let j = Journal::open(dir.path()).unwrap();
        let data = vec![1u8; 3000];
        record_stream(&j, "p", &data, 1000, true);
        // Patch leaf 1 via the closed-record path.
        let patched = strong_of(&[0xEE; 1000]);
        let weak = Rolling32::of(&[0xEE; 1000]);
        let p2 = patched.clone();
        j.patch_record("p", &[(1500, 10)], move |off, len| {
            assert_eq!((off, len), (1000, 1000));
            Ok((p2.clone(), weak))
        })
        .unwrap();
        let rec = j.load("p").unwrap().unwrap();
        assert_eq!(&rec.leaves[rec.digest_len..2 * rec.digest_len], &patched[..]);
        assert_eq!(rec.weaks[1], weak, "the weak sum is patched alongside the digest");
        // Zero-length ranges and out-of-record leaves are ignored.
        j.patch_record("p", &[(2999, 0)], |_, _| panic!("no leaf touched")).unwrap();
        assert!(leaves_touched(&[(5000, 100)], 1000, 3).is_empty());
        assert_eq!(leaves_touched(&[(999, 2)], 1000, 3), vec![0, 1]);
    }

    #[test]
    fn patch_declines_on_digest_width_mismatch() {
        let dir = TempDir::create("fiver-jrnl").unwrap();
        let j = Journal::open(dir.path()).unwrap();
        let data = vec![1u8; 3000];
        record_stream(&j, "p", &data, 1000, true); // md5-width record
        // A session running a different hash tier recomputes at another
        // width: the record must be dropped (decline), never an error and
        // never an in-place write that would shear later entries.
        j.patch_record("p", &[(1500, 10)], |_, _| Ok((vec![0u8; 16 + 1], 0)))
            .expect("width mismatch declines instead of erroring");
        assert!(j.load("p").unwrap().is_none(), "stale record is dropped");
    }

    #[test]
    fn prefix_root_matches_stream_tree() {
        let dir = TempDir::create("fiver-jrnl").unwrap();
        let j = Journal::open(dir.path()).unwrap();
        let f = factory();
        let data: Vec<u8> = (0u8..=255).cycle().take(5000).collect();
        record_stream(&j, "x", &data, 1000, false);
        let rec = j.load("x").unwrap().unwrap();
        // Root over the first 3 leaves == a builder over the first 3000 B.
        let got = rec.prefix_root(3, 3000, &f, false);
        let mut b = MerkleBuilder::new(1000, f.clone());
        b.update(&data[..3000]);
        assert_eq!(got, b.finish().root());
    }

    #[test]
    fn handshake_agrees_on_common_prefix() {
        let dir = TempDir::create("fiver-hs").unwrap();
        let sdir = dir.join("snd");
        let rdir = dir.join("rcv");
        let sj = Journal::open(&sdir).unwrap();
        let rj = Journal::open(&rdir).unwrap();
        let data: Vec<u8> = (0u8..=255).cycle().take(10_000).collect();
        let leaf = 1000u64;
        // Records carry the *full* source size; leaves cover the streamed
        // prefix. f0: receiver journaled 6 leaves, sender only 4 ->
        // the common prefix is the sender's 4000 bytes.
        let partial = |j: &Journal, name: &str, size: u64, bytes: &[u8]| {
            let f = factory();
            let dlen = f().digest_len();
            let mut fj = j.create(name, size, leaf, dlen).unwrap();
            let mut tr = LeafTracker::new(leaf, &f);
            tr.update(bytes, |_, d, w| fj.push_leaf(&d, w));
            fj.checkpoint().unwrap();
        };
        partial(&rj, "f0", 10_000, &data[..6000]);
        partial(&sj, "f0", 10_000, &data[..4000]);
        // f1: both complete -> skipped outright.
        record_stream(&rj, "f1", &data[..2500], leaf, true);
        record_stream(&sj, "f1", &data[..2500], leaf, true);
        // f2: receiver journal diverges (different bytes) -> rejected.
        partial(&rj, "f2", 3000, &[0xAA; 3000]);
        partial(&sj, "f2", 3000, &data[..3000]);
        // f3: receiver-only record -> the sender declines; the record
        // must survive (a decline is not divergence).
        partial(&rj, "f3", 4000, &data[..2000]);

        let cfg = cfg_with(leaf);
        let names: Vec<String> = vec!["f0".into(), "f1".into(), "f2".into(), "f3".into()];
        let sizes: Vec<u64> = vec![10_000, 2500, 3000, 4000];
        // Destination holds at least each record's watermark.
        let dst = MemStorage::new();
        dst.put("f0", data[..6000].to_vec());
        dst.put("f1", data[..2500].to_vec());
        dst.put("f2", vec![0xAA; 3000]);
        dst.put("f3", data[..2000].to_vec());
        let storage: Arc<dyn Storage> = Arc::new(dst);

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let rcfg = cfg.clone();
        let recv = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            negotiate_receiver(&mut sock, Some(&rj), &rcfg, &storage).unwrap()
        });
        let mut sock = std::net::TcpStream::connect(addr).unwrap();
        let splan = negotiate_sender(&mut sock, Some(&sj), &cfg, &names, &sizes).unwrap();
        let rplan = recv.join().unwrap();

        for plan in [&splan, &rplan] {
            assert_eq!(plan.offset_for("f0"), Some(4000), "common prefix = sender's 4 leaves");
            assert_eq!(plan.offset_for("f1"), Some(2500), "both complete -> full skip");
            assert!(plan.is_complete("f1"));
            assert_eq!(plan.offset_for("f2"), None, "divergent prefix rejected");
            assert_eq!(plan.offset_for("f3"), None, "declined offer resumes nothing");
            assert_eq!(plan.skipped_files(), 1);
            assert_eq!(plan.skipped_bytes(), 4000 + 2500);
        }
        // Both sides hold root-equal prefix leaves for f0.
        let s0 = splan.get("f0").unwrap();
        let r0 = rplan.get("f0").unwrap();
        assert_eq!(s0.leaves, r0.leaves);
        assert_eq!(s0.size, 10_000);
        // Only *proven divergence* costs a record: f2 was dropped,
        // the merely-declined f3 survives for a later resume.
        let rj = Journal::open(&rdir).unwrap();
        assert!(rj.load("f2").unwrap().is_none());
        assert!(rj.load("f3").unwrap().is_some(), "declined record must survive");
        assert!(rj.load("f0").unwrap().is_some());
    }

    #[test]
    fn handshake_with_no_journals_is_empty() {
        let cfg = cfg_with(1024);
        let storage: Arc<dyn Storage> = Arc::new(MemStorage::new());
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let rcfg = cfg.clone();
        let recv = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            negotiate_receiver(&mut sock, None, &rcfg, &storage).unwrap()
        });
        let mut sock = std::net::TcpStream::connect(addr).unwrap();
        let splan = negotiate_sender(&mut sock, None, &cfg, &["a".into()], &[100]).unwrap();
        assert!(splan.is_empty());
        assert!(recv.join().unwrap().is_empty());
    }

    #[test]
    fn prefix_leaf_geometry() {
        assert_eq!(prefix_leaves_for(0, 0, 64), Some(1), "empty file skips via its one leaf");
        assert_eq!(prefix_leaves_for(128, 128, 64), Some(2), "exact-multiple full skip");
        assert_eq!(prefix_leaves_for(100, 100, 64), Some(2), "partial-leaf full skip");
        assert_eq!(prefix_leaves_for(64, 100, 64), Some(1));
        assert_eq!(prefix_leaves_for(0, 100, 64), None, "offset 0 = no resume");
        assert_eq!(prefix_leaves_for(65, 100, 64), None, "misaligned");
        assert_eq!(prefix_leaves_for(200, 100, 64), None, "past the file");
    }

    #[test]
    fn delta_handshake_journaled_hashed_and_declined() {
        let dir = TempDir::create("fiver-delta").unwrap();
        let rj = Journal::open(dir.path()).unwrap();
        let leaf = 1000u64;
        let cfg = cfg_with(leaf);

        // "big": the receiver holds a complete v2 record for the bytes it
        // journaled, while the destination file has since been replaced
        // with different bytes of the *same size*. The free path must
        // serve the journal's signatures, not re-hash storage.
        let data_j: Vec<u8> = (0u8..=255).cycle().take(5000).collect();
        let data_s = vec![0x55u8; 5000];
        record_stream(&rj, "big", &data_j, leaf, true);
        // "nojournal": destination bytes only — signatures are computed
        // by reading and hashing the existing file.
        let data_n: Vec<u8> = (7u8..).map(|b| b.wrapping_mul(13)).take(3500).collect();
        let dst = MemStorage::new();
        dst.put("big", data_s.clone());
        dst.put("nojournal", data_n.clone());
        let storage: Arc<dyn Storage> = Arc::new(dst);

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let rcfg = cfg.clone();
        let recv = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            negotiate_delta_receiver(&mut sock, Some(&rj), &rcfg, &storage).unwrap()
        });
        let names: Vec<String> =
            vec!["big".into(), "nojournal".into(), "absent".into(), "tiny".into()];
        let sizes: Vec<u64> = vec![6000, 4000, 2000, 500];
        let mut sock = std::net::TcpStream::connect(addr).unwrap();
        let plan = negotiate_delta_sender(&mut sock, &cfg, &names, &sizes).unwrap();
        recv.join().unwrap();

        // Journal-served basis: confirms the *journaled* leaves...
        let big = plan.basis(0).expect("journaled basis");
        assert_eq!((big.old_size, big.leaves), (5000, 5));
        let w = Rolling32::of(&data_j[..1000]);
        assert_eq!(big.confirm(w, &strong_of(&data_j[..1000])), Some(0));
        // ...and not the bytes now sitting in storage.
        let ws = Rolling32::of(&data_s[..1000]);
        assert_eq!(big.confirm(ws, &strong_of(&data_s[..1000])), None);

        // Storage-hashed basis: 3 full leaves of the 3500-byte file.
        let nj = plan.basis(1).expect("storage-hashed basis");
        assert_eq!((nj.old_size, nj.leaves), (3500, 3));
        let w1 = Rolling32::of(&data_n[1000..2000]);
        assert_eq!(nj.confirm(w1, &strong_of(&data_n[1000..2000])), Some(1000));

        // No destination file -> declined; sub-leaf source never asked.
        assert!(plan.basis(2).is_none(), "absent file declines");
        assert!(plan.basis(3).is_none(), "sub-leaf file is never requested");
        assert!(!plan.is_empty());
    }

    #[test]
    fn delta_handshake_empty_without_state() {
        let cfg = cfg_with(1024);
        let storage: Arc<dyn Storage> = Arc::new(MemStorage::new());
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let rcfg = cfg.clone();
        let recv = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            negotiate_delta_receiver(&mut sock, None, &rcfg, &storage).unwrap()
        });
        let mut sock = std::net::TcpStream::connect(addr).unwrap();
        let plan = negotiate_delta_sender(&mut sock, &cfg, &["a".into()], &[5000]).unwrap();
        recv.join().unwrap();
        assert!(plan.is_empty());
    }
}
