//! Delta sync: rsync-style incremental transfer over journaled leaf
//! digests.
//!
//! A recurring sync re-transfers datasets that are mostly unchanged. The
//! checkpoint journal (v2, see [`super::journal`]) already persists every
//! file's leaf digests — *and* a 32-bit rolling weak sum per leaf — so a
//! re-run can ship only the leaves that actually changed:
//!
//! 1. **Handshake** (one dedicated control connection, session id
//!    [`super::protocol::DELTA_SESSION`]): the sender lists its files
//!    (`DeltaReq`); the receiver answers with per-leaf signatures of
//!    whatever basis it holds for each name (`DeltaSig`) — journaled v2
//!    digests when a compatible record exists, else weak+strong sums
//!    computed by reading its existing destination data.
//! 2. **Scan** (sender): each file's fresh source bytes stream through a
//!    [`DeltaScanner`], which slides a leaf-sized window with an O(1)
//!    [`Rolling32`] weak checksum. A weak hit is confirmed with the
//!    session's strong hash before it counts — a weak collision can
//!    therefore never ship a wrong leaf, it only costs one extra strong
//!    hash. Confirmed windows become `DeltaCopy` instructions (reuse a
//!    leaf the receiver already holds), everything else ships as
//!    `DeltaData` literals.
//! 3. **Reconstruct** (receiver): instructions arrive in new-file order;
//!    the receiver assembles the new content into a staging file (reading
//!    copy sources from its existing destination), then atomically
//!    renames it over the destination.
//! 4. **Verify**: both endpoints fold the *new* byte stream into leaf
//!    digests and exchange Merkle roots through the existing
//!    `TreeRoot`/descent machinery — so even a stale or lying basis
//!    self-heals: a bad reconstruction fails the root comparison,
//!    descent localizes it, and ordinary `Fix` repair converges.
//!
//! The rolling checksum is the classic rsync pair of 16-bit sums: over a
//! window `x_k..x_l`, `a = Σ x_i (mod 2^16)` and
//! `b = Σ (l - i + 1)·x_i (mod 2^16)`, composed as `(b << 16) | a`.
//! Both roll in O(1) when the window slides one byte.

use std::collections::{HashMap, VecDeque};

use super::HasherFactory;
use crate::hashes::Hasher;

/// Encoded width of one weak checksum in signatures and journal records.
pub const WEAK_LEN: usize = 4;

// ---------------------------------------------------------------------------
// Rolling weak checksum
// ---------------------------------------------------------------------------

/// The rsync 32-bit rolling checksum: two 16-bit sums that update in O(1)
/// as a fixed-size window slides over a byte stream.
///
/// ```
/// use fiver::coordinator::delta::Rolling32;
///
/// let data = b"the quick brown fox jumps over the lazy dog";
/// let window = 16;
/// // Seed the sum over the first window, then roll it one byte at a
/// // time; every rolled value equals the sum computed from scratch.
/// let mut r = Rolling32::new();
/// r.update(&data[..window]);
/// for start in 1..=data.len() - window {
///     r.roll(window, data[start - 1], data[start + window - 1]);
///     assert_eq!(r.digest(), Rolling32::of(&data[start..start + window]));
/// }
/// ```
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Rolling32 {
    a: u32,
    b: u32,
}

impl Rolling32 {
    /// An empty sum (the fixed point of zero bytes).
    pub fn new() -> Rolling32 {
        Rolling32::default()
    }

    /// Absorb one byte at the end of the window.
    #[inline]
    pub fn push(&mut self, byte: u8) {
        self.a = (self.a + byte as u32) & 0xffff;
        self.b = (self.b + self.a) & 0xffff;
    }

    /// Absorb a run of bytes.
    pub fn update(&mut self, data: &[u8]) {
        for &x in data {
            self.push(x);
        }
    }

    /// Slide a `window`-byte window one position: drop `out` (the byte
    /// leaving at the front) and absorb `inb` (the byte entering at the
    /// back). O(1) — the property that makes scanning every window
    /// offset affordable.
    #[inline]
    pub fn roll(&mut self, window: usize, out: u8, inb: u8) {
        self.a = self.a.wrapping_sub(out as u32).wrapping_add(inb as u32) & 0xffff;
        self.b =
            self.b.wrapping_sub((window as u32).wrapping_mul(out as u32)).wrapping_add(self.a)
                & 0xffff;
    }

    /// The composed 32-bit digest: `(b << 16) | a`.
    #[inline]
    pub fn digest(&self) -> u32 {
        (self.b << 16) | self.a
    }

    /// Forget all absorbed bytes.
    pub fn reset(&mut self) {
        *self = Rolling32::default();
    }

    /// One-shot digest of a block.
    pub fn of(block: &[u8]) -> u32 {
        let mut r = Rolling32::new();
        r.update(block);
        r.digest()
    }
}

// ---------------------------------------------------------------------------
// Signatures and the sender's plan
// ---------------------------------------------------------------------------

/// Encode per-leaf `(weak, strong)` signature pairs as a `DeltaSig`
/// payload: fixed `WEAK_LEN + digest_len` stride, leaf order.
pub fn encode_sigs(sigs: &[(u32, Vec<u8>)], digest_len: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(sigs.len() * (WEAK_LEN + digest_len));
    for (weak, strong) in sigs {
        debug_assert_eq!(strong.len(), digest_len);
        out.extend_from_slice(&weak.to_le_bytes());
        out.extend_from_slice(strong);
    }
    out
}

/// One file's delta basis on the sender: the receiver's old leaves,
/// indexed by weak checksum for the O(1) first-pass lookup of the scan.
/// Only *full* (leaf-size-spanning) old leaves participate — a trailing
/// partial leaf cannot anchor a window match.
pub struct DeltaBasis {
    /// Size of the receiver's basis file (reporting only).
    pub old_size: u64,
    /// Number of full old leaves offered.
    pub leaves: u64,
    /// weak → candidate `(old byte offset, strong digest)` pairs.
    by_weak: HashMap<u32, Vec<(u64, Vec<u8>)>>,
}

impl DeltaBasis {
    /// Parse a `DeltaSig` payload (leaf-ordered `(weak, strong)` pairs at
    /// `WEAK_LEN + digest_len` stride). Returns `None` on a malformed
    /// payload — the file then simply transfers in full.
    pub fn from_sig_payload(
        old_size: u64,
        leaf_size: u64,
        digest_len: usize,
        payload: &[u8],
    ) -> Option<DeltaBasis> {
        let stride = WEAK_LEN + digest_len;
        if digest_len == 0 || leaf_size == 0 || payload.len() % stride != 0 {
            return None;
        }
        let leaves = (payload.len() / stride) as u64;
        let mut by_weak: HashMap<u32, Vec<(u64, Vec<u8>)>> = HashMap::new();
        for (i, sig) in payload.chunks_exact(stride).enumerate() {
            let weak = u32::from_le_bytes(sig[..WEAK_LEN].try_into().unwrap());
            let strong = sig[WEAK_LEN..].to_vec();
            by_weak.entry(weak).or_default().push((i as u64 * leaf_size, strong));
        }
        Some(DeltaBasis { old_size, leaves, by_weak })
    }

    /// First-pass filter: is this weak sum present at all? Gates the
    /// strong hash, so a clean scan pays one strong hash per matched
    /// leaf, not per byte.
    pub fn lookup_weak(&self, weak: u32) -> bool {
        self.by_weak.contains_key(&weak)
    }

    /// Exact-position membership: does the basis hold *this* `(weak,
    /// strong)` signature for the leaf at `old_off`? The sender-side
    /// signature cache compares its own journaled leaves against the
    /// basis this way — a full-file match proves both endpoints hold
    /// identical data and the rolling scan can be skipped outright.
    pub fn contains_at(&self, weak: u32, strong: &[u8], old_off: u64) -> bool {
        self.by_weak
            .get(&weak)
            .map(|v| v.iter().any(|(o, s)| *o == old_off && s.as_slice() == strong))
            .unwrap_or(false)
    }

    /// Second-pass confirmation: does any old leaf with this weak sum
    /// also match the window's strong digest? Returns its old byte
    /// offset.
    pub fn confirm(&self, weak: u32, strong: &[u8]) -> Option<u64> {
        self.by_weak
            .get(&weak)?
            .iter()
            .find(|(_, s)| s.as_slice() == strong)
            .map(|&(off, _)| off)
    }
}

/// The sender's negotiated delta plan: per file index, the basis the
/// receiver offered for that file's name. Files absent from the plan
/// transfer in full through the ordinary `FileStart`/`Data` path.
#[derive(Default)]
pub struct DeltaPlan {
    /// file index → basis.
    pub files: HashMap<u32, DeltaBasis>,
}

impl DeltaPlan {
    /// Basis for one file, when the receiver offered one.
    pub fn basis(&self, file_idx: u32) -> Option<&DeltaBasis> {
        self.files.get(&file_idx)
    }

    /// No file has a basis (fresh destination): every transfer is full.
    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }
}

/// Staging-file name a delta reconstruction writes into before the
/// atomic rename over the destination. Kept deterministic so a crashed
/// run's leftover staging file is recognizably ours (and simply
/// overwritten by the next attempt).
pub fn staging_name(name: &str) -> String {
    format!("{name}.fvr-delta-tmp")
}

// ---------------------------------------------------------------------------
// Streaming scanner
// ---------------------------------------------------------------------------

/// One instruction of the delta stream, in strict new-file order.
#[derive(Debug, PartialEq, Eq)]
pub enum DeltaOp {
    /// The receiver already holds these bytes at `old_off` of its basis
    /// file: copy them to `new_off` instead of shipping them.
    Copy {
        /// Destination offset in the new file.
        new_off: u64,
        /// Source offset in the receiver's existing (old) file.
        old_off: u64,
        /// Bytes to copy (always one full leaf).
        len: u64,
    },
    /// Fresh bytes the receiver does not hold: ship them literally.
    Literal {
        /// Destination offset in the new file.
        new_off: u64,
        /// The literal bytes.
        data: Vec<u8>,
    },
}

/// Streaming rsync-style scan of a new file against a [`DeltaBasis`]:
/// feed source chunks in order with [`DeltaScanner::update`], drain
/// [`DeltaOp`]s (emitted in new-file order) with [`DeltaScanner::pop`].
///
/// The scanner slides a leaf-sized window over the stream. At each
/// position the O(1) rolling weak sum gates a strong-hash confirmation;
/// a confirmed window becomes a `Copy` and the window jumps a whole
/// leaf, otherwise it slides one byte and the passed-over byte joins the
/// pending literal run. Unmatched runs flush as `Literal` ops (bounded
/// by an internal flush size), so buffered state stays O(leaf + flush).
pub struct DeltaScanner<'b> {
    basis: &'b DeltaBasis,
    leaf: usize,
    /// Literal runs flush at this size (keeps frames bounded).
    flush_bytes: usize,
    hasher: Box<dyn Hasher>,
    /// Unconsumed stream bytes: `buf[..cursor]` is the pending literal
    /// run, `buf[cursor..]` is window lookahead.
    buf: Vec<u8>,
    cursor: usize,
    /// New-file offset of `buf[0]`.
    base: u64,
    /// Rolling sum over `buf[cursor..cursor + leaf]` when that window is
    /// complete; `None` when it must be (re)seeded.
    roll: Option<Rolling32>,
    /// Emitted ops awaiting [`DeltaScanner::pop`].
    ops: VecDeque<DeltaOp>,
    /// Scan statistics: leaves copied (basis hits).
    pub copies: u64,
    /// Scan statistics: bytes covered by copies (not shipped).
    pub copied_bytes: u64,
    /// Scan statistics: literal bytes emitted (shipped).
    pub literal_bytes: u64,
}

impl<'b> DeltaScanner<'b> {
    /// A scanner for one file. `leaf_size` must match the basis geometry
    /// (both come from the shared session config).
    pub fn new(basis: &'b DeltaBasis, leaf_size: u64, factory: &HasherFactory) -> DeltaScanner<'b> {
        let leaf = leaf_size as usize;
        assert!(leaf > 0, "leaf_size must be positive");
        DeltaScanner {
            basis,
            leaf,
            flush_bytes: leaf.max(64 * 1024),
            hasher: factory(),
            buf: Vec::with_capacity(2 * leaf),
            cursor: 0,
            base: 0,
            roll: None,
            ops: VecDeque::new(),
            copies: 0,
            copied_bytes: 0,
            literal_bytes: 0,
        }
    }

    /// Next emitted op, in new-file order.
    pub fn pop(&mut self) -> Option<DeltaOp> {
        self.ops.pop_front()
    }

    fn flush_literals(&mut self) {
        if self.cursor > 0 {
            self.literal_bytes += self.cursor as u64;
            let data: Vec<u8> = self.buf.drain(..self.cursor).collect();
            self.ops.push_back(DeltaOp::Literal { new_off: self.base, data });
            self.base += self.cursor as u64;
            self.cursor = 0;
        }
    }

    /// Scan as far as the buffered bytes allow.
    fn scan(&mut self) {
        while self.buf.len() >= self.cursor + self.leaf {
            let weak = match &self.roll {
                Some(r) => r.digest(),
                None => {
                    let mut r = Rolling32::new();
                    r.update(&self.buf[self.cursor..self.cursor + self.leaf]);
                    let d = r.digest();
                    self.roll = Some(r);
                    d
                }
            };
            let matched = if self.basis.lookup_weak(weak) {
                self.hasher.reset();
                self.hasher.update(&self.buf[self.cursor..self.cursor + self.leaf]);
                let strong = self.hasher.finalize();
                self.basis.confirm(weak, &strong)
            } else {
                None
            };
            if let Some(old_off) = matched {
                // Flush the pending literal run, then emit the copy.
                self.flush_literals();
                self.ops.push_back(DeltaOp::Copy {
                    new_off: self.base,
                    old_off,
                    len: self.leaf as u64,
                });
                self.copies += 1;
                self.copied_bytes += self.leaf as u64;
                self.base += self.leaf as u64;
                self.buf.drain(..self.leaf);
                self.roll = None;
            } else {
                // Slide one byte: the byte at `cursor` joins the literal
                // run and the window advances.
                let out = self.buf[self.cursor];
                let window_end = self.cursor + self.leaf;
                if window_end < self.buf.len() {
                    let inb = self.buf[window_end];
                    self.roll.as_mut().expect("seeded above").roll(self.leaf, out, inb);
                } else {
                    // The next window is incomplete; reseed when more
                    // bytes arrive.
                    self.roll = None;
                }
                self.cursor += 1;
                if self.cursor >= self.flush_bytes {
                    // Flushing invalidates nothing: the window (and its
                    // rolling state) lives at `cursor`, which resets to
                    // 0 with the same window bytes still buffered.
                    self.flush_literals();
                }
            }
        }
    }

    /// Feed the next in-order source chunk; matched/expired spans queue
    /// as ops. Lookahead shorter than one leaf is retained for the next
    /// call (it may yet match).
    pub fn update(&mut self, chunk: &[u8]) {
        self.buf.extend_from_slice(chunk);
        self.scan();
    }

    /// End of stream: everything still buffered (a tail shorter than one
    /// leaf, plus any pending literal run) is literal by definition.
    pub fn finish(&mut self) {
        self.scan();
        self.cursor = self.buf.len();
        self.flush_literals();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::native_factory;
    use crate::hashes::HashAlgorithm;

    fn factory() -> HasherFactory {
        native_factory(HashAlgorithm::Md5)
    }

    /// Full-leaf signatures of `data` at `leaf` granularity.
    fn sigs_of(data: &[u8], leaf: usize) -> Vec<(u32, Vec<u8>)> {
        let f = factory();
        data.chunks_exact(leaf)
            .map(|c| {
                let mut h = f();
                h.update(c);
                (Rolling32::of(c), h.finalize())
            })
            .collect()
    }

    fn basis_of(data: &[u8], leaf: usize) -> DeltaBasis {
        let f = factory();
        let dlen = f().digest_len();
        let payload = encode_sigs(&sigs_of(data, leaf), dlen);
        DeltaBasis::from_sig_payload(data.len() as u64, leaf as u64, dlen, &payload).unwrap()
    }

    /// Run a full scan; return the ops and the receiver-style
    /// reconstruction (copies read `old`, literals land verbatim).
    fn scan_all(old: &[u8], new: &[u8], leaf: usize, chunk: usize) -> (Vec<DeltaOp>, Vec<u8>) {
        let basis = basis_of(old, leaf);
        let f = factory();
        let mut sc = DeltaScanner::new(&basis, leaf as u64, &f);
        let mut ops = Vec::new();
        for c in new.chunks(chunk.max(1)) {
            sc.update(c);
            while let Some(op) = sc.pop() {
                ops.push(op);
            }
        }
        sc.finish();
        while let Some(op) = sc.pop() {
            ops.push(op);
        }
        let mut rebuilt = Vec::new();
        for op in &ops {
            match op {
                DeltaOp::Copy { new_off, old_off, len } => {
                    assert_eq!(*new_off as usize, rebuilt.len(), "ops must be in-order, gapless");
                    let (o, l) = (*old_off as usize, *len as usize);
                    rebuilt.extend_from_slice(&old[o..o + l]);
                }
                DeltaOp::Literal { new_off, data } => {
                    assert_eq!(*new_off as usize, rebuilt.len(), "ops must be in-order, gapless");
                    rebuilt.extend_from_slice(data);
                }
            }
        }
        (ops, rebuilt)
    }

    fn literal_bytes(ops: &[DeltaOp]) -> usize {
        ops.iter()
            .map(|op| match op {
                DeltaOp::Literal { data, .. } => data.len(),
                _ => 0,
            })
            .sum()
    }

    fn copy_count(ops: &[DeltaOp]) -> usize {
        ops.iter().filter(|op| matches!(op, DeltaOp::Copy { .. })).count()
    }

    #[test]
    fn rolling_matches_scratch_at_every_offset() {
        let data: Vec<u8> = (0u8..=255).cycle().take(1000).map(|b| b.wrapping_mul(31)).collect();
        for window in [1usize, 2, 16, 64] {
            let mut r = Rolling32::new();
            r.update(&data[..window]);
            assert_eq!(r.digest(), Rolling32::of(&data[..window]));
            for start in 1..=data.len() - window {
                r.roll(window, data[start - 1], data[start + window - 1]);
                assert_eq!(
                    r.digest(),
                    Rolling32::of(&data[start..start + window]),
                    "window {window} at {start}"
                );
            }
        }
    }

    #[test]
    fn rolling_reset_and_empty() {
        let mut r = Rolling32::new();
        assert_eq!(r.digest(), 0);
        r.update(b"abc");
        assert_ne!(r.digest(), 0);
        r.reset();
        assert_eq!(r.digest(), 0);
        assert_eq!(Rolling32::of(&[]), 0);
    }

    #[test]
    fn weak_collision_is_vetoed_by_strong_hash() {
        // Distinct blocks with identical weak sums: equal byte sums and
        // equal position-weighted sums.
        let x = [1u8, 2, 3, 4];
        let y = [2u8, 1, 2, 5];
        assert_ne!(x, y);
        assert_eq!(Rolling32::of(&x), Rolling32::of(&y), "forced weak collision");
        // Old file = x; new file = y. The weak sum collides, so the
        // scanner *must* compute the strong hash — which differs, so y
        // ships as a literal, never as a wrong copy of x.
        let (ops, rebuilt) = scan_all(&x, &y, 4, 4);
        assert_eq!(rebuilt, y);
        assert_eq!(copy_count(&ops), 0, "collision must not copy");
        // And the basis itself confirms only the true strong digest.
        let basis = basis_of(&x, 4);
        assert!(basis.lookup_weak(Rolling32::of(&y)));
        let f = factory();
        let strong_y = {
            let mut h = f();
            h.update(&y);
            h.finalize()
        };
        assert_eq!(basis.confirm(Rolling32::of(&y), &strong_y), None);
    }

    #[test]
    fn identical_file_is_all_copies() {
        let leaf = 64;
        let data: Vec<u8> = (0u8..=255).cycle().take(leaf * 8).collect();
        for chunk in [1, 7, leaf, leaf * 3, data.len()] {
            let (ops, rebuilt) = scan_all(&data, &data, leaf, chunk);
            assert_eq!(rebuilt, data);
            assert_eq!(copy_count(&ops), 8, "chunk {chunk}: every leaf copies");
            assert_eq!(literal_bytes(&ops), 0, "chunk {chunk}");
        }
    }

    #[test]
    fn in_place_mutation_dirties_only_touched_leaves() {
        let leaf = 32;
        let old: Vec<u8> = (0u8..=255).cycle().take(leaf * 10).collect();
        let mut new = old.clone();
        // Mutate one byte in leaf 3 and two bytes in leaf 7.
        new[3 * leaf + 5] ^= 0xFF;
        new[7 * leaf] ^= 0x55;
        new[7 * leaf + 31] ^= 0x11;
        let (ops, rebuilt) = scan_all(&old, &new, leaf, 100);
        assert_eq!(rebuilt, new);
        assert_eq!(literal_bytes(&ops), 2 * leaf, "exactly the two touched leaves ship");
        assert_eq!(copy_count(&ops), 8);
    }

    #[test]
    fn append_keeps_prefix_as_copies() {
        let leaf = 32;
        let old: Vec<u8> = (17u8..=255).cycle().take(leaf * 4).collect();
        let mut new = old.clone();
        new.extend((0u8..100).map(|b| b.wrapping_mul(7)));
        let (ops, rebuilt) = scan_all(&old, &new, leaf, 50);
        assert_eq!(rebuilt, new);
        assert_eq!(copy_count(&ops), 4, "the whole old prefix copies");
        assert_eq!(literal_bytes(&ops), 100, "only the appended tail ships");
    }

    #[test]
    fn truncation_ships_nothing_extra() {
        let leaf = 32;
        let old: Vec<u8> = (0u8..=255).cycle().take(leaf * 6).collect();
        let new = old[..leaf * 3 + 10].to_vec();
        let (ops, rebuilt) = scan_all(&old, &new, leaf, 64);
        assert_eq!(rebuilt, new);
        assert_eq!(copy_count(&ops), 3);
        assert_eq!(literal_bytes(&ops), 10, "only the sub-leaf tail is literal");
    }

    #[test]
    fn insertion_shifts_are_found_by_rolling() {
        // Insert bytes mid-file: every old leaf after the insertion point
        // sits at a *shifted* (unaligned) offset in the new file. Only a
        // genuinely rolling weak sum finds those matches.
        let leaf = 32;
        let old: Vec<u8> = (0u8..=255).cycle().take(leaf * 8).collect();
        let mut new = old[..leaf + 7].to_vec();
        new.extend_from_slice(b"INSERTED");
        new.extend_from_slice(&old[leaf + 7..]);
        let (ops, rebuilt) = scan_all(&old, &new, leaf, 60);
        assert_eq!(rebuilt, new);
        // Leaf 0 matches aligned; leaves 2..8 match at shifted offsets
        // (leaf 1 is split by the insertion).
        let copies = copy_count(&ops);
        assert!(copies >= 7, "rolling must recover shifted leaves, got {copies} copies");
        let lit = literal_bytes(&ops);
        assert!(lit <= 2 * leaf + 8, "literals stay near the insertion, got {lit}");
    }

    #[test]
    fn empty_and_sub_leaf_files() {
        let leaf = 64;
        // Empty new file: no ops at all.
        let (ops, rebuilt) = scan_all(b"old content that does not matter", &[], leaf, 16);
        assert!(ops.is_empty());
        assert!(rebuilt.is_empty());
        // Sub-leaf new file: one literal, no window ever forms.
        let new = b"tiny".to_vec();
        let (ops, rebuilt) = scan_all(&vec![9u8; leaf * 4], &new, leaf, 2);
        assert_eq!(rebuilt, new);
        assert_eq!(ops.len(), 1);
        assert_eq!(copy_count(&ops), 0);
        // Empty basis (old file empty): everything literal.
        let new: Vec<u8> = (0u8..200).collect();
        let (ops, rebuilt) = scan_all(&[], &new, leaf, 33);
        assert_eq!(rebuilt, new);
        assert_eq!(copy_count(&ops), 0);
    }

    #[test]
    fn window_state_survives_chunk_boundaries() {
        // Feed the same mutated file at every chunk size from 1 up: the
        // op stream must be identical regardless of how the stream is
        // sliced (window wrap/reset across chunk and leaf boundaries).
        let leaf = 16;
        let old: Vec<u8> = (0u8..=255).cycle().take(leaf * 5).collect();
        let mut new = old.clone();
        new[2 * leaf + 3] ^= 0xA5; // dirty one mid leaf
        let (ref_ops, ref_rebuilt) = scan_all(&old, &new, leaf, new.len());
        for chunk in 1..=40 {
            let (ops, rebuilt) = scan_all(&old, &new, leaf, chunk);
            assert_eq!(rebuilt, ref_rebuilt, "chunk {chunk}");
            assert_eq!(ops, ref_ops, "chunk {chunk}: op stream must be slice-invariant");
        }
    }

    #[test]
    fn scanner_counters_track_ops() {
        let leaf = 32;
        let old: Vec<u8> = (3u8..=255).cycle().take(leaf * 6).collect();
        let mut new = old.clone();
        new[leaf] ^= 0x42;
        let basis = basis_of(&old, leaf);
        let f = factory();
        let mut sc = DeltaScanner::new(&basis, leaf as u64, &f);
        sc.update(&new);
        sc.finish();
        while sc.pop().is_some() {}
        assert_eq!(sc.copies, 5);
        assert_eq!(sc.copied_bytes, 5 * leaf as u64);
        assert_eq!(sc.literal_bytes, leaf as u64);
    }

    #[test]
    fn malformed_sig_payload_is_rejected() {
        assert!(DeltaBasis::from_sig_payload(100, 32, 16, &[0u8; 21]).is_none());
        assert!(DeltaBasis::from_sig_payload(100, 0, 16, &[]).is_none());
        assert!(DeltaBasis::from_sig_payload(100, 32, 0, &[]).is_none());
        let b = DeltaBasis::from_sig_payload(100, 32, 16, &[0u8; 40]).unwrap();
        assert_eq!(b.leaves, 2);
        assert_eq!(b.old_size, 100);
    }
}
