//! The fixed-size synchronized queue of Algorithms 1 & 2 — the mechanism
//! that lets FIVER share one file read between the network thread and the
//! checksum thread.
//!
//! Semantics match the paper exactly: `add` blocks when the queue is full
//! (so a fast transfer backs off to checksum speed — "if transfer operation
//! is faster and queue is filled, then transfer operations will need
//! back-off [and] run at same speed as checksum computation"), `remove`
//! blocks when empty (a fast checksum "will just wait for data to be
//! available, so its total CPU time will not change").
//!
//! The queue carries [`SharedBuf`]s, not owned `Vec`s: inserting a buffer
//! is a refcount bump, so the same pooled bytes the socket just saw flow
//! to the checksum worker without a copy, and the backing returns to its
//! [`super::bufpool::BufferPool`] when the worker drops the last
//! reference.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use super::bufpool::SharedBuf;

struct Inner {
    buffers: VecDeque<SharedBuf>,
    bytes: usize,
    closed: bool,
    /// Blocked producers/consumers — lets the hot path skip the condvar
    /// syscall entirely when the peer is running free (measured ~25% of
    /// FIVER's end-to-end time on fast links; EXPERIMENTS.md §Perf).
    waiting_add: usize,
    waiting_remove: usize,
}

/// Bounded byte-buffer queue. Capacity is in *bytes*, not buffer count, so
/// back-pressure is independent of the I/O buffer size in use.
#[derive(Clone)]
pub struct ByteQueue {
    inner: Arc<(Mutex<Inner>, Condvar, Condvar)>,
    capacity: usize,
}

impl ByteQueue {
    /// A queue admitting at most `capacity_bytes` of queued data.
    pub fn new(capacity_bytes: usize) -> ByteQueue {
        assert!(capacity_bytes > 0);
        ByteQueue {
            inner: Arc::new((
                Mutex::new(Inner {
                    buffers: VecDeque::new(),
                    bytes: 0,
                    closed: false,
                    waiting_add: 0,
                    waiting_remove: 0,
                }),
                Condvar::new(), // not_full
                Condvar::new(), // not_empty
            )),
            capacity: capacity_bytes,
        }
    }

    /// Configured capacity in bytes.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Blocking add (Algorithm 1 line 7). Returns `false` if the queue was
    /// closed (consumer gone) — producers should stop.
    pub fn add(&self, buf: SharedBuf) -> bool {
        let (lock, not_full, not_empty) = &*self.inner;
        let mut g = lock.lock().unwrap();
        // A buffer larger than capacity is still accepted when empty,
        // otherwise nothing could ever flow.
        while !g.closed && g.bytes > 0 && g.bytes + buf.len() > self.capacity {
            g.waiting_add += 1;
            g = not_full.wait(g).unwrap();
            g.waiting_add -= 1;
        }
        if g.closed {
            return false;
        }
        g.bytes += buf.len();
        g.buffers.push_back(buf);
        if g.waiting_remove > 0 {
            not_empty.notify_one();
        }
        true
    }

    /// Non-blocking add: give the buffer back (`Err`) when the queue is
    /// full so the caller can spill instead of blocking — the receiver's
    /// frame merger must never block on a queue whose hash job may still
    /// be waiting for a pool worker (see [`crate::coordinator::pool`]).
    /// A closed queue accepts-and-drops (the consumer is gone).
    pub fn try_add(&self, buf: SharedBuf) -> Result<(), SharedBuf> {
        let (lock, _not_full, not_empty) = &*self.inner;
        let mut g = lock.lock().unwrap();
        if g.closed {
            return Ok(());
        }
        if g.bytes > 0 && g.bytes + buf.len() > self.capacity {
            return Err(buf);
        }
        g.bytes += buf.len();
        g.buffers.push_back(buf);
        if g.waiting_remove > 0 {
            not_empty.notify_one();
        }
        Ok(())
    }

    /// Blocking remove (Algorithm 1 line 14). `None` once closed and
    /// drained — the consumer's end-of-stream.
    pub fn remove(&self) -> Option<SharedBuf> {
        let (lock, not_full, not_empty) = &*self.inner;
        let mut g = lock.lock().unwrap();
        loop {
            if let Some(buf) = g.buffers.pop_front() {
                g.bytes -= buf.len();
                if g.waiting_add > 0 {
                    not_full.notify_one();
                }
                return Some(buf);
            }
            if g.closed {
                return None;
            }
            g.waiting_remove += 1;
            g = not_empty.wait(g).unwrap();
            g.waiting_remove -= 1;
        }
    }

    /// Close the queue: producers fail fast, consumers drain then get None.
    pub fn close(&self) {
        let (lock, not_full, not_empty) = &*self.inner;
        lock.lock().unwrap().closed = true;
        not_full.notify_all();
        not_empty.notify_all();
    }

    /// Bytes currently queued.
    pub fn len_bytes(&self) -> usize {
        self.inner.0.lock().unwrap().bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    fn buf(v: Vec<u8>) -> SharedBuf {
        SharedBuf::from_vec(v)
    }

    #[test]
    fn fifo_order() {
        let q = ByteQueue::new(1024);
        q.add(buf(vec![1]));
        q.add(buf(vec![2, 2]));
        q.add(buf(vec![3]));
        assert_eq!(q.remove().unwrap(), vec![1]);
        assert_eq!(q.remove().unwrap(), vec![2, 2]);
        assert_eq!(q.remove().unwrap(), vec![3]);
    }

    #[test]
    fn close_drains_then_none() {
        let q = ByteQueue::new(1024);
        q.add(buf(vec![1]));
        q.close();
        assert_eq!(q.remove().unwrap(), vec![1]);
        assert_eq!(q.remove(), None);
    }

    #[test]
    fn add_after_close_rejected() {
        let q = ByteQueue::new(1024);
        q.close();
        assert!(!q.add(buf(vec![1])));
    }

    #[test]
    fn producer_backs_off_when_full() {
        let q = ByteQueue::new(10);
        q.add(buf(vec![0; 8]));
        let q2 = q.clone();
        let handle = thread::spawn(move || {
            // Blocks until the consumer drains.
            let start = std::time::Instant::now();
            assert!(q2.add(buf(vec![0; 8])));
            start.elapsed()
        });
        thread::sleep(Duration::from_millis(50));
        assert_eq!(q.remove().unwrap().len(), 8);
        let waited = handle.join().unwrap();
        assert!(waited >= Duration::from_millis(40), "producer should have blocked: {waited:?}");
    }

    #[test]
    fn oversized_buffer_accepted_when_empty() {
        let q = ByteQueue::new(4);
        assert!(q.add(buf(vec![0; 100])));
        assert_eq!(q.remove().unwrap().len(), 100);
    }

    #[test]
    fn try_add_returns_buffer_when_full() {
        let q = ByteQueue::new(10);
        assert!(q.try_add(buf(vec![1; 8])).is_ok());
        let back = q.try_add(buf(vec![2; 8])).unwrap_err();
        assert_eq!(back, vec![2; 8], "full queue hands the buffer back");
        assert_eq!(q.remove().unwrap(), vec![1; 8]);
        assert!(q.try_add(back).is_ok(), "accepted once drained");
        // Closed queues accept-and-drop.
        q.close();
        assert!(q.try_add(buf(vec![3; 3])).is_ok());
        assert_eq!(q.remove().unwrap(), vec![2; 8]);
        assert_eq!(q.remove(), None);
    }

    #[test]
    fn consumer_blocks_until_data() {
        let q = ByteQueue::new(16);
        let q2 = q.clone();
        let handle = thread::spawn(move || q2.remove());
        thread::sleep(Duration::from_millis(30));
        q.add(buf(vec![7; 3]));
        assert_eq!(handle.join().unwrap().unwrap(), vec![7; 3]);
    }

    #[test]
    fn byte_accounting_with_slices() {
        // Slices of one backing count their view length, not the backing.
        let q = ByteQueue::new(100);
        let big = buf((0u8..=99).collect());
        q.add(big.slice(0, 30));
        q.add(big.slice(30, 40));
        assert_eq!(q.len_bytes(), 40);
        assert_eq!(q.remove().unwrap().len(), 30);
        assert_eq!(q.len_bytes(), 10);
        assert_eq!(&q.remove().unwrap()[..], &(30u8..40).collect::<Vec<u8>>()[..]);
        assert_eq!(q.len_bytes(), 0);
    }

    #[test]
    fn concurrent_stream_integrity() {
        // Pump 1 MB through a small queue; consumer must see every byte in
        // order — the property FIVER's checksum correctness rests on.
        let q = ByteQueue::new(8 * 1024);
        let q2 = q.clone();
        let producer = thread::spawn(move || {
            let mut counter = 0u8;
            for _ in 0..256 {
                let data: Vec<u8> = (0..4096)
                    .map(|_| {
                        counter = counter.wrapping_add(1);
                        counter
                    })
                    .collect();
                assert!(q2.add(buf(data)));
            }
            q2.close();
        });
        let mut expect = 0u8;
        let mut total = 0usize;
        while let Some(b) = q.remove() {
            for &v in b.iter() {
                expect = expect.wrapping_add(1);
                assert_eq!(v, expect);
                total += 1;
            }
        }
        producer.join().unwrap();
        assert_eq!(total, 256 * 4096);
    }
}
