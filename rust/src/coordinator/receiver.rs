//! Algorithm 2 — the FIVER receiver, generalized over all five policies
//! and engine-driven: one session serves one control channel plus one or
//! more striped data channels, and checksum compute runs on the shared
//! [`super::pool::HashPool`] instead of per-file threads.
//!
//! Concurrent roles per session:
//!
//! * **stripe readers**: one per data socket; decode frames and forward
//!   them (per-socket FIFO preserved) to the merger.
//! * **merger** (the caller's thread): routes frames to per-file state,
//!   writes file bytes to storage, and — in queue mode — feeds the shared
//!   [`ByteQueue`] *in stream order* (an offset-keyed reorder stash
//!   absorbs stripe skew), so the checksum of the in-flight file proceeds
//!   without any file I/O (Algorithm 2 lines 5-8). The merger never
//!   blocks on a full queue mid-stream — it spills and retries — which is
//!   what keeps the shared pool deadlock-free (see [`super::pool`]).
//! * **hash pool workers**: execute one job per queue-mode file; consume
//!   the queue and produce per-unit digests or the digest tree
//!   (Algorithm 2's COMPUTECHECKSUM).
//! * **verify worker**: owns the control channel; sends digests, reads
//!   verdicts, applies the repair/recompute loop for failed units, and
//!   for re-read-mode files performs the checksum itself by reading
//!   storage (the sequential / pipelined checksum station).

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::bufpool::{BufferPool, SharedBuf};
use super::journal::{FileJournal, Journal, JournalFold, LeafTracker, ResumePlan};
use super::pool::{HashPool, PoolHandle};
use super::protocol::Frame;
use super::queue::ByteQueue;
use super::{HasherFactory, RealAlgorithm, SessionConfig};
use crate::merkle::{MerkleBuilder, MerkleTree};
use crate::obs::{Shard, Stage};
use crate::storage::Storage;

/// Receiver-side session summary.
#[derive(Debug, Default, Clone)]
pub struct ReceiverReport {
    /// Files fully received and written.
    pub files_received: usize,
    /// Payload bytes received.
    pub bytes_received: u64,
    /// Verification units (files, chunks or trees) that passed.
    pub units_verified: u64,
    /// Digest exchanges that failed (corruption caught).
    pub units_failed: u64,
    /// Bytes rewritten by repair frames.
    pub bytes_repaired: u64,
    /// Active storage I/O engine at this endpoint (buffered/mmap/direct/
    /// mem).
    pub io_backend: String,
    /// Storage `sync` calls observed at session end. The counter is
    /// shared per storage, so every session of an endpoint snapshots the
    /// same value — merge takes the max, not the sum.
    pub storage_syncs: u64,
    /// O_DIRECT per-op fallbacks to buffered I/O at this endpoint
    /// (0 for the other engines). Shared per storage like
    /// `storage_syncs` — merge takes the max.
    pub direct_fallbacks: u64,
    /// io_uring fallbacks to buffered I/O at this endpoint (ring setup
    /// refused or a ring died). Shared per storage — merge takes the max.
    pub uring_fallbacks: u64,
    /// `posix_fadvise` streaming hints issued at this endpoint. Shared
    /// per storage — merge takes the max.
    pub storage_hints: u64,
}

impl ReceiverReport {
    /// Sum another session's report into this one (engine aggregation).
    pub fn merge(&mut self, other: &ReceiverReport) {
        self.files_received += other.files_received;
        self.bytes_received += other.bytes_received;
        self.units_verified += other.units_verified;
        self.units_failed += other.units_failed;
        self.bytes_repaired += other.bytes_repaired;
        if self.io_backend.is_empty() {
            self.io_backend = other.io_backend.clone();
        }
        self.storage_syncs = self.storage_syncs.max(other.storage_syncs);
        self.direct_fallbacks = self.direct_fallbacks.max(other.direct_fallbacks);
        self.uring_fallbacks = self.uring_fallbacks.max(other.uring_fallbacks);
        self.storage_hints = self.storage_hints.max(other.storage_hints);
    }
}

/// One work item for the verify worker.
enum Event {
    /// Verify a unit. `digest` is pre-computed for queue-mode files; for
    /// re-read mode the worker hashes `[offset, offset+len)` from storage.
    Verify {
        file_idx: u32,
        name: String,
        unit: u64,
        offset: u64,
        len: u64,
        digest: Option<Vec<u8>>,
    },
    /// FIVER-Merkle: exchange this file's digest tree with the sender and
    /// drive the leaf-repair loop until the roots match.
    VerifyTree { file_idx: u32, name: String, tree: MerkleTree },
    /// Repairs for (file_idx, unit) have been applied; `ranges` are the
    /// byte spans the Fix frames rewrote (so tree mode recomputes only the
    /// touched leaves). Recompute and re-exchange.
    Repaired { file_idx: u32, unit: u64, ranges: Vec<(u64, u64)> },
}

/// Serve one single-stripe session on accepted data/control connections
/// with a private two-worker hash pool. Blocks until the sender's `Done`
/// frame; returns the session report.
pub fn serve_session(
    data: TcpStream,
    ctrl: TcpStream,
    storage: Arc<dyn Storage>,
    cfg: &SessionConfig,
) -> Result<ReceiverReport> {
    let pool = HashPool::new(2);
    serve_session_multi(
        vec![data],
        ctrl,
        storage,
        cfg,
        pool.handle(),
        cfg.make_pool(1),
        Arc::new(ResumePlan::default()),
    )
}

/// Serve one engine session: `datas` are this session's stripe sockets
/// (index = stripe id), `ctrl` its control channel, `pool` the endpoint's
/// shared hash pool, `bufs` its shared data-plane buffer pool, `resume`
/// the handshake-agreed per-file restart state (empty = fresh run).
pub fn serve_session_multi(
    datas: Vec<TcpStream>,
    ctrl: TcpStream,
    storage: Arc<dyn Storage>,
    cfg: &SessionConfig,
    pool: PoolHandle,
    bufs: BufferPool,
    resume: Arc<ResumePlan>,
) -> Result<ReceiverReport> {
    anyhow::ensure!(!datas.is_empty(), "session needs at least one data channel");
    let journal = cfg.open_journal()?;
    let (tx, rx) = mpsc::channel::<Event>();

    // Verify worker: owns both directions of the control channel.
    let worker_storage = storage.clone();
    let worker_cfg = cfg.clone();
    let worker = std::thread::spawn(move || verify_worker(ctrl, worker_storage, &worker_cfg, rx));

    // Stripe readers: per-socket FIFO is preserved through the shared
    // channel (std mpsc keeps each sender's sends in order). The socket
    // is read *unbuffered* on purpose: payloads decode straight from the
    // kernel into pooled buffers with zero intermediate copies (a
    // BufReader would memcpy every payload's first bufferful through its
    // internal buffer), at the cost of one extra small recv per frame
    // for the 25-byte header — noise next to a payload-sized read.
    let (ftx, frx) = mpsc::channel::<Result<Frame>>();
    let mut readers = Vec::new();
    for data in datas {
        let ftx = ftx.clone();
        let bufs2 = bufs.clone();
        let obs = cfg.obs.shard("recv-stripe");
        readers.push(std::thread::spawn(move || {
            let mut input = data;
            loop {
                let t = obs.start();
                match Frame::read_from_pooled(&mut input, &bufs2) {
                    Ok(Some(frame)) => {
                        obs.record(Stage::Recv, t);
                        if ftx.send(Ok(frame)).is_err() {
                            break; // merger gone
                        }
                    }
                    Ok(None) => break, // clean EOF
                    Err(e) => {
                        ftx.send(Err(e)).ok();
                        break;
                    }
                }
            }
        }));
    }
    drop(ftx); // merger's recv ends once every reader is done

    let merged = merge_frames(&frx, &storage, cfg, &pool, &tx, journal.as_ref(), &resume);
    drop(tx);
    let mut report = match merged {
        Ok(report) => {
            // Clean end: every reader saw EOF, so the joins return.
            for r in readers {
                r.join().expect("stripe reader panicked");
            }
            report
        }
        // Error: don't join — readers exit once frx drops (their sends
        // fail) and the verify worker exits when the sender's control
        // socket dies; blocking here could hang a live peer's error path.
        Err(e) => return Err(e),
    };
    let stats = worker.join().expect("verify worker panicked")?;
    report.units_verified = stats.0;
    report.units_failed = stats.1;
    report.io_backend = storage.backend_name().to_string();
    report.storage_syncs = storage.sync_count();
    report.direct_fallbacks = storage.direct_fallbacks();
    report.uring_fallbacks = storage.uring_fallbacks();
    report.storage_hints = storage.hint_count();
    Ok(report)
}

/// Finalize a file if its data is fully in and its FileEnd was seen.
fn maybe_finish(
    open: &mut HashMap<u32, FileState>,
    file_idx: u32,
    report: &mut ReceiverReport,
) -> Result<()> {
    let complete = open.get(&file_idx).map(|st| st.complete()).unwrap_or(false);
    if complete {
        let mut st = open.remove(&file_idx).expect("checked above");
        st.finish()?;
        report.files_received += 1;
    }
    Ok(())
}

/// The merger: route frames from all stripes to per-file state until every
/// reader hits EOF. Returns the partially-filled report (verify counters
/// are added by the caller).
fn merge_frames(
    frx: &mpsc::Receiver<Result<Frame>>,
    storage: &Arc<dyn Storage>,
    cfg: &SessionConfig,
    pool: &PoolHandle,
    tx: &mpsc::Sender<Event>,
    journal: Option<&Journal>,
    resume: &ResumePlan,
) -> Result<ReceiverReport> {
    let mut report = ReceiverReport::default();
    let mut open: HashMap<u32, FileState> = HashMap::new();
    // FileStart order — the blocking end-of-stream spill drain must run
    // oldest-first (see the deadlock-freedom note below).
    let mut start_order: Vec<u32> = Vec::new();
    let mut names: HashMap<u32, String> = HashMap::new();
    // Data frames whose FileStart (stripe 0) has not arrived yet —
    // bounded by stripe skew, drained on FileStart.
    let mut early: HashMap<u32, Vec<(u64, SharedBuf)>> = HashMap::new();
    // Byte spans rewritten by Fix frames since the last FixEnd, per file,
    // plus one scatter-write batch per file: payloads accumulate as
    // refcounted views and land as coalesced `write_at_vectored` calls —
    // a multi-leaf repair run is one positioned syscall, not one per
    // frame (and one open + one sync per batch).
    let mut fix_ranges: HashMap<u32, Vec<(u64, u64)>> = HashMap::new();
    let mut fix_batches: HashMap<u32, FixBatch> = HashMap::new();
    // Files being reconstructed incrementally (`DeltaStart`..`DeltaEnd`):
    // literals and copy directives land in a staging file that replaces
    // the destination atomically at `DeltaEnd`.
    let mut delta_open: HashMap<u32, DeltaFileState> = HashMap::new();
    let mut done_seen = false;

    loop {
        let next = match frx.try_recv() {
            Ok(frame) => Some(frame),
            Err(mpsc::TryRecvError::Empty) => {
                // No frame ready. If the oldest open file has spilled
                // queue feeds, this is the moment to push them — and it
                // may be the *only* moment: after the last data frame the
                // sender is waiting on our digests before it closes the
                // sockets, so waiting for EOF here would deadlock. The
                // blocking add is safe oldest-first (see the note below).
                let oldest_spilled = start_order
                    .iter()
                    .copied()
                    .find(|idx| open.contains_key(idx))
                    .filter(|idx| {
                        open.get(idx).map(|st| !st.spill.is_empty()).unwrap_or(false)
                    });
                if let Some(idx) = oldest_spilled {
                    if let Some(st) = open.get_mut(&idx) {
                        st.drain_spill_blocking();
                    }
                    maybe_finish(&mut open, idx, &mut report)?;
                    continue;
                }
                match frx.recv() {
                    Ok(frame) => Some(frame),
                    Err(_) => None,
                }
            }
            Err(mpsc::TryRecvError::Disconnected) => None,
        };
        let Some(frame) = next else { break };
        match frame? {
            Frame::FileStart { file_idx, size, attempt: _, name } => {
                anyhow::ensure!(
                    !names.contains_key(&file_idx),
                    "duplicate FileStart for file {file_idx}"
                );
                names.insert(file_idx, name.clone());
                start_order.push(file_idx);
                let mut st =
                    FileState::new(file_idx, &name, size, cfg, storage, pool, tx, journal, resume)?;
                for (offset, payload) in early.remove(&file_idx).unwrap_or_default() {
                    st.write(offset, payload)?;
                }
                // Even a zero-size or fully-early file waits for FileEnd.
                open.insert(file_idx, st);
            }
            Frame::Data { file_idx, offset, payload } => {
                report.bytes_received += payload.len() as u64;
                if let Some(st) = delta_open.get_mut(&file_idx) {
                    // Dirty-leaf literals of a delta reconstruction.
                    st.write_literal(offset, &payload)?;
                } else if let Some(st) = open.get_mut(&file_idx) {
                    st.write(offset, payload)?;
                } else {
                    // A stripe outran stripe 0's FileStart (or, worse,
                    // trails a finished file — that means duplicate data
                    // and must fail).
                    anyhow::ensure!(
                        !names.contains_key(&file_idx),
                        "Data for already-finished file {file_idx}"
                    );
                    early.entry(file_idx).or_default().push((offset, payload));
                }
                maybe_finish(&mut open, file_idx, &mut report)?;
            }
            Frame::FileEnd { file_idx } => {
                open.get_mut(&file_idx)
                    .with_context(|| format!("FileEnd for unknown file {file_idx}"))?
                    .end_requested = true;
                maybe_finish(&mut open, file_idx, &mut report)?;
            }
            Frame::Fix { file_idx, offset, payload } => {
                // Repairs may interleave with later files' streams; route
                // by the name recorded at FileStart.
                let name = names
                    .get(&file_idx)
                    .with_context(|| format!("Fix for unknown file {file_idx}"))?;
                let b = match fix_batches.entry(file_idx) {
                    std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
                    std::collections::hash_map::Entry::Vacant(e) => {
                        e.insert(FixBatch::new(storage.open_update(name)?))
                    }
                };
                report.bytes_repaired += payload.len() as u64;
                fix_ranges.entry(file_idx).or_default().push((offset, payload.len() as u64));
                b.push(offset, payload)?;
            }
            Frame::FixEnd { file_idx, unit } => {
                // Land the batch and make it durable before the verify
                // worker re-hashes the repaired ranges from storage (and
                // before the journal digests claiming those bytes do).
                if let Some(mut b) = fix_batches.remove(&file_idx) {
                    b.finish()?;
                }
                let ranges = fix_ranges.remove(&file_idx).unwrap_or_default();
                // Journaled leaf digests describing the patched bytes are
                // stale now: recompute them from the repaired storage.
                if let Some(st) = open.get_mut(&file_idx) {
                    st.jrn_patch(&ranges, storage)?;
                } else if let (Some(j), Some(name)) = (journal, names.get(&file_idx)) {
                    let leaf_factory = cfg.leaf_factory();
                    j.patch_record(name, &ranges, |off, len| {
                        hash_leaf_sig(storage, name, off, len, &leaf_factory)
                    })?;
                }
                tx.send(Event::Repaired { file_idx, unit, ranges }).ok();
            }
            Frame::DeltaStart { file_idx, size, name } => {
                anyhow::ensure!(
                    !names.contains_key(&file_idx),
                    "duplicate start for file {file_idx}"
                );
                names.insert(file_idx, name.clone());
                let st = DeltaFileState::new(&name, size, cfg, storage)?;
                delta_open.insert(file_idx, st);
            }
            Frame::DeltaCopy { file_idx, new_off, old_off, len } => {
                delta_open
                    .get_mut(&file_idx)
                    .with_context(|| format!("DeltaCopy for unknown file {file_idx}"))?
                    .copy(new_off, old_off, len)?;
            }
            Frame::DeltaEnd { file_idx } => {
                let st = delta_open
                    .remove(&file_idx)
                    .with_context(|| format!("DeltaEnd for unknown file {file_idx}"))?;
                let DeltaFileState { name, staging, size, mut writer, reader, .. } = st;
                // Make the reconstruction durable, then swap it in
                // atomically — the destination is never observable in a
                // half-reconstructed state.
                writer.flush()?;
                writer.sync()?;
                drop(writer);
                drop(reader);
                storage.rename(&staging, &name)?;
                report.files_received += 1;
                // Verification + fresh journal state: re-hash the
                // reconstructed file from storage on the shared pool (the
                // integrity backstop — a stale or lying basis surfaces as
                // a TreeRoot mismatch and is repaired by Fix frames).
                let verify = cfg.algorithm != RealAlgorithm::TransferOnly;
                if verify || journal.is_some() {
                    let storage2 = storage.clone();
                    let cfg2 = cfg.clone();
                    let j2 = journal.cloned();
                    let tx2 = tx.clone();
                    let hobs = cfg.obs.shard("recv-hash");
                    pool.submit(move || {
                        let rehash =
                            delta_rehash(&storage2, &name, size, &cfg2, j2.as_ref(), &hobs);
                        if verify {
                            // An unreadable reconstruction yields a
                            // placeholder tree: the root mismatch surfaces
                            // the failure through the normal verdict path
                            // instead of hanging the sender.
                            let tree = rehash.unwrap_or_else(|_| {
                                MerkleBuilder::new(cfg2.leaf_size, cfg2.leaf_factory())
                                    .with_tree_hasher(cfg2.node_factory(), cfg2.tree_rooted())
                                    .finish()
                            });
                            tx2.send(Event::VerifyTree { file_idx, name, tree }).ok();
                        }
                    });
                }
            }
            Frame::Done => done_seen = true,
            other => bail!("unexpected frame on data channel: {other:?}"),
        }
        // Retry spilled queue feeds — their pool job may have started
        // draining since — and finalize anything that completed.
        let spilled: Vec<u32> = open
            .iter()
            .filter(|(_, st)| !st.spill.is_empty())
            .map(|(&idx, _)| idx)
            .collect();
        for idx in spilled {
            if let Some(st) = open.get_mut(&idx) {
                st.pump_spill();
            }
            maybe_finish(&mut open, idx, &mut report)?;
        }
    }
    anyhow::ensure!(done_seen, "data channels closed before Done");
    anyhow::ensure!(early.is_empty(), "data for files that never started: {:?}", early.keys());
    anyhow::ensure!(
        delta_open.is_empty(),
        "delta reconstructions never ended: {:?}",
        delta_open.keys()
    );
    // End of stream: any still-open file either lost data (error) or has
    // spilled queue feeds awaiting a pool worker. Draining those may
    // block, which is safe *only* here and *only* oldest-first: the pool
    // runs jobs FIFO, so the globally earliest unfinished hash job is
    // always running, and it belongs to some session's oldest open file —
    // exactly the queue that session's merger is draining.
    for idx in start_order {
        let Some(mut st) = open.remove(&idx) else { continue };
        anyhow::ensure!(
            st.end_requested && st.contiguous >= st.size,
            "file {idx} ({}) ended short: {} contiguous bytes of {}",
            st.name,
            st.contiguous,
            st.size
        );
        st.drain_spill_blocking();
        st.finish()?;
        report.files_received += 1;
    }
    Ok(report)
}

/// A scatter batch of repair (`Fix`) payloads for one file: refcounted
/// views accumulate (bounded by [`FixBatch::MAX_BUFFERED`]) and land as
/// coalesced [`crate::storage::WriteStream::write_at_vectored`] calls —
/// adjacent frames of one repaired leaf run become a single positioned
/// vectored write.
struct FixBatch {
    writer: Box<dyn crate::storage::WriteStream>,
    parts: Vec<(u64, SharedBuf)>,
    buffered: usize,
}

impl FixBatch {
    /// Flush threshold: a massive repair must not pin unbounded payload
    /// memory behind refcounts.
    const MAX_BUFFERED: usize = 4 << 20;

    fn new(writer: Box<dyn crate::storage::WriteStream>) -> FixBatch {
        FixBatch { writer, parts: Vec::new(), buffered: 0 }
    }

    fn push(&mut self, offset: u64, payload: SharedBuf) -> Result<()> {
        self.buffered += payload.len();
        self.parts.push((offset, payload));
        if self.buffered >= Self::MAX_BUFFERED {
            self.flush()?;
        }
        Ok(())
    }

    /// Land everything buffered: consecutive contiguous parts coalesce
    /// into one scatter write each.
    fn flush(&mut self) -> Result<()> {
        let parts = std::mem::take(&mut self.parts);
        self.buffered = 0;
        let mut i = 0;
        while i < parts.len() {
            let start = parts[i].0;
            let mut end = start + parts[i].1.len() as u64;
            let mut j = i + 1;
            while j < parts.len() && parts[j].0 == end {
                end += parts[j].1.len() as u64;
                j += 1;
            }
            let slices: Vec<&[u8]> = parts[i..j].iter().map(|(_, b)| &b[..]).collect();
            self.writer.write_at_vectored(start, &slices)?;
            i = j;
        }
        Ok(())
    }

    /// Flush and make the repairs durable (called at `FixEnd`).
    fn finish(&mut self) -> Result<()> {
        self.flush()?;
        self.writer.sync()
    }
}

/// Per-file state of an incremental reconstruction
/// (`DeltaStart`..`DeltaEnd`): literal `Data` frames land at their offset
/// in a staging file, `DeltaCopy` directives pull unchanged leaf runs out
/// of the old destination, and `DeltaEnd` renames the staging file over
/// the destination atomically.
struct DeltaFileState {
    name: String,
    staging: String,
    size: u64,
    /// The staging file being reconstructed.
    writer: Box<dyn crate::storage::WriteStream>,
    /// The old destination — the copy source for unchanged leaves.
    reader: Box<dyn crate::storage::ReadStream>,
    /// Reusable bounce buffer for copy directives.
    buf: Vec<u8>,
    obs: Shard,
}

impl DeltaFileState {
    fn new(
        name: &str,
        size: u64,
        cfg: &SessionConfig,
        storage: &Arc<dyn Storage>,
    ) -> Result<DeltaFileState> {
        let staging = super::delta::staging_name(name);
        let writer = storage.open_write_sized(&staging, size)?;
        let reader = storage
            .open_read(name)
            .with_context(|| format!("delta basis {name} vanished before reconstruction"))?;
        Ok(DeltaFileState {
            name: name.to_string(),
            staging,
            size,
            writer,
            reader,
            buf: vec![0u8; 256 * 1024],
            obs: cfg.obs.shard("recv-delta"),
        })
    }

    /// A dirty-leaf literal run from the wire.
    fn write_literal(&mut self, offset: u64, data: &[u8]) -> Result<()> {
        anyhow::ensure!(
            offset + data.len() as u64 <= self.size,
            "delta literal past announced size of {}",
            self.name
        );
        let t = self.obs.start();
        self.writer.write_at(offset, data)?;
        self.obs.record(Stage::Write, t);
        Ok(())
    }

    /// A clean-leaf copy directive: pull `[old_off, old_off+len)` of the
    /// old destination into `[new_off, ...)` of the staging file.
    fn copy(&mut self, new_off: u64, old_off: u64, len: u64) -> Result<()> {
        anyhow::ensure!(
            new_off + len <= self.size,
            "delta copy past announced size of {}",
            self.name
        );
        let t = self.obs.start();
        let mut done = 0u64;
        while done < len {
            let want = self.buf.len().min((len - done) as usize);
            let n = self.reader.read_at(old_off + done, &mut self.buf[..want])?;
            anyhow::ensure!(
                n > 0,
                "short read of delta basis {} at {}",
                self.name,
                old_off + done
            );
            self.writer.write_at(new_off + done, &self.buf[..n])?;
            done += n as u64;
        }
        self.obs.record(Stage::Write, t);
        Ok(())
    }
}

/// Rebuild verification and journal state for a delta-reconstructed file:
/// one sequential read of the renamed destination feeds the digest tree
/// (for the TreeRoot exchange) and a fresh v2 journal record (so the
/// *next* delta run gets its signature basis for free). Reading back what
/// storage actually holds — rather than trusting the reconstruction —
/// is the delta path's end-to-end integrity guarantee.
fn delta_rehash(
    storage: &Arc<dyn Storage>,
    name: &str,
    size: u64,
    cfg: &SessionConfig,
    journal: Option<&Journal>,
    obs: &Shard,
) -> Result<MerkleTree> {
    let factory = &cfg.leaf_factory();
    let dlen = factory().digest_len();
    let leaf_size = cfg.leaf_size;
    let mut fj = match journal {
        Some(j) => Some(j.create(name, size, leaf_size, dlen)?),
        None => None,
    };
    let total_leaves = crate::merkle::leaf_count(size, leaf_size) as usize;
    let mut leaves = Vec::with_capacity(total_leaves * dlen);
    let mut tracker = LeafTracker::new(leaf_size, factory);
    let mut r = storage.open_read(name)?;
    let mut buf = vec![0u8; 256 * 1024];
    let mut pos = 0u64;
    while pos < size {
        let want = buf.len().min((size - pos) as usize);
        let n = r.read_at(pos, &mut buf[..want])?;
        anyhow::ensure!(n > 0, "short read rehashing {name} at {pos}");
        let t = obs.start();
        tracker.update(&buf[..n], |_, d, w| {
            if let Some(fj) = fj.as_mut() {
                fj.push_leaf(&d, w);
            }
            leaves.extend_from_slice(&d);
        });
        obs.record(Stage::Hash, t);
        pos += n as u64;
    }
    tracker.finish(|_, d, w| {
        if let Some(fj) = fj.as_mut() {
            fj.push_leaf(&d, w);
        }
        leaves.extend_from_slice(&d);
    });
    if let Some(mut fj) = fj {
        // The data was fsynced before the staging rename, so the journal
        // may attest it immediately (data-before-journal holds).
        let t = obs.start();
        fj.checkpoint()?;
        obs.record(Stage::Journal, t);
    }
    Ok(MerkleTree::from_leaves(
        leaf_size,
        size,
        dlen,
        leaves,
        &cfg.node_factory(),
        cfg.tree_rooted(),
    ))
}

/// Per-file receive state. Bytes may arrive out of order across stripes;
/// storage writes go straight to their offset while the queue feed (and
/// the completed-unit emission for re-read mode) follows the contiguous
/// prefix.
struct FileState {
    file_idx: u32,
    name: String,
    size: u64,
    /// End of the contiguous prefix received so far (starts at the
    /// negotiated resume offset for a resumed file).
    contiguous: u64,
    /// Out-of-order spans past the prefix: offset -> len.
    spans: BTreeMap<u64, u64>,
    /// Queue/journal mode only: out-of-order payloads awaiting their
    /// turn. A stashed entry is a refcount on the already-written pooled
    /// buffer, not a copy.
    stash: BTreeMap<u64, SharedBuf>,
    /// Queue mode only: in-order payloads the queue had no room for (its
    /// hash job may still be waiting for a pool worker). The merger spills
    /// instead of blocking — see the drain note in `merge_frames`. Spilled
    /// entries are refcounted views, not re-owned copies.
    spill: VecDeque<SharedBuf>,
    writer: Box<dyn crate::storage::WriteStream>,
    /// Queue for FIVER-mode files; its hash job runs on the shared pool.
    queue: Option<ByteQueue>,
    /// Checkpoint journal for this file: the in-order stream folds into
    /// leaf digests, checkpointed (data sync, then journal append+fsync)
    /// every `jrn_checkpoint` completed leaves.
    jrn: Option<(FileJournal, LeafTracker)>,
    jrn_checkpoint: u64,
    /// Hasher factory for journal-leaf recomputes after repairs.
    hasher: HasherFactory,
    /// Re-read mode: units pending emission as the contiguous prefix
    /// crosses their end offset (lets block-level checksums overlap the
    /// next block's data).
    pending_units: Vec<(u64, u64, u64)>,
    /// FileEnd seen (data may still be in flight on other stripes).
    end_requested: bool,
    tx: mpsc::Sender<Event>,
    /// Merger-side span shard (write/journal/queue_wait stages).
    obs: Shard,
}

impl FileState {
    #[allow(clippy::too_many_arguments)]
    fn new(
        file_idx: u32,
        name: &str,
        size: u64,
        cfg: &SessionConfig,
        storage: &Arc<dyn Storage>,
        pool: &PoolHandle,
        tx: &mpsc::Sender<Event>,
        journal: Option<&Journal>,
        resume: &ResumePlan,
    ) -> Result<FileState> {
        // A handshake-agreed partial file resumes: contiguous starts at
        // the agreed offset, the destination opens without truncation,
        // and verification runs on the journal's digest tree (prefix
        // leaves + streamed tail) regardless of the session algorithm.
        let resumed = resume.partial_for(name, size).cloned();
        let start_at = resumed.as_ref().map(|r| r.offset).unwrap_or(0);
        let writer = if start_at > 0 {
            storage.open_update(name)?
        } else {
            // The announced size lets pre-sizing backends (mmap) map the
            // whole destination once and never remap mid-stream.
            storage.open_write_sized(name, size)?
        };
        let uses_queue = resumed.is_some() || cfg.algorithm.uses_queue(size, cfg.hybrid_threshold);
        let units = cfg.units_of(size, uses_queue);
        let verify = cfg.algorithm != RealAlgorithm::TransferOnly;
        // Tree-building files (FIVER-Merkle, and every resumed file) fold
        // the journal inside the hash job: one pass feeds both the tree
        // leaves and the checkpoint record, so journaling stops paying a
        // second in-memory hash of the stream. Data-before-journal holds
        // because the job's `sync_data` closure fdatasyncs the
        // destination inode (which settles mmap-dirtied pages too) before
        // each checkpoint, and the job only ever sees bytes the merger
        // already wrote.
        let tree_mode = uses_queue
            && verify
            && (resumed.is_some() || cfg.algorithm == RealAlgorithm::FiverMerkle);

        let queue = if uses_queue && verify {
            let q = ByteQueue::new(cfg.queue_capacity);
            let q2 = q.clone();
            let hasher_factory = cfg.leaf_factory();
            let tx2 = tx.clone();
            let name2 = name.to_string();
            let hobs = cfg.obs.shard("recv-hash");
            if tree_mode {
                let fold = match journal {
                    Some(j) => {
                        let s2 = storage.clone();
                        let n2 = name.to_string();
                        let sync: super::journal::DataSync = Box::new(move || s2.sync_file(&n2));
                        Some(j.begin_fold(name, size, start_at, cfg, Some(sync))?)
                    }
                    None => None,
                };
                let prefix = resumed.as_ref().map(|rf| (rf.leaves.clone(), rf.offset));
                let leaf_size = cfg.leaf_size;
                let node_factory = cfg.node_factory();
                let rooted = cfg.tree_rooted();
                pool.submit(move || {
                    let tree = queue_build_tree_fold(
                        q2,
                        leaf_size,
                        size,
                        prefix,
                        hasher_factory,
                        node_factory,
                        rooted,
                        fold,
                        hobs,
                    );
                    tx2.send(Event::VerifyTree { file_idx, name: name2, tree }).ok();
                });
            } else {
                let units2 = units.clone();
                pool.submit(move || {
                    queue_hash_units(
                        q2,
                        &units2,
                        hasher_factory,
                        hobs,
                        |unit, offset, len, digest| {
                            tx2.send(Event::Verify {
                                file_idx,
                                name: name2.clone(),
                                unit,
                                offset,
                                len,
                                digest: Some(digest),
                            })
                            .ok();
                        },
                    );
                });
            }
            Some(q)
        } else {
            None
        };
        // Stream-side journal record (policies that build no tree):
        // resumed files truncate to the agreed prefix and append from
        // there; fresh files start a new record. Tree-mode files journal
        // inside the hash job instead (see above).
        let jrn = if tree_mode {
            None
        } else {
            match journal {
                Some(j) => Some(j.begin_file(name, size, start_at, cfg)?),
                None => None,
            }
        };
        Ok(FileState {
            file_idx,
            name: name.to_string(),
            size,
            contiguous: start_at,
            spans: BTreeMap::new(),
            stash: BTreeMap::new(),
            spill: VecDeque::new(),
            writer,
            queue,
            jrn,
            jrn_checkpoint: cfg.journal_checkpoint_leaves.max(1),
            hasher: cfg.leaf_factory(),
            pending_units: if verify && !uses_queue && resumed.is_none() {
                units
            } else {
                Vec::new()
            },
            end_requested: false,
            tx: tx.clone(),
            obs: cfg.obs.shard("recv-merge"),
        })
    }

    fn write(&mut self, offset: u64, payload: SharedBuf) -> Result<()> {
        let t = self.obs.start();
        self.writer.write_at(offset, &payload)?;
        self.obs.record(Stage::Write, t);
        let len = payload.len() as u64;
        if offset == self.contiguous {
            // Algorithm 2 line 7: share the received buffer with the
            // checksum job — the storage write borrowed it above, the
            // journal tracker borrows it here, the queue takes a
            // refcount; no re-read, no copy.
            self.jrn_feed_buf(&payload)?;
            self.feed(payload);
            self.contiguous += len;
            // Pull any stashed successors into the prefix.
            loop {
                let head = self.spans.iter().next().map(|(&o, &l)| (o, l));
                let Some((o, l)) = head else { break };
                if o != self.contiguous {
                    break;
                }
                self.spans.remove(&o);
                if let Some(buf) = self.stash.remove(&o) {
                    self.jrn_feed_buf(&buf)?;
                    self.feed(buf);
                }
                self.contiguous += l;
            }
        } else {
            anyhow::ensure!(
                offset > self.contiguous,
                "overlapping data at {offset} (contiguous prefix {})",
                self.contiguous
            );
            self.spans.insert(offset, len);
            // The journal (like the queue) consumes the stream in order,
            // so out-of-order payloads stash in both modes.
            if self.queue.is_some() || self.jrn.is_some() {
                self.stash.insert(offset, payload);
            }
        }
        self.emit_completed_units(false);
        Ok(())
    }

    /// Fold an in-order payload into the journal tracker; checkpoint
    /// (data sync, then journal append+fsync) every `jrn_checkpoint`
    /// completed leaves, so the journal never attests bytes the storage
    /// could still lose.
    fn jrn_feed_buf(&mut self, data: &[u8]) -> Result<()> {
        let Some((fj, tracker)) = self.jrn.as_mut() else { return Ok(()) };
        let t = self.obs.start();
        tracker.update(data, |_, d, w| fj.push_leaf(&d, w));
        if fj.pending_leaves() >= self.jrn_checkpoint {
            self.writer.sync()?;
            fj.checkpoint()?;
        }
        self.obs.record(Stage::Journal, t);
        Ok(())
    }

    /// Repair `Fix` frames rewrote `ranges`: recompute the journaled leaf
    /// digests they touch from the repaired storage, and rebuild the open
    /// partial leaf's hasher state when the repair reached into it (at
    /// most one leaf re-read per file).
    fn jrn_patch(&mut self, ranges: &[(u64, u64)], storage: &Arc<dyn Storage>) -> Result<()> {
        let Some((fj, tracker)) = self.jrn.as_mut() else { return Ok(()) };
        let leaf = tracker.leaf_size();
        let completed = tracker.completed_leaves();
        // Completed-leaf hits share journal.rs's range->leaf mapping.
        let dirty = super::journal::leaves_touched(ranges, leaf, completed);
        let partial_dirty = ranges.iter().any(|&(off, len)| {
            len > 0 && off / leaf <= completed && completed <= (off + len - 1) / leaf
        });
        for &l in &dirty {
            let loff = l * leaf;
            let llen = leaf.min(self.size - loff);
            let (d, w) = hash_leaf_sig(storage, &self.name, loff, llen, &self.hasher)?;
            fj.overwrite_leaf(l, &d, w)?;
        }
        if partial_dirty && tracker.filled() > 0 {
            // Re-read the open leaf's prefix from storage and rebuild the
            // incremental hasher over the repaired bytes.
            let start = completed * leaf;
            let take = tracker.filled() as usize;
            let mut buf = vec![0u8; take];
            let mut r = storage.open_read(&self.name)?;
            let mut got = 0usize;
            while got < take {
                let n = r.read_at(start + got as u64, &mut buf[got..])?;
                anyhow::ensure!(n > 0, "short read rebuilding journal leaf of {}", self.name);
                got += n;
            }
            tracker.rebuild_partial(&buf);
        }
        if !dirty.is_empty() {
            self.writer.sync()?;
            fj.sync()?;
        }
        Ok(())
    }

    /// Hand an in-order buffer to the checksum queue without ever
    /// blocking the merger (spill on a full queue).
    fn feed(&mut self, payload: SharedBuf) {
        let Some(q) = &self.queue else { return };
        if self.spill.is_empty() {
            if let Err(back) = q.try_add(payload) {
                self.spill.push_back(back);
            }
        } else {
            self.spill.push_back(payload);
        }
        self.obs.gauge_depth(q.len_bytes() as u64);
    }

    /// Retry spilled feeds (non-blocking).
    fn pump_spill(&mut self) {
        let Some(q) = &self.queue else { return };
        while let Some(front) = self.spill.pop_front() {
            match q.try_add(front) {
                Ok(()) => {}
                Err(back) => {
                    self.spill.push_front(back);
                    break;
                }
            }
        }
    }

    /// End-of-stream drain: blocking adds are safe only in the merger's
    /// oldest-first post-loop (see `merge_frames`).
    fn drain_spill_blocking(&mut self) {
        if let Some(q) = &self.queue {
            for buf in self.spill.drain(..) {
                let t = self.obs.start();
                q.add(buf);
                self.obs.record(Stage::QueueWait, t);
            }
        }
    }

    /// All announced bytes received, the sender declared the end, and the
    /// checksum queue has everything (no spill pending).
    fn complete(&self) -> bool {
        self.end_requested && self.contiguous >= self.size && self.spill.is_empty()
    }

    /// Emit re-read-mode verification jobs for fully received units.
    fn emit_completed_units(&mut self, at_eof: bool) {
        while let Some(&(unit, offset, len)) = self.pending_units.first() {
            let done = self.contiguous >= offset + len && (len > 0 || at_eof || self.size == 0);
            if !done {
                break;
            }
            self.tx
                .send(Event::Verify {
                    file_idx: self.file_idx,
                    name: self.name.clone(),
                    unit,
                    offset,
                    len,
                    digest: None,
                })
                .ok();
            self.pending_units.remove(0);
        }
    }

    fn finish(&mut self) -> Result<()> {
        self.writer.flush()?;
        if let Some(q) = self.queue.take() {
            q.close();
        }
        self.emit_completed_units(true);
        anyhow::ensure!(
            self.pending_units.is_empty() && self.spans.is_empty() && self.spill.is_empty(),
            "file {} ended short: {} contiguous bytes of {}",
            self.name,
            self.contiguous,
            self.size
        );
        // Close the journal record: final (partial) leaf, then the
        // data-before-journal durability pair.
        if let Some((fj, tracker)) = self.jrn.as_mut() {
            tracker.finish(|_, d, w| fj.push_leaf(&d, w));
        }
        if self.jrn.is_some() {
            self.writer.sync()?;
            if let Some((fj, _)) = self.jrn.as_mut() {
                fj.checkpoint()?;
            }
        }
        Ok(())
    }
}

impl Drop for FileState {
    fn drop(&mut self) {
        // Error paths must not leave a pool worker blocked on an open
        // queue forever (the pool's Drop joins its workers).
        if let Some(q) = self.queue.take() {
            q.close();
        }
    }
}

/// Consume a queue, cutting unit digests at the configured boundaries.
/// `units` are (id, offset, len) in stream order, contiguous.
pub(crate) fn queue_hash_units(
    q: ByteQueue,
    units: &[(u64, u64, u64)],
    hasher_factory: super::HasherFactory,
    obs: Shard,
    mut emit: impl FnMut(u64, u64, u64, Vec<u8>),
) {
    let mut idx = 0usize;
    let mut hasher = hasher_factory();
    let mut consumed = 0u64;
    // Zero-length units (empty files) need no data.
    while idx < units.len() && units[idx].2 == 0 {
        let (u, o, l) = units[idx];
        emit(u, o, l, hasher.finalize());
        hasher.reset();
        idx += 1;
    }
    while idx < units.len() {
        // The blocking `remove` (waiting for stream bytes) is *not* hash
        // busy time — only the digesting of a drained buffer is.
        let Some(buf) = q.remove() else { break };
        let t = obs.start();
        let mut slice = &buf[..];
        while !slice.is_empty() && idx < units.len() {
            let (unit, offset, len) = units[idx];
            let take = ((len - consumed) as usize).min(slice.len());
            hasher.update(&slice[..take]);
            consumed += take as u64;
            slice = &slice[take..];
            if consumed == len {
                emit(unit, offset, len, hasher.finalize());
                hasher.reset();
                consumed = 0;
                idx += 1;
            }
        }
        obs.record(Stage::Hash, t);
    }
    // Queue closed early (short stream): emit the partial unit so
    // verification fails closed rather than hanging the session.
    if idx < units.len() && consumed > 0 {
        let (unit, offset, len) = units[idx];
        emit(unit, offset, len, hasher.finalize());
    }
}

/// Consume a queue into a digest tree — FIVER-Merkle's COMPUTECHECKSUM,
/// the tree-shaped twin of [`queue_hash_units`]; *both* endpoints drain
/// their queue through this one function (fresh files pass
/// `prefix = None`, resumed files their handshake-agreed prefix leaves),
/// which keeps the two trees provably identical — the TreeRoot
/// comparison's soundness rests on that.
///
/// When a [`JournalFold`] is given, each completed leaf digest also
/// appends to the file's checkpoint record (with the data-before-journal
/// sync ordering at the configured cadence): the one hash pass this job
/// already performs serves verification *and* journaling, so FIVER-Merkle
/// and resumed files stop paying the stream-side `LeafTracker`'s second
/// in-memory hash.
///
/// The final (partial) leaf — and the final checkpoint — are emitted only
/// when the stream actually completed (`prefix + streamed == size`). A
/// crash-truncated stream must never journal a digest over partial
/// final-leaf bytes: both endpoints could otherwise agree on a bogus
/// "complete" record at the resume handshake and skip undelivered tail
/// bytes. In the truncated case the returned tree is a placeholder (the
/// session is already dead; nobody exchanges it).
pub(crate) fn queue_build_tree_fold(
    q: ByteQueue,
    leaf_size: u64,
    size: u64,
    prefix: Option<(Vec<u8>, u64)>,
    hasher_factory: super::HasherFactory,
    node_factory: super::HasherFactory,
    rooted: bool,
    mut journal: Option<JournalFold>,
    obs: Shard,
) -> MerkleTree {
    let dlen = hasher_factory().digest_len();
    let (mut leaves, prefix_bytes) = prefix.unwrap_or((Vec::new(), 0));
    debug_assert!(prefix_bytes % leaf_size == 0, "resume prefix must be leaf-aligned");
    // Pre-size the digest vec from the announced file size so a large
    // file's build never reallocates mid-stream (PR 3's
    // MerkleBuilder::with_capacity guarantee, preserved).
    let total_leaves = crate::merkle::leaf_count(size, leaf_size) as usize;
    leaves.reserve((total_leaves * dlen).saturating_sub(leaves.len()));
    let mut tracker = LeafTracker::resume(leaf_size, &hasher_factory, prefix_bytes / leaf_size);
    let mut streamed = 0u64;
    while let Some(buf) = q.remove() {
        streamed += buf.len() as u64;
        let t = obs.start();
        tracker.update(&buf, |_, d, w| {
            if let Some(j) = journal.as_mut() {
                j.push_leaf(&d, w);
            }
            leaves.extend_from_slice(&d);
        });
        obs.record(Stage::Hash, t);
    }
    let complete = prefix_bytes + streamed == size;
    if complete {
        let t = obs.start();
        tracker.finish(|_, d, w| {
            if let Some(j) = journal.as_mut() {
                j.push_leaf(&d, w);
            }
            leaves.extend_from_slice(&d);
        });
        obs.record(Stage::Hash, t);
    }
    if let Some(mut j) = journal.take() {
        let t = obs.start();
        j.finish();
        obs.record(Stage::Journal, t);
    }
    if !complete {
        return MerkleBuilder::new(leaf_size, hasher_factory)
            .with_tree_hasher(node_factory, rooted)
            .finish();
    }
    // Interior/root folding is the tier's cryptographic anchor; attribute
    // it to its own stage so per-tier reports can split leaf vs tree cost.
    let t = obs.start();
    let tree = MerkleTree::from_leaves(leaf_size, size, dlen, leaves, &node_factory, rooted);
    obs.record(Stage::TreeHash, t);
    tree
}

/// The verify worker: digests out, verdicts in, repair loop.
fn verify_worker(
    ctrl: TcpStream,
    storage: Arc<dyn Storage>,
    cfg: &SessionConfig,
    rx: mpsc::Receiver<Event>,
) -> Result<(u64, u64)> {
    let mut ctrl_in = BufReader::new(ctrl.try_clone().context("ctrl clone")?);
    let mut ctrl_out = BufWriter::new(ctrl);
    let obs = cfg.obs.shard("recv-verify");
    let mut verified = 0u64;
    let mut failed = 0u64;
    let mut stash: std::collections::VecDeque<Event> = Default::default();

    loop {
        let ev = match stash.pop_front() {
            Some(e) => e,
            None => match rx.recv() {
                Ok(e) => e,
                Err(_) => break, // all senders dropped: session over
            },
        };
        let (file_idx, name, unit, offset, len, digest) = match ev {
            Event::Verify { file_idx, name, unit, offset, len, digest } => {
                (file_idx, name, unit, offset, len, digest)
            }
            Event::VerifyTree { file_idx, name, tree } => {
                let (v, f) = verify_tree_exchange(
                    &mut ctrl_in,
                    &mut ctrl_out,
                    &storage,
                    cfg,
                    &rx,
                    &mut stash,
                    file_idx,
                    &name,
                    tree,
                    &obs,
                )?;
                verified += v;
                failed += f;
                continue;
            }
            // Stray Repaired with no pending verification.
            Event::Repaired { .. } => continue,
        };
        // Compute (re-read mode) or take (queue mode) the digest.
        let mut digest = match digest {
            Some(d) => d,
            None => {
                let t = obs.start();
                let d = hash_range(&storage, &name, offset, len, &cfg.leaf_factory())?;
                obs.record(Stage::Hash, t);
                d
            }
        };
        loop {
            let t = obs.start();
            Frame::Digest { file_idx, unit, digest: digest.clone() }.write_to(&mut ctrl_out)?;
            use std::io::Write;
            ctrl_out.flush()?;
            let verdict =
                Frame::read_from(&mut ctrl_in)?.context("ctrl channel closed awaiting verdict")?;
            obs.record(Stage::Verify, t);
            match verdict {
                Frame::Verdict { file_idx: fi, unit: u, ok } => {
                    anyhow::ensure!(
                        fi == file_idx && u == unit,
                        "verdict for wrong unit ({fi},{u}) != ({file_idx},{unit})"
                    );
                    if ok {
                        verified += 1;
                        // Delivered bytes verified: they won't be
                        // re-hashed, so the page cache can let them go.
                        storage.advise_done(&name, offset, len).ok();
                        break;
                    }
                    failed += 1;
                    // Wait for the repairs to land (FixEnd), stashing other
                    // files' verification events that arrive meanwhile
                    // (FIVER keeps streaming during recovery).
                    loop {
                        match rx.recv() {
                            Ok(Event::Repaired { file_idx: fi, unit: u, ranges: _ })
                                if fi == file_idx && u == unit =>
                            {
                                break;
                            }
                            Ok(other) => stash.push_back(other),
                            Err(_) => bail!("session ended mid-repair"),
                        }
                    }
                    let t = obs.start();
                    digest = hash_range(&storage, &name, offset, len, &cfg.leaf_factory())?;
                    obs.record(Stage::Repair, t);
                }
                other => bail!("expected Verdict, got {other:?}"),
            }
        }
    }
    Ok((verified, failed))
}

/// FIVER-Merkle receiver loop: offer the tree root; on a mismatch verdict,
/// answer the sender's node-range queries (its binary search down the
/// tree), wait for the repair Fixes to land, patch only the touched leaves
/// from storage (O(k) leaf hashes + O(k log n) combines), and re-offer the
/// fresh root until the sender accepts it.
#[allow(clippy::too_many_arguments)]
fn verify_tree_exchange(
    ctrl_in: &mut BufReader<TcpStream>,
    ctrl_out: &mut BufWriter<TcpStream>,
    storage: &Arc<dyn Storage>,
    cfg: &SessionConfig,
    rx: &mpsc::Receiver<Event>,
    stash: &mut std::collections::VecDeque<Event>,
    file_idx: u32,
    name: &str,
    mut tree: MerkleTree,
    obs: &Shard,
) -> Result<(u64, u64)> {
    use std::io::Write;
    let mut verified = 0u64;
    let mut failed = 0u64;
    loop {
        let t = obs.start();
        Frame::TreeRoot {
            file_idx,
            leaves: tree.leaf_count() as u64,
            leaf_size: tree.leaf_size(),
            digest: tree.root().to_vec(),
        }
        .write_to(ctrl_out)?;
        ctrl_out.flush()?;
        let verdict =
            Frame::read_from(ctrl_in)?.context("ctrl channel closed awaiting tree verdict")?;
        obs.record(Stage::Verify, t);
        let Frame::Verdict { file_idx: fi, unit: _, ok } = verdict else {
            bail!("expected Verdict for tree root, got {verdict:?}");
        };
        anyhow::ensure!(fi == file_idx, "tree verdict for wrong file {fi} != {file_idx}");
        if ok {
            verified += 1;
            // Root accepted: the whole delivered file is verified.
            storage.advise_done(name, 0, 0).ok();
            return Ok((verified, failed));
        }
        failed += 1;
        // Serve the descent queries until the sender announces repairs.
        loop {
            let frame = Frame::read_from(ctrl_in)?.context("ctrl channel closed mid-descent")?;
            match frame {
                Frame::TreeQuery { file_idx: fi, level, start, count } => {
                    anyhow::ensure!(fi == file_idx, "tree query for wrong file");
                    Frame::TreeNodes {
                        file_idx,
                        level,
                        start,
                        digests: tree.nodes_concat(
                            level as usize,
                            start as usize,
                            count as usize,
                        ),
                    }
                    .write_to(ctrl_out)?;
                    ctrl_out.flush()?;
                }
                Frame::TreeRepairSent { .. } => break,
                other => bail!("expected TreeQuery/TreeRepairSent, got {other:?}"),
            }
        }
        // Await the data channel's FixEnd (repairs applied), stashing other
        // files' verification events that arrive meanwhile.
        let ranges = loop {
            match rx.recv() {
                Ok(Event::Repaired { file_idx: fi, unit: _, ranges }) if fi == file_idx => {
                    break ranges;
                }
                Ok(other) => stash.push_back(other),
                Err(_) => bail!("session ended mid-tree-repair"),
            }
        };
        let mut dirty: Vec<usize> = Vec::new();
        for (off, len) in ranges {
            dirty.extend(tree.leaves_touching(off, len));
        }
        dirty.sort_unstable();
        dirty.dedup();
        let t = obs.start();
        let leaf_factory = cfg.leaf_factory();
        for &leaf in &dirty {
            let (off, len) = tree.leaf_range(leaf);
            tree.set_leaf(leaf, hash_range(storage, name, off, len, &leaf_factory)?);
        }
        tree.recompute_paths(&dirty, &cfg.node_factory());
        obs.record(Stage::Repair, t);
    }
}

/// Hash `[offset, offset+len)` of a stored file (checksum via the
/// filesystem — the non-FIVER path, and the repair-recompute path).
pub(crate) fn hash_range(
    storage: &Arc<dyn Storage>,
    name: &str,
    offset: u64,
    len: u64,
    hasher_factory: &super::HasherFactory,
) -> Result<Vec<u8>> {
    let mut h = hasher_factory();
    let mut r = storage.open_read(name)?;
    let mut buf = vec![0u8; 256 * 1024];
    let mut pos = offset;
    let end = offset + len;
    while pos < end {
        let want = buf.len().min((end - pos) as usize);
        let n = r.read_at(pos, &mut buf[..want])?;
        anyhow::ensure!(n > 0, "short read hashing {name} at {pos}");
        h.update(&buf[..n]);
        pos += n as u64;
    }
    Ok(h.finalize())
}

/// Hash `[offset, offset+len)` of a stored file into *both* the strong
/// digest and the rolling weak sum — one read serves the journal's v2
/// leaf entry (repair-recompute and delta-rehash paths).
pub(crate) fn hash_leaf_sig(
    storage: &Arc<dyn Storage>,
    name: &str,
    offset: u64,
    len: u64,
    hasher_factory: &super::HasherFactory,
) -> Result<(Vec<u8>, u32)> {
    let mut h = hasher_factory();
    let mut weak = super::delta::Rolling32::new();
    let mut r = storage.open_read(name)?;
    let mut buf = vec![0u8; 256 * 1024];
    let mut pos = offset;
    let end = offset + len;
    while pos < end {
        let want = buf.len().min((end - pos) as usize);
        let n = r.read_at(pos, &mut buf[..want])?;
        anyhow::ensure!(n > 0, "short read hashing {name} at {pos}");
        h.update(&buf[..n]);
        weak.update(&buf[..n]);
        pos += n as u64;
    }
    Ok((h.finalize(), weak.digest()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::native_factory;
    use crate::coordinator::protocol::UNIT_FILE;
    use crate::hashes::HashAlgorithm;
    use crate::storage::MemStorage;

    #[test]
    fn queue_hash_single_unit_matches_oneshot() {
        let q = ByteQueue::new(1024);
        let data: Vec<u8> = (0u8..=255).cycle().take(1000).collect();
        for part in data.chunks(100) {
            q.add(part.to_vec().into());
        }
        q.close();
        let mut out = Vec::new();
        queue_hash_units(
            q,
            &[(UNIT_FILE, 0, 1000)],
            native_factory(HashAlgorithm::Md5),
            Shard::disabled(),
            |u, o, l, d| out.push((u, o, l, d)),
        );
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, UNIT_FILE);
        let expect = crate::hashes::hex_digest(HashAlgorithm::Md5, &data);
        assert_eq!(crate::util::hex::encode(&out[0].3), expect);
    }

    #[test]
    fn queue_hash_chunked_boundaries() {
        // Buffers deliberately misaligned with the 400-byte units.
        let q = ByteQueue::new(4096);
        let data: Vec<u8> = (0u8..=255).cycle().take(1000).collect();
        for part in data.chunks(333) {
            q.add(part.to_vec().into());
        }
        q.close();
        let units = [(0u64, 0u64, 400u64), (1, 400, 400), (2, 800, 200)];
        let mut out = Vec::new();
        queue_hash_units(
            q,
            &units,
            native_factory(HashAlgorithm::Sha1),
            Shard::disabled(),
            |u, o, l, d| out.push((u, o, l, d)),
        );
        assert_eq!(out.len(), 3);
        for (i, (u, o, l, d)) in out.iter().enumerate() {
            assert_eq!(*u, i as u64);
            let expect = crate::hashes::hex_digest(
                HashAlgorithm::Sha1,
                &data[*o as usize..(*o + *l) as usize],
            );
            assert_eq!(crate::util::hex::encode(d), expect, "unit {u}");
        }
    }

    #[test]
    fn queue_hash_empty_file() {
        let q = ByteQueue::new(16);
        q.close();
        let mut out = Vec::new();
        queue_hash_units(
            q,
            &[(UNIT_FILE, 0, 0)],
            native_factory(HashAlgorithm::Md5),
            Shard::disabled(),
            |u, o, l, d| out.push((u, o, l, d)),
        );
        assert_eq!(out.len(), 1);
        assert_eq!(crate::util::hex::encode(&out[0].3), "d41d8cd98f00b204e9800998ecf8427e");
    }

    #[test]
    fn queue_hash_early_close_emits_partial() {
        let q = ByteQueue::new(64);
        q.add(vec![1, 2, 3].into());
        q.close();
        let mut out = Vec::new();
        let units = [(UNIT_FILE, 0, 100)];
        queue_hash_units(
            q,
            &units,
            native_factory(HashAlgorithm::Md5),
            Shard::disabled(),
            |u, o, l, d| out.push((u, o, l, d)),
        );
        assert_eq!(out.len(), 1, "partial unit must still emit (fail-closed)");
    }

    #[test]
    fn hash_range_matches_slice() {
        let mem = MemStorage::new();
        mem.put("f", (0u8..200).collect());
        let storage: Arc<dyn Storage> = Arc::new(mem);
        let d = hash_range(&storage, "f", 50, 100, &native_factory(HashAlgorithm::Md5)).unwrap();
        let expect = crate::hashes::hex_digest(
            HashAlgorithm::Md5,
            &(0u8..200).collect::<Vec<_>>()[50..150],
        );
        assert_eq!(crate::util::hex::encode(&d), expect);
    }

    #[test]
    fn file_state_spills_when_hash_job_is_starved() {
        // A 1-worker pool held by a gate job: the file's hash job is
        // queued, its tiny queue fills, and merger-side writes must spill
        // rather than block (the deadlock-freedom invariant). Releasing
        // the gate lets the end-of-stream drain feed the job.
        let mem = MemStorage::new();
        let storage: Arc<dyn Storage> = Arc::new(mem.clone());
        let mut cfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Md5));
        cfg.queue_capacity = 4096;
        let pool = HashPool::new(1);
        let handle = pool.handle();
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        handle.submit(move || {
            gate_rx.recv().ok();
        });
        let (tx, rx) = mpsc::channel::<Event>();
        let data: Vec<u8> = (0u8..=255).cycle().take(64 * 1024).collect();
        let size = data.len() as u64;
        let plan = ResumePlan::default();
        let mut st =
            FileState::new(0, "f", size, &cfg, &storage, &handle, &tx, None, &plan).unwrap();
        for (i, chunk) in data.chunks(8 * 1024).enumerate() {
            st.write((i * 8 * 1024) as u64, chunk.to_vec().into()).unwrap();
        }
        assert!(!st.spill.is_empty(), "writes past queue capacity must spill, not block");
        st.end_requested = true;
        assert!(!st.complete(), "spilled feeds block completion");
        gate_tx.send(()).unwrap();
        st.drain_spill_blocking();
        st.finish().unwrap();
        drop(st);
        drop(tx);
        match rx.recv().expect("digest event") {
            Event::Verify { digest: Some(d), .. } => {
                let expect = crate::hashes::hex_digest(HashAlgorithm::Md5, &data);
                assert_eq!(crate::util::hex::encode(&d), expect);
            }
            _ => panic!("expected queue-mode Verify event"),
        }
        assert_eq!(mem.get("f").unwrap(), data);
    }

    /// PROPERTY (spill path): randomized stripe interleavings across
    /// several files, pushed through `ByteQueue::try_add` with a starved
    /// 1-worker pool so the merger *must* spill, then drained oldest-first
    /// exactly as `merge_frames`'s end-of-stream postlude does — every
    /// file's queue-mode digest must equal the digest of its in-order
    /// bytes (per-file byte ordering survives stash + spill), and storage
    /// must hold the exact bytes.
    #[test]
    fn prop_spill_drains_oldest_first_preserving_order() {
        use crate::util::rng::SplitMix64;
        for seed in 0..20u64 {
            let mut rng = SplitMix64::new(seed * 7919 + 5);
            let mem = MemStorage::new();
            let storage: Arc<dyn Storage> = Arc::new(mem.clone());
            let mut cfg =
                SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Md5));
            // Queue far smaller than the files: in-order feeds must spill
            // while the gate starves the pool.
            cfg.queue_capacity = rng.range(2_048, 8_192) as usize;
            let pool = HashPool::new(1);
            let handle = pool.handle();
            let (gate_tx, gate_rx) = mpsc::channel::<()>();
            handle.submit(move || {
                gate_rx.recv().ok();
            });
            let (tx, rx) = mpsc::channel::<Event>();
            let n_files = rng.range(2, 4) as usize;
            let plan = ResumePlan::default();
            let mut datas: Vec<Vec<u8>> = Vec::new();
            let mut states: Vec<FileState> = Vec::new();
            for i in 0..n_files {
                let size = rng.range(20_000, 60_000) as usize;
                let mut data = vec![0u8; size];
                rng.fork().fill_bytes(&mut data);
                let st = FileState::new(
                    i as u32,
                    &format!("s{i}"),
                    size as u64,
                    &cfg,
                    &storage,
                    &handle,
                    &tx,
                    None,
                    &plan,
                )
                .unwrap();
                datas.push(data);
                states.push(st);
            }
            // Random per-file chunkings with bounded per-file reorder
            // (stripe skew: adjacent chunks swap with 50% probability).
            let mut chunks: Vec<VecDeque<(u64, Vec<u8>)>> = Vec::new();
            for data in &datas {
                let mut parts: Vec<(u64, Vec<u8>)> = Vec::new();
                let mut off = 0usize;
                while off < data.len() {
                    let len = (rng.range(500, 4_000) as usize).min(data.len() - off);
                    parts.push((off as u64, data[off..off + len].to_vec()));
                    off += len;
                }
                let mut j = 0;
                while j + 1 < parts.len() {
                    if rng.below(2) == 1 {
                        parts.swap(j, j + 1);
                    }
                    j += 2;
                }
                chunks.push(parts.into_iter().collect());
            }
            // Deliver in a random global interleaving of the files,
            // occasionally retrying spills (as the merger does per frame).
            while chunks.iter().any(|c| !c.is_empty()) {
                let pick = rng.below(n_files as u64) as usize;
                let Some((off, bytes)) = chunks[pick].pop_front() else { continue };
                states[pick].write(off, bytes.into()).unwrap();
                if rng.below(4) == 0 {
                    states[pick].pump_spill();
                }
            }
            assert!(
                states.iter().any(|st| !st.spill.is_empty()),
                "seed {seed}: geometry must actually exercise the spill path"
            );
            // End of stream: drain oldest-first (FileStart order), exactly
            // like the merger postlude — the 1-worker pool runs the jobs
            // FIFO, so this is the only safe blocking order.
            gate_tx.send(()).unwrap();
            for st in states.iter_mut() {
                st.end_requested = true;
                st.drain_spill_blocking();
                st.finish().unwrap();
            }
            drop(states);
            drop(tx);
            // Storage holds the exact bytes, and every file's queue-fed
            // digest equals the digest of its in-order bytes.
            for (i, data) in datas.iter().enumerate() {
                assert_eq!(&mem.get(&format!("s{i}")).unwrap(), data, "seed {seed} file {i}");
            }
            let mut seen = vec![false; n_files];
            while let Ok(ev) = rx.recv() {
                let Event::Verify { file_idx, digest: Some(d), .. } = ev else {
                    panic!("expected queue-mode Verify event");
                };
                let expect =
                    crate::hashes::hex_digest(HashAlgorithm::Md5, &datas[file_idx as usize]);
                assert_eq!(crate::util::hex::encode(&d), expect, "seed {seed} file {file_idx}");
                seen[file_idx as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "seed {seed}: one digest per file");
        }
    }

    #[test]
    fn file_state_reorders_stripe_skew_for_queue_feed() {
        // Out-of-order arrival: the storage writes land at their offsets
        // and the queue sees the bytes in stream order.
        let mem = MemStorage::new();
        let storage: Arc<dyn Storage> = Arc::new(mem.clone());
        let cfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Md5));
        let pool = HashPool::new(1);
        let handle = pool.handle();
        let (tx, rx) = mpsc::channel::<Event>();
        let data: Vec<u8> = (0u8..=255).cycle().take(900).collect();
        let plan = ResumePlan::default();
        let mut st =
            FileState::new(0, "f", 900, &cfg, &storage, &handle, &tx, None, &plan).unwrap();
        // Stripe skew: chunks 300..600 and 600..900 before 0..300.
        st.write(300, data[300..600].to_vec().into()).unwrap();
        st.write(600, data[600..900].to_vec().into()).unwrap();
        assert!(!st.complete());
        st.write(0, data[0..300].to_vec().into()).unwrap();
        st.end_requested = true;
        assert!(st.complete());
        st.finish().unwrap();
        drop(st);
        drop(tx);
        // The pool job digests the in-order stream.
        let ev = rx.recv().expect("digest event");
        match ev {
            Event::Verify { digest: Some(d), unit, .. } => {
                assert_eq!(unit, UNIT_FILE);
                let expect = crate::hashes::hex_digest(HashAlgorithm::Md5, &data);
                assert_eq!(crate::util::hex::encode(&d), expect);
            }
            _ => panic!("expected queue-mode Verify event"),
        }
        assert_eq!(mem.get("f").unwrap(), data);
    }
}
