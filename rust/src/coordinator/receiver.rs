//! Algorithm 2 — the FIVER receiver, generalized over all five policies.
//!
//! Three concurrent roles per session:
//!
//! * **data thread** (the caller's thread): reads frames off the data
//!   channel, writes file bytes to storage, and — in queue mode — feeds the
//!   shared [`ByteQueue`] so the checksum of the in-flight file proceeds
//!   without any file I/O (Algorithm 2 lines 5-8).
//! * **queue hash threads**: one per queue-mode file; consume the queue and
//!   produce per-unit digests (Algorithm 2's COMPUTECHECKSUM).
//! * **verify worker**: owns the control channel; sends digests, reads
//!   verdicts, applies the repair/recompute loop for failed units, and for
//!   re-read-mode files performs the checksum itself by reading storage
//!   (the sequential / pipelined checksum station).

use std::collections::HashMap;
use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::protocol::Frame;
use super::queue::ByteQueue;
use super::{RealAlgorithm, SessionConfig};
use crate::merkle::{MerkleBuilder, MerkleTree};
use crate::storage::Storage;

/// Receiver-side session summary.
#[derive(Debug, Default, Clone)]
pub struct ReceiverReport {
    pub files_received: usize,
    pub bytes_received: u64,
    pub units_verified: u64,
    /// Digest exchanges that failed (corruption caught).
    pub units_failed: u64,
    /// Bytes rewritten by repair frames.
    pub bytes_repaired: u64,
}

/// One work item for the verify worker.
enum Event {
    /// Verify a unit. `digest` is pre-computed for queue-mode files; for
    /// re-read mode the worker hashes `[offset, offset+len)` from storage.
    Verify {
        file_idx: u32,
        name: String,
        unit: u64,
        offset: u64,
        len: u64,
        digest: Option<Vec<u8>>,
    },
    /// FIVER-Merkle: exchange this file's digest tree with the sender and
    /// drive the leaf-repair loop until the roots match.
    VerifyTree { file_idx: u32, name: String, tree: MerkleTree },
    /// Repairs for (file_idx, unit) have been applied; `ranges` are the
    /// byte spans the Fix frames rewrote (so tree mode recomputes only the
    /// touched leaves). Recompute and re-exchange.
    Repaired { file_idx: u32, unit: u64, ranges: Vec<(u64, u64)> },
}

/// Serve one session on accepted data/control connections. Blocks until
/// the sender's `Done` frame; returns the session report.
pub fn serve_session(
    data: TcpStream,
    ctrl: TcpStream,
    storage: Arc<dyn Storage>,
    cfg: &SessionConfig,
) -> Result<ReceiverReport> {
    let mut data_in = BufReader::with_capacity(1 << 20, data);
    let (tx, rx) = mpsc::channel::<Event>();

    // Verify worker: owns both directions of the control channel.
    let worker_storage = storage.clone();
    let worker_cfg = cfg.clone();
    let worker = std::thread::spawn(move || verify_worker(ctrl, worker_storage, &worker_cfg, rx));

    let mut report = ReceiverReport::default();
    let mut current: Option<FileState> = None;
    let mut names: HashMap<u32, String> = HashMap::new();
    // Byte spans rewritten by Fix frames since the last FixEnd, per file,
    // plus one write handle kept open across the batch (opening and
    // flushing per frame would pay a syscall pair per ~64 KiB of repair).
    let mut fix_ranges: HashMap<u32, Vec<(u64, u64)>> = HashMap::new();
    let mut fix_writers: HashMap<u32, Box<dyn crate::storage::WriteStream>> = HashMap::new();

    loop {
        let frame = Frame::read_from(&mut data_in)
            .context("reading data frame")?
            .context("data channel closed before Done")?;
        match frame {
            Frame::FileStart { file_idx, size, attempt: _, name } => {
                anyhow::ensure!(current.is_none(), "nested FileStart");
                names.insert(file_idx, name.clone());
                current = Some(FileState::new(file_idx, &name, size, cfg, &storage, &tx)?);
            }
            Frame::Data { file_idx, offset, payload } => {
                let st = current.as_mut().context("Data frame outside a file")?;
                anyhow::ensure!(st.file_idx == file_idx, "Data for wrong file");
                report.bytes_received += payload.len() as u64;
                st.write(offset, payload)?;
            }
            Frame::FileEnd { file_idx } => {
                let mut st = current.take().context("FileEnd outside a file")?;
                anyhow::ensure!(st.file_idx == file_idx, "FileEnd for wrong file");
                st.finish()?;
                report.files_received += 1;
            }
            Frame::Fix { file_idx, offset, payload } => {
                // Repairs may interleave with the next file's stream; route
                // by the name recorded at FileStart.
                let name = names
                    .get(&file_idx)
                    .with_context(|| format!("Fix for unknown file {file_idx}"))?;
                let w = match fix_writers.entry(file_idx) {
                    std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
                    std::collections::hash_map::Entry::Vacant(e) => {
                        e.insert(storage.open_update(name)?)
                    }
                };
                w.write_at(offset, &payload)?;
                report.bytes_repaired += payload.len() as u64;
                fix_ranges.entry(file_idx).or_default().push((offset, payload.len() as u64));
            }
            Frame::FixEnd { file_idx, unit } => {
                // Make the batch durable before the verify worker re-hashes
                // the repaired ranges from storage.
                if let Some(mut w) = fix_writers.remove(&file_idx) {
                    w.flush()?;
                }
                let ranges = fix_ranges.remove(&file_idx).unwrap_or_default();
                tx.send(Event::Repaired { file_idx, unit, ranges }).ok();
            }
            Frame::Done => break,
            other => bail!("unexpected frame on data channel: {other:?}"),
        }
    }
    drop(tx);
    drop(current);
    let stats = worker.join().expect("verify worker panicked")?;
    report.units_verified = stats.0;
    report.units_failed = stats.1;
    Ok(report)
}

/// Per-file receive state.
struct FileState {
    file_idx: u32,
    name: String,
    size: u64,
    written: u64,
    writer: Box<dyn crate::storage::WriteStream>,
    /// Queue + hash thread for FIVER-mode files.
    queue: Option<ByteQueue>,
    hash_thread: Option<std::thread::JoinHandle<()>>,
    /// Re-read mode: units pending emission as writes cross their end
    /// offset (lets block-level checksums overlap the next block's data).
    pending_units: Vec<(u64, u64, u64)>,
    tx: mpsc::Sender<Event>,
}

impl FileState {
    fn new(
        file_idx: u32,
        name: &str,
        size: u64,
        cfg: &SessionConfig,
        storage: &Arc<dyn Storage>,
        tx: &mpsc::Sender<Event>,
    ) -> Result<FileState> {
        let writer = storage.open_write(name)?;
        let uses_queue = cfg.algorithm.uses_queue(size, cfg.hybrid_threshold);
        let units = cfg.units_of(size, uses_queue);
        let verify = cfg.algorithm != RealAlgorithm::TransferOnly;

        let (queue, hash_thread) = if uses_queue && verify {
            let q = ByteQueue::new(cfg.queue_capacity);
            let q2 = q.clone();
            let hasher_factory = cfg.hasher.clone();
            let tx2 = tx.clone();
            let name2 = name.to_string();
            let handle = if cfg.algorithm == RealAlgorithm::FiverMerkle {
                // Fold the stream into a digest tree as it drains from the
                // queue (Algorithm 2 line 7 with tree leaves instead of a
                // single running digest) — still zero extra file I/O.
                let leaf_size = cfg.leaf_size;
                std::thread::spawn(move || {
                    let tree = queue_build_tree(q2, leaf_size, hasher_factory);
                    tx2.send(Event::VerifyTree { file_idx, name: name2, tree }).ok();
                })
            } else {
                let units2 = units.clone();
                std::thread::spawn(move || {
                    queue_hash_units(q2, &units2, hasher_factory, |unit, offset, len, digest| {
                        tx2.send(Event::Verify {
                            file_idx,
                            name: name2.clone(),
                            unit,
                            offset,
                            len,
                            digest: Some(digest),
                        })
                        .ok();
                    });
                })
            };
            (Some(q), Some(handle))
        } else {
            (None, None)
        };
        Ok(FileState {
            file_idx,
            name: name.to_string(),
            size,
            written: 0,
            writer,
            queue,
            hash_thread,
            pending_units: if verify && !uses_queue { units } else { Vec::new() },
            tx: tx.clone(),
        })
    }

    fn write(&mut self, offset: u64, payload: Vec<u8>) -> Result<()> {
        self.writer.write_at(offset, &payload)?;
        self.written = self.written.max(offset + payload.len() as u64);
        if let Some(q) = &self.queue {
            // Algorithm 2 line 7: share the received buffer with the
            // checksum thread — no re-read, no extra syscalls.
            q.add(payload);
        }
        self.emit_completed_units(false);
        Ok(())
    }

    /// Emit re-read-mode verification jobs for fully written units.
    fn emit_completed_units(&mut self, at_eof: bool) {
        while let Some(&(unit, offset, len)) = self.pending_units.first() {
            let complete = self.written >= offset + len && (len > 0 || at_eof || self.size == 0);
            if !complete {
                break;
            }
            self.tx
                .send(Event::Verify {
                    file_idx: self.file_idx,
                    name: self.name.clone(),
                    unit,
                    offset,
                    len,
                    digest: None,
                })
                .ok();
            self.pending_units.remove(0);
        }
    }

    fn finish(&mut self) -> Result<()> {
        self.writer.flush()?;
        if let Some(q) = self.queue.take() {
            q.close();
        }
        if let Some(h) = self.hash_thread.take() {
            h.join().expect("hash thread panicked");
        }
        self.emit_completed_units(true);
        anyhow::ensure!(
            self.pending_units.is_empty(),
            "file {} ended short: {} bytes written of {}",
            self.name,
            self.written,
            self.size
        );
        Ok(())
    }
}

/// Consume a queue, cutting unit digests at the configured boundaries.
/// `units` are (id, offset, len) in stream order, contiguous.
pub(crate) fn queue_hash_units(
    q: ByteQueue,
    units: &[(u64, u64, u64)],
    hasher_factory: super::HasherFactory,
    mut emit: impl FnMut(u64, u64, u64, Vec<u8>),
) {
    let mut idx = 0usize;
    let mut hasher = hasher_factory();
    let mut consumed = 0u64;
    // Zero-length units (empty files) need no data.
    while idx < units.len() && units[idx].2 == 0 {
        let (u, o, l) = units[idx];
        emit(u, o, l, hasher.finalize());
        hasher.reset();
        idx += 1;
    }
    while idx < units.len() {
        let Some(buf) = q.remove() else { break };
        let mut slice = &buf[..];
        while !slice.is_empty() && idx < units.len() {
            let (unit, offset, len) = units[idx];
            let take = ((len - consumed) as usize).min(slice.len());
            hasher.update(&slice[..take]);
            consumed += take as u64;
            slice = &slice[take..];
            if consumed == len {
                emit(unit, offset, len, hasher.finalize());
                hasher.reset();
                consumed = 0;
                idx += 1;
            }
        }
    }
    // Queue closed early (short stream): emit the partial unit so
    // verification fails closed rather than hanging the session.
    if idx < units.len() && consumed > 0 {
        let (unit, offset, len) = units[idx];
        emit(unit, offset, len, hasher.finalize());
    }
}

/// Consume a queue into a streaming Merkle builder — FIVER-Merkle's
/// COMPUTECHECKSUM, the tree-shaped twin of [`queue_hash_units`]; both
/// endpoints drain their queue through this.
pub(crate) fn queue_build_tree(
    q: ByteQueue,
    leaf_size: u64,
    hasher_factory: super::HasherFactory,
) -> MerkleTree {
    let mut builder = MerkleBuilder::new(leaf_size, hasher_factory);
    while let Some(buf) = q.remove() {
        builder.update(&buf);
    }
    builder.finish()
}

/// The verify worker: digests out, verdicts in, repair loop.
fn verify_worker(
    ctrl: TcpStream,
    storage: Arc<dyn Storage>,
    cfg: &SessionConfig,
    rx: mpsc::Receiver<Event>,
) -> Result<(u64, u64)> {
    let mut ctrl_in = BufReader::new(ctrl.try_clone().context("ctrl clone")?);
    let mut ctrl_out = BufWriter::new(ctrl);
    let mut verified = 0u64;
    let mut failed = 0u64;
    let mut stash: std::collections::VecDeque<Event> = Default::default();

    loop {
        let ev = match stash.pop_front() {
            Some(e) => e,
            None => match rx.recv() {
                Ok(e) => e,
                Err(_) => break, // all senders dropped: session over
            },
        };
        let (file_idx, name, unit, offset, len, digest) = match ev {
            Event::Verify { file_idx, name, unit, offset, len, digest } => {
                (file_idx, name, unit, offset, len, digest)
            }
            Event::VerifyTree { file_idx, name, tree } => {
                let (v, f) = verify_tree_exchange(
                    &mut ctrl_in,
                    &mut ctrl_out,
                    &storage,
                    cfg,
                    &rx,
                    &mut stash,
                    file_idx,
                    &name,
                    tree,
                )?;
                verified += v;
                failed += f;
                continue;
            }
            // Stray Repaired with no pending verification.
            Event::Repaired { .. } => continue,
        };
        // Compute (re-read mode) or take (queue mode) the digest.
        let mut digest = match digest {
            Some(d) => d,
            None => hash_range(&storage, &name, offset, len, &cfg.hasher)?,
        };
        loop {
            Frame::Digest { file_idx, unit, digest: digest.clone() }.write_to(&mut ctrl_out)?;
            use std::io::Write;
            ctrl_out.flush()?;
            let verdict =
                Frame::read_from(&mut ctrl_in)?.context("ctrl channel closed awaiting verdict")?;
            match verdict {
                Frame::Verdict { file_idx: fi, unit: u, ok } => {
                    anyhow::ensure!(
                        fi == file_idx && u == unit,
                        "verdict for wrong unit ({fi},{u}) != ({file_idx},{unit})"
                    );
                    if ok {
                        verified += 1;
                        break;
                    }
                    failed += 1;
                    // Wait for the repairs to land (FixEnd), stashing other
                    // files' verification events that arrive meanwhile
                    // (FIVER keeps streaming during recovery).
                    loop {
                        match rx.recv() {
                            Ok(Event::Repaired { file_idx: fi, unit: u, ranges: _ })
                                if fi == file_idx && u == unit =>
                            {
                                break;
                            }
                            Ok(other) => stash.push_back(other),
                            Err(_) => bail!("session ended mid-repair"),
                        }
                    }
                    digest = hash_range(&storage, &name, offset, len, &cfg.hasher)?;
                }
                other => bail!("expected Verdict, got {other:?}"),
            }
        }
    }
    Ok((verified, failed))
}

/// FIVER-Merkle receiver loop: offer the tree root; on a mismatch verdict,
/// answer the sender's node-range queries (its binary search down the
/// tree), wait for the repair Fixes to land, patch only the touched leaves
/// from storage (O(k) leaf hashes + O(k log n) combines), and re-offer the
/// fresh root until the sender accepts it.
#[allow(clippy::too_many_arguments)]
fn verify_tree_exchange(
    ctrl_in: &mut BufReader<TcpStream>,
    ctrl_out: &mut BufWriter<TcpStream>,
    storage: &Arc<dyn Storage>,
    cfg: &SessionConfig,
    rx: &mpsc::Receiver<Event>,
    stash: &mut std::collections::VecDeque<Event>,
    file_idx: u32,
    name: &str,
    mut tree: MerkleTree,
) -> Result<(u64, u64)> {
    use std::io::Write;
    let mut verified = 0u64;
    let mut failed = 0u64;
    loop {
        Frame::TreeRoot {
            file_idx,
            leaves: tree.leaf_count() as u64,
            leaf_size: tree.leaf_size(),
            digest: tree.root().to_vec(),
        }
        .write_to(ctrl_out)?;
        ctrl_out.flush()?;
        let verdict =
            Frame::read_from(ctrl_in)?.context("ctrl channel closed awaiting tree verdict")?;
        let Frame::Verdict { file_idx: fi, unit: _, ok } = verdict else {
            bail!("expected Verdict for tree root, got {verdict:?}");
        };
        anyhow::ensure!(fi == file_idx, "tree verdict for wrong file {fi} != {file_idx}");
        if ok {
            verified += 1;
            return Ok((verified, failed));
        }
        failed += 1;
        // Serve the descent queries until the sender announces repairs.
        loop {
            let frame = Frame::read_from(ctrl_in)?.context("ctrl channel closed mid-descent")?;
            match frame {
                Frame::TreeQuery { file_idx: fi, level, start, count } => {
                    anyhow::ensure!(fi == file_idx, "tree query for wrong file");
                    Frame::TreeNodes {
                        file_idx,
                        level,
                        start,
                        digests: tree.nodes_concat(
                            level as usize,
                            start as usize,
                            count as usize,
                        ),
                    }
                    .write_to(ctrl_out)?;
                    ctrl_out.flush()?;
                }
                Frame::TreeRepairSent { .. } => break,
                other => bail!("expected TreeQuery/TreeRepairSent, got {other:?}"),
            }
        }
        // Await the data channel's FixEnd (repairs applied), stashing other
        // files' verification events that arrive meanwhile.
        let ranges = loop {
            match rx.recv() {
                Ok(Event::Repaired { file_idx: fi, unit: _, ranges }) if fi == file_idx => {
                    break ranges;
                }
                Ok(other) => stash.push_back(other),
                Err(_) => bail!("session ended mid-tree-repair"),
            }
        };
        let mut dirty: Vec<usize> = Vec::new();
        for (off, len) in ranges {
            dirty.extend(tree.leaves_touching(off, len));
        }
        dirty.sort_unstable();
        dirty.dedup();
        for &leaf in &dirty {
            let (off, len) = tree.leaf_range(leaf);
            tree.set_leaf(leaf, hash_range(storage, name, off, len, &cfg.hasher)?);
        }
        tree.recompute_paths(&dirty, &cfg.hasher);
    }
}

/// Hash `[offset, offset+len)` of a stored file (checksum via the
/// filesystem — the non-FIVER path, and the repair-recompute path).
pub(crate) fn hash_range(
    storage: &Arc<dyn Storage>,
    name: &str,
    offset: u64,
    len: u64,
    hasher_factory: &super::HasherFactory,
) -> Result<Vec<u8>> {
    let mut h = hasher_factory();
    let mut r = storage.open_read(name)?;
    let mut buf = vec![0u8; 256 * 1024];
    let mut pos = offset;
    let end = offset + len;
    while pos < end {
        let want = buf.len().min((end - pos) as usize);
        let n = r.read_at(pos, &mut buf[..want])?;
        anyhow::ensure!(n > 0, "short read hashing {name} at {pos}");
        h.update(&buf[..n]);
        pos += n as u64;
    }
    Ok(h.finalize())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::native_factory;
    use crate::coordinator::protocol::UNIT_FILE;
    use crate::hashes::HashAlgorithm;
    use crate::storage::MemStorage;

    #[test]
    fn queue_hash_single_unit_matches_oneshot() {
        let q = ByteQueue::new(1024);
        let data: Vec<u8> = (0u8..=255).cycle().take(1000).collect();
        for part in data.chunks(100) {
            q.add(part.to_vec());
        }
        q.close();
        let mut out = Vec::new();
        queue_hash_units(
            q,
            &[(UNIT_FILE, 0, 1000)],
            native_factory(HashAlgorithm::Md5),
            |u, o, l, d| out.push((u, o, l, d)),
        );
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, UNIT_FILE);
        let expect = crate::hashes::hex_digest(HashAlgorithm::Md5, &data);
        assert_eq!(crate::util::hex::encode(&out[0].3), expect);
    }

    #[test]
    fn queue_hash_chunked_boundaries() {
        // Buffers deliberately misaligned with the 400-byte units.
        let q = ByteQueue::new(4096);
        let data: Vec<u8> = (0u8..=255).cycle().take(1000).collect();
        for part in data.chunks(333) {
            q.add(part.to_vec());
        }
        q.close();
        let units = [(0u64, 0u64, 400u64), (1, 400, 400), (2, 800, 200)];
        let mut out = Vec::new();
        queue_hash_units(q, &units, native_factory(HashAlgorithm::Sha1), |u, o, l, d| {
            out.push((u, o, l, d))
        });
        assert_eq!(out.len(), 3);
        for (i, (u, o, l, d)) in out.iter().enumerate() {
            assert_eq!(*u, i as u64);
            let expect = crate::hashes::hex_digest(
                HashAlgorithm::Sha1,
                &data[*o as usize..(*o + *l) as usize],
            );
            assert_eq!(crate::util::hex::encode(d), expect, "unit {u}");
        }
    }

    #[test]
    fn queue_hash_empty_file() {
        let q = ByteQueue::new(16);
        q.close();
        let mut out = Vec::new();
        queue_hash_units(
            q,
            &[(UNIT_FILE, 0, 0)],
            native_factory(HashAlgorithm::Md5),
            |u, o, l, d| out.push((u, o, l, d)),
        );
        assert_eq!(out.len(), 1);
        assert_eq!(crate::util::hex::encode(&out[0].3), "d41d8cd98f00b204e9800998ecf8427e");
    }

    #[test]
    fn queue_hash_early_close_emits_partial() {
        let q = ByteQueue::new(64);
        q.add(vec![1, 2, 3]);
        q.close();
        let mut out = Vec::new();
        queue_hash_units(q, &[(UNIT_FILE, 0, 100)], native_factory(HashAlgorithm::Md5), |u, o, l, d| {
            out.push((u, o, l, d))
        });
        assert_eq!(out.len(), 1, "partial unit must still emit (fail-closed)");
    }

    #[test]
    fn hash_range_matches_slice() {
        let mem = MemStorage::new();
        mem.put("f", (0u8..200).collect());
        let storage: Arc<dyn Storage> = Arc::new(mem);
        let d = hash_range(&storage, "f", 50, 100, &native_factory(HashAlgorithm::Md5)).unwrap();
        let expect = crate::hashes::hex_digest(
            HashAlgorithm::Md5,
            &(0u8..200).collect::<Vec<_>>()[50..150],
        );
        assert_eq!(crate::util::hex::encode(&d), expect);
    }
}
