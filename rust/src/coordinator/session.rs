//! Session wiring: bind/connect the data + control channels and run a
//! sender/receiver pair — the entrypoint examples, tests and the CLI use.

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::receiver::{serve_session, ReceiverReport};
use super::sender::run_sender;
use super::{SessionConfig, TransferReport};
use crate::faults::FaultPlan;
use crate::storage::Storage;

/// A listening receiver endpoint.
pub struct ReceiverEndpoint {
    data_listener: TcpListener,
    ctrl_listener: TcpListener,
}

impl ReceiverEndpoint {
    /// Bind on an ephemeral local port pair.
    pub fn bind_local() -> Result<ReceiverEndpoint> {
        Ok(ReceiverEndpoint {
            data_listener: TcpListener::bind("127.0.0.1:0").context("bind data")?,
            ctrl_listener: TcpListener::bind("127.0.0.1:0").context("bind ctrl")?,
        })
    }

    /// Bind on explicit addresses (e.g. "0.0.0.0:7001"/"0.0.0.0:7002").
    pub fn bind(data_addr: &str, ctrl_addr: &str) -> Result<ReceiverEndpoint> {
        Ok(ReceiverEndpoint {
            data_listener: TcpListener::bind(data_addr).context("bind data")?,
            ctrl_listener: TcpListener::bind(ctrl_addr).context("bind ctrl")?,
        })
    }

    /// (data, ctrl) addresses to hand to the sender.
    pub fn addrs(&self) -> Result<(String, String)> {
        Ok((
            self.data_listener.local_addr()?.to_string(),
            self.ctrl_listener.local_addr()?.to_string(),
        ))
    }

    /// Accept one session and serve it to completion.
    pub fn serve_one(
        &self,
        storage: Arc<dyn Storage>,
        cfg: &SessionConfig,
    ) -> Result<ReceiverReport> {
        let (data, _) = self.data_listener.accept().context("accept data")?;
        let (ctrl, _) = self.ctrl_listener.accept().context("accept ctrl")?;
        data.set_nodelay(true).ok();
        ctrl.set_nodelay(true).ok();
        serve_session(data, ctrl, storage, cfg)
    }
}

/// Connect to a receiver and run a sender session.
pub fn connect_and_send(
    data_addr: &str,
    ctrl_addr: &str,
    files: &[String],
    storage: Arc<dyn Storage>,
    cfg: &SessionConfig,
    faults: &FaultPlan,
) -> Result<TransferReport> {
    let data = TcpStream::connect(data_addr).context("connect data")?;
    let ctrl = TcpStream::connect(ctrl_addr).context("connect ctrl")?;
    data.set_nodelay(true).ok();
    ctrl.set_nodelay(true).ok();
    run_sender(data, ctrl, files, storage, cfg, faults)
}

/// Run a complete local transfer: receiver thread + sender on the calling
/// thread, over loopback TCP. Returns both reports.
pub fn run_local_transfer(
    files: &[String],
    src: Arc<dyn Storage>,
    dst: Arc<dyn Storage>,
    cfg: &SessionConfig,
    faults: &FaultPlan,
) -> Result<(TransferReport, ReceiverReport)> {
    let endpoint = ReceiverEndpoint::bind_local()?;
    let (data_addr, ctrl_addr) = endpoint.addrs()?;
    let rcfg = cfg.clone();
    let receiver = std::thread::spawn(move || endpoint.serve_one(dst, &rcfg));
    let sender_report = connect_and_send(&data_addr, &ctrl_addr, files, src, cfg, faults)?;
    let receiver_report = receiver.join().expect("receiver panicked")?;
    Ok((sender_report, receiver_report))
}
