//! Session wiring: bind/connect the data + control channels and run
//! sender/receiver pairs — both the classic single-session entrypoints
//! and the parallel engine (N concurrent sessions × P data stripes,
//! work-stealing file scheduler, shared hash pools).

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::AtomicUsize;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::control::Controller;
use super::delta::DeltaPlan;
use super::journal::{self, ResumePlan};
use super::pool::HashPool;
use super::protocol::{Frame, DELTA_SESSION, RESUME_SESSION};
use super::receiver::{serve_session, serve_session_multi, ReceiverReport};
use super::scheduler::{EngineConfig, EngineReport, WorkStealQueue};
use super::sender::{run_sender, SenderSession};
use super::{SessionConfig, TransferReport};
use crate::faults::FaultPlan;
use crate::storage::Storage;

/// A listening receiver endpoint.
pub struct ReceiverEndpoint {
    data_listener: TcpListener,
    ctrl_listener: TcpListener,
}

impl ReceiverEndpoint {
    /// Bind on an ephemeral local port pair (port 0: the OS assigns free
    /// ports, so concurrent tests and sessions never collide).
    pub fn bind_local() -> Result<ReceiverEndpoint> {
        Ok(ReceiverEndpoint {
            data_listener: TcpListener::bind("127.0.0.1:0").context("bind data")?,
            ctrl_listener: TcpListener::bind("127.0.0.1:0").context("bind ctrl")?,
        })
    }

    /// Bind on explicit addresses (e.g. "0.0.0.0:7001"/"0.0.0.0:7002").
    pub fn bind(data_addr: &str, ctrl_addr: &str) -> Result<ReceiverEndpoint> {
        Ok(ReceiverEndpoint {
            data_listener: TcpListener::bind(data_addr).context("bind data")?,
            ctrl_listener: TcpListener::bind(ctrl_addr).context("bind ctrl")?,
        })
    }

    /// (data, ctrl) addresses to hand to the sender.
    pub fn addrs(&self) -> Result<(String, String)> {
        Ok((
            self.data_listener.local_addr()?.to_string(),
            self.ctrl_listener.local_addr()?.to_string(),
        ))
    }

    /// Accept one classic (single-stripe, no-handshake) session and serve
    /// it to completion.
    pub fn serve_one(
        &self,
        storage: Arc<dyn Storage>,
        cfg: &SessionConfig,
    ) -> Result<ReceiverReport> {
        let (data, _) = self.data_listener.accept().context("accept data")?;
        let (ctrl, _) = self.ctrl_listener.accept().context("accept ctrl")?;
        data.set_nodelay(true).ok();
        ctrl.set_nodelay(true).ok();
        serve_session(data, ctrl, storage, cfg)
    }

    /// Accept and serve a full engine run: `concurrency` sessions, each
    /// one control connection plus its data stripes, routed by the
    /// `Hello` handshake and served concurrently over one shared hash
    /// pool. Returns the per-session reports in session-id order.
    ///
    /// Each session's ctrl `Hello` announces how many data lanes that
    /// session provisions (an adaptive sender provisions up to its
    /// `--max-parallel` ceiling; a fixed sender announces exactly its
    /// `--parallel`), so the two endpoints no longer need to agree on a
    /// global stripe count — the receiver's merger reads whatever lanes
    /// carry frames. The total connection count must stay within the
    /// listen backlog (128).
    pub fn serve_engine(
        &self,
        storage: Arc<dyn Storage>,
        cfg: &SessionConfig,
        eng: &EngineConfig,
    ) -> Result<Vec<ReceiverReport>> {
        let n = eng.concurrency.max(1);
        let p = eng.parallel.max(1);
        anyhow::ensure!(n * (p + 1) <= 128, "connection count exceeds the listen backlog");

        // Route control connections by their Hello. A resume-handshake
        // connection (session id RESUME_SESSION) may arrive first: serve
        // the negotiation from our checkpoint journal, then keep routing.
        let mut resume_plan = Arc::new(ResumePlan::default());
        let mut ctrls: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        // Per-session provisioned lane count, read from each ctrl Hello.
        let mut lane_counts: Vec<usize> = vec![p; n];
        let mut routed = 0usize;
        while routed < n {
            let (mut c, _) = self.ctrl_listener.accept().context("accept ctrl")?;
            c.set_nodelay(true).ok();
            let hello = Frame::read_from(&mut c)?.context("ctrl closed before Hello")?;
            let Frame::Hello { session_id, stripes, .. } = hello else {
                bail!("expected Hello on ctrl, got {hello:?}");
            };
            if session_id == RESUME_SESSION {
                let jrnl = cfg.open_journal()?;
                resume_plan =
                    Arc::new(journal::negotiate_receiver(&mut c, jrnl.as_ref(), cfg, &storage)?);
                continue;
            }
            if session_id == DELTA_SESSION {
                // Serve per-file signature bases from the journal (free)
                // or by hashing the existing destination data.
                let jrnl = cfg.open_journal()?;
                journal::negotiate_delta_receiver(&mut c, jrnl.as_ref(), cfg, &storage)?;
                continue;
            }
            let sid = session_id as usize;
            anyhow::ensure!(sid < n, "session id {sid} out of range");
            anyhow::ensure!(ctrls[sid].is_none(), "duplicate ctrl for session {sid}");
            lane_counts[sid] = (stripes as usize).max(1);
            ctrls[sid] = Some(c);
            routed += 1;
        }
        let total_lanes: usize = lane_counts.iter().sum();
        anyhow::ensure!(total_lanes + n <= 128, "connection count exceeds the listen backlog");
        // Route data connections by (session, stripe): each session owes
        // exactly the lane count its ctrl Hello announced.
        let mut datas: Vec<Vec<Option<TcpStream>>> =
            lane_counts.iter().map(|&s| (0..s).map(|_| None).collect()).collect();
        for _ in 0..total_lanes {
            let (mut d, _) = self.data_listener.accept().context("accept data")?;
            d.set_nodelay(true).ok();
            let hello = Frame::read_from(&mut d)?.context("data closed before Hello")?;
            let Frame::Hello { session_id, stripe_id, stripes } = hello else {
                bail!("expected Hello on data, got {hello:?}");
            };
            let (sid, stripe) = (session_id as usize, stripe_id as usize);
            anyhow::ensure!(sid < n, "session id {sid} out of range");
            anyhow::ensure!(
                stripes as usize == lane_counts[sid],
                "stripe count mismatch: data Hello {stripes} vs the {} lanes \
                 session {sid}'s ctrl Hello announced",
                lane_counts[sid]
            );
            anyhow::ensure!(stripe < lane_counts[sid], "stripe ({sid},{stripe}) out of range");
            anyhow::ensure!(datas[sid][stripe].is_none(), "duplicate stripe ({sid},{stripe})");
            datas[sid][stripe] = Some(d);
        }

        let pool = HashPool::new(eng.pool_workers());
        // One data-plane buffer pool per endpoint: payload decode, storage
        // write and hash queue all share its refcounted buffers. Offer it
        // to the storage too — the io_uring engine registers its aligned
        // backings as the ring's fixed-buffer table.
        let bufs = cfg.make_pool(n);
        storage.register_pool(&bufs);
        let mut handles = Vec::new();
        for sid in 0..n {
            let ctrl = ctrls[sid].take().expect("routed above");
            let stripes: Vec<TcpStream> =
                datas[sid].iter_mut().map(|s| s.take().expect("routed above")).collect();
            let storage2 = storage.clone();
            let cfg2 = cfg.clone();
            let handle = pool.handle();
            let bufs2 = bufs.clone();
            let plan2 = resume_plan.clone();
            handles.push(std::thread::spawn(move || {
                serve_session_multi(stripes, ctrl, storage2, &cfg2, handle, bufs2, plan2)
            }));
        }
        // Join *every* session before surfacing an error: a crashed peer
        // fails several sessions at once, and returning early would race
        // the survivors against this scope's pool teardown.
        let results: Vec<Result<ReceiverReport>> =
            handles.into_iter().map(|h| h.join().expect("receiver session panicked")).collect();
        let mut reports = Vec::with_capacity(n);
        for r in results {
            reports.push(r?);
        }
        // A clean run folds its per-file records into the append-only
        // segment, so a million-file journal settles to one file plus a
        // short tail of fresh records.
        if let Some(j) = cfg.open_journal()? {
            j.compact()?;
        }
        Ok(reports)
    }
}

/// Connect to a receiver and run a classic single sender session.
pub fn connect_and_send(
    data_addr: &str,
    ctrl_addr: &str,
    files: &[String],
    storage: Arc<dyn Storage>,
    cfg: &SessionConfig,
    faults: &FaultPlan,
) -> Result<TransferReport> {
    let data = TcpStream::connect(data_addr).context("connect data")?;
    let ctrl = TcpStream::connect(ctrl_addr).context("connect ctrl")?;
    data.set_nodelay(true).ok();
    ctrl.set_nodelay(true).ok();
    run_sender(data, ctrl, files, storage, cfg, faults)
}

/// Connect and drive a full engine run against a receiver serving
/// [`ReceiverEndpoint::serve_engine`] with the same `eng` parameters:
/// plan the work items, spawn one sender session per concurrency slot,
/// and let the sessions steal work until the dataset drains.
pub fn connect_and_send_engine(
    data_addr: &str,
    ctrl_addr: &str,
    files: &[String],
    storage: Arc<dyn Storage>,
    cfg: &SessionConfig,
    eng: &EngineConfig,
    faults: &FaultPlan,
) -> Result<EngineReport> {
    let n = eng.concurrency.max(1);
    let p = eng.parallel.max(1);
    // Adaptive runs provision data lanes up front to the controller's
    // `--max-parallel` ceiling (announced in every Hello) and start the
    // stripe target at `--parallel`; the controller then moves the
    // target between file boundaries while idle lanes simply carry no
    // frames. Fixed runs provision exactly `p`.
    let adaptive = cfg.control.adaptive;
    let lanes_cap = if adaptive { cfg.control.max_parallel.max(p) } else { p };
    let lanes = Arc::new(AtomicUsize::new(p));
    let names: Arc<Vec<String>> = Arc::new(files.to_vec());
    let mut sizes = Vec::with_capacity(names.len());
    for name in names.iter() {
        sizes.push(storage.size_of(name)?);
    }
    // Resume handshake (opt-in): one dedicated control connection up
    // front negotiates per-file restart offsets from the two endpoints'
    // checkpoint journals before any session spawns.
    let mut resume_plan = Arc::new(ResumePlan::default());
    if cfg.resume {
        let journal = cfg.open_journal()?;
        let mut c = TcpStream::connect(ctrl_addr).context("connect resume ctrl")?;
        c.set_nodelay(true).ok();
        Frame::Hello { session_id: RESUME_SESSION, stripe_id: 0, stripes: p as u64 }
            .write_to(&mut c)?;
        resume_plan =
            Arc::new(journal::negotiate_sender(&mut c, journal.as_ref(), cfg, &names, &sizes)?);
    }
    // Delta handshake (opt-in): a second dedicated control connection
    // fetches per-file signature bases of the receiver's existing data.
    // Files with a basis transfer incrementally; the rest stream in full.
    let mut delta_plan = Arc::new(DeltaPlan::default());
    if cfg.delta {
        let mut c = TcpStream::connect(ctrl_addr).context("connect delta ctrl")?;
        c.set_nodelay(true).ok();
        Frame::Hello { session_id: DELTA_SESSION, stripe_id: 0, stripes: p as u64 }
            .write_to(&mut c)?;
        delta_plan = Arc::new(journal::negotiate_delta_sender(&mut c, cfg, &names, &sizes)?);
    }
    // Files fully delivered and root-verified at handshake never
    // re-enqueue: the scheduler plans only the unfinished tail. (The
    // resume plan is name-keyed; map it back to dataset indices here.)
    let completed: std::collections::HashSet<usize> = names
        .iter()
        .enumerate()
        .filter(|(_, name)| resume_plan.is_complete(name))
        .map(|(idx, _)| idx)
        .collect();
    let files_skipped = resume_plan.skipped_files();
    let bytes_skipped = resume_plan.skipped_bytes();
    // Delta files schedule as standalone items (their cost is the local
    // scan, not the wire — batching several onto one session would
    // serialize the scans while other sessions idle).
    let delta_files: std::collections::HashSet<usize> =
        delta_plan.files.keys().map(|&idx| idx as usize).collect();
    let queue = Arc::new(WorkStealQueue::new(
        eng.plan_delta(&sizes, &completed, &delta_files),
        n,
    ));
    let pool = HashPool::new(eng.pool_workers());
    // Shared sender-side buffer pool: every session's reads recycle
    // through it, and hash jobs return buffers as they drain the queues.
    // The storage gets a handle too (io_uring registered buffers).
    let bufs = cfg.make_pool(n);
    storage.register_pool(&bufs);
    // Scheduler shard: one queue-depth observation per dispatched work
    // item, shared by every session's steal loop.
    let sched_obs = cfg.obs.shard("scheduler");
    let start = Instant::now();

    let mut handles = Vec::new();
    for sid in 0..n {
        let queue = queue.clone();
        let sched_obs = sched_obs.clone();
        let names = names.clone();
        let storage = storage.clone();
        let cfg = cfg.clone();
        let faults = faults.clone();
        let handle = pool.handle();
        let bufs = bufs.clone();
        let plan = resume_plan.clone();
        let dplan = delta_plan.clone();
        let lanes = lanes.clone();
        let data_addr = data_addr.to_string();
        let ctrl_addr = ctrl_addr.to_string();
        handles.push(std::thread::spawn(move || -> Result<TransferReport> {
            let mut ctrl = TcpStream::connect(&ctrl_addr).context("connect ctrl")?;
            ctrl.set_nodelay(true).ok();
            Frame::Hello { session_id: sid as u32, stripe_id: 0, stripes: lanes_cap as u64 }
                .write_to(&mut ctrl)?;
            let mut stripes = Vec::with_capacity(lanes_cap);
            for stripe in 0..lanes_cap {
                let mut d = TcpStream::connect(&data_addr).context("connect data")?;
                d.set_nodelay(true).ok();
                Frame::Hello {
                    session_id: sid as u32,
                    stripe_id: stripe as u64,
                    stripes: lanes_cap as u64,
                }
                .write_to(&mut d)?;
                stripes.push(d);
            }
            let mut session = SenderSession::new(
                stripes,
                ctrl,
                names.clone(),
                storage,
                cfg,
                faults,
                handle,
                bufs,
                plan,
                dplan,
                lanes,
            )?;
            while let Some(item) = queue.next(sid) {
                sched_obs.gauge_depth(queue.remaining() as u64);
                for &fi in &item.files {
                    session.send_file(fi as u32, &names[fi])?;
                }
            }
            session.finish()
        }));
    }
    // The feedback controller samples the live recorder and actuates the
    // shared hash pool + stripe target until the sessions drain. Without
    // tracing enabled it would see only zeros, so the CLI force-enables
    // the recorder whenever `--adaptive` is on.
    let controller = if adaptive {
        Some(Controller::spawn(
            cfg.control.clone(),
            cfg.obs.clone(),
            pool.clone(),
            lanes.clone(),
            lanes_cap,
        ))
    } else {
        None
    };
    // Join every session before surfacing an error (see serve_engine).
    let results: Vec<Result<TransferReport>> =
        handles.into_iter().map(|h| h.join().expect("sender session panicked")).collect();
    let adaptations = controller.map(|c| c.stop()).unwrap_or_default();
    let mut per_session = Vec::with_capacity(n);
    for r in results {
        per_session.push(r?);
    }
    // Clean-run journal hygiene, mirroring the receiver side.
    if let Some(j) = cfg.open_journal()? {
        j.compact()?;
    }
    Ok(EngineReport {
        per_session,
        adaptations,
        files_skipped,
        bytes_skipped,
        elapsed_secs: start.elapsed().as_secs_f64(),
    })
}

/// Run a complete local transfer: receiver thread + sender on the calling
/// thread, over loopback TCP. Returns both reports.
pub fn run_local_transfer(
    files: &[String],
    src: Arc<dyn Storage>,
    dst: Arc<dyn Storage>,
    cfg: &SessionConfig,
    faults: &FaultPlan,
) -> Result<(TransferReport, ReceiverReport)> {
    let endpoint = ReceiverEndpoint::bind_local()?;
    let (data_addr, ctrl_addr) = endpoint.addrs()?;
    let rcfg = cfg.clone();
    let receiver = std::thread::spawn(move || endpoint.serve_one(dst, &rcfg));
    let sender_report = connect_and_send(&data_addr, &ctrl_addr, files, src, cfg, faults)?;
    let receiver_report = receiver.join().expect("receiver panicked")?;
    Ok((sender_report, receiver_report))
}

/// Run a complete local *engine* transfer over loopback TCP: a receiver
/// engine thread serving N×P connections plus N work-stealing sender
/// sessions. Returns the sender engine report and the per-session
/// receiver reports.
pub fn run_parallel_local_transfer(
    files: &[String],
    src: Arc<dyn Storage>,
    dst: Arc<dyn Storage>,
    cfg: &SessionConfig,
    eng: &EngineConfig,
    faults: &FaultPlan,
) -> Result<(EngineReport, Vec<ReceiverReport>)> {
    run_recoverable_local_transfer(files, src, dst, cfg, cfg, eng, faults)
}

/// [`run_parallel_local_transfer`] with distinct sender/receiver session
/// configurations — the crash-recovery surface: each endpoint needs its
/// own `journal_dir`, and a resumed run sets `resume` on both. On a
/// crashed run *both* sides return the error; journals and partially
/// delivered files stay behind for the next attempt.
pub fn run_recoverable_local_transfer(
    files: &[String],
    src: Arc<dyn Storage>,
    dst: Arc<dyn Storage>,
    sender_cfg: &SessionConfig,
    receiver_cfg: &SessionConfig,
    eng: &EngineConfig,
    faults: &FaultPlan,
) -> Result<(EngineReport, Vec<ReceiverReport>)> {
    let endpoint = ReceiverEndpoint::bind_local()?;
    let (data_addr, ctrl_addr) = endpoint.addrs()?;
    let rcfg = receiver_cfg.clone();
    let reng = *eng;
    let receiver = std::thread::spawn(move || endpoint.serve_engine(dst, &rcfg, &reng));
    let sent = connect_and_send_engine(&data_addr, &ctrl_addr, files, src, sender_cfg, eng, faults);
    if sent.is_err() {
        // The sender may have died before connecting anything (bad
        // journal dir, missing source file): a receiver still parked in
        // its accept loop would make the join below hang forever. A dead
        // connection per listener errors the loop out instead; when the
        // receiver is already past accepting, the stray sockets just
        // close unread.
        TcpStream::connect(&ctrl_addr).map(drop).ok();
        TcpStream::connect(&data_addr).map(drop).ok();
    }
    let received = receiver.join().expect("receiver engine panicked");
    let report = sent?;
    let rreports = received?;
    Ok((report, rreports))
}
