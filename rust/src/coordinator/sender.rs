//! Algorithm 1 — the FIVER sender, generalized over all five policies
//! and engine-driven: a [`SenderSession`] is handed files one at a time
//! (by [`run_sender`] for a fixed list, or by the parallel engine's
//! work-stealing scheduler), streams them over one or more striped data
//! channels, and runs checksum compute on the shared
//! [`super::pool::HashPool`].
//!
//! Concurrent roles per session:
//!
//! * **session thread** (the caller): reads source files, stripes `Data`
//!   frames round-robin across the data channels, and feeds the shared
//!   queue (Algorithm 1 lines 5-8). Pacing differs per policy:
//!   Sequential waits for each file's verification; file-/block-level
//!   pipelining hand re-read checksum jobs to a checksum worker in
//!   lockstep; FIVER never waits (its checksum rides the queue).
//! * **hash pool workers**: FIVER's COMPUTECHECKSUM — digest the exact
//!   bytes that went to the sockets, no second read; one job per
//!   queue-mode file.
//! * **checksum worker**: the re-read checksum station for the baseline
//!   policies (depth-1 job channel = the paper's "checksum of file i
//!   overlaps transfer of file i+1").
//! * **verifier thread**: owns the control channel; compares receiver
//!   digests against local ones, issues verdicts, and repairs failed units
//!   by re-reading the source range and sending `Fix` frames (§IV-A).

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::bufpool::{BufferPool, SharedBuf, POOL_GRACE};
use super::delta::{DeltaBasis, DeltaOp, DeltaPlan, DeltaScanner};
use super::journal::{
    FileJournal, Journal, JournalRecord, LeafTracker, ResumePlan, ResumedFile,
};
use super::pool::{HashPool, PoolHandle};
use super::protocol::Frame;
use super::queue::ByteQueue;
use super::receiver::{hash_range, queue_build_tree_fold, queue_hash_units};
use super::{RealAlgorithm, SessionConfig, TransferReport};
use crate::faults::{CrashError, CrashPoint, FaultInjector, FaultPlan};
use crate::merkle::MerkleTree;
use crate::obs::{Shard, Stage};
use crate::storage::Storage;

/// Shared sender state between the session thread, hash jobs and the
/// verifier.
struct Shared {
    /// Local digests by (file_idx, unit).
    local: Mutex<HashMap<(u32, u64), Vec<u8>>>,
    local_cv: Condvar,
    /// Local digest trees per file (FIVER-Merkle); evicted once verified.
    trees: Mutex<HashMap<u32, Arc<MerkleTree>>>,
    trees_cv: Condvar,
    /// Unverified unit counts per file (present once registered).
    remaining: Mutex<HashMap<u32, usize>>,
    remaining_cv: Condvar,
    all_registered: AtomicBool,
    /// Set when the verifier (or an abort) fails the session: blocked
    /// waiters bail instead of sleeping on verifications that will never
    /// arrive.
    failed: AtomicBool,
    failures: AtomicU64,
    bytes_resent: AtomicU64,
    repair_rounds: AtomicU64,
    bytes_reread: AtomicU64,
    verify_rtts: AtomicU64,
}

impl Shared {
    fn new() -> Arc<Shared> {
        Arc::new(Shared {
            local: Mutex::new(HashMap::new()),
            local_cv: Condvar::new(),
            trees: Mutex::new(HashMap::new()),
            trees_cv: Condvar::new(),
            remaining: Mutex::new(HashMap::new()),
            remaining_cv: Condvar::new(),
            all_registered: AtomicBool::new(false),
            failed: AtomicBool::new(false),
            failures: AtomicU64::new(0),
            bytes_resent: AtomicU64::new(0),
            repair_rounds: AtomicU64::new(0),
            bytes_reread: AtomicU64::new(0),
            verify_rtts: AtomicU64::new(0),
        })
    }

    fn put_local(&self, file_idx: u32, unit: u64, digest: Vec<u8>) {
        self.local.lock().unwrap().insert((file_idx, unit), digest);
        self.local_cv.notify_all();
    }

    /// Take the unit's local digest *out* of the map (instead of cloning
    /// it and letting the map accumulate O(files × units) digests for the
    /// whole session). The verifier re-inserts it while a repair round is
    /// pending, since the receiver's fresh digest compares against the
    /// same local value. Bails when the session is failed/aborting, so a
    /// dying session can always join its verifier.
    fn take_local(&self, file_idx: u32, unit: u64) -> Result<Vec<u8>> {
        let mut g = self.local.lock().unwrap();
        loop {
            if let Some(d) = g.remove(&(file_idx, unit)) {
                return Ok(d);
            }
            if self.failed.load(Ordering::SeqCst) {
                bail!("session aborting while awaiting local digest ({file_idx},{unit})");
            }
            g = self.local_cv.wait(g).unwrap();
        }
    }

    fn put_tree(&self, file_idx: u32, tree: MerkleTree) {
        self.trees.lock().unwrap().insert(file_idx, Arc::new(tree));
        self.trees_cv.notify_all();
    }

    /// Cheap Arc clone — a 1 TB file's tree holds tens of millions of
    /// digests; copying it per verification round would dwarf the repair.
    /// Bails when the session is failed/aborting (see `take_local`).
    fn wait_tree(&self, file_idx: u32) -> Result<Arc<MerkleTree>> {
        let mut g = self.trees.lock().unwrap();
        loop {
            if let Some(t) = g.get(&file_idx) {
                return Ok(t.clone());
            }
            if self.failed.load(Ordering::SeqCst) {
                bail!("session aborting while awaiting digest tree of file {file_idx}");
            }
            g = self.trees_cv.wait(g).unwrap();
        }
    }

    /// Evict a verified file's tree (digests held for the session would
    /// accumulate O(total_bytes / leaf_size) memory on big datasets).
    fn drop_tree(&self, file_idx: u32) {
        self.trees.lock().unwrap().remove(&file_idx);
    }

    fn register(&self, file_idx: u32, units: usize) {
        self.remaining.lock().unwrap().insert(file_idx, units);
        self.remaining_cv.notify_all();
    }

    fn unit_ok(&self, file_idx: u32) {
        let mut g = self.remaining.lock().unwrap();
        if let Some(n) = g.get_mut(&file_idx) {
            *n = n.saturating_sub(1);
        }
        self.remaining_cv.notify_all();
    }

    /// Mark the session failed and wake every waiter (the verifier died,
    /// or the session is being aborted) — blocked pacing/finish waits
    /// bail instead of hanging on verifications that cannot complete.
    ///
    /// Each condvar's mutex is acquired (and released) before its notify:
    /// a waiter that observed `failed == false` but has not parked yet
    /// still holds its lock, so taking it here orders the store before
    /// that waiter's `wait()` — without this, the notify could land while
    /// nobody is parked and the wakeup would be lost forever.
    fn fail(&self) {
        self.failed.store(true, Ordering::SeqCst);
        drop(self.local.lock().unwrap());
        self.local_cv.notify_all();
        drop(self.trees.lock().unwrap());
        self.trees_cv.notify_all();
        drop(self.remaining.lock().unwrap());
        self.remaining_cv.notify_all();
    }

    fn wait_file_verified(&self, file_idx: u32) -> Result<()> {
        let mut g = self.remaining.lock().unwrap();
        while g.get(&file_idx).copied().unwrap_or(0) > 0 {
            if self.failed.load(Ordering::SeqCst) {
                bail!("session failed while awaiting verification of file {file_idx}");
            }
            g = self.remaining_cv.wait(g).unwrap();
        }
        Ok(())
    }

    fn wait_all_verified(&self) -> Result<()> {
        let mut g = self.remaining.lock().unwrap();
        while g.values().any(|&n| n > 0) {
            if self.failed.load(Ordering::SeqCst) {
                bail!("session failed with unverified files");
            }
            g = self.remaining_cv.wait(g).unwrap();
        }
        Ok(())
    }

    fn all_done(&self) -> bool {
        self.all_registered.load(Ordering::SeqCst)
            && self.remaining.lock().unwrap().values().all(|&n| n == 0)
    }
}

/// A shareable, mutex-guarded frame writer for one data channel (the
/// session thread's stream + the verifier's repair frames interleave at
/// frame granularity).
#[derive(Clone)]
struct DataOut(Arc<Mutex<BufWriter<TcpStream>>>);

impl DataOut {
    fn send(&self, frame: &Frame) -> Result<()> {
        let mut g = self.0.lock().unwrap();
        frame.write_to(&mut *g)?;
        Ok(())
    }

    /// Hot path: write a Data frame from a borrowed slice — no owned
    /// payload built, and large payloads leave as one `writev` of header +
    /// slice (no serialization copy).
    fn send_data(&self, file_idx: u32, offset: u64, payload: &[u8]) -> Result<()> {
        let mut g = self.0.lock().unwrap();
        super::protocol::write_data_frame_vectored(&mut *g, file_idx, offset, payload)
    }

    /// The repair twin: Fix frames from a borrowed (pooled) slice.
    fn send_fix(&self, file_idx: u32, offset: u64, payload: &[u8]) -> Result<()> {
        let mut g = self.0.lock().unwrap();
        super::protocol::write_fix_frame_vectored(&mut *g, file_idx, offset, payload)
    }

    fn flush(&self) -> Result<()> {
        self.0.lock().unwrap().flush()?;
        Ok(())
    }
}

/// One sender session: owns its data channels, control channel (via the
/// verifier thread), and per-session report. The engine drives it file by
/// file; `file_idx` is always the *dataset-global* index so fault plans
/// and receiver-side routing agree across sessions.
pub struct SenderSession {
    cfg: SessionConfig,
    storage: Arc<dyn Storage>,
    shared: Arc<Shared>,
    data_outs: Vec<DataOut>,
    /// Round-robin stripe cursor for Data frames.
    rr: usize,
    /// Stripe-count target shared with the adaptive controller; latched
    /// into `active_lanes` at each file boundary, never mid-file.
    lanes: Arc<AtomicUsize>,
    /// How many of the provisioned data channels this *file* stripes
    /// across (the first `active_lanes` of `data_outs`).
    active_lanes: usize,
    pool: PoolHandle,
    /// Data-plane buffer pool: one pooled buffer per read, shared by
    /// refcount between the socket write and the hash queue.
    bufs: BufferPool,
    ck_tx: Option<mpsc::SyncSender<(u32, String, u64, u64, u64)>>,
    ck_handle: Option<std::thread::JoinHandle<Result<()>>>,
    verifier: Option<std::thread::JoinHandle<Result<()>>>,
    /// Clone of the control socket kept for the abort path (the verifier
    /// owns the original).
    ctrl_shutdown: Option<TcpStream>,
    /// Raw clones of the data sockets for the abort path: severing them
    /// must not take the `DataOut` mutexes, which a thread stuck in a
    /// full-socket write may hold.
    data_shutdown: Vec<TcpStream>,
    injector: FaultInjector,
    /// Negotiated resume state: per-file restart offsets + prefix leaves.
    resume: Arc<ResumePlan>,
    /// Negotiated delta bases: per-file weak/strong signatures of the
    /// receiver's existing data (empty = full-copy every file).
    delta: Arc<DeltaPlan>,
    /// Checkpoint journal for this endpoint (None = journaling off).
    journal: Option<Journal>,
    /// Shared engine kill switch (crash injection).
    crash: Option<CrashPoint>,
    /// Session-thread span shard (read/send/queue_wait/journal stages).
    obs: Shard,
    /// Checksum-station shard, cloned into hash pool jobs and the
    /// re-read checksum worker (hash stage).
    obs_hash: Shard,
    report: TransferReport,
    start: Instant,
    verify: bool,
}

impl SenderSession {
    /// Wire up a session over connected data stripes + control socket.
    /// `names` is the full dataset name list (indexed by global file_idx —
    /// the verifier re-reads failed ranges by name).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        datas: Vec<TcpStream>,
        ctrl: TcpStream,
        names: Arc<Vec<String>>,
        storage: Arc<dyn Storage>,
        cfg: SessionConfig,
        faults: FaultPlan,
        pool: PoolHandle,
        bufs: BufferPool,
        resume: Arc<ResumePlan>,
        delta: Arc<DeltaPlan>,
        lanes: Arc<AtomicUsize>,
    ) -> Result<SenderSession> {
        anyhow::ensure!(!datas.is_empty(), "session needs at least one data channel");
        let shared = Shared::new();
        let data_shutdown: Vec<TcpStream> =
            datas.iter().filter_map(|d| d.try_clone().ok()).collect();
        let data_outs: Vec<DataOut> = datas
            .into_iter()
            .map(|d| DataOut(Arc::new(Mutex::new(BufWriter::with_capacity(1 << 20, d)))))
            .collect();
        let verify = cfg.algorithm != RealAlgorithm::TransferOnly;
        let journal = cfg.open_journal()?;
        let ctrl_shutdown = ctrl.try_clone().ok();
        let obs = cfg.obs.shard("sender");
        let obs_hash = cfg.obs.shard("sender-hash");

        // Verifier thread (owns ctrl). Repair Fix frames ride stripe 0.
        // On error it fails the shared state so pacing/finish waiters
        // bail instead of sleeping forever.
        let verifier = if verify {
            let shared2 = shared.clone();
            let shared3 = shared.clone();
            let storage2 = storage.clone();
            let data_out2 = data_outs[0].clone();
            let cfg2 = cfg.clone();
            let faults2 = faults.clone();
            let bufs2 = bufs.clone();
            Some(std::thread::spawn(move || {
                let r = run_verifier(
                    ctrl, shared2, storage2, data_out2, &cfg2, &names, &faults2, &bufs2,
                );
                if r.is_err() {
                    shared3.fail();
                }
                r
            }))
        } else {
            None
        };

        // Re-read checksum worker (the pipelined checksum station). Depth-1
        // channel: sending the next job blocks until the previous one was
        // *picked up* — checksum of unit i overlaps transfer of unit i+1
        // only. This pacing is the definition of the baseline policies, so
        // it stays a dedicated per-session thread rather than a pool job.
        let (ck_tx, ck_handle) = if verify {
            let (tx, rx) = mpsc::sync_channel::<(u32, String, u64, u64, u64)>(1);
            let shared2 = shared.clone();
            let storage2 = storage.clone();
            let hasher = cfg.leaf_factory();
            let hobs = obs_hash.clone();
            let handle = std::thread::spawn(move || -> Result<()> {
                while let Ok((file_idx, name, unit, offset, len)) = rx.recv() {
                    let t = hobs.start();
                    let digest = hash_range(&storage2, &name, offset, len, &hasher)?;
                    hobs.record(Stage::Hash, t);
                    shared2.put_local(file_idx, unit, digest);
                }
                Ok(())
            });
            (Some(tx), Some(handle))
        } else {
            (None, None)
        };

        let report = TransferReport {
            algorithm: cfg.algorithm.name().to_string(),
            hash_tier: cfg.hash_tier.name().to_string(),
            ..Default::default()
        };
        Ok(SenderSession {
            injector: FaultInjector::new(&faults),
            crash: faults.crash.clone(),
            cfg,
            storage,
            shared,
            data_outs,
            rr: 0,
            active_lanes: lanes.load(Ordering::Relaxed).max(1),
            lanes,
            pool,
            bufs,
            ck_tx,
            ck_handle,
            verifier,
            ctrl_shutdown,
            data_shutdown,
            resume,
            delta,
            journal,
            obs,
            obs_hash,
            report,
            start: Instant::now(),
            verify,
        })
    }

    /// Stream one file (Algorithm 1 lines 5-8) and arrange its
    /// verification. Returns once the stream is on the wire (FIVER) or
    /// once verified (Sequential pacing). A file the resume handshake
    /// proved fully delivered is skipped outright; a partially-delivered
    /// one streams only its journaled tail and verifies end-to-end via
    /// the journal's digest tree (prefix leaves + streamed tail).
    pub fn send_file(&mut self, file_idx: u32, name: &str) -> Result<()> {
        if self.resume.is_complete(name) {
            return Ok(()); // verified at handshake; accounted engine-level
        }
        // Latch the controller's stripe target at the file boundary: the
        // stripe count is renegotiated *per file* only, so every Data
        // frame of this file round-robins over a fixed lane prefix.
        self.active_lanes =
            self.lanes.load(Ordering::Relaxed).clamp(1, self.data_outs.len());
        let size = self.storage.size_of(name)?;
        let resumed: Option<ResumedFile> = self.resume.partial_for(name, size).cloned();
        // Delta path: the receiver offered a signature basis for this file
        // and no resume prefix claims it — ship only the leaf ranges that
        // changed. (A resumed partial is already incremental; it wins.)
        if resumed.is_none() && self.delta.basis(file_idx).is_some() {
            let delta = self.delta.clone();
            return self.send_file_delta(file_idx, name, size, delta.basis(file_idx).unwrap());
        }
        if self.storage.backend_name() == "auto" {
            // Record the per-file engine choice the auto policy made.
            self.report
                .file_backends
                .push((name.to_string(), self.storage.backend_for(name).to_string()));
        }
        let start_at = resumed.as_ref().map(|r| r.offset).unwrap_or(0);
        let uses_queue = resumed.is_some()
            || self.cfg.algorithm.uses_queue(size, self.cfg.hybrid_threshold);
        let units = if resumed.is_some() {
            vec![(super::protocol::UNIT_FILE, 0, size)]
        } else {
            self.cfg.units_of(size, uses_queue)
        };
        if self.verify {
            self.shared.register(file_idx, units.len());
        }
        self.data_outs[0].send(&Frame::FileStart {
            file_idx,
            size,
            attempt: 0,
            name: name.to_string(),
        })?;

        // FIVER path: queue + pool job digesting the shared buffers. A
        // resumed file always verifies by digest tree, whatever the
        // session algorithm: the pool job seeds the tree with the
        // journaled prefix leaves and folds only the streamed tail.
        // Tree-building jobs also own this file's checkpoint journaling
        // (one hash pass serves both — no LeafTracker second hash on the
        // stream thread; the source is read-only, so no data sync is
        // needed before a checkpoint here).
        let tree_mode = uses_queue
            && self.verify
            && (resumed.is_some() || self.cfg.algorithm == RealAlgorithm::FiverMerkle);
        let queue = if uses_queue && self.verify {
            let q = ByteQueue::new(self.cfg.queue_capacity);
            let q2 = q.clone();
            let hasher = self.cfg.leaf_factory();
            let shared2 = self.shared.clone();
            if tree_mode {
                let fold = match &self.journal {
                    Some(j) => Some(j.begin_fold(name, size, start_at, &self.cfg, None)?),
                    None => None,
                };
                let prefix = resumed.as_ref().map(|rf| (rf.leaves.clone(), rf.offset));
                let leaf_size = self.cfg.leaf_size;
                let node_factory = self.cfg.node_factory();
                let rooted = self.cfg.tree_rooted();
                let hobs = self.obs_hash.clone();
                self.pool.submit(move || {
                    let tree = queue_build_tree_fold(
                        q2,
                        leaf_size,
                        size,
                        prefix,
                        hasher,
                        node_factory,
                        rooted,
                        fold,
                        hobs,
                    );
                    shared2.put_tree(file_idx, tree);
                });
            } else {
                let units2 = units.clone();
                let hobs = self.obs_hash.clone();
                self.pool.submit(move || {
                    queue_hash_units(q2, &units2, hasher, hobs, |unit, _o, _l, digest| {
                        shared2.put_local(file_idx, unit, digest);
                    });
                });
            }
            Some(q)
        } else {
            None
        };

        // Stream-side checkpoint journal (policies whose hash job builds
        // no tree): clean source bytes fold into leaf digests as they
        // stream; resumed files truncate the record to the agreed prefix
        // and append from there. Tree-mode files journal inside the hash
        // job instead (see above).
        let mut jrn: Option<(FileJournal, LeafTracker)> = if tree_mode {
            None
        } else {
            match &self.journal {
                Some(j) => Some(j.begin_file(name, size, start_at, &self.cfg)?),
                None => None,
            }
        };

        self.injector.start_file_at(file_idx as usize, 0, start_at);
        let streamed =
            self.stream_file(file_idx, name, size, start_at, queue.as_ref(), &units, &mut jrn);
        // The hash job must never be left consuming an open queue — the
        // pool's Drop joins its workers (crash/error liveness).
        if let Some(q) = &queue {
            q.close();
        }
        let mut unit_cursor = streamed?;
        self.data_outs[0].send(&Frame::FileEnd { file_idx })?;
        for out in &self.data_outs {
            out.flush()?;
        }
        if queue.is_none() && self.verify {
            // Remaining units past the stream loop's cursor (zero-length
            // files have nothing to stream).
            while unit_cursor < units.len() {
                let (unit, uoff, ulen) = units[unit_cursor];
                self.ck_tx
                    .as_ref()
                    .unwrap()
                    .send((file_idx, name.to_string(), unit, uoff, ulen))?;
                unit_cursor += 1;
            }
        }
        // Close the final (partial) journal leaf and make it durable.
        if let Some((mut fj, mut tracker)) = jrn.take() {
            let t = self.obs.start();
            tracker.finish(|_, d, w| fj.push_leaf(&d, w));
            fj.checkpoint()?;
            self.obs.record(Stage::Journal, t);
        }
        // Pacing per policy. (Resume savings are accounted engine-level
        // from the negotiated plan, not per session.)
        if self.verify {
            let sequential_pace = resumed.is_none()
                && (matches!(self.cfg.algorithm, RealAlgorithm::Sequential)
                    || (matches!(self.cfg.algorithm, RealAlgorithm::FiverHybrid) && !uses_queue));
            if sequential_pace {
                // Definitionally: verification completes before the next
                // file starts.
                self.shared.wait_file_verified(file_idx)?;
            }
            // File-/block-level pipelining pace through the depth-1 job
            // channel (the sends above block appropriately); FIVER doesn't
            // pace at all.
        }
        self.report.files += 1;
        Ok(())
    }

    /// Incremental transfer of one file against the receiver's signature
    /// basis (rsync over journaled leaves, §delta in DESIGN.md). The source
    /// is read once; a rolling weak checksum finds candidate leaf matches
    /// in the basis and a strong digest confirms them. Confirmed leaves
    /// ship as `DeltaCopy` directives (the receiver copies them from its
    /// own old data), everything else ships as literal `Data` frames. All
    /// delta frames ride stripe 0 so `DeltaEnd` cannot overtake them.
    ///
    /// Verification is unchanged: the same read feeds the tree-hash queue,
    /// and the receiver re-hashes its reconstructed file, so a stale or
    /// corrupt basis is caught by the normal TreeRoot/Fix machinery.
    fn send_file_delta(
        &mut self,
        file_idx: u32,
        name: &str,
        size: u64,
        basis: &super::delta::DeltaBasis,
    ) -> Result<()> {
        if self.verify {
            // One tree-verified unit, like a resumed file.
            self.shared.register(file_idx, 1);
        }
        self.data_outs[0].send(&Frame::DeltaStart {
            file_idx,
            size,
            name: name.to_string(),
        })?;
        // Sender-side signature cache: when our own journaled record for
        // this file matches the receiver's basis pair-for-pair (size,
        // geometry, and every full-leaf `(weak, strong)` signature at the
        // same offset), the receiver provably holds the journaled content
        // at identical aligned offsets — the per-byte rolling scan is
        // pure overhead. The read loop then only re-verifies each leaf's
        // strong digest against the record (the bytes are read anyway for
        // the verify tree), so a source mutated *after* journaling ships
        // exactly its dirty leaves as literals rather than poisoning the
        // copies. Decide *before* `begin_fold` below truncates the
        // record.
        let cached_rec = self
            .journal
            .as_ref()
            .and_then(|j| j.load(name).ok().flatten())
            .filter(|rec| delta_cache_hit(rec, basis, &self.cfg, size));
        // Tree verification + journaling ride the same hash queue as the
        // FIVER path: the pool job digests the exact bytes being scanned
        // and journals fresh v2 leaves for the *next* delta run.
        let queue = if self.verify {
            let q = ByteQueue::new(self.cfg.queue_capacity);
            let q2 = q.clone();
            let hasher = self.cfg.leaf_factory();
            let shared2 = self.shared.clone();
            let fold = match &self.journal {
                Some(j) => Some(j.begin_fold(name, size, 0, &self.cfg, None)?),
                None => None,
            };
            let leaf_size = self.cfg.leaf_size;
            let node_factory = self.cfg.node_factory();
            let rooted = self.cfg.tree_rooted();
            let hobs = self.obs_hash.clone();
            self.pool.submit(move || {
                let tree = queue_build_tree_fold(
                    q2,
                    leaf_size,
                    size,
                    None,
                    hasher,
                    node_factory,
                    rooted,
                    fold,
                    hobs,
                );
                shared2.put_tree(file_idx, tree);
            });
            Some(q)
        } else {
            None
        };
        if let Some(rec) = cached_rec {
            let streamed = self.stream_file_delta_cached(file_idx, size, &rec, name, queue.as_ref());
            if let Some(q) = &queue {
                q.close();
            }
            let (copied, clean, literal) = streamed?;
            self.data_outs[0].send(&Frame::DeltaEnd { file_idx })?;
            self.data_outs[0].flush()?;
            self.report.bytes_skipped_delta += copied;
            self.report.leaves_clean += clean;
            let leaf = self.cfg.leaf_size.max(1);
            self.report.leaves_dirty += (literal + leaf - 1) / leaf;
            self.report.delta_scans_skipped += 1;
        } else {
            let mut scanner =
                DeltaScanner::new(basis, self.cfg.leaf_size, &self.cfg.leaf_factory());
            let streamed =
                self.stream_file_delta(file_idx, name, size, queue.as_ref(), &mut scanner);
            if let Some(q) = &queue {
                q.close();
            }
            streamed?;
            self.data_outs[0].send(&Frame::DeltaEnd { file_idx })?;
            self.data_outs[0].flush()?;
            self.report.bytes_skipped_delta += scanner.copied_bytes;
            self.report.leaves_clean += scanner.copies;
            let leaf = self.cfg.leaf_size.max(1);
            self.report.leaves_dirty += (scanner.literal_bytes + leaf - 1) / leaf;
        }
        if self.verify && matches!(self.cfg.algorithm, RealAlgorithm::Sequential) {
            // Sequential keeps its definitional pacing even in delta mode.
            self.shared.wait_file_verified(file_idx)?;
        }
        self.report.files += 1;
        Ok(())
    }

    /// Cache-hit variant of the delta read loop: the rolling scan is
    /// skipped — the journal record already proves the receiver holds the
    /// journaled leaves at identical aligned offsets — but each full
    /// leaf's strong digest is still recomputed from the bytes streaming
    /// past (the same read that feeds the tree-hash queue) and compared
    /// against the record. Matching leaves coalesce into aligned
    /// `DeltaCopy` runs; a leaf mutated since journaling hashes
    /// differently and ships as literal bytes, so a stale cache costs
    /// exactly its dirty leaves, not a repair round. Returns
    /// `(copied_bytes, clean_leaves, literal_bytes)`.
    fn stream_file_delta_cached(
        &mut self,
        file_idx: u32,
        size: u64,
        rec: &JournalRecord,
        name: &str,
        queue: Option<&ByteQueue>,
    ) -> Result<(u64, u64, u64)> {
        let dlen = rec.digest_len;
        let leaf_size = self.cfg.leaf_size as usize;
        let mut hasher = (self.cfg.leaf_factory())();
        let mut leaf_buf: Vec<u8> = Vec::with_capacity(leaf_size);
        let mut leaf_idx = 0usize;
        let full_leaves = (size / self.cfg.leaf_size) as usize;
        let mut run_start = 0u64;
        let mut run_len = 0u64;
        let (mut copied, mut clean, mut literal) = (0u64, 0u64, 0u64);
        let mut reader = self.storage.open_read(name)?;
        let mut offset = 0u64;
        while offset < size {
            if let Some(c) = &self.crash {
                if c.tripped() {
                    return Err(anyhow::Error::new(CrashError));
                }
            }
            let want = self.cfg.buf_size.min((size - offset) as usize).min(self.bufs.buf_size());
            let t = self.obs.start();
            let chunk: SharedBuf = reader.read_shared(offset, want, &self.bufs)?;
            anyhow::ensure!(!chunk.is_empty(), "short read of {name} at {offset}");
            self.obs.record(Stage::Read, t);
            // Classify the chunk leaf by leaf (a leaf may span chunks).
            let mut pos = 0usize;
            while pos < chunk.len() {
                let take = (leaf_size - leaf_buf.len()).min(chunk.len() - pos);
                leaf_buf.extend_from_slice(&chunk[pos..pos + take]);
                pos += take;
                if leaf_buf.len() < leaf_size || leaf_idx >= full_leaves {
                    continue; // partial leaf, or the unaligned tail
                }
                let leaf_off = leaf_idx as u64 * leaf_size as u64;
                hasher.reset();
                hasher.update(&leaf_buf);
                let digest = hasher.finalize();
                if digest.as_slice() == &rec.leaves[leaf_idx * dlen..(leaf_idx + 1) * dlen] {
                    if run_len == 0 {
                        run_start = leaf_off;
                    }
                    run_len += leaf_size as u64;
                    copied += leaf_size as u64;
                    clean += 1;
                } else {
                    if run_len > 0 {
                        self.data_outs[0].send(&Frame::DeltaCopy {
                            file_idx,
                            new_off: run_start,
                            old_off: run_start,
                            len: run_len,
                        })?;
                        run_len = 0;
                    }
                    let t = self.obs.start();
                    self.data_outs[0].send_data(file_idx, leaf_off, &leaf_buf)?;
                    self.obs.record(Stage::Send, t);
                    self.report.bytes_sent += leaf_buf.len() as u64;
                    literal += leaf_buf.len() as u64;
                }
                leaf_buf.clear();
                leaf_idx += 1;
            }
            if let Some(c) = &self.crash {
                c.consume(chunk.len() as u64);
            }
            offset += chunk.len() as u64;
            self.obs.add_bytes(chunk.len() as u64);
            if let Some(q) = queue {
                let t = self.obs.start();
                q.add(chunk);
                self.obs.record(Stage::QueueWait, t);
                self.obs.gauge_depth(q.len_bytes() as u64);
            }
        }
        // Flush the pending copy run, then the unaligned tail (never in
        // the record — always literal) in strict new-file order.
        if run_len > 0 {
            self.data_outs[0].send(&Frame::DeltaCopy {
                file_idx,
                new_off: run_start,
                old_off: run_start,
                len: run_len,
            })?;
        }
        if !leaf_buf.is_empty() {
            let tail_off = full_leaves as u64 * leaf_size as u64;
            let t = self.obs.start();
            self.data_outs[0].send_data(file_idx, tail_off, &leaf_buf)?;
            self.obs.record(Stage::Send, t);
            self.report.bytes_sent += leaf_buf.len() as u64;
            literal += leaf_buf.len() as u64;
        }
        Ok((copied, clean, literal))
    }

    /// Read/scan loop of the delta path: sequential shared-buffer reads
    /// feed the rolling scanner and the tree-hash queue; emitted ops are
    /// flushed to stripe 0 as they appear, so memory stays bounded by the
    /// scanner's window plus one read buffer.
    fn stream_file_delta(
        &mut self,
        file_idx: u32,
        name: &str,
        size: u64,
        queue: Option<&ByteQueue>,
        scanner: &mut DeltaScanner<'_>,
    ) -> Result<()> {
        let mut reader = self.storage.open_read(name)?;
        let mut offset = 0u64;
        while offset < size {
            if let Some(c) = &self.crash {
                if c.tripped() {
                    return Err(anyhow::Error::new(CrashError));
                }
            }
            let want = self.cfg.buf_size.min((size - offset) as usize).min(self.bufs.buf_size());
            let t = self.obs.start();
            let chunk: SharedBuf = reader.read_shared(offset, want, &self.bufs)?;
            anyhow::ensure!(!chunk.is_empty(), "short read of {name} at {offset}");
            self.obs.record(Stage::Read, t);
            scanner.update(&chunk);
            self.flush_delta_ops(file_idx, scanner)?;
            if let Some(c) = &self.crash {
                c.consume(chunk.len() as u64);
            }
            offset += chunk.len() as u64;
            self.obs.add_bytes(chunk.len() as u64);
            if let Some(q) = queue {
                let t = self.obs.start();
                q.add(chunk);
                self.obs.record(Stage::QueueWait, t);
                self.obs.gauge_depth(q.len_bytes() as u64);
            }
        }
        scanner.finish();
        self.flush_delta_ops(file_idx, scanner)?;
        Ok(())
    }

    /// Drain the scanner's pending ops onto stripe 0. Literal bytes count
    /// toward `bytes_sent`; copies are pure directives (a few dozen wire
    /// bytes each) and count toward the skipped total instead.
    fn flush_delta_ops(&mut self, file_idx: u32, scanner: &mut DeltaScanner<'_>) -> Result<()> {
        while let Some(op) = scanner.pop() {
            match op {
                DeltaOp::Literal { new_off, data } => {
                    let t = self.obs.start();
                    self.data_outs[0].send_data(file_idx, new_off, &data)?;
                    self.obs.record(Stage::Send, t);
                    self.report.bytes_sent += data.len() as u64;
                }
                DeltaOp::Copy { new_off, old_off, len } => {
                    self.data_outs[0].send(&Frame::DeltaCopy {
                        file_idx,
                        new_off,
                        old_off,
                        len,
                    })?;
                }
            }
        }
        Ok(())
    }

    /// The read/stripe/queue loop of one file: stream `[start_at, size)`
    /// from source storage over the data channels, feeding the checksum
    /// queue, the re-read-mode unit jobs and the checkpoint journal along
    /// the way. Returns the unit cursor (how many re-read-mode units were
    /// emitted) so the caller continues from exactly where the loop
    /// stopped. Aborts with [`CrashError`] at the next frame boundary
    /// once the fault plan's crash budget is spent.
    #[allow(clippy::too_many_arguments)]
    fn stream_file(
        &mut self,
        file_idx: u32,
        name: &str,
        size: u64,
        start_at: u64,
        queue: Option<&ByteQueue>,
        units: &[(u64, u64, u64)],
        jrn: &mut Option<(FileJournal, LeafTracker)>,
    ) -> Result<usize> {
        let mut reader = self.storage.open_read(name)?;
        let mut offset = start_at;
        let mut unit_cursor = 0usize;
        while offset < size {
            if let Some(c) = &self.crash {
                if c.tripped() {
                    return Err(anyhow::Error::new(CrashError));
                }
            }
            let want = self.cfg.buf_size.min((size - offset) as usize).min(self.bufs.buf_size());
            let lane = self.rr % self.active_lanes;
            self.rr += 1;
            // One ranged read serves socket, hash queue and journal. The
            // clean path is zero-copy: `read_shared` fills a pooled
            // buffer — or, on the mmap backend, returns a refcounted view
            // of the file mapping — which the socket borrows and the hash
            // queue shares by refcount. Only when the fault plan targets
            // this window does the stream pay for a mutable duplicate:
            // the wire gets the corrupted copy while the clean bytes keep
            // feeding checksum and journal (no XOR flip-back dance, and
            // mmap views stay untouched).
            let chunk: SharedBuf = if self.injector.will_corrupt(want) {
                let t = self.obs.start();
                let mut wire = self.bufs.get_or_alloc(POOL_GRACE);
                let n = reader.read_at(offset, &mut wire[..want])?;
                anyhow::ensure!(n > 0, "short read of {name} at {offset}");
                let flips = self.injector.corrupt(&mut wire[..n]);
                self.obs.record(Stage::Read, t);
                let t = self.obs.start();
                self.data_outs[lane].send_data(file_idx, offset, &wire[..n])?;
                self.obs.record(Stage::Send, t);
                for &(pos, bit) in &flips {
                    wire[pos] ^= 1 << bit;
                }
                wire.freeze(n)
            } else {
                let t = self.obs.start();
                let chunk = reader.read_shared(offset, want, &self.bufs)?;
                anyhow::ensure!(!chunk.is_empty(), "short read of {name} at {offset}");
                self.obs.record(Stage::Read, t);
                self.injector.advance(chunk.len());
                let t = self.obs.start();
                self.data_outs[lane].send_data(file_idx, offset, &chunk)?;
                self.obs.record(Stage::Send, t);
                chunk
            };
            let n = chunk.len();
            if let Some(c) = &self.crash {
                c.consume(n as u64);
            }
            // Journal the clean stream: completed leaves append, and every
            // checkpoint_leaves of them fsync (source is read-only, so no
            // data sync is needed on this side).
            if let Some((fj, tracker)) = jrn.as_mut() {
                let t = self.obs.start();
                tracker.update(&chunk, |_, d, w| fj.push_leaf(&d, w));
                if fj.pending_leaves() >= self.cfg.journal_checkpoint_leaves.max(1) {
                    fj.checkpoint()?;
                }
                self.obs.record(Stage::Journal, t);
            }
            self.report.bytes_sent += n as u64;
            self.obs.add_bytes(n as u64);
            offset += n as u64;
            if let Some(q) = queue {
                let t = self.obs.start();
                q.add(chunk);
                self.obs.record(Stage::QueueWait, t);
                self.obs.gauge_depth(q.len_bytes() as u64);
            }
            // Re-read-mode: emit checksum jobs for completed units
            // (block-level overlap within the file).
            if queue.is_none() && self.verify {
                while unit_cursor < units.len() {
                    let (unit, uoff, ulen) = units[unit_cursor];
                    if offset >= uoff + ulen && ulen > 0 {
                        self.ck_tx.as_ref().unwrap().send((
                            file_idx,
                            name.to_string(),
                            unit,
                            uoff,
                            ulen,
                        ))?;
                        unit_cursor += 1;
                    } else {
                        break;
                    }
                }
            }
        }
        Ok(unit_cursor)
    }

    /// Wait for every sent file to verify, close the session (`Done`), and
    /// return the per-session report.
    pub fn finish(mut self) -> Result<TransferReport> {
        if self.verify {
            self.shared.all_registered.store(true, Ordering::SeqCst);
            self.shared.wait_all_verified()?;
        }
        drop(self.ck_tx.take()); // hang up the checksum worker
        self.data_outs[0].send(&Frame::Done)?;
        for out in &self.data_outs {
            out.flush()?;
        }
        if let Some(h) = self.ck_handle.take() {
            h.join().expect("checksum worker panicked")?;
        }
        if let Some(v) = self.verifier.take() {
            v.join().expect("verifier panicked")?;
        }
        self.report.failures_detected = self.shared.failures.load(Ordering::SeqCst);
        self.report.bytes_resent = self.shared.bytes_resent.load(Ordering::SeqCst);
        self.report.repair_rounds = self.shared.repair_rounds.load(Ordering::SeqCst);
        self.report.bytes_reread = self.shared.bytes_reread.load(Ordering::SeqCst);
        self.report.verify_rtts = self.shared.verify_rtts.load(Ordering::SeqCst);
        self.report.pool_fallback_allocs = self.bufs.fallback_allocs();
        self.report.pool_peak_in_flight = self.bufs.peak_in_flight() as u64;
        self.report.pool_grow_events = self.bufs.grow_events();
        self.report.io_backend = self.storage.backend_name().to_string();
        self.report.storage_syncs = self.storage.sync_count();
        self.report.direct_fallbacks = self.storage.direct_fallbacks();
        self.report.uring_fallbacks = self.storage.uring_fallbacks();
        self.report.storage_hints = self.storage.hint_count();
        if self.cfg.obs.is_enabled() {
            // Endpoint-wide snapshot: every session of this endpoint
            // reports the same merged view (the aggregator takes the
            // first non-empty one, mirroring `storage_syncs`).
            let o = self.cfg.obs.report();
            self.report.stage_stats = o.stages;
            self.report.bottleneck = o.bottleneck;
            self.report.bottleneck_confidence = o.confidence;
            self.report.trace_dropped = o.dropped_events;
        }
        self.report.elapsed_secs = self.start.elapsed().as_secs_f64();
        Ok(std::mem::take(&mut self.report))
        // data_outs drop here: BufWriters flush (already flushed above)
        // and the sockets close, which is the receiver readers' EOF.
    }
}

impl Drop for SenderSession {
    fn drop(&mut self) {
        // Clean completion (`finish`) already joined everything. An abort
        // (error / injected crash) must sever the transport so the
        // verifier, the checksum worker and the remote peer all unwind —
        // otherwise healthy sockets could deadlock a half-dead session
        // against a receiver waiting for data that will never come.
        if self.verifier.is_none() && self.ck_handle.is_none() {
            return;
        }
        self.shared.fail();
        if let Some(c) = &self.ctrl_shutdown {
            c.shutdown(std::net::Shutdown::Both).ok();
        }
        for d in &self.data_shutdown {
            d.shutdown(std::net::Shutdown::Both).ok();
        }
        drop(self.ck_tx.take());
        if let Some(h) = self.ck_handle.take() {
            h.join().ok();
        }
        if let Some(v) = self.verifier.take() {
            let _ = v.join();
        }
    }
}

/// Run a single-stripe sender session over connected data/control sockets
/// with a private two-worker hash pool. `files` are names resolvable in
/// `storage`, transferred in order.
pub fn run_sender(
    data: TcpStream,
    ctrl: TcpStream,
    files: &[String],
    storage: Arc<dyn Storage>,
    cfg: &SessionConfig,
    faults: &FaultPlan,
) -> Result<TransferReport> {
    let pool = HashPool::new(2);
    let names: Arc<Vec<String>> = Arc::new(files.to_vec());
    let mut session = SenderSession::new(
        vec![data],
        ctrl,
        names.clone(),
        storage,
        cfg.clone(),
        faults.clone(),
        pool.handle(),
        cfg.make_pool(1),
        Arc::new(ResumePlan::default()),
        Arc::new(DeltaPlan::default()),
        Arc::new(AtomicUsize::new(1)),
    )?;
    for (i, name) in names.iter().enumerate() {
        session.send_file(i as u32, name)?;
    }
    session.finish()
}

/// Verifier: match receiver digests (or Merkle roots) against local ones;
/// repair mismatches by re-reading the failed source range and sending Fix
/// frames. FIVER-Merkle mismatches are binary-searched down the digest
/// tree first, so only the corrupted leaf ranges are re-read and re-sent.
#[allow(clippy::too_many_arguments)]
fn run_verifier(
    ctrl: TcpStream,
    shared: Arc<Shared>,
    storage: Arc<dyn Storage>,
    data_out: DataOut,
    cfg: &SessionConfig,
    names: &[String],
    faults: &FaultPlan,
    bufs: &BufferPool,
) -> Result<()> {
    let mut ctrl_in = BufReader::new(ctrl.try_clone().context("ctrl clone")?);
    let mut ctrl_out = BufWriter::new(ctrl);
    let obs = cfg.obs.shard("sender-verify");
    // Repair rounds per (file, unit): round n's re-sent bytes count as
    // occurrence n for the fault plan (corruption strikes re-transfers too).
    let mut attempts: HashMap<(u32, u64), u32> = HashMap::new();
    loop {
        if shared.all_done() {
            break;
        }
        let frame = match Frame::read_from(&mut ctrl_in)? {
            Some(f) => f,
            None => {
                if shared.all_done() {
                    break;
                }
                bail!("ctrl channel closed with unverified units");
            }
        };
        match frame {
            Frame::Digest { file_idx, unit, digest } => {
                let t = obs.start();
                let local = shared.take_local(file_idx, unit)?;
                shared.verify_rtts.fetch_add(1, Ordering::SeqCst);
                let ok = local == digest;
                Frame::Verdict { file_idx, unit, ok }.write_to(&mut ctrl_out)?;
                ctrl_out.flush()?;
                obs.record(Stage::Verify, t);
                if ok {
                    shared.unit_ok(file_idx);
                    // Verified source bytes won't be re-read (repairs
                    // re-read only on mismatch): let the page cache go.
                    let name = &names[file_idx as usize];
                    if unit == super::protocol::UNIT_FILE {
                        storage.advise_done(name, 0, 0).ok();
                    } else {
                        storage.advise_done(name, unit * cfg.block_size, cfg.block_size).ok();
                    }
                    continue;
                }
                // Mismatch: the receiver recomputes after the repair lands
                // and offers a fresh digest, which compares against the
                // same local value — put it back for that round.
                shared.put_local(file_idx, unit, local);
                shared.failures.fetch_add(1, Ordering::SeqCst);
                let attempt = bump_attempt(&mut attempts, file_idx, unit);
                let name = &names[file_idx as usize];
                let size = storage.size_of(name)?;
                let (offset, len) = unit_range(cfg, unit, size);
                let t = obs.start();
                send_repair_range(
                    &storage, &data_out, &shared, faults, cfg, file_idx, name, offset, len,
                    attempt, bufs,
                )?;
                data_out.send(&Frame::FixEnd { file_idx, unit })?;
                data_out.flush()?;
                obs.record(Stage::Repair, t);
                shared.repair_rounds.fetch_add(1, Ordering::SeqCst);
                // The receiver recomputes and sends a fresh Digest; handled
                // on the next loop iteration.
            }
            Frame::TreeRoot { file_idx, leaves, leaf_size, digest } => {
                let t = obs.start();
                let tree = shared.wait_tree(file_idx)?;
                // Geometry disagreements (leaf size or leaf count) are
                // configuration/protocol errors, not wire corruption: leaf
                // repairs can never change the remote tree's shape, so the
                // loop could not converge — fail loudly instead.
                anyhow::ensure!(
                    leaf_size == tree.leaf_size(),
                    "merkle leaf size mismatch: sender {} vs receiver {} — \
                     both endpoints must agree on --leaf-size",
                    tree.leaf_size(),
                    leaf_size
                );
                anyhow::ensure!(
                    leaves as usize == tree.leaf_count(),
                    "merkle leaf count mismatch on file {file_idx}: sender {} vs receiver \
                     {leaves} — stream length disagrees with the announced size",
                    tree.leaf_count()
                );
                shared.verify_rtts.fetch_add(1, Ordering::SeqCst);
                let ok = tree.root() == &digest[..];
                Frame::Verdict { file_idx, unit: super::protocol::UNIT_FILE, ok }
                    .write_to(&mut ctrl_out)?;
                ctrl_out.flush()?;
                obs.record(Stage::Verify, t);
                if ok {
                    shared.unit_ok(file_idx);
                    shared.drop_tree(file_idx);
                    // Root verified: the whole source file is done with.
                    storage.advise_done(&names[file_idx as usize], 0, 0).ok();
                    continue;
                }
                shared.failures.fetch_add(1, Ordering::SeqCst);
                let attempt = bump_attempt(&mut attempts, file_idx, super::protocol::UNIT_FILE);
                // Binary-search the mismatch down the tree — O(log n)
                // node-range round trips — then re-send only bad leaves.
                let t = obs.start();
                let bad_leaves: Vec<usize> =
                    descend_tree(&mut ctrl_in, &mut ctrl_out, &shared, &tree, file_idx)?;
                anyhow::ensure!(
                    !bad_leaves.is_empty(),
                    "tree root mismatch but no differing leaf found"
                );
                let name = &names[file_idx as usize];
                for (first, last) in coalesce_runs(&bad_leaves) {
                    let (off, _) = tree.leaf_range(first);
                    let (last_off, last_len) = tree.leaf_range(last);
                    send_repair_range(
                        &storage,
                        &data_out,
                        &shared,
                        faults,
                        cfg,
                        file_idx,
                        name,
                        off,
                        last_off + last_len - off,
                        attempt,
                        bufs,
                    )?;
                }
                data_out.send(&Frame::FixEnd { file_idx, unit: super::protocol::UNIT_FILE })?;
                data_out.flush()?;
                obs.record(Stage::Repair, t);
                shared.repair_rounds.fetch_add(1, Ordering::SeqCst);
                Frame::TreeRepairSent {
                    file_idx,
                    round: attempt as u64,
                    leaves_fixed: bad_leaves.len() as u64,
                }
                .write_to(&mut ctrl_out)?;
                ctrl_out.flush()?;
                // The receiver patches the repaired leaves and answers with
                // a fresh TreeRoot; handled on the next loop iteration.
            }
            other => bail!("expected Digest/TreeRoot on ctrl, got {other:?}"),
        }
    }
    Ok(())
}

/// Does the sender's journaled `rec` prove the receiver's `basis` holds
/// byte-identical aligned data for the current `size`-byte source? True
/// only when the record is complete, carries weak sums, matches the
/// session geometry (leaf size and digest width — a record journaled
/// under another hash tier never qualifies), covers every full source
/// leaf, and each of its `(weak, strong)` leaf signatures appears at the
/// same offset in the basis. The check is pure in-memory signature
/// comparison: no source bytes are read.
fn delta_cache_hit(
    rec: &JournalRecord,
    basis: &DeltaBasis,
    cfg: &SessionConfig,
    size: u64,
) -> bool {
    let full = size / cfg.leaf_size;
    let eligible = rec.size == size
        && rec.leaf_size == cfg.leaf_size
        && rec.digest_len == cfg.leaf_len()
        && rec.is_complete()
        && rec.has_weaks()
        && rec.aligned_leaves() == full
        && basis.old_size == size
        && basis.leaves == full
        && full > 0;
    if !eligible {
        return false;
    }
    let dlen = rec.digest_len;
    (0..full as usize).all(|i| {
        basis.contains_at(
            rec.weaks[i],
            &rec.leaves[i * dlen..(i + 1) * dlen],
            i as u64 * cfg.leaf_size,
        )
    })
}

/// Increment and return the repair-round counter for a (file, unit).
fn bump_attempt(attempts: &mut HashMap<(u32, u64), u32>, file_idx: u32, unit: u64) -> u32 {
    let a = attempts.entry((file_idx, unit)).or_insert(0);
    *a += 1;
    *a
}

/// Re-read `[offset, offset+len)` from the source and stream it as Fix
/// frames, applying the fault plan's occurrence-`attempt` flips to the
/// outbound copy only (local digests keep hashing clean source bytes).
/// Repairs ride the same zero-copy plane as the stream: the clean path
/// sends refcounted `read_shared` buffers (a view of the mapping on the
/// mmap backend) as borrowed Fix slices; only a fault-targeted attempt
/// pays a mutable pooled copy.
#[allow(clippy::too_many_arguments)]
fn send_repair_range(
    storage: &Arc<dyn Storage>,
    data_out: &DataOut,
    shared: &Shared,
    faults: &FaultPlan,
    cfg: &SessionConfig,
    file_idx: u32,
    name: &str,
    offset: u64,
    len: u64,
    attempt: u32,
    bufs: &BufferPool,
) -> Result<()> {
    let mut r = storage.open_read(name)?;
    let mut pos = offset;
    let end = offset + len;
    let step = cfg.buf_size.min(bufs.buf_size());
    let dirty = !faults.for_attempt(file_idx as usize, attempt).is_empty();
    while pos < end {
        let want = step.min((end - pos) as usize);
        let n = if dirty {
            let mut buf = bufs.get_or_alloc(POOL_GRACE);
            let n = r.read_at(pos, &mut buf[..want])?;
            anyhow::ensure!(n > 0, "short repair read");
            faults.corrupt_in_place(file_idx as usize, attempt, pos, &mut buf[..n]);
            data_out.send_fix(file_idx, pos, &buf[..n])?;
            n
        } else {
            let chunk = r.read_shared(pos, want, bufs)?;
            anyhow::ensure!(!chunk.is_empty(), "short repair read");
            data_out.send_fix(file_idx, pos, &chunk)?;
            chunk.len()
        };
        shared.bytes_resent.fetch_add(n as u64, Ordering::SeqCst);
        shared.bytes_reread.fetch_add(n as u64, Ordering::SeqCst);
        pos += n as u64;
    }
    Ok(())
}

/// Top-down binary search of a root mismatch: one batched node-range
/// query round per tree level, descending only into mismatched children.
/// Returns the corrupted leaf indices; the wire carries O(k log n) digests
/// for k corrupted leaves instead of the O(n) of a full leaf exchange.
fn descend_tree(
    ctrl_in: &mut BufReader<TcpStream>,
    ctrl_out: &mut BufWriter<TcpStream>,
    shared: &Shared,
    tree: &MerkleTree,
    file_idx: u32,
) -> Result<Vec<usize>> {
    if tree.height() == 1 {
        return Ok(vec![0]); // the root *is* the only leaf
    }
    let mut suspects: Vec<usize> = vec![0]; // the root, at the top level
    for level in (0..tree.height() - 1).rev() {
        // Leaf and interior digests may differ in width under tiered
        // hashing — size comparisons by the level being queried.
        let dlen = tree.level_len(level);
        let width = tree.level_width(level);
        let mut wanted: Vec<usize> = Vec::new();
        for &p in &suspects {
            for c in [2 * p, 2 * p + 1] {
                if c < width {
                    wanted.push(c);
                }
            }
        }
        // A coalesced run's TreeNodes reply must stay far below the 64 MiB
        // frame payload cap even at 32-byte digests: split long runs.
        const MAX_QUERY_NODES: usize = 4096; // 128 KiB of digests per reply
        let queries: Vec<(usize, usize)> = coalesce_runs(&wanted)
            .into_iter()
            .flat_map(|(first, last)| {
                (first..=last)
                    .step_by(MAX_QUERY_NODES)
                    .map(move |s| (s, last.min(s + MAX_QUERY_NODES - 1)))
            })
            .collect();
        let mut mismatched: Vec<usize> = Vec::new();
        // Bounded request window per flush: writing *every* query before
        // reading any response can deadlock both TCP directions when
        // corruption is massive (thousands of runs per level filling the
        // receive buffers on both sides). 64 runs ≈ 2 KiB of queries,
        // and the sender drains each reply as it arrives.
        const QUERY_WINDOW: usize = 64;
        for batch in queries.chunks(QUERY_WINDOW) {
            for &(first, last) in batch {
                Frame::TreeQuery {
                    file_idx,
                    level: level as u64,
                    start: first as u64,
                    count: (last - first + 1) as u64,
                }
                .write_to(ctrl_out)?;
            }
            ctrl_out.flush()?;
            shared.verify_rtts.fetch_add(1, Ordering::SeqCst);
            for &(first, last) in batch {
                let frame =
                    Frame::read_from(ctrl_in)?.context("ctrl channel closed mid-descent")?;
                let Frame::TreeNodes { file_idx: fi, level: lv, start, digests } = frame else {
                    bail!("expected TreeNodes, got {frame:?}");
                };
                anyhow::ensure!(
                    fi == file_idx && lv == level as u64 && start == first as u64,
                    "tree nodes for wrong range ({fi},{lv},{start})"
                );
                for (i, idx) in (first..=last).enumerate() {
                    // Absent or differing remote node => suspect.
                    if digests.get(i * dlen..(i + 1) * dlen) != Some(tree.node(level, idx)) {
                        mismatched.push(idx);
                    }
                }
            }
        }
        suspects = mismatched;
        anyhow::ensure!(
            !suspects.is_empty(),
            "tree level {level} matches but the level above did not"
        );
    }
    Ok(suspects)
}

/// Coalesce sorted indices into inclusive `(first, last)` runs.
fn coalesce_runs(sorted: &[usize]) -> Vec<(usize, usize)> {
    let mut runs: Vec<(usize, usize)> = Vec::new();
    for &i in sorted {
        match runs.last_mut() {
            Some((_, last)) if *last + 1 == i => *last = i,
            Some((_, last)) if *last >= i => {} // duplicate
            _ => runs.push((i, i)),
        }
    }
    runs
}

/// Byte range of a verification unit.
fn unit_range(cfg: &SessionConfig, unit: u64, file_size: u64) -> (u64, u64) {
    if unit == super::protocol::UNIT_FILE {
        (0, file_size)
    } else {
        let us = cfg.block_size;
        let offset = unit * us;
        (offset, us.min(file_size - offset))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::native_factory;
    use crate::hashes::HashAlgorithm;

    #[test]
    fn unit_range_math() {
        let mut cfg =
            SessionConfig::new(RealAlgorithm::FiverChunk, native_factory(HashAlgorithm::Md5));
        cfg.block_size = 100;
        assert_eq!(unit_range(&cfg, super::super::protocol::UNIT_FILE, 250), (0, 250));
        assert_eq!(unit_range(&cfg, 0, 250), (0, 100));
        assert_eq!(unit_range(&cfg, 2, 250), (200, 50));
    }

    #[test]
    fn shared_local_digest_rendezvous() {
        let shared = Shared::new();
        let s2 = shared.clone();
        let t = std::thread::spawn(move || s2.take_local(3, 7));
        std::thread::sleep(std::time::Duration::from_millis(20));
        shared.put_local(3, 7, vec![0xAB]);
        assert_eq!(t.join().unwrap(), vec![0xAB]);
        // take_local removed the entry; the session map stays bounded.
        assert!(shared.local.lock().unwrap().is_empty());
    }

    #[test]
    fn shared_remaining_tracking() {
        let shared = Shared::new();
        shared.register(0, 2);
        assert!(!shared.all_done());
        shared.unit_ok(0);
        shared.unit_ok(0);
        shared.all_registered.store(true, Ordering::SeqCst);
        assert!(shared.all_done());
        shared.wait_file_verified(0).unwrap(); // returns immediately
        shared.wait_all_verified().unwrap();
    }

    #[test]
    fn failed_session_unblocks_waiters() {
        let shared = Shared::new();
        shared.register(0, 1); // never verified
        let s2 = shared.clone();
        let t = std::thread::spawn(move || s2.wait_all_verified());
        std::thread::sleep(std::time::Duration::from_millis(20));
        shared.fail();
        assert!(t.join().unwrap().is_err(), "failed session must wake + bail waiters");
        assert!(shared.wait_file_verified(0).is_err());
    }
}
