//! The adaptive concurrency controller: close the feedback loop from
//! the observability plane's bottleneck signal to the knobs that move
//! it.
//!
//! PR 6 labels every run `hash-` / `read-` / `write-` / `net-bound`
//! with a confidence ratio, but hash-pool width and per-file stripe
//! count are fixed at launch. This module acts on the signal with an
//! AIMD loop sampled every `--control-interval` milliseconds:
//!
//! * **Signal.** Each window diffs [`crate::obs::Recorder`]'s cheap
//!   live counters — per-group busy seconds
//!   ([`crate::obs::Recorder::stage_busy_snapshot`], which folds queue
//!   depth in as `QueueWait` busy and hash-pool saturation as `Hash`
//!   busy), total payload bytes, and pool occupancy — into a
//!   [`WindowSample`], then labels the window via
//!   [`crate::obs::attribute`].
//! * **Decision.** [`Aimd`] is pure and deterministic (shared with the
//!   sim's replayable controller): *additive* grow of the hash pool by
//!   one worker on a sustained hash-bound label above the confidence
//!   threshold; *multiplicative* probe-halving of the stripe count on a
//!   sustained net-bound label (a saturated wire needs fewer lanes, so
//!   the controller walks P down and **restores** the previous value if
//!   throughput regresses more than 10%); halving of an overshot hash
//!   pool whose group went near-idle. Every decision is followed by a
//!   cooldown of `cooldown_windows` windows (hysteresis — the pipeline
//!   needs time to show the effect) and a sustained-signal requirement
//!   before the next, so the loop cannot oscillate. Stripes never grow
//!   past the provisioned lane count and the pool is clamped to
//!   `--max-hash-workers`.
//! * **Actuation.** Hash workers are added/drain-retired on the live
//!   [`HashPool`] (see the retire argument in
//!   [`crate::coordinator::pool`]); the stripe target is a shared
//!   atomic the sender latches *per file* — an in-flight file's lane
//!   assignment never changes mid-file, so the receiver's merger sees
//!   every file on a stable stripe set (lanes are provisioned up front
//!   to `--max-parallel`; idle lanes simply carry no frames).
//!
//! Every decision is recorded as a [`ControlEvent`] and surfaces in the
//! report's `adaptations` list, so a run's control trajectory is
//! auditable after the fact.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::pool::HashPool;
use crate::obs::Recorder;

/// Adaptive-controller knobs, carried on
/// [`super::SessionConfig`]. `adaptive` is off by default: all existing
/// behavior is unchanged unless `--adaptive` is passed.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlConfig {
    /// Run the feedback controller (`--adaptive`).
    pub adaptive: bool,
    /// Sample-window length in milliseconds (`--control-interval`).
    pub interval_ms: u64,
    /// Ceiling for the per-file stripe count (`--max-parallel`); lanes
    /// are provisioned up front to this count.
    pub max_parallel: usize,
    /// Ceiling for the hash-pool width (`--max-hash-workers`).
    pub max_hash_workers: usize,
    /// Minimum attribution confidence (busiest group over runner-up)
    /// before a window counts toward a sustained imbalance.
    pub conf_threshold: f64,
    /// Windows of hysteresis after every action before the next.
    pub cooldown_windows: u32,
}

impl Default for ControlConfig {
    fn default() -> ControlConfig {
        ControlConfig {
            adaptive: false,
            interval_ms: 200,
            max_parallel: 8,
            max_hash_workers: 8,
            conf_threshold: 1.5,
            cooldown_windows: 2,
        }
    }
}

impl ControlConfig {
    /// The defaults, with `adaptive` forced on when `FIVER_ADAPTIVE=1`
    /// is set — the CI lever that runs an entire test suite with the
    /// controller live (mirroring `FIVER_TRACE` / `FIVER_IO_BACKEND`).
    pub fn from_env() -> ControlConfig {
        ControlConfig {
            adaptive: std::env::var("FIVER_ADAPTIVE").is_ok_and(|v| v == "1"),
            ..Default::default()
        }
    }
}

/// One recorded controller decision — the report's `adaptations` trail.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlEvent {
    /// Seconds since the run started.
    pub t_secs: f64,
    /// The window's signal, e.g. `"hash-bound (conf 3.2x, pool 4/4)"`.
    pub signal: String,
    /// Which knob moved: `"hash_workers"` or `"stripes"`.
    pub actuator: &'static str,
    /// `"grow"`, `"shrink"`, or `"restore"` (a reverted stripe probe).
    pub action: String,
    /// Knob value before the decision.
    pub before: usize,
    /// Knob value after the decision.
    pub after: usize,
}

/// One sample window's worth of signal, fed to [`Aimd::step`]. Busy
/// values are per-window deltas (not cumulative), in seconds.
#[derive(Debug, Clone)]
pub struct WindowSample {
    /// Seconds since the run started.
    pub t_secs: f64,
    /// Per-group busy-seconds delta for this window, in
    /// [`crate::obs::Recorder::stage_busy_snapshot`] order.
    pub busy: [(&'static str, f64); 4],
    /// Payload bytes per second over this window.
    pub throughput: f64,
    /// Live hash-pool width at sample time.
    pub hash_workers: usize,
    /// Current per-file stripe target at sample time.
    pub stripes: usize,
    /// Buffer-pool occupancy `(in_flight, capacity)` at sample time
    /// (context for the decision trail).
    pub pool_occupancy: (usize, usize),
}

/// Windows a label must persist above the confidence threshold before
/// the controller acts on it.
const SUSTAIN_WINDOWS: u32 = 2;

/// Throughput regression tolerance for a stripe-shrink probe: if the
/// window after a shrink moves fewer bytes/sec than `1 - 0.10` of the
/// pre-shrink baseline, the shrink is restored.
const PROBE_TOLERANCE: f64 = 0.10;

/// An outstanding stripe-shrink probe: the value to restore and the
/// throughput baseline it must hold.
struct Probe {
    prev_stripes: usize,
    baseline: f64,
}

/// The deterministic AIMD decision core, shared verbatim between the
/// real controller thread and the sim's replayable controller. Feed it
/// one [`WindowSample`] per window; it returns at most one actuation
/// per window and records every decision.
pub struct Aimd {
    cfg: ControlConfig,
    cooldown: u32,
    sustain: u32,
    last_label: String,
    /// A stripe probe regressed: hold P until the bottleneck label
    /// changes (re-probing the same regime would thrash).
    failed_shrink: bool,
    probe: Option<Probe>,
    events: Vec<ControlEvent>,
}

impl Aimd {
    /// A fresh controller with zeroed hysteresis state.
    pub fn new(cfg: ControlConfig) -> Aimd {
        Aimd {
            cfg,
            cooldown: 0,
            sustain: 0,
            last_label: String::new(),
            failed_shrink: false,
            probe: None,
            events: Vec::new(),
        }
    }

    fn push(
        &mut self,
        s: &WindowSample,
        signal: String,
        actuator: &'static str,
        action: &str,
        before: usize,
        after: usize,
    ) {
        self.events.push(ControlEvent {
            t_secs: s.t_secs,
            signal,
            actuator,
            action: action.to_string(),
            before,
            after,
        });
    }

    /// Consume one sample window; returns `Some((actuator, target))`
    /// when a knob should move. The caller applies the actuation and
    /// reflects it in the next window's sample.
    pub fn step(&mut self, s: &WindowSample) -> Option<(&'static str, usize)> {
        let (label, conf) = crate::obs::attribute(&s.busy);
        let signal = format!(
            "{} (conf {}, pool {}/{})",
            if label.is_empty() { "idle" } else { label.as_str() },
            crate::obs::cli_confidence(conf),
            s.pool_occupancy.0,
            s.pool_occupancy.1,
        );

        // Resolve an outstanding stripe probe first, even inside the
        // cooldown: the window right after the shrink is exactly the
        // evidence the probe waits for.
        if let Some(p) = self.probe.take() {
            if s.throughput < p.baseline * (1.0 - PROBE_TOLERANCE) {
                self.failed_shrink = true;
                let before = s.stripes;
                self.push(s, signal, "stripes", "restore", before, p.prev_stripes);
                self.cooldown = self.cfg.cooldown_windows;
                return Some(("stripes", p.prev_stripes));
            }
        }

        if label != self.last_label {
            self.sustain = 0;
            self.failed_shrink = false;
            self.last_label = label.clone();
        }
        if !label.is_empty() && conf >= self.cfg.conf_threshold {
            self.sustain += 1;
        } else {
            self.sustain = 0;
        }
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return None;
        }
        if self.sustain < SUSTAIN_WINDOWS {
            return None;
        }

        // Additive grow: a sustained hash bottleneck gets one more
        // worker per decision, up to the ceiling.
        if label == "hash-bound" && s.hash_workers < self.cfg.max_hash_workers {
            let to = s.hash_workers + 1;
            self.push(s, signal, "hash_workers", "grow", s.hash_workers, to);
            self.cooldown = self.cfg.cooldown_windows;
            return Some(("hash_workers", to));
        }

        // Multiplicative stripe probe: a saturated wire needs fewer
        // lanes; halve P and verify throughput holds next window.
        if label == "net-bound" && s.stripes > 1 && !self.failed_shrink {
            let to = (s.stripes / 2).max(1);
            self.probe = Some(Probe { prev_stripes: s.stripes, baseline: s.throughput });
            self.push(s, signal, "stripes", "shrink", s.stripes, to);
            self.cooldown = self.cfg.cooldown_windows;
            return Some(("stripes", to));
        }

        // Overshoot: the hash group went near-idle while something else
        // is the bottleneck — halve the pool back down.
        let top = s.busy.iter().fold(0.0f64, |a, &(_, v)| a.max(v));
        let hash_busy = s.busy.iter().find(|(g, _)| *g == "hash").map_or(0.0, |&(_, v)| v);
        if label != "hash-bound" && s.hash_workers > 1 && hash_busy < 0.5 * top {
            let to = (s.hash_workers / 2).max(1);
            self.push(s, signal, "hash_workers", "shrink", s.hash_workers, to);
            self.cooldown = self.cfg.cooldown_windows;
            return Some(("hash_workers", to));
        }
        None
    }

    /// The recorded decision trail (drains the controller).
    pub fn take_events(&mut self) -> Vec<ControlEvent> {
        std::mem::take(&mut self.events)
    }
}

/// The real engine's controller thread: samples the recorder every
/// interval, runs [`Aimd`], and actuates the live [`HashPool`] and the
/// shared stripe target. [`Controller::stop`] joins it and returns the
/// decision trail.
pub struct Controller {
    stop_tx: mpsc::Sender<()>,
    handle: JoinHandle<Vec<ControlEvent>>,
}

impl Controller {
    /// Spawn the sampling thread. `lanes` is the sender-side stripe
    /// target (latched per file); `lanes_cap` is how many data lanes
    /// were actually provisioned at session setup — the hard ceiling
    /// for any stripe actuation.
    pub fn spawn(
        cfg: ControlConfig,
        rec: Recorder,
        pool: HashPool,
        lanes: Arc<AtomicUsize>,
        lanes_cap: usize,
    ) -> Controller {
        let (stop_tx, stop_rx) = mpsc::channel::<()>();
        let interval = Duration::from_millis(cfg.interval_ms.max(1));
        let handle = std::thread::Builder::new()
            .name("fiver-control".into())
            .spawn(move || {
                let start = Instant::now();
                let mut aimd = Aimd::new(cfg);
                let mut prev_busy = rec.stage_busy_snapshot();
                let mut prev_bytes = rec.total_bytes();
                let mut prev_t = start;
                loop {
                    match stop_rx.recv_timeout(interval) {
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                    let busy = rec.stage_busy_snapshot();
                    let bytes = rec.total_bytes();
                    let now = Instant::now();
                    let dt = now.duration_since(prev_t).as_secs_f64().max(1e-9);
                    let mut delta = busy;
                    for (d, p) in delta.iter_mut().zip(prev_busy.iter()) {
                        d.1 = (d.1 - p.1).max(0.0);
                    }
                    let sample = WindowSample {
                        t_secs: start.elapsed().as_secs_f64(),
                        busy: delta,
                        throughput: bytes.saturating_sub(prev_bytes) as f64 / dt,
                        hash_workers: pool.workers(),
                        stripes: lanes.load(Ordering::Relaxed),
                        pool_occupancy: rec.pool_occupancy(),
                    };
                    prev_busy = busy;
                    prev_bytes = bytes;
                    prev_t = now;
                    if let Some((actuator, to)) = aimd.step(&sample) {
                        match actuator {
                            "hash_workers" => {
                                let cur = pool.workers();
                                if to > cur {
                                    pool.grow(to - cur);
                                } else if to < cur {
                                    pool.retire(cur - to);
                                }
                            }
                            "stripes" => {
                                lanes.store(to.clamp(1, lanes_cap.max(1)), Ordering::Relaxed);
                            }
                            _ => {}
                        }
                    }
                }
                aimd.take_events()
            })
            .expect("spawn control thread");
        Controller { stop_tx, handle }
    }

    /// Stop sampling, join the thread, and return the decision trail.
    pub fn stop(self) -> Vec<ControlEvent> {
        let _ = self.stop_tx.send(());
        self.handle.join().expect("control thread panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(
        busy: [(&'static str, f64); 4],
        tput: f64,
        workers: usize,
        stripes: usize,
    ) -> WindowSample {
        WindowSample {
            t_secs: 0.0,
            busy,
            throughput: tput,
            hash_workers: workers,
            stripes,
            pool_occupancy: (0, 0),
        }
    }

    fn hash_bound(workers: usize) -> WindowSample {
        sample([("read", 0.01), ("hash", 0.18), ("write", 0.01), ("net", 0.02)], 1e8, workers, 1)
    }

    fn net_bound(stripes: usize, tput: f64) -> WindowSample {
        sample([("read", 0.01), ("hash", 0.02), ("write", 0.01), ("net", 0.18)], tput, 1, stripes)
    }

    #[test]
    fn sustained_hash_bound_grows_additively_with_cooldown() {
        let mut aimd = Aimd::new(ControlConfig { max_hash_workers: 4, ..Default::default() });
        let mut workers = 1usize;
        let mut grows = Vec::new();
        for w in 0..40 {
            if let Some((actuator, to)) = aimd.step(&hash_bound(workers)) {
                assert_eq!(actuator, "hash_workers");
                assert_eq!(to, workers + 1, "additive: one worker per decision");
                workers = to;
                grows.push(w);
            }
        }
        assert_eq!(workers, 4, "clamped at --max-hash-workers");
        for pair in grows.windows(2) {
            assert!(pair[1] - pair[0] > 2, "hysteresis between decisions: {grows:?}");
        }
        let events = aimd.take_events();
        assert_eq!(events.len(), 3);
        assert!(events.iter().all(|e| e.action == "grow" && e.after == e.before + 1));
        assert!(events[0].signal.contains("hash-bound"), "{}", events[0].signal);
    }

    #[test]
    fn one_noisy_window_does_not_trigger() {
        let mut aimd = Aimd::new(ControlConfig::default());
        // A single hash-bound window between idle ones: no sustained
        // signal, no decision.
        let idle = sample([("read", 0.0), ("hash", 0.0), ("write", 0.0), ("net", 0.0)], 0.0, 1, 1);
        assert!(aimd.step(&idle).is_none());
        assert!(aimd.step(&hash_bound(1)).is_none());
        assert!(aimd.step(&idle).is_none());
        assert!(aimd.step(&hash_bound(1)).is_none());
        assert!(aimd.take_events().is_empty());
    }

    #[test]
    fn low_confidence_never_acts() {
        let mut aimd = Aimd::new(ControlConfig::default());
        // hash barely above net: confidence ~1.1 < 1.5 threshold.
        let s = sample([("read", 0.0), ("hash", 0.11), ("write", 0.0), ("net", 0.10)], 1e8, 1, 1);
        for _ in 0..20 {
            assert!(aimd.step(&s).is_none());
        }
    }

    #[test]
    fn net_bound_probe_halves_stripes_to_one_when_throughput_holds() {
        let mut aimd = Aimd::new(ControlConfig::default());
        let mut stripes = 8usize;
        for _ in 0..40 {
            if let Some((actuator, to)) = aimd.step(&net_bound(stripes, 1e9)) {
                assert_eq!(actuator, "stripes");
                assert_eq!(to, (stripes / 2).max(1), "multiplicative halve");
                stripes = to;
            }
        }
        assert_eq!(stripes, 1, "a saturated wire converges to one lane");
        let events = aimd.take_events();
        assert_eq!(events.len(), 3, "8 -> 4 -> 2 -> 1");
        assert!(events.iter().all(|e| e.action == "shrink"));
    }

    #[test]
    fn regressed_probe_restores_and_stops_probing() {
        let mut aimd = Aimd::new(ControlConfig::default());
        let mut stripes = 8usize;
        let mut restored = false;
        for _ in 0..40 {
            // Model per-lane throttling: throughput scales with lanes,
            // so any shrink regresses by ~half.
            let tput = 1e8 * stripes as f64;
            if let Some((actuator, to)) = aimd.step(&net_bound(stripes, tput)) {
                assert_eq!(actuator, "stripes");
                if to > stripes {
                    assert_eq!(to, 8, "restore returns to the pre-probe value");
                    restored = true;
                } else {
                    assert!(!restored, "no re-probe after a failed shrink");
                }
                stripes = to;
            }
        }
        assert!(restored);
        assert_eq!(stripes, 8);
        let events = aimd.take_events();
        assert_eq!(events.len(), 2, "one probe, one restore: {events:?}");
        assert_eq!(events[1].action, "restore");
    }

    #[test]
    fn idle_hash_pool_is_halved_on_overshoot() {
        let mut aimd = Aimd::new(ControlConfig::default());
        let mut workers = 8usize;
        for _ in 0..40 {
            let probe = net_bound(1, 1e9).clone_with_workers(workers);
            if let Some((actuator, to)) = aimd.step(&probe) {
                assert_eq!(actuator, "hash_workers");
                assert_eq!(to, (workers / 2).max(1));
                workers = to;
            }
        }
        assert_eq!(workers, 1, "idle pool decays to the floor");
    }

    impl WindowSample {
        fn clone_with_workers(&self, w: usize) -> WindowSample {
            let mut s = self.clone();
            s.hash_workers = w;
            s
        }
    }

    #[test]
    fn controller_thread_actuates_pool_and_lanes() {
        // Drive the real harness with a recorder we feed synthetically:
        // hash-bound busy deltas must grow the live pool; the trail
        // records it.
        let rec = Recorder::enabled();
        let shard = rec.shard("synthetic");
        let pool = HashPool::new(1);
        let lanes = Arc::new(AtomicUsize::new(4));
        let cfg = ControlConfig {
            adaptive: true,
            interval_ms: 10,
            max_hash_workers: 2,
            ..Default::default()
        };
        let ctl = Controller::spawn(cfg, rec.clone(), pool.clone(), lanes.clone(), 4);
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut t0 = 0u64;
        while pool.workers() < 2 && Instant::now() < deadline {
            // Keep every window hash-bound: ~5ms hash busy per 10ms.
            shard.record_ns(crate::obs::Stage::Hash, t0, 5_000_000);
            shard.record_ns(crate::obs::Stage::Send, t0, 100_000);
            t0 += 10_000_000;
            std::thread::sleep(Duration::from_millis(5));
        }
        let events = ctl.stop();
        assert_eq!(pool.workers(), 2, "controller must grow the pool to the max");
        assert!(
            events.iter().any(|e| e.actuator == "hash_workers" && e.action == "grow"),
            "{events:?}"
        );
        assert_eq!(lanes.load(Ordering::Relaxed), 4, "hash-bound run never moves stripes");
    }
}
