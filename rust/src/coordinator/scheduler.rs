//! The parallel engine's file scheduler: work items, the work-stealing
//! queue that feeds N concurrent sessions, and the engine configuration
//! and aggregate report types.
//!
//! Scheduling policy (mirrored by the simulator in
//! [`crate::sim::algorithms::run_concurrent`]):
//!
//! 1. [`crate::workload::plan_batches`] turns the file list into work
//!    items — small files aggregate into tar-like batches, large files
//!    stand alone — so per-file control exchanges amortize and no single
//!    huge file serializes the tail.
//! 2. Items are dealt round-robin onto per-session deques. Each session
//!    pops from the *front* of its own deque; when empty it steals from
//!    the *back* of the longest other deque. Front-pop keeps each
//!    session's files in dataset order (sequential source reads); back-
//!    steal takes the work its owner would reach last, minimizing
//!    contention on the same region of the dataset.

use std::collections::VecDeque;
use std::sync::Mutex;

use super::TransferReport;

/// One schedulable unit: the dataset indices a session transfers
/// back-to-back.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkItem {
    /// Indices into the run's file list that this item covers.
    pub files: Vec<usize>,
}

/// Parallel-engine knobs (the GridFTP-style concurrency/parallelism pair
/// plus pool and batching tuning).
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Concurrent sessions (GridFTP "concurrency"): each drives its own
    /// sender/receiver pair over its own connection set.
    pub concurrency: usize,
    /// Data channels per session (GridFTP "parallelism"): each file's
    /// Data frames round-robin across this many sockets.
    pub parallel: usize,
    /// Shared hash pool size per endpoint; 0 = `max(concurrency, 2)`.
    pub hash_workers: usize,
    /// Files smaller than this aggregate into batched work items
    /// (0 disables batching).
    pub batch_threshold: u64,
    /// Target payload per batch.
    pub batch_bytes: u64,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        // Batching defaults match the simulator's
        // (`crate::config::AlgoParams`), so a default real run and a
        // default `run_concurrent` plan the same schedule.
        EngineConfig {
            concurrency: 1,
            parallel: 1,
            hash_workers: 0,
            batch_threshold: 16 << 20,
            batch_bytes: 64 << 20,
        }
    }
}

impl EngineConfig {
    /// A config with `concurrency` sessions and defaults elsewhere.
    pub fn with_concurrency(concurrency: usize) -> EngineConfig {
        EngineConfig { concurrency: concurrency.max(1), ..Default::default() }
    }

    /// Effective hash pool size.
    pub fn pool_workers(&self) -> usize {
        if self.hash_workers > 0 {
            self.hash_workers
        } else {
            self.concurrency.max(2)
        }
    }

    /// Plan the work items for `sizes` under this configuration.
    pub fn plan(&self, sizes: &[u64]) -> Vec<WorkItem> {
        crate::workload::plan_batches(sizes, self.batch_threshold, self.batch_bytes)
            .into_iter()
            .map(|files| WorkItem { files })
            .collect()
    }

    /// Plan a resumed run: files in `skip` (fully delivered and verified
    /// at the resume handshake) drop out, and items that become empty
    /// vanish — the crashed queue's drain state reconstructs as exactly
    /// the unfinished tail of the dataset. Partially-delivered files stay
    /// in the plan; their sessions stream only the journaled tail.
    pub fn plan_resume(
        &self,
        sizes: &[u64],
        skip: &std::collections::HashSet<usize>,
    ) -> Vec<WorkItem> {
        self.plan(sizes)
            .into_iter()
            .filter_map(|mut item| {
                item.files.retain(|f| !skip.contains(f));
                if item.files.is_empty() {
                    None
                } else {
                    Some(item)
                }
            })
            .collect()
    }

    /// Plan a delta run on top of [`EngineConfig::plan_resume`]: files
    /// with a negotiated signature basis leave their batches and stand
    /// alone. A delta file's cost is dominated by the local source scan,
    /// not the wire, so batching several of them into one work item would
    /// serialize their scans on a single session while others idle; as
    /// standalone items the work-stealing queue spreads them out.
    pub fn plan_delta(
        &self,
        sizes: &[u64],
        skip: &std::collections::HashSet<usize>,
        delta_files: &std::collections::HashSet<usize>,
    ) -> Vec<WorkItem> {
        if delta_files.is_empty() {
            return self.plan_resume(sizes, skip);
        }
        let mut out = Vec::new();
        for item in self.plan_resume(sizes, skip) {
            let (solo, rest): (Vec<usize>, Vec<usize>) =
                item.files.iter().copied().partition(|f| delta_files.contains(f));
            out.extend(solo.into_iter().map(|f| WorkItem { files: vec![f] }));
            if !rest.is_empty() {
                out.push(WorkItem { files: rest });
            }
        }
        out
    }
}

/// Per-session deques with stealing. All methods are safe to call from
/// any session thread.
pub struct WorkStealQueue {
    deques: Vec<Mutex<VecDeque<WorkItem>>>,
}

impl WorkStealQueue {
    /// Deal `items` round-robin across `sessions` deques.
    pub fn new(items: Vec<WorkItem>, sessions: usize) -> WorkStealQueue {
        let n = sessions.max(1);
        let mut deques: Vec<VecDeque<WorkItem>> = (0..n).map(|_| VecDeque::new()).collect();
        for (i, item) in items.into_iter().enumerate() {
            deques[i % n].push_back(item);
        }
        WorkStealQueue { deques: deques.into_iter().map(Mutex::new).collect() }
    }

    /// Next item for `session`: own front, else steal from the back of
    /// the currently longest other deque. `None` only when every deque is
    /// empty at the moment of the scan.
    pub fn next(&self, session: usize) -> Option<WorkItem> {
        if let Some(item) = self.deques[session].lock().unwrap().pop_front() {
            return Some(item);
        }
        // Steal from the victim with the most remaining work.
        loop {
            let mut victim: Option<(usize, usize)> = None; // (index, len)
            for (i, d) in self.deques.iter().enumerate() {
                if i == session {
                    continue;
                }
                let len = d.lock().unwrap().len();
                if len > 0 && victim.map(|(_, l)| len > l).unwrap_or(true) {
                    victim = Some((i, len));
                }
            }
            let Some((v, _)) = victim else { return None };
            // The victim may have drained between the scan and the lock;
            // rescan rather than give up.
            if let Some(item) = self.deques[v].lock().unwrap().pop_back() {
                return Some(item);
            }
        }
    }

    /// Remaining items across all deques (racy snapshot, for reporting).
    pub fn remaining(&self) -> usize {
        self.deques.iter().map(|d| d.lock().unwrap().len()).sum()
    }

    /// Racy snapshot of the undrained items per deque — the queue's
    /// "drain state". The checkpoint journal does not persist this
    /// directly (per-file watermarks are the durable truth); the snapshot
    /// exists for telemetry and for tests that pin the resume planner's
    /// equivalence to it.
    pub fn snapshot(&self) -> Vec<Vec<WorkItem>> {
        self.deques.iter().map(|d| d.lock().unwrap().iter().cloned().collect()).collect()
    }
}

/// Aggregate outcome of an engine run: one [`TransferReport`] per session
/// plus the wall-clock of the whole fan-out.
#[derive(Debug, Default, Clone)]
pub struct EngineReport {
    /// One report per sender session, in session order.
    pub per_session: Vec<TransferReport>,
    /// Files skipped outright at the resume handshake (engine-level: the
    /// scheduler never enqueued them).
    pub files_skipped: u64,
    /// Bytes not re-sent thanks to the checkpoint journal (sum of agreed
    /// resume offsets).
    pub bytes_skipped: u64,
    /// Adaptive-controller decision trail (engine-level: one controller
    /// per engine run; empty when `--adaptive` is off).
    pub adaptations: Vec<super::control::ControlEvent>,
    /// Wall-clock of the engine run (sessions overlap, so this is less
    /// than the sum of per-session elapsed times whenever concurrency
    /// helps).
    pub elapsed_secs: f64,
}

impl EngineReport {
    /// Sum the per-session reports into one dataset-level report.
    /// `elapsed_secs` is the engine wall-clock, not the per-session sum.
    /// Pool telemetry takes the per-session max (the pool is shared per
    /// endpoint, so each session snapshots the same counters).
    pub fn aggregate(&self) -> TransferReport {
        let mut total = TransferReport {
            algorithm: self.per_session.first().map(|r| r.algorithm.clone()).unwrap_or_default(),
            io_backend: self.per_session.first().map(|r| r.io_backend.clone()).unwrap_or_default(),
            hash_tier: self.per_session.first().map(|r| r.hash_tier.clone()).unwrap_or_default(),
            elapsed_secs: self.elapsed_secs,
            files_skipped: self.files_skipped,
            bytes_skipped: self.bytes_skipped,
            adaptations: self.adaptations.clone(),
            ..Default::default()
        };
        for r in &self.per_session {
            total.files += r.files;
            total.bytes_sent += r.bytes_sent;
            total.bytes_resent += r.bytes_resent;
            total.failures_detected += r.failures_detected;
            total.repair_rounds += r.repair_rounds;
            total.bytes_reread += r.bytes_reread;
            total.bytes_skipped_delta += r.bytes_skipped_delta;
            total.leaves_dirty += r.leaves_dirty;
            total.leaves_clean += r.leaves_clean;
            total.delta_scans_skipped += r.delta_scans_skipped;
            total.verify_rtts += r.verify_rtts;
            total.pool_fallback_allocs = total.pool_fallback_allocs.max(r.pool_fallback_allocs);
            total.pool_peak_in_flight = total.pool_peak_in_flight.max(r.pool_peak_in_flight);
            total.pool_grow_events = total.pool_grow_events.max(r.pool_grow_events);
            // The sync counter is shared per storage: every session
            // snapshots the same value, so max (not sum) is the truth.
            total.storage_syncs = total.storage_syncs.max(r.storage_syncs);
            total.direct_fallbacks = total.direct_fallbacks.max(r.direct_fallbacks);
            total.uring_fallbacks = total.uring_fallbacks.max(r.uring_fallbacks);
            total.storage_hints = total.storage_hints.max(r.storage_hints);
            total.file_backends.extend(r.file_backends.iter().cloned());
            total.trace_dropped = total.trace_dropped.max(r.trace_dropped);
            // Observability stats merge the whole endpoint's recorder,
            // so every session's snapshot is the same merged view: take
            // the first non-empty one.
            if total.stage_stats.is_empty() && !r.stage_stats.is_empty() {
                total.stage_stats = r.stage_stats.clone();
                total.bottleneck = r.bottleneck.clone();
                total.bottleneck_confidence = r.bottleneck_confidence;
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn items(n: usize) -> Vec<WorkItem> {
        (0..n).map(|i| WorkItem { files: vec![i] }).collect()
    }

    #[test]
    fn own_deque_pops_in_order() {
        let q = WorkStealQueue::new(items(6), 2);
        // Session 0 got items 0, 2, 4 round-robin.
        assert_eq!(q.next(0).unwrap().files, vec![0]);
        assert_eq!(q.next(0).unwrap().files, vec![2]);
        assert_eq!(q.next(0).unwrap().files, vec![4]);
    }

    #[test]
    fn steals_from_back_when_empty() {
        let q = WorkStealQueue::new(items(4), 2);
        // Session 0: [0, 2]; session 1: [1, 3]. Drain 0's own work.
        q.next(0).unwrap();
        q.next(0).unwrap();
        // Now steal: back of session 1's deque is item 3.
        assert_eq!(q.next(0).unwrap().files, vec![3]);
        assert_eq!(q.next(1).unwrap().files, vec![1]);
        assert!(q.next(0).is_none());
        assert_eq!(q.remaining(), 0);
    }

    #[test]
    fn concurrent_drain_sees_every_item_once() {
        use std::collections::HashSet;
        use std::sync::Arc;
        let q = Arc::new(WorkStealQueue::new(items(200), 4));
        let mut handles = Vec::new();
        for s in 0..4 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(item) = q.next(s) {
                    got.push(item.files[0]);
                }
                got
            }));
        }
        let mut all: Vec<usize> = Vec::new();
        for h in handles {
            all.extend(h.join().unwrap());
        }
        assert_eq!(all.len(), 200, "every item claimed exactly once");
        let set: HashSet<usize> = all.into_iter().collect();
        assert_eq!(set.len(), 200);
    }

    #[test]
    fn plan_resume_drops_completed_files_and_empty_items() {
        use std::collections::HashSet;
        let eng = EngineConfig { batch_threshold: 100, batch_bytes: 150, ..Default::default() };
        // Files 0..4 all small: they batch into multi-file items.
        let sizes = [50u64, 50, 50, 50, 200];
        let full = eng.plan(&sizes);
        let all: usize = full.iter().map(|i| i.files.len()).sum();
        assert_eq!(all, 5);
        let skip: HashSet<usize> = [0, 1, 4].into_iter().collect();
        let resumed = eng.plan_resume(&sizes, &skip);
        let kept: Vec<usize> = resumed.iter().flat_map(|i| i.files.iter().copied()).collect();
        assert_eq!(kept, vec![2, 3], "only unfinished files re-enqueue");
        // Skipping everything leaves an empty plan, not empty items.
        let skip: HashSet<usize> = (0..5).collect();
        assert!(eng.plan_resume(&sizes, &skip).is_empty());
    }

    #[test]
    fn plan_delta_isolates_basis_files() {
        use std::collections::HashSet;
        let eng = EngineConfig { batch_threshold: 100, batch_bytes: 300, ..Default::default() };
        // Five small files batch together without delta.
        let sizes = [50u64, 50, 50, 50, 50];
        let none: HashSet<usize> = HashSet::new();
        assert_eq!(eng.plan_delta(&sizes, &none, &none), eng.plan_resume(&sizes, &none));
        // Files 1 and 3 have a basis: they stand alone, the rest stay
        // batched, and nothing is lost or duplicated.
        let delta: HashSet<usize> = [1, 3].into_iter().collect();
        let plan = eng.plan_delta(&sizes, &none, &delta);
        let mut solo: Vec<usize> = plan
            .iter()
            .filter(|i| i.files.len() == 1 && delta.contains(&i.files[0]))
            .map(|i| i.files[0])
            .collect();
        solo.sort_unstable();
        assert_eq!(solo, vec![1, 3]);
        let mut all: Vec<usize> = plan.iter().flat_map(|i| i.files.iter().copied()).collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
        // Completed files still drop out first.
        let skip: HashSet<usize> = [1].into_iter().collect();
        let plan = eng.plan_delta(&sizes, &skip, &delta);
        let all: Vec<usize> = plan.iter().flat_map(|i| i.files.iter().copied()).collect();
        assert!(!all.contains(&1));
    }

    #[test]
    fn snapshot_reflects_drain_state() {
        let q = WorkStealQueue::new(items(4), 2);
        q.next(0).unwrap();
        let snap = q.snapshot();
        assert_eq!(snap.len(), 2);
        let left: Vec<usize> =
            snap.iter().flatten().flat_map(|i| i.files.iter().copied()).collect();
        assert_eq!(left.len(), 3, "one item drained, three remain");
        assert_eq!(q.remaining(), 3);
    }

    #[test]
    fn engine_config_defaults() {
        let e = EngineConfig::default();
        assert_eq!(e.concurrency, 1);
        assert_eq!(e.parallel, 1);
        assert_eq!(e.pool_workers(), 2);
        assert_eq!(EngineConfig::with_concurrency(8).pool_workers(), 8);
    }

    #[test]
    fn aggregate_sums_sessions() {
        let mut rep = EngineReport { elapsed_secs: 2.0, ..Default::default() };
        for i in 0..3u64 {
            rep.per_session.push(TransferReport {
                algorithm: "FIVER".into(),
                files: 2,
                bytes_sent: 100 * (i + 1),
                failures_detected: i,
                ..Default::default()
            });
        }
        let total = rep.aggregate();
        assert_eq!(total.files, 6);
        assert_eq!(total.bytes_sent, 600);
        assert_eq!(total.failures_detected, 3);
        assert_eq!(total.elapsed_secs, 2.0);
        assert_eq!(total.algorithm, "FIVER");
    }
}
