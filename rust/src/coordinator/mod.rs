//! The real-mode coordinator — the paper's system, over real sockets,
//! threads and files, scaled out by a parallel multi-session engine.
//!
//! * [`bufpool`] — the zero-copy data plane: refcounted sliceable buffers
//!   ([`bufpool::SharedBuf`]) recycled through a fixed-size
//!   [`bufpool::BufferPool`]; steady state performs no payload
//!   allocation or copy per buffer cycle.
//! * [`queue`] — the fixed-size synchronized queue of Algorithms 1 & 2,
//!   carrying refcounted buffers (insertion is a refcount, not a copy).
//! * [`protocol`] — framed data + control channels (GridFTP-style split),
//!   plus the engine's session-id/stripe `Hello` handshake.
//! * [`scheduler`] — work items (small files batch, large files stand
//!   alone), the work-stealing queue feeding N concurrent sessions, and
//!   the engine configuration/report types.
//! * [`journal`] — the crash-recovery and incremental-sync subsystem:
//!   name-keyed checkpoint records of leaf digests (v2 adds per-leaf
//!   rolling weak sums) with crash-consistent (append-only,
//!   data-before-journal fsync) writes, an append-only segment file
//!   that compacts a million-file journal into one file per transfer,
//!   the resume handshake that lets a restarted sender/receiver pair
//!   verify the already-delivered prefix by Merkle-root comparison and
//!   re-enqueue only the unfinished tail, and the delta handshake that
//!   ships the receiver's per-leaf signatures to the sender.
//! * [`delta`] — rsync-style incremental transfer (`--delta`): a 32-bit
//!   rolling weak checksum scans the new source bytes against the
//!   receiver's basis signatures, a strong hash confirms candidate
//!   matches, and only unmatched byte ranges ship as literals; matched
//!   leaves become `DeltaCopy` ops the receiver satisfies from its own
//!   disk.
//! * [`pool`] — the shared hash worker pool: checksum compute decoupled
//!   from per-session threads (one job per queue-mode file).
//! * [`sender`] / [`receiver`] — Algorithm 1 (SEND + COMPUTECHECKSUM) and
//!   Algorithm 2 (RECEIVE + COMPUTECHECKSUM), engine-driven and
//!   generalized so the same machinery runs all five
//!   integrity-verification policies:
//!
//! | algorithm        | checksum source | verify unit | overlap             |
//! |------------------|-----------------|-------------|---------------------|
//! | Sequential       | file re-read    | file        | none                |
//! | FileLevelPpl     | file re-read    | file        | prev file           |
//! | BlockLevelPpl    | file re-read    | block       | prev block          |
//! | FIVER            | shared queue    | file        | same file           |
//! | FIVER-Chunk      | shared queue    | chunk       | same file           |
//! | FIVER-Hybrid     | per-file: FIVER if it fits in memory, else Sequential |
//!
//! Verification failures recover in place: the sender re-reads the failed
//! unit from source storage and sends `Fix` frames; the receiver rewrites
//! the range, recomputes the digest from storage, and re-exchanges until
//! digests match (§IV-A's efficient error recovery).

/// Pooled refcounted I/O buffers — the zero-copy data plane.
pub mod bufpool;
/// Adaptive concurrency controller: obs-plane feedback onto the hash
/// pool and per-file stripe count (`--adaptive`).
pub mod control;
/// Rolling-checksum delta sync (rsync-style) over Merkle leaves.
pub mod delta;
/// Leaf-digest journal plus the resume and delta handshakes.
pub mod journal;
/// Shared hash worker pool.
pub mod pool;
/// Length-prefixed wire frames and their encoding.
pub mod protocol;
/// Bounded byte queue between the reader and sender stages.
pub mod queue;
/// Receiver side: frame routing, verification, repair.
pub mod receiver;
/// Multi-session engine: file scheduling and report aggregation.
pub mod scheduler;
/// Session orchestration over loopback or TCP.
pub mod session;
/// Sender side: streaming, delta scan, repair rounds.
pub mod sender;

use std::sync::Arc;

pub use crate::hashes::HashTier;

/// Real-mode algorithm selector (mirrors [`crate::sim::algorithms::Algorithm`]
/// plus a transfer-only baseline for Eq. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RealAlgorithm {
    /// Transfer with no verification at all (the Eq. 1 baseline).
    TransferOnly,
    /// Hash each file only after its transfer completes.
    Sequential,
    /// Pipeline whole-file hashing with the transfer.
    FileLevelPpl,
    /// Pipeline fixed-size block hashing with the transfer.
    BlockLevelPpl,
    /// FIVER: file-level verification pipelined at I/O granularity.
    Fiver,
    /// FIVER verifying fixed-size chunks instead of whole files.
    FiverChunk,
    /// FIVER choosing file- or chunk-level verification by file size.
    FiverHybrid,
    /// FIVER with a streaming Merkle digest tree (see [`crate::merkle`]):
    /// corruption is localized by binary-searching the tree and only the
    /// corrupted leaf ranges are re-read and re-sent.
    FiverMerkle,
}

impl RealAlgorithm {
    /// Every real-mode algorithm, in presentation order — the single
    /// source of truth for tests, benches and CLI help.
    pub const ALL: [RealAlgorithm; 8] = [
        RealAlgorithm::TransferOnly,
        RealAlgorithm::Sequential,
        RealAlgorithm::FileLevelPpl,
        RealAlgorithm::BlockLevelPpl,
        RealAlgorithm::Fiver,
        RealAlgorithm::FiverChunk,
        RealAlgorithm::FiverHybrid,
        RealAlgorithm::FiverMerkle,
    ];

    /// Canonical display/CLI name of this algorithm.
    pub fn name(&self) -> &'static str {
        match self {
            RealAlgorithm::TransferOnly => "TransferOnly",
            RealAlgorithm::Sequential => "Sequential",
            RealAlgorithm::FileLevelPpl => "FileLevelPpl",
            RealAlgorithm::BlockLevelPpl => "BlockLevelPpl",
            RealAlgorithm::Fiver => "FIVER",
            RealAlgorithm::FiverChunk => "FIVER-Chunk",
            RealAlgorithm::FiverHybrid => "FIVER-Hybrid",
            RealAlgorithm::FiverMerkle => "FIVER-Merkle",
        }
    }

    /// Parse a CLI algorithm name (aliases accepted).
    pub fn parse(s: &str) -> Option<RealAlgorithm> {
        match s.to_ascii_lowercase().as_str() {
            "transferonly" | "transfer-only" | "none" => Some(RealAlgorithm::TransferOnly),
            "sequential" | "seq" => Some(RealAlgorithm::Sequential),
            "filelevelppl" | "file" => Some(RealAlgorithm::FileLevelPpl),
            "blocklevelppl" | "block" => Some(RealAlgorithm::BlockLevelPpl),
            "fiver" => Some(RealAlgorithm::Fiver),
            "fiver-chunk" | "fiverchunk" | "chunk" => Some(RealAlgorithm::FiverChunk),
            "fiver-hybrid" | "fiverhybrid" | "hybrid" => Some(RealAlgorithm::FiverHybrid),
            "fiver-merkle" | "fivermerkle" | "merkle" | "tree" => Some(RealAlgorithm::FiverMerkle),
            _ => None,
        }
    }

    /// Does this algorithm feed the checksum from the shared queue
    /// (FIVER's I/O sharing) rather than re-reading the file?
    pub fn uses_queue(&self, file_size: u64, hybrid_threshold: u64) -> bool {
        match self {
            RealAlgorithm::Fiver | RealAlgorithm::FiverChunk | RealAlgorithm::FiverMerkle => true,
            RealAlgorithm::FiverHybrid => file_size < hybrid_threshold,
            _ => false,
        }
    }

    /// Verification unit size (None = whole file).
    pub fn unit_size(&self, block_size: u64) -> Option<u64> {
        match self {
            RealAlgorithm::BlockLevelPpl | RealAlgorithm::FiverChunk => Some(block_size),
            _ => None,
        }
    }
}

/// Factory producing fresh streaming hashers (native MD5/SHA/FVR or the
/// XLA-backed [`crate::runtime::FvrHasher`]); shared across threads.
pub type HasherFactory = crate::hashes::DigestFactory;

/// Make a factory from a named algorithm.
pub fn native_factory(alg: crate::hashes::HashAlgorithm) -> HasherFactory {
    Arc::new(move || alg.hasher())
}

/// Make a factory backed by the compiled XLA artifact.
pub fn xla_factory(engine: crate::runtime::XlaHashEngine) -> HasherFactory {
    Arc::new(move || Box::new(crate::runtime::FvrHasher::new(engine.clone())))
}

/// Session configuration shared by sender and receiver.
#[derive(Clone)]
pub struct SessionConfig {
    /// Verification policy this session runs.
    pub algorithm: RealAlgorithm,
    /// I/O buffer granularity for reads/sends (paper's `buffer`).
    pub buf_size: usize,
    /// Block/chunk size for block-level pipelining and FIVER-Chunk.
    pub block_size: u64,
    /// Queue capacity in bytes (Algorithm 1/2's fixed-size queue).
    pub queue_capacity: usize,
    /// FIVER-Hybrid threshold: files >= this use the Sequential path.
    pub hybrid_threshold: u64,
    /// Merkle leaf span for FIVER-Merkle (repair granularity; digest
    /// exchange on a mismatch is O(log(size/leaf_size))).
    pub leaf_size: u64,
    /// Data-plane buffer pool size in buffers of `buf_size` bytes
    /// (0 = auto: sized so a full queue plus in-flight slack per session
    /// never exhausts it — see [`SessionConfig::pool_buffers_for`]).
    pub pool_buffers: usize,
    /// Adaptive-growth ceiling for the buffer pool (0 = auto: twice the
    /// effective `pool_buffers`). Sustained exhaustion grows the pool up
    /// to this cap instead of permanently degrading to
    /// allocate-per-buffer; grow events surface in pool telemetry.
    pub pool_max_buffers: usize,
    /// Storage I/O engine this endpoint's pools and reports assume (the
    /// `--io-backend` selection; [`crate::storage::FsStorage`] is
    /// constructed to match). Decides pool buffer alignment — the direct
    /// engine needs block-aligned buffers to avoid bounce copies.
    pub io_backend: crate::storage::IoBackend,
    /// `--io-backend auto` size threshold (`--direct-threshold`): files
    /// at or above this open on the uring/direct engines, smaller files
    /// stay buffered (the page cache wins for small files; batched or
    /// uncached I/O wins once a file dwarfs memory).
    pub direct_threshold: u64,
    /// Checkpoint-journal directory for this endpoint (`None` disables
    /// journaling). Each endpoint needs its own directory; see
    /// [`journal`].
    pub journal_dir: Option<std::path::PathBuf>,
    /// Run the resume handshake at engine start (both endpoints must set
    /// it; requires the engine path, i.e. `serve_engine` /
    /// `connect_and_send_engine`).
    pub resume: bool,
    /// Run the delta handshake at engine start and transfer mutated
    /// files incrementally (`--delta`): the receiver offers per-leaf
    /// `(weak, strong)` signatures of its existing data — served for
    /// free from a complete v2 journal record when one matches — and
    /// the sender ships only byte ranges the rolling-checksum scan
    /// can't match against that basis. Requires the engine path; most
    /// useful with `journal_dir` set on the receiver.
    pub delta: bool,
    /// Journal durability cadence: sync data + journal every this many
    /// completed leaves (and always at file end). Smaller = fresher
    /// checkpoints after a crash, more fsyncs on the stream path.
    pub journal_checkpoint_leaves: u64,
    /// The endpoint's observability recorder ([`crate::obs`]): enabled
    /// by `FIVER_TRACE=1` (or explicitly by the `--trace-out` /
    /// `--metrics-json` / `--progress` flags), disabled otherwise at
    /// near-zero recording cost. Sessions, hash jobs and the receiver
    /// draw per-worker [`crate::obs::Shard`]s from it; reports merge
    /// them into per-stage percentiles and a bottleneck label.
    pub obs: crate::obs::Recorder,
    /// Adaptive concurrency controller knobs (`--adaptive`,
    /// `--control-interval`, `--max-parallel`, `--max-hash-workers`).
    /// Off by default; see [`control`].
    pub control: control::ControlConfig,
    /// Factory producing the session's streaming hashers — the
    /// *cryptographic* family (`--hash`). How it is actually applied
    /// depends on `hash_tier`; data-plane code must draw hashers through
    /// [`SessionConfig::leaf_factory`] / [`SessionConfig::node_factory`]
    /// rather than using this field directly.
    pub hasher: HasherFactory,
    /// Tier composition (`--hash-tier`, env `FIVER_HASH_TIER`): which
    /// digests come from the fast XXH3 family and which from `hasher`.
    /// Both endpoints of a session must agree (like `leaf_size`); the
    /// journal declines records whose leaf width doesn't match, so a
    /// tier switch between runs costs a clean re-verify, never an error.
    pub hash_tier: HashTier,
}

impl SessionConfig {
    /// A config with the given policy and hasher; everything else defaulted.
    pub fn new(algorithm: RealAlgorithm, hasher: HasherFactory) -> SessionConfig {
        SessionConfig {
            algorithm,
            buf_size: 256 * 1024,
            block_size: 4 << 20,
            queue_capacity: 8 << 20,
            hybrid_threshold: 64 << 20,
            leaf_size: 64 << 10,
            pool_buffers: 0,
            pool_max_buffers: 0,
            io_backend: crate::storage::IoBackend::from_env(),
            direct_threshold: crate::storage::fs::DEFAULT_DIRECT_THRESHOLD,
            journal_dir: None,
            resume: false,
            delta: false,
            journal_checkpoint_leaves: 8,
            obs: crate::obs::Recorder::from_env(),
            control: control::ControlConfig::from_env(),
            hasher,
            hash_tier: HashTier::from_env(),
        }
    }

    /// Factory for *leaf-tier* digests: leaf/unit/transport checksums,
    /// journal leaf records and delta strong-confirms. The fast XXH3-128
    /// under `fast`/`tiered`, the cryptographic `hasher` otherwise. Leaf
    /// hashing is O(file bytes) — this is where the tier saves its time.
    pub fn leaf_factory(&self) -> HasherFactory {
        match self.hash_tier {
            HashTier::Cryptographic => self.hasher.clone(),
            HashTier::Fast | HashTier::Tiered => {
                native_factory(crate::hashes::HashAlgorithm::Xxh3128)
            }
        }
    }

    /// Factory for *node-tier* digests: Merkle interior nodes and roots
    /// (including the resume handshake's prefix roots). Cryptographic
    /// under `cryptographic`/`tiered` — interior hashing is O(leaves x
    /// digest width), so the trust anchor costs next to nothing — and
    /// XXH3-128 under `fast`, where the caller has explicitly traded the
    /// anchor away.
    pub fn node_factory(&self) -> HasherFactory {
        match self.hash_tier {
            HashTier::Fast => native_factory(crate::hashes::HashAlgorithm::Xxh3128),
            HashTier::Cryptographic | HashTier::Tiered => self.hasher.clone(),
        }
    }

    /// Leaf-tier digest width in bytes (the journal's record width and the
    /// wire width of leaf/unit digests).
    pub fn leaf_len(&self) -> usize {
        self.leaf_factory()().digest_len()
    }

    /// Whether Merkle trees must fold even a single leaf into a node-tier
    /// root: true exactly when the two tiers differ, so small files keep
    /// the cryptographic anchor.
    pub fn tree_rooted(&self) -> bool {
        self.hash_tier == HashTier::Tiered
    }

    /// Effective buffer pool size for an endpoint running `sessions`
    /// concurrent sessions. The auto default gives every session enough
    /// buffers to fill its checksum queue (`queue_capacity / buf_size`)
    /// plus slack for buffers in flight between socket, reorder stash and
    /// spill, so the steady state never touches
    /// [`bufpool::BufferPool::get_or_alloc`]'s fallback.
    pub fn pool_buffers_for(&self, sessions: usize) -> usize {
        if self.pool_buffers > 0 {
            return self.pool_buffers;
        }
        let per_session = (self.queue_capacity / self.buf_size.max(1)).max(1) + 8;
        sessions.max(1) * per_session + 8
    }

    /// Build the endpoint's data-plane buffer pool: capacity from
    /// [`SessionConfig::pool_buffers_for`], backing alignment from the
    /// I/O backend (O_DIRECT needs block-aligned buffers), and an
    /// adaptive-growth ceiling so sustained exhaustion grows the pool
    /// instead of degrading to allocate-per-buffer.
    pub fn make_pool(&self, sessions: usize) -> bufpool::BufferPool {
        let cap = self.pool_buffers_for(sessions);
        let max = if self.pool_max_buffers > 0 { self.pool_max_buffers.max(cap) } else { cap * 2 };
        let pool = bufpool::BufferPool::with_options(
            self.buf_size,
            cap,
            self.io_backend.buffer_align(),
            max,
        );
        if self.obs.is_enabled() {
            let p = pool.clone();
            self.obs.register_pool_gauge(move || (p.in_flight(), p.capacity()));
        }
        pool
    }

    /// Open this endpoint's checkpoint journal, if one is configured.
    pub fn open_journal(&self) -> anyhow::Result<Option<journal::Journal>> {
        self.journal_dir.as_deref().map(journal::Journal::open).transpose()
    }

    /// Verification units of a file as `(unit_id, offset, len)`.
    /// `unit_id == UNIT_FILE` means a single whole-file unit.
    pub fn units_of(&self, file_size: u64, uses_queue: bool) -> Vec<(u64, u64, u64)> {
        let unit_size = match self.algorithm {
            RealAlgorithm::FiverHybrid if !uses_queue => None, // sequential path
            _ => self.algorithm.unit_size(self.block_size),
        };
        match unit_size {
            None => vec![(protocol::UNIT_FILE, 0, file_size)],
            Some(us) => {
                let mut units = Vec::new();
                let mut off = 0;
                let mut idx = 0u64;
                loop {
                    let len = us.min(file_size - off);
                    units.push((idx, off, len));
                    off += len;
                    idx += 1;
                    if off >= file_size {
                        break;
                    }
                }
                units
            }
        }
    }
}

/// Outcome of a sender-side session.
#[derive(Debug, Default, Clone)]
pub struct TransferReport {
    /// Algorithm name, as reported by [`RealAlgorithm::name`].
    pub algorithm: String,
    /// Files whose delivery this session completed.
    pub files: usize,
    /// Payload bytes that crossed the wire.
    pub bytes_sent: u64,
    /// Extra bytes sent for verification repairs.
    pub bytes_resent: u64,
    /// Verification failures detected (file, chunk or leaf level).
    pub failures_detected: u64,
    /// Repair rounds executed (FixEnd batches sent).
    pub repair_rounds: u64,
    /// Bytes re-read from source storage for repairs.
    pub bytes_reread: u64,
    /// Control-channel round trips spent on verification (digest/root
    /// exchanges plus tree node-range query rounds).
    pub verify_rtts: u64,
    /// Files skipped outright at the resume handshake (fully delivered
    /// and root-verified before the restart).
    pub files_skipped: u64,
    /// Bytes not re-sent thanks to the checkpoint journal (sum of agreed
    /// resume offsets, including fully-skipped files).
    pub bytes_skipped: u64,
    /// Bytes not sent because the delta scan matched them against the
    /// receiver's existing data (sum of `DeltaCopy` lengths).
    pub bytes_skipped_delta: u64,
    /// Delta mode: leaves whose bytes had to ship as literals (changed
    /// or unmatched data).
    pub leaves_dirty: u64,
    /// Delta mode: leaves satisfied from the receiver's basis without
    /// sending data.
    pub leaves_clean: u64,
    /// Delta mode: files whose rolling scan was skipped entirely because
    /// the sender's own journaled signatures for the file still describe
    /// the source *and* match the receiver's offered basis (the
    /// sender-side signature cache; the Merkle verify pass backstops a
    /// stale journal).
    pub delta_scans_skipped: u64,
    /// Tier composition this session ran under
    /// ([`crate::hashes::HashTier::name`]).
    pub hash_tier: String,
    /// Data-plane pool telemetry: grace-expired unpooled allocations
    /// (nonzero = the pool was exhausted; consider a larger
    /// `--pool-buffers`).
    pub pool_fallback_allocs: u64,
    /// Data-plane pool telemetry: peak pooled buffers in flight (how
    /// close the run came to the pool's capacity).
    pub pool_peak_in_flight: u64,
    /// Data-plane pool telemetry: adaptive capacity raises (sustained
    /// exhaustion grew the pool instead of falling back per buffer).
    pub pool_grow_events: u64,
    /// Active storage I/O engine of this endpoint's storage (buffered /
    /// mmap / direct / mem), so experiments can attribute overhead to
    /// storage vs hash vs network.
    pub io_backend: String,
    /// Times this endpoint's storage forced durability (`sync`) — the
    /// journal's checkpoint cadence dominates this in journaled runs.
    pub storage_syncs: u64,
    /// O_DIRECT per-op fallbacks to buffered I/O on this endpoint's
    /// storage (nonzero = alignment or filesystem support forced the
    /// direct engine off its fast path).
    pub direct_fallbacks: u64,
    /// io_uring fallbacks to buffered I/O on this endpoint's storage
    /// (ring setup refused — kernels/sandboxes without io_uring — or a
    /// ring died mid-transfer; delivery is bit-identical either way).
    pub uring_fallbacks: u64,
    /// `posix_fadvise` streaming hints issued by this endpoint's storage
    /// (SEQUENTIAL at stream open, coalesced DONTNEED after verified
    /// spans).
    pub storage_hints: u64,
    /// With `--io-backend auto`: the engine each file resolved to, as
    /// `(file name, engine name)` in completion order. Empty for fixed
    /// engines.
    pub file_backends: Vec<(String, String)>,
    /// Merged per-stage span statistics from the observability plane
    /// (p50/p95/p99 latencies + busy time; empty when tracing is
    /// disabled).
    pub stage_stats: Vec<crate::obs::StageStats>,
    /// Bottleneck label from per-stage busy-time decomposition
    /// (`hash-bound` / `read-bound` / `write-bound` / `net-bound`;
    /// empty when tracing is disabled).
    pub bottleneck: String,
    /// Busiest stage group over the runner-up (>= 1;
    /// [`f64::INFINITY`] when no other group recorded anything —
    /// rendered as `sole` on the CLI and `null` in JSON).
    pub bottleneck_confidence: f64,
    /// Span events dropped by contended ring pushes (recording never
    /// blocks; nonzero here means the trace has gaps, not the run).
    pub trace_dropped: u64,
    /// Adaptive-controller decision trail (`--adaptive`): every
    /// grow/shrink/restore of the hash pool or stripe count, in order.
    /// Empty when the controller is off.
    pub adaptations: Vec<control::ControlEvent>,
    /// Wall-clock duration of the run in seconds.
    pub elapsed_secs: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashes::HashAlgorithm;

    #[test]
    fn parse_roundtrip() {
        for alg in RealAlgorithm::ALL {
            assert_eq!(RealAlgorithm::parse(alg.name()), Some(alg));
        }
    }

    #[test]
    fn units_whole_file() {
        let cfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Md5));
        assert_eq!(cfg.units_of(100, true), vec![(protocol::UNIT_FILE, 0, 100)]);
    }

    #[test]
    fn units_chunked() {
        let mut cfg =
            SessionConfig::new(RealAlgorithm::FiverChunk, native_factory(HashAlgorithm::Md5));
        cfg.block_size = 40;
        assert_eq!(cfg.units_of(100, true), vec![(0, 0, 40), (1, 40, 40), (2, 80, 20)]);
        // Exact multiple.
        assert_eq!(cfg.units_of(80, true), vec![(0, 0, 40), (1, 40, 40)]);
        // Empty file still has one (empty) unit.
        assert_eq!(cfg.units_of(0, true), vec![(0, 0, 0)]);
    }

    #[test]
    fn hybrid_unit_selection() {
        let cfg =
            SessionConfig::new(RealAlgorithm::FiverHybrid, native_factory(HashAlgorithm::Md5));
        // Small file -> FIVER path (queue, whole-file digest).
        assert!(cfg.algorithm.uses_queue(1 << 20, cfg.hybrid_threshold));
        // Large file -> sequential path.
        assert!(!cfg.algorithm.uses_queue(1 << 30, cfg.hybrid_threshold));
    }

    #[test]
    fn queue_usage_by_algorithm() {
        assert!(RealAlgorithm::Fiver.uses_queue(1, 0));
        assert!(RealAlgorithm::FiverMerkle.uses_queue(1, 0));
        assert!(!RealAlgorithm::Sequential.uses_queue(1, u64::MAX));
        assert!(!RealAlgorithm::BlockLevelPpl.uses_queue(1, u64::MAX));
    }

    #[test]
    fn pool_sizing_covers_queue_plus_slack() {
        let mut cfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Md5));
        // Default: queue (8 MiB / 256 KiB = 32) + 8 slack per session + 8.
        assert_eq!(cfg.pool_buffers_for(1), 48);
        assert_eq!(cfg.pool_buffers_for(4), 4 * 40 + 8);
        // Explicit size wins regardless of session count.
        cfg.pool_buffers = 7;
        assert_eq!(cfg.pool_buffers_for(8), 7);
        cfg.pool_buffers = 0;
        let pool = cfg.make_pool(2);
        assert_eq!(pool.buf_size(), cfg.buf_size);
        assert_eq!(pool.capacity(), cfg.pool_buffers_for(2));
    }

    #[test]
    fn tier_factories_compose() {
        let mut cfg =
            SessionConfig::new(RealAlgorithm::FiverMerkle, native_factory(HashAlgorithm::Sha1));
        cfg.hash_tier = HashTier::Cryptographic;
        assert_eq!(cfg.leaf_len(), 20);
        assert_eq!(cfg.node_factory()().digest_len(), 20);
        assert!(!cfg.tree_rooted());
        cfg.hash_tier = HashTier::Tiered;
        assert_eq!(cfg.leaf_len(), 16, "fast xxh3-128 leaves");
        assert_eq!(cfg.node_factory()().digest_len(), 20, "crypto root");
        assert!(cfg.tree_rooted());
        cfg.hash_tier = HashTier::Fast;
        assert_eq!(cfg.leaf_len(), 16);
        assert_eq!(cfg.node_factory()().digest_len(), 16);
        assert!(!cfg.tree_rooted());
    }

    #[test]
    fn merkle_is_a_whole_file_unit() {
        // The tree refines verification *below* the unit level; the
        // digest/verdict rendezvous still runs per file.
        let cfg =
            SessionConfig::new(RealAlgorithm::FiverMerkle, native_factory(HashAlgorithm::Md5));
        assert_eq!(cfg.units_of(1 << 20, true), vec![(protocol::UNIT_FILE, 0, 1 << 20)]);
        assert_eq!(cfg.leaf_size, 64 << 10);
    }
}
