//! The zero-copy data plane: a refcounted, sliceable byte buffer
//! ([`SharedBuf`]) and a recycling pool ([`BufferPool`]).
//!
//! FIVER's whole advantage is that transfer and checksum share one file
//! read — but an implementation that allocates a fresh `Vec<u8>` per I/O
//! buffer and copies it at frame encode, frame decode, queue insertion and
//! spill gives that advantage straight back to the allocator and `memcpy`.
//! This module is the ownership substrate that removes those costs:
//!
//! * The sender fills **one** pooled buffer per read; the same bytes go to
//!   the socket (borrowed, scatter/gather — see
//!   [`super::protocol::write_data_frame_vectored`]) and to the hash queue
//!   (a refcount, not a copy).
//! * The receiver decodes frame payloads **directly into** pooled buffers
//!   ([`super::protocol::Frame::read_from_pooled`]); the same buffer feeds
//!   the storage write (borrowed) and the hash queue (refcount).
//! * When the last reference drops, the backing storage returns to the
//!   pool — steady state after warmup performs no *payload* allocation or
//!   copy per buffer cycle (the residue is one constant-size refcount
//!   block per [`PoolBuf::freeze`], ~100 B vs the 256 KiB zeroed `Vec`
//!   the owned plane paid; `rust/tests/alloc_regression.rs` gates the
//!   byte cost).
//!
//! The pool serves the pluggable storage backends too
//! (`crate::storage`): backings can be allocated at a configured
//! **alignment** (O_DIRECT requires block-aligned buffers — see
//! [`BufferPool::with_options`]), and a [`SharedBuf`] can wrap an
//! **external** backing ([`SharedBuf::from_external`]) such as a live
//! mmap region, so a memory-mapped file serves socket + hash queue with
//! zero read copies and zero pool traffic.
//!
//! Backpressure and liveness: [`BufferPool::get`] blocks once `capacity`
//! buffers are outstanding, which bounds data-plane memory exactly like
//! the paper's fixed-size queue bounds decoupling. Blocking on a shared
//! pool can, however, interleave badly with the hash pool's FIFO progress
//! argument (a starved session can hold buffers hostage in the queue of a
//! not-yet-scheduled hash job — see DESIGN.md "Data plane & buffer
//! ownership"). Hot paths therefore use [`BufferPool::get_or_alloc`]: wait
//! for the backpressure grace period, then fall back to a one-off unpooled
//! allocation and count it in [`BufferPool::fallback_allocs`]. A
//! well-sized pool (the [`super::SessionConfig::pool_buffers_for`]
//! default) never takes the fallback; the counter makes mis-sizing
//! observable instead of deadlocking the transfer. And instead of
//! *permanently* degrading to allocate-per-buffer, a persistently
//! exhausted pool **grows**: every [`GROW_FALLBACK_THRESHOLD`]
//! grace-expired misses raise `capacity` by half (up to the configured
//! `max_capacity`), counted in [`BufferPool::grow_events`] so telemetry
//! shows the adaptation instead of hiding it.

use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Default backpressure grace before a starved acquisition falls back to
/// a one-off allocation instead of risking a sizing-dependent deadlock
/// against the hash pool's FIFO argument (DESIGN.md "Data plane & buffer
/// ownership"). Hot paths pass this to [`BufferPool::get_or_alloc`].
pub const POOL_GRACE: Duration = Duration::from_millis(100);

/// Grace-expired misses before an undersized pool grows its capacity
/// (adaptive sizing): the first few misses fall back to one-off
/// allocations — a transient burst shouldn't commit memory permanently —
/// but a *sustained* shortfall raises `capacity` by half, up to the
/// configured cap.
pub const GROW_FALLBACK_THRESHOLD: u64 = 4;

/// An owned, heap-allocated, fixed-size byte buffer with an explicit
/// alignment — the pool's backing storage. `align == 1` is a plain
/// allocation; the O_DIRECT storage backend asks for block alignment
/// (`crate::storage::DIRECT_ALIGN`) so pooled buffers are valid direct-I/O
/// targets without a bounce copy.
pub(crate) struct AlignedBytes {
    ptr: NonNull<u8>,
    len: usize,
    align: usize,
}

// SAFETY: AlignedBytes uniquely owns its allocation; &/&mut access follows
// Rust's usual borrow rules via Deref/DerefMut.
unsafe impl Send for AlignedBytes {}
unsafe impl Sync for AlignedBytes {}

impl AlignedBytes {
    fn zeroed(len: usize, align: usize) -> AlignedBytes {
        assert!(len > 0, "buffer length must be positive");
        assert!(align.is_power_of_two(), "alignment must be a power of two");
        let layout = std::alloc::Layout::from_size_align(len, align).expect("buffer layout");
        // SAFETY: layout has non-zero size (asserted above).
        let raw = unsafe { std::alloc::alloc_zeroed(layout) };
        let Some(ptr) = NonNull::new(raw) else { std::alloc::handle_alloc_error(layout) };
        AlignedBytes { ptr, len, align }
    }
}

impl Deref for AlignedBytes {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        // SAFETY: ptr/len describe our live, uniquely owned allocation.
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl DerefMut for AlignedBytes {
    fn deref_mut(&mut self) -> &mut [u8] {
        // SAFETY: as above, and &mut self guarantees exclusivity.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl Drop for AlignedBytes {
    fn drop(&mut self) {
        // SAFETY: same layout as the allocation (len > 0, align power of 2).
        unsafe {
            let layout = std::alloc::Layout::from_size_align_unchecked(self.len, self.align);
            std::alloc::dealloc(self.ptr.as_ptr(), layout);
        }
    }
}

/// Bytes owned by something other than the pool or the heap — e.g. a live
/// mmap region held by the mmap storage backend. A [`SharedBuf`] view over
/// an external backing keeps it alive (refcounted) and copies nothing.
pub trait ExternalBytes: Send + Sync {
    /// The readable bytes of the external backing.
    fn as_bytes(&self) -> &[u8];
}

/// Pool bookkeeping behind the mutex.
struct PoolState {
    /// Recycled backings ready for reuse.
    free: Vec<AlignedBytes>,
    /// Current capacity: starts at the configured size and grows (up to
    /// `PoolCore::max_capacity`) when sustained exhaustion shows the
    /// workload needs more — see [`GROW_FALLBACK_THRESHOLD`].
    capacity: usize,
    /// Pooled backings currently alive (free + lent out). Lazily grown up
    /// to `capacity`, so an idle pool costs nothing.
    allocated: usize,
    /// Pooled backings lent out right now.
    in_use: usize,
    /// High-water mark of `in_use` — how close the run came to exhausting
    /// the pool (surfaces in `TransferReport` so `--pool-buffers` can be
    /// tuned from telemetry instead of guesswork).
    peak_in_use: usize,
    /// One-off unpooled allocations taken by [`BufferPool::get_or_alloc`]
    /// after the grace period — zero in a well-sized steady state.
    fallback_allocs: u64,
    /// Capacity raises taken by the adaptive sizer.
    grow_events: u64,
    /// Grace-expired misses since the last grow (or since creation) —
    /// the adaptive sizer's trigger counter.
    misses_since_grow: u64,
    /// Set when a `get_or_alloc` grace period expired without a return
    /// and cleared on the next return: while starved, further
    /// `get_or_alloc` calls fall back immediately instead of repaying the
    /// full grace wait per buffer (a persistently exhausted pool must
    /// degrade to allocate-per-buffer speed, not to one buffer per grace
    /// period).
    starved: bool,
    /// `(address, length)` of every pooled backing ever allocated — the
    /// registration table the io_uring storage engine hands to
    /// `IORING_REGISTER_BUFFERS`. Backings live until the process exits
    /// (the free list never shrinks), so recorded entries never dangle;
    /// grace-period fallback buffers are unpooled and deliberately absent.
    backings: Vec<(usize, usize)>,
}

struct PoolCore {
    buf_size: usize,
    align: usize,
    /// Adaptive-growth ceiling (>= the initial capacity; equal disables
    /// growth).
    max_capacity: usize,
    state: Mutex<PoolState>,
    available: Condvar,
}

impl PoolCore {
    /// Return a backing to the free list (called from the last-ref drop).
    fn put_back(&self, data: AlignedBytes) {
        let mut g = self.state.lock().unwrap();
        g.free.push(data);
        g.in_use = g.in_use.saturating_sub(1);
        g.starved = false; // buffers are flowing again
        drop(g);
        self.available.notify_one();
    }
}

/// Update the lent-out accounting for one pooled acquisition.
fn note_acquired(g: &mut PoolState) {
    g.in_use += 1;
    g.peak_in_use = g.peak_in_use.max(g.in_use);
}

/// A bounded pool of `buf_size`-byte buffers. Cloning shares the
/// pool (cheap `Arc` clone); buffers return on the last drop of any
/// [`PoolBuf`]/[`SharedBuf`] referencing them, even if every `BufferPool`
/// handle is gone by then.
#[derive(Clone)]
pub struct BufferPool {
    core: Arc<PoolCore>,
}

impl BufferPool {
    /// A pool of up to `capacity` buffers of `buf_size` bytes each.
    /// Backings are allocated lazily on first use and recycled forever
    /// after. No alignment requirement, no adaptive growth.
    pub fn new(buf_size: usize, capacity: usize) -> BufferPool {
        BufferPool::with_options(buf_size, capacity, 1, capacity)
    }

    /// The fully-specified constructor: `align` is the backing alignment
    /// (1 = none; the direct storage backend needs
    /// `crate::storage::DIRECT_ALIGN`), `max_capacity` the adaptive-growth
    /// ceiling (clamped to >= `capacity`; equal disables growth).
    pub fn with_options(
        buf_size: usize,
        capacity: usize,
        align: usize,
        max_capacity: usize,
    ) -> BufferPool {
        assert!(buf_size > 0, "buffer size must be positive");
        assert!(align.is_power_of_two(), "alignment must be a power of two");
        let capacity = capacity.max(1);
        BufferPool {
            core: Arc::new(PoolCore {
                buf_size,
                align,
                max_capacity: max_capacity.max(capacity),
                state: Mutex::new(PoolState {
                    free: Vec::with_capacity(capacity),
                    capacity,
                    allocated: 0,
                    in_use: 0,
                    peak_in_use: 0,
                    fallback_allocs: 0,
                    grow_events: 0,
                    misses_since_grow: 0,
                    starved: false,
                    backings: Vec::new(),
                }),
                available: Condvar::new(),
            }),
        }
    }

    /// Size in bytes of each pooled buffer.
    pub fn buf_size(&self) -> usize {
        self.core.buf_size
    }

    /// Backing alignment (1 = unaligned).
    pub fn align(&self) -> usize {
        self.core.align
    }

    /// Current capacity (grows adaptively up to [`BufferPool::max_capacity`]).
    pub fn capacity(&self) -> usize {
        self.core.state.lock().unwrap().capacity
    }

    /// Adaptive-growth ceiling.
    pub fn max_capacity(&self) -> usize {
        self.core.max_capacity
    }

    /// Pooled backings currently alive (free + lent out).
    pub fn allocated(&self) -> usize {
        self.core.state.lock().unwrap().allocated
    }

    /// Buffers on the free list right now.
    pub fn free_buffers(&self) -> usize {
        self.core.state.lock().unwrap().free.len()
    }

    /// Unpooled allocations taken by [`BufferPool::get_or_alloc`] because
    /// the pool stayed exhausted past the grace period.
    pub fn fallback_allocs(&self) -> u64 {
        self.core.state.lock().unwrap().fallback_allocs
    }

    /// Capacity raises taken by the adaptive sizer (sustained exhaustion
    /// grew the pool instead of degrading to allocate-per-buffer).
    pub fn grow_events(&self) -> u64 {
        self.core.state.lock().unwrap().grow_events
    }

    /// Pooled buffers lent out right now.
    pub fn in_flight(&self) -> usize {
        self.core.state.lock().unwrap().in_use
    }

    /// High-water mark of lent-out pooled buffers over the pool's life —
    /// `peak == capacity` plus nonzero fallbacks means the pool is sized
    /// at (or below) what the workload actually needs.
    pub fn peak_in_flight(&self) -> usize {
        self.core.state.lock().unwrap().peak_in_use
    }

    /// Blocking acquire: recycle a free backing, lazily allocate while
    /// under capacity, else wait for a return (the capacity backpressure).
    pub fn get(&self) -> PoolBuf {
        let mut g = self.core.state.lock().unwrap();
        loop {
            if let Some(data) = g.free.pop() {
                note_acquired(&mut g);
                return self.wrap(data);
            }
            if g.allocated < g.capacity {
                g.allocated += 1;
                note_acquired(&mut g);
                let data = self.alloc_recorded(&mut g);
                drop(g);
                return self.wrap(data);
            }
            g = self.core.available.wait(g).unwrap();
        }
    }

    /// Non-blocking acquire.
    pub fn try_get(&self) -> Option<PoolBuf> {
        let mut g = self.core.state.lock().unwrap();
        if let Some(data) = g.free.pop() {
            note_acquired(&mut g);
            return Some(self.wrap(data));
        }
        if g.allocated < g.capacity {
            g.allocated += 1;
            note_acquired(&mut g);
            let data = self.alloc_recorded(&mut g);
            drop(g);
            return Some(self.wrap(data));
        }
        None
    }

    /// Acquire with bounded backpressure: wait up to `grace` for a pooled
    /// buffer, then fall back to a one-off unpooled allocation (counted in
    /// [`BufferPool::fallback_allocs`]) so data-plane liveness never
    /// depends on pool sizing. See the module docs for why a hard block
    /// here could defeat the hash pool's FIFO progress argument.
    ///
    /// The grace wait is paid only at the *edge* of exhaustion: once it
    /// expires, the pool is marked starved and further calls fall back
    /// immediately (degrading to allocate-per-buffer speed, not one
    /// buffer per grace period) until a return clears the mark. Sustained
    /// exhaustion instead *grows* the pool: every
    /// [`GROW_FALLBACK_THRESHOLD`] grace-expired misses raise capacity by
    /// half, up to `max_capacity`.
    pub fn get_or_alloc(&self, grace: Duration) -> PoolBuf {
        let mut g = self.core.state.lock().unwrap();
        let deadline = std::time::Instant::now() + grace;
        loop {
            if let Some(data) = g.free.pop() {
                note_acquired(&mut g);
                return self.wrap(data);
            }
            if g.allocated < g.capacity {
                g.allocated += 1;
                note_acquired(&mut g);
                let data = self.alloc_recorded(&mut g);
                drop(g);
                return self.wrap(data);
            }
            let now = std::time::Instant::now();
            if g.starved || now >= deadline {
                // Adaptive sizing: once GROW_FALLBACK_THRESHOLD misses
                // have fallen back since the last grow, the shortfall is
                // sustained — raise capacity instead of committing to
                // allocate-per-buffer forever.
                if g.capacity < self.core.max_capacity
                    && g.misses_since_grow >= GROW_FALLBACK_THRESHOLD
                {
                    let step = (g.capacity / 2).max(1);
                    g.capacity = (g.capacity + step).min(self.core.max_capacity);
                    g.grow_events += 1;
                    g.misses_since_grow = 0;
                    g.starved = false;
                    continue; // allocated < capacity now: pooled path
                }
                g.misses_since_grow += 1;
                g.starved = true;
                g.fallback_allocs += 1;
                drop(g);
                return PoolBuf {
                    data: Some(AlignedBytes::zeroed(self.core.buf_size, self.core.align)),
                    pool: None,
                };
            }
            let (guard, _timeout) = self.core.available.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    fn alloc_backing(&self) -> AlignedBytes {
        AlignedBytes::zeroed(self.core.buf_size, self.core.align)
    }

    /// Allocate a pooled backing and record its `(address, length)` in
    /// the registration table, all under the state lock — the io_uring
    /// engine's epoch check relies on the table and `allocated` moving
    /// together.
    fn alloc_recorded(&self, g: &mut PoolState) -> AlignedBytes {
        let b = self.alloc_backing();
        g.backings.push((b.ptr.as_ptr() as usize, b.len));
        b
    }

    fn wrap(&self, data: AlignedBytes) -> PoolBuf {
        PoolBuf { data: Some(data), pool: Some(self.core.clone()) }
    }

    /// Stable identity of the shared pool core (`Arc` pointer) — lets the
    /// io_uring engine tell "same pool, new epoch" from "different pool".
    pub(crate) fn core_id(&self) -> usize {
        Arc::as_ptr(&self.core) as usize
    }

    /// The io_uring registration snapshot: eagerly allocate the free list
    /// up to the current capacity (so the table covers every buffer the
    /// pool will hand out at this capacity), then return
    /// `(grow_events, backings)`. After the eager fill `allocated ==
    /// capacity`, so no new pooled backing can appear until the adaptive
    /// sizer raises capacity — which bumps `grow_events`, making
    /// `(core_id, grow_events)` a valid registration-epoch key.
    pub(crate) fn registration_table(&self) -> (u64, Vec<(usize, usize)>) {
        let mut g = self.core.state.lock().unwrap();
        while g.allocated < g.capacity {
            g.allocated += 1;
            let b = self.alloc_recorded(&mut g);
            g.free.push(b);
        }
        let snapshot = (g.grow_events, g.backings.clone());
        drop(g);
        // The eager fill put fresh buffers on the free list; wake any
        // waiter blocked on capacity.
        self.core.available.notify_all();
        snapshot
    }
}

/// A uniquely-owned, writable pool buffer (always `buf_size` bytes).
/// Either [`PoolBuf::freeze`] it into an immutable [`SharedBuf`] for
/// refcounted sharing, or drop it to return the backing immediately.
pub struct PoolBuf {
    data: Option<AlignedBytes>,
    /// `None` for grace-period fallback buffers: they free on drop instead
    /// of returning to the pool.
    pool: Option<Arc<PoolCore>>,
}

impl PoolBuf {
    /// Seal the first `len` bytes as an immutable refcounted buffer. The
    /// backing returns to its pool when the last [`SharedBuf`] clone (or
    /// slice) drops.
    pub fn freeze(mut self, len: usize) -> SharedBuf {
        let data = self.data.take().expect("freeze after drop");
        assert!(len <= data.len(), "freeze length {} exceeds buffer {}", len, data.len());
        SharedBuf {
            backing: Arc::new(Backing {
                pooled: Some(data),
                pool: self.pool.take(),
                owned: None,
                external: None,
            }),
            off: 0,
            len,
        }
    }

    /// Is this a pooled backing (vs a grace-period fallback allocation)?
    pub fn is_pooled(&self) -> bool {
        self.pool.is_some()
    }
}

impl Deref for PoolBuf {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.data.as_ref().expect("deref after drop")
    }
}

impl DerefMut for PoolBuf {
    fn deref_mut(&mut self) -> &mut [u8] {
        self.data.as_mut().expect("deref after drop")
    }
}

impl Drop for PoolBuf {
    fn drop(&mut self) {
        if let (Some(data), Some(pool)) = (self.data.take(), self.pool.take()) {
            pool.put_back(data);
        }
    }
}

/// The refcounted backing of one or more [`SharedBuf`] views: exactly one
/// of `pooled` / `owned` / `external` is set.
struct Backing {
    /// Pool-shaped storage; returns to `pool` on drop when one is set,
    /// frees otherwise (grace-period fallbacks).
    pooled: Option<AlignedBytes>,
    pool: Option<Arc<PoolCore>>,
    /// Plain heap storage ([`SharedBuf::from_vec`]).
    owned: Option<Box<[u8]>>,
    /// Externally owned bytes ([`SharedBuf::from_external`]) — e.g. a live
    /// mmap region; the refcount keeps the owner alive, nothing to free.
    external: Option<Arc<dyn ExternalBytes>>,
}

impl Backing {
    fn as_slice(&self) -> &[u8] {
        if let Some(d) = &self.pooled {
            return d;
        }
        if let Some(d) = &self.owned {
            return d;
        }
        if let Some(e) = &self.external {
            return e.as_bytes();
        }
        unreachable!("backing has no storage")
    }
}

impl Drop for Backing {
    fn drop(&mut self) {
        // Last reference gone: recycle pooled storage, free the rest.
        if let (Some(data), Some(pool)) = (self.pooled.take(), self.pool.take()) {
            pool.put_back(data);
        }
    }
}

/// An immutable, refcounted, sliceable view of a byte buffer — the unit of
/// currency of the zero-copy data plane. Clones and slices share one
/// backing; no byte is copied until someone explicitly asks for a `Vec`.
#[derive(Clone)]
pub struct SharedBuf {
    backing: Arc<Backing>,
    off: usize,
    len: usize,
}

impl SharedBuf {
    /// Wrap an owned `Vec` (unpooled backing; freed on last drop). The
    /// escape hatch for cold paths and tests.
    pub fn from_vec(v: Vec<u8>) -> SharedBuf {
        let len = v.len();
        SharedBuf {
            backing: Arc::new(Backing {
                pooled: None,
                pool: None,
                owned: Some(v.into_boxed_slice()),
                external: None,
            }),
            off: 0,
            len,
        }
    }

    /// A view of `[off, off+len)` of externally owned bytes — the mmap
    /// storage backend's zero-copy read path: the refcount keeps the
    /// mapping alive for as long as any view (socket write, hash queue,
    /// stash, spill) still needs the bytes; nothing is copied and no pool
    /// buffer is consumed.
    pub fn from_external(ext: Arc<dyn ExternalBytes>, off: usize, len: usize) -> SharedBuf {
        let total = ext.as_bytes().len();
        assert!(
            off <= total && len <= total - off,
            "external view [{off}, {off}+{len}) of {total}"
        );
        SharedBuf {
            backing: Arc::new(Backing {
                pooled: None,
                pool: None,
                owned: None,
                external: Some(ext),
            }),
            off,
            len,
        }
    }

    /// Length of the slice in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the slice is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// A sub-view of `[start, end)` sharing the same backing — no copy, no
    /// allocation beyond the `Arc` refcount bump.
    pub fn slice(&self, start: usize, end: usize) -> SharedBuf {
        assert!(start <= end && end <= self.len, "slice [{start}, {end}) of {}", self.len);
        SharedBuf { backing: self.backing.clone(), off: self.off + start, len: end - start }
    }

    /// The bytes this slice covers.
    pub fn as_slice(&self) -> &[u8] {
        &self.backing.as_slice()[self.off..self.off + self.len]
    }

    /// Strong references to the backing (tests / diagnostics).
    pub fn ref_count(&self) -> usize {
        Arc::strong_count(&self.backing)
    }
}

impl Deref for SharedBuf {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for SharedBuf {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl From<Vec<u8>> for SharedBuf {
    fn from(v: Vec<u8>) -> SharedBuf {
        SharedBuf::from_vec(v)
    }
}

impl std::fmt::Debug for SharedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Frames embed SharedBufs; dumping megabytes into error messages
        // helps nobody.
        if self.len <= 16 {
            write!(f, "SharedBuf({:?})", self.as_slice())
        } else {
            write!(f, "SharedBuf(len={}, head={:?}…)", self.len, &self.as_slice()[..8])
        }
    }
}

impl PartialEq for SharedBuf {
    fn eq(&self, other: &SharedBuf) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for SharedBuf {}

impl PartialEq<[u8]> for SharedBuf {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<Vec<u8>> for SharedBuf {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_slice() == &other[..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn freeze_and_read_back() {
        let pool = BufferPool::new(64, 2);
        let mut b = pool.get();
        b[..4].copy_from_slice(&[1, 2, 3, 4]);
        let s = b.freeze(4);
        assert_eq!(s.len(), 4);
        assert_eq!(&s[..], &[1, 2, 3, 4]);
        assert_eq!(s, vec![1, 2, 3, 4]);
    }

    #[test]
    fn returns_to_pool_on_last_drop() {
        let pool = BufferPool::new(8, 1);
        let s = pool.get().freeze(8);
        let s2 = s.clone();
        let sub = s.slice(2, 5);
        assert!(pool.try_get().is_none(), "sole buffer is lent out");
        drop(s);
        drop(s2);
        assert!(pool.try_get().is_none(), "slice still holds the backing");
        drop(sub);
        assert_eq!(pool.free_buffers(), 1);
        assert!(pool.try_get().is_some(), "backing recycled after last ref");
        assert_eq!(pool.allocated(), 1, "no second allocation");
    }

    #[test]
    fn unused_poolbuf_drop_recycles_immediately() {
        let pool = BufferPool::new(8, 1);
        drop(pool.get());
        assert_eq!(pool.free_buffers(), 1);
    }

    #[test]
    fn slices_share_backing_without_copy() {
        let s = SharedBuf::from_vec((0u8..100).collect());
        let a = s.slice(10, 20);
        let b = s.slice(15, 100);
        assert_eq!(&a[..], &(10u8..20).collect::<Vec<u8>>()[..]);
        assert_eq!(b.len(), 85);
        assert_eq!(b[0], 15);
        assert_eq!(s.ref_count(), 3);
        // Sub-slicing a slice stays relative to the slice.
        let c = b.slice(5, 7);
        assert_eq!(&c[..], &[20, 21]);
    }

    #[test]
    fn get_blocks_until_return() {
        let pool = BufferPool::new(16, 1);
        let held = pool.get().freeze(16);
        let pool2 = pool.clone();
        let t = thread::spawn(move || {
            let start = std::time::Instant::now();
            let b = pool2.get();
            (start.elapsed(), b.len())
        });
        thread::sleep(Duration::from_millis(50));
        drop(held);
        let (waited, len) = t.join().unwrap();
        assert_eq!(len, 16);
        assert!(waited >= Duration::from_millis(40), "get should have blocked: {waited:?}");
    }

    #[test]
    fn get_or_alloc_falls_back_after_grace() {
        let pool = BufferPool::new(8, 1);
        let held = pool.get();
        let b = pool.get_or_alloc(Duration::from_millis(20));
        assert!(!b.is_pooled(), "exhausted pool must fall back");
        assert_eq!(pool.fallback_allocs(), 1);
        drop(b);
        assert_eq!(pool.free_buffers(), 0, "fallback buffers don't join the pool");
        drop(held);
        assert_eq!(pool.free_buffers(), 1);
        assert!(pool.get_or_alloc(Duration::from_millis(20)).is_pooled());
        assert_eq!(pool.fallback_allocs(), 1, "pooled grab doesn't count");
    }

    #[test]
    fn starved_pool_falls_back_immediately_until_a_return() {
        let pool = BufferPool::new(8, 1);
        let held = pool.get();
        // First miss pays the grace; once starved, further misses must
        // not wait again.
        let _ = pool.get_or_alloc(Duration::from_millis(10));
        let start = std::time::Instant::now();
        let b = pool.get_or_alloc(Duration::from_secs(60));
        assert!(!b.is_pooled());
        assert!(start.elapsed() < Duration::from_secs(10), "starved pool must not re-wait");
        assert_eq!(pool.fallback_allocs(), 2);
        // A return clears the starvation mark: the next acquisition is
        // pooled again.
        drop(held);
        assert!(pool.get_or_alloc(Duration::from_millis(10)).is_pooled());
    }

    #[test]
    fn in_flight_accounting_tracks_peak() {
        let pool = BufferPool::new(8, 3);
        assert_eq!(pool.peak_in_flight(), 0);
        let a = pool.get().freeze(8);
        let b = pool.get();
        assert_eq!(pool.in_flight(), 2);
        assert_eq!(pool.peak_in_flight(), 2);
        drop(b);
        assert_eq!(pool.in_flight(), 1);
        let c = pool.try_get().unwrap();
        assert_eq!(pool.peak_in_flight(), 2, "peak is a high-water mark");
        drop(c);
        drop(a);
        assert_eq!(pool.in_flight(), 0);
        assert_eq!(pool.peak_in_flight(), 2);
        // Fallback buffers are unpooled and never count as in-flight.
        let held: Vec<PoolBuf> = (0..3).map(|_| pool.get()).collect();
        let fb = pool.get_or_alloc(Duration::from_millis(5));
        assert!(!fb.is_pooled());
        assert_eq!(pool.in_flight(), 3);
        assert_eq!(pool.peak_in_flight(), 3);
        drop(held);
        assert_eq!(pool.in_flight(), 0);
    }

    #[test]
    fn buffers_outlive_pool_handle() {
        let pool = BufferPool::new(8, 2);
        let s = pool.get().freeze(8);
        drop(pool);
        assert_eq!(&s[..], &[0u8; 8]); // backing stays valid
        drop(s); // returns to the (now unreachable) core without panicking
    }

    #[test]
    fn from_vec_roundtrip_and_eq() {
        let s: SharedBuf = vec![9u8, 8, 7].into();
        assert_eq!(s, SharedBuf::from_vec(vec![9, 8, 7]));
        assert!(!s.is_empty());
        assert_eq!(format!("{s:?}"), "SharedBuf([9, 8, 7])");
    }

    #[test]
    fn aligned_pool_yields_aligned_buffers() {
        let pool = BufferPool::with_options(4096, 2, 4096, 2);
        assert_eq!(pool.align(), 4096);
        let b = pool.get();
        assert_eq!(b.as_ptr() as usize % 4096, 0, "pooled backing must honor the alignment");
        // Recycled and fallback backings keep it too.
        let s = b.freeze(4096);
        assert_eq!(s.as_slice().as_ptr() as usize % 4096, 0);
        drop(s);
        let b2 = pool.get();
        assert_eq!(b2.as_ptr() as usize % 4096, 0);
        let _hold = pool.get();
        let fb = pool.get_or_alloc(Duration::from_millis(5));
        assert!(!fb.is_pooled());
        assert_eq!(fb.as_ptr() as usize % 4096, 0, "fallbacks honor the alignment too");
    }

    #[test]
    fn sustained_exhaustion_grows_capacity_up_to_cap() {
        let pool = BufferPool::with_options(8, 2, 1, 4);
        assert_eq!(pool.capacity(), 2);
        assert_eq!(pool.max_capacity(), 4);
        let held: Vec<PoolBuf> = (0..2).map(|_| pool.get()).collect();
        // The first GROW_FALLBACK_THRESHOLD misses fall back...
        let mut fallbacks = Vec::new();
        for _ in 0..GROW_FALLBACK_THRESHOLD {
            let b = pool.get_or_alloc(Duration::from_millis(2));
            assert!(!b.is_pooled());
            fallbacks.push(b);
        }
        assert_eq!(pool.grow_events(), 0);
        // ...and the next one grows the pool instead (2 -> 3).
        let grown = pool.get_or_alloc(Duration::from_millis(2));
        assert!(grown.is_pooled(), "sustained exhaustion must grow, not degrade");
        assert_eq!(pool.capacity(), 3);
        assert_eq!(pool.grow_events(), 1);
        assert_eq!(pool.fallback_allocs(), GROW_FALLBACK_THRESHOLD);
        // Growth is capped at max_capacity: drain the threshold again...
        let mut more = Vec::new();
        for _ in 0..GROW_FALLBACK_THRESHOLD {
            more.push(pool.get_or_alloc(Duration::from_millis(2)));
        }
        let grown2 = pool.get_or_alloc(Duration::from_millis(2));
        assert!(grown2.is_pooled());
        assert_eq!(pool.capacity(), 4, "second grow clamps to the cap");
        assert_eq!(pool.grow_events(), 2);
        // ...after which exhaustion can only fall back.
        for _ in 0..2 * GROW_FALLBACK_THRESHOLD {
            assert!(!pool.get_or_alloc(Duration::from_millis(2)).is_pooled());
        }
        assert_eq!(pool.capacity(), 4, "capacity never exceeds max_capacity");
        assert_eq!(pool.grow_events(), 2);
        drop(held);
        drop(fallbacks);
        drop(more);
        drop(grown);
        drop(grown2);
        assert_eq!(pool.in_flight(), 0);
    }

    #[test]
    fn default_pool_never_grows() {
        let pool = BufferPool::new(8, 1);
        assert_eq!(pool.max_capacity(), 1);
        let _held = pool.get();
        for _ in 0..2 * GROW_FALLBACK_THRESHOLD {
            assert!(!pool.get_or_alloc(Duration::from_millis(1)).is_pooled());
        }
        assert_eq!(pool.capacity(), 1);
        assert_eq!(pool.grow_events(), 0);
    }

    #[test]
    fn registration_table_covers_every_pooled_backing() {
        let pool = BufferPool::with_options(4096, 3, 4096, 6);
        let (epoch, table) = pool.registration_table();
        assert_eq!(epoch, 0);
        assert_eq!(table.len(), 3, "eager fill allocates to capacity");
        assert_eq!(pool.allocated(), 3);
        // Every buffer the pool hands out afterwards lies inside a
        // recorded backing — the property READ_FIXED/WRITE_FIXED needs.
        let b = pool.get();
        let p = b.as_ptr() as usize;
        assert!(table.iter().any(|&(start, len)| p >= start && p < start + len));
        // Stable while grow_events is: a re-snapshot is identical.
        let (epoch2, table2) = pool.registration_table();
        assert_eq!((epoch2, table2.len()), (0, 3));
        drop(b);
        // A grow moves the epoch and the new backing joins the table.
        let held: Vec<PoolBuf> = (0..3).map(|_| pool.get()).collect();
        for _ in 0..=GROW_FALLBACK_THRESHOLD {
            let _ = pool.get_or_alloc(Duration::from_millis(1));
        }
        assert_eq!(pool.grow_events(), 1);
        let (epoch3, table3) = pool.registration_table();
        assert_eq!(epoch3, 1);
        assert!(table3.len() > 3, "grown capacity brings new recorded backings");
        assert!(table3.starts_with(&table), "registration is append-only");
        drop(held);
    }

    #[test]
    fn registration_epochs_stay_consistent_through_clamped_growth() {
        // Odd capacity and ceiling: half-steps round down (5 -> 7) and
        // the last grow clamps (7 -> 9, capped at 9). At every step the
        // epoch equals grow_events, the table stays append-only, and it
        // covers exactly the pooled backings.
        let pool = BufferPool::with_options(16, 5, 1, 9);
        let (mut epoch, mut table) = pool.registration_table();
        assert_eq!((epoch, table.len()), (0, 5), "eager fill to the odd capacity");
        let mut held: Vec<PoolBuf> = (0..5).map(|_| pool.get()).collect();
        for expect_cap in [7usize, 9] {
            for _ in 0..=GROW_FALLBACK_THRESHOLD {
                let b = pool.get_or_alloc(Duration::from_millis(1));
                held.push(b);
            }
            assert_eq!(pool.capacity(), expect_cap);
            assert!(pool.capacity() <= pool.max_capacity());
            let (e, t) = pool.registration_table();
            assert_eq!(e, pool.grow_events(), "epoch is the grow count");
            assert!(e > epoch, "every grow moves the epoch");
            assert!(t.starts_with(&table), "registration is append-only");
            assert_eq!(t.len(), expect_cap, "table covers every pooled backing");
            epoch = e;
            table = t;
            // Drain the eager-filled free list plus any headroom so the
            // next cycle starts exhausted again.
            while let Some(b) = pool.try_get() {
                held.push(b);
            }
        }
        // At the ceiling the epoch freezes with the capacity.
        for _ in 0..2 * GROW_FALLBACK_THRESHOLD {
            assert!(!pool.get_or_alloc(Duration::from_millis(1)).is_pooled());
        }
        let (e, t) = pool.registration_table();
        assert_eq!((e, t.len()), (epoch, 9), "no growth past max_capacity");
        drop(held);
    }

    struct Blob(Vec<u8>);
    impl ExternalBytes for Blob {
        fn as_bytes(&self) -> &[u8] {
            &self.0
        }
    }

    #[test]
    fn external_backing_views_share_without_copy() {
        let ext: Arc<dyn ExternalBytes> = Arc::new(Blob((0u8..100).collect()));
        let s = SharedBuf::from_external(ext.clone(), 10, 50);
        assert_eq!(s.len(), 50);
        assert_eq!(s[0], 10);
        let sub = s.slice(5, 10);
        assert_eq!(&sub[..], &[15, 16, 17, 18, 19]);
        // The views keep the owner alive: 1 (ext) + 1 inside the backing.
        assert_eq!(Arc::strong_count(&ext), 2);
        drop(s);
        drop(sub);
        assert_eq!(Arc::strong_count(&ext), 1, "last view releases the owner");
        // Zero-length view of the very end is fine.
        let empty = SharedBuf::from_external(ext, 100, 0);
        assert!(empty.is_empty());
    }
}
