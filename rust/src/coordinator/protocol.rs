//! Wire protocol between the FIVER sender and receiver.
//!
//! Two TCP streams per session, mirroring GridFTP's split:
//!
//! * **data channel** (sender → receiver): file bytes and repair writes,
//!   framed and self-describing so repairs of file *i* can interleave with
//!   the stream of file *i+1* (FIVER's pipelined recovery).
//! * **control channel** (bidirectional): digests from the receiver,
//!   verdicts/completion from the sender.
//!
//! Frames are length-prefixed: `u8 tag, u32 file_idx, u64 a, u64 b,
//! u32 payload_len, payload`. Fixed 25-byte header; integers little-endian.
//!
//! Zero-copy hot path: `Data`/`Fix` payloads are [`SharedBuf`]s, written
//! with scatter/gather I/O (one `writev` of header + borrowed payload —
//! no serialization copy, see [`write_data_frame_vectored`]) and read
//! directly into pooled buffers ([`Frame::read_from_pooled`]) so the bytes
//! the kernel hands us are the very bytes the storage writer and the hash
//! queue consume.

use std::io::{BufWriter, IoSlice, Read, Write};

use anyhow::{bail, Context, Result};

use super::bufpool::{BufferPool, SharedBuf, POOL_GRACE};

/// Verification scope of a digest (whole file vs one chunk).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DigestKind {
    /// Digest covers a whole file.
    File,
    /// Digest covers one fixed-size chunk.
    Chunk,
}

/// Protocol frames.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Announce a file: `a` = size, `b` = attempt, payload = name.
    FileStart { file_idx: u32, size: u64, attempt: u64, name: String },
    /// File content in stream order: `a` = offset, payload = bytes.
    Data { file_idx: u32, offset: u64, payload: SharedBuf },
    /// End of a file's stream.
    FileEnd { file_idx: u32 },
    /// Repair write into an already-received file: `a` = offset.
    Fix { file_idx: u32, offset: u64, payload: SharedBuf },
    /// All repairs for a verification round sent; `a` = chunk index or
    /// u64::MAX for whole-file.
    FixEnd { file_idx: u32, unit: u64 },
    /// Receiver -> sender digest: `a` = chunk index (u64::MAX = file
    /// digest), payload = digest bytes.
    Digest { file_idx: u32, unit: u64, digest: Vec<u8> },
    /// Sender -> receiver verdict for a digest unit: `a` = unit,
    /// `b` = 1 if ok (0 => expect repairs then a fresh digest).
    Verdict { file_idx: u32, unit: u64, ok: bool },
    /// Receiver -> sender Merkle root (FIVER-Merkle): `a` = leaf count,
    /// `b` = leaf size, payload = root digest.
    TreeRoot { file_idx: u32, leaves: u64, leaf_size: u64, digest: Vec<u8> },
    /// Sender -> receiver node-range query during tree descent: `a` =
    /// level (0 = leaves), `b` = start index, payload = count (u64 LE).
    TreeQuery { file_idx: u32, level: u64, start: u64, count: u64 },
    /// Receiver -> sender node-range response: `a` = level, `b` = start,
    /// payload = concatenated node digests (clipped to the level width).
    TreeNodes { file_idx: u32, level: u64, start: u64, digests: Vec<u8> },
    /// Sender -> receiver, after the repair Fixes of a descent round were
    /// written to the data channel: `a` = repair round (1-based), `b` =
    /// leaves repaired. The receiver then awaits the FixEnd on the data
    /// channel, patches its tree, and answers with a fresh TreeRoot.
    TreeRepairSent { file_idx: u32, round: u64, leaves_fixed: u64 },
    /// Engine handshake, first frame on every engine-mode connection:
    /// `file_idx` = session id, `a` = stripe id (0 for the control
    /// channel), `b` = stripe count. The accept loop uses it to route
    /// freshly accepted sockets to their session.
    Hello { session_id: u32, stripe_id: u64, stripes: u64 },
    /// Receiver -> sender on the resume channel: "my journal attests
    /// `watermark` delivered bytes of this file" — `a` = watermark, `b` =
    /// leaf size, payload = file name (sanity cross-check).
    ResumeOffer { file_idx: u32, watermark: u64, leaf_size: u64, name: String },
    /// Sender -> receiver resume counter-offer: `a` = agreed restart
    /// offset, payload = the sender's Merkle root over its journaled
    /// prefix leaves up to that offset. An empty payload declines the
    /// offer (no/stale sender journal); the receiver answers every ack
    /// with a `Verdict`.
    ResumeAck { file_idx: u32, offset: u64, digest: Vec<u8> },
    /// Sender -> receiver on the delta channel: "what basis do you hold
    /// for this file?" — `a` = the sender's (new) file size, payload =
    /// file name. The receiver answers each request with a `DeltaSig`.
    DeltaReq { file_idx: u32, size: u64, name: String },
    /// Receiver -> sender delta basis: `a` = basis (old destination) file
    /// size, payload = leaf-ordered `(weak u32 LE, strong digest)`
    /// signature pairs at `WEAK_LEN + digest_len` stride. An empty
    /// payload declines (no usable basis: the file transfers in full).
    DeltaSig { file_idx: u32, basis_size: u64, sigs: Vec<u8> },
    /// Announce a delta-reconstructed file on the data channel: `a` =
    /// new file size, payload = name. Followed by interleaved `Data`
    /// (literal bytes) and `DeltaCopy` instructions in strict new-file
    /// order, closed by `DeltaEnd`.
    DeltaStart { file_idx: u32, size: u64, name: String },
    /// Copy instruction: the receiver already holds these bytes — read
    /// `len` bytes at `old_off` of its existing destination file and
    /// append them at `new_off` of the reconstruction. `a` = new_off,
    /// `b` = old_off, payload = len (u64 LE).
    DeltaCopy { file_idx: u32, new_off: u64, old_off: u64, len: u64 },
    /// End of a delta instruction stream: the receiver finalizes the
    /// staged reconstruction and renames it over the destination.
    DeltaEnd { file_idx: u32 },
    /// Session end.
    Done,
}

const TAG_FILE_START: u8 = 1;
const TAG_DATA: u8 = 2;
const TAG_FILE_END: u8 = 3;
const TAG_FIX: u8 = 4;
const TAG_FIX_END: u8 = 5;
const TAG_DIGEST: u8 = 6;
const TAG_VERDICT: u8 = 7;
const TAG_DONE: u8 = 8;
const TAG_TREE_ROOT: u8 = 9;
const TAG_TREE_QUERY: u8 = 10;
const TAG_TREE_NODES: u8 = 11;
const TAG_TREE_REPAIR_SENT: u8 = 12;
const TAG_HELLO: u8 = 13;
const TAG_RESUME_OFFER: u8 = 14;
const TAG_RESUME_ACK: u8 = 15;
const TAG_DELTA_REQ: u8 = 16;
const TAG_DELTA_SIG: u8 = 17;
const TAG_DELTA_START: u8 = 18;
const TAG_DELTA_COPY: u8 = 19;
const TAG_DELTA_END: u8 = 20;

/// Unit value meaning "whole file" in Digest/Verdict/FixEnd frames.
pub const UNIT_FILE: u64 = u64::MAX;

/// `Hello.session_id` marking the dedicated resume-handshake control
/// connection (routed to [`super::journal::negotiate_receiver`] instead
/// of a transfer session).
pub const RESUME_SESSION: u32 = u32::MAX;

/// `Hello.session_id` marking the dedicated delta-sync handshake control
/// connection (routed to [`super::journal::negotiate_delta_receiver`]
/// instead of a transfer session).
pub const DELTA_SESSION: u32 = u32::MAX - 1;

/// Fixed frame header width.
pub const HEADER_LEN: usize = 25;

/// Payloads below this go through the caller's `BufWriter` (one memcpy
/// into warm buffer memory beats a syscall); at or above it the writer is
/// flushed and header + payload leave in a single `writev` — no copy.
const VECTORED_MIN: usize = 8 * 1024;

fn encode_header(tag: u8, idx: u32, a: u64, b: u64, payload_len: usize) -> [u8; HEADER_LEN] {
    let mut header = [0u8; HEADER_LEN];
    header[0] = tag;
    header[1..5].copy_from_slice(&idx.to_le_bytes());
    header[5..13].copy_from_slice(&a.to_le_bytes());
    header[13..21].copy_from_slice(&b.to_le_bytes());
    header[21..25].copy_from_slice(&(payload_len as u32).to_le_bytes());
    header
}

impl Frame {
    /// Serialize to a writer. One syscall-ish write for the header plus one
    /// for the payload; callers wrap sockets in BufWriter. (The Data/Fix
    /// hot paths use [`write_data_frame_vectored`] /
    /// [`write_fix_frame_vectored`] instead.)
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        let count_bytes;
        let (tag, idx, a, b, payload): (u8, u32, u64, u64, &[u8]) = match self {
            Frame::FileStart { file_idx, size, attempt, name } => {
                (TAG_FILE_START, *file_idx, *size, *attempt, name.as_bytes())
            }
            Frame::Data { file_idx, offset, payload } => {
                (TAG_DATA, *file_idx, *offset, 0, payload)
            }
            Frame::FileEnd { file_idx } => (TAG_FILE_END, *file_idx, 0, 0, &[]),
            Frame::Fix { file_idx, offset, payload } => (TAG_FIX, *file_idx, *offset, 0, payload),
            Frame::FixEnd { file_idx, unit } => (TAG_FIX_END, *file_idx, *unit, 0, &[]),
            Frame::Digest { file_idx, unit, digest } => {
                (TAG_DIGEST, *file_idx, *unit, 0, digest)
            }
            Frame::Verdict { file_idx, unit, ok } => {
                (TAG_VERDICT, *file_idx, *unit, u64::from(*ok), &[])
            }
            Frame::TreeRoot { file_idx, leaves, leaf_size, digest } => {
                (TAG_TREE_ROOT, *file_idx, *leaves, *leaf_size, digest)
            }
            Frame::TreeQuery { file_idx, level, start, count } => {
                count_bytes = count.to_le_bytes();
                (TAG_TREE_QUERY, *file_idx, *level, *start, &count_bytes)
            }
            Frame::TreeNodes { file_idx, level, start, digests } => {
                (TAG_TREE_NODES, *file_idx, *level, *start, digests)
            }
            Frame::TreeRepairSent { file_idx, round, leaves_fixed } => {
                (TAG_TREE_REPAIR_SENT, *file_idx, *round, *leaves_fixed, &[])
            }
            Frame::Hello { session_id, stripe_id, stripes } => {
                (TAG_HELLO, *session_id, *stripe_id, *stripes, &[])
            }
            Frame::ResumeOffer { file_idx, watermark, leaf_size, name } => {
                (TAG_RESUME_OFFER, *file_idx, *watermark, *leaf_size, name.as_bytes())
            }
            Frame::ResumeAck { file_idx, offset, digest } => {
                (TAG_RESUME_ACK, *file_idx, *offset, 0, digest)
            }
            Frame::DeltaReq { file_idx, size, name } => {
                (TAG_DELTA_REQ, *file_idx, *size, 0, name.as_bytes())
            }
            Frame::DeltaSig { file_idx, basis_size, sigs } => {
                (TAG_DELTA_SIG, *file_idx, *basis_size, 0, sigs)
            }
            Frame::DeltaStart { file_idx, size, name } => {
                (TAG_DELTA_START, *file_idx, *size, 0, name.as_bytes())
            }
            Frame::DeltaCopy { file_idx, new_off, old_off, len } => {
                count_bytes = len.to_le_bytes();
                (TAG_DELTA_COPY, *file_idx, *new_off, *old_off, &count_bytes)
            }
            Frame::DeltaEnd { file_idx } => (TAG_DELTA_END, *file_idx, 0, 0, &[]),
            Frame::Done => (TAG_DONE, 0, 0, 0, &[]),
        };
        let header = encode_header(tag, idx, a, b, payload.len());
        w.write_all(&header)?;
        w.write_all(payload)?;
        Ok(())
    }

    /// Read one frame, allocating payloads on the heap. `Ok(None)` on
    /// clean EOF at a frame boundary. Control channels and tests use this;
    /// data channels use [`Frame::read_from_pooled`].
    pub fn read_from<R: Read>(r: &mut R) -> Result<Option<Frame>> {
        Frame::read_framed(r, None)
    }

    /// Read one frame, filling `Data`/`Fix` payloads directly from the
    /// stream into a pooled buffer (refcounted; returns to `pool` on last
    /// drop). Oversized payloads fall back to a heap allocation rather
    /// than failing.
    pub fn read_from_pooled<R: Read>(r: &mut R, pool: &BufferPool) -> Result<Option<Frame>> {
        Frame::read_framed(r, Some(pool))
    }

    fn read_framed<R: Read>(r: &mut R, pool: Option<&BufferPool>) -> Result<Option<Frame>> {
        let mut header = [0u8; HEADER_LEN];
        match read_exact_or_eof(r, &mut header)? {
            false => return Ok(None),
            true => {}
        }
        let tag = header[0];
        let file_idx = u32::from_le_bytes(header[1..5].try_into().unwrap());
        let a = u64::from_le_bytes(header[5..13].try_into().unwrap());
        let b = u64::from_le_bytes(header[13..21].try_into().unwrap());
        let len = u32::from_le_bytes(header[21..25].try_into().unwrap()) as usize;
        const MAX_PAYLOAD: usize = 64 << 20;
        if len > MAX_PAYLOAD {
            bail!("frame payload {len} exceeds limit");
        }
        // Byte-carrying frames read straight into a pooled buffer; the
        // metadata frames below own small Vec payloads.
        if tag == TAG_DATA || tag == TAG_FIX {
            let payload = read_payload(r, len, pool)?;
            return Ok(Some(match tag {
                TAG_DATA => Frame::Data { file_idx, offset: a, payload },
                _ => Frame::Fix { file_idx, offset: a, payload },
            }));
        }
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload).context("frame payload")?;
        Ok(Some(match tag {
            TAG_FILE_START => Frame::FileStart {
                file_idx,
                size: a,
                attempt: b,
                name: String::from_utf8(payload).context("file name utf8")?,
            },
            TAG_FILE_END => Frame::FileEnd { file_idx },
            TAG_FIX_END => Frame::FixEnd { file_idx, unit: a },
            TAG_DIGEST => Frame::Digest { file_idx, unit: a, digest: payload },
            TAG_VERDICT => Frame::Verdict { file_idx, unit: a, ok: b != 0 },
            TAG_TREE_ROOT => Frame::TreeRoot { file_idx, leaves: a, leaf_size: b, digest: payload },
            TAG_TREE_QUERY => Frame::TreeQuery {
                file_idx,
                level: a,
                start: b,
                count: u64::from_le_bytes(
                    payload.as_slice().try_into().context("tree query count")?,
                ),
            },
            TAG_TREE_NODES => Frame::TreeNodes { file_idx, level: a, start: b, digests: payload },
            TAG_TREE_REPAIR_SENT => {
                Frame::TreeRepairSent { file_idx, round: a, leaves_fixed: b }
            }
            TAG_HELLO => Frame::Hello { session_id: file_idx, stripe_id: a, stripes: b },
            TAG_RESUME_OFFER => Frame::ResumeOffer {
                file_idx,
                watermark: a,
                leaf_size: b,
                name: String::from_utf8(payload).context("resume offer name utf8")?,
            },
            TAG_RESUME_ACK => Frame::ResumeAck { file_idx, offset: a, digest: payload },
            TAG_DELTA_REQ => Frame::DeltaReq {
                file_idx,
                size: a,
                name: String::from_utf8(payload).context("delta req name utf8")?,
            },
            TAG_DELTA_SIG => Frame::DeltaSig { file_idx, basis_size: a, sigs: payload },
            TAG_DELTA_START => Frame::DeltaStart {
                file_idx,
                size: a,
                name: String::from_utf8(payload).context("delta start name utf8")?,
            },
            TAG_DELTA_COPY => Frame::DeltaCopy {
                file_idx,
                new_off: a,
                old_off: b,
                len: u64::from_le_bytes(payload.as_slice().try_into().context("delta copy len")?),
            },
            TAG_DELTA_END => Frame::DeltaEnd { file_idx },
            TAG_DONE => Frame::Done,
            _ => bail!("unknown frame tag {tag}"),
        }))
    }
}

/// Fill a payload of `len` bytes from the stream: pooled when a pool is
/// given and the payload fits its buffer size, heap otherwise.
fn read_payload<R: Read>(r: &mut R, len: usize, pool: Option<&BufferPool>) -> Result<SharedBuf> {
    match pool {
        Some(pool) if len <= pool.buf_size() => {
            let mut buf = pool.get_or_alloc(POOL_GRACE);
            r.read_exact(&mut buf[..len]).context("frame payload")?;
            Ok(buf.freeze(len))
        }
        _ => {
            let mut payload = vec![0u8; len];
            r.read_exact(&mut payload).context("frame payload")?;
            Ok(SharedBuf::from_vec(payload))
        }
    }
}

/// Write a `Data` frame from a borrowed slice — the hot path; avoids
/// constructing a `Frame` (and its owned payload) per buffer.
pub fn write_data_frame<W: Write>(
    w: &mut W,
    file_idx: u32,
    offset: u64,
    payload: &[u8],
) -> Result<()> {
    let header = encode_header(TAG_DATA, file_idx, offset, 0, payload.len());
    w.write_all(&header)?;
    w.write_all(payload)?;
    Ok(())
}

/// Write a `Data` frame with scatter/gather I/O: small payloads ride the
/// `BufWriter`, large ones flush it and leave as one `writev` of header +
/// borrowed payload — the payload bytes are never copied into a staging
/// buffer.
pub fn write_data_frame_vectored<W: Write>(
    w: &mut BufWriter<W>,
    file_idx: u32,
    offset: u64,
    payload: &[u8],
) -> Result<()> {
    let header = encode_header(TAG_DATA, file_idx, offset, 0, payload.len());
    write_frame_vectored(w, &header, payload)
}

/// [`write_data_frame_vectored`]'s twin for repair `Fix` frames, so the
/// recovery path shares the zero-copy machinery.
pub fn write_fix_frame_vectored<W: Write>(
    w: &mut BufWriter<W>,
    file_idx: u32,
    offset: u64,
    payload: &[u8],
) -> Result<()> {
    let header = encode_header(TAG_FIX, file_idx, offset, 0, payload.len());
    write_frame_vectored(w, &header, payload)
}

fn write_frame_vectored<W: Write>(
    w: &mut BufWriter<W>,
    header: &[u8; HEADER_LEN],
    payload: &[u8],
) -> Result<()> {
    if payload.len() < VECTORED_MIN {
        w.write_all(header)?;
        w.write_all(payload)?;
        return Ok(());
    }
    // Preserve frame ordering: everything buffered so far goes first.
    w.flush()?;
    let inner = w.get_mut();
    let mut hdr_written = 0usize;
    let mut pay_written = 0usize;
    while hdr_written < header.len() || pay_written < payload.len() {
        let n = if hdr_written < header.len() {
            // writev consumes slices in order, so payload bytes can only
            // follow a fully written header within one call.
            let bufs = [IoSlice::new(&header[hdr_written..]), IoSlice::new(payload)];
            inner.write_vectored(&bufs)?
        } else {
            inner.write(&payload[pay_written..])?
        };
        if n == 0 {
            bail!("write_vectored wrote zero bytes");
        }
        let hdr_take = n.min(header.len() - hdr_written);
        hdr_written += hdr_take;
        pay_written += n - hdr_take;
    }
    Ok(())
}

/// read_exact that distinguishes clean EOF (nothing read) from truncation.
fn read_exact_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        let n = r.read(&mut buf[filled..])?;
        if n == 0 {
            if filled == 0 {
                return Ok(false);
            }
            bail!("truncated frame: {filled}/{} header bytes", buf.len());
        }
        filled += n;
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sbuf(v: Vec<u8>) -> SharedBuf {
        SharedBuf::from_vec(v)
    }

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        let mut cursor = &buf[..];
        let back = Frame::read_from(&mut cursor).unwrap().unwrap();
        assert_eq!(back, f);
        // Stream fully consumed.
        assert!(Frame::read_from(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn roundtrip_all_variants() {
        roundtrip(Frame::FileStart {
            file_idx: 7,
            size: 1 << 40,
            attempt: 2,
            name: "dataset/file-0001".into(),
        });
        roundtrip(Frame::Data { file_idx: 1, offset: 12345, payload: sbuf(vec![1, 2, 3]) });
        roundtrip(Frame::FileEnd { file_idx: 9 });
        roundtrip(Frame::Fix { file_idx: 3, offset: 999, payload: sbuf(vec![0xAA; 100]) });
        roundtrip(Frame::FixEnd { file_idx: 3, unit: UNIT_FILE });
        roundtrip(Frame::Digest { file_idx: 2, unit: 5, digest: vec![0xCD; 32] });
        roundtrip(Frame::Verdict { file_idx: 2, unit: UNIT_FILE, ok: true });
        roundtrip(Frame::Verdict { file_idx: 2, unit: 0, ok: false });
        roundtrip(Frame::TreeRoot {
            file_idx: 4,
            leaves: 16384,
            leaf_size: 64 << 10,
            digest: vec![0x5A; 32],
        });
        roundtrip(Frame::TreeQuery { file_idx: 4, level: 7, start: 128, count: 2 });
        roundtrip(Frame::TreeNodes { file_idx: 4, level: 7, start: 128, digests: vec![1; 64] });
        roundtrip(Frame::TreeRepairSent { file_idx: 4, round: 1, leaves_fixed: 3 });
        roundtrip(Frame::Hello { session_id: 3, stripe_id: 1, stripes: 4 });
        roundtrip(Frame::ResumeOffer {
            file_idx: 11,
            watermark: 3 << 20,
            leaf_size: 64 << 10,
            name: "dataset/file-0011".into(),
        });
        roundtrip(Frame::ResumeAck { file_idx: 11, offset: 3 << 20, digest: vec![0x6C; 32] });
        roundtrip(Frame::ResumeAck { file_idx: 12, offset: 0, digest: Vec::new() });
        roundtrip(Frame::DeltaReq { file_idx: 5, size: 1 << 30, name: "dataset/d.bin".into() });
        roundtrip(Frame::DeltaSig { file_idx: 5, basis_size: 1 << 30, sigs: vec![0x3B; 72] });
        roundtrip(Frame::DeltaSig { file_idx: 6, basis_size: 0, sigs: Vec::new() });
        roundtrip(Frame::DeltaStart { file_idx: 5, size: 1 << 30, name: "dataset/d.bin".into() });
        roundtrip(Frame::DeltaCopy { file_idx: 5, new_off: 1 << 17, old_off: 65536, len: 65536 });
        roundtrip(Frame::DeltaEnd { file_idx: 5 });
        roundtrip(Frame::Done);
    }

    #[test]
    fn sequential_frames_in_one_stream() {
        let mut buf = Vec::new();
        let frames = vec![
            Frame::FileStart { file_idx: 0, size: 3, attempt: 0, name: "a".into() },
            Frame::Data { file_idx: 0, offset: 0, payload: sbuf(vec![1, 2, 3]) },
            Frame::FileEnd { file_idx: 0 },
            Frame::Done,
        ];
        for f in &frames {
            f.write_to(&mut buf).unwrap();
        }
        let mut cursor = &buf[..];
        for f in &frames {
            assert_eq!(&Frame::read_from(&mut cursor).unwrap().unwrap(), f);
        }
    }

    #[test]
    fn vectored_write_matches_plain_encoding() {
        // Below and above VECTORED_MIN must produce identical bytes.
        for size in [16usize, 100 * 1024] {
            let payload: Vec<u8> = (0..size).map(|i| i as u8).collect();
            let mut plain = Vec::new();
            write_data_frame(&mut plain, 3, 777, &payload).unwrap();
            let mut w = BufWriter::new(Vec::new());
            write_data_frame_vectored(&mut w, 3, 777, &payload).unwrap();
            let vectored = w.into_inner().unwrap();
            assert_eq!(plain, vectored, "size {size}");
            // And the fix twin differs only in its tag.
            let mut wf = BufWriter::new(Vec::new());
            write_fix_frame_vectored(&mut wf, 3, 777, &payload).unwrap();
            let fix = wf.into_inner().unwrap();
            let mut cursor = &fix[..];
            match Frame::read_from(&mut cursor).unwrap().unwrap() {
                Frame::Fix { file_idx: 3, offset: 777, payload: p } => {
                    assert_eq!(p, payload);
                }
                other => panic!("expected Fix, got {other:?}"),
            }
        }
    }

    /// A writer that accepts at most `max` bytes per call — exercises the
    /// partial-write loop of the vectored path.
    #[derive(Debug)]
    struct Dribble {
        out: Vec<u8>,
        max: usize,
    }

    impl Write for Dribble {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            let n = buf.len().min(self.max);
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn vectored_write_survives_partial_writes() {
        let payload: Vec<u8> = (0..50_000).map(|i| (i * 7) as u8).collect();
        let mut w = BufWriter::new(Dribble { out: Vec::new(), max: 11 });
        write_data_frame_vectored(&mut w, 1, 0, &payload).unwrap();
        w.flush().unwrap();
        let bytes = w.into_inner().unwrap().out;
        let mut cursor = &bytes[..];
        match Frame::read_from(&mut cursor).unwrap().unwrap() {
            Frame::Data { payload: p, .. } => assert_eq!(p, payload),
            other => panic!("expected Data, got {other:?}"),
        }
    }

    #[test]
    fn pooled_read_recycles_payload_buffers() {
        let pool = BufferPool::new(1024, 2);
        let mut stream = Vec::new();
        for i in 0..4u8 {
            write_data_frame(&mut stream, 0, i as u64 * 100, &[i; 100]).unwrap();
        }
        Frame::Done.write_to(&mut stream).unwrap();
        let mut cursor = &stream[..];
        for i in 0..4u8 {
            let f = Frame::read_from_pooled(&mut cursor, &pool).unwrap().unwrap();
            let Frame::Data { payload, .. } = f else { panic!("expected Data") };
            assert_eq!(payload, vec![i; 100]);
            // Dropping the payload here returns the buffer; the pool never
            // grows past one backing.
        }
        assert_eq!(pool.allocated(), 1, "buffers recycled, not re-allocated");
        assert!(matches!(
            Frame::read_from_pooled(&mut cursor, &pool).unwrap().unwrap(),
            Frame::Done
        ));
    }

    #[test]
    fn pooled_read_falls_back_for_oversized_payload() {
        let pool = BufferPool::new(16, 1);
        let mut stream = Vec::new();
        write_data_frame(&mut stream, 0, 0, &[7u8; 64]).unwrap();
        let mut cursor = &stream[..];
        let f = Frame::read_from_pooled(&mut cursor, &pool).unwrap().unwrap();
        let Frame::Data { payload, .. } = f else { panic!("expected Data") };
        assert_eq!(payload, vec![7u8; 64]);
        assert_eq!(pool.allocated(), 0, "oversized payload skipped the pool");
    }

    #[test]
    fn truncated_frame_errors() {
        let mut buf = Vec::new();
        Frame::Data { file_idx: 0, offset: 0, payload: sbuf(vec![9; 10]) }
            .write_to(&mut buf)
            .unwrap();
        let mut cursor = &buf[..20]; // mid-header
        assert!(Frame::read_from(&mut cursor).is_err());
    }

    #[test]
    fn unknown_tag_errors() {
        let mut buf = vec![0xFFu8; 25];
        buf[21..25].copy_from_slice(&0u32.to_le_bytes());
        let mut cursor = &buf[..];
        assert!(Frame::read_from(&mut cursor).is_err());
    }

    #[test]
    fn oversized_payload_rejected() {
        let mut header = [0u8; 25];
        header[0] = TAG_DATA;
        header[21..25].copy_from_slice(&(65u32 << 20).to_le_bytes());
        let mut cursor = &header[..];
        assert!(Frame::read_from(&mut cursor).is_err());
    }
}
