//! Wire protocol between the FIVER sender and receiver.
//!
//! Two TCP streams per session, mirroring GridFTP's split:
//!
//! * **data channel** (sender → receiver): file bytes and repair writes,
//!   framed and self-describing so repairs of file *i* can interleave with
//!   the stream of file *i+1* (FIVER's pipelined recovery).
//! * **control channel** (bidirectional): digests from the receiver,
//!   verdicts/completion from the sender.
//!
//! Frames are length-prefixed: `u8 tag, u32 file_idx, u64 a, u64 b,
//! u32 payload_len, payload`. Fixed 25-byte header; integers little-endian.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

/// Verification scope of a digest (whole file vs one chunk).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DigestKind {
    File,
    Chunk,
}

/// Protocol frames.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Announce a file: `a` = size, `b` = attempt, payload = name.
    FileStart { file_idx: u32, size: u64, attempt: u64, name: String },
    /// File content in stream order: `a` = offset, payload = bytes.
    Data { file_idx: u32, offset: u64, payload: Vec<u8> },
    /// End of a file's stream.
    FileEnd { file_idx: u32 },
    /// Repair write into an already-received file: `a` = offset.
    Fix { file_idx: u32, offset: u64, payload: Vec<u8> },
    /// All repairs for a verification round sent; `a` = chunk index or
    /// u64::MAX for whole-file.
    FixEnd { file_idx: u32, unit: u64 },
    /// Receiver -> sender digest: `a` = chunk index (u64::MAX = file
    /// digest), payload = digest bytes.
    Digest { file_idx: u32, unit: u64, digest: Vec<u8> },
    /// Sender -> receiver verdict for a digest unit: `a` = unit,
    /// `b` = 1 if ok (0 => expect repairs then a fresh digest).
    Verdict { file_idx: u32, unit: u64, ok: bool },
    /// Receiver -> sender Merkle root (FIVER-Merkle): `a` = leaf count,
    /// `b` = leaf size, payload = root digest.
    TreeRoot { file_idx: u32, leaves: u64, leaf_size: u64, digest: Vec<u8> },
    /// Sender -> receiver node-range query during tree descent: `a` =
    /// level (0 = leaves), `b` = start index, payload = count (u64 LE).
    TreeQuery { file_idx: u32, level: u64, start: u64, count: u64 },
    /// Receiver -> sender node-range response: `a` = level, `b` = start,
    /// payload = concatenated node digests (clipped to the level width).
    TreeNodes { file_idx: u32, level: u64, start: u64, digests: Vec<u8> },
    /// Sender -> receiver, after the repair Fixes of a descent round were
    /// written to the data channel: `a` = repair round (1-based), `b` =
    /// leaves repaired. The receiver then awaits the FixEnd on the data
    /// channel, patches its tree, and answers with a fresh TreeRoot.
    TreeRepairSent { file_idx: u32, round: u64, leaves_fixed: u64 },
    /// Engine handshake, first frame on every engine-mode connection:
    /// `file_idx` = session id, `a` = stripe id (0 for the control
    /// channel), `b` = stripe count. The accept loop uses it to route
    /// freshly accepted sockets to their session.
    Hello { session_id: u32, stripe_id: u64, stripes: u64 },
    /// Session end.
    Done,
}

const TAG_FILE_START: u8 = 1;
const TAG_DATA: u8 = 2;
const TAG_FILE_END: u8 = 3;
const TAG_FIX: u8 = 4;
const TAG_FIX_END: u8 = 5;
const TAG_DIGEST: u8 = 6;
const TAG_VERDICT: u8 = 7;
const TAG_DONE: u8 = 8;
const TAG_TREE_ROOT: u8 = 9;
const TAG_TREE_QUERY: u8 = 10;
const TAG_TREE_NODES: u8 = 11;
const TAG_TREE_REPAIR_SENT: u8 = 12;
const TAG_HELLO: u8 = 13;

/// Unit value meaning "whole file" in Digest/Verdict/FixEnd frames.
pub const UNIT_FILE: u64 = u64::MAX;

impl Frame {
    /// Serialize to a writer. One syscall-ish write for the header plus one
    /// for the payload; callers wrap sockets in BufWriter.
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        let count_bytes;
        let (tag, idx, a, b, payload): (u8, u32, u64, u64, &[u8]) = match self {
            Frame::FileStart { file_idx, size, attempt, name } => {
                (TAG_FILE_START, *file_idx, *size, *attempt, name.as_bytes())
            }
            Frame::Data { file_idx, offset, payload } => {
                (TAG_DATA, *file_idx, *offset, 0, payload)
            }
            Frame::FileEnd { file_idx } => (TAG_FILE_END, *file_idx, 0, 0, &[]),
            Frame::Fix { file_idx, offset, payload } => (TAG_FIX, *file_idx, *offset, 0, payload),
            Frame::FixEnd { file_idx, unit } => (TAG_FIX_END, *file_idx, *unit, 0, &[]),
            Frame::Digest { file_idx, unit, digest } => {
                (TAG_DIGEST, *file_idx, *unit, 0, digest)
            }
            Frame::Verdict { file_idx, unit, ok } => {
                (TAG_VERDICT, *file_idx, *unit, u64::from(*ok), &[])
            }
            Frame::TreeRoot { file_idx, leaves, leaf_size, digest } => {
                (TAG_TREE_ROOT, *file_idx, *leaves, *leaf_size, digest)
            }
            Frame::TreeQuery { file_idx, level, start, count } => {
                count_bytes = count.to_le_bytes();
                (TAG_TREE_QUERY, *file_idx, *level, *start, &count_bytes)
            }
            Frame::TreeNodes { file_idx, level, start, digests } => {
                (TAG_TREE_NODES, *file_idx, *level, *start, digests)
            }
            Frame::TreeRepairSent { file_idx, round, leaves_fixed } => {
                (TAG_TREE_REPAIR_SENT, *file_idx, *round, *leaves_fixed, &[])
            }
            Frame::Hello { session_id, stripe_id, stripes } => {
                (TAG_HELLO, *session_id, *stripe_id, *stripes, &[])
            }
            Frame::Done => (TAG_DONE, 0, 0, 0, &[]),
        };
        let mut header = [0u8; 25];
        header[0] = tag;
        header[1..5].copy_from_slice(&idx.to_le_bytes());
        header[5..13].copy_from_slice(&a.to_le_bytes());
        header[13..21].copy_from_slice(&b.to_le_bytes());
        header[21..25].copy_from_slice(&(payload.len() as u32).to_le_bytes());
        w.write_all(&header)?;
        w.write_all(payload)?;
        Ok(())
    }

    /// Read one frame. `Ok(None)` on clean EOF at a frame boundary.
    pub fn read_from<R: Read>(r: &mut R) -> Result<Option<Frame>> {
        let mut header = [0u8; 25];
        match read_exact_or_eof(r, &mut header)? {
            false => return Ok(None),
            true => {}
        }
        let tag = header[0];
        let file_idx = u32::from_le_bytes(header[1..5].try_into().unwrap());
        let a = u64::from_le_bytes(header[5..13].try_into().unwrap());
        let b = u64::from_le_bytes(header[13..21].try_into().unwrap());
        let len = u32::from_le_bytes(header[21..25].try_into().unwrap()) as usize;
        const MAX_PAYLOAD: usize = 64 << 20;
        if len > MAX_PAYLOAD {
            bail!("frame payload {len} exceeds limit");
        }
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload).context("frame payload")?;
        Ok(Some(match tag {
            TAG_FILE_START => Frame::FileStart {
                file_idx,
                size: a,
                attempt: b,
                name: String::from_utf8(payload).context("file name utf8")?,
            },
            TAG_DATA => Frame::Data { file_idx, offset: a, payload },
            TAG_FILE_END => Frame::FileEnd { file_idx },
            TAG_FIX => Frame::Fix { file_idx, offset: a, payload },
            TAG_FIX_END => Frame::FixEnd { file_idx, unit: a },
            TAG_DIGEST => Frame::Digest { file_idx, unit: a, digest: payload },
            TAG_VERDICT => Frame::Verdict { file_idx, unit: a, ok: b != 0 },
            TAG_TREE_ROOT => Frame::TreeRoot { file_idx, leaves: a, leaf_size: b, digest: payload },
            TAG_TREE_QUERY => Frame::TreeQuery {
                file_idx,
                level: a,
                start: b,
                count: u64::from_le_bytes(
                    payload.as_slice().try_into().context("tree query count")?,
                ),
            },
            TAG_TREE_NODES => Frame::TreeNodes { file_idx, level: a, start: b, digests: payload },
            TAG_TREE_REPAIR_SENT => {
                Frame::TreeRepairSent { file_idx, round: a, leaves_fixed: b }
            }
            TAG_HELLO => Frame::Hello { session_id: file_idx, stripe_id: a, stripes: b },
            TAG_DONE => Frame::Done,
            _ => bail!("unknown frame tag {tag}"),
        }))
    }
}

/// Write a `Data` frame from a borrowed slice — the hot path; avoids
/// constructing a `Frame` (and its owned `Vec`) per buffer.
pub fn write_data_frame<W: Write>(
    w: &mut W,
    file_idx: u32,
    offset: u64,
    payload: &[u8],
) -> Result<()> {
    let mut header = [0u8; 25];
    header[0] = TAG_DATA;
    header[1..5].copy_from_slice(&file_idx.to_le_bytes());
    header[5..13].copy_from_slice(&offset.to_le_bytes());
    header[21..25].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    Ok(())
}

/// read_exact that distinguishes clean EOF (nothing read) from truncation.
fn read_exact_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        let n = r.read(&mut buf[filled..])?;
        if n == 0 {
            if filled == 0 {
                return Ok(false);
            }
            bail!("truncated frame: {filled}/{} header bytes", buf.len());
        }
        filled += n;
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        let mut cursor = &buf[..];
        let back = Frame::read_from(&mut cursor).unwrap().unwrap();
        assert_eq!(back, f);
        // Stream fully consumed.
        assert!(Frame::read_from(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn roundtrip_all_variants() {
        roundtrip(Frame::FileStart {
            file_idx: 7,
            size: 1 << 40,
            attempt: 2,
            name: "dataset/file-0001".into(),
        });
        roundtrip(Frame::Data { file_idx: 1, offset: 12345, payload: vec![1, 2, 3] });
        roundtrip(Frame::FileEnd { file_idx: 9 });
        roundtrip(Frame::Fix { file_idx: 3, offset: 999, payload: vec![0xAA; 100] });
        roundtrip(Frame::FixEnd { file_idx: 3, unit: UNIT_FILE });
        roundtrip(Frame::Digest { file_idx: 2, unit: 5, digest: vec![0xCD; 32] });
        roundtrip(Frame::Verdict { file_idx: 2, unit: UNIT_FILE, ok: true });
        roundtrip(Frame::Verdict { file_idx: 2, unit: 0, ok: false });
        roundtrip(Frame::TreeRoot {
            file_idx: 4,
            leaves: 16384,
            leaf_size: 64 << 10,
            digest: vec![0x5A; 32],
        });
        roundtrip(Frame::TreeQuery { file_idx: 4, level: 7, start: 128, count: 2 });
        roundtrip(Frame::TreeNodes { file_idx: 4, level: 7, start: 128, digests: vec![1; 64] });
        roundtrip(Frame::TreeRepairSent { file_idx: 4, round: 1, leaves_fixed: 3 });
        roundtrip(Frame::Hello { session_id: 3, stripe_id: 1, stripes: 4 });
        roundtrip(Frame::Done);
    }

    #[test]
    fn sequential_frames_in_one_stream() {
        let mut buf = Vec::new();
        let frames = vec![
            Frame::FileStart { file_idx: 0, size: 3, attempt: 0, name: "a".into() },
            Frame::Data { file_idx: 0, offset: 0, payload: vec![1, 2, 3] },
            Frame::FileEnd { file_idx: 0 },
            Frame::Done,
        ];
        for f in &frames {
            f.write_to(&mut buf).unwrap();
        }
        let mut cursor = &buf[..];
        for f in &frames {
            assert_eq!(&Frame::read_from(&mut cursor).unwrap().unwrap(), f);
        }
    }

    #[test]
    fn truncated_frame_errors() {
        let mut buf = Vec::new();
        Frame::Data { file_idx: 0, offset: 0, payload: vec![9; 10] }.write_to(&mut buf).unwrap();
        let mut cursor = &buf[..20]; // mid-header
        assert!(Frame::read_from(&mut cursor).is_err());
    }

    #[test]
    fn unknown_tag_errors() {
        let mut buf = vec![0xFFu8; 25];
        buf[21..25].copy_from_slice(&0u32.to_le_bytes());
        let mut cursor = &buf[..];
        assert!(Frame::read_from(&mut cursor).is_err());
    }

    #[test]
    fn oversized_payload_rejected() {
        let mut header = [0u8; 25];
        header[0] = TAG_DATA;
        header[21..25].copy_from_slice(&(65u32 << 20).to_le_bytes());
        let mut cursor = &header[..];
        assert!(Frame::read_from(&mut cursor).is_err());
    }
}
