//! The shared hash worker pool — checksum compute decoupled from
//! per-session threads.
//!
//! The original coordinator spawned one hash thread per queue-mode file,
//! so a 1000-file dataset paid 1000 thread spawns per endpoint and a
//! single slow hash could not borrow an idle core. The engine instead
//! owns one [`HashPool`] per endpoint: sessions submit one job per
//! queue-mode file (drain that file's [`super::queue::ByteQueue`] into a
//! digest or digest tree), and a set of workers executes them. FIVER's
//! per-file queue sharing is untouched — the queue is still the
//! rendezvous between the transfer thread and the checksum computation;
//! only *who runs* the computation changed.
//!
//! Deadlock-freedom (any pool size >= 1): jobs run FIFO, so the earliest
//! *unfinished* job is always occupying a worker. On the sender a session
//! streams one file at a time, so that job's queue is either closed
//! (finite drain) or the very queue its session thread is feeding —
//! mutual progress through the queue's back-pressure. On the receiver,
//! stripe skew can hold several files open per session, so the frame
//! merger never blocks on a full queue mid-stream (it spills —
//! [`super::queue::ByteQueue::try_add`]); its only blocking adds happen
//! after end-of-stream, oldest file first, and the earliest unfinished
//! job is exactly some session's oldest open file.
//!
//! Dynamic resizing (the adaptive controller's actuator) preserves that
//! argument:
//!
//! * [`HashPool::grow`] spawns workers onto the *same* shared channel,
//!   so submission order — and therefore the FIFO earliest-unfinished
//!   invariant — is unchanged; more workers only means more jobs run
//!   concurrently.
//! * [`HashPool::retire`] never kills a worker mid-job. It publishes N
//!   retire tokens and N no-op wake jobs; each worker checks for a
//!   token only *after completing a job*, so a retiring worker drains
//!   its current job first, and a parked worker is woken by a no-op to
//!   observe the token. Exactly N workers exit (each token is consumed
//!   at most once), and the live count is clamped to >= 1, so there is
//!   always a worker to occupy the earliest unfinished job.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Retire tokens + live-worker target, shared with the worker threads.
/// Workers hold only this (and the receiver) — never the pool itself —
/// so the pool's drop (which joins the workers) can actually run.
struct WorkerCtl {
    /// Outstanding drain-retire requests; a worker that wins a token
    /// (after finishing a job) exits.
    pending_retire: AtomicUsize,
    /// Intended live worker count — what [`HashPool::workers`] reports.
    target: AtomicUsize,
}

struct PoolShared {
    /// The pool's own submission end; `None` once shutdown began.
    tx: Mutex<Option<mpsc::Sender<Job>>>,
    /// Shared FIFO all workers dequeue from (lock held only for the
    /// dequeue, never while a job runs).
    rx: Arc<Mutex<mpsc::Receiver<Job>>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    ctl: Arc<WorkerCtl>,
    next_id: AtomicUsize,
}

/// A worker pool for checksum jobs, resizable at run time by the
/// adaptive controller. Cloning shares the pool; when the last clone
/// drops (after all outstanding [`PoolHandle`]s are gone) the workers
/// drain the queue and are joined.
#[derive(Clone)]
pub struct HashPool {
    inner: Arc<PoolShared>,
}

fn spawn_worker(
    id: usize,
    rx: Arc<Mutex<mpsc::Receiver<Job>>>,
    ctl: Arc<WorkerCtl>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("fiver-hash-{id}"))
        .spawn(move || loop {
            // Hold the lock only for the dequeue, not the job.
            let job = { rx.lock().unwrap().recv() };
            match job {
                Ok(job) => {
                    job();
                    // Drain-retire: only ever exit *between* jobs.
                    let won_token = ctl
                        .pending_retire
                        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| n.checked_sub(1))
                        .is_ok();
                    if won_token {
                        break;
                    }
                }
                Err(_) => break,
            }
        })
        .expect("spawn hash worker")
}

impl HashPool {
    /// Spawn `workers` hash threads (clamped to at least 1).
    pub fn new(workers: usize) -> HashPool {
        let n = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let ctl = Arc::new(WorkerCtl {
            pending_retire: AtomicUsize::new(0),
            target: AtomicUsize::new(n),
        });
        let handles = (0..n).map(|i| spawn_worker(i, rx.clone(), ctl.clone())).collect();
        HashPool {
            inner: Arc::new(PoolShared {
                tx: Mutex::new(Some(tx)),
                rx,
                workers: Mutex::new(handles),
                ctl,
                next_id: AtomicUsize::new(n),
            }),
        }
    }

    /// A submit handle for sessions. All handles must drop before the
    /// last pool clone's `Drop` can join its workers.
    pub fn handle(&self) -> PoolHandle {
        let tx = self.inner.tx.lock().unwrap();
        PoolHandle { tx: tx.as_ref().expect("pool already shut down").clone() }
    }

    /// Live worker count (the retire target; a drain-retiring worker
    /// still finishing its last job is already excluded).
    pub fn workers(&self) -> usize {
        self.inner.ctl.target.load(Ordering::SeqCst)
    }

    /// Add `n` workers on the shared FIFO. Safe at any time: new
    /// workers only change how many queued jobs run concurrently, not
    /// their order.
    pub fn grow(&self, n: usize) {
        if n == 0 {
            return;
        }
        let mut workers = self.inner.workers.lock().unwrap();
        for _ in 0..n {
            let id = self.inner.next_id.fetch_add(1, Ordering::SeqCst);
            workers.push(spawn_worker(id, self.inner.rx.clone(), self.inner.ctl.clone()));
        }
        self.inner.ctl.target.fetch_add(n, Ordering::SeqCst);
    }

    /// Retire up to `n` workers by drain: each exits only after
    /// completing a job, and the pool never shrinks below one worker.
    /// Returns how many retirements were actually issued.
    pub fn retire(&self, n: usize) -> usize {
        let mut eff = 0;
        let _ = self.inner.ctl.target.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |t| {
            eff = n.min(t.saturating_sub(1));
            Some(t - eff)
        });
        if eff == 0 {
            return 0;
        }
        self.inner.ctl.pending_retire.fetch_add(eff, Ordering::SeqCst);
        // No-op wake jobs so parked workers observe their tokens; if a
        // busy worker consumes the token first, the no-op is harmless.
        let tx = self.inner.tx.lock().unwrap();
        if let Some(tx) = tx.as_ref() {
            for _ in 0..eff {
                let _ = tx.send(Box::new(|| {}));
            }
        }
        eff
    }
}

impl Drop for PoolShared {
    fn drop(&mut self) {
        self.tx.get_mut().unwrap().take(); // close the channel; workers drain then exit
        for w in self.workers.get_mut().unwrap().drain(..) {
            w.join().expect("hash worker panicked");
        }
    }
}

/// Cloneable submission handle onto a [`HashPool`].
#[derive(Clone)]
pub struct PoolHandle {
    tx: mpsc::Sender<Job>,
}

impl PoolHandle {
    /// Enqueue a job. FIFO across all submitters.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.send(Box::new(job)).expect("hash pool shut down with sessions active");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = HashPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let h = pool.handle();
        for _ in 0..100 {
            let c = counter.clone();
            h.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(h);
        drop(pool); // joins workers after the queue drains
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_of_one_still_progresses_under_queue_backpressure() {
        use crate::coordinator::queue::ByteQueue;
        // One worker, one open queue fed by this thread through a tiny
        // capacity: the deadlock-freedom argument in the module docs.
        let pool = HashPool::new(1);
        let q = ByteQueue::new(64);
        let q2 = q.clone();
        let total = Arc::new(AtomicUsize::new(0));
        let total2 = total.clone();
        pool.handle().submit(move || {
            while let Some(buf) = q2.remove() {
                total2.fetch_add(buf.len(), Ordering::SeqCst);
            }
        });
        for _ in 0..64 {
            assert!(q.add(vec![0u8; 48])); // blocks unless the job drains
        }
        q.close();
        drop(pool);
        assert_eq!(total.load(Ordering::SeqCst), 64 * 48);
    }

    #[test]
    fn clamps_to_one_worker() {
        let pool = HashPool::new(0);
        assert_eq!(pool.workers(), 1);
    }

    #[test]
    fn grow_unblocks_a_saturated_pool() {
        // One worker wedged on a gate job: a second job cannot run until
        // grow() adds a worker sharing the same FIFO.
        let pool = HashPool::new(1);
        let q = crate::coordinator::queue::ByteQueue::new(64);
        let q2 = q.clone();
        pool.handle().submit(move || while q2.remove().is_some() {});
        let ran = Arc::new(AtomicUsize::new(0));
        let ran2 = ran.clone();
        pool.handle().submit(move || {
            ran2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(pool.workers(), 1);
        pool.grow(1);
        assert_eq!(pool.workers(), 2);
        // The new worker picks up the queued job while the first stays
        // wedged on the open queue.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while ran.load(Ordering::SeqCst) == 0 {
            assert!(std::time::Instant::now() < deadline, "grown worker never ran the job");
            std::thread::yield_now();
        }
        q.close();
    }

    #[test]
    fn retire_drains_and_never_kills_mid_job() {
        // Three workers, a long FIFO of jobs, a retire(2) issued while
        // they run: every job still executes exactly once (drain
        // semantics — no job is lost with its worker) and the pool
        // settles at one worker.
        let pool = HashPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let h = pool.handle();
        for _ in 0..200 {
            let c = counter.clone();
            h.submit(move || {
                std::thread::sleep(std::time::Duration::from_micros(50));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(pool.retire(2), 2);
        assert_eq!(pool.workers(), 1);
        drop(h);
        let pool2 = pool.clone();
        drop(pool); // pool2 still holds the shared state
        // More work after the retire still runs on the surviving worker.
        let c = counter.clone();
        pool2.handle().submit(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        drop(pool2); // joins: every submitted job ran
        assert_eq!(counter.load(Ordering::SeqCst), 201);
    }

    #[test]
    fn retire_clamps_to_one_worker() {
        let pool = HashPool::new(2);
        assert_eq!(pool.retire(10), 1, "only one retirement available above the floor");
        assert_eq!(pool.workers(), 1);
        assert_eq!(pool.retire(1), 0, "floor of one worker holds");
        assert_eq!(pool.workers(), 1);
        // The floor worker still serves jobs.
        let ran = Arc::new(AtomicUsize::new(0));
        let r = ran.clone();
        pool.handle().submit(move || {
            r.fetch_add(1, Ordering::SeqCst);
        });
        drop(pool);
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }
}
