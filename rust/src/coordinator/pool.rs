//! The shared hash worker pool — checksum compute decoupled from
//! per-session threads.
//!
//! The original coordinator spawned one hash thread per queue-mode file,
//! so a 1000-file dataset paid 1000 thread spawns per endpoint and a
//! single slow hash could not borrow an idle core. The engine instead
//! owns one [`HashPool`] per endpoint: sessions submit one job per
//! queue-mode file (drain that file's [`super::queue::ByteQueue`] into a
//! digest or digest tree), and a fixed set of workers executes them.
//! FIVER's per-file queue sharing is untouched — the queue is still the
//! rendezvous between the transfer thread and the checksum computation;
//! only *who runs* the computation changed.
//!
//! Deadlock-freedom (any pool size >= 1): jobs run FIFO, so the earliest
//! *unfinished* job is always occupying a worker. On the sender a session
//! streams one file at a time, so that job's queue is either closed
//! (finite drain) or the very queue its session thread is feeding —
//! mutual progress through the queue's back-pressure. On the receiver,
//! stripe skew can hold several files open per session, so the frame
//! merger never blocks on a full queue mid-stream (it spills —
//! [`super::queue::ByteQueue::try_add`]); its only blocking adds happen
//! after end-of-stream, oldest file first, and the earliest unfinished
//! job is exactly some session's oldest open file.

use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size worker pool for checksum jobs. Dropping the pool joins
/// the workers after all outstanding [`PoolHandle`]s are gone.
pub struct HashPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl HashPool {
    /// Spawn `workers` hash threads (clamped to at least 1).
    pub fn new(workers: usize) -> HashPool {
        let n = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("fiver-hash-{i}"))
                    .spawn(move || loop {
                        // Hold the lock only for the dequeue, not the job.
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn hash worker")
            })
            .collect();
        HashPool { tx: Some(tx), workers }
    }

    /// A submit handle for sessions. All handles must drop before the
    /// pool's `Drop` can join its workers.
    pub fn handle(&self) -> PoolHandle {
        PoolHandle { tx: self.tx.as_ref().expect("pool already shut down").clone() }
    }

    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for HashPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers drain then exit
        for w in self.workers.drain(..) {
            w.join().expect("hash worker panicked");
        }
    }
}

/// Cloneable submission handle onto a [`HashPool`].
#[derive(Clone)]
pub struct PoolHandle {
    tx: mpsc::Sender<Job>,
}

impl PoolHandle {
    /// Enqueue a job. FIFO across all submitters.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.send(Box::new(job)).expect("hash pool shut down with sessions active");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = HashPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let h = pool.handle();
        for _ in 0..100 {
            let c = counter.clone();
            h.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(h);
        drop(pool); // joins workers after the queue drains
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_of_one_still_progresses_under_queue_backpressure() {
        use crate::coordinator::queue::ByteQueue;
        // One worker, one open queue fed by this thread through a tiny
        // capacity: the deadlock-freedom argument in the module docs.
        let pool = HashPool::new(1);
        let q = ByteQueue::new(64);
        let q2 = q.clone();
        let total = Arc::new(AtomicUsize::new(0));
        let total2 = total.clone();
        pool.handle().submit(move || {
            while let Some(buf) = q2.remove() {
                total2.fetch_add(buf.len(), Ordering::SeqCst);
            }
        });
        for _ in 0..64 {
            assert!(q.add(vec![0u8; 48])); // blocks unless the job drains
        }
        q.close();
        drop(pool);
        assert_eq!(total.load(Ordering::SeqCst), 64 * 48);
    }

    #[test]
    fn clamps_to_one_worker() {
        let pool = HashPool::new(0);
        assert_eq!(pool.workers(), 1);
    }
}
