//! The observability plane: always-on, allocation-free-in-steady-state
//! tracing and metrics for the real engine and the sim testbed.
//!
//! The paper's entire argument is a *timing* claim — FIVER wins because
//! checksum and transfer overlap (Eq. 1) — but end-of-run aggregates
//! (`TransferReport`) can't show *where* a run's time went: hash pool,
//! `ByteQueue`, socket, or storage. This module records that signal
//! without perturbing it:
//!
//! * A [`Recorder`] is created per endpoint (enabled by `FIVER_TRACE=1`
//!   or the `--trace-out`/`--metrics-json`/`--progress` flags, disabled
//!   otherwise at near-zero cost) and handed out as cheap [`Shard`]
//!   handles, one per session / hash worker / role — the "per-thread"
//!   in the design. All allocation happens at shard creation (the span
//!   ring is pre-allocated); the record path is atomics plus a
//!   `try_lock` ring push and never allocates or blocks.
//! * Every stage of the pipeline gets [`Stage`] spans and fixed-bucket
//!   log2 latency histograms ([`Hist`]), sharded per worker and merged
//!   at report time into p50/p95/p99 percentiles per stage.
//! * Per-stage cumulative busy time feeds [`attribute`] — the per-stage
//!   analogue of Eq. 1's `max(t_chksum, t_transfer)` — labeling a run
//!   `hash-bound` / `read-bound` / `write-bound` / `net-bound` with a
//!   confidence ratio (busiest group over the runner-up).
//! * Spans export as Chrome/Perfetto `trace_event` JSON
//!   ([`Recorder::write_chrome_trace`], one track per shard), merged
//!   histograms as JSON ([`Recorder::metrics_json`]), and a live
//!   throughput + pool-occupancy line renders via [`Progress`].
//!
//! Why recording must never block: hash jobs run on the shared FIFO
//! [`crate::coordinator::pool::HashPool`], whose deadlock-freedom
//! argument requires every submitted job to make progress. A recorder
//! that blocked a hash job on a contended lock would couple the hash
//! pool to the observer. So the ring push is `try_lock`: a contended
//! record is *dropped and counted* ([`Recorder::dropped`]) instead of
//! waited for, and the histogram/busy-time path is purely atomic.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::HitTrace;

/// Pipeline stages a span can belong to. The indices are stable (used
/// as array offsets in shards and reports).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Source storage read (any backend).
    Read,
    /// Checksum compute (hash pool job, per drained buffer).
    Hash,
    /// Blocked inserting into the fixed-size `ByteQueue` (backpressure
    /// from a slow checksum consumer) or draining spill into it.
    QueueWait,
    /// Socket write of a data/fix frame (includes blocking on the
    /// kernel buffer — a throttled link surfaces here).
    Send,
    /// Socket read of a frame on the receiver.
    Recv,
    /// Destination storage write (any backend).
    Write,
    /// Digest/root exchange and verdict handling.
    Verify,
    /// Checkpoint-journal feeding and sync.
    Journal,
    /// Re-read + Fix retransmission of a failed unit.
    Repair,
    /// io_uring SQE batch submission (`io_uring_enter`); the queue-depth
    /// gauge on this stage records the batch size.
    Submit,
    /// io_uring completion-queue drain for a submitted batch.
    Complete,
    /// Merkle interior/root folding over finished leaf digests — the
    /// cryptographic-tier cost under tiered hashing, split from leaf
    /// [`Stage::Hash`] so reports show each tier's share.
    TreeHash,
}

impl Stage {
    /// Number of stages.
    pub const COUNT: usize = 12;
    /// All stages, in pipeline order.
    pub const ALL: [Stage; Stage::COUNT] = [
        Stage::Read,
        Stage::Hash,
        Stage::QueueWait,
        Stage::Send,
        Stage::Recv,
        Stage::Write,
        Stage::Verify,
        Stage::Journal,
        Stage::Repair,
        Stage::Submit,
        Stage::Complete,
        Stage::TreeHash,
    ];

    /// Short stage label used in traces and reports.
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Read => "read",
            Stage::Hash => "hash",
            Stage::QueueWait => "queue_wait",
            Stage::Send => "send",
            Stage::Recv => "recv",
            Stage::Write => "write",
            Stage::Verify => "verify",
            Stage::Journal => "journal",
            Stage::Repair => "repair",
            Stage::Submit => "submit",
            Stage::Complete => "complete",
            Stage::TreeHash => "tree_hash",
        }
    }

    fn index(&self) -> usize {
        *self as usize
    }
}

/// One completed span: stage, start offset from the recorder epoch, and
/// duration, both in nanoseconds (virtual nanoseconds in the sim).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    /// Stage this span measures.
    pub stage: Stage,
    /// Start timestamp in ns since the recorder epoch.
    pub t0_ns: u64,
    /// Span duration in ns.
    pub dur_ns: u64,
}

/// Fixed-capacity wrapping span ring. The buffer is pre-allocated at
/// shard creation; once full, new events overwrite the oldest, so the
/// steady state never allocates.
struct SpanRing {
    buf: Vec<SpanEvent>,
    cap: usize,
    /// Overwrite cursor, meaningful once `buf.len() == cap`: points at
    /// the oldest event.
    next: usize,
}

impl SpanRing {
    fn new(cap: usize) -> SpanRing {
        SpanRing { buf: Vec::with_capacity(cap.max(1)), cap: cap.max(1), next: 0 }
    }

    fn push(&mut self, ev: SpanEvent) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.next] = ev;
            self.next = (self.next + 1) % self.cap;
        }
    }

    /// Oldest-first snapshot.
    fn snapshot(&self) -> Vec<SpanEvent> {
        if self.buf.len() < self.cap {
            return self.buf.clone();
        }
        let mut out = Vec::with_capacity(self.cap);
        out.extend_from_slice(&self.buf[self.next..]);
        out.extend_from_slice(&self.buf[..self.next]);
        out
    }
}

/// Number of log2 buckets: bucket 0 holds the value 0, bucket `b >= 1`
/// holds values in `[2^(b-1), 2^b)`; values past bucket 62 clamp into
/// the last bucket.
pub const HIST_BUCKETS: usize = 64;

fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }
}

/// Representative value of a bucket (geometric-ish midpoint), used when
/// reading percentiles back out of the log2 grid.
fn bucket_value(b: usize) -> u64 {
    if b == 0 {
        0
    } else {
        let lo = 1u64 << (b - 1);
        lo + lo / 2
    }
}

/// A fixed-bucket log2 histogram with atomic counters — concurrent
/// `record` from any thread, no locks, no allocation.
pub struct Hist {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
}

impl Hist {
    /// An empty histogram.
    pub fn new() -> Hist {
        Hist { buckets: std::array::from_fn(|_| AtomicU64::new(0)), sum: AtomicU64::new(0) }
    }

    /// Record one value.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// A point-in-time copy of the buckets.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut s = HistSnapshot::default();
        for (i, b) in self.buckets.iter().enumerate() {
            s.buckets[i] = b.load(Ordering::Relaxed);
        }
        s.sum = self.sum.load(Ordering::Relaxed);
        s
    }
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

/// An owned, mergeable histogram snapshot — the report-time currency.
/// Merging shards is elementwise bucket addition, so N sharded
/// histograms merged are bit-identical to one histogram that saw every
/// sample (the shard-merge property test pins this).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Per-bucket counts (log-spaced bounds).
    pub buckets: [u64; HIST_BUCKETS],
    /// Sum of all recorded values.
    pub sum: u64,
}

impl Default for HistSnapshot {
    fn default() -> HistSnapshot {
        HistSnapshot { buckets: [0; HIST_BUCKETS], sum: 0 }
    }
}

impl HistSnapshot {
    /// Fold `other` into this snapshot.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.sum += other.sum;
    }

    /// Total recorded values.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Value at percentile `p` (0..=100), as the representative value of
    /// the bucket containing that rank. 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_value(i);
            }
        }
        bucket_value(HIST_BUCKETS - 1)
    }
}

/// Merged per-stage statistics, carried on `TransferReport` /
/// `RunSummary` and printed on the CLI `data plane:` lines. Sim-side
/// summaries fill only `stage` and `busy_secs` (the fluid model has no
/// per-op latencies).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageStats {
    /// Stage name.
    pub stage: String,
    /// Recorded spans for this stage (0 in the sim).
    pub count: u64,
    /// Cumulative busy seconds across all shards.
    pub busy_secs: f64,
    /// Latency percentiles in microseconds (0 in the sim).
    pub p50_us: f64,
    /// 95th-percentile duration in microseconds.
    pub p95_us: f64,
    /// 99th-percentile duration in microseconds.
    pub p99_us: f64,
}

/// Merged observability snapshot for one run.
#[derive(Debug, Clone, Default)]
pub struct ObsReport {
    /// Stages that recorded anything, in [`Stage::ALL`] order.
    pub stages: Vec<StageStats>,
    /// `hash-bound` / `read-bound` / `write-bound` / `net-bound`, or
    /// empty when nothing was recorded.
    pub bottleneck: String,
    /// Busiest stage group over the runner-up (>= 1; higher = more
    /// clear-cut). [`f64::INFINITY`] when no other group recorded
    /// anything — rendered as `sole` on the CLI and `null` in JSON.
    pub confidence: f64,
    /// Span events dropped because a recorder found its ring contended
    /// (recording never blocks).
    pub dropped_events: u64,
}

/// Per-stage busy-time decomposition: label the run by its busiest
/// stage *group* — the per-stage analogue of Eq. 1's
/// `max(t_chksum, t_transfer)`. `groups` maps a label stem ("hash") to
/// cumulative busy seconds; returns `("hash-bound", confidence)` where
/// confidence = busiest / runner-up, or `("", 0.0)` when nothing was
/// busy.
///
/// When no runner-up group recorded anything the ratio is undefined and
/// the confidence is [`f64::INFINITY`] — renderers treat it as null
/// (`"confidence":null` in JSON, `sole` on the CLI) rather than a
/// numeric ratio. Equal-busy groups tie-break deterministically by
/// group name (lexicographically smallest wins), independent of slice
/// order.
pub fn attribute(groups: &[(&str, f64)]) -> (String, f64) {
    let mut best: Option<(usize, f64)> = None;
    let mut second = 0.0f64;
    for (i, &(name, v)) in groups.iter().enumerate() {
        let wins = match best {
            None => true,
            Some((bi, bv)) => v > bv || (v == bv && name < groups[bi].0),
        };
        if wins {
            if let Some((_, bv)) = best {
                second = second.max(bv);
            }
            best = Some((i, v));
        } else {
            second = second.max(v);
        }
    }
    match best {
        Some((i, v)) if v > 0.0 => {
            let confidence = if second > 0.0 { v / second } else { f64::INFINITY };
            (format!("{}-bound", groups[i].0), confidence)
        }
        _ => (String::new(), 0.0),
    }
}

/// Group per-stage busy nanoseconds into the four bottleneck
/// candidates: queue_wait is backpressure from a slow checksum consumer
/// (hash), journal rides the destination write path; verify/repair are
/// control-plane and excluded. Submit/Complete are excluded too: they
/// are sub-spans of the io_uring engine's Read/Write work, which the
/// calling stream already records under Read/Write — counting them here
/// would double-bill the storage time. They still appear in the
/// per-stage percentiles, with the Submit depth gauge carrying the SQE
/// batch size.
fn busy_groups(busy: &[u64; Stage::COUNT]) -> [(&'static str, f64); 4] {
    let secs = |st: Stage| busy[st.index()] as f64 / 1e9;
    [
        ("read", secs(Stage::Read)),
        ("hash", secs(Stage::Hash) + secs(Stage::QueueWait) + secs(Stage::TreeHash)),
        ("write", secs(Stage::Write) + secs(Stage::Journal)),
        ("net", secs(Stage::Send) + secs(Stage::Recv)),
    ]
}

struct ShardInner {
    label: String,
    tid: u64,
    epoch: Instant,
    ring: Mutex<SpanRing>,
    dropped: AtomicU64,
    stage_busy_ns: [AtomicU64; Stage::COUNT],
    stage_hist: [Hist; Stage::COUNT],
    depth_hist: Hist,
    bytes: AtomicU64,
}

/// A per-worker recording handle. Cloning shares the shard; a disabled
/// shard (from a disabled [`Recorder`]) no-ops at the cost of one
/// `Option` check per call.
#[derive(Clone)]
pub struct Shard {
    inner: Option<Arc<ShardInner>>,
}

impl Shard {
    /// The always-no-op shard.
    pub fn disabled() -> Shard {
        Shard { inner: None }
    }

    /// Whether this shard records anything.
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Start a span: `None` (and no clock read) when disabled.
    #[inline]
    pub fn start(&self) -> Option<Instant> {
        self.inner.as_ref().map(|_| Instant::now())
    }

    /// Finish a span started with [`Shard::start`]. Never allocates,
    /// never blocks: histogram/busy-time updates are atomic and the
    /// ring push is `try_lock` (contended pushes are drop-counted).
    #[inline]
    pub fn record(&self, stage: Stage, t0: Option<Instant>) {
        if let (Some(inner), Some(t0)) = (&self.inner, t0) {
            let dur_ns = t0.elapsed().as_nanos() as u64;
            let t0_ns = t0.saturating_duration_since(inner.epoch).as_nanos() as u64;
            record_inner(inner, stage, t0_ns, dur_ns);
        }
    }

    /// Record a span with explicit timestamps (the sim's virtual-time
    /// path and tests).
    pub fn record_ns(&self, stage: Stage, t0_ns: u64, dur_ns: u64) {
        if let Some(inner) = &self.inner {
            record_inner(inner, stage, t0_ns, dur_ns);
        }
    }

    /// Record an instantaneous queue-depth observation.
    #[inline]
    pub fn gauge_depth(&self, depth: u64) {
        if let Some(inner) = &self.inner {
            inner.depth_hist.record(depth);
        }
    }

    /// Account payload bytes moved (feeds the live progress view).
    #[inline]
    pub fn add_bytes(&self, n: u64) {
        if let Some(inner) = &self.inner {
            inner.bytes.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Oldest-first snapshot of the span ring.
    pub fn spans(&self) -> Vec<SpanEvent> {
        match &self.inner {
            Some(inner) => inner.ring.lock().unwrap().snapshot(),
            None => Vec::new(),
        }
    }

    /// Span events dropped on contended ring pushes.
    pub fn dropped(&self) -> u64 {
        self.inner.as_ref().map_or(0, |i| i.dropped.load(Ordering::Relaxed))
    }
}

fn record_inner(inner: &ShardInner, stage: Stage, t0_ns: u64, dur_ns: u64) {
    let i = stage.index();
    inner.stage_busy_ns[i].fetch_add(dur_ns, Ordering::Relaxed);
    inner.stage_hist[i].record(dur_ns);
    match inner.ring.try_lock() {
        Ok(mut ring) => ring.push(SpanEvent { stage, t0_ns, dur_ns }),
        Err(_) => {
            inner.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Pool-occupancy gauge: `(in_flight, capacity)`.
type PoolGauge = Box<dyn Fn() -> (usize, usize) + Send + Sync>;

struct RecorderInner {
    epoch: Instant,
    ring_capacity: usize,
    shards: Mutex<Vec<Arc<ShardInner>>>,
    next_tid: AtomicU64,
    gauges: Mutex<Vec<PoolGauge>>,
}

/// Default per-shard span-ring capacity. Spans past it wrap (oldest
/// overwritten); histograms and busy time keep counting everything.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// The per-endpoint recorder: owns the shard registry and the report /
/// export surface. Cloning shares the recorder (it rides along on
/// `SessionConfig`). A disabled recorder hands out disabled shards and
/// costs one `Option` check per recording call.
#[derive(Clone)]
pub struct Recorder {
    inner: Option<Arc<RecorderInner>>,
}

impl Recorder {
    /// A recorder that drops everything at near-zero cost.
    pub fn disabled() -> Recorder {
        Recorder { inner: None }
    }

    /// A recorder that captures spans and counters.
    pub fn enabled() -> Recorder {
        Recorder::with_capacity(DEFAULT_RING_CAPACITY)
    }

    /// Enabled recorder with an explicit per-shard span-ring capacity.
    pub fn with_capacity(ring_capacity: usize) -> Recorder {
        Recorder {
            inner: Some(Arc::new(RecorderInner {
                epoch: Instant::now(),
                ring_capacity: ring_capacity.max(1),
                shards: Mutex::new(Vec::new()),
                next_tid: AtomicU64::new(1),
                gauges: Mutex::new(Vec::new()),
            })),
        }
    }

    /// Enabled when `FIVER_TRACE` is `1`/`true`, disabled otherwise.
    pub fn from_env() -> Recorder {
        match std::env::var("FIVER_TRACE") {
            Ok(v) if v == "1" || v.eq_ignore_ascii_case("true") => Recorder::enabled(),
            _ => Recorder::disabled(),
        }
    }

    /// Whether recording is on.
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Create (and register) a shard for one worker/role. This is the
    /// cold path: the span ring and label are allocated here, once per
    /// session/worker/file — never per chunk.
    pub fn shard(&self, label: &str) -> Shard {
        let Some(inner) = &self.inner else { return Shard::disabled() };
        let shard = Arc::new(ShardInner {
            label: label.to_string(),
            tid: inner.next_tid.fetch_add(1, Ordering::Relaxed),
            epoch: inner.epoch,
            ring: Mutex::new(SpanRing::new(inner.ring_capacity)),
            dropped: AtomicU64::new(0),
            stage_busy_ns: std::array::from_fn(|_| AtomicU64::new(0)),
            stage_hist: std::array::from_fn(|_| Hist::new()),
            depth_hist: Hist::new(),
            bytes: AtomicU64::new(0),
        });
        inner.shards.lock().unwrap().push(shard.clone());
        Shard { inner: Some(shard) }
    }

    /// Register a pool-occupancy gauge for the progress view.
    pub fn register_pool_gauge(
        &self,
        gauge: impl Fn() -> (usize, usize) + Send + Sync + 'static,
    ) {
        if let Some(inner) = &self.inner {
            inner.gauges.lock().unwrap().push(Box::new(gauge));
        }
    }

    /// Summed pool occupancy across registered gauges.
    pub fn pool_occupancy(&self) -> (usize, usize) {
        let Some(inner) = &self.inner else { return (0, 0) };
        let gauges = inner.gauges.lock().unwrap();
        gauges.iter().fold((0, 0), |(fi, fc), g| {
            let (i, c) = g();
            (fi + i, fc + c)
        })
    }

    /// Total payload bytes accounted across shards.
    pub fn total_bytes(&self) -> u64 {
        self.for_shards(0, |acc, s| acc + s.bytes.load(Ordering::Relaxed))
    }

    /// Span events dropped across shards (contended ring pushes).
    pub fn dropped(&self) -> u64 {
        self.for_shards(0, |acc, s| acc + s.dropped.load(Ordering::Relaxed))
    }

    fn for_shards<T>(&self, init: T, f: impl Fn(T, &ShardInner) -> T) -> T {
        match &self.inner {
            Some(inner) => inner.shards.lock().unwrap().iter().fold(init, |a, s| f(a, s)),
            None => init,
        }
    }

    /// Merged per-stage histogram snapshots, in [`Stage::ALL`] order.
    fn merged_hists(&self) -> ([HistSnapshot; Stage::COUNT], [u64; Stage::COUNT], HistSnapshot) {
        let mut hists: [HistSnapshot; Stage::COUNT] = Default::default();
        let mut busy = [0u64; Stage::COUNT];
        let mut depth = HistSnapshot::default();
        if let Some(inner) = &self.inner {
            for s in inner.shards.lock().unwrap().iter() {
                for st in Stage::ALL {
                    let i = st.index();
                    hists[i].merge(&s.stage_hist[i].snapshot());
                    busy[i] += s.stage_busy_ns[i].load(Ordering::Relaxed);
                }
                depth.merge(&s.depth_hist.snapshot());
            }
        }
        (hists, busy, depth)
    }

    /// Merge every shard into per-stage stats + a bottleneck label.
    pub fn report(&self) -> ObsReport {
        if self.inner.is_none() {
            return ObsReport::default();
        }
        let (hists, busy, _depth) = self.merged_hists();
        let mut stages = Vec::new();
        for st in Stage::ALL {
            let i = st.index();
            let count = hists[i].count();
            if count == 0 && busy[i] == 0 {
                continue;
            }
            stages.push(StageStats {
                stage: st.name().to_string(),
                count,
                busy_secs: busy[i] as f64 / 1e9,
                p50_us: hists[i].percentile(50.0) as f64 / 1e3,
                p95_us: hists[i].percentile(95.0) as f64 / 1e3,
                p99_us: hists[i].percentile(99.0) as f64 / 1e3,
            });
        }
        let groups = busy_groups(&busy);
        let (bottleneck, confidence) = attribute(&groups);
        ObsReport { stages, bottleneck, confidence, dropped_events: self.dropped() }
    }

    /// Cheap live per-group busy snapshot for the adaptive controller:
    /// sums the four attribution groups straight from the shards'
    /// atomic busy counters — no histogram merges, no per-call
    /// allocation beyond the fixed array. Values are cumulative; the
    /// controller diffs consecutive snapshots to get per-window ratios.
    pub fn stage_busy_snapshot(&self) -> [(&'static str, f64); 4] {
        let busy = self.for_shards([0u64; Stage::COUNT], |mut acc, s| {
            for st in Stage::ALL {
                acc[st.index()] += s.stage_busy_ns[st.index()].load(Ordering::Relaxed);
            }
            acc
        });
        busy_groups(&busy)
    }

    /// Write the span timeline as Chrome/Perfetto `trace_event` JSON:
    /// one complete-event (`"ph":"X"`) per span, one track (tid) per
    /// shard, thread names from the shard labels. Load the file at
    /// `chrome://tracing` or <https://ui.perfetto.dev>.
    pub fn write_chrome_trace<W: std::io::Write>(&self, w: &mut W) -> std::io::Result<()> {
        write!(w, "{{\"traceEvents\":[")?;
        let mut first = true;
        if let Some(inner) = &self.inner {
            let shards = inner.shards.lock().unwrap();
            for s in shards.iter() {
                if !first {
                    write!(w, ",")?;
                }
                first = false;
                write!(
                    w,
                    "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{},\
                     \"args\":{{\"name\":\"{}\"}}}}",
                    s.tid,
                    esc(&s.label)
                )?;
                for ev in s.ring.lock().unwrap().snapshot() {
                    write!(
                        w,
                        ",{{\"name\":\"{}\",\"cat\":\"stage\",\"ph\":\"X\",\"pid\":1,\
                         \"tid\":{},\"ts\":{:.3},\"dur\":{:.3}}}",
                        ev.stage.name(),
                        s.tid,
                        ev.t0_ns as f64 / 1e3,
                        ev.dur_ns as f64 / 1e3,
                    )?;
                }
            }
        }
        write!(w, "]}}")
    }

    /// Write the Chrome trace to a file path.
    pub fn write_chrome_trace_to(&self, path: &std::path::Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        self.write_chrome_trace(&mut f)?;
        std::io::Write::flush(&mut f)
    }

    /// Merged histograms + attribution as a JSON object.
    pub fn metrics_json(&self) -> String {
        let (hists, busy, depth) = self.merged_hists();
        let rep = self.report();
        let mut out = String::from("{\"stages\":[");
        let mut first = true;
        for st in Stage::ALL {
            let i = st.index();
            let count = hists[i].count();
            if count == 0 && busy[i] == 0 {
                continue;
            }
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "{{\"stage\":\"{}\",\"count\":{},\"busy_secs\":{:.6},\"sum_ns\":{},\
                 \"p50_us\":{:.3},\"p95_us\":{:.3},\"p99_us\":{:.3},\"buckets\":{}}}",
                st.name(),
                count,
                busy[i] as f64 / 1e9,
                hists[i].sum,
                hists[i].percentile(50.0) as f64 / 1e3,
                hists[i].percentile(95.0) as f64 / 1e3,
                hists[i].percentile(99.0) as f64 / 1e3,
                json_buckets(&hists[i]),
            ));
        }
        out.push_str(&format!(
            "],\"queue_depth\":{{\"count\":{},\"buckets\":{}}},\
             \"dropped\":{},\"bottleneck\":\"{}\",\"confidence\":{}}}",
            depth.count(),
            json_buckets(&depth),
            rep.dropped_events,
            esc(&rep.bottleneck),
            json_confidence(rep.confidence),
        ));
        out
    }
}

fn json_buckets(h: &HistSnapshot) -> String {
    // Sparse [bucket, count] pairs: 64 mostly-zero buckets per stage
    // would dominate the dump.
    let mut out = String::from("[");
    let mut first = true;
    for (i, &c) in h.buckets.iter().enumerate() {
        if c == 0 {
            continue;
        }
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!("[{i},{c}]"));
    }
    out.push(']');
    out
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Render an attribution confidence for JSON: a finite ratio as a
/// number, the [`f64::INFINITY`] "sole nonzero group" sentinel as
/// `null` (infinity is not representable in JSON).
pub fn json_confidence(c: f64) -> String {
    if c.is_finite() {
        format!("{c:.3}")
    } else {
        "null".to_string()
    }
}

/// Render an attribution confidence for the CLI: `"4.0x"` for a finite
/// ratio, `"sole"` when no other group recorded anything.
pub fn cli_confidence(c: f64) -> String {
    if c.is_finite() {
        format!("{c:.1}x")
    } else {
        "sole".to_string()
    }
}

/// Live progress line: a background thread samples the recorder's byte
/// counter ~4x/second and renders per-second throughput as a
/// [`HitTrace`] sparkline plus current pool occupancy to stderr. Drop
/// (or [`Progress::finish`]) stops it.
pub struct Progress {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

const PROGRESS_TICK: Duration = Duration::from_millis(250);

impl Progress {
    /// Spawn the progress-ticker thread (joined by `finish`/drop).
    pub fn start(rec: Recorder) -> Progress {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("fiver-progress".into())
            .spawn(move || {
                let mut trace = HitTrace::new(1.0);
                let mut last = rec.total_bytes();
                let mut peak = 1u64;
                let mut t = 0.0f64;
                while !stop2.load(Ordering::Relaxed) {
                    std::thread::sleep(PROGRESS_TICK);
                    let now = rec.total_bytes();
                    let delta = now.saturating_sub(last);
                    last = now;
                    peak = peak.max(delta);
                    // Throughput relative to the peak tick renders as the
                    // hit ratio of the tick's bucket.
                    trace.record(t, t, delta, peak.saturating_sub(delta));
                    t += PROGRESS_TICK.as_secs_f64();
                    let (in_flight, cap) = rec.pool_occupancy();
                    let mbps = delta as f64 / PROGRESS_TICK.as_secs_f64() / 1e6;
                    eprint!(
                        "\r{:>9.1} MB/s |{}| pool {}/{} in flight   ",
                        mbps,
                        trace.sparkline(30),
                        in_flight,
                        cap
                    );
                    let _ = std::io::Write::flush(&mut std::io::stderr());
                }
                eprintln!();
            })
            .expect("spawn progress thread");
        Progress { stop, handle: Some(handle) }
    }

    /// Stop and join the render thread (Drop does the same).
    pub fn finish(self) {}
}

impl Drop for Progress {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_indexing_covers_the_line() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        // Every bucket's representative value maps back into it (except
        // the clamped last bucket).
        for b in 0..HIST_BUCKETS - 1 {
            assert_eq!(bucket_of(bucket_value(b)), b, "bucket {b}");
        }
    }

    #[test]
    fn percentiles_walk_the_buckets() {
        let h = Hist::new();
        for v in [1u64, 1, 1, 1000, 1000, 1000, 1000, 1_000_000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 8);
        assert_eq!(s.sum, 1_004_003);
        assert_eq!(bucket_of(s.percentile(1.0)), bucket_of(1));
        assert_eq!(bucket_of(s.percentile(50.0)), bucket_of(1000));
        assert_eq!(bucket_of(s.percentile(99.0)), bucket_of(1_000_000));
        assert!(s.percentile(50.0) <= s.percentile(95.0));
        assert!(s.percentile(95.0) <= s.percentile(99.0));
        assert_eq!(HistSnapshot::default().percentile(50.0), 0);
    }

    #[test]
    fn ring_wraps_oldest_first() {
        let mut r = SpanRing::new(3);
        let ev = |n: u64| SpanEvent { stage: Stage::Read, t0_ns: n, dur_ns: 1 };
        r.push(ev(1));
        r.push(ev(2));
        assert_eq!(r.snapshot().iter().map(|e| e.t0_ns).collect::<Vec<_>>(), vec![1, 2]);
        r.push(ev(3));
        r.push(ev(4));
        r.push(ev(5));
        assert_eq!(r.snapshot().iter().map(|e| e.t0_ns).collect::<Vec<_>>(), vec![3, 4, 5]);
    }

    #[test]
    fn attribute_picks_the_busiest_group() {
        let (label, conf) =
            attribute(&[("read", 1.0), ("hash", 4.0), ("write", 2.0), ("net", 0.5)]);
        assert_eq!(label, "hash-bound");
        assert!((conf - 2.0).abs() < 1e-9, "{conf}");
        let (label, conf) = attribute(&[("read", 0.0), ("net", 3.0)]);
        assert_eq!(label, "net-bound");
        assert!(conf.is_infinite(), "no runner-up is the sole sentinel, got {conf}");
        assert_eq!(attribute(&[("read", 0.0), ("net", 0.0)]).0, "");
    }

    #[test]
    fn attribute_ties_break_by_name_not_order() {
        // Equal busy values: the lexicographically smallest name wins,
        // regardless of slice order, and the tie is confidence 1.0.
        let (label, conf) = attribute(&[("write", 2.0), ("hash", 2.0), ("read", 1.0)]);
        assert_eq!(label, "hash-bound");
        assert!((conf - 1.0).abs() < 1e-9, "{conf}");
        let (label, _) = attribute(&[("hash", 2.0), ("write", 2.0), ("read", 1.0)]);
        assert_eq!(label, "hash-bound", "order must not matter");
    }

    #[test]
    fn confidence_renderers_treat_infinity_as_null() {
        assert_eq!(json_confidence(2.5), "2.500");
        assert_eq!(json_confidence(f64::INFINITY), "null");
        assert_eq!(cli_confidence(4.0), "4.0x");
        assert_eq!(cli_confidence(f64::INFINITY), "sole");
    }

    #[test]
    fn disabled_shard_is_inert() {
        let s = Shard::disabled();
        assert!(!s.is_enabled());
        assert!(s.start().is_none());
        s.record(Stage::Hash, None);
        s.record_ns(Stage::Hash, 0, 100);
        s.gauge_depth(5);
        s.add_bytes(100);
        assert!(s.spans().is_empty());
        let rec = Recorder::disabled();
        assert!(!rec.is_enabled());
        assert!(!rec.shard("x").is_enabled());
        assert!(rec.report().stages.is_empty());
        assert_eq!(rec.total_bytes(), 0);
    }

    #[test]
    fn report_merges_shards_and_attributes() {
        let rec = Recorder::enabled();
        let a = rec.shard("worker-a");
        let b = rec.shard("worker-b");
        a.record_ns(Stage::Hash, 0, 3_000_000_000);
        b.record_ns(Stage::Hash, 0, 2_000_000_000);
        b.record_ns(Stage::Send, 0, 1_000_000_000);
        a.add_bytes(10);
        b.add_bytes(20);
        let rep = rec.report();
        assert_eq!(rep.bottleneck, "hash-bound");
        assert!((rep.confidence - 5.0).abs() < 1e-6, "{}", rep.confidence);
        let hash = rep.stages.iter().find(|s| s.stage == "hash").unwrap();
        assert_eq!(hash.count, 2);
        assert!((hash.busy_secs - 5.0).abs() < 1e-6);
        assert_eq!(rec.total_bytes(), 30);
        assert_eq!(rec.dropped(), 0);
    }

    #[test]
    fn chrome_trace_shape() {
        let rec = Recorder::enabled();
        let s = rec.shard("sess\"0\\");
        s.record_ns(Stage::Read, 1000, 500);
        s.record_ns(Stage::Send, 1500, 250);
        let mut buf = Vec::new();
        rec.write_chrome_trace(&mut buf).unwrap();
        let out = String::from_utf8(buf).unwrap();
        assert!(out.starts_with("{\"traceEvents\":["));
        assert!(out.ends_with("]}"));
        assert!(out.contains("\"ph\":\"X\""));
        assert!(out.contains("\"ph\":\"M\""));
        assert!(out.contains("sess\\\"0\\\\"), "label must be escaped: {out}");
        assert!(out.contains("\"name\":\"read\""));
    }

    #[test]
    fn metrics_json_shape() {
        let rec = Recorder::enabled();
        let s = rec.shard("w");
        s.record_ns(Stage::Write, 0, 42);
        s.gauge_depth(7);
        let j = rec.metrics_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"stage\":\"write\""));
        assert!(j.contains("\"queue_depth\""));
        assert!(j.contains("\"bottleneck\":\"write-bound\""));
        // Only one group recorded: the sole-group confidence renders as
        // JSON null, never as an unparseable "inf".
        assert!(j.contains("\"confidence\":null"), "{j}");
    }

    #[test]
    fn stage_busy_snapshot_matches_report_groups() {
        let rec = Recorder::enabled();
        let a = rec.shard("a");
        let b = rec.shard("b");
        a.record_ns(Stage::Hash, 0, 2_000_000_000);
        a.record_ns(Stage::QueueWait, 0, 1_000_000_000);
        b.record_ns(Stage::Send, 0, 500_000_000);
        b.record_ns(Stage::Write, 0, 250_000_000);
        let snap = rec.stage_busy_snapshot();
        let get = |n: &str| snap.iter().find(|(g, _)| *g == n).unwrap().1;
        assert!((get("hash") - 3.0).abs() < 1e-9, "hash folds queue_wait in");
        assert!((get("net") - 0.5).abs() < 1e-9);
        assert!((get("write") - 0.25).abs() < 1e-9);
        assert_eq!(get("read"), 0.0);
        // Disabled recorder: all zeros, no panic.
        assert!(Recorder::disabled().stage_busy_snapshot().iter().all(|(_, v)| *v == 0.0));
    }
}
