//! Tiny CLI argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.
//! Each binary declares its options by querying [`Args`].

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional (non-flag) arguments, in order.
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `value_opts` lists option names that consume a following value when
    /// written as `--name value`.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, value_opts: &[&str]) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if value_opts.contains(&body) {
                    match iter.next() {
                        Some(v) => {
                            out.options.insert(body.to_string(), v);
                        }
                        None => {
                            out.flags.push(body.to_string());
                        }
                    }
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env(value_opts: &[&str]) -> Args {
        Self::parse(std::env::args().skip(1), value_opts)
    }

    /// Whether `--name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--name <value>`, if present.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Like [`Args::opt`] with a default.
    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    /// An integer option with a default.
    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name).map(|v| v.parse().expect("integer option")).unwrap_or(default)
    }

    /// A float option with a default.
    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).map(|v| v.parse().expect("float option")).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], value_opts: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), value_opts)
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["run", "--verbose", "file.txt"], &[]);
        assert_eq!(a.positional, vec!["run", "file.txt"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn key_equals_value() {
        let a = parse(&["--size=100", "--name=x"], &[]);
        assert_eq!(a.opt("size"), Some("100"));
        assert_eq!(a.opt("name"), Some("x"));
    }

    #[test]
    fn key_space_value() {
        let a = parse(&["--size", "100", "pos"], &["size"]);
        assert_eq!(a.opt("size"), Some("100"));
        assert_eq!(a.positional, vec!["pos"]);
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["--n=5", "--x=1.5"], &[]);
        assert_eq!(a.opt_u64("n", 0), 5);
        assert_eq!(a.opt_f64("x", 0.0), 1.5);
        assert_eq!(a.opt_u64("missing", 9), 9);
        assert_eq!(a.opt_or("missing", "d"), "d");
    }
}
