//! Minimal recursive-descent JSON parser.
//!
//! Parses the artifact manifest (`artifacts/manifest.json`) and the
//! cross-language test vectors (`artifacts/test_vectors.json`). Supports the
//! full JSON grammar except `\u` surrogate pairs beyond the BMP (not needed
//! for our artifacts; lone escapes are decoded as the corresponding char).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (stored as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (keys kept sorted).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document; trailing non-whitespace is an error.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The number, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number as `u64`, if this is a non-negative integral `Num`.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= 2f64.powi(53) {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key/value map, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access that tolerates missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// Byte offset of the error in the input.
    pub offset: usize,
    /// What went wrong.
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-assemble multi-byte UTF-8 (input is a &str, so valid).
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\"A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\"A"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn rejects_unterminated() {
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("{\"a\": 1").is_err());
    }

    #[test]
    fn as_u64_guards() {
        assert_eq!(Json::parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(Json::parse("-7").unwrap().as_u64(), None);
        assert_eq!(Json::parse("7.5").unwrap().as_u64(), None);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
