//! In-tree utility substrates.
//!
//! The offline build environment ships no serde/rand/clap, so the small
//! pieces this crate needs are implemented here: a JSON parser for the
//! artifact manifest and cross-language test vectors ([`json`]), a
//! deterministic PRNG for workload generation and property tests ([`rng`]),
//! hex encoding ([`hex`]), human-readable byte/time formatting ([`fmt`]),
//! a tiny CLI argument parser ([`cli`]), and collision-free scratch
//! directories for parallel tests ([`tmpdir`]).

/// Tiny argv parser: flags and `--opt value` pairs.
pub mod cli;
/// Byte/rate/time formatting and aligned text tables.
pub mod fmt;
/// Hex encoding and decoding.
pub mod hex;
/// Minimal JSON value, parser and writer.
pub mod json;
/// Deterministic PRNGs (SplitMix64 and a 31-bit LCG).
pub mod rng;
/// Self-cleaning temporary directories.
pub mod tmpdir;
