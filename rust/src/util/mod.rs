//! In-tree utility substrates.
//!
//! The offline build environment ships no serde/rand/clap, so the small
//! pieces this crate needs are implemented here: a JSON parser for the
//! artifact manifest and cross-language test vectors ([`json`]), a
//! deterministic PRNG for workload generation and property tests ([`rng`]),
//! hex encoding ([`hex`]), human-readable byte/time formatting ([`fmt`]),
//! a tiny CLI argument parser ([`cli`]), and collision-free scratch
//! directories for parallel tests ([`tmpdir`]).

pub mod cli;
pub mod fmt;
pub mod hex;
pub mod json;
pub mod rng;
pub mod tmpdir;
