//! Hex encoding/decoding for digests.

/// Encode bytes as lowercase hex.
pub fn encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push(char::from_digit((b >> 4) as u32, 16).unwrap());
        s.push(char::from_digit((b & 0xf) as u32, 16).unwrap());
    }
    s
}

/// Decode a hex string; returns `None` on odd length or invalid digits.
pub fn decode(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    let digits: Vec<u8> = s
        .chars()
        .map(|c| c.to_digit(16).map(|d| d as u8))
        .collect::<Option<_>>()?;
    Some(digits.chunks(2).map(|p| (p[0] << 4) | p[1]).collect())
}

/// Encode a u32 word slice as the hex string convention used by FVR-256
/// (each word rendered as 8 big-endian hex digits, matching python's
/// `f"{w:08x}"`).
pub fn encode_words(words: &[u32]) -> String {
    words.iter().map(|w| format!("{w:08x}")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let data = [0x00u8, 0x01, 0xab, 0xff, 0x7f];
        assert_eq!(decode(&encode(&data)).unwrap(), data);
    }

    #[test]
    fn encode_known() {
        assert_eq!(encode(&[0xde, 0xad, 0xbe, 0xef]), "deadbeef");
    }

    #[test]
    fn decode_rejects_odd_length() {
        assert!(decode("abc").is_none());
    }

    #[test]
    fn decode_rejects_bad_digit() {
        assert!(decode("zz").is_none());
    }

    #[test]
    fn words_match_python_format() {
        assert_eq!(encode_words(&[0x1, 0xdeadbeef]), "00000001deadbeef");
    }
}
