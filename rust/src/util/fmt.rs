//! Human-readable formatting for sizes, rates and durations, plus a fixed
//! ASCII table printer used by the experiment harness to emit paper-style
//! rows.

/// Format a byte count with binary units ("1.5 GiB").
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if n < 1024 {
        return format!("{n} B");
    }
    let mut v = n as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if v >= 100.0 {
        format!("{v:.0} {}", UNITS[unit])
    } else {
        format!("{v:.1} {}", UNITS[unit])
    }
}

/// Format a throughput in bits/s with SI units ("40.0 Gbps").
pub fn rate_bps(bits_per_sec: f64) -> String {
    const UNITS: [&str; 5] = ["bps", "Kbps", "Mbps", "Gbps", "Tbps"];
    let mut v = bits_per_sec;
    let mut unit = 0;
    while v >= 1000.0 && unit < UNITS.len() - 1 {
        v /= 1000.0;
        unit += 1;
    }
    format!("{v:.1} {}", UNITS[unit])
}

/// Format seconds as "1h02m03.4s" / "2m03.4s" / "3.4s".
pub fn secs(t: f64) -> String {
    if !t.is_finite() {
        return format!("{t}");
    }
    let total = t.max(0.0);
    let h = (total / 3600.0) as u64;
    let m = ((total % 3600.0) / 60.0) as u64;
    let s = total % 60.0;
    if h > 0 {
        format!("{h}h{m:02}m{s:04.1}s")
    } else if m > 0 {
        format!("{m}m{s:04.1}s")
    } else {
        format!("{s:.1}s")
    }
}

/// Format a ratio as a percentage ("8.3%").
pub fn pct(r: f64) -> String {
    format!("{:.1}%", r * 100.0)
}

/// Fixed-width ASCII table builder for experiment output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (cell count must match the headers).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render the table with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", cell, w = widths[c]));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.headers);
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(10 * 1024 * 1024), "10.0 MiB");
        assert_eq!(bytes(8 * 1024 * 1024 * 1024), "8.0 GiB");
    }

    #[test]
    fn rate_units() {
        assert_eq!(rate_bps(1e9), "1.0 Gbps");
        assert_eq!(rate_bps(40e9), "40.0 Gbps");
        assert_eq!(rate_bps(999.0), "999.0 bps");
    }

    #[test]
    fn secs_formats() {
        assert_eq!(secs(3.42), "3.4s");
        assert_eq!(secs(123.4), "2m03.4s");
        assert_eq!(secs(3723.4), "1h02m03.4s");
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.083), "8.3%");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["alg", "time"]);
        t.row(&["FIVER".into(), "130s".into()]);
        t.row(&["Sequential".into(), "210s".into()]);
        let out = t.render();
        assert!(out.contains("| alg        | time |"));
        assert!(out.contains("| FIVER      | 130s |"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_row() {
        Table::new(&["a"]).row(&["x".into(), "y".into()]);
    }
}
