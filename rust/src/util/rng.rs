//! Deterministic PRNGs for workload generation, fault injection and
//! property tests (no `rand` crate offline).
//!
//! [`SplitMix64`] — tiny, seedable, passes BigCrush-level mixing for our
//! purposes; also used to derive independent sub-streams. [`Lcg31`] mirrors
//! the exact LCG used by `python/compile/aot.py` to generate the
//! cross-language test-vector byte streams.

/// splitmix64: one multiply-xor-shift chain per output.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// A generator seeded with `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Next 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)`. `bound` must be > 0.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Rejection sampling to avoid modulo bias.
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % bound;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Fill a byte buffer.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let w = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&w[..rem.len()]);
        }
    }

    /// Derive an independent child stream (for per-file content etc.).
    pub fn fork(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ 0xA5A5_A5A5_5A5A_5A5A)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

/// The 31-bit LCG used by `aot.py::lcg_bytes` (glibc-style constants).
/// `s = (s * 1103515245 + 12345) mod 2^31`, emitting `s & 0xFF` per byte.
#[derive(Debug, Clone)]
pub struct Lcg31 {
    state: u64,
}

impl Lcg31 {
    /// A generator seeded with `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next pseudo-random byte.
    pub fn next_byte(&mut self) -> u8 {
        self.state = (self.state.wrapping_mul(1103515245).wrapping_add(12345)) & 0x7FFF_FFFF;
        (self.state & 0xFF) as u8
    }

    /// The next `n` bytes.
    pub fn bytes(&mut self, n: usize) -> Vec<u8> {
        (0..n).map(|_| self.next_byte()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_seed_sensitivity() {
        assert_ne!(SplitMix64::new(1).next_u64(), SplitMix64::new(2).next_u64());
    }

    #[test]
    fn below_is_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = SplitMix64::new(8);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = SplitMix64::new(10);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        // Statistically certain at least one non-zero byte in the tail.
        assert!(buf[8..].iter().any(|&b| b != 0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(11);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn lcg_matches_python() {
        // python: s=0x12345678; s=(s*1103515245+12345)&0x7FFFFFFF -> s&0xFF
        let mut lcg = Lcg31::new(0x12345678);
        let first: Vec<u8> = lcg.bytes(4);
        // Computed with the python reference implementation.
        let mut s: u64 = 0x12345678;
        let mut expect = Vec::new();
        for _ in 0..4 {
            s = (s.wrapping_mul(1103515245).wrapping_add(12345)) & 0x7FFF_FFFF;
            expect.push((s & 0xFF) as u8);
        }
        assert_eq!(first, expect);
    }
}
