//! Unique scratch directories for tests, examples and the CLI demo.
//!
//! `cargo test` runs test functions in parallel threads and test binaries
//! in parallel processes; a directory keyed on the process id alone can
//! collide across threads of one binary, and a fixed name collides across
//! runs that did not clean up. Keying on (pid, per-process counter,
//! subsecond clock) makes every call unique with default test
//! parallelism.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique (not yet created) path under the system temp directory.
pub fn unique_dir(prefix: &str) -> PathBuf {
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.subsec_nanos())
        .unwrap_or(0);
    std::env::temp_dir().join(format!("{prefix}-{}-{n}-{nanos:x}", std::process::id()))
}

/// A scratch directory that removes itself (best-effort) on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a fresh unique directory.
    pub fn create(prefix: &str) -> std::io::Result<TempDir> {
        let path = unique_dir(prefix);
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    /// The directory's path.
    pub fn path(&self) -> &std::path::Path {
        &self.path
    }

    /// A path inside the directory.
    pub fn join(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.path).ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unique_across_calls() {
        let a = unique_dir("fiver-x");
        let b = unique_dir("fiver-x");
        assert_ne!(a, b);
    }

    #[test]
    fn tempdir_cleans_up() {
        let kept;
        {
            let d = TempDir::create("fiver-td").unwrap();
            kept = d.path().to_path_buf();
            std::fs::write(d.join("f"), b"x").unwrap();
            assert!(kept.exists());
        }
        assert!(!kept.exists(), "dropped TempDir removes its tree");
    }
}
