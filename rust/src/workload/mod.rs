//! Dataset generators: the uniform and mixed datasets of the paper's
//! evaluation (§IV), both as *virtual* descriptors for the simulator and
//! as *real* on-disk files for the real-mode coordinator.

use std::path::{Path, PathBuf};

use crate::config::{GB, MB};
use crate::util::rng::SplitMix64;

/// One file in a dataset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileSpec {
    /// Stable id (also the cache-model FileId).
    pub id: u64,
    /// File name, unique within the dataset.
    pub name: String,
    /// File size in bytes.
    pub size: u64,
}

/// A named dataset (ordered: transfer order matters for pipelining).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Dataset name used in reports.
    pub name: String,
    /// The files, in transfer order.
    pub files: Vec<FileSpec>,
}

impl Dataset {
    /// Sum of all file sizes.
    pub fn total_bytes(&self) -> u64 {
        self.files.iter().map(|f| f.size).sum()
    }

    /// Number of files.
    pub fn len(&self) -> usize {
        self.files.len()
    }

    /// Whether the dataset has no files.
    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }

    /// Uniform dataset: `count` files of `size` bytes (paper: "one or more
    /// files in same size", e.g. 1000x10M, 100x100M, 10x1G, 1x10G).
    pub fn uniform(name: &str, size: u64, count: usize) -> Dataset {
        let files = (0..count)
            .map(|i| FileSpec { id: i as u64, name: format!("{name}-{i:04}"), size })
            .collect();
        Dataset { name: name.to_string(), files }
    }

    /// From an explicit (count, size) spec list, shuffled with `seed`
    /// (paper: "files are shuffled before the transfer to guarantee
    /// randomness in the order").
    pub fn mixed_shuffled(name: &str, groups: &[(usize, u64)], seed: u64) -> Dataset {
        let mut files = Vec::new();
        for &(count, size) in groups {
            for _ in 0..count {
                files.push(size);
            }
        }
        let mut rng = SplitMix64::new(seed);
        rng.shuffle(&mut files);
        let files = files
            .into_iter()
            .enumerate()
            .map(|(i, size)| FileSpec { id: i as u64, name: format!("{name}-{i:04}"), size })
            .collect();
        Dataset { name: name.to_string(), files }
    }

    /// Sorted-5M250M (paper §IV): equal numbers of 5 MB and 250 MB files
    /// arranged so each 5 MB file is followed by a 250 MB file — the
    /// adversarial ordering for file- and block-level pipelining.
    pub fn sorted_5m250m(pairs: usize) -> Dataset {
        let mut files = Vec::new();
        for i in 0..pairs {
            files.push(FileSpec {
                id: (2 * i) as u64,
                name: format!("sorted-5m-{i:04}"),
                size: 5 * MB,
            });
            files.push(FileSpec {
                id: (2 * i + 1) as u64,
                name: format!("sorted-250m-{i:04}"),
                size: 250 * MB,
            });
        }
        Dataset { name: "Sorted-5M250M".to_string(), files }
    }

    /// The ESNet mixed dataset quoted verbatim in §IV: "100x10MB, 100x50MB,
    /// 50x250MB, 10x2GB, 4x8GB, 4x10GB, 1x15GB, and 2x20GB; in total of 271
    /// files with total size 165.5GB".
    pub fn esnet_mixed(seed: u64) -> Dataset {
        Dataset::mixed_shuffled(
            "Shuffled",
            &[
                (100, 10 * MB),
                (100, 50 * MB),
                (50, 250 * MB),
                (10, 2 * GB),
                (4, 8 * GB),
                (4, 10 * GB),
                (1, 15 * GB),
                (2, 20 * GB),
            ],
            seed,
        )
    }

    /// The HPCLab mixed dataset (§IV analysis of Fig 3b/4: "Shuffled
    /// dataset contains 10 MB and 500 MB files", and Fig 4's hit-ratio
    /// analysis adds "five 20GB files that are larger than free memory
    /// (16 GB)").
    pub fn hpclab_mixed(seed: u64) -> Dataset {
        Dataset::mixed_shuffled(
            "Shuffled",
            &[(100, 10 * MB), (100, 500 * MB), (5, 20 * GB)],
            seed,
        )
    }

    /// Table III fault-recovery dataset: "15 large files (10 of 1GB files
    /// and 5 of 10GB files)".
    pub fn table3_dataset() -> Dataset {
        let mut files: Vec<FileSpec> = (0..10)
            .map(|i| FileSpec { id: i, name: format!("t3-1g-{i:02}"), size: GB })
            .collect();
        for i in 0..5 {
            files.push(FileSpec {
                id: 10 + i,
                name: format!("t3-10g-{i:02}"),
                size: 10 * GB,
            });
        }
        Dataset { name: "Table3-15files".to_string(), files }
    }

    /// Aggregation plan for the parallel engine: see [`plan_batches`].
    pub fn batches(&self, batch_threshold: u64, batch_bytes: u64) -> Vec<Vec<usize>> {
        let sizes: Vec<u64> = self.files.iter().map(|f| f.size).collect();
        plan_batches(&sizes, batch_threshold, batch_bytes)
    }

    /// Materialize the dataset as real files under `dir`, with
    /// deterministic pseudo-random content (seeded per file id).
    /// Returns the created paths in dataset order.
    pub fn materialize(&self, dir: &Path, seed: u64) -> std::io::Result<Vec<PathBuf>> {
        use std::io::Write;
        std::fs::create_dir_all(dir)?;
        let mut paths = Vec::with_capacity(self.files.len());
        for f in &self.files {
            let path = dir.join(&f.name);
            let mut rng = SplitMix64::new(seed ^ f.id.wrapping_mul(0x9E3779B97F4A7C15));
            let mut out = std::io::BufWriter::new(std::fs::File::create(&path)?);
            let mut remaining = f.size as usize;
            let mut buf = vec![0u8; (256 * 1024).min(remaining.max(1))];
            while remaining > 0 {
                let n = buf.len().min(remaining);
                rng.fill_bytes(&mut buf[..n]);
                out.write_all(&buf[..n])?;
                remaining -= n;
            }
            out.flush()?;
            paths.push(path);
        }
        Ok(paths)
    }
}

/// Tar-like aggregation for the parallel engine's scheduler: files smaller
/// than `batch_threshold` are grouped (in dataset order) into batches of up
/// to `batch_bytes` payload, so one session drains a whole batch
/// back-to-back and the per-file control round trips amortize; larger
/// files are singleton work items. Both the real-mode scheduler
/// ([`crate::coordinator::scheduler`]) and the simulated engine
/// ([`crate::sim::algorithms::run_concurrent`]) plan with this function,
/// so sim and real replay the same schedule.
///
/// A `batch_threshold` of 0 disables aggregation (every file is its own
/// work item). Every returned batch is non-empty and the items cover all
/// file indices exactly once, in order.
pub fn plan_batches(sizes: &[u64], batch_threshold: u64, batch_bytes: u64) -> Vec<Vec<usize>> {
    let mut items: Vec<Vec<usize>> = Vec::new();
    let mut batch: Vec<usize> = Vec::new();
    let mut batch_total = 0u64;
    for (i, &size) in sizes.iter().enumerate() {
        if size < batch_threshold {
            batch.push(i);
            batch_total += size;
            if batch_total >= batch_bytes {
                items.push(std::mem::take(&mut batch));
                batch_total = 0;
            }
        } else {
            items.push(vec![i]);
        }
    }
    if !batch.is_empty() {
        items.push(batch);
    }
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_batches_covers_all_files_once_in_order() {
        let sizes = [10, 10, 5_000, 10, 10, 10, 9_999, 10];
        let items = plan_batches(&sizes, 1_000, 25);
        let flat: Vec<usize> = items.iter().flatten().copied().collect();
        let mut sorted = flat.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..sizes.len()).collect::<Vec<_>>());
        assert!(items.iter().all(|it| !it.is_empty()));
        // Large files are singletons; small files keep batching across
        // them until the batch reaches batch_bytes.
        assert!(items.contains(&vec![2]));
        assert!(items.contains(&vec![6]));
        assert!(items.contains(&vec![0, 1, 3]), "{items:?}");
        assert!(items.contains(&vec![4, 5, 7]), "{items:?}");
    }

    #[test]
    fn plan_batches_threshold_zero_disables_aggregation() {
        let sizes = [1u64, 2, 3];
        let items = plan_batches(&sizes, 0, 1 << 20);
        assert_eq!(items, vec![vec![0], vec![1], vec![2]]);
    }

    #[test]
    fn plan_batches_seals_at_batch_bytes() {
        let sizes = [10u64; 10];
        let items = plan_batches(&sizes, 100, 30);
        // 10+10+10 = 30 >= 30 seals each batch at three files.
        assert_eq!(items.len(), 4);
        assert_eq!(items[0], vec![0, 1, 2]);
        assert_eq!(items[3], vec![9]);
    }

    #[test]
    fn uniform_shape() {
        let d = Dataset::uniform("10M", 10 * MB, 1000);
        assert_eq!(d.len(), 1000);
        assert_eq!(d.total_bytes(), 10_000 * MB);
        assert!(d.files.iter().all(|f| f.size == 10 * MB));
    }

    #[test]
    fn esnet_mixed_matches_paper_inventory() {
        let d = Dataset::esnet_mixed(42);
        assert_eq!(d.len(), 271, "271 files");
        let total_gb = d.total_bytes() as f64 / GB as f64;
        assert!((total_gb - 165.5).abs() < 1.0, "165.5 GB total, got {total_gb}");
    }

    #[test]
    fn shuffle_is_deterministic_and_total_preserving() {
        let a = Dataset::esnet_mixed(1);
        let b = Dataset::esnet_mixed(1);
        let c = Dataset::esnet_mixed(2);
        assert_eq!(
            a.files.iter().map(|f| f.size).collect::<Vec<_>>(),
            b.files.iter().map(|f| f.size).collect::<Vec<_>>()
        );
        assert_eq!(a.total_bytes(), c.total_bytes());
        assert_ne!(
            a.files.iter().map(|f| f.size).collect::<Vec<_>>(),
            c.files.iter().map(|f| f.size).collect::<Vec<_>>(),
            "different seeds give different orders"
        );
    }

    #[test]
    fn sorted_alternates() {
        let d = Dataset::sorted_5m250m(10);
        assert_eq!(d.len(), 20);
        for (i, f) in d.files.iter().enumerate() {
            let expect = if i % 2 == 0 { 5 * MB } else { 250 * MB };
            assert_eq!(f.size, expect, "position {i}");
        }
    }

    #[test]
    fn table3_inventory() {
        let d = Dataset::table3_dataset();
        assert_eq!(d.len(), 15);
        assert_eq!(d.total_bytes(), 10 * GB + 50 * GB);
    }

    #[test]
    fn materialize_writes_expected_sizes() {
        let dir = crate::util::tmpdir::unique_dir("fiver-wl-test");
        let d = Dataset::uniform("tiny", 10_000, 3);
        let paths = d.materialize(&dir, 7).unwrap();
        assert_eq!(paths.len(), 3);
        for p in &paths {
            assert_eq!(std::fs::metadata(p).unwrap().len(), 10_000);
        }
        // Deterministic content.
        let again = std::fs::read(&paths[0]).unwrap();
        let d2 = Dataset::uniform("tiny", 10_000, 3);
        let dir2 = dir.join("again");
        let paths2 = d2.materialize(&dir2, 7).unwrap();
        assert_eq!(std::fs::read(&paths2[0]).unwrap(), again);
        // Distinct files differ.
        assert_ne!(std::fs::read(&paths[1]).unwrap(), again);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
