//! In-memory storage backend: deterministic tests and fault experiments
//! that must not touch the disk. Implements the full [`Storage`] surface
//! (including the vectored/ranged extensions), so the backend conformance
//! suite runs against it like any disk engine.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::{ReadStream, Storage, WriteStream};

type MemMap = Arc<Mutex<HashMap<String, Arc<Mutex<Vec<u8>>>>>>;

/// In-memory storage shared between "hosts" in tests.
#[derive(Clone, Default)]
pub struct MemStorage {
    files: MemMap,
    /// `sync` calls across every stream of this storage (durability is a
    /// no-op in memory, but the *count* lets tests and telemetry verify
    /// sync discipline per backend).
    syncs: Arc<AtomicU64>,
}

impl MemStorage {
    /// An empty in-memory store.
    pub fn new() -> MemStorage {
        MemStorage::default()
    }

    /// Preload a file.
    pub fn put(&self, name: &str, data: Vec<u8>) {
        self.files
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::new(Mutex::new(data)));
    }

    /// Snapshot a file's bytes.
    pub fn get(&self, name: &str) -> Option<Vec<u8>> {
        self.files.lock().unwrap().get(name).map(|v| v.lock().unwrap().clone())
    }
}

impl Storage for MemStorage {
    fn open_read(&self, name: &str) -> Result<Box<dyn ReadStream>> {
        let data = self
            .files
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .with_context(|| format!("no such mem file {name}"))?;
        Ok(Box::new(MemStream { data, pos: 0, syncs: self.syncs.clone() }))
    }

    fn open_write(&self, name: &str) -> Result<Box<dyn WriteStream>> {
        let data = Arc::new(Mutex::new(Vec::new()));
        self.files.lock().unwrap().insert(name.to_string(), data.clone());
        Ok(Box::new(MemStream { data, pos: 0, syncs: self.syncs.clone() }))
    }

    fn open_update(&self, name: &str) -> Result<Box<dyn WriteStream>> {
        let data = self
            .files
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .with_context(|| format!("no such mem file {name}"))?;
        Ok(Box::new(MemStream { data, pos: 0, syncs: self.syncs.clone() }))
    }

    fn size_of(&self, name: &str) -> Result<u64> {
        let files = self.files.lock().unwrap();
        let f = files.get(name).with_context(|| format!("no such mem file {name}"))?;
        let len = f.lock().unwrap().len() as u64;
        Ok(len)
    }

    fn backend_name(&self) -> &'static str {
        "mem"
    }

    fn sync_count(&self) -> u64 {
        self.syncs.load(Ordering::Relaxed)
    }

    fn sync_file(&self, name: &str) -> Result<()> {
        // Memory is "durable" by definition; count the call so sync
        // discipline is observable.
        anyhow::ensure!(
            self.files.lock().unwrap().contains_key(name),
            "no such mem file {name}"
        );
        self.syncs.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn rename(&self, from: &str, to: &str) -> Result<()> {
        let mut files = self.files.lock().unwrap();
        let data = files.remove(from).with_context(|| format!("no such mem file {from}"))?;
        files.insert(to.to_string(), data);
        Ok(())
    }
}

struct MemStream {
    data: Arc<Mutex<Vec<u8>>>,
    pos: u64,
    syncs: Arc<AtomicU64>,
}

impl ReadStream for MemStream {
    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        self.pos = offset;
        self.read_next(buf)
    }

    fn read_next(&mut self, buf: &mut [u8]) -> Result<usize> {
        let data = self.data.lock().unwrap();
        let start = (self.pos as usize).min(data.len());
        let n = buf.len().min(data.len() - start);
        buf[..n].copy_from_slice(&data[start..start + n]);
        self.pos += n as u64;
        Ok(n)
    }
}

impl WriteStream for MemStream {
    fn write_at(&mut self, offset: u64, bytes: &[u8]) -> Result<()> {
        if !bytes.is_empty() {
            let mut data = self.data.lock().unwrap();
            let end = offset as usize + bytes.len();
            if data.len() < end {
                data.resize(end, 0);
            }
            data[offset as usize..end].copy_from_slice(bytes);
        }
        // Ranged writes keep the sequential cursor at the logical end —
        // the cursor rule every backend shares (even for empty writes,
        // which raise the cursor without extending the file).
        self.pos = self.pos.max(offset + bytes.len() as u64);
        Ok(())
    }

    fn write_next(&mut self, bytes: &[u8]) -> Result<()> {
        let pos = self.pos;
        let end = pos + bytes.len() as u64;
        {
            let mut data = self.data.lock().unwrap();
            let e = end as usize;
            if data.len() < e {
                data.resize(e, 0);
            }
            data[pos as usize..e].copy_from_slice(bytes);
        }
        self.pos = end;
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        Ok(())
    }

    fn sync(&mut self) -> Result<()> {
        self.syncs.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }
}
