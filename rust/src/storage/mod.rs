//! Storage backends for the real-mode coordinator: file I/O with the
//! read/write patterns of the paper's Algorithms 1 & 2, behind a
//! pluggable **I/O engine** selection ([`IoBackend`]), plus an in-memory
//! backend for deterministic tests and fault experiments that must not
//! touch the disk.
//!
//! Engines (see DESIGN.md "Storage I/O backends" for the full ownership
//! and durability story):
//!
//! * [`IoBackend::Buffered`] — positioned `pread`/`pwrite` through the
//!   page cache (the PR 3 data plane, unchanged; the default).
//! * [`IoBackend::Mmap`] — memory-mapped streams: reads hand out
//!   zero-copy [`SharedBuf`] views of the file mapping, writes are stores
//!   into a `MAP_SHARED` mapping, durability is `msync` + `fdatasync`.
//! * [`IoBackend::Direct`] — O_DIRECT-style aligned I/O that bypasses the
//!   page cache where offset/length/buffer all meet [`DIRECT_ALIGN`],
//!   with graceful per-operation and per-filesystem fallback to buffered.
//! * [`IoBackend::Uring`] — io_uring submission-queue I/O: multiple reads
//!   or writes queue as SQEs and drain with one `io_uring_enter`, pooled
//!   buffers register once (`IORING_REGISTER_BUFFERS`) so fixed-buffer
//!   ops skip per-op pinning. Kernels without io_uring degrade to
//!   buffered, counted in `uring_fallbacks`.
//! * [`IoBackend::Auto`] — per-file policy, not an engine: files at or
//!   above the direct threshold open on the uring engine (direct when the
//!   ring is unavailable), smaller files stay buffered.
//!
//! The traits carry the vectored/ranged operations the data plane wants:
//! [`ReadStream::read_shared`] fills (or, on mmap, *aliases*) a pooled
//! buffer and returns it refcounted, [`WriteStream::write_at_vectored`]
//! lands scatter repair batches in one positioned call, and
//! [`WriteStream::sync`] has explicit per-backend durability semantics —
//! the checkpoint journal calls it *before* recording a watermark, so a
//! journal never attests bytes the storage could still lose.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::bufpool::{BufferPool, SharedBuf, POOL_GRACE};

/// Filesystem-backed storage (buffered, direct and mmap engines).
pub mod fs;
/// In-memory storage for tests and loopback runs.
pub mod mem;
#[cfg(target_os = "linux")]
pub(crate) mod mmap;
#[cfg(target_os = "linux")]
pub(crate) mod uring;

pub use fs::FsStorage;
pub use mem::MemStorage;

/// Block alignment the direct engine requires of offsets, lengths and
/// buffer addresses (covers 512 B and 4 KiB logical block sizes).
pub const DIRECT_ALIGN: usize = 4096;

/// Selectable storage I/O engine for [`FsStorage`]. The engine decides
/// *how bytes move between the process and the disk* — which determines
/// both the syscall/copy cost per byte and what the page cache sees
/// (FIVER-Hybrid's read-back verification cares about exactly this).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoBackend {
    /// Positioned read/write syscalls through the page cache.
    Buffered,
    /// Memory-mapped reads (zero-copy `SharedBuf` views) and writes, with
    /// msync-backed durability.
    Mmap,
    /// O_DIRECT-style aligned I/O bypassing the page cache, with graceful
    /// fallback where the filesystem or platform refuses it.
    Direct,
    /// io_uring submission-queue I/O with registered buffers: batched
    /// SQE submissions drain with one `io_uring_enter`, falling back to
    /// buffered on kernels without io_uring support.
    Uring,
    /// Per-file automatic selection: large files (at or above the direct
    /// threshold) open on the uring/direct engine, small files stay
    /// buffered. A policy over the other engines, so it is not in
    /// [`IoBackend::ALL`] (sweeps iterate real engines).
    Auto,
}

impl IoBackend {
    /// Every *engine*, in presentation order — the single source of truth
    /// for tests, benches, CI matrix legs and CLI help. `Auto` is a
    /// per-file policy over these and deliberately absent.
    pub const ALL: [IoBackend; 4] =
        [IoBackend::Buffered, IoBackend::Mmap, IoBackend::Direct, IoBackend::Uring];

    /// Canonical display/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            IoBackend::Buffered => "buffered",
            IoBackend::Mmap => "mmap",
            IoBackend::Direct => "direct",
            IoBackend::Uring => "uring",
            IoBackend::Auto => "auto",
        }
    }

    /// Parse a CLI backend name.
    pub fn parse(s: &str) -> Option<IoBackend> {
        match s.to_ascii_lowercase().as_str() {
            "buffered" | "pread" | "default" => Some(IoBackend::Buffered),
            "mmap" => Some(IoBackend::Mmap),
            "direct" | "o_direct" | "odirect" => Some(IoBackend::Direct),
            "uring" | "io_uring" | "io-uring" => Some(IoBackend::Uring),
            "auto" => Some(IoBackend::Auto),
            _ => None,
        }
    }

    /// Backend selected by the `FIVER_IO_BACKEND` environment variable
    /// (`buffered` when unset or unknown). [`FsStorage::new`] and the CLI
    /// default route through this, which is how the CI io-backend matrix
    /// steers the whole test suite.
    pub fn from_env() -> IoBackend {
        std::env::var("FIVER_IO_BACKEND")
            .ok()
            .and_then(|v| IoBackend::parse(&v))
            .unwrap_or(IoBackend::Buffered)
    }

    /// Buffer alignment the data-plane pool should use for this backend
    /// (pooled buffers become valid O_DIRECT / registered-buffer targets
    /// without a bounce copy; `Auto` may resolve to either, so it aligns
    /// too).
    pub fn buffer_align(&self) -> usize {
        match self {
            IoBackend::Direct | IoBackend::Uring | IoBackend::Auto => DIRECT_ALIGN,
            _ => 1,
        }
    }
}

/// Abstract storage: open files for streaming read/write by name.
pub trait Storage: Send + Sync {
    /// Open `name` for sequential reading.
    fn open_read(&self, name: &str) -> Result<Box<dyn ReadStream>>;
    /// Create (or truncate) a file for writing.
    fn open_write(&self, name: &str) -> Result<Box<dyn WriteStream>>;
    /// [`Storage::open_write`] with the final size announced up front
    /// (the receiver knows it from `FileStart`): backends that benefit
    /// from pre-sizing (mmap pre-maps the whole file and never remaps)
    /// use the hint; the default ignores it.
    fn open_write_sized(&self, name: &str, _size_hint: u64) -> Result<Box<dyn WriteStream>> {
        self.open_write(name)
    }
    /// Open an existing file for in-place updates (repair writes) without
    /// truncating it.
    fn open_update(&self, name: &str) -> Result<Box<dyn WriteStream>>;
    /// Size of `name` in bytes.
    fn size_of(&self, name: &str) -> Result<u64>;
    /// The active I/O engine, for telemetry (`TransferReport::io_backend`).
    fn backend_name(&self) -> &'static str;
    /// Times any stream of this storage forced durability (`sync`) — lets
    /// experiments attribute overhead to storage vs hash vs network.
    fn sync_count(&self) -> u64 {
        0
    }
    /// Times the O_DIRECT engine fell back to buffered I/O (per-op
    /// alignment misses or filesystem refusal). 0 for every other
    /// engine; surfaces in `TransferReport::direct_fallbacks` and the
    /// CLI `data plane:` line.
    fn direct_fallbacks(&self) -> u64 {
        0
    }
    /// Times the io_uring engine fell back to buffered I/O (ring setup
    /// refused — `ENOSYS`/`EPERM` on kernels or sandboxes without
    /// io_uring — or a mid-stream ring error). 0 for every other engine;
    /// surfaces in `TransferReport::uring_fallbacks`.
    fn uring_fallbacks(&self) -> u64 {
        0
    }
    /// Page-cache hint calls issued (`posix_fadvise` SEQUENTIAL at stream
    /// open plus DONTNEED after verified ranges). Surfaces in
    /// `TransferReport::storage_hints`.
    fn hint_count(&self) -> u64 {
        0
    }
    /// The engine a specific file would open on — equals
    /// [`Storage::backend_name`] for every fixed engine; the `auto`
    /// policy resolves it per file by size.
    fn backend_for(&self, _name: &str) -> &'static str {
        self.backend_name()
    }
    /// Streaming page-cache hint: the bytes of `name` in
    /// `[offset, offset + len)` were verified and will not be re-read —
    /// the backend may drop them from the page cache
    /// (`POSIX_FADV_DONTNEED`). `len == 0` means "to end of file". Purely
    /// advisory: errors are swallowed, backends without a page-cache
    /// notion ignore it.
    fn advise_done(&self, _name: &str, _offset: u64, _len: u64) -> Result<()> {
        Ok(())
    }
    /// Offer the data-plane [`BufferPool`] to the backend. The io_uring
    /// engine registers its aligned backings as the ring's fixed-buffer
    /// table (`IORING_REGISTER_BUFFERS`) so pooled reads and writes skip
    /// per-op page pinning; every other engine ignores it. Sessions call
    /// this right after constructing their pool — write streams only ever
    /// see `&[u8]`, so the pool has to arrive out of band.
    fn register_pool(&self, _pool: &BufferPool) {}
    /// Force every written byte of `name` to durable storage, regardless
    /// of which stream wrote it. On Unix this is `fdatasync` on the
    /// inode, which also settles pages dirtied through `MAP_SHARED`
    /// mappings (the page cache is unified) — the checkpoint journal's
    /// hash-job checkpoints rely on that.
    fn sync_file(&self, name: &str) -> Result<()> {
        let mut w = self.open_update(name)?;
        w.sync()
    }
    /// Atomically replace `to` with `from` (both names within this
    /// storage). The delta receiver reconstructs an incremental file
    /// into a staging name while the old destination still serves
    /// `DeltaCopy` reads, then renames it into place — readers never
    /// observe a half-built file and the old basis stays intact until
    /// the new bytes are complete.
    fn rename(&self, from: &str, to: &str) -> Result<()>;
}

/// Streaming reader with range support (chunk re-reads for recovery).
pub trait ReadStream: Send {
    /// Ranged read: repositions the sequential cursor to the end of the
    /// range (every backend shares these cursor semantics).
    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize>;
    /// Sequential read from the current position.
    fn read_next(&mut self, buf: &mut [u8]) -> Result<usize>;
    /// Ranged read of up to `len` bytes into a refcounted buffer — the
    /// data plane's hot-path read. The default fills a pooled buffer
    /// (clamped to the pool's buffer size); the mmap engine overrides it
    /// to return a zero-copy view of the file mapping instead. Returns an
    /// empty buffer at/past EOF; otherwise at least one byte.
    fn read_shared(&mut self, offset: u64, len: usize, pool: &BufferPool) -> Result<SharedBuf> {
        let mut buf = pool.get_or_alloc(POOL_GRACE);
        let want = len.min(buf.len());
        let n = self.read_at(offset, &mut buf[..want])?;
        Ok(buf.freeze(n))
    }
}

/// Streaming writer with range support.
///
/// Cursor rule (every backend): `write_next` appends at the cursor and
/// advances it; `write_at` lands at its offset and only ever *raises* the
/// cursor to the end of the written range (repair writes never rewind a
/// sequential stream).
pub trait WriteStream: Send {
    /// Write `data` at the absolute `offset`.
    fn write_at(&mut self, offset: u64, data: &[u8]) -> Result<()>;
    /// Append `data` at the stream cursor.
    fn write_next(&mut self, data: &[u8]) -> Result<()>;
    /// Scatter write: land `parts` as one contiguous span starting at
    /// `offset`. The buffered engine batches this into `pwritev`; the
    /// default is a loop of positioned writes. Repair (`Fix`) batches use
    /// it so a multi-leaf repair is one syscall, not one per frame.
    fn write_at_vectored(&mut self, offset: u64, parts: &[&[u8]]) -> Result<()> {
        let mut off = offset;
        for p in parts {
            self.write_at(off, p)?;
            off += p.len() as u64;
        }
        Ok(())
    }
    /// Flush buffered writes to the backing store.
    fn flush(&mut self) -> Result<()>;
    /// Force written bytes to durable storage (`fdatasync`-strength where
    /// the backend has a notion of durability; `msync` + `fdatasync` on
    /// mmap). The checkpoint journal calls this *before* recording a
    /// watermark, so a journal never attests bytes the storage could
    /// still lose. Defaults to `flush`.
    fn sync(&mut self) -> Result<()> {
        self.flush()
    }
}

/// Read a whole stored file through the trait surface (tests, experiment
/// cross-checks — works on every backend, unlike `std::fs::read`).
pub fn read_all(storage: &Arc<dyn Storage>, name: &str) -> Result<Vec<u8>> {
    let size = storage.size_of(name)? as usize;
    let mut out = vec![0u8; size];
    let mut r = storage.open_read(name)?;
    let mut got = 0usize;
    while got < size {
        let n = r.read_next(&mut out[got..])?;
        anyhow::ensure!(n > 0, "short read of {name}: {got} of {size}");
        got += n;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every backend a test host can construct: the in-memory one plus an
    /// FsStorage per engine (engines unsupported on this platform degrade
    /// to buffered inside FsStorage — still worth exercising).
    fn all_backends(dir: &std::path::Path) -> Vec<(String, Arc<dyn Storage>)> {
        let mut out: Vec<(String, Arc<dyn Storage>)> =
            vec![("mem".to_string(), Arc::new(MemStorage::new()))];
        for b in IoBackend::ALL {
            let sub = dir.join(b.name());
            let s = FsStorage::with_backend(&sub, b).unwrap();
            out.push((format!("fs-{}", b.name()), Arc::new(s)));
        }
        out
    }

    fn roundtrip(storage: &dyn Storage) {
        let data: Vec<u8> = (0u8..=255).cycle().take(10_000).collect();
        {
            let mut w = storage.open_write("f1").unwrap();
            for part in data.chunks(777) {
                w.write_next(part).unwrap();
            }
            w.flush().unwrap();
        }
        assert_eq!(storage.size_of("f1").unwrap(), 10_000);
        let mut r = storage.open_read("f1").unwrap();
        let mut back = vec![0u8; 10_000];
        assert_eq!(r.read_next(&mut back).unwrap(), 10_000);
        assert_eq!(back, data);
    }

    #[test]
    fn roundtrip_every_backend() {
        let dir = crate::util::tmpdir::unique_dir("fiver-storage");
        for (name, storage) in all_backends(&dir) {
            roundtrip(storage.as_ref());
            assert!(!storage.backend_name().is_empty(), "{name}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ranged_rewrite_repairs_chunk() {
        // The chunk-recovery pattern: overwrite a corrupted range in place.
        let s = MemStorage::new();
        {
            let mut w = s.open_write("f").unwrap();
            w.write_next(&[0xAA; 100]).unwrap();
            w.write_at(40, &[0xBB; 10]).unwrap();
        }
        let data = s.get("f").unwrap();
        assert_eq!(&data[39..42], &[0xAA, 0xBB, 0xBB]);
        assert_eq!(data.len(), 100);
    }

    #[test]
    fn ranged_rewrite_keeps_sequential_cursor_every_backend() {
        // Positioned repair writes must not disturb the stream cursor:
        // write 100 bytes, patch the middle, keep streaming — exactly how
        // Fix frames interleave with a later file's Data frames.
        let dir = crate::util::tmpdir::unique_dir("fiver-pwrite");
        for (name, s) in all_backends(&dir) {
            {
                let mut w = s.open_write("f").unwrap();
                w.write_next(&[0xAA; 100]).unwrap();
                w.write_at(40, &[0xBB; 10]).unwrap();
                w.write_next(&[0xCC; 10]).unwrap();
                w.flush().unwrap();
            }
            assert_eq!(s.size_of("f").unwrap(), 110, "{name}");
            let back = read_all(&s, "f").unwrap();
            assert_eq!(&back[39..42], &[0xAA, 0xBB, 0xBB], "{name}");
            assert_eq!(&back[100..], &[0xCC; 10], "{name}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_at_then_sequential_continues_every_backend() {
        let dir = crate::util::tmpdir::unique_dir("fiver-pread");
        for (name, s) in all_backends(&dir) {
            {
                let mut w = s.open_write("f").unwrap();
                w.write_next(&(0u8..200).collect::<Vec<u8>>()).unwrap();
                w.flush().unwrap();
            }
            let mut r = s.open_read("f").unwrap();
            let mut buf = [0u8; 10];
            assert_eq!(r.read_at(50, &mut buf).unwrap(), 10, "{name}");
            assert_eq!(buf[0], 50, "{name}");
            // Sequential read resumes after the ranged one.
            assert_eq!(r.read_next(&mut buf).unwrap(), 10, "{name}");
            assert_eq!(buf[0], 60, "{name}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_shared_matches_read_at_every_backend() {
        let dir = crate::util::tmpdir::unique_dir("fiver-rshared");
        let pool = BufferPool::with_options(64 * 1024, 4, DIRECT_ALIGN, 4);
        for (name, s) in all_backends(&dir) {
            let data: Vec<u8> = (0u8..=255).cycle().take(150_000).collect();
            {
                let mut w = s.open_write_sized("f", data.len() as u64).unwrap();
                w.write_next(&data).unwrap();
                w.flush().unwrap();
            }
            let mut r = s.open_read("f").unwrap();
            for (off, len) in [(0u64, 64 * 1024usize), (64 * 1024, 64 * 1024), (140_000, 64 * 1024)]
            {
                let shared = r.read_shared(off, len, &pool).unwrap();
                assert!(!shared.is_empty(), "{name} at {off}");
                let end = (off as usize + shared.len()).min(data.len());
                assert_eq!(&shared[..], &data[off as usize..end], "{name} at {off}");
            }
            // Past EOF: empty, not an error.
            let past = r.read_shared(data.len() as u64 + 10, 100, &pool).unwrap();
            assert!(past.is_empty(), "{name}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_at_vectored_lands_scatter_batches_every_backend() {
        let dir = crate::util::tmpdir::unique_dir("fiver-writev");
        for (name, s) in all_backends(&dir) {
            {
                let mut w = s.open_write("f").unwrap();
                w.write_next(&[0u8; 300]).unwrap();
                let parts: Vec<&[u8]> = vec![&[1u8; 10], &[2u8; 20], &[3u8; 30]];
                w.write_at_vectored(100, &parts).unwrap();
                w.flush().unwrap();
                w.sync().unwrap();
            }
            let back = read_all(&s, "f").unwrap();
            assert_eq!(back.len(), 300, "{name}");
            assert_eq!(&back[100..110], &[1u8; 10], "{name}");
            assert_eq!(&back[110..130], &[2u8; 20], "{name}");
            assert_eq!(&back[130..160], &[3u8; 30], "{name}");
            assert_eq!(back[160], 0, "{name}");
            assert!(s.sync_count() >= 1, "{name}: sync must be counted");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_write_sized_final_size_is_exact_every_backend() {
        // A pre-sized destination must still end at exactly the written
        // length — even when the stream writes less than the hint (the
        // engine errors upstream in that case, but storage must not lie).
        let dir = crate::util::tmpdir::unique_dir("fiver-sized");
        for (name, s) in all_backends(&dir) {
            {
                let mut w = s.open_write_sized("exact", 5000).unwrap();
                w.write_next(&[7u8; 5000]).unwrap();
                w.flush().unwrap();
            }
            assert_eq!(s.size_of("exact").unwrap(), 5000, "{name}");
            {
                let mut w = s.open_write_sized("short", 5000).unwrap();
                w.write_next(&[7u8; 1200]).unwrap();
                w.flush().unwrap();
            }
            assert_eq!(s.size_of("short").unwrap(), 1200, "{name}: flush truncates the hint");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_files_every_backend() {
        let dir = crate::util::tmpdir::unique_dir("fiver-empty");
        let pool = BufferPool::new(4096, 2);
        for (name, s) in all_backends(&dir) {
            {
                let mut w = s.open_write("e").unwrap();
                w.flush().unwrap();
            }
            assert_eq!(s.size_of("e").unwrap(), 0, "{name}");
            let mut r = s.open_read("e").unwrap();
            let mut buf = [0u8; 16];
            assert_eq!(r.read_next(&mut buf).unwrap(), 0, "{name}");
            assert!(r.read_shared(0, 16, &pool).unwrap().is_empty(), "{name}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rename_replaces_destination_every_backend() {
        let dir = crate::util::tmpdir::unique_dir("fiver-rename");
        for (name, s) in all_backends(&dir) {
            for (f, byte, len) in [("old", 1u8, 10usize), ("staging", 2, 20)] {
                let mut w = s.open_write(f).unwrap();
                w.write_next(&vec![byte; len]).unwrap();
                w.flush().unwrap();
            }
            s.rename("staging", "old").unwrap();
            assert_eq!(read_all(&s, "old").unwrap(), vec![2u8; 20], "{name}");
            assert!(s.size_of("staging").is_err(), "{name}: source gone after rename");
            assert!(s.rename("missing", "x").is_err(), "{name}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_at_offset() {
        let s = MemStorage::new();
        s.put("f", (0u8..100).collect());
        let mut r = s.open_read("f").unwrap();
        let mut buf = [0u8; 10];
        assert_eq!(r.read_at(90, &mut buf).unwrap(), 10);
        assert_eq!(buf[0], 90);
        // Reading past EOF returns short.
        assert_eq!(r.read_at(95, &mut buf).unwrap(), 5);
    }

    #[test]
    fn missing_file_errors() {
        let s = MemStorage::new();
        assert!(s.open_read("nope").is_err());
        assert!(s.size_of("nope").is_err());
    }

    #[test]
    fn backend_parse_roundtrip_and_env() {
        for b in IoBackend::ALL {
            assert_eq!(IoBackend::parse(b.name()), Some(b));
        }
        assert_eq!(IoBackend::parse("O_DIRECT"), Some(IoBackend::Direct));
        assert_eq!(IoBackend::parse("io_uring"), Some(IoBackend::Uring));
        assert_eq!(IoBackend::parse("auto"), Some(IoBackend::Auto));
        assert!(!IoBackend::ALL.contains(&IoBackend::Auto), "auto is a policy, not an engine");
        assert_eq!(IoBackend::parse("nope"), None);
        assert_eq!(IoBackend::Buffered.buffer_align(), 1);
        assert_eq!(IoBackend::Direct.buffer_align(), DIRECT_ALIGN);
        assert_eq!(IoBackend::Uring.buffer_align(), DIRECT_ALIGN);
        assert_eq!(IoBackend::Auto.buffer_align(), DIRECT_ALIGN);
        assert!(DIRECT_ALIGN.is_power_of_two());
    }

    #[test]
    fn mmap_read_shared_is_zero_copy_view() {
        // The mmap engine's read_shared must alias the mapping, not a
        // pool buffer: the pool stays untouched.
        let dir = crate::util::tmpdir::unique_dir("fiver-mmapview");
        let s = FsStorage::with_backend(&dir, IoBackend::Mmap).unwrap();
        if s.backend() != IoBackend::Mmap {
            return; // platform degraded to buffered; nothing to assert
        }
        let data: Vec<u8> = (0u8..=255).cycle().take(64 * 1024).collect();
        {
            let mut w = s.open_write("f").unwrap();
            w.write_next(&data).unwrap();
            w.flush().unwrap();
        }
        let pool = BufferPool::new(16 * 1024, 2);
        let mut r = s.open_read("f").unwrap();
        let a = r.read_shared(0, 16 * 1024, &pool).unwrap();
        let b = r.read_shared(16 * 1024, 16 * 1024, &pool).unwrap();
        assert_eq!(&a[..], &data[..16 * 1024]);
        assert_eq!(&b[..], &data[16 * 1024..32 * 1024]);
        assert_eq!(pool.allocated(), 0, "mmap views must not consume pool buffers");
        // Views can exceed the pool's buffer size (they are not pool-backed).
        let big = r.read_shared(0, 64 * 1024, &pool).unwrap();
        assert_eq!(big.len(), 64 * 1024);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn direct_backend_survives_unaligned_traffic() {
        // Whatever the filesystem decides about O_DIRECT, the direct
        // engine must deliver byte-exact results for arbitrary unaligned
        // traffic (per-op fallback).
        let dir = crate::util::tmpdir::unique_dir("fiver-directmix");
        let s = FsStorage::with_backend(&dir, IoBackend::Direct).unwrap();
        let mut data = vec![0u8; 10_000];
        for (i, b) in data.iter_mut().enumerate() {
            *b = (i * 7) as u8;
        }
        {
            let mut w = s.open_write("f").unwrap();
            w.write_next(&data[..4096]).unwrap(); // aligned prefix
            w.write_next(&data[4096..]).unwrap(); // unaligned tail
            w.write_at(100, &[0xEE; 7]).unwrap(); // unaligned repair
            w.flush().unwrap();
            w.sync().unwrap();
        }
        data[100..107].copy_from_slice(&[0xEE; 7]);
        let storage: Arc<dyn Storage> = Arc::new(s);
        assert_eq!(read_all(&storage, "f").unwrap(), data);
    }

    #[test]
    fn sync_file_counts_and_succeeds_every_backend() {
        let dir = crate::util::tmpdir::unique_dir("fiver-syncfile");
        for (name, s) in all_backends(&dir) {
            {
                let mut w = s.open_write("f").unwrap();
                w.write_next(&[1u8; 64]).unwrap();
                w.flush().unwrap();
            }
            let before = s.sync_count();
            s.sync_file("f").unwrap();
            assert!(s.sync_count() > before, "{name}: sync_file must count");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
