//! Storage backends for the real-mode coordinator: file I/O with the
//! read/write patterns of the paper's Algorithms 1 & 2, plus an in-memory
//! backend for deterministic tests and fault experiments that must not
//! touch the disk.
//!
//! The filesystem backend uses *positioned* I/O (`pread`/`pwrite` on
//! Unix): every ranged access is one syscall instead of a seek + I/O
//! pair, and ranged repair writes never disturb the sequential cursor —
//! the storage half of the zero-copy data plane (readers fill pooled
//! buffers, writers consume borrowed slices; see
//! [`crate::coordinator::bufpool`]).

use std::collections::HashMap;
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

/// Abstract storage: open files for streaming read/write by name.
pub trait Storage: Send + Sync {
    fn open_read(&self, name: &str) -> Result<Box<dyn ReadStream>>;
    /// Create (or truncate) a file for writing.
    fn open_write(&self, name: &str) -> Result<Box<dyn WriteStream>>;
    /// Open an existing file for in-place updates (repair writes) without
    /// truncating it.
    fn open_update(&self, name: &str) -> Result<Box<dyn WriteStream>>;
    fn size_of(&self, name: &str) -> Result<u64>;
}

/// Streaming reader with range support (chunk re-reads for recovery).
pub trait ReadStream: Send {
    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize>;
    /// Sequential read from the current position.
    fn read_next(&mut self, buf: &mut [u8]) -> Result<usize>;
}

/// Streaming writer with range support.
pub trait WriteStream: Send {
    fn write_at(&mut self, offset: u64, data: &[u8]) -> Result<()>;
    fn write_next(&mut self, data: &[u8]) -> Result<()>;
    fn flush(&mut self) -> Result<()>;
    /// Force written bytes to durable storage (`fdatasync`-strength where
    /// the backend has a notion of durability). The checkpoint journal
    /// calls this *before* recording a watermark, so a journal never
    /// attests bytes the storage could still lose. Defaults to `flush`.
    fn sync(&mut self) -> Result<()> {
        self.flush()
    }
}

// ---------------------------------------------------------------------------
// Filesystem backend
// ---------------------------------------------------------------------------

/// Real files under a root directory.
pub struct FsStorage {
    root: PathBuf,
}

impl FsStorage {
    pub fn new(root: &Path) -> Result<FsStorage> {
        std::fs::create_dir_all(root)
            .with_context(|| format!("creating storage root {}", root.display()))?;
        Ok(FsStorage { root: root.to_path_buf() })
    }

    fn path(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }
}

impl Storage for FsStorage {
    fn open_read(&self, name: &str) -> Result<Box<dyn ReadStream>> {
        let f = File::open(self.path(name))
            .with_context(|| format!("opening {name} for read"))?;
        Ok(Box::new(FsRead { f, pos: 0 }))
    }

    fn open_write(&self, name: &str) -> Result<Box<dyn WriteStream>> {
        let f = File::create(self.path(name))
            .with_context(|| format!("opening {name} for write"))?;
        Ok(Box::new(FsWrite { f, pos: 0 }))
    }

    fn open_update(&self, name: &str) -> Result<Box<dyn WriteStream>> {
        let f = std::fs::OpenOptions::new()
            .write(true)
            .open(self.path(name))
            .with_context(|| format!("opening {name} for update"))?;
        Ok(Box::new(FsWrite { f, pos: 0 }))
    }

    fn size_of(&self, name: &str) -> Result<u64> {
        Ok(std::fs::metadata(self.path(name))
            .with_context(|| format!("stat {name}"))?
            .len())
    }
}

/// Positioned read of one range: `pread` on Unix (no seek, kernel cursor
/// untouched), seek + read elsewhere.
fn pread(f: &mut File, offset: u64, buf: &mut [u8]) -> Result<usize> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        Ok(f.read_at(buf, offset)?)
    }
    #[cfg(not(unix))]
    {
        use std::io::{Read, Seek, SeekFrom};
        f.seek(SeekFrom::Start(offset))?;
        Ok(f.read(buf)?)
    }
}

/// Positioned write of one range: `pwrite` on Unix, seek + write elsewhere.
fn pwrite_all(f: &mut File, offset: u64, data: &[u8]) -> Result<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        f.write_all_at(data, offset)?;
        Ok(())
    }
    #[cfg(not(unix))]
    {
        use std::io::{Seek, SeekFrom};
        f.seek(SeekFrom::Start(offset))?;
        f.write_all(data)?;
        Ok(())
    }
}

/// Filesystem reader with an explicit cursor: sequential reads advance it,
/// ranged reads reposition it — every access is a single positioned-I/O
/// syscall (the same cursor semantics as [`MemStream`]).
struct FsRead {
    f: File,
    pos: u64,
}

impl ReadStream for FsRead {
    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        self.pos = offset;
        self.read_next(buf)
    }

    fn read_next(&mut self, buf: &mut [u8]) -> Result<usize> {
        let mut total = 0;
        while total < buf.len() {
            let n = pread(&mut self.f, self.pos, &mut buf[total..])?;
            if n == 0 {
                break;
            }
            total += n;
            self.pos += n as u64;
        }
        Ok(total)
    }
}

/// Filesystem writer with an explicit append cursor. Ranged writes
/// (`write_at`) land without touching the cursor beyond keeping it at the
/// logical end, so repair writes interleave freely with a sequential
/// stream.
struct FsWrite {
    f: File,
    pos: u64,
}

impl WriteStream for FsWrite {
    fn write_at(&mut self, offset: u64, data: &[u8]) -> Result<()> {
        pwrite_all(&mut self.f, offset, data)?;
        self.pos = self.pos.max(offset + data.len() as u64);
        Ok(())
    }

    fn write_next(&mut self, data: &[u8]) -> Result<()> {
        pwrite_all(&mut self.f, self.pos, data)?;
        self.pos += data.len() as u64;
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        self.f.flush()?;
        Ok(())
    }

    fn sync(&mut self) -> Result<()> {
        self.f.sync_data()?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// In-memory backend
// ---------------------------------------------------------------------------

type MemMap = Arc<Mutex<HashMap<String, Arc<Mutex<Vec<u8>>>>>>;

/// In-memory storage shared between "hosts" in tests.
#[derive(Clone, Default)]
pub struct MemStorage {
    files: MemMap,
}

impl MemStorage {
    pub fn new() -> MemStorage {
        MemStorage::default()
    }

    /// Preload a file.
    pub fn put(&self, name: &str, data: Vec<u8>) {
        self.files
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::new(Mutex::new(data)));
    }

    /// Snapshot a file's bytes.
    pub fn get(&self, name: &str) -> Option<Vec<u8>> {
        self.files.lock().unwrap().get(name).map(|v| v.lock().unwrap().clone())
    }
}

impl Storage for MemStorage {
    fn open_read(&self, name: &str) -> Result<Box<dyn ReadStream>> {
        let data = self
            .files
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .with_context(|| format!("no such mem file {name}"))?;
        Ok(Box::new(MemStream { data, pos: 0 }))
    }

    fn open_write(&self, name: &str) -> Result<Box<dyn WriteStream>> {
        let data = Arc::new(Mutex::new(Vec::new()));
        self.files.lock().unwrap().insert(name.to_string(), data.clone());
        Ok(Box::new(MemStream { data, pos: 0 }))
    }

    fn open_update(&self, name: &str) -> Result<Box<dyn WriteStream>> {
        let data = self
            .files
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .with_context(|| format!("no such mem file {name}"))?;
        Ok(Box::new(MemStream { data, pos: 0 }))
    }

    fn size_of(&self, name: &str) -> Result<u64> {
        let files = self.files.lock().unwrap();
        let f = files.get(name).with_context(|| format!("no such mem file {name}"))?;
        let len = f.lock().unwrap().len() as u64;
        Ok(len)
    }
}

struct MemStream {
    data: Arc<Mutex<Vec<u8>>>,
    pos: u64,
}

impl ReadStream for MemStream {
    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        self.pos = offset;
        self.read_next(buf)
    }

    fn read_next(&mut self, buf: &mut [u8]) -> Result<usize> {
        let data = self.data.lock().unwrap();
        let start = (self.pos as usize).min(data.len());
        let n = buf.len().min(data.len() - start);
        buf[..n].copy_from_slice(&data[start..start + n]);
        self.pos += n as u64;
        Ok(n)
    }
}

impl WriteStream for MemStream {
    fn write_at(&mut self, offset: u64, bytes: &[u8]) -> Result<()> {
        let mut data = self.data.lock().unwrap();
        let end = offset as usize + bytes.len();
        if data.len() < end {
            data.resize(end, 0);
        }
        data[offset as usize..end].copy_from_slice(bytes);
        Ok(())
    }

    fn write_next(&mut self, bytes: &[u8]) -> Result<()> {
        let pos = self.pos;
        self.write_at(pos, bytes)?;
        self.pos += bytes.len() as u64;
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(storage: &dyn Storage) {
        let data: Vec<u8> = (0u8..=255).cycle().take(10_000).collect();
        {
            let mut w = storage.open_write("f1").unwrap();
            for part in data.chunks(777) {
                w.write_next(part).unwrap();
            }
            w.flush().unwrap();
        }
        assert_eq!(storage.size_of("f1").unwrap(), 10_000);
        let mut r = storage.open_read("f1").unwrap();
        let mut back = vec![0u8; 10_000];
        assert_eq!(r.read_next(&mut back).unwrap(), 10_000);
        assert_eq!(back, data);
    }

    #[test]
    fn mem_roundtrip() {
        roundtrip(&MemStorage::new());
    }

    #[test]
    fn fs_roundtrip() {
        let dir = crate::util::tmpdir::unique_dir("fiver-storage");
        let s = FsStorage::new(&dir).unwrap();
        roundtrip(&s);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ranged_rewrite_repairs_chunk() {
        // The chunk-recovery pattern: overwrite a corrupted range in place.
        let s = MemStorage::new();
        {
            let mut w = s.open_write("f").unwrap();
            w.write_next(&[0xAA; 100]).unwrap();
            w.write_at(40, &[0xBB; 10]).unwrap();
        }
        let data = s.get("f").unwrap();
        assert_eq!(&data[39..42], &[0xAA, 0xBB, 0xBB]);
        assert_eq!(data.len(), 100);
    }

    #[test]
    fn fs_ranged_rewrite_keeps_sequential_cursor() {
        // Positioned repair writes must not disturb the stream cursor:
        // write 100 bytes, patch the middle, keep streaming — exactly how
        // Fix frames interleave with a later file's Data frames.
        let dir = crate::util::tmpdir::unique_dir("fiver-pwrite");
        let s = FsStorage::new(&dir).unwrap();
        {
            let mut w = s.open_write("f").unwrap();
            w.write_next(&[0xAA; 100]).unwrap();
            w.write_at(40, &[0xBB; 10]).unwrap();
            w.write_next(&[0xCC; 10]).unwrap();
            w.flush().unwrap();
        }
        assert_eq!(s.size_of("f").unwrap(), 110);
        let mut r = s.open_read("f").unwrap();
        let mut back = vec![0u8; 110];
        assert_eq!(r.read_next(&mut back).unwrap(), 110);
        assert_eq!(&back[39..42], &[0xAA, 0xBB, 0xBB]);
        assert_eq!(&back[100..], &[0xCC; 10]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fs_read_at_then_sequential_continues() {
        let dir = crate::util::tmpdir::unique_dir("fiver-pread");
        let s = FsStorage::new(&dir).unwrap();
        {
            let mut w = s.open_write("f").unwrap();
            w.write_next(&(0u8..200).collect::<Vec<u8>>()).unwrap();
            w.flush().unwrap();
        }
        let mut r = s.open_read("f").unwrap();
        let mut buf = [0u8; 10];
        assert_eq!(r.read_at(50, &mut buf).unwrap(), 10);
        assert_eq!(buf[0], 50);
        // Sequential read resumes after the ranged one (MemStream parity).
        assert_eq!(r.read_next(&mut buf).unwrap(), 10);
        assert_eq!(buf[0], 60);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn read_at_offset() {
        let s = MemStorage::new();
        s.put("f", (0u8..100).collect());
        let mut r = s.open_read("f").unwrap();
        let mut buf = [0u8; 10];
        assert_eq!(r.read_at(90, &mut buf).unwrap(), 10);
        assert_eq!(buf[0], 90);
        // Reading past EOF returns short.
        assert_eq!(r.read_at(95, &mut buf).unwrap(), 5);
    }

    #[test]
    fn missing_file_errors() {
        let s = MemStorage::new();
        assert!(s.open_read("nope").is_err());
        assert!(s.size_of("nope").is_err());
    }
}
