//! Memory-mapped storage engine (Linux): reads serve zero-copy
//! [`SharedBuf`] views straight out of a shared file mapping, writes land
//! as stores into a `MAP_SHARED` mapping (no write syscalls on the hot
//! path), and durability is `msync` + `fdatasync`.
//!
//! Ownership story: a read stream maps the whole file once and hands out
//! refcounted views ([`SharedBuf::from_external`]) — the mapping stays
//! alive for as long as any view (socket write, hash queue, stash, spill)
//! still needs the bytes, and *no pool buffer and no copy* are involved
//! on the read path at all. A write stream maps the destination
//! read-write; `open_write_sized` pre-sizes the mapping to the announced
//! file size so the streaming path never remaps, while the unhinted path
//! grows geometrically and truncates back to the logical length on
//! `flush`/drop.
//!
//! Durability: `MAP_SHARED` dirty pages live in the page cache like any
//! written page, so [`WriteStream::sync`] = `msync(MS_SYNC)` +
//! `fdatasync` gives the same "bytes are on stable storage when sync
//! returns" guarantee the buffered engine's `fdatasync` gives — which is
//! exactly what the checkpoint journal's data-before-watermark ordering
//! needs (see DESIGN.md "Storage I/O backends").

#![cfg(target_os = "linux")]

use std::fs::{File, OpenOptions};
use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::fs::IoCounters;
use super::{ReadStream, WriteStream};
use crate::coordinator::bufpool::{BufferPool, ExternalBytes, SharedBuf};

mod sys {
    use std::ffi::c_void;

    /// `PROT_READ` from `<sys/mman.h>`.
    pub const PROT_READ: i32 = 0x1;
    /// `PROT_WRITE` from `<sys/mman.h>`.
    pub const PROT_WRITE: i32 = 0x2;
    /// `MAP_SHARED` from `<sys/mman.h>`.
    pub const MAP_SHARED: i32 = 0x01;
    /// `MS_SYNC` flag for `msync(2)`.
    pub const MS_SYNC: i32 = 0x4;

    extern "C" {
        /// Map a file region — see `mmap(2)`.
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        /// Unmap a region — see `munmap(2)`.
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
        /// Flush a mapped region to its file — see `msync(2)`.
        pub fn msync(addr: *mut c_void, len: usize, flags: i32) -> i32;
    }
}

/// One live `MAP_SHARED` mapping of a file's first `len` bytes.
struct Region {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: the mapping is process-global memory; access through &/&mut
// follows the usual borrow discipline of the owning stream, and the
// read-only regions handed to SharedBuf views are immutable by contract
// (source files do not change during a transfer — same assumption every
// checksum-while-reading pipeline makes).
unsafe impl Send for Region {}
unsafe impl Sync for Region {}

impl Region {
    fn map(f: &File, len: usize, write: bool) -> Result<Region> {
        use std::os::unix::io::AsRawFd;
        anyhow::ensure!(len > 0, "cannot map zero bytes");
        let prot = if write { sys::PROT_READ | sys::PROT_WRITE } else { sys::PROT_READ };
        // SAFETY: fd is a live descriptor; len > 0; kernel validates the rest.
        let p = unsafe {
            sys::mmap(std::ptr::null_mut(), len, prot, sys::MAP_SHARED, f.as_raw_fd(), 0)
        };
        if p as isize == -1 {
            return Err(std::io::Error::last_os_error()).context("mmap");
        }
        Ok(Region { ptr: p as *mut u8, len })
    }

    fn as_slice(&self) -> &[u8] {
        // SAFETY: ptr/len describe the live mapping.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    fn as_mut_slice(&mut self) -> &mut [u8] {
        // SAFETY: as above; &mut self guarantees exclusive access through
        // this handle.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }

    fn msync(&self) -> Result<()> {
        // SAFETY: ptr/len describe the live mapping.
        let rc = unsafe { sys::msync(self.ptr as *mut _, self.len, sys::MS_SYNC) };
        if rc != 0 {
            return Err(std::io::Error::last_os_error()).context("msync");
        }
        Ok(())
    }
}

impl Drop for Region {
    fn drop(&mut self) {
        // SAFETY: mapping is live until this very munmap.
        unsafe {
            sys::munmap(self.ptr as *mut _, self.len);
        }
    }
}

/// A read-only mapped file, shared into the data plane as an external
/// [`SharedBuf`] backing: every view holds a refcount, so the mapping
/// outlives the stream for as long as any byte of it is still in flight.
struct MappedFile {
    region: Region,
}

impl ExternalBytes for MappedFile {
    fn as_bytes(&self) -> &[u8] {
        self.region.as_slice()
    }
}

/// mmap engine reader.
pub(crate) struct MmapRead {
    /// `None` for an empty file (zero-length mappings are invalid).
    map: Option<Arc<MappedFile>>,
    size: u64,
    pos: u64,
}

impl MmapRead {
    pub(crate) fn open(path: &Path, name: &str) -> Result<MmapRead> {
        let f = File::open(path).with_context(|| format!("opening {name} for read"))?;
        let size = f.metadata()?.len();
        let map = if size > 0 {
            Some(Arc::new(MappedFile { region: Region::map(&f, size as usize, false)? }))
        } else {
            None
        };
        Ok(MmapRead { map, size, pos: 0 })
    }
}

impl ReadStream for MmapRead {
    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        self.pos = offset;
        self.read_next(buf)
    }

    fn read_next(&mut self, buf: &mut [u8]) -> Result<usize> {
        let Some(map) = &self.map else { return Ok(0) };
        let data = map.as_bytes();
        let start = (self.pos as usize).min(data.len());
        let n = buf.len().min(data.len() - start);
        buf[..n].copy_from_slice(&data[start..start + n]);
        self.pos += n as u64;
        Ok(n)
    }

    fn read_shared(
        &mut self,
        offset: u64,
        len: usize,
        _pool: &BufferPool,
    ) -> Result<SharedBuf> {
        // The zero-copy path: a refcounted window of the mapping itself.
        // No pool buffer, no copy — the socket writes and the hash queue
        // consume the very pages the kernel faulted in.
        let Some(map) = &self.map else { return Ok(SharedBuf::from_vec(Vec::new())) };
        let start = (offset as usize).min(self.size as usize);
        let n = len.min(self.size as usize - start);
        self.pos = (start + n) as u64;
        if n == 0 {
            return Ok(SharedBuf::from_vec(Vec::new()));
        }
        let ext: Arc<dyn ExternalBytes> = map.clone();
        Ok(SharedBuf::from_external(ext, start, n))
    }
}

/// mmap engine writer: stores into a `MAP_SHARED` mapping. `cap` is the
/// mapped (= physical) length, `logical` the high-water byte actually
/// written; `flush` truncates physical down to logical when the two
/// diverge (the unhinted growth path).
pub(crate) struct MmapWrite {
    file: File,
    region: Option<Region>,
    cap: u64,
    logical: u64,
    pos: u64,
    counters: Arc<IoCounters>,
}

impl MmapWrite {
    pub(crate) fn create(
        path: &Path,
        name: &str,
        size_hint: u64,
        counters: Arc<IoCounters>,
    ) -> Result<MmapWrite> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .with_context(|| format!("opening {name} for write"))?;
        let mut w = MmapWrite { file, region: None, cap: 0, logical: 0, pos: 0, counters };
        if size_hint > 0 {
            // Pre-size to the announced length: the streaming write path
            // then never remaps and never truncates.
            w.ensure_cap(size_hint)?;
        }
        Ok(w)
    }

    pub(crate) fn open_existing(
        path: &Path,
        name: &str,
        counters: Arc<IoCounters>,
    ) -> Result<MmapWrite> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("opening {name} for update"))?;
        let len = file.metadata()?.len();
        let region = if len > 0 { Some(Region::map(&file, len as usize, true)?) } else { None };
        Ok(MmapWrite { file, region, cap: len, logical: len, pos: 0, counters })
    }

    /// Make the mapping cover at least `need` bytes (geometric growth so
    /// an unhinted stream remaps O(log n) times, exact for the pre-sized
    /// path).
    fn ensure_cap(&mut self, need: u64) -> Result<()> {
        if need <= self.cap {
            return Ok(());
        }
        let new_cap = need.max(self.cap.saturating_mul(2));
        self.region = None; // unmap before resizing the file
        self.file.set_len(new_cap).context("growing mmap destination")?;
        self.region = Some(Region::map(&self.file, new_cap as usize, true)?);
        self.cap = new_cap;
        Ok(())
    }

    fn store(&mut self, offset: u64, data: &[u8]) -> Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        let end = offset + data.len() as u64;
        self.ensure_cap(end)?;
        let region = self.region.as_mut().expect("ensure_cap mapped");
        region.as_mut_slice()[offset as usize..end as usize].copy_from_slice(data);
        self.logical = self.logical.max(end);
        Ok(())
    }
}

impl WriteStream for MmapWrite {
    fn write_at(&mut self, offset: u64, data: &[u8]) -> Result<()> {
        self.store(offset, data)?;
        self.pos = self.pos.max(offset + data.len() as u64);
        Ok(())
    }

    fn write_next(&mut self, data: &[u8]) -> Result<()> {
        let pos = self.pos;
        self.store(pos, data)?;
        self.pos = pos + data.len() as u64;
        Ok(())
    }

    fn write_at_vectored(&mut self, offset: u64, parts: &[&[u8]]) -> Result<()> {
        // Scatter writes into a mapping are just consecutive stores — no
        // syscall to batch, so the win over the default is one cursor
        // update and a single capacity check.
        let total: usize = parts.iter().map(|p| p.len()).sum();
        if total > 0 {
            self.ensure_cap(offset + total as u64)?;
        }
        let mut off = offset;
        for p in parts {
            self.store(off, p)?;
            off += p.len() as u64;
        }
        self.pos = self.pos.max(off);
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        // Close the unhinted growth path's over-allocation: physical
        // length snaps back to the bytes actually written. (The pre-sized
        // streaming path has cap == logical and skips all of this.)
        if self.cap != self.logical {
            self.region = None;
            self.file.set_len(self.logical).context("truncating mmap destination")?;
            self.cap = self.logical;
            if self.cap > 0 {
                self.region = Some(Region::map(&self.file, self.cap as usize, true)?);
            }
        }
        Ok(())
    }

    fn sync(&mut self) -> Result<()> {
        if let Some(r) = &self.region {
            r.msync()?;
        }
        // msync settles the mapped pages; fdatasync covers file length
        // changes from ensure_cap/flush.
        self.file.sync_data()?;
        self.counters.syncs.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }
}

impl Drop for MmapWrite {
    fn drop(&mut self) {
        // A stream dropped without flush (error paths, crash injection)
        // must not leave pre-allocated capacity past the written bytes.
        if self.cap > self.logical {
            self.region = None;
            self.file.set_len(self.logical).ok();
        }
    }
}
