//! io_uring storage engine (Linux): batched submission-queue I/O with
//! registered buffers, behind the same [`ReadStream`]/[`WriteStream`]
//! seam as the other engines — raw `io_uring_setup`/`io_uring_enter`/
//! `io_uring_register` syscalls, no crates.
//!
//! Where the syscalls go: the buffered engine pays one `pread` per chunk
//! and one `pwrite` per chunk (plus one per repair part). This engine
//! queues multiple operations as SQEs and drains them with a *single*
//! `io_uring_enter` — the reader submits a small readahead batch
//! ([`RA_DEPTH`] sequential chunks) per miss and then serves the next
//! chunks from completed buffers with **zero** syscalls, and
//! `write_at_vectored`'s coalesced repair batches land as one SQE per
//! part under one enter. `IoCounters::uring_enters` vs
//! `IoCounters::uring_ops` makes the batching factor observable (the
//! `coordinator_hotpath` bench asserts enters < ops).
//!
//! Registered buffers: the [`BufferPool`]'s aligned backings are
//! registered once per pool epoch (`IORING_REGISTER_BUFFERS`), so
//! operations on pooled buffers run as `IORING_OP_READ_FIXED`/
//! `WRITE_FIXED` and skip per-op page pinning. The pool's adaptive growth
//! bumps its `grow_events` epoch; the ring detects the stale key on the
//! next batch and re-registers (see `BufferPool::registration_table`).
//! Registration refusal (e.g. `RLIMIT_MEMLOCK`) is tolerated: operations
//! simply run unregistered (`READV`/`WRITEV`), still batched.
//!
//! Degradation mirrors the O_DIRECT engine: `ENOSYS`/`EPERM` at ring
//! setup (kernels or sandboxes without io_uring) falls back to buffered
//! streams, counted once in `IoCounters::uring_fallbacks`; a mid-stream
//! ring failure kills the shared ring (counted once) and every stream
//! completes through its plain descriptor. Data delivery is bit-identical
//! either way.
//!
//! Durability & ordering: every batch *completes before the call
//! returns* (one `io_uring_enter` with `min_complete == n`), so a
//! `WriteStream::sync` (`fdatasync`) can never run ahead of queued
//! writes — the checkpoint journal's data-before-watermark ordering
//! holds exactly as it does for the synchronous engines (see DESIGN.md
//! "io_uring data plane").

#![cfg(target_os = "linux")]

use std::collections::VecDeque;
use std::fs::{File, OpenOptions};
use std::path::Path;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::fs::{pread, pwrite_all, IoCounters};
use super::{ReadStream, WriteStream};
use crate::coordinator::bufpool::{BufferPool, PoolBuf, SharedBuf, POOL_GRACE};
use crate::obs::{Shard, Stage};

/// Submission/completion queue entries requested at ring setup. Sized
/// for the engine's batches (readahead depth, repair waves), not for
/// deep async pipelines — every batch completes synchronously.
const RING_ENTRIES: u32 = 64;

/// Sequential chunks submitted per readahead batch: one miss costs one
/// `io_uring_enter` and the next `RA_DEPTH - 1` chunks are then served
/// syscall-free, putting the read path well under one syscall per chunk.
const RA_DEPTH: usize = 4;

/// Largest SQE wave per `io_uring_enter` (bounded so per-wave iovec
/// storage lives on the stack); longer op lists submit in waves.
const MAX_BATCH: usize = 32;

mod sys {
    use std::ffi::{c_long, c_void};

    /// `io_uring_setup(2)` syscall number (same on every 64-bit arch).
    pub const SYS_IO_URING_SETUP: c_long = 425;
    /// `io_uring_enter(2)` syscall number.
    pub const SYS_IO_URING_ENTER: c_long = 426;
    /// `io_uring_register(2)` syscall number.
    pub const SYS_IO_URING_REGISTER: c_long = 427;

    /// mmap offset of the submission-queue ring.
    pub const IORING_OFF_SQ_RING: i64 = 0;
    /// mmap offset of the completion-queue ring.
    pub const IORING_OFF_CQ_RING: i64 = 0x8000000;
    /// mmap offset of the SQE array.
    pub const IORING_OFF_SQES: i64 = 0x10000000;

    /// Feature bit: one mmap covers both rings (kernel >= 5.4).
    pub const IORING_FEAT_SINGLE_MMAP: u32 = 1;
    /// `io_uring_enter` flag: wait for `min_complete` completions.
    pub const IORING_ENTER_GETEVENTS: u32 = 1;

    /// Vectored read opcode.
    pub const IORING_OP_READV: u8 = 1;
    /// Vectored write opcode.
    pub const IORING_OP_WRITEV: u8 = 2;
    /// Registered-buffer read opcode.
    pub const IORING_OP_READ_FIXED: u8 = 4;
    /// Registered-buffer write opcode.
    pub const IORING_OP_WRITE_FIXED: u8 = 5;

    /// `io_uring_register` opcode: register a buffer table.
    pub const IORING_REGISTER_BUFFERS: u32 = 0;
    /// `io_uring_register` opcode: drop the registered buffer table.
    pub const IORING_UNREGISTER_BUFFERS: u32 = 1;

    /// `PROT_READ | PROT_WRITE` for the ring mappings.
    pub const PROT_RW: i32 = 0x1 | 0x2;
    /// `MAP_SHARED` — ring memory is shared with the kernel.
    pub const MAP_SHARED: i32 = 0x01;

    /// Offsets into the SQ ring mapping (`struct io_sqring_offsets`).
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct SqringOffsets {
        pub head: u32,
        pub tail: u32,
        pub ring_mask: u32,
        pub ring_entries: u32,
        pub flags: u32,
        pub dropped: u32,
        pub array: u32,
        pub resv1: u32,
        pub resv2: u64,
    }

    /// Offsets into the CQ ring mapping (`struct io_cqring_offsets`).
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct CqringOffsets {
        pub head: u32,
        pub tail: u32,
        pub ring_mask: u32,
        pub ring_entries: u32,
        pub overflow: u32,
        pub cqes: u32,
        pub flags: u32,
        pub resv1: u32,
        pub resv2: u64,
    }

    /// `struct io_uring_params` — filled in by `io_uring_setup`.
    #[repr(C)]
    pub struct IoUringParams {
        pub sq_entries: u32,
        pub cq_entries: u32,
        pub flags: u32,
        pub sq_thread_cpu: u32,
        pub sq_thread_idle: u32,
        pub features: u32,
        pub wq_fd: u32,
        pub resv: [u32; 3],
        pub sq_off: SqringOffsets,
        pub cq_off: CqringOffsets,
    }

    /// One 64-byte submission-queue entry (`struct io_uring_sqe`).
    #[repr(C)]
    pub struct Sqe {
        pub opcode: u8,
        pub flags: u8,
        pub ioprio: u16,
        pub fd: i32,
        pub off: u64,
        pub addr: u64,
        pub len: u32,
        pub rw_flags: u32,
        pub user_data: u64,
        pub buf_index: u16,
        pub personality: u16,
        pub splice_fd_in: i32,
        pub pad2: [u64; 2],
    }

    /// One 16-byte completion-queue entry (`struct io_uring_cqe`).
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct Cqe {
        pub user_data: u64,
        pub res: i32,
        pub flags: u32,
    }

    /// One `struct iovec` (READV/WRITEV payload descriptor and the
    /// registration table entry format).
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct IoVec {
        pub base: *mut c_void,
        pub len: usize,
    }

    extern "C" {
        /// Raw syscall entry — how the three io_uring calls are made
        /// without a libc wrapper dependency.
        pub fn syscall(num: c_long, ...) -> c_long;
        /// Map ring memory — see `mmap(2)`.
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        /// Unmap ring memory — see `munmap(2)`.
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
        /// Close the ring descriptor — see `close(2)`.
        pub fn close(fd: i32) -> i32;
    }
}

/// One mmap'd ring region, unmapped on drop.
struct Mapping {
    ptr: *mut u8,
    len: usize,
}

impl Mapping {
    fn map(fd: i32, len: usize, offset: i64) -> std::io::Result<Mapping> {
        // SAFETY: fd is the live ring descriptor; the kernel validates
        // len/offset against the ring geometry.
        let p = unsafe {
            sys::mmap(std::ptr::null_mut(), len, sys::PROT_RW, sys::MAP_SHARED, fd, offset)
        };
        if p as isize == -1 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(Mapping { ptr: p as *mut u8, len })
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        // SAFETY: mapping is live until this munmap.
        unsafe {
            sys::munmap(self.ptr as *mut _, self.len);
        }
    }
}

/// One I/O operation to queue: a single-buffer read or write at an
/// absolute file offset. `submit_wave` picks the fixed-buffer opcode
/// when `ptr` lies inside a registered backing.
struct SqOp {
    write: bool,
    fd: i32,
    offset: u64,
    ptr: *mut u8,
    len: usize,
}

/// The live ring: fd, the three mappings, cached ring pointers, and the
/// registered-buffer table. Owned behind [`UringCore`]'s mutex; raw ring
/// pointers are only touched while that lock is held.
struct Ring {
    fd: i32,
    _sq_ring: Mapping,
    _cq_ring: Option<Mapping>,
    _sqes: Mapping,
    sq_tail: *const AtomicU32,
    sq_mask: u32,
    sq_array: *mut u32,
    cq_head: *const AtomicU32,
    cq_tail: *const AtomicU32,
    cq_mask: u32,
    cqes: *const sys::Cqe,
    sqe_ptr: *mut sys::Sqe,
    /// Is a buffer table currently registered with the kernel?
    registered: bool,
    /// `(pool core_id, grow_events epoch)` the current registration (or
    /// registration *attempt* — failures are cached too, so a refusing
    /// kernel is asked once per epoch, not once per batch) corresponds to.
    reg_key: Option<(usize, u64)>,
    /// Registered backings as `(address, length)`, sorted by address —
    /// `fixed_index` resolves op buffers against it by binary search.
    table: Vec<(usize, usize)>,
}

// SAFETY: the ring is confined behind UringCore's Mutex — all pointer
// access happens under that lock, one thread at a time.
unsafe impl Send for Ring {}

impl Ring {
    /// `io_uring_setup` + the ring mmaps. Any failure (ENOSYS on old
    /// kernels, EPERM in sandboxes, mmap refusal) surfaces as `Err` and
    /// the caller degrades to buffered I/O.
    fn setup(entries: u32) -> std::io::Result<Ring> {
        // SAFETY: params is a zeroed struct the kernel fills in.
        let mut p: sys::IoUringParams = unsafe { std::mem::zeroed() };
        // SAFETY: valid pointer to params; kernel validates entries.
        let rc = unsafe {
            sys::syscall(sys::SYS_IO_URING_SETUP, entries, &mut p as *mut sys::IoUringParams)
        };
        if rc < 0 {
            return Err(std::io::Error::last_os_error());
        }
        let fd = rc as i32;
        match Ring::map_rings(fd, &p) {
            Ok(ring) => Ok(ring),
            Err(e) => {
                // SAFETY: fd is the live ring descriptor we just created.
                unsafe { sys::close(fd) };
                Err(e)
            }
        }
    }

    fn map_rings(fd: i32, p: &sys::IoUringParams) -> std::io::Result<Ring> {
        let sq_size = p.sq_off.array as usize + p.sq_entries as usize * 4;
        let cq_size =
            p.cq_off.cqes as usize + p.cq_entries as usize * std::mem::size_of::<sys::Cqe>();
        let single = p.features & sys::IORING_FEAT_SINGLE_MMAP != 0;
        let sq_map_len = if single { sq_size.max(cq_size) } else { sq_size };
        let sq_ring = Mapping::map(fd, sq_map_len, sys::IORING_OFF_SQ_RING)?;
        let cq_ring = if single {
            None
        } else {
            Some(Mapping::map(fd, cq_size, sys::IORING_OFF_CQ_RING)?)
        };
        let sqes = Mapping::map(
            fd,
            p.sq_entries as usize * std::mem::size_of::<sys::Sqe>(),
            sys::IORING_OFF_SQES,
        )?;
        let sqp = sq_ring.ptr;
        let cqp = cq_ring.as_ref().map(|m| m.ptr).unwrap_or(sq_ring.ptr);
        // SAFETY: all offsets come from the kernel's params and lie
        // within the mappings created above.
        unsafe {
            Ok(Ring {
                fd,
                sq_tail: sqp.add(p.sq_off.tail as usize) as *const AtomicU32,
                sq_mask: *(sqp.add(p.sq_off.ring_mask as usize) as *const u32),
                sq_array: sqp.add(p.sq_off.array as usize) as *mut u32,
                cq_head: cqp.add(p.cq_off.head as usize) as *const AtomicU32,
                cq_tail: cqp.add(p.cq_off.tail as usize) as *const AtomicU32,
                cq_mask: *(cqp.add(p.cq_off.ring_mask as usize) as *const u32),
                cqes: cqp.add(p.cq_off.cqes as usize) as *const sys::Cqe,
                sqe_ptr: sqes.ptr as *mut sys::Sqe,
                _sq_ring: sq_ring,
                _cq_ring: cq_ring,
                _sqes: sqes,
                registered: false,
                reg_key: None,
                table: Vec::new(),
            })
        }
    }

    /// (Re-)register the pool's backings as the ring's fixed-buffer
    /// table. Failures (e.g. `RLIMIT_MEMLOCK`) leave the ring usable in
    /// unregistered mode; the attempt is cached per epoch either way.
    fn reregister(&mut self, core_id: usize, pool: &BufferPool) {
        let (epoch, mut table) = pool.registration_table();
        let key = (core_id, epoch);
        if self.reg_key == Some(key) {
            return;
        }
        if self.registered {
            // SAFETY: fd is live; UNREGISTER takes no argument payload.
            unsafe {
                sys::syscall(
                    sys::SYS_IO_URING_REGISTER,
                    self.fd,
                    sys::IORING_UNREGISTER_BUFFERS,
                    0usize,
                    0u32,
                )
            };
            self.registered = false;
        }
        table.sort_unstable();
        let iovecs: Vec<sys::IoVec> = table
            .iter()
            .map(|&(a, l)| sys::IoVec { base: a as *mut _, len: l })
            .collect();
        // SAFETY: iovecs describe live pool backings (pooled backings are
        // never freed — see PoolState::backings) and outlive the call.
        let rc = unsafe {
            sys::syscall(
                sys::SYS_IO_URING_REGISTER,
                self.fd,
                sys::IORING_REGISTER_BUFFERS,
                iovecs.as_ptr(),
                iovecs.len() as u32,
            )
        };
        self.registered = rc >= 0;
        self.table = if self.registered { table } else { Vec::new() };
        self.reg_key = Some(key);
    }

    /// The registered-buffer index covering `[ptr, ptr + len)`, if any.
    fn fixed_index(&self, ptr: *const u8, len: usize) -> Option<u16> {
        if !self.registered {
            return None;
        }
        let p = ptr as usize;
        let i = self.table.partition_point(|&(start, _)| start <= p);
        if i == 0 {
            return None;
        }
        let (start, blen) = self.table[i - 1];
        (p + len <= start + blen).then_some((i - 1) as u16)
    }

    /// Queue `ops` as SQEs and drain their completions with (normally)
    /// one `io_uring_enter`. `results[i]` receives op i's CQE result.
    /// Returns the number of enter syscalls taken; `Err` means the ring
    /// itself failed and must be abandoned.
    fn submit_wave(
        &mut self,
        ops: &[SqOp],
        results: &mut [i32],
        obs: &Shard,
    ) -> std::io::Result<u32> {
        let n = ops.len() as u32;
        debug_assert!(n as usize <= MAX_BATCH && n <= self.sq_mask + 1);
        let mut iovecs = [sys::IoVec { base: std::ptr::null_mut(), len: 0 }; MAX_BATCH];
        let t_submit = obs.start();
        // SAFETY (this block and below): ring pointers are valid for the
        // ring's lifetime and we are the only submitter (caller holds the
        // UringCore lock); the kernel only reads SQE slots in
        // [head, tail), which cannot include the ones being written here.
        let tail0 = unsafe { (*self.sq_tail).load(Ordering::Relaxed) };
        for (i, op) in ops.iter().enumerate() {
            let idx = tail0.wrapping_add(i as u32) & self.sq_mask;
            // SAFETY: idx is masked into the SQE array.
            let sqe = unsafe { &mut *self.sqe_ptr.add(idx as usize) };
            *sqe = unsafe { std::mem::zeroed() };
            sqe.fd = op.fd;
            sqe.off = op.offset;
            sqe.user_data = i as u64;
            match self.fixed_index(op.ptr, op.len) {
                Some(bi) => {
                    sqe.opcode = if op.write {
                        sys::IORING_OP_WRITE_FIXED
                    } else {
                        sys::IORING_OP_READ_FIXED
                    };
                    sqe.addr = op.ptr as u64;
                    sqe.len = op.len as u32;
                    sqe.buf_index = bi;
                }
                None => {
                    iovecs[i] = sys::IoVec { base: op.ptr as *mut _, len: op.len };
                    sqe.opcode =
                        if op.write { sys::IORING_OP_WRITEV } else { sys::IORING_OP_READV };
                    sqe.addr = &iovecs[i] as *const sys::IoVec as u64;
                    sqe.len = 1;
                }
            }
            // SAFETY: idx is masked into the SQ index array.
            unsafe { *self.sq_array.add(idx as usize) = idx };
        }
        // Publish the new tail (Release: SQE stores above must be visible
        // to the kernel before it sees the tail move).
        unsafe { (*self.sq_tail).store(tail0.wrapping_add(n), Ordering::Release) };
        // One syscall submits the whole wave and waits for every
        // completion (min_complete = n) — this is the batching win, and
        // it is also why completion can never outlive this call.
        let mut enters = 0u32;
        loop {
            // SAFETY: fd is live; null sigset with zero size.
            let rc = unsafe {
                sys::syscall(
                    sys::SYS_IO_URING_ENTER,
                    self.fd,
                    n,
                    n,
                    sys::IORING_ENTER_GETEVENTS,
                    std::ptr::null::<std::ffi::c_void>(),
                    0usize,
                )
            };
            enters += 1;
            if rc >= 0 {
                break;
            }
            let err = std::io::Error::last_os_error();
            if err.raw_os_error() != Some(4 /* EINTR */) {
                return Err(err);
            }
        }
        obs.record(Stage::Submit, t_submit);
        obs.gauge_depth(n as u64);
        // Drain the CQ. The enter above waited for n completions, so the
        // extra-enter loop below is belt-and-braces for CQE visibility
        // races, not the common path.
        let t_complete = obs.start();
        let mut done = 0u32;
        while done < n {
            // SAFETY: CQ pointers are valid; Acquire on tail pairs with
            // the kernel's Release publish of new CQEs.
            let tail = unsafe { (*self.cq_tail).load(Ordering::Acquire) };
            let mut head = unsafe { (*self.cq_head).load(Ordering::Relaxed) };
            while head != tail {
                // SAFETY: masked index into the CQE array.
                let cqe = unsafe { *self.cqes.add((head & self.cq_mask) as usize) };
                let ud = cqe.user_data as usize;
                if ud < results.len() {
                    results[ud] = cqe.res;
                }
                head = head.wrapping_add(1);
                done += 1;
            }
            // SAFETY: Release hands the consumed slots back to the kernel.
            unsafe { (*self.cq_head).store(head, Ordering::Release) };
            if done < n {
                // SAFETY: as above — wait for the stragglers.
                let rc = unsafe {
                    sys::syscall(
                        sys::SYS_IO_URING_ENTER,
                        self.fd,
                        0u32,
                        n - done,
                        sys::IORING_ENTER_GETEVENTS,
                        std::ptr::null::<std::ffi::c_void>(),
                        0usize,
                    )
                };
                enters += 1;
                if rc < 0 {
                    let err = std::io::Error::last_os_error();
                    if err.raw_os_error() != Some(4 /* EINTR */) {
                        return Err(err);
                    }
                }
            }
        }
        obs.record(Stage::Complete, t_complete);
        Ok(enters)
    }
}

impl Drop for Ring {
    fn drop(&mut self) {
        // SAFETY: fd is live until this close; the mappings unmap via
        // their own Drop.
        unsafe {
            sys::close(self.fd);
        }
    }
}

/// The per-[`super::FsStorage`] shared ring: created lazily by the first
/// uring stream open, shared by every stream of that storage (the mutex
/// serializes batches — each batch is submit + complete, so there is no
/// cross-stream in-flight state to entangle).
pub(crate) struct UringCore {
    ring: Mutex<Option<Ring>>,
    /// The data-plane pool whose backings get registered
    /// ([`super::Storage::register_pool`] wires it in).
    pool: Mutex<Option<BufferPool>>,
    counters: Arc<IoCounters>,
    obs: Shard,
}

impl UringCore {
    /// Set up the shared ring. `None` (with `uring_fallbacks` counted
    /// once) when the kernel refuses io_uring — the storage then serves
    /// buffered streams. `FIVER_URING_DISABLE=1` forces the refusal, so
    /// tests can exercise the degradation path on any kernel.
    pub(crate) fn create(counters: Arc<IoCounters>, obs: Shard) -> Option<Arc<UringCore>> {
        let disabled = std::env::var("FIVER_URING_DISABLE")
            .map(|v| v != "0" && !v.is_empty())
            .unwrap_or(false);
        let ring = if disabled { None } else { Ring::setup(RING_ENTRIES).ok() };
        match ring {
            Some(r) => Some(Arc::new(UringCore {
                ring: Mutex::new(Some(r)),
                pool: Mutex::new(None),
                counters,
                obs,
            })),
            None => {
                counters.uring_fallbacks.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Adopt `pool` as the registration source: its backings become the
    /// ring's fixed-buffer table (refreshed per grow epoch).
    pub(crate) fn adopt_pool(&self, pool: &BufferPool) {
        *self.pool.lock().unwrap() = Some(pool.clone());
    }

    /// Run one batch: refresh buffer registration if the pool epoch
    /// moved, submit every op (in waves of [`MAX_BATCH`]), wait for all
    /// completions. `Err(())` means the ring died — it is torn down (one
    /// `uring_fallbacks` count) and callers finish on plain descriptors.
    fn run_batch(&self, ops: &[SqOp], results: &mut [i32]) -> std::result::Result<(), ()> {
        let mut guard = self.ring.lock().unwrap();
        let Some(ring) = guard.as_mut() else { return Err(()) };
        {
            let pg = self.pool.lock().unwrap();
            if let Some(p) = pg.as_ref() {
                // Cheap epoch probe per batch; the full table snapshot +
                // register syscall runs only when the epoch moved.
                let key = (p.core_id(), p.grow_events());
                if ring.reg_key != Some(key) {
                    ring.reregister(key.0, p);
                }
            }
        }
        let mut off = 0usize;
        for wave in ops.chunks(MAX_BATCH) {
            match ring.submit_wave(wave, &mut results[off..off + wave.len()], &self.obs) {
                Ok(enters) => {
                    self.counters.uring_enters.fetch_add(enters as u64, Ordering::Relaxed);
                    self.counters.uring_ops.fetch_add(wave.len() as u64, Ordering::Relaxed);
                }
                Err(_) => {
                    // Ring-level failure: abandon it for the whole
                    // storage and count the degradation once.
                    *guard = None;
                    self.counters.uring_fallbacks.fetch_add(1, Ordering::Relaxed);
                    return Err(());
                }
            }
            off += wave.len();
        }
        Ok(())
    }
}

/// uring engine reader: readahead batches over the shared ring, plus a
/// plain descriptor for the generic ranged API, top-ups and fallback.
pub(crate) struct UringRead {
    core: Option<Arc<UringCore>>,
    file: File,
    pos: u64,
    /// Completed readahead chunks keyed by absolute file offset, in
    /// submission order — a sequential hit pops the front with zero
    /// syscalls. Capacity is reserved once (alloc-free steady state).
    ready: VecDeque<(u64, SharedBuf)>,
}

impl UringRead {
    pub(crate) fn open(path: &Path, name: &str, core: Arc<UringCore>) -> Result<UringRead> {
        let file = File::open(path).with_context(|| format!("opening {name} for read"))?;
        super::fs::advise_sequential(&file, &core.counters);
        Ok(UringRead {
            core: Some(core),
            file,
            pos: 0,
            ready: VecDeque::with_capacity(RA_DEPTH),
        })
    }

    /// Fill `buf[..want]` from `offset` via positioned reads on the plain
    /// descriptor (fallback path and short-completion top-ups).
    fn pread_fill(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        let mut total = 0;
        while total < buf.len() {
            let n = pread(&self.file, offset + total as u64, &mut buf[total..])?;
            if n == 0 {
                break;
            }
            total += n;
        }
        Ok(total)
    }
}

impl ReadStream for UringRead {
    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        self.pos = offset;
        self.read_next(buf)
    }

    fn read_next(&mut self, buf: &mut [u8]) -> Result<usize> {
        // Any non-read_shared read invalidates the prefetch run: only
        // consecutive read_shared calls may consume it, so a stream mixing
        // APIs (repair re-reads) can never observe pre-write bytes.
        self.ready.clear();
        let n = self.pread_fill(self.pos, buf)?;
        self.pos += n as u64;
        Ok(n)
    }

    fn read_shared(&mut self, offset: u64, len: usize, pool: &BufferPool) -> Result<SharedBuf> {
        // Readahead hit: the bytes are already here, zero syscalls.
        if let Some(&(o, _)) = self.ready.front() {
            if o == offset {
                let (_, shared) = self.ready.pop_front().expect("front checked");
                if shared.len() > len {
                    // Caller wants less than was prefetched; later
                    // prefetched offsets no longer line up.
                    self.ready.clear();
                    self.pos = offset + len as u64;
                    return Ok(shared.slice(0, len));
                }
                self.pos = offset + shared.len() as u64;
                return Ok(shared);
            }
            // Offset mismatch (repair re-read, random access): the
            // prefetched run is stale.
            self.ready.clear();
        }
        let mut first = pool.get_or_alloc(POOL_GRACE);
        let want = len.min(first.len());
        let Some(core) = self.core.clone() else {
            let n = self.pread_fill(offset, &mut first[..want])?;
            self.pos = offset + n as u64;
            return Ok(first.freeze(n));
        };
        // Batch a readahead run: the requested chunk plus up to
        // RA_DEPTH - 1 sequential successors — but only on the streaming
        // shape (full-buffer chunks), and only with buffers the pool can
        // spare without blocking.
        let mut bufs: [Option<PoolBuf>; RA_DEPTH] = [None, None, None, None];
        let full = want == first.len();
        bufs[0] = Some(first);
        let mut k = 1usize;
        if full {
            while k < RA_DEPTH {
                match pool.try_get() {
                    Some(b) => {
                        bufs[k] = Some(b);
                        k += 1;
                    }
                    None => break,
                }
            }
        }
        let mut ops: [Option<SqOp>; RA_DEPTH] = [None, None, None, None];
        for (i, slot) in bufs.iter_mut().take(k).enumerate() {
            let b = slot.as_mut().expect("filled above");
            ops[i] = Some(SqOp {
                write: false,
                fd: {
                    use std::os::unix::io::AsRawFd;
                    self.file.as_raw_fd()
                },
                offset: offset + (i * want) as u64,
                ptr: b.as_mut_ptr(),
                len: want,
            });
        }
        let op_arr: [SqOp; RA_DEPTH] = ops.map(|o| {
            o.unwrap_or(SqOp { write: false, fd: -1, offset: 0, ptr: std::ptr::null_mut(), len: 0 })
        });
        let mut results = [-1i32; RA_DEPTH];
        if core.run_batch(&op_arr[..k], &mut results[..k]).is_err() {
            // Ring died: this stream (and its siblings) finish buffered.
            self.core = None;
            let mut b = bufs[0].take().expect("first buffer");
            let n = self.pread_fill(offset, &mut b[..want])?;
            self.pos = offset + n as u64;
            return Ok(b.freeze(n));
        }
        for i in 0..k {
            let mut b = bufs[i].take().expect("filled above");
            let o = offset + (i * want) as u64;
            let mut n = results[i].max(0) as usize;
            if results[i] < 0 || (n > 0 && n < want) {
                // Per-op error or short completion: finish the chunk
                // through the plain descriptor (regular files only short
                // at EOF, so this is the rare path).
                n += self.pread_fill(o + n as u64, &mut b[n..want])?;
            }
            if n == 0 {
                break; // EOF: later chunks are empty too
            }
            self.ready.push_back((o, b.freeze(n)));
            if n < want {
                break; // EOF inside this chunk
            }
        }
        match self.ready.pop_front() {
            Some((_, shared)) => {
                self.pos = offset + shared.len() as u64;
                Ok(shared)
            }
            None => Ok(SharedBuf::from_vec(Vec::new())), // at/past EOF
        }
    }
}

/// uring engine writer: ranged and sequential writes submit through the
/// shared ring (repair batches as one multi-SQE wave per enter), with
/// plain positioned writes as the completion/fallback path. Every batch
/// completes before the call returns, so `sync` and the journal's
/// ordering guarantees work exactly as on the synchronous engines.
pub(crate) struct UringWrite {
    core: Option<Arc<UringCore>>,
    file: File,
    pos: u64,
    counters: Arc<IoCounters>,
}

impl UringWrite {
    pub(crate) fn create(
        path: &Path,
        name: &str,
        core: Arc<UringCore>,
        counters: Arc<IoCounters>,
    ) -> Result<UringWrite> {
        let file = File::create(path).with_context(|| format!("opening {name} for write"))?;
        Ok(UringWrite { core: Some(core), file, pos: 0, counters })
    }

    pub(crate) fn open_existing(
        path: &Path,
        name: &str,
        core: Arc<UringCore>,
        counters: Arc<IoCounters>,
    ) -> Result<UringWrite> {
        let file = OpenOptions::new()
            .write(true)
            .open(path)
            .with_context(|| format!("opening {name} for update"))?;
        Ok(UringWrite { core: Some(core), file, pos: 0, counters })
    }

    fn write_range(&mut self, offset: u64, data: &[u8]) -> Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        if let Some(core) = self.core.clone() {
            use std::os::unix::io::AsRawFd;
            let op = SqOp {
                write: true,
                fd: self.file.as_raw_fd(),
                offset,
                ptr: data.as_ptr() as *mut u8,
                len: data.len(),
            };
            let mut res = [-1i32; 1];
            if core.run_batch(std::slice::from_ref(&op), &mut res).is_err() {
                self.core = None;
                pwrite_all(&self.file, offset, data)?;
                return Ok(());
            }
            let n = res[0].max(0) as usize;
            if n < data.len() {
                // Per-op refusal or short write: complete positionally.
                pwrite_all(&self.file, offset + n as u64, &data[n..])?;
            }
            return Ok(());
        }
        pwrite_all(&self.file, offset, data)?;
        Ok(())
    }
}

impl WriteStream for UringWrite {
    fn write_at(&mut self, offset: u64, data: &[u8]) -> Result<()> {
        self.write_range(offset, data)?;
        self.pos = self.pos.max(offset + data.len() as u64);
        Ok(())
    }

    fn write_next(&mut self, data: &[u8]) -> Result<()> {
        let pos = self.pos;
        self.write_range(pos, data)?;
        self.pos = pos + data.len() as u64;
        Ok(())
    }

    fn write_at_vectored(&mut self, offset: u64, parts: &[&[u8]]) -> Result<()> {
        let total: usize = parts.iter().map(|p| p.len()).sum();
        if total == 0 {
            self.pos = self.pos.max(offset);
            return Ok(());
        }
        if let Some(core) = self.core.clone() {
            use std::os::unix::io::AsRawFd;
            let fd = self.file.as_raw_fd();
            // One SQE per part, all under (at most parts/MAX_BATCH)
            // enters — the coalesced Fix-batch analogue of pwritev.
            // Repair is the cold path, so the op list may allocate.
            let mut ops = Vec::with_capacity(parts.len());
            let mut off = offset;
            for p in parts.iter().filter(|p| !p.is_empty()) {
                ops.push(SqOp {
                    write: true,
                    fd,
                    offset: off,
                    ptr: p.as_ptr() as *mut u8,
                    len: p.len(),
                });
                off += p.len() as u64;
            }
            let mut results = vec![-1i32; ops.len()];
            if core.run_batch(&ops, &mut results).is_ok() {
                for (op, res) in ops.iter().zip(&results) {
                    let n = (*res).max(0) as usize;
                    if n < op.len {
                        // SAFETY: ptr/len describe the caller's live part.
                        let rest = unsafe {
                            std::slice::from_raw_parts(op.ptr.add(n), op.len - n)
                        };
                        pwrite_all(&self.file, op.offset + n as u64, rest)?;
                    }
                }
                self.pos = self.pos.max(offset + total as u64);
                return Ok(());
            }
            self.core = None;
        }
        let mut off = offset;
        for p in parts {
            pwrite_all(&self.file, off, p)?;
            off += p.len() as u64;
        }
        self.pos = self.pos.max(offset + total as u64);
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        Ok(())
    }

    fn sync(&mut self) -> Result<()> {
        // Every batch completed before its call returned, so fdatasync
        // covers all written bytes — data-before-watermark holds.
        self.file.sync_data()?;
        self.counters.syncs.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{FsStorage, IoBackend, Storage};

    #[test]
    fn ring_setup_and_single_batch_roundtrip() {
        // Exercise the raw ring directly when the kernel provides one
        // (skip silently where it doesn't — the conformance suite covers
        // the fallback shape).
        let Ok(mut ring) = Ring::setup(8) else { return };
        let dir = crate::util::tmpdir::unique_dir("fiver-uring-ring");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("f");
        std::fs::write(&path, vec![7u8; 8192]).unwrap();
        let file = File::open(&path).unwrap();
        use std::os::unix::io::AsRawFd;
        let mut a = vec![0u8; 4096];
        let mut b = vec![0u8; 4096];
        let ops = [
            SqOp { write: false, fd: file.as_raw_fd(), offset: 0, ptr: a.as_mut_ptr(), len: 4096 },
            SqOp {
                write: false,
                fd: file.as_raw_fd(),
                offset: 4096,
                ptr: b.as_mut_ptr(),
                len: 4096,
            },
        ];
        let mut results = [-1i32; 2];
        let enters =
            ring.submit_wave(&ops, &mut results, &Shard::disabled()).expect("wave completes");
        assert_eq!(results, [4096, 4096], "both SQEs complete fully");
        assert_eq!(enters, 1, "two ops, one io_uring_enter");
        assert!(a.iter().chain(b.iter()).all(|&x| x == 7));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn forced_disable_counts_one_fallback_and_stays_buffered() {
        let counters = IoCounters::new();
        std::env::set_var("FIVER_URING_DISABLE", "1");
        let core = UringCore::create(counters.clone(), Shard::disabled());
        std::env::remove_var("FIVER_URING_DISABLE");
        assert!(core.is_none());
        assert_eq!(counters.uring_fallbacks.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn uring_storage_roundtrips_with_registered_pool() {
        let dir = crate::util::tmpdir::unique_dir("fiver-uring-rt");
        let s = FsStorage::with_backend(&dir, IoBackend::Uring).unwrap();
        let pool = BufferPool::with_options(64 * 1024, 4, crate::storage::DIRECT_ALIGN, 8);
        s.register_pool(&pool);
        let data: Vec<u8> = (0u8..=255).cycle().take(300_000).collect();
        {
            let mut w = s.open_write_sized("f", data.len() as u64).unwrap();
            w.write_next(&data).unwrap();
            w.flush().unwrap();
            w.sync().unwrap();
        }
        let mut r = s.open_read("f").unwrap();
        let mut got = Vec::new();
        let mut off = 0u64;
        loop {
            let shared = r.read_shared(off, 64 * 1024, &pool).unwrap();
            if shared.is_empty() {
                break;
            }
            assert!(shared.len() <= 64 * 1024);
            got.extend_from_slice(&shared[..]);
            off += shared.len() as u64;
        }
        assert_eq!(got, data);
        // Whether the kernel granted a ring or not, the op/enter
        // accounting must be consistent: batched submissions never take
        // more enters than ops.
        assert!(s.uring_enters() <= s.uring_ops() || s.uring_ops() == 0);
        if s.uring_fallbacks() == 0 && s.uring_ops() > 0 {
            assert!(
                s.uring_enters() < s.uring_ops(),
                "readahead batching must amortize enters: {} enters / {} ops",
                s.uring_enters(),
                s.uring_ops()
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn registered_buffers_survive_pool_growth() {
        let dir = crate::util::tmpdir::unique_dir("fiver-uring-grow");
        let s = FsStorage::with_backend(&dir, IoBackend::Uring).unwrap();
        // Tiny pool with head-room to grow: capacity 2 -> up to 8.
        let pool = BufferPool::with_options(8192, 2, crate::storage::DIRECT_ALIGN, 8);
        s.register_pool(&pool);
        let data: Vec<u8> = (0u8..=255).cycle().take(100_000).collect();
        {
            let mut w = s.open_write("f").unwrap();
            w.write_next(&data).unwrap();
            w.flush().unwrap();
        }
        // First read registers epoch 0's table.
        {
            let mut r = s.open_read("f").unwrap();
            let shared = r.read_shared(0, 8192, &pool).unwrap();
            assert_eq!(&shared[..], &data[..8192]);
        }
        // Force adaptive growth (registration epoch moves).
        {
            let held: Vec<_> = (0..pool.capacity()).map(|_| pool.get()).collect();
            for _ in 0..=crate::coordinator::bufpool::GROW_FALLBACK_THRESHOLD {
                let _ = pool.get_or_alloc(std::time::Duration::from_millis(1));
            }
            drop(held);
        }
        assert!(pool.grow_events() >= 1);
        // Post-growth reads must re-register and stay byte-exact.
        let mut r = s.open_read("f").unwrap();
        let mut got = Vec::new();
        let mut off = 0u64;
        loop {
            let shared = r.read_shared(off, 8192, &pool).unwrap();
            if shared.is_empty() {
                break;
            }
            got.extend_from_slice(&shared[..]);
            off += shared.len() as u64;
        }
        assert_eq!(got, data, "registered-buffer path must survive pool growth");
        std::fs::remove_dir_all(&dir).ok();
    }
}
