//! Filesystem storage: one [`FsStorage`] root directory served by a
//! selectable I/O engine (see [`IoBackend`]).
//!
//! * **buffered** — positioned `pread`/`pwrite` through the page cache:
//!   every ranged access is one syscall instead of a seek + I/O pair, and
//!   ranged repair writes never disturb the sequential cursor. This is
//!   the PR 3 data plane, unchanged.
//! * **direct** — O_DIRECT-style aligned I/O (this file): reads and
//!   writes whose offset, length and buffer address are all
//!   [`DIRECT_ALIGN`]-aligned bypass the page cache entirely; everything
//!   else (file tails, unaligned repair patches) degrades per-operation
//!   to a plain descriptor of the same file, and a filesystem that
//!   refuses `O_DIRECT` altogether (tmpfs, some overlayfs) degrades the
//!   whole stream — graceful fallback, counted in
//!   [`FsStorage::direct_fallbacks`], never an error.
//! * **mmap** — memory-mapped streams, in [`super::mmap`].
//! * **uring** — io_uring batched submission-queue I/O with registered
//!   buffers, in [`super::uring`]; ring setup failure (old kernels,
//!   sandboxes) degrades to buffered, counted in
//!   [`FsStorage::uring_fallbacks`].
//! * **auto** — per-file selection: files at or above the configured
//!   direct threshold open on uring (direct if the ring is unavailable),
//!   smaller files stay buffered; [`Storage::backend_for`] reports the
//!   choice per file.
//!
//! The read-side engines also issue `posix_fadvise` streaming hints:
//! `SEQUENTIAL` at stream open, and coalesced `DONTNEED` after verified
//! spans ([`Storage::advise_done`]) so a long transfer doesn't evict the
//! rest of the machine's page cache. Hint calls are counted in
//! [`FsStorage::storage_hints`].

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
#[cfg(target_os = "linux")]
use std::sync::{Mutex, OnceLock};

use anyhow::{Context, Result};

use super::{IoBackend, ReadStream, Storage, WriteStream};
#[cfg(target_os = "linux")]
use super::DIRECT_ALIGN;
#[cfg(target_os = "linux")]
use crate::coordinator::bufpool::{BufferPool, SharedBuf, POOL_GRACE};
use crate::obs::Recorder;

/// Shared per-storage telemetry: how many times streams forced durability
/// (`sync`), how many times the direct engine had to fall back to
/// buffered I/O (open refused or an aligned op failed), the io_uring
/// engine's degradations and syscall accounting (`uring_enters` vs
/// `uring_ops` is the batching factor), and `posix_fadvise` hints issued.
pub(crate) struct IoCounters {
    pub(crate) syncs: AtomicU64,
    pub(crate) direct_fallbacks: AtomicU64,
    pub(crate) uring_fallbacks: AtomicU64,
    pub(crate) uring_enters: AtomicU64,
    pub(crate) uring_ops: AtomicU64,
    pub(crate) hints: AtomicU64,
}

impl IoCounters {
    pub(crate) fn new() -> Arc<IoCounters> {
        Arc::new(IoCounters {
            syncs: AtomicU64::new(0),
            direct_fallbacks: AtomicU64::new(0),
            uring_fallbacks: AtomicU64::new(0),
            uring_enters: AtomicU64::new(0),
            uring_ops: AtomicU64::new(0),
            hints: AtomicU64::new(0),
        })
    }
}

/// File-size floor (bytes) at which `--io-backend auto` leaves the
/// page-cache-friendly buffered engine for uring/direct. The boundary
/// is **inclusive**: a file of exactly `--direct-threshold` bytes takes
/// the uring/direct engine, one byte less stays buffered, and a
/// threshold of 0 routes every file (even empty ones) to uring/direct.
pub const DEFAULT_DIRECT_THRESHOLD: u64 = 256 << 20;

/// Minimum verified-span width before a coalesced `POSIX_FADV_DONTNEED`
/// hint is issued — per-leaf hints would cost an open + fadvise per
/// chunk, which the allocation/syscall budget of the hot path can't
/// afford; an 8 MiB batch is invisible in both.
#[cfg(target_os = "linux")]
const HINT_COALESCE: u64 = 8 << 20;

/// Real files under a root directory, accessed through the configured
/// [`IoBackend`] engine.
pub struct FsStorage {
    root: PathBuf,
    backend: IoBackend,
    counters: Arc<IoCounters>,
    /// `auto` threshold: files >= this open on uring/direct.
    threshold: u64,
    /// Obs recorder the uring engine draws its submit/complete shard from.
    #[cfg_attr(not(target_os = "linux"), allow(dead_code))]
    recorder: Recorder,
    /// Lazily-created shared io_uring ring (`None` inside = setup failed,
    /// every uring stream degrades to buffered).
    #[cfg(target_os = "linux")]
    uring: OnceLock<Option<Arc<super::uring::UringCore>>>,
    /// Pool adopted via [`Storage::register_pool`] — the source of the
    /// ring's registered-buffer table.
    #[cfg(target_os = "linux")]
    pool: Mutex<Option<BufferPool>>,
    /// Per-file verified-span bounding boxes awaiting a coalesced
    /// DONTNEED hint (see [`HINT_COALESCE`]).
    #[cfg(target_os = "linux")]
    hint_spans: Mutex<std::collections::HashMap<String, (u64, u64)>>,
}

impl FsStorage {
    /// Open a root with the backend selected by the `FIVER_IO_BACKEND`
    /// environment variable (`buffered` when unset/unknown) — this is how
    /// the CI io-backend matrix steers every FsStorage-based test and
    /// bench without touching call sites.
    pub fn new(root: &Path) -> Result<FsStorage> {
        FsStorage::with_backend(root, IoBackend::from_env())
    }

    /// Open a root with an explicit backend. Platforms without mmap /
    /// O_DIRECT / io_uring support degrade to `buffered` (graceful
    /// fallback — the transfer must run everywhere, just without the
    /// engine's edge).
    pub fn with_backend(root: &Path, backend: IoBackend) -> Result<FsStorage> {
        std::fs::create_dir_all(root)
            .with_context(|| format!("creating storage root {}", root.display()))?;
        let backend = if cfg!(target_os = "linux") { backend } else { IoBackend::Buffered };
        Ok(FsStorage {
            root: root.to_path_buf(),
            backend,
            counters: IoCounters::new(),
            threshold: DEFAULT_DIRECT_THRESHOLD,
            recorder: Recorder::disabled(),
            #[cfg(target_os = "linux")]
            uring: OnceLock::new(),
            #[cfg(target_os = "linux")]
            pool: Mutex::new(None),
            #[cfg(target_os = "linux")]
            hint_spans: Mutex::new(std::collections::HashMap::new()),
        })
    }

    /// Set the `auto` engine's size threshold (`--direct-threshold`).
    /// Inclusive boundary: `size >= threshold` routes uring/direct, so 0
    /// means "always" (see [`DEFAULT_DIRECT_THRESHOLD`]).
    pub fn with_threshold(mut self, threshold: u64) -> FsStorage {
        self.threshold = threshold;
        self
    }

    /// Attach an obs recorder: the uring engine's submit/complete spans
    /// and queue-depth gauge land on its `storage-uring` shard.
    pub fn with_recorder(mut self, recorder: Recorder) -> FsStorage {
        self.recorder = recorder;
        self
    }

    /// The effective engine (after any platform degrade).
    pub fn backend(&self) -> IoBackend {
        self.backend
    }

    /// Times the direct engine fell back to buffered I/O.
    pub fn direct_fallbacks(&self) -> u64 {
        self.counters.direct_fallbacks.load(Ordering::Relaxed)
    }

    /// Times the uring engine fell back to buffered I/O (ring setup
    /// refused, or a ring died mid-transfer).
    pub fn uring_fallbacks(&self) -> u64 {
        self.counters.uring_fallbacks.load(Ordering::Relaxed)
    }

    /// `io_uring_enter` syscalls taken (batching denominator).
    pub fn uring_enters(&self) -> u64 {
        self.counters.uring_enters.load(Ordering::Relaxed)
    }

    /// I/O operations completed through the ring (batching numerator —
    /// `uring_ops / uring_enters` is the realized batch factor).
    pub fn uring_ops(&self) -> u64 {
        self.counters.uring_ops.load(Ordering::Relaxed)
    }

    /// `posix_fadvise` streaming hints issued (SEQUENTIAL + DONTNEED).
    pub fn storage_hints(&self) -> u64 {
        self.counters.hints.load(Ordering::Relaxed)
    }

    fn path(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }

    /// The shared ring, created on first use. `None` = setup failed
    /// (counted once); uring opens then serve buffered streams.
    #[cfg(target_os = "linux")]
    fn uring_core(&self) -> Option<Arc<super::uring::UringCore>> {
        self.uring
            .get_or_init(|| {
                let core = super::uring::UringCore::create(
                    self.counters.clone(),
                    self.recorder.shard("storage-uring"),
                );
                if let Some(c) = core.as_ref() {
                    if let Some(p) = self.pool.lock().unwrap().as_ref() {
                        c.adopt_pool(p);
                    }
                }
                core
            })
            .clone()
    }

    /// Resolve the engine for one file: `auto` picks by size (uring when
    /// the ring is up, direct otherwise, buffered strictly below the
    /// threshold — `size >= threshold` is the pinned boundary, so a file
    /// of exactly the threshold is never buffered and threshold 0 sends
    /// everything to uring/direct); explicit backends pass through.
    fn resolve(&self, size: u64) -> IoBackend {
        match self.backend {
            IoBackend::Auto => {
                if size >= self.threshold {
                    #[cfg(target_os = "linux")]
                    {
                        if self.uring_core().is_some() {
                            return IoBackend::Uring;
                        }
                        return IoBackend::Direct;
                    }
                    #[cfg(not(target_os = "linux"))]
                    IoBackend::Buffered
                } else {
                    IoBackend::Buffered
                }
            }
            b => b,
        }
    }

    fn size_on_disk(&self, name: &str) -> u64 {
        std::fs::metadata(self.path(name)).map(|m| m.len()).unwrap_or(0)
    }

    /// Issue the coalesced DONTNEED for `[offset, offset + len)` of
    /// `name` (`len == 0` = to EOF). Failure is a non-event: hints are
    /// advisory.
    #[cfg(target_os = "linux")]
    fn fadvise_dontneed(&self, name: &str, offset: u64, len: u64) {
        use std::os::unix::io::AsRawFd;
        if let Ok(f) = File::open(self.path(name)) {
            // SAFETY: fd is live for the call; constants match the ABI.
            let rc = unsafe {
                fadv_sys::posix_fadvise(
                    f.as_raw_fd(),
                    offset as i64,
                    len as i64,
                    fadv_sys::POSIX_FADV_DONTNEED,
                )
            };
            if rc == 0 {
                self.counters.hints.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Tell the kernel this descriptor will be read sequentially (readahead
/// doubles on most kernels). Advisory: refusal is ignored.
#[cfg(target_os = "linux")]
pub(crate) fn advise_sequential(f: &File, counters: &IoCounters) {
    use std::os::unix::io::AsRawFd;
    // SAFETY: fd is live for the call; constants match the ABI.
    let rc = unsafe {
        fadv_sys::posix_fadvise(f.as_raw_fd(), 0, 0, fadv_sys::POSIX_FADV_SEQUENTIAL)
    };
    if rc == 0 {
        counters.hints.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(target_os = "linux")]
mod fadv_sys {
    /// Expect sequential access — kernel may double readahead.
    pub const POSIX_FADV_SEQUENTIAL: i32 = 2;
    /// The given range will not be accessed again — drop cached pages.
    pub const POSIX_FADV_DONTNEED: i32 = 4;

    extern "C" {
        /// Page-cache usage hint — see `posix_fadvise(2)`.
        pub fn posix_fadvise(fd: i32, offset: i64, len: i64, advice: i32) -> i32;
    }
}

impl FsStorage {
    fn open_read_buffered(&self, path: &Path, name: &str) -> Result<Box<dyn ReadStream>> {
        let f = File::open(path).with_context(|| format!("opening {name} for read"))?;
        #[cfg(target_os = "linux")]
        advise_sequential(&f, &self.counters);
        Ok(Box::new(FsRead { f, pos: 0 }))
    }

    fn open_write_buffered(&self, path: &Path, name: &str) -> Result<Box<dyn WriteStream>> {
        let f = File::create(path).with_context(|| format!("opening {name} for write"))?;
        Ok(Box::new(FsWrite { f, pos: 0, counters: self.counters.clone() }))
    }

    fn open_update_buffered(&self, path: &Path, name: &str) -> Result<Box<dyn WriteStream>> {
        let f = OpenOptions::new()
            .write(true)
            .open(path)
            .with_context(|| format!("opening {name} for update"))?;
        Ok(Box::new(FsWrite { f, pos: 0, counters: self.counters.clone() }))
    }
}

impl Storage for FsStorage {
    fn open_read(&self, name: &str) -> Result<Box<dyn ReadStream>> {
        let path = self.path(name);
        match self.resolve(self.size_on_disk(name)) {
            IoBackend::Buffered | IoBackend::Auto => self.open_read_buffered(&path, name),
            #[cfg(target_os = "linux")]
            IoBackend::Mmap => Ok(Box::new(super::mmap::MmapRead::open(&path, name)?)),
            #[cfg(target_os = "linux")]
            IoBackend::Direct => {
                Ok(Box::new(DirectRead::open(&path, name, self.counters.clone())?))
            }
            #[cfg(target_os = "linux")]
            IoBackend::Uring => match self.uring_core() {
                Some(core) => Ok(Box::new(super::uring::UringRead::open(&path, name, core)?)),
                None => self.open_read_buffered(&path, name),
            },
            #[cfg(not(target_os = "linux"))]
            _ => unreachable!("non-buffered backends degrade at construction"),
        }
    }

    fn open_write(&self, name: &str) -> Result<Box<dyn WriteStream>> {
        self.open_write_sized(name, 0)
    }

    fn open_write_sized(&self, name: &str, size_hint: u64) -> Result<Box<dyn WriteStream>> {
        let path = self.path(name);
        match self.resolve(size_hint) {
            IoBackend::Buffered | IoBackend::Auto => self.open_write_buffered(&path, name),
            #[cfg(target_os = "linux")]
            IoBackend::Mmap => Ok(Box::new(super::mmap::MmapWrite::create(
                &path,
                name,
                size_hint,
                self.counters.clone(),
            )?)),
            #[cfg(target_os = "linux")]
            IoBackend::Direct => {
                Ok(Box::new(DirectWrite::create(&path, name, self.counters.clone())?))
            }
            #[cfg(target_os = "linux")]
            IoBackend::Uring => match self.uring_core() {
                Some(core) => Ok(Box::new(super::uring::UringWrite::create(
                    &path,
                    name,
                    core,
                    self.counters.clone(),
                )?)),
                None => self.open_write_buffered(&path, name),
            },
            #[cfg(not(target_os = "linux"))]
            _ => unreachable!("non-buffered backends degrade at construction"),
        }
    }

    fn open_update(&self, name: &str) -> Result<Box<dyn WriteStream>> {
        let path = self.path(name);
        match self.resolve(self.size_on_disk(name)) {
            IoBackend::Buffered | IoBackend::Auto => self.open_update_buffered(&path, name),
            #[cfg(target_os = "linux")]
            IoBackend::Mmap => {
                Ok(Box::new(super::mmap::MmapWrite::open_existing(
                    &path,
                    name,
                    self.counters.clone(),
                )?))
            }
            #[cfg(target_os = "linux")]
            IoBackend::Direct => {
                Ok(Box::new(DirectWrite::open_existing(&path, name, self.counters.clone())?))
            }
            #[cfg(target_os = "linux")]
            IoBackend::Uring => match self.uring_core() {
                Some(core) => Ok(Box::new(super::uring::UringWrite::open_existing(
                    &path,
                    name,
                    core,
                    self.counters.clone(),
                )?)),
                None => self.open_update_buffered(&path, name),
            },
            #[cfg(not(target_os = "linux"))]
            _ => unreachable!("non-buffered backends degrade at construction"),
        }
    }

    fn size_of(&self, name: &str) -> Result<u64> {
        Ok(std::fs::metadata(self.path(name))
            .with_context(|| format!("stat {name}"))?
            .len())
    }

    fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    fn sync_count(&self) -> u64 {
        self.counters.syncs.load(Ordering::Relaxed)
    }

    fn direct_fallbacks(&self) -> u64 {
        self.counters.direct_fallbacks.load(Ordering::Relaxed)
    }

    fn uring_fallbacks(&self) -> u64 {
        self.counters.uring_fallbacks.load(Ordering::Relaxed)
    }

    fn hint_count(&self) -> u64 {
        self.counters.hints.load(Ordering::Relaxed)
    }

    fn backend_for(&self, name: &str) -> &'static str {
        self.resolve(self.size_on_disk(name)).name()
    }

    #[cfg(target_os = "linux")]
    fn register_pool(&self, pool: &BufferPool) {
        *self.pool.lock().unwrap() = Some(pool.clone());
        // If the ring already exists, re-point it; otherwise uring_core()
        // adopts the stashed pool at creation.
        if let Some(Some(core)) = self.uring.get() {
            core.adopt_pool(pool);
        }
    }

    fn advise_done(&self, name: &str, offset: u64, len: u64) -> Result<()> {
        #[cfg(target_os = "linux")]
        {
            // The mmap engine keeps live zero-copy views over the file
            // (delta copy ranges, verify reads) — evicting pages under
            // them would just fault them straight back in.
            if self.backend == IoBackend::Mmap {
                return Ok(());
            }
            if len == 0 {
                // Whole file verified: flush immediately, drop any
                // partial bounding box.
                self.hint_spans.lock().unwrap().remove(name);
                self.fadvise_dontneed(name, 0, 0);
                return Ok(());
            }
            // Coalesce per-leaf spans into a per-file bounding box and
            // only hint once it spans HINT_COALESCE bytes — the hot
            // path stays free of per-chunk opens.
            let flush = {
                let mut spans = self.hint_spans.lock().unwrap();
                let (lo, hi) = spans
                    .entry(name.to_string())
                    .or_insert((offset, offset + len));
                *lo = (*lo).min(offset);
                *hi = (*hi).max(offset + len);
                if *hi - *lo >= HINT_COALESCE {
                    spans.remove(name)
                } else {
                    None
                }
            };
            if let Some((lo, hi)) = flush {
                self.fadvise_dontneed(name, lo, hi - lo);
            }
        }
        #[cfg(not(target_os = "linux"))]
        {
            let _ = (name, offset, len);
        }
        Ok(())
    }

    fn sync_file(&self, name: &str) -> Result<()> {
        // fdatasync on any descriptor of the inode flushes every dirty
        // page of the file — including pages dirtied through a MAP_SHARED
        // mapping held by a different stream (the page cache is unified).
        // This is what lets the journal's data-before-watermark ordering
        // run from the hash job while the stream writer owns the mapping.
        let f = File::open(self.path(name))
            .with_context(|| format!("opening {name} for sync"))?;
        f.sync_data().with_context(|| format!("sync of {name}"))?;
        self.counters.syncs.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn rename(&self, from: &str, to: &str) -> Result<()> {
        std::fs::rename(self.path(from), self.path(to))
            .with_context(|| format!("renaming {from} over {to}"))
    }
}

/// Positioned read of one range: `pread` on Unix (no seek, kernel cursor
/// untouched), seek + read elsewhere.
pub(crate) fn pread(f: &File, offset: u64, buf: &mut [u8]) -> std::io::Result<usize> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        f.read_at(buf, offset)
    }
    #[cfg(not(unix))]
    {
        use std::io::{Read, Seek, SeekFrom};
        let mut f = f;
        f.seek(SeekFrom::Start(offset))?;
        f.read(buf)
    }
}

/// Positioned write of one range: `pwrite` on Unix, seek + write elsewhere.
pub(crate) fn pwrite_all(f: &File, offset: u64, data: &[u8]) -> std::io::Result<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        f.write_all_at(data, offset)
    }
    #[cfg(not(unix))]
    {
        use std::io::{Seek, SeekFrom, Write};
        let mut f = f;
        f.seek(SeekFrom::Start(offset))?;
        f.write_all(data)
    }
}

/// Filesystem reader with an explicit cursor: sequential reads advance it,
/// ranged reads reposition it — every access is a single positioned-I/O
/// syscall (the same cursor semantics as the in-memory backend).
struct FsRead {
    f: File,
    pos: u64,
}

impl ReadStream for FsRead {
    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        self.pos = offset;
        self.read_next(buf)
    }

    fn read_next(&mut self, buf: &mut [u8]) -> Result<usize> {
        let mut total = 0;
        while total < buf.len() {
            let n = pread(&self.f, self.pos, &mut buf[total..])?;
            if n == 0 {
                break;
            }
            total += n;
            self.pos += n as u64;
        }
        Ok(total)
    }
}

/// Filesystem writer with an explicit append cursor. Ranged writes
/// (`write_at`) land without touching the cursor beyond keeping it at the
/// logical end, so repair writes interleave freely with a sequential
/// stream.
struct FsWrite {
    f: File,
    pos: u64,
    counters: Arc<IoCounters>,
}

impl WriteStream for FsWrite {
    fn write_at(&mut self, offset: u64, data: &[u8]) -> Result<()> {
        pwrite_all(&self.f, offset, data)?;
        self.pos = self.pos.max(offset + data.len() as u64);
        Ok(())
    }

    fn write_next(&mut self, data: &[u8]) -> Result<()> {
        pwrite_all(&self.f, self.pos, data)?;
        self.pos += data.len() as u64;
        Ok(())
    }

    fn write_at_vectored(&mut self, offset: u64, parts: &[&[u8]]) -> Result<()> {
        let total: usize = parts.iter().map(|p| p.len()).sum();
        if total == 0 {
            self.pos = self.pos.max(offset);
            return Ok(());
        }
        // One pwritev where the platform has it; whatever it didn't take
        // (short write, >IOV_MAX parts, or no pwritev at all) finishes as
        // positioned per-part writes.
        let written = pwritev_once(&self.f, offset, parts).unwrap_or(0);
        write_parts_at(&self.f, offset, parts, written)?;
        self.pos = self.pos.max(offset + total as u64);
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        self.f.flush()?;
        Ok(())
    }

    fn sync(&mut self) -> Result<()> {
        self.f.sync_data()?;
        self.counters.syncs.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }
}

/// Write `parts` as one contiguous span starting at `offset`, skipping
/// the first `skip` bytes (already written by a vectored call).
fn write_parts_at(f: &File, offset: u64, parts: &[&[u8]], mut skip: usize) -> Result<()> {
    let mut off = offset;
    for p in parts {
        if skip >= p.len() {
            skip -= p.len();
            off += p.len() as u64;
            continue;
        }
        pwrite_all(f, off + skip as u64, &p[skip..])?;
        off += p.len() as u64;
        skip = 0;
    }
    Ok(())
}

#[cfg(target_os = "linux")]
mod vec_sys {
    use std::ffi::c_void;

    /// One `struct iovec` entry for `pwritev(2)`.
    #[repr(C)]
    pub struct IoVec {
        /// Start of the buffer.
        pub base: *const c_void,
        /// Length in bytes.
        pub len: usize,
    }

    extern "C" {
        /// Vectored positional write — see `pwritev(2)`.
        pub fn pwritev(fd: i32, iov: *const IoVec, iovcnt: i32, offset: i64) -> isize;
    }
}

/// One `pwritev` of up to IOV_MAX slices; returns the bytes it accepted.
#[cfg(target_os = "linux")]
fn pwritev_once(f: &File, offset: u64, parts: &[&[u8]]) -> std::io::Result<usize> {
    use std::os::unix::io::AsRawFd;
    const MAX_IOV: usize = 1024;
    let iovs: Vec<vec_sys::IoVec> = parts
        .iter()
        .take(MAX_IOV)
        .map(|p| vec_sys::IoVec { base: p.as_ptr() as *const _, len: p.len() })
        .collect();
    // SAFETY: iovs points at live slices for the duration of the call.
    let n = unsafe {
        vec_sys::pwritev(f.as_raw_fd(), iovs.as_ptr(), iovs.len() as i32, offset as i64)
    };
    if n < 0 {
        return Err(std::io::Error::last_os_error());
    }
    Ok(n as usize)
}

#[cfg(not(target_os = "linux"))]
fn pwritev_once(_f: &File, _offset: u64, _parts: &[&[u8]]) -> std::io::Result<usize> {
    Ok(0) // no vectored syscall: the per-part path writes everything
}

// ---------------------------------------------------------------------------
// Direct (O_DIRECT) engine
// ---------------------------------------------------------------------------

/// `O_DIRECT` open flag (architecture-specific on Linux; 0 = unknown arch,
/// which turns the direct engine into plain buffered I/O — fallback, not
/// failure).
#[cfg(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "x86", target_arch = "riscv64")
))]
const O_DIRECT: i32 = 0o40000;
#[cfg(all(target_os = "linux", any(target_arch = "aarch64", target_arch = "arm")))]
const O_DIRECT: i32 = 0o200000;
#[cfg(all(
    target_os = "linux",
    not(any(
        target_arch = "x86_64",
        target_arch = "x86",
        target_arch = "riscv64",
        target_arch = "aarch64",
        target_arch = "arm"
    ))
))]
const O_DIRECT: i32 = 0;

/// Try to open `path` with `O_DIRECT` for the given access mode; `None`
/// when the flag is unknown here or the filesystem refuses it (tmpfs and
/// some overlay mounts do) — callers degrade to the plain descriptor.
#[cfg(target_os = "linux")]
fn open_direct(path: &Path, write: bool, counters: &IoCounters) -> Option<File> {
    use std::os::unix::fs::OpenOptionsExt;
    if O_DIRECT == 0 {
        counters.direct_fallbacks.fetch_add(1, Ordering::Relaxed);
        return None;
    }
    let mut opts = OpenOptions::new();
    if write {
        opts.write(true);
    } else {
        opts.read(true);
    }
    match opts.custom_flags(O_DIRECT).open(path) {
        Ok(f) => Some(f),
        Err(_) => {
            counters.direct_fallbacks.fetch_add(1, Ordering::Relaxed);
            None
        }
    }
}

/// Is this operation eligible for direct I/O? O_DIRECT requires the file
/// offset, the transfer length and the user buffer address to all be
/// block-aligned.
#[cfg(target_os = "linux")]
fn direct_eligible(offset: u64, len: usize, ptr: *const u8) -> bool {
    len > 0
        && offset % DIRECT_ALIGN as u64 == 0
        && len % DIRECT_ALIGN == 0
        && (ptr as usize) % DIRECT_ALIGN == 0
}

/// Direct-engine reader: aligned `read_shared` requests bypass the page
/// cache through the O_DIRECT descriptor; the generic ranged/sequential
/// API (arbitrary offsets and buffers) reads through the plain one.
#[cfg(target_os = "linux")]
pub(crate) struct DirectRead {
    direct: Option<File>,
    plain: File,
    pos: u64,
    counters: Arc<IoCounters>,
}

#[cfg(target_os = "linux")]
impl DirectRead {
    pub(crate) fn open(path: &Path, name: &str, counters: Arc<IoCounters>) -> Result<DirectRead> {
        let plain = File::open(path).with_context(|| format!("opening {name} for read"))?;
        advise_sequential(&plain, &counters);
        let direct = open_direct(path, false, &counters);
        Ok(DirectRead { direct, plain, pos: 0, counters })
    }
}

#[cfg(target_os = "linux")]
impl ReadStream for DirectRead {
    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        self.pos = offset;
        self.read_next(buf)
    }

    fn read_next(&mut self, buf: &mut [u8]) -> Result<usize> {
        let mut total = 0;
        while total < buf.len() {
            let n = pread(&self.plain, self.pos, &mut buf[total..])?;
            if n == 0 {
                break;
            }
            total += n;
            self.pos += n as u64;
        }
        Ok(total)
    }

    fn read_shared(
        &mut self,
        offset: u64,
        len: usize,
        pool: &BufferPool,
    ) -> Result<SharedBuf> {
        let mut buf = pool.get_or_alloc(POOL_GRACE);
        let want = len.min(buf.len());
        // The aligned fast path: round the request up to a whole block
        // (O_DIRECT's length rule; EOF still returns short) and read
        // through the uncached descriptor straight into the aligned
        // pooled buffer. Anything unaligned takes the plain descriptor.
        if let Some(df) = self.direct.take() {
            let aligned_cap = buf.len() - buf.len() % DIRECT_ALIGN;
            let want_up = want.div_ceil(DIRECT_ALIGN) * DIRECT_ALIGN;
            if want_up <= aligned_cap && direct_eligible(offset, want_up, buf.as_ptr()) {
                let mut total = 0usize;
                let mut failed = false;
                while total < want_up {
                    match pread(&df, offset + total as u64, &mut buf[total..want_up]) {
                        Ok(0) => break,
                        Ok(n) => total += n,
                        Err(_) => {
                            // Filesystem rejected the direct op mid-file:
                            // degrade this stream to buffered for good.
                            failed = true;
                            break;
                        }
                    }
                }
                if !failed {
                    self.direct = Some(df);
                    let n = total.min(want);
                    self.pos = offset + n as u64;
                    return Ok(buf.freeze(n));
                }
                self.counters.direct_fallbacks.fetch_add(1, Ordering::Relaxed);
            } else {
                self.direct = Some(df);
            }
        }
        let n = self.read_at(offset, &mut buf[..want])?;
        Ok(buf.freeze(n))
    }
}

/// Direct-engine writer: fully aligned ranged writes go through the
/// O_DIRECT descriptor; tails, repairs and anything unaligned take the
/// plain one (the page cache keeps the two views coherent).
#[cfg(target_os = "linux")]
pub(crate) struct DirectWrite {
    direct: Option<File>,
    plain: File,
    pos: u64,
    counters: Arc<IoCounters>,
}

#[cfg(target_os = "linux")]
impl DirectWrite {
    pub(crate) fn create(
        path: &Path,
        name: &str,
        counters: Arc<IoCounters>,
    ) -> Result<DirectWrite> {
        let plain = File::create(path).with_context(|| format!("opening {name} for write"))?;
        let direct = open_direct(path, true, &counters);
        Ok(DirectWrite { direct, plain, pos: 0, counters })
    }

    pub(crate) fn open_existing(
        path: &Path,
        name: &str,
        counters: Arc<IoCounters>,
    ) -> Result<DirectWrite> {
        let plain = OpenOptions::new()
            .write(true)
            .open(path)
            .with_context(|| format!("opening {name} for update"))?;
        let direct = open_direct(path, true, &counters);
        Ok(DirectWrite { direct, plain, pos: 0, counters })
    }

    fn write_range(&mut self, offset: u64, data: &[u8]) -> Result<()> {
        if let Some(df) = self.direct.take() {
            if direct_eligible(offset, data.len(), data.as_ptr()) {
                match pwrite_all(&df, offset, data) {
                    Ok(()) => {
                        self.direct = Some(df);
                        return Ok(());
                    }
                    Err(_) => {
                        // Degrade this stream to buffered for good.
                        self.counters.direct_fallbacks.fetch_add(1, Ordering::Relaxed);
                    }
                }
            } else {
                self.direct = Some(df);
            }
        }
        pwrite_all(&self.plain, offset, data)?;
        Ok(())
    }
}

#[cfg(target_os = "linux")]
impl WriteStream for DirectWrite {
    fn write_at(&mut self, offset: u64, data: &[u8]) -> Result<()> {
        self.write_range(offset, data)?;
        self.pos = self.pos.max(offset + data.len() as u64);
        Ok(())
    }

    fn write_next(&mut self, data: &[u8]) -> Result<()> {
        let pos = self.pos;
        self.write_range(pos, data)?;
        self.pos = pos + data.len() as u64;
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        self.plain.flush()?;
        Ok(())
    }

    fn sync(&mut self) -> Result<()> {
        // The plain descriptor's fdatasync covers the direct writes too:
        // O_DIRECT data already bypassed the cache, and fdatasync flushes
        // whatever the unaligned tail writes left dirty.
        self.plain.sync_data()?;
        self.counters.syncs.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }
}
