//! Page-cache model with LRU eviction and hit/miss accounting.
//!
//! The paper's motivating observation (§III, Fig 1) is that the OS page
//! cache serves checksum reads from memory whenever a file fits in free
//! memory — so "read the file again after transfer" does **not** re-read
//! the disk, and FIVER's queue sharing gives the same integrity guarantee
//! as the sequential re-read. Conversely, files *larger* than free memory
//! are evicted while they stream, so the sequential re-read genuinely hits
//! the disk (the property FIVER-Hybrid preserves, Fig 9).
//!
//! The model tracks cached extents per file at a configurable granularity
//! (default 1 MiB — fine enough for the paper's figures, coarse enough to
//! simulate 165 GB datasets cheaply) with global LRU ordering. Sequential
//! streaming I/O (the only pattern file transfer produces) makes LRU ==
//! insertion order, and reproduces the emergent behaviours the paper leans
//! on, including the self-eviction of a larger-than-memory file during its
//! own re-read (hit ratio ~0%).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Identifies a file in the cache (workload files are numbered).
pub type FileId = u64;

/// Result of a cache access: how many bytes hit vs missed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Access {
    /// Bytes served from cache.
    pub hit_bytes: u64,
    /// Bytes that missed and went to disk.
    pub miss_bytes: u64,
}

impl Access {
    /// Total bytes accessed.
    pub fn total(&self) -> u64 {
        self.hit_bytes + self.miss_bytes
    }

    /// Fraction of bytes served from cache (0 for an empty access).
    pub fn hit_ratio(&self) -> f64 {
        if self.total() == 0 {
            1.0
        } else {
            self.hit_bytes as f64 / self.total() as f64
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
struct Extent {
    file: FileId,
    /// Extent index within the file (offset / granularity).
    index: u64,
}

/// LRU page cache over fixed-granularity extents.
///
/// LRU is implemented with lazy-invalidated heap entries (amortized
/// O(log n) per access): each touch stamps the extent with a fresh counter
/// and pushes a heap entry; eviction pops entries until one's stamp matches
/// the extent's current stamp. This keeps 165 GB simulated datasets cheap.
#[derive(Debug)]
pub struct PageCache {
    capacity_bytes: u64,
    granularity: u64,
    /// Min-heap of (stamp, extent); stale entries are skipped on pop.
    lru: BinaryHeap<Reverse<(u64, Extent)>>,
    /// Residency set; value is the extent's latest touch stamp.
    resident: HashMap<Extent, u64>,
    clock: u64,
    used_bytes: u64,
    /// Lifetime counters.
    pub total_hits: u64,
    /// Lifetime bytes that missed the cache.
    pub total_misses: u64,
}

impl PageCache {
    /// `capacity_bytes` models *free* memory available to the page cache.
    pub fn new(capacity_bytes: u64) -> PageCache {
        Self::with_granularity(capacity_bytes, 1 << 20)
    }

    /// A cache tracking residency in `granularity`-byte extents.
    pub fn with_granularity(capacity_bytes: u64, granularity: u64) -> PageCache {
        assert!(granularity > 0);
        PageCache {
            capacity_bytes,
            granularity,
            lru: BinaryHeap::new(),
            resident: HashMap::new(),
            clock: 0,
            used_bytes: 0,
            total_hits: 0,
            total_misses: 0,
        }
    }

    /// Configured capacity in bytes.
    pub fn capacity(&self) -> u64 {
        self.capacity_bytes
    }

    /// Resident bytes.
    pub fn used(&self) -> u64 {
        self.used_bytes
    }

    fn extents_of(&self, file: FileId, offset: u64, len: u64) -> impl Iterator<Item = Extent> + '_ {
        let first = offset / self.granularity;
        let last = (offset + len).div_ceil(self.granularity);
        (first..last).map(move |index| Extent { file, index })
    }

    fn touch(&mut self, e: Extent) {
        self.clock += 1;
        let stamp = self.clock;
        if let Some(s) = self.resident.get_mut(&e) {
            // Refresh to MRU: new stamp; the old heap entry goes stale.
            *s = stamp;
            self.lru.push(Reverse((stamp, e)));
            return;
        }
        // Insert, evicting true-LRU extents until it fits.
        while self.used_bytes + self.granularity > self.capacity_bytes {
            match self.lru.pop() {
                Some(Reverse((s, old))) => {
                    if self.resident.get(&old) == Some(&s) {
                        self.resident.remove(&old);
                        self.used_bytes -= self.granularity;
                    }
                    // else: stale entry, skip
                }
                None => return, // capacity smaller than one extent: uncacheable
            }
        }
        self.resident.insert(e, stamp);
        self.lru.push(Reverse((stamp, e)));
        self.used_bytes += self.granularity;
    }

    /// A read of `[offset, offset+len)` of `file`: counts hits/misses and
    /// populates the cache (missed extents are loaded, as the kernel would).
    pub fn read(&mut self, file: FileId, offset: u64, len: u64) -> Access {
        let extents: Vec<Extent> = self.extents_of(file, offset, len).collect();
        let mut acc = Access::default();
        for e in extents {
            let bytes = self.granularity;
            if self.resident.contains_key(&e) {
                acc.hit_bytes += bytes;
            } else {
                acc.miss_bytes += bytes;
            }
            self.touch(e);
        }
        // Normalize to requested length (last extent may be partial).
        let granular_total = acc.total();
        if granular_total > 0 {
            let scale = len as f64 / granular_total as f64;
            acc.hit_bytes = (acc.hit_bytes as f64 * scale).round() as u64;
            acc.miss_bytes = len - acc.hit_bytes.min(len);
        }
        self.total_hits += acc.hit_bytes;
        self.total_misses += acc.miss_bytes;
        acc
    }

    /// A write of `[offset, offset+len)`: populates the cache (write-back
    /// page cache keeps written pages resident) without hit accounting —
    /// writes are not "page cache accesses" in the paper's hit-ratio metric.
    pub fn write(&mut self, file: FileId, offset: u64, len: u64) {
        let extents: Vec<Extent> = self.extents_of(file, offset, len).collect();
        for e in extents {
            self.touch(e);
        }
    }

    /// Bytes of `file` currently resident.
    pub fn cached_bytes(&self, file: FileId) -> u64 {
        self.resident.keys().filter(|e| e.file == file).count() as u64 * self.granularity
    }

    /// Drop a file's extents (models `posix_fadvise(DONTNEED)` / delete).
    /// Heap entries go stale and are skipped during later evictions.
    pub fn invalidate(&mut self, file: FileId) {
        let before = self.resident.len();
        self.resident.retain(|e, _| e.file != file);
        let removed = before - self.resident.len();
        self.used_bytes -= removed as u64 * self.granularity;
    }

    /// Lifetime hit ratio over all reads.
    pub fn hit_ratio(&self) -> f64 {
        let total = self.total_hits + self.total_misses;
        if total == 0 {
            1.0
        } else {
            self.total_hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1 << 20;

    #[test]
    fn cold_read_misses_then_hits() {
        let mut c = PageCache::new(100 * MB);
        let a = c.read(1, 0, 10 * MB);
        assert_eq!(a.miss_bytes, 10 * MB);
        let b = c.read(1, 0, 10 * MB);
        assert_eq!(b.hit_bytes, 10 * MB);
        assert_eq!(b.hit_ratio(), 1.0);
    }

    #[test]
    fn write_populates_cache() {
        // The receiver's pattern: stream-written file is re-read for checksum.
        let mut c = PageCache::new(100 * MB);
        c.write(1, 0, 50 * MB);
        let a = c.read(1, 0, 50 * MB);
        assert_eq!(a.hit_bytes, 50 * MB, "checksum after write should be all-hit");
    }

    #[test]
    fn file_larger_than_memory_evicts_itself() {
        // Fig 1 inverse: 20 GB file through a 16 GB cache ends ~0% on re-read.
        let mut c = PageCache::new(16 * MB);
        c.write(1, 0, 20 * MB);
        // Sequential re-read in 1 MB steps, as the checksum process would.
        let mut acc = Access::default();
        for i in 0..20 {
            let a = c.read(1, i * MB, MB);
            acc.hit_bytes += a.hit_bytes;
            acc.miss_bytes += a.miss_bytes;
        }
        assert!(
            acc.hit_ratio() < 0.05,
            "self-evicting re-read should mostly miss: {}",
            acc.hit_ratio()
        );
    }

    #[test]
    fn small_file_fully_cached_after_stream() {
        let mut c = PageCache::new(64 * MB);
        c.write(7, 0, 8 * MB);
        assert_eq!(c.cached_bytes(7), 8 * MB);
        let a = c.read(7, 0, 8 * MB);
        assert_eq!(a.hit_ratio(), 1.0);
    }

    #[test]
    fn lru_evicts_oldest_file_first() {
        let mut c = PageCache::new(10 * MB);
        c.write(1, 0, 6 * MB);
        c.write(2, 0, 6 * MB); // evicts 2 MB of file 1
        assert!(c.cached_bytes(1) < 6 * MB);
        assert_eq!(c.cached_bytes(2), 6 * MB);
    }

    #[test]
    fn touch_refreshes_lru_position() {
        let mut c = PageCache::new(10 * MB);
        c.write(1, 0, 5 * MB);
        c.write(2, 0, 5 * MB);
        // Touch file 1 so file 2 becomes LRU.
        c.read(1, 0, 5 * MB);
        c.write(3, 0, 5 * MB);
        assert_eq!(c.cached_bytes(1), 5 * MB, "recently-touched survives");
        assert!(c.cached_bytes(2) < 5 * MB, "LRU evicted");
    }

    #[test]
    fn invalidate_frees_space() {
        let mut c = PageCache::new(10 * MB);
        c.write(1, 0, 8 * MB);
        c.invalidate(1);
        assert_eq!(c.cached_bytes(1), 0);
        assert_eq!(c.used(), 0);
    }

    #[test]
    fn accounting_consistency() {
        let mut c = PageCache::new(32 * MB);
        c.write(1, 0, 16 * MB);
        c.read(1, 0, 16 * MB);
        c.read(2, 0, 8 * MB);
        assert_eq!(c.total_hits + c.total_misses, 24 * MB);
        assert!(c.hit_ratio() > 0.0 && c.hit_ratio() <= 1.0);
    }

    #[test]
    fn partial_tail_extent_normalized() {
        let mut c = PageCache::new(32 * MB);
        let a = c.read(1, 0, MB + 1000); // crosses extent boundary
        assert_eq!(a.total(), MB + 1000);
    }

    #[test]
    fn zero_capacity_never_hits() {
        let mut c = PageCache::new(0);
        c.write(1, 0, MB);
        let a = c.read(1, 0, MB);
        assert_eq!(a.hit_bytes, 0);
    }
}
