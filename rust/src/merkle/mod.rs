//! Layer 3½ — streaming Merkle digest trees for O(log n) corruption
//! localization and minimal-byte repair.
//!
//! FIVER's single end-to-end digest (§IV-A) turns a one-bit wire fault into
//! a whole-file re-read + re-send + re-hash. This module folds the leaf
//! digests FIVER already computes *as chunks drain from the shared queue*
//! into a binary digest tree — zero extra file I/O, preserving the paper's
//! I/O-sharing invariant — so a root mismatch can be binary-searched down
//! to the corrupted leaves with O(log n) digest exchange, and only those
//! leaf byte ranges re-read and re-sent (hash-tree checking in the style of
//! Hübschle-Schneider & Sanders 2017; block-additive localization in the
//! spirit of the FITS checksum proposal).
//!
//! Tree shape: level 0 holds one digest per `leaf_size` byte span of the
//! file (an empty file has one empty leaf); each higher level hashes the
//! concatenation of its two children (a lone trailing child is re-hashed
//! alone, so sibling-less nodes still change when their child changes); the
//! top level is the single root. All digests come from the same [`Hasher`]
//! backend the transfer session uses, so MD5/SHA-1/SHA-256/FVR-256 and the
//! XLA-backed hasher all work unchanged.
//!
//! Tiered composition (BLAKE3-style): the leaf level and the interior
//! levels may use *different* hash backends — fast XXH3-128 leaves cut
//! from the byte stream, folded under a cryptographic root. Leaf hashing
//! is O(file bytes) while interior hashing is O(leaves x digest width), so
//! the crypto root costs next to nothing and restores the trust anchor the
//! fast tier alone lacks (DESIGN.md, "Tiered hashing"). Consequently a
//! tree has two strides: [`MerkleTree::leaf_len`] for level 0 and
//! [`MerkleTree::node_len`] for every level above; `rooted` trees fold
//! even a single leaf once more so the root is always a node-tier digest.
//!
//! Each level stores its digests as one contiguous byte vec (fixed stride
//! per level) — a 1 TB file at 64 KiB leaves holds ~32M nodes, and
//! per-node `Vec`s would triple the memory and scatter the cache.

use crate::hashes::Hasher;

/// Factory producing fresh streaming hashers — the same type as
/// [`crate::coordinator::HasherFactory`]; both are aliases of the one
/// definition in [`crate::hashes::DigestFactory`].
pub type DigestFactory = crate::hashes::DigestFactory;

/// Number of leaves a file of `file_size` bytes occupies at `leaf_size`
/// granularity (an empty file still has one leaf).
pub fn leaf_count(file_size: u64, leaf_size: u64) -> u64 {
    assert!(leaf_size > 0, "leaf_size must be positive");
    if file_size == 0 {
        1
    } else {
        file_size.div_ceil(leaf_size)
    }
}

/// Descent depth of the tree: query/response rounds a full binary search
/// from root to leaves costs (0 for a single-leaf tree whose root *is* the
/// leaf).
pub fn descent_rounds(leaves: u64) -> u32 {
    let mut rounds = 0u32;
    let mut width = leaves.max(1);
    while width > 1 {
        width = width.div_ceil(2);
        rounds += 1;
    }
    rounds
}

/// A complete binary digest tree over the leaves of one file.
#[derive(Debug, Clone)]
pub struct MerkleTree {
    leaf_size: u64,
    file_size: u64,
    /// Digest stride of level 0 (the leaf tier).
    leaf_len: usize,
    /// Digest stride of every level above 0 (the node tier).
    node_len: usize,
    /// Whether a single-leaf tree still folds into a node-tier root.
    rooted: bool,
    /// `levels[0]` = leaf digests, …, `levels.last()` = the root — each
    /// level one contiguous byte vec with `level_len(level)` stride.
    levels: Vec<Vec<u8>>,
}

impl MerkleTree {
    /// Build a tree from precomputed leaf digests (concatenated with
    /// `leaf_len` stride). Interior nodes are hashed with `node_hasher`,
    /// whose digest width becomes the node-tier stride — pass the same
    /// backend that cut the leaves for a uniform tree, or the
    /// cryptographic backend over fast leaves for a tiered one. `rooted`
    /// forces at least one fold, so even a single-leaf tree's root is a
    /// node-tier digest (required for the tiered trust anchor; uniform
    /// callers pass `false` and keep the historical leaf-is-root shape).
    pub fn from_leaves(
        leaf_size: u64,
        file_size: u64,
        leaf_len: usize,
        leaves: Vec<u8>,
        node_hasher: &DigestFactory,
        rooted: bool,
    ) -> MerkleTree {
        assert!(leaf_len > 0 && !leaves.is_empty(), "a tree needs at least one leaf");
        assert!(leaves.len() % leaf_len == 0, "ragged leaf digests");
        let node_len = node_hasher().digest_len();
        let mut tree =
            MerkleTree { leaf_size, file_size, leaf_len, node_len, rooted, levels: vec![leaves] };
        tree.build_internal(node_hasher);
        tree
    }

    fn build_internal(&mut self, hasher: &DigestFactory) {
        self.levels.truncate(1);
        let mut h = hasher();
        while self.level_width(self.levels.len() - 1) > 1
            || (self.rooted && self.levels.len() == 1)
        {
            let dlen = self.level_len(self.levels.len() - 1);
            let below = self.levels.last().unwrap();
            let mut above =
                Vec::with_capacity((below.len() / dlen).div_ceil(2) * self.node_len);
            for pair in below.chunks(2 * dlen) {
                h.reset();
                h.update(pair);
                above.extend_from_slice(&h.finalize());
            }
            self.levels.push(above);
        }
    }

    /// Number of levels (1 for a single-leaf unrooted tree).
    pub fn height(&self) -> usize {
        self.levels.len()
    }

    /// Number of leaves.
    pub fn leaf_count(&self) -> usize {
        self.levels[0].len() / self.leaf_len
    }

    /// Leaf size in bytes.
    pub fn leaf_size(&self) -> u64 {
        self.leaf_size
    }

    /// Total file size the tree covers.
    pub fn file_size(&self) -> u64 {
        self.file_size
    }

    /// Digest width of the leaf level (level 0).
    pub fn leaf_len(&self) -> usize {
        self.leaf_len
    }

    /// Digest width of the interior/root levels.
    pub fn node_len(&self) -> usize {
        self.node_len
    }

    /// Digest stride at `level` — `leaf_len` at level 0, `node_len`
    /// above. Every consumer parsing node payloads must use the width of
    /// the level it is reading; a tiered tree has two different ones.
    pub fn level_len(&self, level: usize) -> usize {
        if level == 0 {
            self.leaf_len
        } else {
            self.node_len
        }
    }

    /// The root digest.
    pub fn root(&self) -> &[u8] {
        self.levels.last().unwrap()
    }

    /// Node count at `level` (0 = leaves).
    pub fn level_width(&self, level: usize) -> usize {
        let stride = self.level_len(level);
        self.levels.get(level).map_or(0, |l| l.len() / stride)
    }

    /// Digest of node `idx` at `level` (0 = leaves).
    pub fn node(&self, level: usize, idx: usize) -> &[u8] {
        let stride = self.level_len(level);
        &self.levels[level][idx * stride..(idx + 1) * stride]
    }

    /// Concatenated digests of `[start, start+count)` at `level`, clipped
    /// to the level width — the wire payload of a node-range response.
    pub fn nodes_concat(&self, level: usize, start: usize, count: usize) -> Vec<u8> {
        let Some(nodes) = self.levels.get(level) else { return Vec::new() };
        let stride = self.level_len(level);
        let width = nodes.len() / stride;
        let end = start.saturating_add(count).min(width);
        let start = start.min(end);
        nodes[start * stride..end * stride].to_vec()
    }

    /// Byte range `(offset, len)` of leaf `idx` in the file.
    pub fn leaf_range(&self, idx: usize) -> (u64, u64) {
        let offset = idx as u64 * self.leaf_size;
        (offset, self.leaf_size.min(self.file_size.saturating_sub(offset)))
    }

    /// Leaf indices whose spans intersect `[offset, offset+len)`.
    pub fn leaves_touching(&self, offset: u64, len: u64) -> std::ops::Range<usize> {
        if len == 0 {
            return 0..0;
        }
        let first = (offset / self.leaf_size) as usize;
        let last = ((offset + len - 1) / self.leaf_size) as usize;
        first.min(self.leaf_count())..(last + 1).min(self.leaf_count())
    }

    /// Replace leaf `idx`'s digest (call [`MerkleTree::recompute_paths`]
    /// afterwards to restore internal-node consistency).
    pub fn set_leaf(&mut self, idx: usize, digest: Vec<u8>) {
        assert_eq!(digest.len(), self.leaf_len, "digest width mismatch");
        let dlen = self.leaf_len;
        self.levels[0][idx * dlen..(idx + 1) * dlen].copy_from_slice(&digest);
    }

    /// Recompute only the root-ward paths of `dirty` leaf indices —
    /// O(k log n) combines instead of an O(n) rebuild. `hasher` must be
    /// the node-tier backend (the one `from_leaves` folded with).
    pub fn recompute_paths(&mut self, dirty: &[usize], hasher: &DigestFactory) {
        if dirty.is_empty() {
            return;
        }
        let mut h = hasher();
        let mut idxs: Vec<usize> = dirty.to_vec();
        idxs.sort_unstable();
        idxs.dedup();
        for level in 0..self.levels.len() - 1 {
            let child_len = self.level_len(level);
            let node_len = self.node_len;
            let mut parents: Vec<usize> = idxs.iter().map(|i| i / 2).collect();
            parents.dedup();
            for &p in &parents {
                let lo = 2 * p * child_len;
                let hi = (lo + 2 * child_len).min(self.levels[level].len());
                h.reset();
                h.update(&self.levels[level][lo..hi]);
                let parent = h.finalize();
                self.levels[level + 1][p * node_len..(p + 1) * node_len]
                    .copy_from_slice(&parent);
            }
            idxs = parents;
        }
    }

    /// Leaf indices where the two trees disagree (helper for local diffing
    /// and tests; the wire protocol does the same search remotely).
    pub fn diff_leaves(&self, other: &MerkleTree) -> Vec<usize> {
        let dlen = self.leaf_len;
        (0..self.leaf_count())
            .filter(|&i| other.levels[0].get(i * dlen..(i + 1) * dlen) != Some(self.node(0, i)))
            .collect()
    }
}

/// Streaming tree builder: absorbs the byte stream in arbitrary buffer
/// sizes (exactly as it drains from the FIVER shared queue), cutting leaf
/// digests at `leaf_size` boundaries with a single reused hasher. By
/// default interior nodes fold with the same backend as the leaves; a
/// tiered builder ([`MerkleBuilder::with_tree_hasher`]) folds them with a
/// separate (cryptographic) backend instead.
pub struct MerkleBuilder {
    leaf_size: u64,
    digest_len: usize,
    factory: DigestFactory,
    /// Backend folding interior nodes; `None` = same as the leaf factory.
    node_factory: Option<DigestFactory>,
    /// Fold even a single leaf into a node-tier root (tiered trees).
    rooted: bool,
    hasher: Box<dyn Hasher>,
    /// Bytes absorbed into the current (open) leaf.
    filled: u64,
    total: u64,
    /// Concatenated leaf digests.
    leaves: Vec<u8>,
}

impl MerkleBuilder {
    /// A builder producing `leaf_size` leaves with `factory` digests.
    pub fn new(leaf_size: u64, factory: DigestFactory) -> MerkleBuilder {
        MerkleBuilder::with_capacity(leaf_size, 0, factory)
    }

    /// A builder whose leaf vec is pre-sized for `expected_bytes` of
    /// stream — one upfront allocation instead of O(log n) mid-stream
    /// regrowth copies for a large file (a 1 TB file at 64 KiB leaves
    /// carries ~512 MB of leaf digests through ~30 doublings otherwise).
    ///
    /// `expected_bytes` is a *hint*, and on the receiver it comes from an
    /// unvalidated FileStart size field — the reservation is clamped so a
    /// corrupt or hostile size can at worst over-reserve a bounded amount
    /// (growth past the clamp continues amortized, exactly as without the
    /// hint).
    pub fn with_capacity(
        leaf_size: u64,
        expected_bytes: u64,
        factory: DigestFactory,
    ) -> MerkleBuilder {
        assert!(leaf_size > 0, "leaf_size must be positive");
        let hasher = factory();
        let digest_len = hasher.digest_len();
        // 64 MB of leaf digests ~ a 128 GB file at 64 KiB / 32 B; beyond
        // that the doubling copies are noise relative to the stream.
        const MAX_PREALLOC_BYTES: u64 = 64 << 20;
        let expected_leaves = leaf_count(expected_bytes, leaf_size);
        let reserve = expected_leaves
            .saturating_mul(digest_len as u64)
            .min(MAX_PREALLOC_BYTES) as usize;
        MerkleBuilder {
            leaf_size,
            digest_len,
            factory,
            node_factory: None,
            rooted: false,
            hasher,
            filled: 0,
            total: 0,
            leaves: Vec::with_capacity(reserve),
        }
    }

    /// Fold interior nodes (and the root) with `node_factory` instead of
    /// the leaf backend; `rooted` additionally forces single-leaf trees to
    /// fold once, so the root is always a node-tier digest. This is the
    /// tiered-hashing composition: fast leaves under a cryptographic root.
    pub fn with_tree_hasher(mut self, node_factory: DigestFactory, rooted: bool) -> MerkleBuilder {
        self.node_factory = Some(node_factory);
        self.rooted = rooted;
        self
    }

    /// A builder seeded with precomputed digests of the stream's first
    /// complete leaves — the crash-resume path: the journaled prefix's
    /// leaves verify by root comparison without re-reading a byte, and
    /// only the tail is hashed as it streams. `prefix_bytes` must sit on
    /// a leaf boundary (the journal checkpoints complete leaves only).
    pub fn with_prefix(
        leaf_size: u64,
        prefix_leaves: Vec<u8>,
        prefix_bytes: u64,
        factory: DigestFactory,
    ) -> MerkleBuilder {
        assert!(leaf_size > 0, "leaf_size must be positive");
        let hasher = factory();
        let digest_len = hasher.digest_len();
        assert!(prefix_leaves.len() % digest_len == 0, "ragged prefix leaf digests");
        assert_eq!(
            (prefix_leaves.len() / digest_len) as u64 * leaf_size,
            prefix_bytes,
            "prefix must cover exactly its complete leaves"
        );
        MerkleBuilder {
            leaf_size,
            digest_len,
            factory,
            node_factory: None,
            rooted: false,
            hasher,
            filled: 0,
            total: prefix_bytes,
            leaves: prefix_leaves,
        }
    }

    /// Absorb the next buffer of the stream.
    pub fn update(&mut self, mut data: &[u8]) {
        while !data.is_empty() {
            let take = ((self.leaf_size - self.filled) as usize).min(data.len());
            self.hasher.update(&data[..take]);
            self.filled += take as u64;
            self.total += take as u64;
            data = &data[take..];
            if self.filled == self.leaf_size {
                self.leaves.extend_from_slice(&self.hasher.finalize());
                self.hasher.reset();
                self.filled = 0;
            }
        }
    }

    /// Bytes absorbed so far.
    pub fn bytes_seen(&self) -> u64 {
        self.total
    }

    /// Close the final (possibly partial or empty) leaf and fold the tree.
    pub fn finish(mut self) -> MerkleTree {
        if self.filled > 0 || self.leaves.is_empty() {
            self.leaves.extend_from_slice(&self.hasher.finalize());
        }
        let node_factory = self.node_factory.as_ref().unwrap_or(&self.factory);
        MerkleTree::from_leaves(
            self.leaf_size,
            self.total,
            self.digest_len,
            self.leaves,
            node_factory,
            self.rooted,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashes::HashAlgorithm;
    use crate::util::rng::SplitMix64;
    use std::sync::Arc;

    fn factory(alg: HashAlgorithm) -> DigestFactory {
        Arc::new(move || alg.hasher())
    }

    fn build(data: &[u8], leaf: u64, alg: HashAlgorithm, chunk: usize) -> MerkleTree {
        let mut b = MerkleBuilder::new(leaf, factory(alg));
        for part in data.chunks(chunk.max(1)) {
            b.update(part);
        }
        b.finish()
    }

    #[test]
    fn geometry_helpers() {
        assert_eq!(leaf_count(0, 64), 1);
        assert_eq!(leaf_count(1, 64), 1);
        assert_eq!(leaf_count(64, 64), 1);
        assert_eq!(leaf_count(65, 64), 2);
        assert_eq!(leaf_count(1000, 64), 16);
        assert_eq!(descent_rounds(1), 0);
        assert_eq!(descent_rounds(2), 1);
        assert_eq!(descent_rounds(5), 3);
        assert_eq!(descent_rounds(1024), 10);
    }

    #[test]
    fn build_is_buffering_independent() {
        let mut data = vec![0u8; 100_000];
        SplitMix64::new(7).fill_bytes(&mut data);
        for alg in HashAlgorithm::ALL {
            let a = build(&data, 4096, alg, 1000);
            let b = build(&data, 4096, alg, 4096);
            let c = build(&data, 4096, alg, 99_999);
            assert_eq!(a.root(), b.root(), "{}", alg.name());
            assert_eq!(b.root(), c.root(), "{}", alg.name());
            assert_eq!(a.leaf_count(), leaf_count(100_000, 4096) as usize);
        }
    }

    #[test]
    fn empty_and_tiny_files() {
        let empty = build(&[], 1024, HashAlgorithm::Md5, 64);
        assert_eq!(empty.leaf_count(), 1);
        assert_eq!(empty.height(), 1);
        assert_eq!(empty.root(), empty.node(0, 0));
        let one = build(&[42], 1024, HashAlgorithm::Md5, 64);
        assert_ne!(empty.root(), one.root());
    }

    #[test]
    fn level_widths_halve() {
        let data = vec![1u8; 9000];
        let t = build(&data, 1000, HashAlgorithm::Sha1, 512);
        assert_eq!(t.leaf_count(), 9);
        assert_eq!(t.level_width(0), 9);
        assert_eq!(t.level_width(1), 5);
        assert_eq!(t.level_width(2), 3);
        assert_eq!(t.level_width(3), 2);
        assert_eq!(t.level_width(4), 1);
        assert_eq!(t.height(), 5);
        assert_eq!(descent_rounds(9), 4);
    }

    #[test]
    fn single_bit_flip_localizes_to_one_leaf() {
        let mut data = vec![0u8; 64_000];
        SplitMix64::new(3).fill_bytes(&mut data);
        let clean = build(&data, 4096, HashAlgorithm::Fvr256, 7777);
        data[20_000] ^= 0x10;
        let dirty = build(&data, 4096, HashAlgorithm::Fvr256, 7777);
        assert_ne!(clean.root(), dirty.root());
        assert_eq!(clean.diff_leaves(&dirty), vec![20_000 / 4096]);
    }

    #[test]
    fn leaf_ranges_partition_file() {
        let t = build(&vec![9u8; 10_500], 4096, HashAlgorithm::Md5, 4096);
        assert_eq!(t.leaf_count(), 3);
        assert_eq!(t.leaf_range(0), (0, 4096));
        assert_eq!(t.leaf_range(1), (4096, 4096));
        assert_eq!(t.leaf_range(2), (8192, 10_500 - 8192));
        assert_eq!(t.leaves_touching(4000, 200), 0..2);
        assert_eq!(t.leaves_touching(8192, 1), 2..3);
        assert_eq!(t.leaves_touching(0, 0), 0..0);
    }

    #[test]
    fn recompute_paths_matches_full_rebuild() {
        let mut data = vec![0u8; 50_000];
        SplitMix64::new(11).fill_bytes(&mut data);
        let f = factory(HashAlgorithm::Sha256);
        let mut t = build(&data, 1000, HashAlgorithm::Sha256, 1234);
        // Corrupt three scattered leaves' spans and patch incrementally.
        data[500] ^= 1;
        data[25_250] ^= 2;
        data[49_999] ^= 4;
        let fresh = build(&data, 1000, HashAlgorithm::Sha256, 1234);
        for leaf in [0usize, 25, 49] {
            let (off, len) = t.leaf_range(leaf);
            let mut h = HashAlgorithm::Sha256.hasher();
            h.update(&data[off as usize..(off + len) as usize]);
            t.set_leaf(leaf, h.finalize());
        }
        t.recompute_paths(&[0, 25, 49], &f);
        assert_eq!(t.root(), fresh.root());
        for level in 0..t.height() {
            for i in 0..t.level_width(level) {
                assert_eq!(t.node(level, i), fresh.node(level, i), "level {level} node {i}");
            }
        }
    }

    #[test]
    fn nodes_concat_clips_to_width() {
        let t = build(&vec![1u8; 5000], 1000, HashAlgorithm::Md5, 500);
        assert_eq!(t.level_width(0), 5);
        let all = t.nodes_concat(0, 0, 100);
        assert_eq!(all.len(), 5 * t.leaf_len());
        assert_eq!(t.nodes_concat(0, 4, 2).len(), t.leaf_len());
        assert!(t.nodes_concat(0, 9, 2).is_empty());
        assert!(t.nodes_concat(99, 0, 2).is_empty());
    }

    #[test]
    fn with_prefix_matches_full_stream_build() {
        // Seeding a builder with the first k leaf digests and streaming
        // only the tail must yield the tree of the full stream — the
        // resume-verification invariant.
        let mut data = vec![0u8; 47_000];
        SplitMix64::new(21).fill_bytes(&mut data);
        let f = factory(HashAlgorithm::Md5);
        let full = build(&data, 4096, HashAlgorithm::Md5, 1234);
        for k in [1usize, 5, 11] {
            let cut = k * 4096;
            let dlen = full.leaf_len();
            let prefix = full.levels[0][..k * dlen].to_vec();
            let mut b = MerkleBuilder::with_prefix(4096, prefix, cut as u64, f.clone());
            for part in data[cut..].chunks(999) {
                b.update(part);
            }
            let resumed = b.finish();
            assert_eq!(resumed.root(), full.root(), "k={k}");
            assert_eq!(resumed.leaf_count(), full.leaf_count());
            assert_eq!(resumed.file_size(), data.len() as u64);
        }
    }

    #[test]
    fn lone_child_is_rehashed_not_promoted() {
        // 3 leaves: level 1 = [H(l0||l1), H(l2)]. If the lone child were
        // promoted verbatim, a tree of [x] and a tree whose last internal
        // node is x would collide.
        let t = build(&vec![7u8; 3000], 1000, HashAlgorithm::Md5, 1000);
        assert_ne!(t.node(1, 1), t.node(0, 2));
    }

    fn build_tiered(data: &[u8], leaf: u64, chunk: usize) -> MerkleTree {
        let mut b = MerkleBuilder::new(leaf, factory(HashAlgorithm::Xxh3128))
            .with_tree_hasher(factory(HashAlgorithm::Sha256), true);
        for part in data.chunks(chunk.max(1)) {
            b.update(part);
        }
        b.finish()
    }

    #[test]
    fn tiered_tree_has_two_strides_and_crypto_root() {
        let mut data = vec![0u8; 9000];
        SplitMix64::new(5).fill_bytes(&mut data);
        let t = build_tiered(&data, 1000, 777);
        assert_eq!(t.leaf_len(), 16, "xxh3-128 leaves");
        assert_eq!(t.node_len(), 32, "sha256 interior");
        assert_eq!(t.level_len(0), 16);
        for level in 1..t.height() {
            assert_eq!(t.level_len(level), 32);
        }
        assert_eq!(t.root().len(), 32);
        assert_eq!(t.leaf_count(), 9);
        // Same shape as a uniform tree over 9 leaves.
        assert_eq!(t.level_width(1), 5);
        assert_eq!(t.height(), 5);
        // Building twice is deterministic and chunk-independent.
        assert_eq!(t.root(), build_tiered(&data, 1000, 9000).root());
    }

    #[test]
    fn tiered_single_leaf_still_gets_crypto_root() {
        // A rooted tree folds even one leaf: the root must be node-tier,
        // or small files would lose the cryptographic anchor entirely.
        let t = build_tiered(b"tiny", 1024, 4);
        assert_eq!(t.leaf_count(), 1);
        assert_eq!(t.height(), 2);
        assert_eq!(t.node(0, 0).len(), 16);
        assert_eq!(t.root().len(), 32);
        // An empty file folds the same way.
        let e = build_tiered(&[], 1024, 1);
        assert_eq!(e.height(), 2);
        assert_eq!(e.root().len(), 32);
        assert_ne!(e.root(), t.root());
    }

    #[test]
    fn tiered_recompute_paths_matches_full_rebuild() {
        let mut data = vec![0u8; 50_000];
        SplitMix64::new(13).fill_bytes(&mut data);
        let mut t = build_tiered(&data, 1000, 1234);
        data[500] ^= 1;
        data[25_250] ^= 2;
        data[49_999] ^= 4;
        let fresh = build_tiered(&data, 1000, 1234);
        assert_eq!(t.diff_leaves(&fresh), vec![0, 25, 49]);
        for leaf in [0usize, 25, 49] {
            let (off, len) = t.leaf_range(leaf);
            let mut h = HashAlgorithm::Xxh3128.hasher();
            h.update(&data[off as usize..(off + len) as usize]);
            t.set_leaf(leaf, h.finalize());
        }
        t.recompute_paths(&[0, 25, 49], &factory(HashAlgorithm::Sha256));
        assert_eq!(t.root(), fresh.root());
        for level in 0..t.height() {
            for i in 0..t.level_width(level) {
                assert_eq!(t.node(level, i), fresh.node(level, i), "level {level} node {i}");
            }
        }
    }
}
