//! Artifact manifest: discovers and describes the AOT-lowered HLO modules.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::hashes::fvr256::Geometry;
use crate::util::json::Json;

/// One lowered chunk-size variant from `manifest.json`.
#[derive(Debug, Clone)]
pub struct VariantInfo {
    /// Variant name (keyed by chunk geometry).
    pub name: String,
    /// Chunk geometry the variant was compiled for.
    pub geometry: Geometry,
    /// HLO text file of the Pallas-kernel pipeline.
    pub artifact: String,
    /// HLO text file of the pure-jnp reference pipeline (for A/B tests).
    pub artifact_ref: String,
}

impl VariantInfo {
    /// Bytes per hashing chunk under this geometry.
    pub fn chunk_bytes(&self) -> usize {
        self.geometry.chunk_bytes()
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Compiled variants listed by the manifest.
    pub variants: Vec<VariantInfo>,
}

impl Manifest {
    /// Load the manifest from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        let mut variants = Vec::new();
        for v in root
            .get("variants")
            .and_then(|v| v.as_arr())
            .context("manifest missing `variants`")?
        {
            let name = v.get("name").and_then(|j| j.as_str()).context("variant name")?;
            let num_blocks =
                v.get("num_blocks").and_then(|j| j.as_u64()).context("num_blocks")? as usize;
            let wpb = v
                .get("words_per_block")
                .and_then(|j| j.as_u64())
                .context("words_per_block")? as usize;
            let geometry = Geometry::new(num_blocks, wpb);
            geometry.validate()?;
            variants.push(VariantInfo {
                name: name.to_string(),
                geometry,
                artifact: v
                    .get("artifact")
                    .and_then(|j| j.as_str())
                    .context("artifact")?
                    .to_string(),
                artifact_ref: v
                    .get("artifact_ref")
                    .and_then(|j| j.as_str())
                    .context("artifact_ref")?
                    .to_string(),
            });
        }
        if variants.is_empty() {
            bail!("manifest lists no variants");
        }
        Ok(Manifest { dir: dir.to_path_buf(), variants })
    }

    /// Find a variant by name ("256k", "1m", "4m").
    pub fn variant(&self, name: &str) -> Result<&VariantInfo> {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .with_context(|| format!("no artifact variant named `{name}`"))
    }

    /// Find the variant matching a geometry.
    pub fn variant_for(&self, geo: Geometry) -> Result<&VariantInfo> {
        self.variants
            .iter()
            .find(|v| v.geometry == geo)
            .with_context(|| format!("no artifact variant with geometry {geo:?}"))
    }

    /// Absolute path of a variant's HLO file.
    pub fn hlo_path(&self, v: &VariantInfo, use_ref: bool) -> PathBuf {
        self.dir.join(if use_ref { &v.artifact_ref } else { &v.artifact })
    }
}

/// Locate the artifacts directory: `$FIVER_ARTIFACTS`, else `./artifacts`,
/// else walking up from the current directory (so tests and examples work
/// from any workspace subdirectory).
pub fn find_artifacts_dir() -> Result<PathBuf> {
    if let Ok(dir) = std::env::var("FIVER_ARTIFACTS") {
        let p = PathBuf::from(dir);
        if p.join("manifest.json").exists() {
            return Ok(p);
        }
        bail!("$FIVER_ARTIFACTS={} has no manifest.json", p.display());
    }
    let mut cur = std::env::current_dir()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Ok(cand);
        }
        if !cur.pop() {
            bail!("artifacts/ not found (run `make artifacts` at the repo root)");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        find_artifacts_dir().ok().and_then(|d| Manifest::load(&d).ok())
    }

    #[test]
    fn loads_manifest_with_expected_variants() {
        let Some(m) = manifest() else { return }; // skip if artifacts absent
        let names: Vec<&str> = m.variants.iter().map(|v| v.name.as_str()).collect();
        assert!(names.contains(&"1m"), "variants: {names:?}");
        let v = m.variant("1m").unwrap();
        assert_eq!(v.geometry, Geometry::DEFAULT);
        assert_eq!(v.chunk_bytes(), 1 << 20);
        assert!(m.hlo_path(v, false).exists());
        assert!(m.hlo_path(v, true).exists());
    }

    #[test]
    fn variant_lookup_by_geometry() {
        let Some(m) = manifest() else { return };
        assert!(m.variant_for(Geometry::SMALL).is_ok());
        assert!(m.variant_for(Geometry::TINY).is_err());
    }

    #[test]
    fn unknown_variant_errors() {
        let Some(m) = manifest() else { return };
        assert!(m.variant("16m").is_err());
    }
}
