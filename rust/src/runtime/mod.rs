//! XLA/PJRT runtime: loads the AOT-compiled FVR-256 chunk-digest artifacts
//! (HLO text emitted by `python/compile/aot.py`) and executes them on the
//! PJRT CPU client from the Rust transfer path.
//!
//! This is the boundary of the three-layer architecture: everything below
//! this module is plain Rust; everything that produced `artifacts/` was
//! build-time Python. The calling convention is pinned by
//! `artifacts/manifest.json`:
//!
//! ```text
//! params:  u32[B*W] chunk words (LE-packed), u32[1] true byte length,
//!          u32[1] chunk index
//! result:  1-tuple of u32[8]  (lowered with return_tuple=True)
//! ```
//!
//! [`XlaHashEngine`] owns the compiled executables; [`FvrHasher`] is the
//! streaming [`crate::hashes::Hasher`] that offloads chunk digests to the
//! engine and chains them natively (bit-exact with
//! [`crate::hashes::fvr256`]).

mod artifact;
mod engine;
mod fvr_hasher;

pub use artifact::{find_artifacts_dir, Manifest, VariantInfo};
pub use engine::XlaHashEngine;
pub use fvr_hasher::FvrHasher;
